"""Benchmark harness: one function per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` CSV (and a roofline summary if dry-run
records exist under experiments/dryrun/), and writes a machine-readable
``BENCH_power.json`` (``{bench_name: us_per_call}``) at the repo root so
the perf trajectory is tracked across PRs.

``--gate [PCT]`` turns the run into a CI perf check: fresh timings are
compared against the committed ``BENCH_power.json`` and the process exits
non-zero if any tracked bench regressed by more than PCT percent (default
25).  Quick runs (``--quick``) compare against the ``quick:``-prefixed
baseline entries (quick workloads are smaller, so their timings live in a
separate namespace); seed them once with ``--quick --update-baseline``.
``python benchmarks/run.py --quick --gate`` is then a one-command CI smoke:
correctness asserts (engine agreement) + perf regression gate.

``--profile`` appends a per-bench phase breakdown (render / solve /
kernel / host-sync) for the registered campus workloads, via the
``core.profiling`` spans in the host engine — so a perf PR can see where
the time goes before guessing.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def gate_records(
    records: dict[str, float],
    baseline: dict[str, float],
    pct: float,
    quick: bool,
) -> list[str]:
    """Regression check: every fresh timing vs its committed baseline entry.

    Returns human-readable failure lines (empty = gate passes).  Benches
    without a baseline entry are skipped — a new bench cannot fail the
    gate before its baseline is recorded.
    """
    failures = []
    for name, us in records.items():
        prev = baseline.get(f"quick:{name}" if quick else name)
        if not prev:
            continue
        reg = (us / prev - 1.0) * 100.0
        if reg > pct:
            failures.append(
                f"{name}: {prev:.0f}us -> {us:.0f}us (+{reg:.0f}% > {pct:.0f}%)"
            )
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--quick",
        action="store_true",
        help="CI smoke mode: shrink fleet sizes / trace durations and skip "
        "writing BENCH_power.json (timings are not comparable to full runs)",
    )
    ap.add_argument(
        "--gate",
        nargs="?",
        const=25.0,
        type=float,
        default=None,
        metavar="PCT",
        help="exit non-zero if any tracked bench regressed >PCT%% vs the "
        "committed BENCH_power.json (default 25); implies no baseline "
        "rewrite unless --update-baseline",
    )
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="write this run's timings into BENCH_power.json (quick runs "
        "record under 'quick:'-prefixed keys; the default full run writes "
        "anyway unless --gate is set)",
    )
    ap.add_argument(
        "--profile",
        action="store_true",
        help="after the delta table, re-run each campus bench workload "
        "through the host engine with core.profiling phase spans enabled "
        "and print a render/solve/kernel/host-sync breakdown",
    )
    args = ap.parse_args()
    # A pre-set env var also selects quick sizes (they bind when the bench
    # modules import), so treat it exactly like --quick — otherwise quick
    # timings would silently overwrite the tracked BENCH_power.json.
    quick = args.quick or os.environ.get("REPRO_BENCH_QUICK", "") == "1"
    if quick:
        # must be set before the bench modules import (sizes bind at import)
        os.environ["REPRO_BENCH_QUICK"] = "1"

    # Make both ``repro`` and the ``benchmarks`` package importable when run
    # as a plain script (``python benchmarks/run.py``) from anywhere.
    sys.path.insert(0, _REPO_ROOT)
    sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))
    from benchmarks import kernel_benches, paper_benches

    # The tracked trajectory from the previous PR: read it BEFORE the run so
    # the per-bench delta is printed even when this run overwrites the file.
    bench_path = os.path.join(_REPO_ROOT, "BENCH_power.json")
    baseline: dict[str, float] = {}
    if os.path.exists(bench_path):
        with open(bench_path) as f:
            baseline = json.load(f)

    print("name,us_per_call,derived")
    failures = 0
    records: dict[str, float] = {}
    for fn in paper_benches.ALL + kernel_benches.ALL:
        try:
            name, us, derived = fn()
            records[name] = round(float(us), 1)
            print(f"{name},{us:.0f},{derived}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}")
        sys.stdout.flush()

    # Per-bench delta vs the previous BENCH_power.json + derived metrics
    # (us/rack, samples/s) for the benches that registered their workload
    # size in paper_benches.UNITS.  Quick runs shrink the workloads, so
    # their timings are not comparable to the tracked baseline — skip.
    if not quick:
        header = "prev_us,now_us,speedup,us_per_rack,samples_per_s"
        print(f"\n# perf trajectory vs previous BENCH_power.json\n# name,{header}")
        for name, us in records.items():
            prev = baseline.get(name)
            prev_s = f"{prev:.0f}" if prev else "-"
            speedup = f"{prev / us:.2f}x" if prev else "-"
            units = paper_benches.UNITS.get(name, {})
            upr = f"{us / units['racks']:.0f}" if units.get("racks") else "-"
            sps = f"{units['samples'] / (us / 1e6):.2e}" if units.get("samples") else "-"
            print(f"# {name},{prev_s},{us:.0f},{speedup},{upr},{sps}")

    # Per-bench phase breakdown: each registered campus workload re-runs
    # through the HOST engine (the one whose render / solve / assemble
    # stages are host-visible) with ``core.profiling`` spans enabled.  The
    # solve phase fuses the controller QP and the hardware megakernel into
    # one program, so the kernel share is estimated from one standalone
    # interval (``profile_kernel_estimate``) — printed as ``kernel_est``
    # and NOT subtracted from ``solve``.  Phases are serialized by the
    # profiler (dispatches block inside their span), so the profiled total
    # sits slightly above the bench's async wall clock.
    if args.profile:
        from repro.core import fleet, profiling

        print("\n# per-bench phase breakdown (host-engine re-run, us)")
        print("# name,render,solve,kernel_est,host_sync,total")
        for name, w in paper_benches.PROFILES.items():
            run = lambda: fleet.condition(
                w["scenario"], w["cfg"], w["spec"], engine="host",
                stream=fleet.StreamOptions(
                    chunk_intervals=w["chunk_intervals"]),
                qp_iters=w["qp_iters"],
            )
            run()  # compile outside the spans
            profiling.enable()
            try:
                run()
                ph = profiling.phases()
            finally:
                profiling.disable()
            kern = paper_benches.profile_kernel_estimate(w)
            total = sum(ph.values())
            print(
                f"# {name},{ph.get('render', 0.0) * 1e6:.0f},"
                f"{ph.get('solve', 0.0) * 1e6:.0f},{kern * 1e6:.0f},"
                f"{ph.get('host-sync', 0.0) * 1e6:.0f},{total * 1e6:.0f}"
            )
            sys.stdout.flush()

    # Baseline writes.  A gated run never rewrites its own reference unless
    # explicitly asked; quick entries live under "quick:" so full-run
    # numbers and CI-smoke numbers can coexist in one file.
    write = (not quick and args.gate is None) or args.update_baseline
    if write:
        if quick:
            merged = dict(baseline)
            merged.update({f"quick:{k}": v for k, v in records.items()})
        else:
            merged = {k: v for k, v in baseline.items() if k.startswith("quick:")}
            merged.update(records)
        with open(bench_path, "w") as f:
            json.dump(merged, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {bench_path} ({len(records)} benches)")
    elif quick:
        print(f"# --quick smoke run: BENCH_power.json not written ({len(records)} benches ran)")

    # roofline summary from dry-run records, if present
    recs = sorted(glob.glob("experiments/dryrun/*__16_16.json"))
    if recs:
        print("\n# roofline (single-pod dry-run records)")
        print("cell,bottleneck,compute_s,memory_s,collective_s,useful_flop_ratio,fits_16gb")
        for p in recs:
            r = json.load(open(p))
            rl = r["roofline"]
            print(
                f"{r['arch']}/{r['shape']},{rl['bottleneck']},{rl['compute_s']:.4f},"
                f"{rl['memory_s']:.4f},{rl['collective_s']:.4f},"
                f"{r['useful_flop_ratio']:.3f},{r['fits_16gb']}"
            )

    if args.gate is not None:
        # A gated run also fails on bench-internal assertion errors (e.g.
        # the safe-mode supervision-overhead budget), not just timing
        # regressions vs the baseline.
        if failures:
            print(f"\n# PERF GATE FAILED ({failures} bench(es) errored)")
            sys.exit(1)
        gate_failures = gate_records(records, baseline, args.gate, quick)
        if gate_failures:
            print(f"\n# PERF GATE FAILED (>{args.gate:.0f}% regression):")
            for line in gate_failures:
                print(f"#   {line}")
            sys.exit(1)
        compared = sum(
            1 for n in records if baseline.get(f"quick:{n}" if quick else n)
        )
        print(f"\n# perf gate OK ({compared}/{len(records)} benches vs baseline, "
              f"threshold {args.gate:.0f}%)")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
