"""Benchmark harness: one function per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` CSV (and a roofline summary if dry-run
records exist under experiments/dryrun/), and writes a machine-readable
``BENCH_power.json`` (``{bench_name: us_per_call}``) at the repo root so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--quick",
        action="store_true",
        help="CI smoke mode: shrink fleet sizes / trace durations and skip "
        "writing BENCH_power.json (timings are not comparable)",
    )
    args = ap.parse_args()
    # A pre-set env var also selects quick sizes (they bind when the bench
    # modules import), so treat it exactly like --quick — otherwise quick
    # timings would silently overwrite the tracked BENCH_power.json.
    quick = args.quick or os.environ.get("REPRO_BENCH_QUICK", "") == "1"
    if quick:
        # must be set before the bench modules import (sizes bind at import)
        os.environ["REPRO_BENCH_QUICK"] = "1"

    # Make both ``repro`` and the ``benchmarks`` package importable when run
    # as a plain script (``python benchmarks/run.py``) from anywhere.
    sys.path.insert(0, _REPO_ROOT)
    sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))
    from benchmarks import kernel_benches, paper_benches

    # The tracked trajectory from the previous PR: read it BEFORE the run so
    # the per-bench delta is printed even when this run overwrites the file.
    bench_path = os.path.join(_REPO_ROOT, "BENCH_power.json")
    baseline: dict[str, float] = {}
    if os.path.exists(bench_path):
        with open(bench_path) as f:
            baseline = json.load(f)

    print("name,us_per_call,derived")
    failures = 0
    records: dict[str, float] = {}
    for fn in paper_benches.ALL + kernel_benches.ALL:
        try:
            name, us, derived = fn()
            records[name] = round(float(us), 1)
            print(f"{name},{us:.0f},{derived}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}")
        sys.stdout.flush()

    # Per-bench delta vs the previous BENCH_power.json + derived metrics
    # (us/rack, samples/s) for the benches that registered their workload
    # size in paper_benches.UNITS.  Quick runs shrink the workloads, so
    # their timings are not comparable to the tracked baseline — skip.
    if not quick:
        header = "prev_us,now_us,speedup,us_per_rack,samples_per_s"
        print(f"\n# perf trajectory vs previous BENCH_power.json\n# name,{header}")
        for name, us in records.items():
            prev = baseline.get(name)
            prev_s = f"{prev:.0f}" if prev else "-"
            speedup = f"{prev / us:.2f}x" if prev else "-"
            units = paper_benches.UNITS.get(name, {})
            upr = f"{us / units['racks']:.0f}" if units.get("racks") else "-"
            sps = f"{units['samples'] / (us / 1e6):.2e}" if units.get("samples") else "-"
            print(f"# {name},{prev_s},{us:.0f},{speedup},{upr},{sps}")

    if quick:
        print(f"# --quick smoke run: BENCH_power.json not written ({len(records)} benches ran)")
    else:
        with open(bench_path, "w") as f:
            json.dump(records, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {bench_path} ({len(records)} benches)")

    # roofline summary from dry-run records, if present
    recs = sorted(glob.glob("experiments/dryrun/*__16_16.json"))
    if recs:
        print("\n# roofline (single-pod dry-run records)")
        print("cell,bottleneck,compute_s,memory_s,collective_s,useful_flop_ratio,fits_16gb")
        for p in recs:
            r = json.load(open(p))
            rl = r["roofline"]
            print(
                f"{r['arch']}/{r['shape']},{rl['bottleneck']},{rl['compute_s']:.4f},"
                f"{rl['memory_s']:.4f},{rl['collective_s']:.4f},"
                f"{r['useful_flop_ratio']:.3f},{r['fits_16gb']}"
            )
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
