"""Kernel micro-benchmarks: jnp reference path timings on this host (CPU)
plus the structural roofline numbers that matter for the TPU target
(FLOPs/bytes per call; the Pallas kernels themselves are validated in
interpret mode and only meaningful to time on real TPUs)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import filters, sizing
from repro.core.pdu import per_unit_filter
from repro.kernels import ops


def _timeit(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6, out


def bench_lc_filter():
    s = sizing.size_system(sizing.prototype_rack(), beta=0.0625)
    pp = per_unit_filter(s, sizing.prototype_rack())
    filt = filters.make_discrete_filter(pp, 1e-3)
    t, r = 60_000, 128
    u = 0.5 + 0.3 * jax.random.uniform(jax.random.key(0), (t, r))
    x0 = jnp.tile(filters.steady_state(filt, jnp.array([1.0, 0.5])), (r, 1))
    f = jax.jit(lambda uu: ops.lc_filter(filt.ad, filt.bd, filt.c[0], x0, uu)[0])
    us, _ = _timeit(f, u)
    samples_per_s = t * r / (us / 1e6)
    return "kernel_lc_filter", us, f"{samples_per_s/1e6:.1f}M rack-samples/s (60s x 128 racks @1kHz)"


def _pdu_sim_problem():
    s = sizing.size_system(sizing.prototype_rack(), beta=0.0625)
    pp = per_unit_filter(s, sizing.prototype_rack())
    filt = filters.make_discrete_filter(pp, 1e-3)
    t, r = 60_000, 128
    u = 0.3 + 0.6 * jax.random.uniform(jax.random.key(1), (t, r))
    x0 = jnp.tile(filters.steady_state(filt, jnp.array([1.0, 0.5])), (r, 1))
    kw = dict(beta=0.0625, dt=1e-3, q_max=40.0, eta_c=0.97, eta_d=0.97,
              p_max=1.0, soc_min=0.1, soc_max=0.9)
    return filt, t, r, u, x0, kw


def bench_pdu_sim_fused():
    """Unmasked variant: every ESS healthy (no availability weight)."""
    filt, t, r, u, x0, kw = _pdu_sim_problem()
    corr = jnp.zeros((t, r))
    f = jax.jit(lambda uu: ops.pdu_sim(uu, uu[0], jnp.full((r,), 0.5), x0,
                                       filt.ad, filt.bd, filt.c[0], corr, **kw)[0])
    us, _ = _timeit(f, u)
    return "kernel_pdu_sim", us, f"{t*r/(us/1e6)/1e6:.1f}M rack-samples/s fused (1 HBM pass)"


def bench_pdu_sim_masked():
    """Masked variant: time-varying (T, R) availability weight — the
    degraded-mode path (failures + fractional wind-down ramps)."""
    filt, t, r, u, x0, kw = _pdu_sim_problem()
    corr = jnp.zeros((t, r))
    # ~12% of racks degraded, with a fractional ramp over the first 4s
    mask = (jax.random.uniform(jax.random.key(7), (r,)) > 0.12).astype(jnp.float32)
    ramp = jnp.clip(jnp.arange(t, dtype=jnp.float32)[:, None] / 4000.0, 0.0, 1.0)
    ess_on = mask[None, :] + (1.0 - mask[None, :]) * (1.0 - ramp)
    f = jax.jit(lambda uu, w: ops.pdu_sim(uu, uu[0], jnp.full((r,), 0.5), x0,
                                          filt.ad, filt.bd, filt.c[0], corr,
                                          ess_on=w, **kw)[0])
    us, _ = _timeit(f, u, ess_on)
    return "kernel_pdu_sim_masked", us, f"{t*r/(us/1e6)/1e6:.1f}M rack-samples/s with (T,R) weight"


def bench_attention():
    b, h, t, d = 4, 8, 1024, 64
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (b, h, t, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, t, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, t, d), jnp.float32)
    f = jax.jit(lambda a, b2, c: ops.attention(a, b2, c, causal=True))
    us, _ = _timeit(f, q, k, v)
    fl = 4 * b * h * t * t * d / 2  # causal half
    return "kernel_attention", us, f"{fl/(us/1e6)/1e9:.1f} GFLOP/s host-ref (TPU target: Pallas)"


def bench_attention_bwd():
    """Forward + backward through ops.attention (host path: XLA autodiff;
    TPU target: the fused FlashAttention-2 dK/dV + dQ Pallas kernels)."""
    b, h, t, d = 4, 8, 1024, 64
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (b, h, t, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, t, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, t, d), jnp.float32)
    f = jax.jit(jax.grad(
        lambda a, b2, c: jnp.sum(ops.attention(a, b2, c, causal=True)),
        argnums=(0, 1, 2),
    ))
    us, _ = _timeit(f, q, k, v)
    fl = (4 + 8) * b * h * t * t * d / 2  # fwd + ~2x bwd, causal half
    return "kernel_attention_bwd", us, f"{fl/(us/1e6)/1e9:.1f} GFLOP/s host-ref fwd+bwd"


def bench_rwkv6():
    b, h, t, d = 2, 8, 1024, 64
    ks = jax.random.split(jax.random.key(3), 5)
    r = jax.random.normal(ks[0], (b, h, t, d)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, d)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, d))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    f = jax.jit(lambda *a: ops.rwkv6_scan(*a)[0])
    us, _ = _timeit(f, r, k, v, w, u)
    return "kernel_rwkv6", us, f"{b*h*t/(us/1e6)/1e3:.0f}K head-tokens/s host-ref"


def bench_rmsnorm():
    x = jax.random.normal(jax.random.key(4), (8192, 4096), jnp.float32)
    w = jnp.ones((4096,))
    f = jax.jit(lambda a: ops.rmsnorm(a, w))
    us, _ = _timeit(f, x)
    gb = 2 * x.size * 4 / 1e9
    return "kernel_rmsnorm", us, f"{gb/(us/1e6):.1f} GB/s host-ref (memory-bound)"


def bench_gemm_burn():
    a = jax.random.normal(jax.random.key(5), (512, 512), jnp.float32)
    b2 = jax.random.normal(jax.random.key(6), (512, 512), jnp.float32)
    f = jax.jit(lambda x, y: ops.gemm_burn(x, y, n_iters=4))
    us, _ = _timeit(f, a, b2)
    fl = 4 * 2 * 512**3
    return "kernel_gemm_burn", us, f"{fl/(us/1e6)/1e9:.1f} GFLOP/s burned (duty-cycle knob x4)"


ALL = [bench_lc_filter, bench_pdu_sim_fused, bench_pdu_sim_masked,
       bench_attention, bench_attention_bwd, bench_rwkv6,
       bench_rmsnorm, bench_gemm_burn]
