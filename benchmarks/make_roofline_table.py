"""Render the dry-run JSON records into the EXPERIMENTS.md roofline tables."""
import glob
import json
import sys


def fmt(v, nd=3):
    if v == 0:
        return "0"
    if v < 1e-3:
        return f"{v:.1e}"
    return f"{v:.{nd}f}"


def table(mesh_tag: str) -> str:
    recs = []
    for p in sorted(glob.glob(f"experiments/dryrun/*__{mesh_tag}.json")):
        recs.append(json.load(open(p)))
    lines = [
        "| arch | shape | mem/chip GB | fits 16GB | compute s | memory s | collective s | bottleneck | useful-FLOP ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['bytes_per_device']['total_gb']} | "
            f"{'yes' if r['fits_16gb'] else 'NO'} | {fmt(rl['compute_s'],4)} | "
            f"{fmt(rl['memory_s'],4)} | {fmt(rl['collective_s'],4)} | "
            f"{rl['bottleneck']} | {fmt(r['useful_flop_ratio'],3)} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    tag = sys.argv[1] if len(sys.argv) > 1 else "16_16"
    print(table(tag))
