"""Render the dry-run JSON records into the EXPERIMENTS.md roofline tables,
plus the structural roofline of the repo's Pallas kernels (analytic
FLOPs / HBM bytes per call at the bench shapes — what decides
memory-vs-compute bound on the TPU target, independent of this host)."""
import glob
import json
import sys


def fmt(v, nd=3):
    if v == 0:
        return "0"
    if v < 1e-3:
        return f"{v:.1e}"
    return f"{v:.{nd}f}"


def table(mesh_tag: str) -> str:
    recs = []
    for p in sorted(glob.glob(f"experiments/dryrun/*__{mesh_tag}.json")):
        recs.append(json.load(open(p)))
    lines = [
        "| arch | shape | mem/chip GB | fits 16GB | compute s | memory s | collective s | bottleneck | useful-FLOP ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['bytes_per_device']['total_gb']} | "
            f"{'yes' if r['fits_16gb'] else 'NO'} | {fmt(rl['compute_s'],4)} | "
            f"{fmt(rl['memory_s'],4)} | {fmt(rl['collective_s'],4)} | "
            f"{rl['bottleneck']} | {fmt(r['useful_flop_ratio'],3)} |"
        )
    return "\n".join(lines)


def kernel_rows():
    """(name, shape, flops/call, hbm bytes/call) for each Pallas kernel at
    its kernel_benches.py shape.  Analytic counts: per-element op counts
    read off the kernel bodies, HBM traffic = operands each kernel actually
    streams (VMEM-resident state/scratch excluded — that is the point)."""
    rows = []
    t, r = 60_000, 128  # lc_filter / pdu_sim bench shape
    # LC 3-state filter: ad@x (18) + bd*u (6) + c@x (6) per rack-sample.
    rows.append(("lc_filter", f"T={t} R={r}", 30 * t * r, (t * r * 2) * 4))
    # Fused pdu_sim: ESS ramp/clip/soc (~14) + LC (30) per rack-sample;
    # streams u + corrective in, grid + soc out.
    rows.append(("pdu_sim", f"T={t} R={r}", 44 * t * r, (t * r * 4) * 4))
    # Interval-resident megakernel: pdu_sim math + in-kernel slew render
    # (4) + health turning-point fold (~25) per rack-sample; the slew pair
    # replaces the (T, R) corrective stream, so HBM is ONE read (trace) +
    # two writes (grid, soc) — wear state never leaves VMEM.
    ti, ri = 1000, 1024  # one 5 s controller interval @ 200 Hz, campus width
    rows.append(("pdu_health (megakernel)", f"T={ti} R={ri}",
                 73 * ti * ri, (ti * ri * 3) * 4))
    # Batched ADMM step: per iter per rack the stacked K^-1 GEMM
    # 2n(n+m) + the constraint GEMM 2(m-2h)n + ~6m+2n vector ops, with
    # x/z/y and the plan matrices VMEM-resident across all iters; HBM is
    # the one-time operand read + final x/z/y write.
    h, iters = 12, 30
    n, m = h, 3 * h
    per_iter = 2 * n * (n + m) + 2 * (m - 2 * h) * n + 6 * m + 2 * n
    rows.append(("admm_step (batched)", f"h={h} iters={iters} R={ri}",
                 per_iter * iters * ri,
                 ((n + m) * (n + n + m) + (n + 5 * m) * ri + 3 * m * ri) * 4))
    # FlashAttention-2 forward: 4·t²·d FLOPs (qk^T + pv), causal half.
    b, hh, tt, d = 4, 8, 1024, 64
    fa_f = 4 * b * hh * tt * tt * d // 2
    fa_io = b * hh * tt * d * 4
    rows.append(("flash_attention fwd", f"B={b} H={hh} T={tt} D={d}",
                 fa_f, 4 * fa_io))
    # Backward (dK/dV + dQ kernels): ~2x forward FLOPs, streams q/k/v/o/do
    # + lse/delta in, dq/dk/dv out; tiles revisit HBM once per pass.
    rows.append(("flash_attention bwd", f"B={b} H={hh} T={tt} D={d}",
                 2 * fa_f, 8 * fa_io))
    return rows


def kernel_table() -> str:
    lines = [
        "| kernel | bench shape | GFLOP/call | HBM MB/call | FLOP/byte |",
        "|---|---|---|---|---|",
    ]
    for name, shape, fl, by in kernel_rows():
        lines.append(
            f"| {name} | {shape} | {fmt(fl / 1e9)} | {fmt(by / 1e6, 1)} | "
            f"{fmt(fl / by, 1)} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    tag = sys.argv[1] if len(sys.argv) > 1 else "16_16"
    print(table(tag))
    print("\n## Pallas kernel structural roofline\n")
    print(kernel_table())
