"""One benchmark per paper table/figure (see DESIGN.md §8 index).

Each function returns (name, us_per_call, derived) where ``derived`` is the
paper-comparable headline number(s) as a compact string.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import burn, compliance, controller as ctrl, ess, filters, fleet, pdu, sizing
from repro.power import trace


def _timeit(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6, out


def _conditioned(sample_hz=500.0, duration=240.0, key=0):
    spec = compliance.GridSpec.create()
    cfg = pdu.make_pdu(sample_dt=1.0 / sample_hz)
    sp = trace.TestbenchSpec(duration_s=duration, sample_hz=sample_hz, terminate_at_s=duration - 30)
    rack, dt = trace.testbench_trace(sp, jax.random.key(key))
    st = pdu.init_state(cfg, rack[0])
    f = jax.jit(lambda s, r: pdu.condition(cfg, s, r, qp_iters=40)[0])
    us, grid = _timeit(f, st, rack)
    return spec, cfg, rack, grid, dt, us


def bench_fig9_ramp_rate():
    """Fig. 9: conditioned ramp rate stays within beta = 0.1/s."""
    spec, cfg, rack, grid, dt, us = _conditioned()
    rr = float(compliance.max_abs_ramp(rack, dt))
    rg = float(compliance.max_abs_ramp(grid, dt))
    return "fig9_ramp_rate", us, (
        f"rack_ramp={rr:.1f}/s grid_ramp={rg:.4f}/s beta=0.1 ok={rg <= 0.1}"
    )


def bench_fig10_spectrum():
    """Fig. 10: conditioned spectrum below alpha above f_c."""
    spec, cfg, rack, grid, dt, us = _conditioned(key=1)
    _, sr = compliance.normalized_spectrum(rack, dt)
    fr, sg = compliance.normalized_spectrum(grid, dt)
    above = np.asarray(fr) >= 2.0
    worst_r = float(np.max(np.asarray(sr)[above]))
    worst_g = float(np.max(np.asarray(sg)[above]))
    return "fig10_spectrum", us, (
        f"rack_hf={worst_r:.2e} grid_hf={worst_g:.2e} alpha=1e-4 ok={worst_g <= 1e-4}"
    )


def bench_fig7_frequency_response():
    """Fig. 7: combined response = LC x ESS, -20 then -40 dB/dec."""
    cfg = pdu.make_pdu()
    f = jnp.logspace(-4, 3, 400)
    t0 = time.perf_counter()
    h = pdu.combined_transfer_function(cfg, f)
    us = (time.perf_counter() - t0) * 1e6
    h = np.asarray(h)
    fb = float(cfg.ess_params.cutoff_hz())
    ff = float(cfg.filter_params.cutoff_hz())
    i1, i2 = np.searchsorted(np.asarray(f), [1.0, 10.0])
    slope_mid = np.log10(h[i2] / h[i1])  # ~ -1 (ESS only band)
    i3, i4 = np.searchsorted(np.asarray(f), [30.0, 300.0])
    slope_hi = np.log10(h[i4] / h[i3])  # ~ -3 (ESS+LC)
    return "fig7_response", us, (
        f"f_b={fb:.4f}Hz f_f={ff:.1f}Hz slope(1-10Hz)={slope_mid:.2f}dec "
        f"slope(30-300Hz)={slope_hi:.2f}dec"
    )


def bench_fig11_burn_energy():
    """Fig. 11 / §7.3: software burn vs EasyRider energy overhead."""
    tb, dt = trace.titanx_testbench(jax.random.key(2))
    cal = burn.calibrate(jax.random.key(3), p_idle=0.06, p_peak=1.0)
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, tb[0])
    f = jax.jit(lambda s, r: pdu.condition(cfg, s, r, qp_iters=40))
    us, (gez, _, telem) = _timeit(f, st, tb)
    sched = burn.burn_schedule(tb, dt, beta=0.1, cal=cal)
    nwarm = sched.conditioned.shape[0] - tb.shape[0]
    soc = np.asarray(telem.soc)
    cmp = burn.compare_energy(
        tb, gez, sched.conditioned[nwarm:], dt,
        soc_delta=float(soc[-1]) - 0.5, q_max_seconds=float(cfg.ess_params.q_max),
    )
    return "fig11_burn_energy", us, (
        f"burn_overhead={float(cmp['burn_overhead_frac'])*100:.1f}% "
        f"easyrider_overhead={float(cmp['easyrider_overhead_frac'])*100:.2f}% "
        f"burn_vs_easyrider={float(cmp['burn_vs_easyrider_frac'])*100:.1f}% (paper: 19%)"
    )


def bench_fig12_soc_management():
    """Fig. 12: SoC drift corrected to S_mid within ~20 min."""
    cfg = ctrl.ControllerConfig.create(i_max=4e-3)
    es = ess.ESSParams.create(q_max_seconds=40.0)
    f = jax.jit(lambda: ctrl.simulate_soc_management(cfg, es, 0.62, n_steps=400, qp_iters=80)["soc"])
    us, soc = _timeit(f)
    soc = np.asarray(soc)
    hit = int(np.argmax(np.abs(soc - 0.5) <= float(cfg.deadband)))
    return "fig12_soc", us, (
        f"soc 0.62->{soc[-1]:.3f} converge={hit * 5 / 60:.1f}min (paper ~20min)"
    )


def bench_fig13_cluster_fault():
    """Fig. 13: 40 MW cluster with a computation fault at ~400 s."""
    rack, dt = trace.cluster_fault_trace(jax.random.key(4))
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, rack[0])
    f = jax.jit(lambda s, r: pdu.condition(cfg, s, r, qp_iters=20)[0])
    us, grid = _timeit(f, st, rack)
    # paper's 193.7 MW/s is measured over the fault's ~200 ms fall window
    w = max(int(0.2 / dt), 1)
    rr = float(jnp.max(jnp.abs(rack[w:] - rack[:-w]))) / 0.2 * 40  # MW/s at 40 MW
    rg = float(compliance.max_abs_ramp(grid, dt)) * 40
    return "fig13_cluster_fault", us, (
        f"unconditioned={rr:.1f}MW/s (paper 193.7) conditioned={rg:.2f}MW/s "
        f"ok={float(compliance.max_abs_ramp(grid, dt)) <= 0.1}"
    )


def bench_table1_mitigation_space():
    """Table 1: energy + compliance across mitigation approaches."""
    tb, dt = trace.titanx_testbench(jax.random.key(5))
    spec = compliance.GridSpec.create()
    results = {}
    # none
    results["none"] = (float(jnp.sum(tb)) * dt, bool(compliance.check(tb, dt, spec).ramp_ok))
    # burn
    cal = burn.calibrate(jax.random.key(6), 0.06, 1.0)
    sched = burn.burn_schedule(tb, dt, beta=0.1, cal=cal)
    nwarm = sched.conditioned.shape[0] - tb.shape[0]
    bt = sched.conditioned[nwarm:]
    results["sw_burn"] = (float(jnp.sum(bt)) * dt, bool(compliance.check(bt, dt, spec).ramp_ok))
    # easyrider hw-only and hw+sw
    t0 = time.perf_counter()
    for name, sw in (("easyrider_hw", False), ("easyrider_hw_sw", True)):
        cfg = pdu.make_pdu(sample_dt=dt, software_enabled=sw)
        st = pdu.init_state(cfg, tb[0])
        g, _, _ = pdu.condition(cfg, st, tb, qp_iters=20)
        results[name] = (float(jnp.sum(g)) * dt, bool(compliance.check(g, dt, spec).ramp_ok))
    us = (time.perf_counter() - t0) * 1e6
    base = results["none"][0]
    derived = " ".join(
        f"{k}:E={v[0]/base:.3f}x,ramp_ok={v[1]}" for k, v in results.items()
    )
    return "table1_mitigation", us, derived


def bench_appendixA_sizing():
    """Appendix A.1: sizing table for prototype + 1 MW racks."""
    t0 = time.perf_counter()
    proto = sizing.size_system(sizing.prototype_rack(), beta=0.1)
    mw = sizing.size_system(sizing.mw_rack(), beta=0.1)
    us = (time.perf_counter() - t0) * 1e6
    return "appendixA_sizing", us, (
        f"proto:E_B={proto.battery_energy_j/1e3:.0f}kJ({proto.battery_capacity_ah:.1f}Ah<74Ah)"
        f" P_B={proto.battery_power_w/1e3:.0f}kW | 1MW:E_B={mw.battery_energy_j/1e6:.1f}MJ"
        f" P_B={mw.battery_power_w/1e6:.1f}MW"
    )


def bench_fleet_scale():
    """Appendix D: 128-rack fleet conditioned in one vectorized call."""
    sp = trace.TestbenchSpec(duration_s=44.0, sample_hz=200.0)
    t1, dt = trace.testbench_trace(sp, jax.random.key(7))
    racks = fleet.staggered_fleet(t1, 128, jax.random.key(8), max_offset_samples=800)
    cfg = pdu.make_pdu(sample_dt=dt)
    spec = compliance.GridSpec.create()
    f = jax.jit(lambda tr: fleet.condition_fleet(cfg, tr, spec, qp_iters=10).campus_grid)
    us, campus = _timeit(f, racks, n=1)
    rg = float(compliance.max_abs_ramp(campus, dt))
    per_rack_us = us / 128
    return "fleet_128racks", us, (
        f"campus_ramp={rg:.4f}/s ok={rg <= 0.1} us_per_rack={per_rack_us:.0f}"
    )


ALL = [
    bench_fig7_frequency_response,
    bench_fig9_ramp_rate,
    bench_fig10_spectrum,
    bench_fig11_burn_energy,
    bench_fig12_soc_management,
    bench_fig13_cluster_fault,
    bench_table1_mitigation_space,
    bench_appendixA_sizing,
    bench_fleet_scale,
]
