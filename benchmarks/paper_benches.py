"""One benchmark per paper table/figure (see DESIGN.md §8 index).

Each function returns (name, us_per_call, derived) where ``derived`` is the
paper-comparable headline number(s) as a compact string.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import burn, compliance, controller as ctrl, ess, filters, fleet, pdu, sizing
from repro.power import scenario as SC, trace

# CI smoke mode (`benchmarks/run.py --quick`): shrink fleet sizes and trace
# durations so the whole harness doubles as a fast smoke run.
QUICK = os.environ.get("REPRO_BENCH_QUICK", "") == "1"

# Per-bench workload sizes, registered by the bench functions as they run:
# {bench_name: {"racks": R, "samples": total campus samples}}.  run.py uses
# these to derive us/rack and samples/s next to the raw wall-clock, so the
# perf trajectory is readable across PRs without decoding each derived
# string.
UNITS: dict[str, dict] = {}

# Campus workloads registered by the bench functions as they run, for the
# ``--profile`` pass: {bench_name: {"cfg", "scenario", "spec",
# "chunk_intervals", "qp_iters"}}.  run.py re-runs each through the HOST
# engine (the one whose render/solve/assemble stages are host-visible) with
# ``core.profiling`` spans enabled and prints the phase breakdown.
PROFILES: dict[str, dict] = {}


def _q(full, quick):
    return quick if QUICK else full


def profile_kernel_estimate(w: dict) -> float:
    """Estimated seconds the hardware megakernel contributes to one run of
    the registered workload: one controller interval timed standalone
    (jitted, same backend dispatch the engines use) scaled by the interval
    count.  The in-engine solve phase fuses QP solve + kernel into one
    program, so this standalone estimate is how ``--profile`` splits them.
    """
    cfg, s = w["cfg"], w["scenario"]
    hz = float(s.sample_hz)
    k = max(int(round(float(cfg.controller.dt) * hz)), 1)
    chunk = jax.jit(lambda: SC.render(s, 0, k))()
    if chunk.ndim == 1:
        chunk = chunk[:, None]
    # Kernel-only timing: the engines bridge sensor-dropout NaN before the
    # kernel sees the block, so feed it finite samples.
    chunk = jnp.nan_to_num(chunk, nan=0.0)
    st = pdu.init_state(cfg, chunk[0])
    ep = cfg.ess_params
    filt = st.filter_obj
    kkw = dict(
        beta=float(ep.beta), dt=1.0 / hz, q_max=float(ep.q_max),
        eta_c=float(ep.eta_c), eta_d=float(ep.eta_d),
        p_max=float(ep.p_max), soc_min=float(ep.soc_safe_min),
        soc_max=float(ep.soc_safe_max),
    )
    hin = None
    if getattr(cfg, "track_health", False):
        from repro.core import health as _h

        hin = (_h.step_consts(cfg.health), tuple(st.health))
    from repro.kernels import ops as _ops

    run = jax.jit(lambda c: _ops.pdu_health_sim(
        c, st.ess_state.g_filter, st.ess_state.soc, st.filter_state,
        filt.ad, filt.bd, filt.c[0], health=hin, **kkw,
    ))
    jax.block_until_ready(run(chunk))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(run(chunk))
    per_interval = time.perf_counter() - t0
    return per_interval * (-(-int(s.total_samples) // k))


def _timeit(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6, out


def _conditioned(sample_hz=500.0, duration=None, key=0):
    duration = duration or _q(240.0, 60.0)
    spec = compliance.GridSpec.create()
    cfg = pdu.make_pdu(sample_dt=1.0 / sample_hz)
    sp = trace.TestbenchSpec(duration_s=duration, sample_hz=sample_hz, terminate_at_s=duration - 30)
    rack, dt = trace.testbench_trace(sp, jax.random.key(key))
    st = pdu.init_state(cfg, rack[0])
    f = jax.jit(lambda s, r: pdu.condition(cfg, s, r, qp_iters=40)[0])
    us, grid = _timeit(f, st, rack)
    return spec, cfg, rack, grid, dt, us


def bench_fig9_ramp_rate():
    """Fig. 9: conditioned ramp rate stays within beta = 0.1/s."""
    spec, cfg, rack, grid, dt, us = _conditioned()
    rr = float(compliance.max_abs_ramp(rack, dt))
    rg = float(compliance.max_abs_ramp(grid, dt))
    return "fig9_ramp_rate", us, (
        f"rack_ramp={rr:.1f}/s grid_ramp={rg:.4f}/s beta=0.1 ok={rg <= 0.1}"
    )


def bench_fig10_spectrum():
    """Fig. 10: conditioned spectrum below alpha above f_c."""
    spec, cfg, rack, grid, dt, us = _conditioned(key=1)
    _, sr = compliance.normalized_spectrum(rack, dt)
    fr, sg = compliance.normalized_spectrum(grid, dt)
    above = np.asarray(fr) >= 2.0
    worst_r = float(np.max(np.asarray(sr)[above]))
    worst_g = float(np.max(np.asarray(sg)[above]))
    return "fig10_spectrum", us, (
        f"rack_hf={worst_r:.2e} grid_hf={worst_g:.2e} alpha=1e-4 ok={worst_g <= 1e-4}"
    )


def bench_fig7_frequency_response():
    """Fig. 7: combined response = LC x ESS, -20 then -40 dB/dec."""
    cfg = pdu.make_pdu()
    f = jnp.logspace(-4, 3, 400)
    t0 = time.perf_counter()
    h = pdu.combined_transfer_function(cfg, f)
    us = (time.perf_counter() - t0) * 1e6
    h = np.asarray(h)
    fb = float(cfg.ess_params.cutoff_hz())
    ff = float(cfg.filter_params.cutoff_hz())
    i1, i2 = np.searchsorted(np.asarray(f), [1.0, 10.0])
    slope_mid = np.log10(h[i2] / h[i1])  # ~ -1 (ESS only band)
    i3, i4 = np.searchsorted(np.asarray(f), [30.0, 300.0])
    slope_hi = np.log10(h[i4] / h[i3])  # ~ -3 (ESS+LC)
    return "fig7_response", us, (
        f"f_b={fb:.4f}Hz f_f={ff:.1f}Hz slope(1-10Hz)={slope_mid:.2f}dec "
        f"slope(30-300Hz)={slope_hi:.2f}dec"
    )


def bench_fig11_burn_energy():
    """Fig. 11 / §7.3: software burn vs EasyRider energy overhead."""
    tb, dt = trace.titanx_testbench(jax.random.key(2))
    cal = burn.calibrate(jax.random.key(3), p_idle=0.06, p_peak=1.0)
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, tb[0])
    f = jax.jit(lambda s, r: pdu.condition(cfg, s, r, qp_iters=40))
    us, (gez, _, telem) = _timeit(f, st, tb)
    sched = burn.burn_schedule(tb, dt, beta=0.1, cal=cal)
    nwarm = sched.conditioned.shape[0] - tb.shape[0]
    soc = np.asarray(telem.soc)
    cmp = burn.compare_energy(
        tb, gez, sched.conditioned[nwarm:], dt,
        soc_delta=float(soc[-1]) - 0.5, q_max_seconds=float(cfg.ess_params.q_max),
    )
    return "fig11_burn_energy", us, (
        f"burn_overhead={float(cmp['burn_overhead_frac'])*100:.1f}% "
        f"easyrider_overhead={float(cmp['easyrider_overhead_frac'])*100:.2f}% "
        f"burn_vs_easyrider={float(cmp['burn_vs_easyrider_frac'])*100:.1f}% (paper: 19%)"
    )


def bench_fig12_soc_management():
    """Fig. 12: SoC drift corrected to S_mid within ~20 min."""
    cfg = ctrl.ControllerConfig.create(i_max=4e-3)
    es = ess.ESSParams.create(q_max_seconds=40.0)
    n_steps = _q(400, 80)
    f = jax.jit(lambda: ctrl.simulate_soc_management(cfg, es, 0.62, n_steps=n_steps, qp_iters=80)["soc"])
    us, soc = _timeit(f)
    soc = np.asarray(soc)
    hit = int(np.argmax(np.abs(soc - 0.5) <= float(cfg.deadband)))
    return "fig12_soc", us, (
        f"soc 0.62->{soc[-1]:.3f} converge={hit * 5 / 60:.1f}min (paper ~20min)"
    )


def bench_fig13_cluster_fault():
    """Fig. 13: 40 MW cluster with a computation fault at ~400 s."""
    import dataclasses
    spec = trace.cluster_fault_spec()
    if QUICK:
        spec = dataclasses.replace(spec, duration_s=150.0, warmup_s=10.0,
                                   fault_at_s=80.0, terminate_at_s=130.0)
    rack, dt = trace.testbench_trace(spec, jax.random.key(4))
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, rack[0])
    f = jax.jit(lambda s, r: pdu.condition(cfg, s, r, qp_iters=20)[0])
    us, grid = _timeit(f, st, rack)
    # paper's 193.7 MW/s is measured over the fault's ~200 ms fall window
    w = max(int(0.2 / dt), 1)
    rr = float(jnp.max(jnp.abs(rack[w:] - rack[:-w]))) / 0.2 * 40  # MW/s at 40 MW
    rg = float(compliance.max_abs_ramp(grid, dt)) * 40
    return "fig13_cluster_fault", us, (
        f"unconditioned={rr:.1f}MW/s (paper 193.7) conditioned={rg:.2f}MW/s "
        f"ok={float(compliance.max_abs_ramp(grid, dt)) <= 0.1}"
    )


def bench_table1_mitigation_space():
    """Table 1: energy + compliance across mitigation approaches."""
    tb, dt = trace.titanx_testbench(jax.random.key(5))
    spec = compliance.GridSpec.create()
    results = {}
    # none
    results["none"] = (float(jnp.sum(tb)) * dt, bool(compliance.check(tb, dt, spec).ramp_ok))
    # burn
    cal = burn.calibrate(jax.random.key(6), 0.06, 1.0)
    sched = burn.burn_schedule(tb, dt, beta=0.1, cal=cal)
    nwarm = sched.conditioned.shape[0] - tb.shape[0]
    bt = sched.conditioned[nwarm:]
    results["sw_burn"] = (float(jnp.sum(bt)) * dt, bool(compliance.check(bt, dt, spec).ramp_ok))
    # easyrider hw-only and hw+sw
    t0 = time.perf_counter()
    for name, sw in (("easyrider_hw", False), ("easyrider_hw_sw", True)):
        cfg = pdu.make_pdu(sample_dt=dt, software_enabled=sw)
        st = pdu.init_state(cfg, tb[0])
        g, _, _ = pdu.condition(cfg, st, tb, qp_iters=20)
        results[name] = (float(jnp.sum(g)) * dt, bool(compliance.check(g, dt, spec).ramp_ok))
    us = (time.perf_counter() - t0) * 1e6
    base = results["none"][0]
    derived = " ".join(
        f"{k}:E={v[0]/base:.3f}x,ramp_ok={v[1]}" for k, v in results.items()
    )
    return "table1_mitigation", us, derived


def bench_appendixA_sizing():
    """Appendix A.1: sizing table for prototype + 1 MW racks."""
    t0 = time.perf_counter()
    proto = sizing.size_system(sizing.prototype_rack(), beta=0.1)
    mw = sizing.size_system(sizing.mw_rack(), beta=0.1)
    us = (time.perf_counter() - t0) * 1e6
    return "appendixA_sizing", us, (
        f"proto:E_B={proto.battery_energy_j/1e3:.0f}kJ({proto.battery_capacity_ah:.1f}Ah<74Ah)"
        f" P_B={proto.battery_power_w/1e3:.0f}kW | 1MW:E_B={mw.battery_energy_j/1e6:.1f}MJ"
        f" P_B={mw.battery_power_w/1e6:.1f}MW"
    )


def bench_fleet_scale():
    """Appendix D at campus scale: 1024 racks, cold-start (seed per-interval
    build + factor + vmapped solve, 120 iters) vs the factor-once
    warm-started batched plan (30 iters) at matched QP primal residual."""
    n_racks = _q(1024, 64)
    sp = trace.TestbenchSpec(duration_s=44.0, sample_hz=200.0)
    t1, dt = trace.testbench_trace(sp, jax.random.key(7))
    racks = fleet.staggered_fleet(t1, n_racks, jax.random.key(8), max_offset_samples=800)
    cfg = pdu.make_pdu(sample_dt=dt)

    def run(tr, use_plan, iters):
        st = pdu.init_state(cfg, tr[0])
        grid, _, telem = pdu.condition(cfg, st, tr, qp_iters=iters, use_plan=use_plan)
        return jnp.mean(grid, axis=1), jnp.max(telem.qp_residual)

    f_cold = jax.jit(lambda tr: run(tr, False, 120))
    f_warm = jax.jit(lambda tr: run(tr, True, 30))
    us_cold, (campus_c, resid_c) = _timeit(f_cold, racks, n=1)
    us_warm, (campus_w, resid_w) = _timeit(f_warm, racks, n=1)
    UNITS["fleet_1024racks"] = dict(racks=n_racks, samples=t1.shape[0] * n_racks)
    rg = float(compliance.max_abs_ramp(campus_w, dt))
    speedup = us_cold / us_warm
    return "fleet_1024racks", us_warm, (
        f"campus_ramp={rg:.4f}/s ok={rg <= 0.1} "
        f"cold_us_per_rack={us_cold / n_racks:.0f} "
        f"warm_us_per_rack={us_warm / n_racks:.0f} speedup={speedup:.1f}x "
        f"qp_resid_cold={float(resid_c):.2e} qp_resid_warm={float(resid_w):.2e}"
    )


def bench_controller_throughput():
    """Controller-layer throughput: rack-solves/s, seed cold-start path
    (per-rack _build_qp + cho_factor + 120-iter ADMM, vmapped) vs the
    factor-once plan (one batched 30-iter ADMM, warm-started)."""
    n_racks = _q(2048, 128)
    n_steps = 4
    cfg = ctrl.ControllerConfig.create()
    es = ess.ESSParams.create(q_max_seconds=40.0)
    socs = 0.3 + 0.4 * jax.random.uniform(jax.random.key(12), (n_racks,))
    tgt = jnp.asarray(0.5)
    ups = jnp.zeros((n_racks,))

    UNITS["controller_throughput"] = dict(racks=n_racks)
    cold = jax.jit(
        jax.vmap(
            lambda s, u: ctrl.inner_loop_step(
                cfg, es, s, tgt, u, qp_iters=120
            ).corrective_power
        )
    )
    us_cold, _ = _timeit(cold, socs, ups, n=1)

    plan = ctrl.make_plan(cfg, es)

    def warm_steps(s0):
        def body(carry, _):
            soc, up, warm = carry
            out, warm2 = ctrl.inner_loop_step_plan(
                cfg, es, plan, soc, tgt, up, warm, qp_iters=30
            )
            soc2 = soc - out.corrective_power * cfg.dt / es.q_max
            return (soc2, out.corrective_power / cfg.i_max, warm2), (
                out.qp_primal_residual
            )

        carry0 = (s0, jnp.zeros_like(s0), ctrl.init_warm(plan, s0.shape))
        _, resid = jax.lax.scan(body, carry0, None, length=n_steps)
        return resid

    warm = jax.jit(warm_steps)
    us_warm_total, resid = _timeit(warm, socs, n=1)
    us_warm = us_warm_total / n_steps  # per control interval
    sps_cold = n_racks / (us_cold / 1e6)
    sps_warm = n_racks / (us_warm / 1e6)
    return "controller_throughput", us_warm, (
        f"racksolves_per_s cold={sps_cold:.0f} warm={sps_warm:.0f} "
        f"speedup={sps_warm / sps_cold:.1f}x "
        f"warm_resid={float(jnp.max(resid[-1])):.2e}"
    )


def bench_fleet_streaming():
    """Streaming campus engine: 1024 racks conditioned in time chunks with
    donated state and on-the-fly chunk synthesis — live HBM stays
    O(chunk x racks) instead of 2x the (T, R) campus trace."""
    n_racks = _q(1024, 64)
    sp = trace.TestbenchSpec(duration_s=60.0, sample_hz=200.0)
    t1, dt = trace.testbench_trace(sp, jax.random.key(7))
    offsets = jax.random.randint(jax.random.key(13), (n_racks,), 0, 800)
    cfg = pdu.make_pdu(sample_dt=dt)
    spec = compliance.GridSpec.create()
    t_total = t1.shape[0]

    def provider(t0, n):
        # synthesize the (n, R) chunk from the base trace + per-rack offsets
        idx = (jnp.arange(t0, t0 + n)[:, None] - offsets[None, :]) % t_total
        return t1[idx]

    import time as _time

    fleet.condition_fleet_streaming(  # compile all chunk shapes
        cfg, provider, spec, qp_iters=30, chunk_intervals=4, total_samples=t_total
    )
    t0 = _time.perf_counter()
    res = fleet.condition_fleet_streaming(
        cfg, provider, spec, qp_iters=30, chunk_intervals=4, total_samples=t_total
    )
    jax.block_until_ready(res.campus_grid)
    us = (_time.perf_counter() - t0) * 1e6
    UNITS["fleet_streaming_1024racks"] = dict(racks=n_racks, samples=t_total * n_racks)
    rg = float(compliance.max_abs_ramp(res.campus_grid, dt))
    k = int(round(float(cfg.controller.dt) / dt))
    live_mb = 4 * k * 4 * n_racks / 1e6  # chunk_intervals * k samples x R x f32
    full_mb = 2 * t_total * n_racks * 4 / 1e6
    return "fleet_streaming_1024racks", us, (
        f"campus_ramp={rg:.4f}/s ok={bool(res.report_grid.ramp_ok)} "
        f"us_per_rack={us / n_racks:.0f} qp_resid={float(res.max_qp_residual):.2e} "
        f"live_chunk={live_mb:.0f}MB vs one-shot {full_mb:.0f}MB"
    )


def bench_scenario_render():
    """Scenario-engine synthesis throughput: host-materialized one-shot
    (T, R) render vs on-device chunked rendering (the streaming conditioner's
    chunk provider path).  Derived number is samples/s of campus trace."""
    n_racks = _q(256, 32)
    duration = _q(120.0, 30.0)
    hz = 200.0
    s = SC.mixed_campus(
        n_racks,
        ("llama3_2_1b", "deepseek_v3_671b", "whisper_large_v3"),
        duration_s=duration,
        sample_hz=hz,
        seed=0,
        noise_seed=1,
    )
    t_total = s.total_samples
    chunk = 4000

    one_shot = lambda: np.asarray(SC.render(s, 0, t_total))  # host-materialized
    us_full, _ = _timeit(one_shot, n=1)

    def chunked():
        outs = [SC.render(s, t0, min(chunk, t_total - t0))
                for t0 in range(0, t_total, chunk)]
        jax.block_until_ready(outs)
        return outs

    us_chunk, _ = _timeit(chunked, n=1)
    total = t_total * n_racks
    UNITS["scenario_render"] = dict(racks=n_racks, samples=total)
    return "scenario_render", us_chunk, (
        f"samples_per_s host={total / (us_full / 1e6):.2e} "
        f"chunked={total / (us_chunk / 1e6):.2e} racks={n_racks} T={t_total}"
    )


def _mixed_campus_scenario(n_racks, duration, hz):
    return SC.mixed_campus(
        n_racks,
        ("llama3_2_1b", "deepseek_v3_671b", "chatglm3_6b", "whisper_large_v3"),
        duration_s=duration,
        sample_hz=hz,
        seed=3,
        fault_at_s=duration * 0.6,
        noise_seed=2,
    )


# Cross-bench wall-clock records (e.g. mixed_campus_health reports its
# overhead against the same run's mixed_campus_fleet timing).
LAST_US: dict[str, float] = {}


def _best_of(run, ready, n=3):
    """Min-of-n wall clock: this container's timings drift ±15-20% with
    background load, so single-shot numbers routinely fake both
    regressions and speedups.  Applies in QUICK mode too — that is the
    mode ``--quick --gate`` times, and a gate fed single-shot numbers
    would flap (quick workloads are small, so the extra reps are cheap)."""
    best, out = float("inf"), None
    for _ in range(n):
        t0 = time.perf_counter()
        r = run()
        jax.block_until_ready(ready(r))
        best, out = min(best, (time.perf_counter() - t0) * 1e6), r
    return best, out


def bench_mixed_campus():
    """The heterogeneous-campus acceptance scenario: 1024 racks running 4
    model-derived workloads + an inference-diurnal block, staggered job
    starts/stops, and a mid-trace fault cascade — conditioned end-to-end by
    the scanned engine (render + chunk loop fused into ONE dispatch, no
    (T, R) host materialization ever).  The per-chunk host-loop engine runs
    once for the derived speedup; in ``--quick`` mode the two are asserted
    to agree (campus aggregates bitwise where XLA fusion allows, <= a few
    ulp on the filter chain), so the CI smoke run doubles as an
    engine-equivalence check."""
    n_racks = _q(1024, 64)
    duration = _q(88.0, 30.0)
    hz = 200.0
    s = _mixed_campus_scenario(n_racks, duration, hz)
    cfg = pdu.make_pdu(sample_dt=1.0 / hz)
    spec = compliance.GridSpec.create()
    run = lambda engine: fleet.condition_scenario_streaming(
        cfg, s, spec, engine=engine, qp_iters=30, chunk_intervals=4
    )
    run("scanned")  # compile
    us, res = _best_of(lambda: run("scanned"), lambda r: r.campus_grid)
    UNITS["mixed_campus_fleet"] = dict(racks=n_racks, samples=s.total_samples * n_racks)

    host = run("host")  # warm the host-loop engine
    t0 = time.perf_counter()
    host = run("host")
    jax.block_until_ready(host.campus_grid)
    us_host = (time.perf_counter() - t0) * 1e6
    if QUICK:
        np.testing.assert_array_equal(
            np.asarray(res.campus_rack), np.asarray(host.campus_rack)
        )
        np.testing.assert_array_equal(
            np.asarray(res.soc_mean), np.asarray(host.soc_mean)
        )
        np.testing.assert_allclose(
            np.asarray(res.campus_grid), np.asarray(host.campus_grid), atol=1e-6
        )

    rg = float(res.report_grid.max_ramp)
    LAST_US["mixed_campus_fleet"] = us
    return "mixed_campus_fleet", us, (
        f"racks={n_racks} workloads=5 campus_ramp={rg:.4f}/s "
        f"ok={bool(res.report_grid.ramp_ok)} raw_ok={bool(res.report_rack.ramp_ok)} "
        f"us_per_rack={us / n_racks:.0f} qp_resid={float(res.max_qp_residual):.2e} "
        f"host_loop_us={us_host:.0f} ({us_host / us:.2f}x scanned)"
        + (" engines_agree=True" if QUICK else "")
    )


def bench_mixed_campus_health():
    """Observer overhead: the PR-3 acceptance campus re-run with the full
    health-aware telemetry spine enabled — per-sample battery wear state
    machine (`core.health`) folded into the conditioning scan plus the
    streaming compliance observers — must stay within ~10% of the
    telemetry-free `mixed_campus_fleet` wall clock."""
    from repro.core import health as hlt

    n_racks = _q(1024, 64)
    duration = _q(88.0, 30.0)
    hz = 200.0
    s = _mixed_campus_scenario(n_racks, duration, hz)
    cfg = pdu.make_pdu(sample_dt=1.0 / hz, track_health=True)
    spec = compliance.GridSpec.create()
    run = lambda: fleet.condition_scenario_streaming(
        cfg, s, spec, qp_iters=30, chunk_intervals=4
    )
    run()  # compile
    us, res = _best_of(run, lambda r: r.campus_grid)
    UNITS["mixed_campus_health"] = dict(racks=n_racks, samples=s.total_samples * n_racks)
    PROFILES["mixed_campus_health"] = dict(
        cfg=cfg, scenario=s, spec=spec, chunk_intervals=4, qp_iters=30
    )

    if QUICK:
        # Megakernel-vs-ref agreement ride-along: one controller interval of
        # THIS campus through the interpret-mode Pallas megakernel vs the
        # jnp reference the engines run on CPU.  SoC path + every health
        # leaf bitwise, grid bitwise on the (sublane-aligned) interval.
        from repro.core import health as _h
        from repro.kernels import ops as _ops, ref as _kref

        k = int(round(cfg.controller.dt * hz))
        chunk = jax.jit(lambda: SC.render(s, 0, k))()
        st = pdu.init_state(cfg, chunk[0])
        ep = cfg.ess_params
        kkw = dict(
            beta=float(ep.beta), dt=1.0 / hz, q_max=float(ep.q_max),
            eta_c=float(ep.eta_c), eta_d=float(ep.eta_d),
            p_max=float(ep.p_max), soc_min=float(ep.soc_safe_min),
            soc_max=float(ep.soc_safe_max),
        )
        filt = st.filter_obj
        a = (chunk, st.ess_state.g_filter, st.ess_state.soc, st.filter_state,
             filt.ad, filt.bd, filt.c[0])
        hin = (_h.step_consts(cfg.health), tuple(st.health))
        r_ref = _kref.pdu_health_sim(*a, health=hin, **kkw)
        r_pl = _ops.pdu_health_sim(*a, health=hin, force="pallas", **kkw)
        np.testing.assert_array_equal(np.asarray(r_ref[1]), np.asarray(r_pl[1]))
        np.testing.assert_array_equal(np.asarray(r_ref[0]), np.asarray(r_pl[0]))
        for lf_r, lf_p in zip(r_ref[3], r_pl[3]):
            np.testing.assert_array_equal(np.asarray(lf_r), np.asarray(lf_p))

    base = LAST_US.get("mixed_campus_fleet")
    overhead = f"{(us / base - 1) * 100:+.1f}%" if base else "-"
    h = hlt.fleet_summary(res.health)
    LAST_US["mixed_campus_health"] = us
    return "mixed_campus_health", us, (
        f"racks={n_racks} overhead_vs_fleet={overhead} "
        f"efc_mean={h['efc_mean']:.3f} half_cycles={h['half_cycles_mean']:.0f} "
        f"worst_dod={h['worst_dod']:.3f} fade_max={h['fade_max']:.2e} "
        f"life_min={h['projected_life_years_min']:.1f}y "
        f"hf_lines_ok={bool(res.report_grid.spectrum_ok)}"
        + (" megakernel_agrees=True" if QUICK else "")
    )


def bench_mixed_campus_safemode():
    """Supervision overhead (ISSUE 9): the health-telemetry acceptance
    campus re-run with the full safe-mode control plane live — per-rack
    sanitizer sweep over every carried leaf, in-kernel output guard, ADMM
    divergence watchdog, and the supervisor state machine folded into the
    interval scan.  Must stay within 10% of the unsupervised
    ``mixed_campus_health`` wall clock from the same run (asserted — a
    gated run fails if supervision stops being effectively free)."""
    n_racks = _q(1024, 64)
    duration = _q(88.0, 30.0)
    hz = 200.0
    s = _mixed_campus_scenario(n_racks, duration, hz)
    cfg_off = pdu.make_pdu(sample_dt=1.0 / hz, track_health=True)
    cfg_on = pdu.make_pdu(sample_dt=1.0 / hz, track_health=True, safemode=True)
    spec = compliance.GridSpec.create()
    run = lambda c: fleet.condition_scenario_streaming(
        c, s, spec, qp_iters=30, chunk_intervals=4
    )
    run(cfg_off), run(cfg_on)  # compile both
    # The two configs are timed INTERLEAVED (not vs the earlier
    # mixed_campus_health record): this container's wall clock drifts
    # between benches, and an overhead *assert* fed cross-bench timings
    # would flap on load spikes.  Interleaving keeps both sides under the
    # same drift.
    us_off = us = float("inf")
    res = None
    for _ in range(3):
        t0 = time.perf_counter()
        r = run(cfg_off)
        jax.block_until_ready(r.campus_grid)
        us_off = min(us_off, (time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        r = run(cfg_on)
        jax.block_until_ready(r.campus_grid)
        us, res = min(us, (time.perf_counter() - t0) * 1e6), r
    UNITS["mixed_campus_safemode"] = dict(
        racks=n_racks, samples=s.total_samples * n_racks
    )
    PROFILES["mixed_campus_safemode"] = dict(
        cfg=cfg_on, scenario=s, spec=spec, chunk_intervals=4, qp_iters=30
    )
    LAST_US["mixed_campus_safemode"] = us

    trace = np.asarray(res.safemode_trace)
    assert np.all(trace[:, 0] == 1.0), "clean campus tripped the supervisor"
    summ = res.safemode_summary()
    overhead = (us / us_off - 1) * 100
    assert us < 1.10 * us_off, (
        f"safe-mode supervision overhead {overhead:+.1f}% exceeds the "
        f"10% budget vs the unsupervised run ({us_off:.0f}us -> {us:.0f}us)"
    )
    return "mixed_campus_safemode", us, (
        f"racks={n_racks} overhead_interleaved={overhead:+.1f}% "
        f"n_normal={summ['n_normal']} entries="
        f"{summ['passthrough_entries'] + summ['quarantine_entries']} "
        f"worst_streak={summ['worst_resid_streak']} "
        f"ramp_ok={bool(res.report_grid.ramp_ok)} budget_ok=True"
    )


def bench_mixed_campus_faulty():
    """ISSUE-6 acceptance campus: the 1024-rack heterogeneous fleet under a
    stochastic fault soup (ESS trips ~30% of units offline at the worst
    interval, rack power losses, sensor-dropout NaN windows) plus one
    scripted mid-trace cascade injected into the fault engine's rack
    channel — conditioned end-to-end by the degraded-mode scanned engine,
    with the availability mask derived in-jit from the schedule's episode
    table.  Asserts the campus still meets the ramp spec with a third of
    the conditioning fleet dark (the honest claim rides in
    min_online_frac), and in ``--quick`` mode cross-checks the host-loop
    engine for degraded-path equivalence.

    The campus renders with ``edge_pad='clamp'`` — the legacy zero-padded
    smoothing window fabricates a fleet-synchronized half-power decay at
    the trace boundaries, which no spec-compliant campus should be judged
    on."""
    from repro.power import faults as FLT

    n_racks = _q(1024, 256)  # quick stays large enough for fleet statistics
    duration = _q(88.0, 30.0)
    hz = 200.0
    s = SC.mixed_campus(
        n_racks,
        ("llama3_2_1b", "deepseek_v3_671b", "chatglm3_6b", "whisper_large_v3"),
        duration_s=duration,
        sample_hz=hz,
        seed=3,
        fault_rack_fraction=0.0,  # the cascade rides in the fault schedule
        edge_pad="clamp",
        noise_seed=2,
    )
    # ESS steady-state offline fraction = mttr/(mtbf+mttr) = 0.3: the
    # acceptance claim is a campus that holds the ramp spec with roughly a
    # third of its conditioning fleet dark at the worst interval.
    proc = FLT.FaultProcess.create(
        rack_mtbf_s=duration * 4.0, rack_mttr_s=duration * 0.25,
        ess_mtbf_s=duration * 1.75, ess_mttr_s=duration * 0.75,
        sensor_mtbf_s=duration * 3.0, sensor_mttr_s=duration * 0.1,
    )
    sched = FLT.sample_schedule(
        proc, n_racks, s.total_samples, hz, seed=6
    )
    # One cascade: rack power loss ripples across a contiguous tenth of
    # the fleet over ~5 s, 20 s outages, starting at 60% of the trace.
    n_cas = max(n_racks // 10, 1)
    lo = n_racks // 3
    t0f = int(0.6 * duration * hz)
    step = max(int(5.0 * hz) // max(n_cas - 1, 1), 1)
    durf = int(20.0 * hz)
    sched = FLT.inject_episodes(sched, rack=[
        (lo + i, t0f + i * step, min(t0f + i * step + durf, s.total_samples))
        for i in range(n_cas)
    ])
    s = SC.attach_faults(s, sched)
    cfg = pdu.make_pdu(sample_dt=1.0 / hz, degraded_mode=True)
    spec = compliance.GridSpec.create()
    run = lambda engine: fleet.condition_scenario_streaming(
        cfg, s, spec, engine=engine, qp_iters=30, chunk_intervals=4
    )
    run("scanned")  # compile
    us, res = _best_of(lambda: run("scanned"), lambda r: r.campus_grid)
    UNITS["mixed_campus_faulty"] = dict(racks=n_racks, samples=s.total_samples * n_racks)
    PROFILES["mixed_campus_faulty"] = dict(
        cfg=cfg, scenario=s, spec=spec, chunk_intervals=4, qp_iters=30
    )

    if QUICK:
        host = run("host")
        np.testing.assert_array_equal(
            np.asarray(res.campus_rack), np.asarray(host.campus_rack)
        )
        np.testing.assert_array_equal(
            np.asarray(res.ess_online_frac), np.asarray(host.ess_online_frac)
        )
        np.testing.assert_allclose(
            np.asarray(res.campus_grid), np.asarray(host.campus_grid), atol=1e-6
        )

        # Megakernel-vs-ref ride-along on the fused weight operand
        # (mirrors bench_mixed_campus_health's QUICK block): one mid-trace
        # controller interval of THIS campus, with the ESS availability
        # weight rendered IN-KERNEL from the schedule's boundary-event
        # tables, through the interpret-mode Pallas megakernel vs the jnp
        # reference the engines run on CPU.  SoC path, grid, and machine
        # state bitwise.
        from repro.kernels import ops as _ops, ref as _kref

        k = int(round(cfg.controller.dt * hz))
        t0q = (s.total_samples // (2 * k)) * k
        chunk = jnp.nan_to_num(jax.jit(lambda: SC.render(s, t0q, k))(), nan=0.0)
        st = pdu.init_state(cfg, chunk[0])
        ep = cfg.ess_params
        filt = st.filter_obj
        kkw = dict(
            beta=float(ep.beta), dt=1.0 / hz, q_max=float(ep.q_max),
            eta_c=float(ep.eta_c), eta_d=float(ep.eta_d),
            p_max=float(ep.p_max), soc_min=float(ep.soc_safe_min),
            soc_max=float(ep.soc_safe_max),
        )
        ev = (
            sched.ess_start.T, sched.ess_end.T,
            jnp.ones((n_racks,), jnp.float32),
            jnp.asarray(t0q, jnp.int32), jnp.asarray(t0q + k - 1, jnp.int32),
        )
        a = (chunk, st.ess_state.g_filter, st.ess_state.soc, st.filter_state,
             filt.ad, filt.bd, filt.c[0])
        ekw = dict(ess_events=ev, ess_edge=max(s.edge_width, 1), **kkw)
        r_ref = _kref.pdu_health_sim(*a, **ekw)
        r_pl = _ops.pdu_health_sim(*a, force="pallas", **ekw)
        np.testing.assert_array_equal(np.asarray(r_ref[1]), np.asarray(r_pl[1]))
        np.testing.assert_array_equal(np.asarray(r_ref[0]), np.asarray(r_pl[0]))
        for lf_r, lf_p in zip(
            jax.tree_util.tree_leaves(r_ref[2]), jax.tree_util.tree_leaves(r_pl[2])
        ):
            np.testing.assert_array_equal(np.asarray(lf_r), np.asarray(lf_p))

    frac = np.asarray(res.ess_online_frac)
    assert np.all(np.isfinite(np.asarray(res.campus_grid))), (
        "sensor-dropout NaN leaked into the conditioned campus trace"
    )
    assert bool(res.report_grid.ramp_ok), (
        f"degraded campus failed the ramp spec at min_online_frac="
        f"{float(frac.min()):.2f}"
    )
    base = LAST_US.get("mixed_campus_fleet")
    overhead = f"{(us / base - 1) * 100:+.1f}%" if base else "-"
    return "mixed_campus_faulty", us, (
        f"racks={n_racks} min_online_frac={float(frac.min()):.2f} "
        f"mean_online_frac={float(frac.mean()):.2f} "
        f"campus_ramp={float(res.report_grid.max_ramp):.4f}/s "
        f"ok={bool(res.report_grid.ramp_ok)} "
        f"overhead_vs_clean={overhead} us_per_rack={us / n_racks:.0f}"
        + (" engines_agree=True megakernel_agrees=True" if QUICK else "")
    )


def bench_grid_region():
    """ISSUE-8 acceptance region: 4 campuses x 256 racks of synchronized
    checkpoint stalls aggregated at one point of interconnection and
    conditioned by the region engine (per-campus scanned conditioning +
    in-scan POI fold + wide-area Goertzel mode bank in one program).  The
    headline is the POI view: ramp rate at the interconnection, the
    swing-model frequency excursion, and the inter-area mode verdict —
    lockstep checkpoints must ring the 0.1-1 Hz band (the staggered twin
    of this scenario passes; see EXPERIMENTS §Grid-region).  In ``--quick``
    mode the in-scan psum POI is re-derived host-side as the left-to-right
    weighted sum of the per-campus aggregates and asserted bitwise — the
    same engine-agreement contract the sharded parity test holds across
    8 forced devices."""
    from repro.core import grid

    n_campuses = 4
    n_racks = _q(256, 32)
    duration = _q(200.0, 100.0)
    hz = 50.0
    reg = grid.synchronized_region(
        n_campuses=n_campuses, n_racks=n_racks, duration_s=duration,
        sample_hz=hz,
    )
    cfg = pdu.make_pdu(sample_dt=1.0 / hz)
    spec = compliance.GridSpec.create()
    run = lambda: fleet.condition(reg, cfg, spec)
    run()  # compile
    us, res = _best_of(run, lambda r: r.poi_grid)
    total_racks = n_campuses * n_racks
    UNITS["grid_region"] = dict(
        racks=total_racks, samples=reg.total_samples * total_racks)

    if QUICK:
        w = np.asarray(res.weights)
        acc = jnp.float32(w[0]) * res.per_campus[0].campus_grid
        for c in range(1, n_campuses):
            acc = acc + jnp.float32(w[c]) * res.per_campus[c].campus_grid
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(res.poi_grid))

    rep = res.report_poi
    mags = np.asarray(rep.mode_mags)
    assert not bool(rep.modes_ok), (
        "synchronized checkpoint region failed to ring the inter-area band"
    )
    assert bool(rep.ramp_ok), "region POI trace broke the ramp spec"
    return "grid_region", us, (
        f"campuses={n_campuses} racks={total_racks} "
        f"poi_ramp={float(rep.max_ramp):.4f}/s ramp_ok={bool(rep.ramp_ok)} "
        f"inter_area_mag={mags[0]:.4f} modes_ok={bool(rep.modes_ok)} "
        f"max_freq_dev={float(np.max(np.abs(np.asarray(res.poi_freq_dev)))):.3f}Hz "
        f"us_per_rack={us / total_racks:.0f}"
        + (" engines_agree=True" if QUICK else "")
    )


ALL = [
    bench_fig7_frequency_response,
    bench_fig9_ramp_rate,
    bench_fig10_spectrum,
    bench_fig11_burn_energy,
    bench_fig12_soc_management,
    bench_fig13_cluster_fault,
    bench_table1_mitigation_space,
    bench_appendixA_sizing,
    bench_controller_throughput,
    bench_fleet_scale,
    bench_fleet_streaming,
    bench_scenario_render,
    bench_mixed_campus,
    bench_mixed_campus_health,
    bench_mixed_campus_safemode,
    bench_mixed_campus_faulty,
    bench_grid_region,
]
