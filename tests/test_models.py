"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward and one train step on CPU, asserting output
shapes and no NaNs; plus decode-path consistency and family-specific
behaviors (MoE balance, MLA cache size, SSD chunking, MTP)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, smoke_config
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.step import build_train_step


def _batch(cfg, b=2, t=16, key=0):
    tokens = jax.random.randint(jax.random.key(key), (b, t), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-100)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "audio":
        batch["frames"] = (
            jax.random.normal(jax.random.key(key + 1), (b, cfg.encdec.encoder_seq, cfg.d_model)) * 0.02
        ).astype(jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    p = (ED if cfg.family == "audio" else T).init(jax.random.key(0), cfg)
    batch = _batch(cfg)
    if cfg.family == "audio":
        out = ED.forward(p, cfg, batch["tokens"], batch["frames"])
    else:
        out = T.forward(p, cfg, batch["tokens"])
    assert out.logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(out.logits)))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_train_step(arch):
    """One full train step (loss + grads + AdamW) — finite loss, params move."""
    cfg = smoke_config(arch)
    p = (ED if cfg.family == "audio" else T).init(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(p, opt_cfg)
    step = jax.jit(build_train_step(cfg, opt_cfg, total_steps=10, warmup_steps=1))
    p2, opt2, metrics = step(p, opt, _batch(cfg), jnp.asarray(0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    la = jax.tree_util.tree_leaves(p)
    lb = jax.tree_util.tree_leaves(p2)
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(la, lb)
    )
    assert delta > 0  # optimizer actually updated


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_decode_matches_forward(arch):
    """Chunked prefill + single-token decode == full forward logits."""
    cfg = smoke_config(arch)
    b, t = 2, 16
    batch = _batch(cfg, b, t)
    if cfg.family == "audio":
        p = ED.init(jax.random.key(0), cfg)
        full = ED.forward(p, cfg, batch["tokens"], batch["frames"]).logits
        st = ED.init_decode_state(p, cfg, batch["frames"], b, 32)
        lg1, st = ED.decode_step(p, cfg, batch["tokens"][:, :8], st, jnp.asarray(0, jnp.int32), prefill=True)
        lg2, st = ED.decode_step(p, cfg, batch["tokens"][:, 8:9], st, jnp.asarray(8, jnp.int32))
    else:
        p = T.init(jax.random.key(0), cfg)
        full = T.forward(p, cfg, batch["tokens"]).logits
        st = T.init_decode_state(cfg, b, 32)
        lg1, st = T.decode_step(p, cfg, batch["tokens"][:, :8], st, jnp.asarray(0, jnp.int32), prefill=True)
        lg2, st = T.decode_step(p, cfg, batch["tokens"][:, 8:9], st, jnp.asarray(8, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(full[:, :8]), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(full[:, 8]), atol=2e-4, rtol=1e-3)


def test_moe_router_balance_bias_updates():
    from repro.models import moe as MOE

    cfg = smoke_config("deepseek_v3_671b")
    p = MOE.init_moe(jax.random.key(0), cfg, jnp.float32)
    load = jnp.asarray([0.9, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.5])
    p2 = MOE.update_router_bias(p, cfg, load)
    bias = np.asarray(p2["router"]["bias"])
    assert bias[0] < 0  # overloaded expert pushed down
    assert bias[1] > 0  # underloaded pulled up


def test_moe_dispatch_is_linear_in_tokens():
    """Grouped dispatch: doubling tokens must not change per-token outputs
    (dropless capacity in smoke configs)."""
    from repro.models import moe as MOE

    cfg = smoke_config("deepseek_v2_236b")
    p = MOE.init_moe(jax.random.key(1), cfg, jnp.float32)
    x1 = jax.random.normal(jax.random.key(2), (1, 32, cfg.d_model)) * 0.1
    x2 = jnp.concatenate([x1, x1], axis=0)
    y1, _ = MOE.moe_fwd(p, cfg, x1, group_size=32)
    y2, _ = MOE.moe_fwd(p, cfg, x2, group_size=32)
    np.testing.assert_allclose(np.asarray(y2[0]), np.asarray(y1[0]), atol=1e-5)


def test_mla_cache_is_compressed():
    """The MLA cache must be ~(kv_lora + rope)/(2*H*hd) the size of GQA's."""
    from repro.models import attention as A

    cfg = smoke_config("deepseek_v2_236b")
    cache = A.init_cache(cfg, batch=2, max_len=64, dtype=jnp.float32)
    mla_bytes = cache.k.size + cache.v.size
    gqa_equiv = 2 * 64 * cfg.n_heads * (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim) * 2
    assert mla_bytes < gqa_equiv / 4


def test_mamba2_chunked_matches_unchunked():
    from repro.configs.base import SSMConfig
    import dataclasses
    from repro.models import mamba2 as M

    cfg = smoke_config("zamba2_2_7b")
    p = M.init_mamba2(jax.random.key(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(4), (2, 32, cfg.d_model)) * 0.1
    y1, s1 = M.mamba2_fwd(p, cfg, x)
    cfg2 = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=8))
    y2, s2 = M.mamba2_fwd(p, cfg2, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1.ssm), np.asarray(s2.ssm), atol=2e-4, rtol=1e-3)


def test_mamba2_state_carry():
    """Two-chunk streaming == one-shot (decode contract)."""
    from repro.models import mamba2 as M

    cfg = smoke_config("zamba2_2_7b")
    p = M.init_mamba2(jax.random.key(5), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(6), (1, 32, cfg.d_model)) * 0.1
    y_full, _ = M.mamba2_fwd(p, cfg, x)
    st = M.init_ssm_state(cfg, 1, jnp.float32)
    y1, st = M.mamba2_fwd(p, cfg, x[:, :16], st)
    y2, st = M.mamba2_fwd(p, cfg, x[:, 16:], st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_full),
        atol=2e-4, rtol=1e-3,
    )


def test_mtp_loss_present_for_v3():
    cfg = smoke_config("deepseek_v3_671b")
    p = T.init(jax.random.key(0), cfg)
    batch = _batch(cfg)
    loss, metrics = T.lm_loss(p, cfg, batch["tokens"], batch["labels"])
    assert "mtp_loss" in metrics
    assert np.isfinite(float(metrics["mtp_loss"]))


def test_param_counts_match_published():
    """Analytic parameter counts against published figures."""
    from repro.configs import full_config

    expect = {
        "deepseek_v3_671b": (671e9, 0.02),
        "deepseek_v2_236b": (236e9, 0.03),
        "stablelm_12b": (12.1e9, 0.05),
        "llama3_2_1b": (1.24e9, 0.05),
        "chameleon_34b": (34e9, 0.03),
        "zamba2_2_7b": (2.7e9, 0.2),
        "rwkv6_7b": (7.6e9, 0.2),
    }
    for arch, (target, tol) in expect.items():
        n = full_config(arch).param_count()
        assert abs(n - target) / target < tol, f"{arch}: {n:.3e} vs {target:.3e}"


def test_v3_active_params_match_published():
    from repro.configs import full_config

    n = full_config("deepseek_v3_671b").active_param_count()
    assert abs(n - 37e9) / 37e9 < 0.05


from _hyp_compat import given, settings, strategies as hyp_st  # optional-hypothesis shim


@settings(max_examples=10, deadline=None)
@given(seed=hyp_st.integers(0, 100), n_tokens=hyp_st.sampled_from([16, 32, 48]))
def test_property_moe_dropless_under_capacity(seed, n_tokens):
    """With capacity_factor = E/k (dropless bound), no token is ever
    dropped regardless of routing skew."""
    from repro.models import moe as MOE

    cfg = smoke_config("deepseek_v2_236b")  # cf=4.0 == E/k == 8/2
    p = MOE.init_moe(jax.random.key(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(seed + 1), (1, n_tokens, cfg.d_model))
    _, aux = MOE.moe_fwd(p, cfg, x, group_size=16)
    assert float(aux.dropped_fraction) == 0.0


def test_moe_expert_load_sums_to_k():
    from repro.models import moe as MOE

    cfg = smoke_config("deepseek_v3_671b")
    p = MOE.init_moe(jax.random.key(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(4), (2, 64, cfg.d_model))
    _, aux = MOE.moe_fwd(p, cfg, x)
    assert float(jnp.sum(aux.expert_load)) == pytest.approx(
        cfg.moe.experts_per_token, rel=1e-5
    )
