"""Fleet aggregation tests (paper Appendix D, Fig. 13)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance, fleet, pdu
from repro.power import trace


def test_synchronous_spectrum_scales_linearly():
    """Eq. 20: per-unit spectrum of N lockstep racks equals one rack's."""
    sp = trace.TestbenchSpec(duration_s=60.0, sample_hz=200.0)
    t1, dt = trace.testbench_trace(sp, None)
    fleet_traces = jnp.tile(t1[:, None], (1, 4))
    campus = jnp.mean(fleet_traces, axis=1)
    f1, s1 = compliance.normalized_spectrum(t1, dt)
    f2, s2 = compliance.normalized_spectrum(campus, dt)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-6)


def test_staggered_fleet_shapes_and_offsets():
    t1, dt = trace.testbench_trace(trace.TestbenchSpec(duration_s=30.0, sample_hz=100.0), None)
    traces = fleet.staggered_fleet(t1, 8, jax.random.key(0), max_offset_samples=50)
    assert traces.shape == (t1.shape[0], 8)


def test_staggering_reduces_campus_swing():
    """Desynchronized racks partially cancel — aggregate swing shrinks."""
    sp = trace.TestbenchSpec(duration_s=88.0, sample_hz=100.0, noise_std=0.0)
    t1, dt = trace.testbench_trace(sp, None)
    sync = fleet.staggered_fleet(t1, 16, jax.random.key(1), max_offset_samples=0)
    desync = fleet.staggered_fleet(t1, 16, jax.random.key(1), max_offset_samples=2200)
    swing = lambda x: float(jnp.ptp(jnp.mean(x, axis=1)))
    assert swing(desync) < swing(sync)


def test_fleet_conditioning_composes(tmp_path):
    """Per-rack EasyRider conditioning makes the campus compliant
    (the paper's composition argument)."""
    sp = trace.TestbenchSpec(duration_s=66.0, sample_hz=250.0)
    t1, dt = trace.testbench_trace(sp, jax.random.key(2))
    traces = fleet.staggered_fleet(t1, 4, jax.random.key(3), max_offset_samples=500,
                                   scale_jitter=0.05)
    traces = jnp.clip(traces, 0.0, 1.0)
    cfg = pdu.make_pdu(sample_dt=dt)
    spec = compliance.GridSpec.create()
    res = fleet.condition_fleet(cfg, traces, spec, qp_iters=15)
    assert not bool(res.report_rack.ramp_ok)
    assert bool(res.report_grid.ramp_ok)
    assert bool(res.report_grid.ok)


def test_streaming_fleet_matches_one_shot():
    """condition_fleet_streaming (chunked, donated, campus-reduced) must
    reproduce the one-shot vectorized call's campus waveform."""
    sp = trace.TestbenchSpec(duration_s=44.0, sample_hz=200.0)
    t1, dt = trace.testbench_trace(sp, jax.random.key(7))
    traces = fleet.staggered_fleet(t1, 8, jax.random.key(8), max_offset_samples=800)
    cfg = pdu.make_pdu(sample_dt=dt)
    spec = compliance.GridSpec.create()
    full = fleet.condition_fleet(cfg, traces, spec, qp_iters=30)
    stream = fleet.condition_fleet_streaming(
        cfg, traces, spec, qp_iters=30, chunk_intervals=3
    )
    np.testing.assert_allclose(
        np.asarray(stream.campus_grid), np.asarray(full.campus_grid), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(stream.campus_rack), np.asarray(full.campus_rack), atol=1e-6
    )
    assert bool(stream.report_grid.ramp_ok)
    assert float(stream.max_qp_residual) >= 0.0


def test_streaming_fleet_chunk_provider():
    """Hour-scale path: chunks synthesized on the fly (no (T, R) input array
    ever materialized) produce the same campus result."""
    sp = trace.TestbenchSpec(duration_s=44.0, sample_hz=200.0)
    t1, dt = trace.testbench_trace(sp, jax.random.key(7))
    traces = fleet.staggered_fleet(t1, 4, jax.random.key(9), max_offset_samples=400)
    cfg = pdu.make_pdu(sample_dt=dt)
    spec = compliance.GridSpec.create()
    want = fleet.condition_fleet_streaming(
        cfg, traces, spec, qp_iters=20, chunk_intervals=4
    )
    got = fleet.condition_fleet_streaming(
        cfg,
        lambda t0, n: traces[t0 : t0 + n],
        spec,
        qp_iters=20,
        chunk_intervals=4,
        total_samples=traces.shape[0],
    )
    np.testing.assert_allclose(
        np.asarray(got.campus_grid), np.asarray(want.campus_grid), atol=1e-6
    )


def test_streaming_fleet_requires_total_samples_with_provider():
    cfg = pdu.make_pdu(sample_dt=5e-3)
    spec = compliance.GridSpec.create()
    with pytest.raises(ValueError, match="total_samples"):
        fleet.condition_fleet_streaming(
            cfg, lambda t0, n: jnp.zeros((n, 2)), spec
        )


def test_rack_failure_mid_trace():
    """Fig. 13: a fault drops rack power near-instantly; conditioned campus
    ramp stays within beta even though the failure is unannounced."""
    sp = trace.TestbenchSpec(duration_s=66.0, sample_hz=250.0, noise_std=0.0)
    t1, dt = trace.testbench_trace(sp, None)
    traces = jnp.tile(t1[:, None], (1, 3))
    fails = jnp.asarray([-1, 8000, -1])
    traces = fleet.apply_failures(traces, fails, p_idle=0.02)
    cfg = pdu.make_pdu(sample_dt=dt)
    spec = compliance.GridSpec.create()
    res = fleet.condition_fleet(cfg, traces, spec, qp_iters=15)
    assert bool(res.report_grid.ramp_ok)
    # the failed rack's own conditioned trace tapers instead of stepping:
    failed_grid = np.asarray(res.grid_traces[:, 1])
    assert float(np.max(np.abs(np.diff(failed_grid)))) / dt <= 0.1 + 1e-4
