"""End-to-end PDU tests (paper §7.2): the central claims — a rack trace
violating the grid spec becomes compliant after EasyRider conditioning,
without workload modification; frequency response composes (Fig. 7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance, ess, filters, pdu
from repro.power import trace


@pytest.fixture(scope="module")
def spec():
    return compliance.GridSpec.create(beta=0.1, alpha=1e-4, f_c=2.0)


@pytest.fixture(scope="module")
def cfg():
    return pdu.make_pdu(sample_dt=2e-3)


@pytest.fixture(scope="module")
def testbench():
    sp = trace.TestbenchSpec(duration_s=120.0, sample_hz=500.0, terminate_at_s=100.0)
    return trace.testbench_trace(sp, jax.random.key(0))


@pytest.fixture(scope="module")
def conditioned(cfg, testbench):
    rack, dt = testbench
    st = pdu.init_state(cfg, rack[0])
    grid, st2, telem = jax.jit(lambda s, r: pdu.condition(cfg, s, r, qp_iters=40))(st, rack)
    return rack, grid, telem, dt


def test_rack_trace_violates(conditioned, spec):
    rack, _, _, dt = conditioned
    rep = compliance.check(rack, dt, spec)
    assert not bool(rep.ok)
    assert float(rep.max_ramp) > 1.0  # raw training swings are wildly out


def test_conditioned_trace_complies(conditioned, spec):
    """Paper Fig. 9/10: ramp <= beta AND S(f >= f_c) <= alpha."""
    _, grid, _, dt = conditioned
    rep = compliance.check(grid, dt, spec)
    assert float(rep.max_ramp) <= float(spec.beta) + 1e-4
    assert float(rep.worst_high_freq_mag) <= float(spec.alpha)
    assert bool(rep.ok)


def test_peak_power_reduced(conditioned):
    """Paper §7.2: 'exhibits a lower peak power draw'."""
    rack, grid, _, _ = conditioned
    assert float(grid.max()) < float(rack.max())


def test_energy_approximately_conserved(conditioned, cfg):
    """The PDU is not a burn: grid energy ~ rack energy (+small losses and
    battery SoC movement)."""
    rack, grid, telem, dt = conditioned
    e_rack = float(jnp.sum(rack)) * dt
    e_grid = float(jnp.sum(grid)) * dt
    soc = np.asarray(telem.soc)
    stored = (soc[-1] - 0.5) * float(cfg.ess_params.q_max)
    assert abs(e_grid - stored - e_rack) / e_rack < 0.05


def test_soc_stays_in_safe_band(conditioned, cfg):
    _, _, telem, _ = conditioned
    soc = np.asarray(telem.soc)
    assert soc.min() >= float(cfg.ess_params.soc_safe_min) - 1e-6
    assert soc.max() <= float(cfg.ess_params.soc_safe_max) + 1e-6


def test_streaming_equals_batch(cfg, testbench):
    """Conditioning in chunks (the trainer integration path) must equal
    conditioning the whole trace at once."""
    rack, dt = testbench
    st = pdu.init_state(cfg, rack[0])
    full, _, _ = pdu.condition(cfg, st, rack, qp_iters=20)
    st2 = pdu.init_state(cfg, rack[0])
    n = rack.shape[0]
    # chunk at controller-interval multiples (streaming contract)
    k = int(round(float(cfg.controller.dt) / cfg.sample_dt))
    cut = (n // (2 * k)) * k
    a, st2, _ = pdu.condition(cfg, st2, rack[:cut], qp_iters=20)
    b, st2, _ = pdu.condition(cfg, st2, rack[cut:], qp_iters=20)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([a, b])), np.asarray(full), atol=1e-5
    )


def test_combined_response_is_product(cfg):
    f = jnp.logspace(-3, 2, 50)
    total = pdu.combined_transfer_function(cfg, f)
    prod = ess.transfer_function(cfg.ess_params, f) * filters.transfer_function_rack_to_grid(
        cfg.filter_params, f
    )
    np.testing.assert_allclose(np.asarray(total), np.asarray(prod), rtol=1e-6)


def test_combined_response_meets_spec_envelope(cfg, spec):
    """Above f_c the combined response times a worst-case unit fluctuation
    must sit below alpha with the paper's prototype parameters... with the
    testbench's actual content (<= ~0.2 above 2 Hz) this is what enforces
    Fig. 10."""
    f = jnp.linspace(2.0, 100.0, 200)
    h = np.asarray(pdu.combined_transfer_function(cfg, f))
    # worst rack magnitude at/above 2 Hz for compliant conditioning:
    allowed_rack_mag = float(spec.alpha) / h.max()
    assert allowed_rack_mag > 5e-3  # tolerates >0.5% rated-power lines


def test_hardware_only_mode_still_complies(testbench, spec):
    """Paper §8 fault tolerance: software offline -> hardware still smooths
    (only SoC management degrades)."""
    rack, dt = testbench
    cfg = pdu.make_pdu(sample_dt=1.0 / 500.0, software_enabled=False)
    st = pdu.init_state(cfg, rack[0])
    grid, _, _ = pdu.condition(cfg, st, rack)
    rep = compliance.check(grid, dt, spec)
    assert bool(rep.ok)


def test_multi_rack_vectorized(cfg, spec):
    sp = trace.TestbenchSpec(duration_s=60.0, sample_hz=500.0)
    t1, dt = trace.testbench_trace(sp, jax.random.key(1))
    t2, _ = trace.testbench_trace(sp, jax.random.key(2))
    racks = jnp.stack([t1, t2], axis=1)
    cfg2 = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg2, racks[0])
    grid, _, telem = pdu.condition(cfg2, st, racks, qp_iters=20)
    assert grid.shape == racks.shape
    rep = compliance.check(grid, dt, spec)
    assert rep.ok.shape == (2,)
    assert bool(rep.ramp_ok.all())


def test_storage_mode_lowers_soc_during_idle(cfg):
    """Outer-loop storage mode (paper §6/Eq. 11): during a long predicted
    idle window the controller walks the SoC down toward S_idle."""
    import jax.numpy as jnp
    from repro.core import pdu as pdu_mod

    dt = 0.05  # coarse samples: long horizon, cheap sim
    cfg2 = pdu_mod.make_pdu(sample_dt=dt)
    t = int(40 * 60 / dt)  # 40 minutes of idle at constant low power
    rack = jnp.full((t,), 0.1, jnp.float32)
    st = pdu_mod.init_state(cfg2, rack[0], soc0=0.5)
    _, _, telem = pdu_mod.condition(
        cfg2, st, rack, idle_remaining_s=3 * 3600.0, qp_iters=40
    )
    soc = np.asarray(telem.soc)
    tgt = np.asarray(telem.target)
    assert tgt[2] < 0.5 - 0.05  # storage-mode target selected
    assert soc[-1] < soc[0] - 0.02  # SoC walked down toward it


def test_active_mode_keeps_mid_target(cfg):
    import jax.numpy as jnp
    from repro.core import pdu as pdu_mod

    dt = 0.05
    cfg2 = pdu_mod.make_pdu(sample_dt=dt)
    rack = jnp.full((int(60 / dt),), 0.6, jnp.float32)
    st = pdu_mod.init_state(cfg2, rack[0], soc0=0.5)
    _, _, telem = pdu_mod.condition(cfg2, st, rack, idle_remaining_s=0.0, qp_iters=20)
    tgt = np.asarray(telem.target)
    np.testing.assert_allclose(tgt, 0.5, atol=1e-6)
