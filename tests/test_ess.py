"""ESS (paper §5.3, Eq. 2, Appendix A.1) tests, incl. hypothesis property
tests of the paper's guarantees: ramp bound and energy-swing bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st  # optional-hypothesis shim

from repro.core import compliance, ess


def _params(beta=0.1, q=120.0, lo=0.0, hi=1.0):
    return ess.ESSParams.create(
        beta=beta, q_max_seconds=q, soc_safe_min=lo, soc_safe_max=hi
    )


def test_step_drop_is_ramp_limited():
    p = _params()
    dt = 1e-3
    r = jnp.ones((40_000,)) * 0.9
    r = r.at[20_000:].set(0.1)
    g, soc, _ = ess.simulate(p, ess.init_state(p, jnp.asarray(0.9)), r, dt)
    assert float(compliance.max_abs_ramp(g, dt)) <= 0.1 * 0.8 + 1e-5


def test_settles_in_about_30s():
    """Paper §5.3: 'the DC supply takes about 30 seconds after a step change
    ... before tapering off' — 3 time constants at beta=0.1 is 30 s."""
    p = _params()
    dt = 1e-2
    n = 8000
    r = jnp.ones((n,)) * 0.9
    r = r.at[1000:].set(0.1)
    g, _, _ = ess.simulate(p, ess.init_state(p, jnp.asarray(0.9)), r, dt)
    # 95% settled (3 tau) ~30 s after the step at t=10 s.
    t95 = 0.9 - 0.95 * 0.8
    idx = int(np.argmax(np.asarray(g) <= t95))
    assert (idx - 1000) * dt == pytest.approx(30.0, rel=0.05)


def test_cutoff_matches_paper():
    """f_b = beta/2pi ~= 0.016 Hz for beta = 0.1 (paper §1: '>= 0.016 Hz')."""
    p = _params()
    assert float(p.cutoff_hz()) == pytest.approx(0.0159, abs=2e-4)


def test_transfer_function_20db_per_decade():
    p = _params()
    f = jnp.array([0.16, 1.6, 16.0])
    m = np.asarray(ess.transfer_function(p, f))
    assert m[0] / m[1] == pytest.approx(10.0, rel=0.05)
    assert m[1] / m[2] == pytest.approx(10.0, rel=0.05)


def test_charge_discharge_efficiency_asymmetry():
    p = ess.ESSParams.create(eta_c=0.9, eta_d=0.8, q_max_seconds=10.0)
    up = ess.soc_increment(p, jnp.asarray(1.0), dt=1.0)
    down = ess.soc_increment(p, jnp.asarray(-1.0), dt=1.0)
    assert float(up) == pytest.approx(0.09)
    assert float(down) == pytest.approx(-0.125)


def test_saturation_sheds_to_grid():
    """A battery at its upper safe bound cannot absorb a drop: the grid
    must see the transient (and the SoC must not exceed the bound)."""
    p = ess.ESSParams.create(beta=0.1, q_max_seconds=5.0, soc_safe_max=0.6)
    dt = 1e-2
    r = jnp.ones((4000,)) * 0.9
    r = r.at[500:].set(0.1)
    st = ess.ESSState(g_filter=jnp.asarray(0.9), soc=jnp.asarray(0.58))
    g, soc, _ = ess.simulate(p, st, r, dt)
    assert float(jnp.max(soc)) <= 0.6 + 1e-6
    assert float(compliance.max_abs_ramp(g, dt)) > 0.1  # transient leaked


def test_corrective_power_isolation():
    """Paper §6: a (bounded) wrong software command cannot break filtering —
    grid output differs by at most the corrective magnitude."""
    p = _params()
    dt = 1e-3
    key = jax.random.key(0)
    r = 0.5 + 0.3 * jax.random.uniform(key, (20_000,))
    st = ess.init_state(p, r[0])
    g0, _, _ = ess.simulate(p, st, r, dt, corrective_power=0.0)
    g1, _, _ = ess.simulate(p, st, r, dt, corrective_power=2e-3)
    assert float(jnp.max(jnp.abs(g1 - g0))) <= 2e-3 + 1e-6


@settings(max_examples=25, deadline=None)
@given(
    beta=st.floats(0.02, 0.5),
    p_hi=st.floats(0.5, 1.0),
    p_lo=st.floats(0.0, 0.4),
)
def test_property_ramp_never_exceeds_beta(beta, p_hi, p_lo):
    """Paper's core guarantee (Eq. 2): |dP_grid/dt| <= beta for ANY step."""
    p = _params(beta=beta, q=1e6)  # capacity large enough to never saturate
    dt = 1e-2
    r = jnp.ones((2000,)) * p_hi
    r = r.at[1000:].set(p_lo)
    g, _, _ = ess.simulate(p, ess.init_state(p, jnp.asarray(p_hi)), r, dt)
    # discrete exact ZOH gives (1-exp(-b dt))/dt < b
    assert float(compliance.max_abs_ramp(g, dt)) <= beta * abs(p_hi - p_lo) + 1e-5


@settings(max_examples=25, deadline=None)
@given(
    beta=st.floats(0.05, 0.3),
    i1=st.floats(0.3, 1.0),
    i2=st.floats(0.0, 0.25),
    data=st.data(),
)
def test_property_energy_swing_bound(beta, i1, i2, data):
    """Appendix A.1 Eq. 7: net stored energy during any trace <= (eps/beta).

    We generate a random piecewise-constant trace bounded in [i2, i1] and
    check |cumulative battery energy| <= (i1 - i2)/beta at all times.
    """
    p = _params(beta=beta, q=1e6)
    dt = 0.05
    n_seg = data.draw(st.integers(3, 8))
    levels = [data.draw(st.floats(i2, i1)) for _ in range(n_seg)]
    seg = 400
    r = jnp.concatenate([jnp.full((seg,), lv, jnp.float32) for lv in levels])
    st0 = ess.init_state(p, r[0])
    g, _, _ = ess.simulate(p, st0, r, dt)
    batt_energy = jnp.cumsum(g - r) * dt  # per-unit seconds
    bound = (i1 - i2) / beta
    assert float(jnp.max(jnp.abs(batt_energy))) <= bound + 1e-3


def test_sizing_formulas():
    assert ess.required_capacity_seconds(beta=0.1, epsilon=0.8, gamma=0.5) == pytest.approx(16.0)
    assert ess.required_power_fraction(0.8) == pytest.approx(0.8)
    p = _params(beta=0.1)
    assert float(ess.worst_case_energy_swing(p, 0.8)) == pytest.approx(8.0)
