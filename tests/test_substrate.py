"""Substrate tests: optimizer, data, checkpointing, fault tolerance,
gradient compression, serving, end-to-end training integration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st  # optional-hypothesis shim

from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticLMDataset
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_int8, decompress_int8, init_compression
from repro.optim.schedules import cosine_schedule
from repro.train import Checkpointer, PowerAwareCheckpointer, StragglerMonitor, reassign_shards
from repro.train.loop import TrainConfig, train


# ----------------------------------------------------------------- optim --


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(grads, state, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_adamw_clips_global_norm():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params, cfg)
    _, _, m = adamw_update({"w": jnp.asarray([1e3, 0.0, 0.0])}, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(1e3)


def test_adamw_bf16_state_dtype():
    cfg = AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4))}
    state = adamw_init(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, 100, 10)) < 0.2
    assert float(cosine_schedule(10, 100, 10)) == pytest.approx(1.0, abs=0.02)
    assert float(cosine_schedule(99, 100, 10)) < 0.2


# ------------------------------------------------------------------ data --


def test_data_deterministic_across_restarts():
    ds = SyntheticLMDataset(DataConfig(seed=7, batch=4, seq_len=32))
    a = ds.batch_at(13)
    b = ds.batch_at(13)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_data_labels_are_shifted_tokens():
    ds = SyntheticLMDataset(DataConfig(batch=2, seq_len=16))
    b = ds.batch_at(0)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1])
    )


def test_data_prefetch_iterator():
    ds = SyntheticLMDataset(DataConfig(batch=2, seq_len=8))
    it = ds.iterate(start_step=5)
    first = next(it)
    np.testing.assert_array_equal(
        np.asarray(first["tokens"]), np.asarray(ds.batch_at(5)["tokens"])
    )


# ------------------------------------------------------------ checkpoint --


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    ck.save(10, tree, blocking=True)
    step, restored = ck.restore(None, tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.all_steps() == [3, 4]
    # a stale tmp dir must not be treated as a checkpoint
    os.makedirs(tmp_path / "tmp-99", exist_ok=True)
    assert ck.latest_step() == 4


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones(8)})
    ck.wait()
    assert ck.all_steps() == [1]


def test_elastic_restore_different_sharding(tmp_path):
    """Restore places leaves under any given sharding (elastic remesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(0, tree, blocking=True)
    from repro.sharding import rules
    mesh = rules.make_mesh((1,), ("data",), axis_types=(rules.AxisType.Auto,))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    _, restored = ck.restore(None, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


# -------------------------------------------------------- fault tolerance --


def test_straggler_monitor_flags_persistent_outlier():
    mon = StragglerMonitor(n_hosts=8, patience=3)
    for _ in range(2):
        assert mon.observe([1.0] * 8) == []
    for _ in range(3):
        out = mon.observe([1.0] * 7 + [3.0])
    assert out == [7]


def test_straggler_monitor_ignores_transient_blip():
    mon = StragglerMonitor(n_hosts=4, patience=3)
    mon.observe([1, 1, 1, 5.0])
    out = mon.observe([1, 1, 1, 1.0])
    for _ in range(4):
        out = mon.observe([1, 1, 1, 1.0])
    assert out == []


def test_power_degraded_host_flagged_immediately():
    mon = StragglerMonitor(n_hosts=4, patience=3)
    mon.mark_power_degraded(2)
    assert 2 in mon.observe([1.0] * 4)


def test_reassign_shards_covers_all():
    m = reassign_shards(16, [0, 2, 3])
    got = sorted(s for shards in m.values() for s in shards)
    assert got == list(range(16))


def test_power_aware_emergency_checkpoint(tmp_path):
    ck = PowerAwareCheckpointer(Checkpointer(str(tmp_path)), every_steps=1000,
                                soc_window=(0.2, 0.8))
    tree = {"w": jnp.ones(2)}
    assert ck.maybe_save(5, tree, soc=0.5) is None
    assert ck.maybe_save(6, tree, soc=0.05) == "emergency"  # battery excursion
    ck.ckpt.wait()
    assert ck.ckpt.all_steps() == [6]
    # cooldown suppresses immediate repeat
    assert ck.maybe_save(7, tree, soc=0.05) is None


# ------------------------------------------------------------ compression --


def test_int8_compression_roundtrip_accuracy():
    g = {"w": jnp.asarray([0.5, -0.25, 1.0, 0.0])}
    state = init_compression(g)
    q, state = compress_int8(g, state)
    out = decompress_int8(q)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), atol=1.0 / 127)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_error_feedback_unbiased(seed):
    """With error feedback, the SUM of decompressed grads tracks the sum of
    true grads (residual bounded by one quantization step)."""
    key = jax.random.key(seed)
    state = init_compression({"w": jnp.zeros(16)})
    total_true = jnp.zeros(16)
    total_sent = jnp.zeros(16)
    for i in range(8):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (16,))}
        total_true = total_true + g["w"]
        q, state = compress_int8(g, state)
        total_sent = total_sent + decompress_int8(q)["w"]
    resid = np.abs(np.asarray(total_true - total_sent))
    scale = float(jnp.max(jnp.abs(total_true))) / 127 + 0.1
    assert resid.max() < 0.2  # bounded residual, not accumulating


def test_compressed_training_converges():
    """AdamW on int8-compressed grads still solves the quadratic."""
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    comp = init_compression(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(250):
        grads = {"w": 2 * (params["w"] - target)}
        q, comp = compress_int8(grads, comp)
        params, state, _ = adamw_update(decompress_int8(q), state, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=5e-2)


# ------------------------------------------------------------- serving ----


def test_serve_engine_greedy_matches_forward():
    from repro.models import transformer as T
    from repro.serve import ServeEngine

    cfg = smoke_config("llama3_2_1b")
    p = T.init(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, p, max_len=64)
    prompts = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    out = eng.generate(prompts, n_tokens=4)
    assert out.shape == (2, 12)
    # greedy continuation must equal argmax of the full forward each step
    full = T.forward(p, cfg, out[:, :-1]).logits
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(full[:, 7:11], -1)), np.asarray(out[:, 8:12])
    )


# ------------------------------------------------- end-to-end integration --


def test_train_loop_with_checkpoint_resume(tmp_path):
    cfg = smoke_config("llama3_2_1b")
    dc = DataConfig(batch=4, seq_len=32, vocab_size=cfg.vocab_size)
    oc = AdamWConfig(lr=1e-3)
    d = str(tmp_path / "ckpt")
    r1 = train(cfg, dc, oc, TrainConfig(steps=6, checkpoint_every=3, checkpoint_dir=d, log_every=2))
    # resume and continue: must pick up from the saved step
    r2 = train(cfg, dc, oc, TrainConfig(steps=8, checkpoint_every=3, checkpoint_dir=d,
                                        log_every=2, resume=True))
    assert r2["history"][0]["step"] >= 6


def test_train_loop_loss_decreases():
    cfg = smoke_config("llama3_2_1b")
    res = train(
        cfg,
        DataConfig(batch=8, seq_len=64, vocab_size=cfg.vocab_size),
        AdamWConfig(lr=3e-3),
        TrainConfig(steps=80, log_every=40),
    )
    assert res["last_loss"] < res["first_loss"] * 0.95


def test_train_loop_with_power_sim():
    """EasyRider in the loop: grid-compliant power while training runs."""
    from repro.power.integration import PowerSim
    from repro.power.phases import HardwareConstants, PhaseModel, StepCost

    cfg = smoke_config("llama3_2_1b")
    sim = PowerSim(
        StepCost(flops=5e18, hbm_bytes=2e15, collective_bytes=5e14),
        HardwareConstants(chips=256),
        PhaseModel(checkpoint_every_steps=0),
    )
    res = train(
        cfg,
        DataConfig(batch=2, seq_len=16, vocab_size=cfg.vocab_size),
        AdamWConfig(),
        TrainConfig(steps=8, log_every=4),
        power_sim=sim,
    )
    rep = res["power_report"]
    assert rep["grid_max_ramp"] <= 0.1 + 1e-3
    assert rep["rack_max_ramp"] > rep["grid_max_ramp"]
    assert 0.1 <= rep["final_soc"] <= 0.9
