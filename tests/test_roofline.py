"""Roofline HLO-parser tests (single-device: no collectives, but dots,
scans and trip counts are all exercised and checked against analytics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import roofline as RL


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_dot_flops_exact():
    def f(a, b):
        return a @ b

    txt = _compile(
        f,
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 32), jnp.float32),
    )
    costs = RL.analyze_compiled_hlo(txt)
    assert costs.flops_per_device == pytest.approx(2 * 64 * 128 * 32, rel=1e-6)


def test_scan_trip_count_multiplies_flops():
    L = 7

    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, ws)
        return h.sum()

    txt = _compile(
        f,
        jax.ShapeDtypeStruct((L, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
    )
    costs = RL.analyze_compiled_hlo(txt)
    assert L in costs.while_trip_counts.values()
    assert costs.flops_per_device == pytest.approx(L * 2 * 8 * 64 * 64, rel=1e-3)


def test_nested_scan_composes_trip_counts():
    lo, li = 3, 5

    def f(ws, x):
        def outer(h, wgroup):
            def inner(hh, w):
                return hh @ w, None

            h2, _ = jax.lax.scan(inner, h, wgroup)
            return h2, None

        h, _ = jax.lax.scan(outer, x, ws)
        return h.sum()

    txt = _compile(
        f,
        jax.ShapeDtypeStruct((lo, li, 32, 32), jnp.float32),
        jax.ShapeDtypeStruct((4, 32), jnp.float32),
    )
    costs = RL.analyze_compiled_hlo(txt)
    assert costs.flops_per_device == pytest.approx(lo * li * 2 * 4 * 32 * 32, rel=1e-3)


def test_shape_bytes_tuple_types():
    assert RL._shape_bytes("f32[4,8]{1,0}") == 128
    assert RL._shape_bytes("(s32[], f32[2,2]{1,0}, bf16[8]{0})") == 4 + 16 + 16
    assert RL._shape_bytes("pred[]") == 1


def test_roofline_terms_and_bottleneck():
    hw = RL.HardwareModel()
    costs = RL.HLOCosts(
        flops_per_device=197e12,  # exactly 1 second of compute
        hbm_bytes_per_device=819e9 * 0.5,
        collective_bytes_per_device=0.0,
        collective_breakdown={},
        n_collectives=0,
        while_trip_counts={},
    )
    t = RL.roofline_terms(costs, hw)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.bottleneck == "compute"
    assert t.step_time_s == pytest.approx(1.0)


def test_model_flops_dense_vs_moe():
    from repro.configs import full_config
    from repro.configs.shapes import TRAIN_4K

    dense = full_config("llama3_2_1b")
    moe = full_config("deepseek_v3_671b")
    mf_dense = RL.model_flops(dense, TRAIN_4K, backward=True)
    assert mf_dense == pytest.approx(6 * dense.param_count() * 256 * 4096, rel=1e-6)
    # MoE counts ACTIVE params only
    mf_moe = RL.model_flops(moe, TRAIN_4K, backward=True)
    assert mf_moe < 6 * moe.param_count() * 256 * 4096 * 0.1
