"""GPU-burn baseline tests (paper §7.3, Appendix C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import burn, compliance, pdu
from repro.power import trace


def test_calibration_recovers_linear_map():
    cal = burn.calibrate(jax.random.key(0), p_idle=0.06, p_peak=1.0, noise_std=0.005)
    assert float(cal.a) == pytest.approx(0.94, abs=0.02)
    assert float(cal.b) == pytest.approx(0.06, abs=0.02)
    assert float(cal.residual) < 0.01


def test_duty_inversion_roundtrip():
    cal = burn.DutyCalibration(a=jnp.asarray(0.9), b=jnp.asarray(0.1), residual=jnp.asarray(0.0))
    for target in (0.2, 0.5, 0.95):
        d = burn.duty_for_power(cal, jnp.asarray(target))
        p = burn.true_duty_power(d, 0.1, 1.0)
        assert float(p) == pytest.approx(target, abs=1e-6)


def test_duty_clipped():
    cal = burn.DutyCalibration(a=jnp.asarray(0.9), b=jnp.asarray(0.1), residual=jnp.asarray(0.0))
    assert float(burn.duty_for_power(cal, jnp.asarray(2.0))) == 1.0
    assert float(burn.duty_for_power(cal, jnp.asarray(0.0))) == 0.0


def test_envelope_is_ramp_compliant_and_above_rack():
    key = jax.random.key(1)
    rack = 0.5 + 0.4 * jnp.sign(jax.random.normal(key, (5000,)))
    dt = 0.01
    env = burn.ramp_compliant_envelope(rack, dt, beta=0.1)
    assert bool(jnp.all(env >= rack - 1e-6))
    assert float(compliance.max_abs_ramp(env, dt)) <= 0.1 + 1e-6


def test_envelope_tight_on_compliant_trace():
    dt = 0.01
    t = jnp.arange(2000) * dt
    slow = 0.5 + 0.3 * jnp.sin(2 * jnp.pi * 0.01 * t)  # well within ramp
    env = burn.ramp_compliant_envelope(slow, dt, beta=0.1)
    np.testing.assert_allclose(np.asarray(env), np.asarray(slow), atol=1e-6)


def test_burn_energy_overhead_matches_paper():
    """Paper §7.3: software burn consumes ~19% more energy than
    rack+EasyRider on the Titan X trace.  We assert the reproduced figure
    falls in 10-30% and that EasyRider's own overhead is <2%."""
    tb, dt = trace.titanx_testbench(jax.random.key(2))
    cal = burn.calibrate(jax.random.key(3), p_idle=0.06, p_peak=1.0)
    sched = burn.burn_schedule(tb, dt, beta=0.1, cal=cal)

    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, tb[0])
    gez, _, telem = pdu.condition(cfg, st, tb, qp_iters=20)
    soc = np.asarray(telem.soc)
    nwarm = sched.conditioned.shape[0] - tb.shape[0]
    cmp = burn.compare_energy(
        tb, gez, sched.conditioned[nwarm:], dt,
        soc_delta=float(soc[-1]) - 0.5, q_max_seconds=float(cfg.ess_params.q_max),
    )
    assert 0.10 <= float(cmp["burn_vs_easyrider_frac"]) <= 0.30
    assert 0.0 - 1e-3 <= float(cmp["easyrider_overhead_frac"]) <= 0.02


def test_burn_conditioned_trace_is_ramp_compliant():
    tb, dt = trace.titanx_testbench(jax.random.key(4))
    cal = burn.calibrate(jax.random.key(5), p_idle=0.06, p_peak=1.0)
    sched = burn.burn_schedule(tb, dt, beta=0.1, cal=cal)
    assert float(compliance.max_abs_ramp(sched.conditioned, dt)) <= 0.1 * (1 + 1e-3)
