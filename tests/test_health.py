"""Health-aware telemetry spine (ISSUE 5): online battery aging, cycle
counting, and streaming compliance.

Three contracts pinned here:

  * The scan-carried half-cycle counter matches a NumPy turning-point
    (rainflow-equivalent) reference on synthetic traces, and the whole
    ``HealthState`` is bit-identical under any chunking of the SoC stream
    — through raw ``health.update`` folds, chunked ``pdu.condition``
    calls, and all three fleet engines (incl. ragged tails and resume).
  * The streaming compliance observers reproduce the whole-trace oracles:
    the cross-chunk ramp observer equals ``max_abs_ramp`` bit-for-bit
    (including a worst-case step placed exactly on a chunk boundary — the
    regression the per-chunk ``jnp.diff`` blind spot would miss), and the
    Goertzel bank matches ``normalized_spectrum`` at every monitored line
    to <= 1e-5.
  * The health-aware outer loop (``wear_gain``) is bit-identical to the
    wear-blind policy at gain 0 and shrinks storage-mode excursions as
    cycle damage grows.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance, controller as ctrl, ess, fleet, health as H, pdu
from repro.power import scenario as SC

_HZ = 200.0
_SPEC = compliance.GridSpec.create()


# ------------------------------------------------- NumPy reference rainflow


def ref_half_cycles(soc, init, eps=0.0, g=0.6, soc_ref=0.5, kappa=2.0):
    """Turning-point half-cycle extraction (rainflow-equivalent on
    monotone-segment waves): every direction reversal closes a half cycle
    spanning the previous and current extremum.  Mirrors the documented
    ``health.update`` semantics but written as plain Python over floats."""
    prev, ext, d = float(init), float(init), 0.0
    hc, dmg, maxd, depths = 0, 0.0, 0.0, []
    for cur in np.asarray(soc, np.float64):
        delta = cur - prev
        sd = 1.0 if delta > eps else (-1.0 if delta < -eps else 0.0)
        if sd * d < 0.0:
            depth = abs(prev - ext)
            mid = 0.5 * (prev + ext)
            w = max(1.0 + g * (mid - soc_ref), 0.0)
            dmg += 0.5 * w * depth**kappa
            hc += 1
            maxd = max(maxd, depth)
            depths.append(depth)
            ext = prev
        if sd != 0.0:
            d = sd
        prev = cur
    return hc, dmg, maxd, depths


def _fold(p, soc, init, splits=None):
    st = H.init_state(jnp.asarray(init, jnp.float32))
    bounds = [0] + list(splits or []) + [len(soc)]
    for a, b in zip(bounds[:-1], bounds[1:]):
        if b > a:
            st = H.update(p, st, jnp.asarray(soc[a:b], jnp.float32), 1.0 / _HZ)
    return st


def _sawtooth(n=4000, periods=10, lo=0.35, hi=0.65):
    t = np.arange(n) * (2.0 * periods / n)  # triangle period = 2.0 in t
    return (lo + (hi - lo) * np.abs((t % 2.0) - 1.0)).astype(np.float32)


def _iteration_wave(n=4000, period=137):
    # square-ish compute/communicate wave with ramped edges, like a
    # training iteration's power cycle integrated into SoC
    t = np.arange(n)
    tri = np.abs(((t / period) % 2.0) - 1.0)
    return (0.45 + 0.1 * np.clip(2.0 * tri - 0.5, 0.0, 1.0)).astype(np.float32)


def _mixed_walk(n=4000, seed=0):
    rng = np.random.default_rng(seed)
    steps = rng.normal(0.0, 2e-4, n) + 3e-4 * np.sin(np.arange(n) / 60.0)
    soc = 0.5 + np.cumsum(steps)
    # plateaus: zero-delta runs must not close cycles
    soc[1200:1300] = soc[1200]
    return np.clip(soc, 0.1, 0.9).astype(np.float32)


@pytest.mark.parametrize(
    "trace", [_sawtooth(), _iteration_wave(), _mixed_walk()],
    ids=["sawtooth", "iteration_wave", "mixed"],
)
def test_half_cycles_match_numpy_reference(trace):
    p = H.HealthParams.create()
    st = _fold(p, trace, trace[0])
    hc, dmg, maxd, _ = ref_half_cycles(trace, trace[0])
    assert int(st.half_cycles) == hc
    np.testing.assert_allclose(float(st.cycle_damage), dmg, rtol=1e-4, atol=1e-9)
    np.testing.assert_allclose(float(st.max_dod), maxd, rtol=1e-5, atol=1e-7)


def test_sawtooth_counts_are_the_analytic_rainflow():
    """10 triangle periods = 20 monotone segments: 19 closed half cycles at
    full range (the final segment stays open) + the initial half segment."""
    tr = _sawtooth(n=4000, periods=10)
    st = _fold(H.HealthParams.create(), tr, tr[0])
    assert int(st.half_cycles) == 19
    np.testing.assert_allclose(float(st.max_dod), 0.3, atol=1e-3)
    # EFC: total |dSoC|/2 = 10 periods * 2*0.3 swing / 2 (the sampled
    # triangle misses the exact peaks by up to one sample step)
    np.testing.assert_allclose(
        float(H.equivalent_full_cycles(st)), 3.0, rtol=1e-3
    )


_SCAN_LEAVES = (  # carried sample-by-sample: bitwise under ANY split
    "prev_soc", "last_ext", "direction", "half_cycles", "cycle_damage",
    "max_dod", "samples",
)


def test_update_split_invariance():
    """Scan-carried leaves are bitwise under any split; the block-reduction
    leaves (charge/discharge/SoC sums) are bitwise whenever the blocks
    match — the engines always fold one controller interval per block —
    and agree to float tolerance under any other split."""
    p = H.HealthParams.create()
    tr = _mixed_walk(seed=3)
    whole = _fold(p, tr, 0.5)
    for splits in ([1], [7, 13, 14, 1999], list(range(100, 4000, 100))):
        parts = _fold(p, tr, 0.5, splits=splits)
        for name, a, b in zip(whole._fields, whole, parts):
            if name in _SCAN_LEAVES:
                assert np.array_equal(np.asarray(a), np.asarray(b)), name
            else:
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7,
                    err_msg=name,
                )
    # identical blocks => identical bits, reduction leaves included
    a = _fold(p, tr, 0.5, splits=[1000, 2000, 3000])
    b = _fold(p, tr, 0.5, splits=[1000, 2000, 3000])
    for name, x, y in zip(a._fields, a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), name


def test_update_batched_matches_per_rack():
    p = H.HealthParams.create()
    tr = np.stack([_sawtooth(), _iteration_wave(), _mixed_walk()], axis=1)
    st = H.init_state(jnp.asarray(tr[0]))
    st = H.update(p, st, jnp.asarray(tr), 1.0 / _HZ)
    for r in range(tr.shape[1]):
        single = _fold(p, tr[:, r], tr[0, r])
        for name, a, b in zip(st._fields, st, single):
            if name in _SCAN_LEAVES:
                np.testing.assert_array_equal(
                    np.asarray(a)[r], np.asarray(b), err_msg=f"{name} rack {r}"
                )
            else:  # block reductions: order differs with the batch shape
                np.testing.assert_allclose(
                    np.asarray(a)[r], np.asarray(b), rtol=1e-6,
                    err_msg=f"{name} rack {r}",
                )


def test_battery_power_from_soc_delta_roundtrip():
    ep = ess.ESSParams.create()
    dt = 5e-3
    power = jnp.asarray([-0.8, -1e-4, 0.0, 3e-4, 0.9], jnp.float32)
    d_soc = ess.soc_increment(ep, power, dt)
    back = ess.battery_power_from_soc_delta(ep, d_soc, dt)
    np.testing.assert_allclose(np.asarray(back), np.asarray(power), rtol=1e-5, atol=1e-9)


def test_report_derivations():
    p = H.HealthParams.create()
    ep = ess.ESSParams.create()
    tr = _sawtooth()
    st = _fold(p, tr, tr[0])
    rep = H.report(p, ep, st, 1.0 / _HZ)
    assert float(rep.elapsed_s) == pytest.approx(4000 / _HZ)
    assert float(rep.mean_soc) == pytest.approx(float(np.mean(tr)), rel=1e-4)
    assert float(rep.soc_std) == pytest.approx(float(np.std(tr)), rel=1e-3)
    assert float(rep.capacity_fade) > 0.0
    assert np.isfinite(float(rep.projected_life_s))
    # zero-history state: no damage, infinite projected life
    rep0 = H.report(p, ep, H.init_state(0.5), 1.0 / _HZ)
    assert float(rep0.capacity_fade) == 0.0
    assert np.isposinf(float(rep0.projected_life_s))


# ------------------------------------------------ health through the engines


def _campus(n_racks=4, duration_s=44.0):
    return SC.mixed_campus(
        n_racks,
        ("llama3_2_1b", "whisper_large_v3"),
        duration_s=duration_s,
        sample_hz=_HZ,
        seed=2,
        fault_at_s=duration_s * 0.6,
        noise_seed=7,
    )


def _cfg(**kw):
    kw.setdefault("track_health", True)
    return pdu.make_pdu(sample_dt=1.0 / _HZ, **kw)


def _assert_health_equal(ha, hb, what=""):
    for name, a, b in zip(ha._fields, ha, hb):
        assert np.array_equal(np.asarray(a), np.asarray(b)), f"{what}{name}"


def test_condition_chunked_equals_one_shot_health():
    """pdu-level: conditioning in interval-aligned chunks folds the same
    HealthState bit-for-bit as one whole-trace call."""
    cfg = _cfg()
    k = int(round(float(cfg.controller.dt) * _HZ))
    tr = SC.render(_campus(3), 0, 6 * k)
    st = pdu.init_state(cfg, tr[0])
    _, whole, _ = pdu.condition(cfg, st, tr, qp_iters=10)
    st2 = pdu.init_state(cfg, tr[0])
    for a in range(0, 6 * k, 2 * k):
        _, st2, _ = pdu.condition(cfg, st2, tr[a : a + 2 * k], qp_iters=10)
    _assert_health_equal(whole.health, st2.health)


@pytest.mark.parametrize("duration_s", [44.0, 32.5])
def test_engines_agree_on_health(duration_s):
    """scanned == host-loop == one-shot for every health accumulator,
    bitwise — including a ragged tail shorter than one controller
    interval (32.5 s against k = 1000 chunks)."""
    s = _campus(4, duration_s)
    cfg = _cfg()
    a = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=10, chunk_intervals=2)
    b = fleet.condition_scenario_streaming(
        cfg, s, _SPEC, engine="host", qp_iters=10, chunk_intervals=2
    )
    _assert_health_equal(a.state.health, b.state.health, "scanned vs host: ")
    # per-chunk telemetry: EFC / max-DoD columns are raw accumulators
    # (bitwise); the fade column is a derived mul+add chain, which XLA
    # FMA-contracts differently per fusion context (few-ulp contract).
    ta, tb = np.asarray(a.health_trace), np.asarray(b.health_trace)
    np.testing.assert_array_equal(ta[:, [0, 2]], tb[:, [0, 2]])
    np.testing.assert_allclose(ta[:, 1], tb[:, 1], rtol=1e-5, atol=1e-9)
    full = SC.render(s, 0, s.total_samples)
    st0 = pdu.init_state(cfg, full[0])
    _, st_f, _ = pdu.condition(cfg, st0, full, qp_iters=10)
    _assert_health_equal(a.state.health, st_f.health, "scanned vs one-shot: ")
    # derived fade agrees too (pure function of bitwise-equal accumulators)
    np.testing.assert_allclose(
        np.asarray(a.health.capacity_fade),
        np.asarray(b.health.capacity_fade),
        atol=1e-5,
    )


def test_health_is_chunk_size_invariant_and_resume_safe():
    s = _campus(4)
    cfg = _cfg()
    a = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=10, chunk_intervals=2)
    b = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=10, chunk_intervals=4)
    _assert_health_equal(a.state.health, b.state.health, "chunk size: ")
    k = int(round(float(cfg.controller.dt) * _HZ))
    first = fleet.condition_scenario_scanned(
        cfg, s, _SPEC, qp_iters=10, chunk_intervals=2, stop_sample=4 * k
    )
    rest = fleet.condition_scenario_scanned(
        cfg, s, _SPEC, qp_iters=10, chunk_intervals=2,
        state=first.state, start_sample=4 * k,
    )
    _assert_health_equal(a.state.health, rest.state.health, "resume: ")


@pytest.mark.pallas
def test_megakernel_fold_matches_hybrid_update_bitwise():
    """The interval-resident Pallas megakernel (interpret mode) folds the
    exact HealthState the shipped hybrid path produces — ``pdu_sim`` +
    ``update_consts`` per interval — bit for bit, including across an
    interval-aligned resume split."""
    from repro.kernels import ops, ref

    cfg = _cfg()
    k = int(round(float(cfg.controller.dt) * _HZ))
    tr = SC.render(_campus(4), 0, 2 * k)
    st = pdu.init_state(cfg, tr[0])
    ep = cfg.ess_params
    filt = st.filter_obj
    kw = dict(
        beta=float(ep.beta), dt=1.0 / _HZ, q_max=float(ep.q_max),
        eta_c=float(ep.eta_c), eta_d=float(ep.eta_d), p_max=float(ep.p_max),
        soc_min=float(ep.soc_safe_min), soc_max=float(ep.soc_safe_max),
    )
    hc = H.step_consts(cfg.health)
    zero = jnp.zeros_like(st.ess_state.g_filter)

    def hybrid(chunk, g0, soc0, x0, hstate):
        _, soc_t, fin = ref.pdu_sim(
            chunk, g0, soc0, x0, filt.ad, filt.bd, filt.c[0],
            corrective=jnp.zeros_like(chunk), **kw
        )
        return fin, H.update_consts(hc, H.HealthState(*hstate), soc_t)

    def kernel(chunk, g0, soc0, x0, hstate):
        _, _, fin, h2 = ops.pdu_health_sim(
            chunk, g0, soc0, x0, filt.ad, filt.bd, filt.c[0],
            corrective=0.0, health=(hc, tuple(hstate)), force="pallas", **kw
        )
        return fin, h2

    for fold in (hybrid, kernel):
        g0, soc0, x0, hs = st.ess_state.g_filter, st.ess_state.soc, st.filter_state, st.health
        for a in range(0, 2 * k, k):  # one controller interval per block
            (g0, soc0, x0), hs = fold(tr[a : a + k], g0, soc0, x0, hs)
        if fold is hybrid:
            want = H.HealthState(*hs)
        else:
            _assert_health_equal(want, H.HealthState(*hs), "megakernel vs hybrid: ")


def test_health_trace_monotone_and_disabled_is_zero():
    s = _campus(3)
    res = fleet.condition_scenario_scanned(_cfg(), s, _SPEC, qp_iters=10, chunk_intervals=2)
    ht = np.asarray(res.health_trace)
    assert ht.shape[1] == 3
    # accumulators only grow chunk over chunk
    assert np.all(np.diff(ht[:, 0]) >= 0)  # mean EFC
    assert np.all(np.diff(ht[:, 1]) >= 0)  # max fade
    assert float(ht[-1, 0]) > 0
    off = fleet.condition_scenario_scanned(
        pdu.make_pdu(sample_dt=1.0 / _HZ), s, _SPEC, qp_iters=10, chunk_intervals=2
    )
    assert np.all(np.asarray(off.health_trace) == 0.0)
    assert float(np.max(np.asarray(off.health.capacity_fade))) == 0.0


# -------------------------------------------------- health-aware outer loop


def test_wear_gain_zero_is_bit_identical():
    cfg = ctrl.ControllerConfig.create()
    es = ess.ESSParams.create()
    idle = jnp.asarray(1e6)
    t0 = ctrl.select_target(cfg, es, idle, 0.0)
    t1 = ctrl.select_target(cfg, es, idle, 0.73)  # wear ignored at gain 0
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))


def test_wear_gain_narrows_storage_excursion():
    cfg = ctrl.ControllerConfig.create(
        s_idle=0.1, delta_s_max=0.15, wear_gain=1.0
    )
    es = ess.ESSParams.create()
    idle = jnp.asarray(1e6)
    fresh = float(ctrl.select_target(cfg, es, idle, 0.0))
    worn = float(ctrl.select_target(cfg, es, idle, 0.5))
    dead = float(ctrl.select_target(cfg, es, idle, 1.0))
    assert fresh == pytest.approx(0.35)  # s_mid - delta_s_max
    assert worn == pytest.approx(0.425)  # excursion halved
    assert dead == pytest.approx(0.5)  # no excursion left -> stays at S_mid
    # negative gain widens instead (calendar-dominated installs)
    cfg_w = ctrl.ControllerConfig.create(
        s_idle=0.1, delta_s_max=0.15, wear_gain=-1.0
    )
    wider = float(ctrl.select_target(cfg_w, es, idle, 0.5))
    assert wider == pytest.approx(0.275)
    # per-rack wear vector -> per-rack targets
    t = ctrl.select_target(cfg, es, idle, jnp.asarray([0.0, 0.5, 1.0]))
    np.testing.assert_allclose(np.asarray(t), [0.35, 0.425, 0.5], atol=1e-6)


# ------------------------------------------------------ streaming compliance


def test_ramp_observer_matches_whole_trace_bitwise():
    rng = np.random.default_rng(0)
    tr = rng.uniform(0.2, 1.0, 5000).astype(np.float32)
    dt = 1.0 / _HZ
    whole = compliance.max_abs_ramp(jnp.asarray(tr), dt)
    obs = compliance.ramp_observer_init()
    for a in (0, 700, 701, 2500, 4999):
        b = {0: 700, 700: 701, 701: 2500, 2500: 4999, 4999: 5000}[a]
        obs = compliance.ramp_observer_update(obs, jnp.asarray(tr[a:b]), dt)
    assert np.asarray(obs.max_ramp) == np.asarray(whole)
    assert int(obs.n) == 5000


def test_boundary_step_is_not_dropped():
    """Regression (ISSUE 5 satellite): the worst-case step placed EXACTLY on
    a chunk boundary.  A per-chunk ``jnp.diff`` never sees it; the observer
    must."""
    dt = 1.0 / _HZ
    chunk = 1000
    tr = np.full(4000, 0.2, np.float32)
    tr[2 * chunk :] = 1.0  # step between sample 1999 and 2000: a boundary
    chunks = [jnp.asarray(tr[a : a + chunk]) for a in range(0, 4000, chunk)]
    naive = max(float(jnp.max(jnp.abs(jnp.diff(c)))) / dt for c in chunks)
    assert naive == 0.0  # the blind spot: each chunk is flat
    obs = compliance.ramp_observer_init()
    for c in chunks:
        obs = compliance.ramp_observer_update(obs, c, dt)
    expected = float(compliance.max_abs_ramp(jnp.asarray(tr), dt))
    assert float(obs.max_ramp) == expected > 100.0


def test_streaming_engine_sees_boundary_step():
    """End-to-end: a raw campus step landing exactly on the streaming
    chunk boundary shows up in the engine's rack-side report."""
    cfg = pdu.make_pdu(sample_dt=1.0 / _HZ)
    k = int(round(float(cfg.controller.dt) * _HZ))
    chunk = 2 * k  # chunk_intervals=2
    tr = np.full((2 * chunk, 2), 0.3, np.float32)
    tr[chunk:] = 0.9  # step exactly at the chunk boundary
    res = fleet.condition_fleet_streaming(
        cfg, jnp.asarray(tr), _SPEC, qp_iters=5, chunk_intervals=2
    )
    expected = float(compliance.max_abs_ramp(jnp.mean(jnp.asarray(tr), axis=1), 1.0 / _HZ))
    assert float(res.report_rack.max_ramp) == pytest.approx(expected)
    assert not bool(res.report_rack.ramp_ok)


@pytest.mark.parametrize("chunk", [997, 4000])
def test_goertzel_bank_matches_normalized_spectrum(chunk):
    """Chunk-folded Goertzel == whole-trace windowed FFT at every monitored
    line, <= 1e-5 (the streaming spectral-compliance contract)."""
    sp_mod = __import__("repro.power.trace", fromlist=["trace"])
    rack, dt = sp_mod.testbench_trace(
        sp_mod.TestbenchSpec(duration_s=60.0, sample_hz=_HZ), jax.random.key(0)
    )
    tr = np.asarray(rack)
    n = tr.shape[0]
    bank = compliance.make_bank(n, dt, float(_SPEC.f_c))
    obs = compliance.spectrum_observer_init(bank)
    for a in range(0, n, chunk):
        obs = compliance.spectrum_observer_update(bank, obs, jnp.asarray(tr[a : a + chunk]))
    freqs, s_obs = compliance.spectrum_observer_finalize(bank, obs)
    _, s_fft = compliance.normalized_spectrum(jnp.asarray(tr), dt)
    ref = np.asarray(s_fft)[np.asarray(bank.bins)]
    np.testing.assert_allclose(np.asarray(s_obs), ref, atol=1e-5)
    assert np.all(freqs >= float(_SPEC.f_c) - 1e-9)


def test_goertzel_rect_window_online_mode():
    """Open-ended (total length unknown) banks: rectangular window, lines
    snapped to the test trace's bins for an exact FFT comparison."""
    rng = np.random.default_rng(1)
    n = 1 << 13
    dt = 1.0 / _HZ
    tr = (0.5 + 0.1 * rng.standard_normal(n)).astype(np.float32)
    bank = compliance.make_online_bank(dt, 2.0, modulus=n)
    obs = compliance.spectrum_observer_init(bank)
    for a in range(0, n, 600):
        obs = compliance.spectrum_observer_update(bank, obs, jnp.asarray(tr[a : a + 600]))
    _, s_obs = compliance.spectrum_observer_finalize(bank, obs)
    _, s_fft = compliance.normalized_spectrum(jnp.asarray(tr), dt, window=None)
    ref = np.asarray(s_fft)[np.asarray(bank.bins)]
    np.testing.assert_allclose(np.asarray(s_obs), ref, atol=1e-5)


def test_streaming_report_matches_whole_trace_compliance():
    """The mixed-campus acceptance check at test scale: the scanned
    engine's observer-built report reproduces the whole-trace oracle —
    ramp exactly, spectral lines <= 1e-5."""
    s = _campus(4)
    cfg = _cfg()
    res = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=10, chunk_intervals=2)
    camp = np.asarray(res.campus_grid)
    assert float(res.report_grid.max_ramp) == float(
        compliance.max_abs_ramp(jnp.asarray(camp), 1.0 / _HZ)
    )
    bank = compliance.make_bank(len(camp), 1.0 / _HZ, float(_SPEC.f_c))
    _, s_fft = compliance.normalized_spectrum(jnp.asarray(camp), 1.0 / _HZ)
    worst_lines = float(np.max(np.asarray(s_fft)[np.asarray(bank.bins)]))
    assert float(res.report_grid.worst_high_freq_mag) == pytest.approx(
        worst_lines, abs=1e-5
    )


def test_powersim_reports_health_and_boundary_ramp():
    from repro.power.integration import PowerSim, PowerSimConfig
    from repro.power import phases

    cost = phases.StepCost(flops=5e17, hbm_bytes=2e14, collective_bytes=5e13)
    sim = PowerSim(
        cost, phases.HardwareConstants(),
        phases.PhaseModel(checkpoint_every_steps=0),
        PowerSimConfig(),
    )
    k = sim._k
    lo = jnp.full((k,), 0.3, jnp.float32)
    hi = jnp.full((k,), 0.9, jnp.float32)
    sim._condition(lo, 1.0 / _HZ)
    sim._condition(hi, 1.0 / _HZ)  # step exactly at the conditioned-chunk seam
    rep = sim.report()
    expected = 0.6 * _HZ
    assert rep["rack_max_ramp"] == pytest.approx(expected, rel=1e-5)
    assert rep["battery_efc"] >= 0.0
    assert 0.0 <= rep["battery_capacity_fade"] < 1.0
    assert rep["battery_projected_life_years"] > 0.0


# ------------------------------------------------------------- bench gating


def test_bench_gate_records():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.run import gate_records

    baseline = {"a": 100.0, "quick:a": 10.0, "b": 200.0}
    # pass: within threshold; new bench without baseline is skipped
    assert gate_records({"a": 110.0, "c": 999.0}, baseline, 25.0, quick=False) == []
    # fail: >25% regression, reported with the offending numbers
    fails = gate_records({"a": 140.0}, baseline, 25.0, quick=False)
    assert len(fails) == 1 and "a:" in fails[0]
    # quick mode compares against the quick: namespace
    assert gate_records({"a": 11.0}, baseline, 25.0, quick=True) == []
    assert len(gate_records({"a": 14.0}, baseline, 25.0, quick=True)) == 1
