"""Sizing (Appendix A.1) tests incl. hypothesis properties tying the sizing
formulas to simulated behavior."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st  # optional-hypothesis shim

from repro.core import compliance, ess, filters, sizing


def test_epsilon():
    r = sizing.RackRating(p_rated_w=10_000, p_min_w=2_000)
    assert r.epsilon == pytest.approx(0.8)


def test_eq8_capacity_bound():
    r = sizing.prototype_rack()
    s = sizing.size_system(r, beta=0.1, gamma=0.5)
    assert s.battery_energy_j == pytest.approx(0.8 / (0.5 * 0.1) * 10_000)


def test_eq9_power_rating():
    r = sizing.prototype_rack()
    s = sizing.size_system(r, beta=0.1)
    assert s.battery_power_w == pytest.approx(0.8 * 10_000)


def test_eq10_lc_cutoff():
    l, c = sizing.lc_from_cutoff(4.0, 4.0)
    f = 1.0 / (2 * np.pi * np.sqrt(l * c))
    assert f == pytest.approx(4.0, rel=1e-9)


def test_prototype_capacity_less_than_paper_battery():
    """Paper §8: the 74 Ah pack is 'intentionally oversized relative to the
    requirements derived in Appendix A.1' — our derived requirement must
    come out well below 74 Ah."""
    r = sizing.prototype_rack()
    s = sizing.size_system(r, beta=0.1, gamma=0.5)
    assert s.battery_capacity_ah < 74.0


def test_damping_leg_bounds_peak():
    r = sizing.prototype_rack()
    s = sizing.size_system(r, beta=0.1)
    p = filters.LCFilterParams.create(s.l_f, s.c_f, s.r_da, s.l_da)
    assert float(filters.resonance_peak_db(p)) < 7.0


@settings(max_examples=15, deadline=None)
@given(
    beta=st.floats(0.05, 0.3),
    eps=st.floats(0.3, 0.95),
)
def test_property_sized_battery_never_saturates_on_worst_step(beta, eps):
    """A battery sized by Eq. 8 (gamma = usable window, starting at the
    favorable edge) absorbs the worst-case step without saturating."""
    gamma = 0.8
    q = sizing.size_system(
        sizing.RackRating(10_000, 10_000 * (1 - eps)), beta=beta, gamma=gamma
    ).battery_energy_j / 10_000.0
    p = ess.ESSParams.create(
        beta=beta, q_max_seconds=q, eta_c=1.0, eta_d=1.0,
        soc_safe_min=0.1, soc_safe_max=0.9,
    )
    dt = 0.02
    n = int(20 / beta / dt)
    r = jnp.ones((n,)) * 1.0
    r = r.at[n // 4 :].set(1.0 - eps)
    # worst-case (downward step): start at the lower safe bound.
    st0 = ess.ESSState(g_filter=jnp.asarray(1.0), soc=jnp.asarray(0.1))
    g, soc, _ = ess.simulate(p, st0, r, dt)
    assert float(jnp.max(soc)) <= 0.9 + 1e-5
    # no shedding: ramp stays within beta * eps
    assert float(compliance.max_abs_ramp(g, dt)) <= beta * eps + 1e-4


@settings(max_examples=10, deadline=None)
@given(f_f=st.floats(0.5, 20.0))
def test_property_lc_sizing_hits_cutoff(f_f):
    l, c = sizing.lc_from_cutoff(f_f, 4.0)
    r_da, l_da = sizing.damping_leg(l, c)
    p = filters.LCFilterParams.create(l, c, r_da, l_da)
    assert float(p.cutoff_hz()) == pytest.approx(f_f, rel=1e-3)
    assert float(filters.resonance_peak_db(p)) < 7.0


def test_workload_informed_cutoff():
    """A workload with strong 2-4 Hz content needs a lower f_f than the
    4 Hz prototype; a quiet workload allows a higher one."""
    freqs = np.array([2.5, 5.0, 10.0])
    hot = np.array([3e-2, 1e-2, 5e-3])
    quiet = np.array([1e-4, 5e-5, 1e-5])
    f_hot = sizing.filter_cutoff_for_workload((freqs, hot), beta=0.1, alpha=1e-4, f_c=2.0)
    f_quiet = sizing.filter_cutoff_for_workload((freqs, quiet), beta=0.1, alpha=1e-4, f_c=2.0)
    assert f_hot < f_quiet
    assert f_hot < 4.0


def test_mw_rack_sizing_scales_linearly():
    proto = sizing.size_system(sizing.prototype_rack(), beta=0.1)
    mw = sizing.size_system(sizing.mw_rack(), beta=0.1)
    assert mw.battery_energy_j == pytest.approx(proto.battery_energy_j * 100.0)
    assert mw.battery_power_w == pytest.approx(proto.battery_power_w * 100.0)
