"""Grid-region tests (ISSUE 8): POI aggregation, swing coupling, mode-band
verdicts, the ``fleet.condition`` facade vs its deprecated wrappers, and
campus sharding.

Bitwise contract: the sequential region engine routes every campus through
the same trivial (campus=1, data=1) ``shard_map`` mesh the sharded engine
compiles, and the POI is a left-to-right float32 weighted sum matching the
in-scan ``psum`` order — so sequential vs sharded agreement is exact array
equality, not a tolerance.  The multi-device half of that claim runs in a
subprocess with ``--xla_force_host_platform_device_count=8`` (this process
has already initialized a 1-CPU backend).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance, fleet, grid, pdu
from repro.power import scenario as SC
from repro.sharding import rules

pytestmark = pytest.mark.grid

_HZ = 50.0
_SPEC = compliance.GridSpec.create()


def _cfg(**kw):
    return pdu.make_pdu(sample_dt=1.0 / _HZ, **kw)


def _small_region(n_campuses=3, n_racks=4, duration_s=60.0, **kw):
    return grid.checkpoint_region(
        n_campuses, n_racks, duration_s=duration_s, sample_hz=_HZ, **kw)


def _campus(n_racks=4, duration_s=60.0, seed=2, noise_seed=7):
    return SC.mixed_campus(
        n_racks,
        ("llama3_2_1b", "whisper_large_v3"),
        duration_s=duration_s,
        sample_hz=_HZ,
        seed=seed,
        noise_seed=noise_seed,
    )


def _tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------------------- builders


def test_region_rejects_mismatched_clock():
    a = _campus(duration_s=60.0)
    b = _campus(duration_s=40.0)
    with pytest.raises(ValueError, match="one POI clock"):
        grid.region([a, b])


def test_region_default_weights_follow_rack_share():
    reg = grid.region([_campus(n_racks=4), _campus(n_racks=6, seed=3)])
    np.testing.assert_allclose(np.asarray(reg.weights), [0.4, 0.6], atol=1e-7)
    assert reg.n_racks == (4, 6)
    assert reg.names == ("campus0", "campus1")
    assert reg.n_campuses == 2
    assert reg.sample_hz == _HZ


def test_region_validates_weights_and_names():
    c = [_campus(), _campus(seed=3)]
    with pytest.raises(ValueError, match="weights shape"):
        grid.region(c, weights=np.ones((3,), np.float32))
    with pytest.raises(ValueError, match="names"):
        grid.region(c, names=("only-one",))
    with pytest.raises(ValueError, match="at least one campus"):
        grid.region([])


def test_region_salts_noise_per_campus():
    # Same workload spec + same static noise_seed: the builder must salt
    # each campus so the measurement noise decorrelates across the region.
    reg = _small_region(n_campuses=2, duration_s=20.0, noise_seed=5)
    salts = [c.noise_salt for c in reg.campuses]
    assert salts[0] is not None and salts[1] is not None
    assert int(np.asarray(salts[0])) != int(np.asarray(salts[1]))
    r0 = np.asarray(SC.render(reg.campuses[0], 0, 200))
    r1 = np.asarray(SC.render(reg.campuses[1], 0, 200))
    assert not np.array_equal(r0, r1)

    # Without noise there is nothing to salt.
    clean = _small_region(n_campuses=2, duration_s=20.0, noise_seed=None)
    assert all(c.noise_salt is None for c in clean.campuses)


# ---------------------------------------------------------------- POI model


def test_poi_response_flat_trace_is_quiet():
    r = grid.poi_response(jnp.full((500,), 0.5), grid.POIConfig(), 1.0 / _HZ)
    np.testing.assert_array_equal(np.asarray(r.freq_dev_hz), 0.0)
    np.testing.assert_array_equal(np.asarray(r.volt_dev), 0.0)
    assert float(r.max_freq_dev_hz) == 0.0


def test_poi_response_step_signs_and_linearity():
    # A sustained load increase must depress both frequency and voltage.
    # Post-step span of 60 s ≈ 11 swing time constants (M/D ≈ 5.3 s), so
    # the tail sits at the analytic steady state.
    p = jnp.concatenate([jnp.full((250,), 0.5), jnp.full((3000,), 0.7)])
    poi = grid.POIConfig()
    r = grid.poi_response(p, poi, 1.0 / _HZ, p_ref=jnp.float32(0.5))
    assert float(r.freq_dev_hz[-1]) < 0.0
    assert float(r.volt_dev[-1]) < 0.0
    np.testing.assert_allclose(
        float(r.volt_dev[-1]), -poi.v_sens * 0.2, rtol=1e-5)
    # Steady state of M df/dt = -(k*dp + D f) is f = -k*dp/D (per unit).
    expect = -poi.region_fraction * 0.2 / poi.damping * poi.f0_hz
    np.testing.assert_allclose(float(r.freq_dev_hz[-1]), expect, rtol=1e-3)
    # The swing recurrence is linear: doubling the coupling doubles freq.
    r2 = grid.poi_response(
        p, grid.POIConfig(region_fraction=2 * poi.region_fraction),
        1.0 / _HZ, p_ref=jnp.float32(0.5))
    np.testing.assert_allclose(
        np.asarray(r2.freq_dev_hz), 2.0 * np.asarray(r.freq_dev_hz),
        atol=1e-6)


# ------------------------------------------------------------ mode detector


def test_mode_bank_lines_cover_every_band():
    n = int(100.0 * _HZ)
    bank = grid.mode_bank(n, 1.0 / _HZ)
    freqs = bank.freqs
    for b in grid.DEFAULT_MODE_BANDS:
        sel = (freqs >= b.lo_hz) & (freqs < b.hi_hz)
        assert np.any(sel), f"no monitored line in {b.name}"
    lo = min(b.lo_hz for b in grid.DEFAULT_MODE_BANDS)
    hi = max(b.hi_hz for b in grid.DEFAULT_MODE_BANDS)
    # The bank monitors the inclusive band-edge bin; verdicts select
    # half-open [lo, hi) per band.
    assert np.all((freqs >= lo) & (freqs <= hi))


def test_mode_verdicts_flag_injected_tone():
    n = int(100.0 * _HZ)
    dt = 1.0 / _HZ
    t = np.arange(n) * dt
    tone = jnp.asarray(0.5 + 0.02 * np.sin(2 * np.pi * 0.5 * t), jnp.float32)
    bank = grid.mode_bank(n, dt)
    obs = compliance.spectrum_observer_update(
        bank, compliance.spectrum_observer_init(bank), tone)
    mags, ok = grid.mode_verdicts(bank, obs, grid.DEFAULT_MODE_BANDS)
    mags, ok = np.asarray(mags), np.asarray(ok)
    assert not ok[0] and mags[0] == pytest.approx(0.02, rel=0.05)
    assert ok[1] and mags[1] < 1e-3

    quiet = jnp.full((n,), 0.5)
    obs_q = compliance.spectrum_observer_update(
        bank, compliance.spectrum_observer_init(bank), quiet)
    _, ok_q = grid.mode_verdicts(bank, obs_q, grid.DEFAULT_MODE_BANDS)
    assert np.all(np.asarray(ok_q))


def test_mode_verdicts_empty_band_passes():
    # A 4 s trace cannot resolve the 0.1-1 Hz band's lower end with bins
    # strictly inside [0.1, 1.0) only if lines exist; shrink to a band
    # below the fundamental so no DFT bin lands inside it.
    n = int(4.0 * _HZ)
    bank = grid.mode_bank(
        n, 1.0 / _HZ, bands=(grid.ModeBand("sub", 0.01, 0.2, 1e-9),))
    narrow = (grid.ModeBand("none", 0.0101, 0.0102, 1e-9),)
    obs = compliance.spectrum_observer_update(
        bank, compliance.spectrum_observer_init(bank),
        jnp.ones((n,), jnp.float32))
    mags, ok = grid.mode_verdicts(bank, obs, narrow)
    assert float(mags[0]) == 0.0 and bool(ok[0])


@pytest.mark.slow
def test_synchronized_checkpoints_ring_staggered_cancel():
    # The paper-level finding: lockstep checkpoint stalls across campuses
    # excite a sub-Hz inter-area mode at the POI; staggering the same
    # schedule cancels it.  Runs the full conditioning stack.
    cfg = _cfg()
    sync = grid.synchronized_region(
        n_campuses=4, n_racks=6, duration_s=100.0, sample_hz=_HZ)
    stag = grid.staggered_region(
        n_campuses=4, n_racks=6, duration_s=100.0, sample_hz=_HZ)
    rs = fleet.condition(sync, cfg, _SPEC)
    rt = fleet.condition(stag, cfg, _SPEC)
    assert not bool(rs.report_poi.modes_ok)
    assert not bool(np.asarray(rs.report_poi.mode_ok)[0])  # inter-area band
    assert bool(rt.report_poi.modes_ok)
    # An order of magnitude of separation, not a marginal verdict.
    assert float(rs.report_poi.mode_mags[0]) > 10 * float(
        rt.report_poi.mode_mags[0])
    # The verdict folds into the region-level ok and the facade's report().
    assert not bool(rs.report_grid.ok)
    assert not bool(rs.report("poi").modes_ok)
    # Physically plausible excursions at 1% regional penetration.
    assert float(np.max(np.abs(np.asarray(rs.poi_freq_dev)))) < 1.0


# ------------------------------------------------- facade vs legacy wrappers


def _assert_bitwise(a, b):
    """Every populated array field of two ConditioningResults is equal."""
    for f in fleet.ConditioningResult._fields:
        va, vb = getattr(a, f), getattr(b, f)
        assert (va is None) == (vb is None), f
        if va is None or f in ("bank", "grid_spec", "per_campus"):
            continue
        _tree_equal(va, vb)


def test_facade_matches_condition_fleet_oneshot():
    cfg = _cfg()
    traces = SC.render(_campus(), 0, 1500)
    legacy = fleet.condition_fleet(cfg, traces, _SPEC)
    new = fleet.condition(traces, cfg, _SPEC, engine="oneshot")
    _assert_bitwise(legacy, new)
    assert legacy.campus_grid is not None


def test_facade_matches_condition_fleet_streaming():
    cfg = _cfg()
    traces = SC.render(_campus(), 0, 1500)
    legacy = fleet.condition_fleet_streaming(cfg, traces, _SPEC,
                                             chunk_intervals=2)
    new = fleet.condition(traces, cfg, _SPEC, engine="host",
                          stream=dict(chunk_intervals=2))
    _assert_bitwise(legacy, new)


def test_facade_matches_condition_scenario_scanned():
    cfg = _cfg()
    scen = _campus()
    legacy = fleet.condition_scenario_scanned(cfg, scen, _SPEC)
    new = fleet.condition(scen, cfg, _SPEC)
    _assert_bitwise(legacy, new)


def test_facade_matches_condition_scenario_streaming_host():
    cfg = _cfg()
    scen = _campus()
    legacy = fleet.condition_scenario_streaming(cfg, scen, _SPEC,
                                                engine="host")
    new = fleet.condition(scen, cfg, _SPEC, engine="host")
    _assert_bitwise(legacy, new)


def test_result_aliases_and_report():
    assert fleet.FleetResult is fleet.ConditioningResult
    assert fleet.StreamingFleetResult is fleet.ConditioningResult
    res = fleet.condition(_campus(), _cfg(), _SPEC)
    rep = res.report("grid")
    assert bool(np.asarray(rep.ramp_ok)) == bool(
        np.asarray(res.report_grid.ramp_ok))
    with pytest.raises(ValueError):
        res.report("nope")


def test_facade_rejects_bad_engines_and_stream_options():
    cfg = _cfg()
    reg = _small_region(duration_s=20.0)
    with pytest.raises(ValueError, match="scanned engine only"):
        fleet.condition(reg, cfg, _SPEC, engine="host")
    with pytest.raises(ValueError, match="total_samples"):
        fleet.condition(reg, cfg, _SPEC, stream=dict(total_samples=100))
    with pytest.raises(ValueError, match="unknown engine"):
        fleet.condition(SC.render(_campus(), 0, 500), cfg, _SPEC,
                        engine="warp")
    with pytest.raises(TypeError):
        fleet.condition(_campus(), cfg, _SPEC, stream=42)


# ---------------------------------------------------------- region engines


@pytest.fixture(scope="module")
def region_result():
    cfg = _cfg()
    reg = _small_region(duration_s=60.0, noise_seed=3)
    return reg, fleet.condition(reg, cfg, _SPEC)


def test_region_result_shapes(region_result):
    reg, res = region_result
    c, t = reg.n_campuses, int(reg.total_samples)
    assert np.asarray(res.campus_rack).shape == (c, t)
    assert np.asarray(res.campus_grid).shape == (c, t)
    assert np.asarray(res.poi_rack).shape == (t,)
    assert np.asarray(res.poi_grid).shape == (t,)
    assert np.asarray(res.poi_freq_dev).shape == (t,)
    assert np.asarray(res.poi_volt_dev).shape == (t,)
    assert len(res.per_campus) == c and len(res.state) == c
    assert res.report_grid is res.report_poi
    assert res.health is None  # per-campus health lives in per_campus
    assert all(r.health is not None for r in res.per_campus)


def test_region_poi_is_left_to_right_weighted_sum(region_result):
    reg, res = region_result
    w = np.asarray(res.weights)
    for name in ("campus_rack", "campus_grid"):
        per = [getattr(r, name) for r in res.per_campus]
        acc = jnp.float32(w[0]) * per[0]
        for c in range(1, reg.n_campuses):
            acc = acc + jnp.float32(w[c]) * per[c]
        got = getattr(res, "poi_rack" if name == "campus_rack" else "poi_grid")
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(got))


def test_region_per_campus_matches_stacked_aggregates(region_result):
    reg, res = region_result
    for c in range(reg.n_campuses):
        np.testing.assert_array_equal(
            np.asarray(res.per_campus[c].campus_rack),
            np.asarray(res.campus_rack)[c])
        np.testing.assert_array_equal(
            np.asarray(res.per_campus[c].campus_grid),
            np.asarray(res.campus_grid)[c])


def test_region_poi_freq_matches_direct_poi_response(region_result):
    reg, res = region_result
    r = grid.poi_response(res.poi_grid, reg.poi, 1.0 / reg.sample_hz)
    np.testing.assert_array_equal(
        np.asarray(r.freq_dev_hz), np.asarray(res.poi_freq_dev))
    np.testing.assert_array_equal(
        np.asarray(r.volt_dev), np.asarray(res.poi_volt_dev))


def test_region_heterogeneous_rack_counts():
    cfg = _cfg()
    reg = grid.region(
        [_campus(n_racks=3, seed=2), _campus(n_racks=5, seed=4)])
    res = fleet.condition(reg, cfg, _SPEC)
    assert np.asarray(res.campus_rack).shape[0] == 2
    np.testing.assert_allclose(
        np.asarray(res.weights), [3 / 8, 5 / 8], atol=1e-7)


def test_region_windowed_resume_is_bitwise(region_result):
    reg, full = region_result
    cfg = _cfg()
    k = int(round(float(cfg.controller.dt) * _HZ))  # samples per interval
    cut = 4 * k
    a = fleet.condition(reg, cfg, _SPEC, stream=dict(stop_sample=cut))
    b = fleet.condition(
        reg, cfg, _SPEC,
        stream=dict(state=a.state, start_sample=cut))
    for f in ("campus_rack", "campus_grid", "poi_rack", "poi_grid"):
        cat = np.concatenate(
            [np.asarray(getattr(a, f)), np.asarray(getattr(b, f))], axis=-1)
        np.testing.assert_array_equal(cat, np.asarray(getattr(full, f)))
    _tree_equal(b.state, full.state)

    with pytest.raises(ValueError, match="multiple of"):
        fleet.condition(reg, cfg, _SPEC, stream=dict(start_sample=7))


def test_region_sharded_one_device_mesh_is_noop():
    # A 1-campus region through the public sharded entry on a trivial
    # (campus=1, data=1) mesh must equal the sequential loop bitwise.
    cfg = _cfg()
    reg = grid.region([_campus(seed=5)])
    mesh = rules.region_mesh(1, devices=jax.devices()[:1])
    seq = grid.condition_region_sequential(cfg, reg, _SPEC)
    shd = grid.condition_region_sharded(cfg, reg, _SPEC, mesh)
    _assert_bitwise(seq, shd)
    _tree_equal(seq.state, shd.state)


def test_region_sharded_validates_mesh():
    cfg = _cfg()
    reg = _small_region(n_campuses=2, duration_s=20.0)
    no_campus = rules.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="campus"):
        grid.condition_region_sharded(cfg, reg, _SPEC, no_campus)


_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8")
import jax
import numpy as np
from repro.core import compliance, fleet, grid, pdu
from repro.sharding import rules

assert len(jax.devices()) == 8
hz = 50.0
cfg = pdu.make_pdu(sample_dt=1.0 / hz)
spec = compliance.GridSpec.create()
reg = grid.synchronized_region(
    n_campuses=4, n_racks=4, duration_s=40.0, sample_hz=hz)
mesh = rules.region_mesh(4)  # (campus=4, data=2) over 8 forced devices
seq = grid.condition_region_sequential(cfg, reg, spec)
shd = grid.condition_region_sharded(cfg, reg, spec, mesh)
for f in ("campus_rack", "campus_grid", "soc_mean", "ess_online_frac",
          "health_trace", "poi_rack", "poi_grid", "poi_freq_dev",
          "poi_volt_dev", "max_qp_residual"):
    a, b = getattr(seq, f), getattr(shd, f)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb), err_msg=f)
for la, lb in zip(jax.tree_util.tree_leaves(seq.state),
                  jax.tree_util.tree_leaves(shd.state)):
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
np.testing.assert_array_equal(np.asarray(seq.report_poi.mode_mags),
                              np.asarray(shd.report_poi.mode_mags))
assert bool(seq.report_poi.modes_ok) == bool(shd.report_poi.modes_ok)
print("PARITY-OK")
"""


@pytest.mark.slow
def test_sharded_region_matches_sequential_on_8_devices():
    # jax pins the device count at backend init, so the 8-device half of
    # the bitwise contract needs a fresh process.
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "PARITY-OK" in out.stdout


# ----------------------------------------------------------------- service


@pytest.mark.service
def test_service_runs_grid_region(tmp_path):
    from repro.serve import conditioner as SRV

    cfg = _cfg()
    reg = _small_region(n_campuses=3, n_racks=4, duration_s=60.0)
    svc = SRV.ConditionerService(
        cfg, reg, _SPEC, chunk_intervals=4,
        audit_path=tmp_path / "audit.jsonl")
    assert svc.n_racks == 12
    svc.advance()
    # Global rack index 5 lives in campus 1 (racks 4-7) as local rack 1.
    svc.inject_fault([5])
    assert float(np.asarray(svc.state[1].ess_online)[1]) == 0.0
    st = svc.status()
    assert st["manual_offline_racks"] == [5]
    assert st["region"]["campus_racks"] == [4, 4, 4]
    assert {"peak_power_pu", "max_freq_dev_hz", "mode_bands"} <= set(
        st["poi"])
    assert len(st["campuses"]) == 3
    svc.clear_fault([5])

    ck = svc.checkpoint(tmp_path / "ck")
    r_live = svc.advance()
    svc2 = SRV.ConditionerService(cfg, reg, _SPEC, chunk_intervals=4)
    svc2.restore(ck)
    r_resumed = svc2.advance()
    for f in ("poi_grid", "campus_rack", "poi_freq_dev"):
        np.testing.assert_array_equal(
            np.asarray(getattr(r_live, f)), np.asarray(getattr(r_resumed, f)))
    _tree_equal(svc.state, svc2.state)

    while not svc.exhausted:
        svc.advance()
    events = {e["event"] for e in svc.audit.tail(10_000)}
    # Synchronized checkpoint campuses ring the inter-area band; the
    # violation must land in the audit log as a first-class event.
    assert "mode_band_violation" in events
    mv = [e for e in svc.audit.tail(10_000)
          if e["event"] == "mode_band_violation"]
    assert all(e["band"] == "inter_area" for e in mv)
    assert all(e["magnitude"] > e["threshold"] for e in mv)
