"""Fault-engine tests (ISSUE 6): stochastic fault/repair processes in the
scenario IR, chunk-bitwise fault rendering, interval-quantized ESS masks,
and degraded-mode conditioning semantics.

The fault schedule is struct-of-arrays episode data; membership tests are
pure in the absolute sample index, so every derived signal (rack power
loss, sensor NaN windows, the per-interval ESS availability mask) must be
chunk- and resume-invariant bit-for-bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pdu
from repro.power import faults as FLT, scenario as SC

_HZ = 100.0


def _proc(**kw):
    base = dict(
        rack_mtbf_s=50.0, rack_mttr_s=15.0,
        ess_mtbf_s=40.0, ess_mttr_s=10.0,
        sensor_mtbf_s=30.0, sensor_mttr_s=5.0,
    )
    base.update(kw)
    return FLT.FaultProcess.create(**base)


# ------------------------------------------------------------ sampling


def test_sample_schedule_is_deterministic():
    a = FLT.sample_schedule(_proc(), 8, 12000, _HZ, seed=3)
    b = FLT.sample_schedule(_proc(), 8, 12000, _HZ, seed=3)
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_sample_schedule_seeds_differ():
    a = FLT.sample_schedule(_proc(), 8, 12000, _HZ, seed=3)
    b = FLT.sample_schedule(_proc(), 8, 12000, _HZ, seed=4)
    assert not np.array_equal(np.asarray(a.ess_start), np.asarray(b.ess_start))


def test_sample_schedule_produces_episodes():
    s = FLT.sample_schedule(_proc(), 8, 60000, _HZ, seed=1)
    for st, en in (
        (s.rack_start, s.rack_end),
        (s.ess_start, s.ess_end),
        (s.sensor_start, s.sensor_end),
    ):
        st, en = np.asarray(st), np.asarray(en)
        assert np.any(en > st), "expected at least one episode per channel"
        # rows sorted, episodes well-formed, padding start == end
        assert np.all(en >= st)
        assert np.all(np.diff(st, axis=1) >= 0)


def test_fault_process_validates_timescales():
    with pytest.raises(ValueError):
        FLT.FaultProcess.create(rack_mtbf_s=0.0)
    with pytest.raises(ValueError):
        FLT.FaultProcess.create(ess_mttr_s=-1.0)


def test_schedule_from_episodes_validates():
    with pytest.raises(ValueError):
        FLT.schedule_from_episodes(4, rack=[(7, 0, 10)])  # rack out of range
    with pytest.raises(ValueError):
        FLT.schedule_from_episodes(4, ess=[(1, 20, 10)])  # reversed window


# ------------------------------------------------ chunk-bitwise membership


def test_rack_and_sensor_down_chunk_bitwise():
    s = FLT.sample_schedule(_proc(), 6, 9000, _HZ, seed=5)
    for fn in (FLT.rack_down, FLT.sensor_down):
        whole = np.asarray(fn(s, 0, 9000))
        parts = np.concatenate(
            [np.asarray(fn(s, t0, 1500)) for t0 in range(0, 9000, 1500)]
        )
        np.testing.assert_array_equal(whole, parts)


def test_interval_online_chunk_invariant():
    s = FLT.sample_schedule(_proc(), 6, 9000, _HZ, seed=5)
    k = 500
    whole = np.asarray(FLT.interval_online(s, 0, 18, k))
    parts = np.concatenate(
        [np.asarray(FLT.interval_online(s, t0, 3, k)) for t0 in range(0, 9000, 3 * k)]
    )
    np.testing.assert_array_equal(whole, parts)
    assert whole.shape == (18, 6)
    assert set(np.unique(whole)).issubset({0.0, 1.0})


def test_interval_online_quantizes_to_interval_start():
    # ESS trip mid-interval only takes effect judged at the interval-start
    # sample: deterministic single episode covering samples [120, 380).
    s = FLT.schedule_from_episodes(2, ess=[(0, 120, 380)])
    on = np.asarray(FLT.interval_online(s, 0, 5, 100))
    # interval starts at 0,100,200,300,400 -> offline where start in [120,380)
    np.testing.assert_array_equal(on[:, 0], [1.0, 1.0, 0.0, 0.0, 1.0])
    np.testing.assert_array_equal(on[:, 1], np.ones(5))


def test_episodes_in_window_sorted_events():
    s = FLT.schedule_from_episodes(
        3, rack=[(1, 50, 90)], ess=[(0, 10, 60)], sensor=[(2, 70, 80)]
    )
    ev = FLT.episodes_in_window(s, 0, 100)
    assert [e["event"] for e in ev].count("fault") == 3
    assert [e["event"] for e in ev].count("repair") == 3
    samples = [e["sample"] for e in ev]
    assert samples == sorted(samples)
    # window filtering
    assert all(0 <= e["sample"] < 100 for e in ev)
    assert FLT.episodes_in_window(s, 200, 300) == []


# --------------------------------------------------- renderer integration


def _faulty_campus(n_racks=5, duration_s=60.0, seed=2):
    s = SC.mixed_campus(
        n_racks, ("llama3_2_1b", "qwen1_5_4b"),
        duration_s=duration_s, sample_hz=_HZ, seed=seed,
    )
    return SC.attach_faults(s, _proc(), seed=11)


def test_render_applies_rack_fault_power():
    s = _faulty_campus()
    tr = np.asarray(SC.render(s, 0, s.total_samples))
    wgt = np.asarray(
        FLT.fault_weight(s.faults, 0, s.total_samples, max(s.edge_width, 1))
    )
    dead = np.asarray(FLT.sensor_down(s.faults, 0, s.total_samples))
    pf = np.asarray(s.faults.p_fault)
    hit = (wgt >= 1.0) & ~dead  # fully collapsed interior, past the edge ramp
    assert np.any(hit), "schedule produced no visible rack outage"
    # Noise and per-rack scale apply after the fault substitution (the
    # faulted rack still has a real, slightly noisy meter), so the outage
    # reads as idle-level power, not an exact constant.
    np.testing.assert_allclose(
        tr[hit], np.broadcast_to(pf, wgt.shape)[hit], atol=0.05
    )
    assert tr[hit].mean() < 0.1 < tr[(wgt == 0.0) & ~dead].mean()


def test_fault_weight_ramps_over_edge_window():
    edge = 8
    sched = FLT.schedule_from_episodes(2, rack=[(1, 100, 200)])
    w = np.asarray(FLT.fault_weight(sched, 0, 300, edge))
    assert np.all(w[:, 0] == 0.0)
    np.testing.assert_allclose(  # linear rise starting at the fault sample
        w[100 : 100 + edge, 1], (np.arange(edge) + 1.0) / edge, rtol=1e-6
    )
    assert np.all(w[100 + edge : 200, 1] == 1.0)
    np.testing.assert_allclose(  # linear decay after the repair sample
        w[200 : 200 + edge, 1], 1.0 - (np.arange(edge) + 1.0) / edge,
        atol=1e-6,
    )
    assert np.all(w[200 + edge :, 1] == 0.0)
    # edge <= 1 reduces exactly to binary membership
    b = np.asarray(FLT.fault_weight(sched, 0, 300, 1))
    np.testing.assert_array_equal(
        b, np.asarray(FLT.rack_down(sched, 0, 300)).astype(np.float32)
    )
    # chunked == whole, split mid-ramp
    parts = np.concatenate(
        [np.asarray(FLT.fault_weight(sched, t0, 50, edge))
         for t0 in range(0, 300, 50)]
    )
    np.testing.assert_array_equal(parts, np.asarray(w))


def test_scripted_schedule_mixed_episode_counts():
    # Rows with fewer episodes than K must pad *after* the real episodes
    # with a sorted sentinel — (0, 0) padding broke searchsorted membership.
    sched = FLT.schedule_from_episodes(
        2, rack=[(0, 100, 200), (0, 300, 400), (1, 50, 60)]
    )
    down = np.asarray(FLT.rack_down(sched, 0, 500))
    assert down[150, 0] and down[350, 0] and not down[250, 0]
    assert down[55, 1] and not down[65, 1]
    assert not down[150, 1]


def test_render_sensor_dropout_is_nan():
    s = _faulty_campus()
    tr = np.asarray(SC.render(s, 0, s.total_samples))
    dead = np.asarray(FLT.sensor_down(s.faults, 0, s.total_samples))
    assert np.any(dead), "schedule produced no sensor outage"
    assert np.all(np.isnan(tr[dead]))
    assert np.all(np.isfinite(tr[~dead]))


def test_faulty_render_chunk_bitwise():
    s = _faulty_campus()
    whole = np.asarray(SC.render(s, 0, s.total_samples))
    chunk = 700  # deliberately not a divisor of the total
    parts = np.concatenate([
        np.asarray(SC.render(s, t0, min(chunk, s.total_samples - t0)))
        for t0 in range(0, s.total_samples, chunk)
    ])
    np.testing.assert_array_equal(whole, parts)


def test_attach_faults_rejects_rack_mismatch():
    s = SC.mixed_campus(
        4, ("llama3_2_1b",), duration_s=20.0, sample_hz=_HZ, seed=0
    )
    sched = FLT.sample_schedule(_proc(), 7, s.total_samples, _HZ, seed=0)
    with pytest.raises(ValueError):
        SC.attach_faults(s, sched)


def test_workload_validates_fault_params():
    with pytest.raises(ValueError):
        SC.workload(fault_duration_s=-1.0)
    with pytest.raises(ValueError):
        SC.workload(fault_at_s=-3.0)


def test_make_scenario_rejects_fault_past_end():
    w = SC.workload(fault_at_s=100.0)
    with pytest.raises(ValueError):
        SC.make_scenario(w, duration_s=50.0, sample_hz=_HZ)


# ----------------------------------------------- degraded-mode conditioning


def test_degraded_clean_trace_matches_plain_bitwise():
    """degraded_mode with no faults and no mask is the identity refactor:
    every output must match the non-degraded config bit-for-bit."""
    s = SC.mixed_campus(
        4, ("llama3_2_1b", "qwen1_5_4b"), duration_s=30.0, sample_hz=_HZ, seed=2
    )
    tr = SC.render(s, 0, s.total_samples)
    plain = pdu.make_pdu(sample_dt=1.0 / _HZ)
    deg = pdu.make_pdu(sample_dt=1.0 / _HZ, degraded_mode=True)
    g0, st0, _ = pdu.condition(plain, pdu.init_state(plain, tr[0]), tr, qp_iters=20)
    g1, st1, te = pdu.condition(deg, pdu.init_state(deg, tr[0]), tr, qp_iters=20)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    np.testing.assert_array_equal(
        np.asarray(st0.ess_state.soc), np.asarray(st1.ess_state.soc)
    )
    np.testing.assert_array_equal(np.asarray(te.ess_online), 1.0)


def test_degraded_offline_rack_is_lc_passthrough():
    """An offline rack sheds no battery power: SoC frozen, zero command."""
    s = SC.mixed_campus(
        4, ("llama3_2_1b", "qwen1_5_4b"), duration_s=30.0, sample_hz=_HZ, seed=2
    )
    tr = SC.render(s, 0, s.total_samples)
    deg = pdu.make_pdu(sample_dt=1.0 / _HZ, degraded_mode=True)
    st = pdu.init_state(deg, tr[0])
    mask = jnp.asarray([0.0, 1.0, 1.0, 1.0])
    _, st_f, te = pdu.condition(deg, st, tr, qp_iters=20, ess_online=mask)
    np.testing.assert_array_equal(
        np.asarray(st_f.ess_state.soc[0]), np.asarray(st.ess_state.soc[0])
    )
    np.testing.assert_array_equal(np.asarray(te.command[:, 0]), 0.0)
    assert np.any(np.asarray(te.command[:, 1:]) != 0.0)


def test_degraded_bridges_nan_and_trips_blind_intervals():
    """NaN sensor samples never reach outputs; a rack dark for a whole
    interval is forced offline by the finite-guard tripwire."""
    s = _faulty_campus()
    tr = SC.render(s, 0, s.total_samples)
    assert bool(jnp.any(jnp.isnan(tr)))
    deg = pdu.make_pdu(sample_dt=1.0 / _HZ, degraded_mode=True)
    grid, st_f, te = pdu.condition(deg, pdu.init_state(deg, tr[0]), tr, qp_iters=20)
    assert bool(jnp.all(jnp.isfinite(grid)))
    assert bool(jnp.all(jnp.isfinite(te.rack_mean)))
    k = int(round(float(deg.controller.dt) * _HZ))
    dead = np.asarray(FLT.sensor_down(s.faults, 0, s.total_samples))
    n_ctrl = te.ess_online.shape[0]
    blind = dead[: n_ctrl * k].reshape(n_ctrl, k, -1).all(axis=1)
    assert np.any(blind), "schedule produced no fully-blind interval"
    np.testing.assert_array_equal(np.asarray(te.ess_online)[blind], 0.0)


def test_degraded_condition_chunked_matches_whole_bitwise():
    s = _faulty_campus()
    tr = SC.render(s, 0, s.total_samples)
    deg = pdu.make_pdu(sample_dt=1.0 / _HZ, degraded_mode=True)
    k = int(round(float(deg.controller.dt) * _HZ))
    n_ctrl = -(-s.total_samples // k)
    on = FLT.interval_online(s.faults, 0, n_ctrl, k)

    g_whole, st_whole, _ = pdu.condition(
        deg, pdu.init_state(deg, tr[0]), tr, qp_iters=20, ess_online=on
    )
    st = pdu.init_state(deg, tr[0])
    parts = []
    chunk = 4 * k
    for t0 in range(0, s.total_samples, chunk):
        n = min(chunk, s.total_samples - t0)
        rows = on[t0 // k : t0 // k + -(-n // k)]
        g, st, _ = pdu.condition(deg, st, tr[t0 : t0 + n], qp_iters=20, ess_online=rows)
        parts.append(np.asarray(g))
    np.testing.assert_array_equal(np.asarray(g_whole), np.concatenate(parts))
    for la, lb in zip(
        jax.tree_util.tree_leaves(st_whole), jax.tree_util.tree_leaves(st)
    ):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_ess_online_requires_degraded_mode():
    plain = pdu.make_pdu(sample_dt=1.0 / _HZ)
    tr = jnp.ones((200, 3), jnp.float32) * 0.5
    with pytest.raises(ValueError):
        pdu.condition(
            plain, pdu.init_state(plain, tr[0]), tr, ess_online=jnp.ones((3,))
        )


# ---------------------------------- compiled-vs-legacy fault rendering
#
# The interval-compiled fault path (PR 10) renders every availability
# signal from episode boundary events with a K-unrolled membership count;
# the legacy path is the per-sample vmapped searchsorted oracle.  Both
# reduce to the same two integers ("episodes started" / "episodes ended"
# at-or-before each sample), so every derived float must be bitwise
# identical at any chunk split or resume point.


def _equivalence_schedules():
    """Schedules covering both padding conventions: stochastic (trace-end
    clamped empty slots), a scripted cascade injected on top (re-coalesced
    rows), and a hand-scripted table with mixed episode counts per rack
    (int32-max sentinel padding)."""
    stoch = FLT.sample_schedule(_proc(), 6, 9000, _HZ, seed=5)
    cascade = FLT.inject_episodes(
        stoch,
        rack=[(i, 4000 + i * 37, 4600 + i * 41) for i in range(6)],
        sensor=[(2, 8000, 8999)],
    )
    scripted = FLT.schedule_from_episodes(
        6,
        rack=[(0, 100, 200), (0, 300, 400), (0, 450, 470), (1, 50, 60)],
        ess=[(2, 10, 900), (2, 2000, 2400), (4, 8990, 9000)],
        sensor=[(3, 120, 180)],
    )
    return {"stochastic": stoch, "cascade": cascade, "scripted": scripted}


_EQ_RENDERERS = {
    "rack_down": lambda s, t0, n, m: FLT.rack_down(s, t0, n, method=m),
    "sensor_down": lambda s, t0, n, m: FLT.sensor_down(s, t0, n, method=m),
    "fault_weight_e7": lambda s, t0, n, m: FLT.fault_weight(s, t0, n, 7, method=m),
    "fault_weight_e1": lambda s, t0, n, m: FLT.fault_weight(s, t0, n, 1, method=m),
    "ess_weight_e7": lambda s, t0, n, m: FLT.ess_weight(s, t0, n, 7, method=m),
    "ess_weight_e0": lambda s, t0, n, m: FLT.ess_weight(s, t0, n, 0, method=m),
}


@pytest.mark.parametrize("sched_name", ["stochastic", "cascade", "scripted"])
@pytest.mark.parametrize("fn_name", sorted(_EQ_RENDERERS))
def test_compiled_rendering_bitwise_vs_legacy(sched_name, fn_name):
    s = _equivalence_schedules()[sched_name]
    fn = _EQ_RENDERERS[fn_name]
    # Whole window and resume points that land mid-episode, mid-ramp, and
    # in the trailing clamped region.
    for t0, n in ((0, 9000), (123, 2000), (4391, 777), (8800, 200)):
        legacy = np.asarray(fn(s, t0, n, "legacy"))
        compiled = np.asarray(fn(s, t0, n, "compiled"))
        np.testing.assert_array_equal(legacy, compiled)


@pytest.mark.parametrize("sched_name", ["stochastic", "cascade", "scripted"])
@pytest.mark.parametrize("chunk", [700, 1500])
def test_compiled_rendering_chunk_bitwise(sched_name, chunk):
    s = _equivalence_schedules()[sched_name]
    for fn_name in ("fault_weight_e7", "ess_weight_e7"):
        fn = _EQ_RENDERERS[fn_name]
        whole = np.asarray(fn(s, 0, 9000, "compiled"))
        parts = np.concatenate([
            np.asarray(fn(s, t0, min(chunk, 9000 - t0), "compiled"))
            for t0 in range(0, 9000, chunk)
        ])
        np.testing.assert_array_equal(whole, parts)


def test_compiled_interval_masks_bitwise_vs_legacy():
    k = 500
    for s in _equivalence_schedules().values():
        for t0 in (0, 3 * k):
            on_l = np.asarray(FLT.interval_online(s, t0, 12, k, method="legacy"))
            on_c = np.asarray(FLT.interval_online(s, t0, 12, k, method="compiled"))
            np.testing.assert_array_equal(on_l, on_c)
            se_l = np.asarray(FLT.interval_sensed(s, t0, 12, k, method="legacy"))
            se_c = np.asarray(FLT.interval_sensed(s, t0, 12, k, method="compiled"))
            np.testing.assert_array_equal(se_l, se_c)


def test_interval_sensed_matches_isfinite_oracle():
    """``interval_sensed`` must equal the legacy any(isfinite) reduction
    over the ZOH-padded chunk — including a partial final interval, where
    the pad replicates the last real sample."""
    s = _equivalence_schedules()["cascade"]
    k = 500
    for t0, n_int, stop in ((0, 6, None), (1000, 4, 1000 + 3 * 500 + 137)):
        t_end = t0 + n_int * k if stop is None else stop
        dead = np.asarray(FLT.sensor_down(s, t0, t_end - t0))
        # ZOH pad to whole intervals with the last real row, as
        # pdu.condition pads its trailing partial interval.
        pad = n_int * k - dead.shape[0]
        if pad:
            dead = np.concatenate([dead, np.repeat(dead[-1:], pad, 0)])
        oracle = ~dead.reshape(n_int, k, -1).all(axis=1)
        got = np.asarray(FLT.interval_sensed(s, t0, n_int, k, stop=stop))
        np.testing.assert_array_equal(got, oracle)


def test_sensor_dark_hold_matches_membership():
    """``dark`` must equal per-sample sensor membership, and every held
    index must point at the clean sample just before its episode start."""
    s = _equivalence_schedules()["cascade"]
    idx = jnp.arange(1000, 3000, dtype=jnp.int32)
    dark, hold = (np.asarray(x) for x in FLT.sensor_dark_hold(s, idx))
    np.testing.assert_array_equal(
        dark, np.asarray(FLT.sensor_down(s, 1000, 2000))
    )
    assert np.any(dark), "window has no sensor outage to exercise"
    starts = np.asarray(s.sensor_start)
    ends = np.asarray(s.sensor_end)
    r_idx, t_off = np.nonzero(dark.T)
    for r, t in zip(r_idx[:200], t_off[:200]):
        h = hold[t, r]
        # hold is the sample before the episode start: a real episode
        # boundary, and (coalesced rows) outside every episode.
        assert (h + 1) in starts[r]
        assert not np.any((starts[r] <= h) & (h < ends[r]))


def test_auto_method_falls_back_past_unroll_limit():
    wide = FLT.sample_schedule(
        _proc(), 4, 9000, _HZ, seed=5, max_episodes=FLT._UNROLL_MAX + 8
    )
    assert wide.rack_start.shape[1] > FLT._UNROLL_MAX
    assert FLT._resolve_method("auto", int(wide.rack_start.shape[1])) == "legacy"
    assert FLT._resolve_method("auto", 4) == "compiled"
    # The explicit compiled path still agrees even past the auto cutoff.
    np.testing.assert_array_equal(
        np.asarray(FLT.rack_down(wide, 0, 9000, method="legacy")),
        np.asarray(FLT.rack_down(wide, 0, 9000, method="compiled")),
    )
    with pytest.raises(ValueError):
        FLT._resolve_method("fast", 4)


def test_validate_tables_accepts_both_padding_conventions():
    for s in _equivalence_schedules().values():
        FLT.validate_tables(s)  # must not raise
    import dataclasses
    good = _equivalence_schedules()["scripted"]
    bad = dataclasses.replace(
        good, ess_start=good.ess_start.at[2, 1].set(100)
    )
    with pytest.raises(ValueError):
        FLT.validate_tables(bad)


def test_events_kernel_matches_streamed_weight():
    """The megakernel's compact boundary-event operand must reproduce the
    streamed per-sample weight block bitwise (ref backend — the oracle the
    Pallas kernel is held to in tests/test_pdu_health_kernel.py)."""
    from repro.kernels import ref as kref

    s = _equivalence_schedules()["cascade"]
    n_racks = 6
    t, k = 1000, 500
    cfg = pdu.make_pdu(sample_dt=1.0 / _HZ, degraded_mode=True)
    rng = np.random.default_rng(0)
    tr = jnp.asarray(rng.uniform(0.2, 0.9, (t, n_racks)), jnp.float32)
    st = pdu.init_state(cfg, tr[0])
    ep = cfg.ess_params
    filt = st.filter_obj
    base = jnp.asarray(0.5 + np.arange(n_racks) / 8.0, jnp.float32)
    kkw = dict(
        beta=float(ep.beta), dt=1.0 / _HZ, q_max=float(ep.q_max),
        eta_c=float(ep.eta_c), eta_d=float(ep.eta_d),
        p_max=float(ep.p_max), soc_min=float(ep.soc_safe_min),
        soc_max=float(ep.soc_safe_max),
    )
    args = (tr, st.ess_state.g_filter, st.ess_state.soc, st.filter_state,
            filt.ad, filt.bd, filt.c[0])
    for t0, edge in ((0, 1), (2000, 7), (4391, 7)):
        streamed = FLT.ess_weight(s, t0, t, edge) * base[None, :]
        events = (
            s.ess_start.T, s.ess_end.T, base,
            jnp.asarray(t0, jnp.int32), jnp.asarray(t0 + t - 1, jnp.int32),
        )
        r_st = kref.pdu_health_sim(*args, ess_on=streamed, **kkw)
        r_ev = kref.pdu_health_sim(*args, ess_events=events, ess_edge=edge, **kkw)
        for a, b in zip(jax.tree_util.tree_leaves(r_st),
                        jax.tree_util.tree_leaves(r_ev)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_condition_faults_fast_path_bitwise():
    """``pdu.condition(..., faults=schedule)`` — the interval-compiled fast
    path — against the legacy streamed mask/weight arrays: grid, every
    carried state leaf, and every telemetry leaf bitwise, whole-trace and
    resumed mid-stream."""
    s = _faulty_campus()
    tr = SC.render(s, 0, s.total_samples)
    deg = pdu.make_pdu(sample_dt=1.0 / _HZ, degraded_mode=True)
    k = int(round(float(deg.controller.dt) * _HZ))
    n_ctrl = -(-s.total_samples // k)
    edge = 7
    on = FLT.interval_online(s.faults, 0, n_ctrl, k)
    wt = FLT.ess_weight(s.faults, 0, s.total_samples, edge)

    g_leg, st_leg, te_leg = pdu.condition(
        deg, pdu.init_state(deg, tr[0]), tr, qp_iters=20,
        ess_online=on, ess_weight=wt,
    )
    g_fast, st_fast, te_fast = pdu.condition(
        deg, pdu.init_state(deg, tr[0]), tr, qp_iters=20,
        faults=s.faults, chunk_start=0, fault_edge=edge,
    )
    np.testing.assert_array_equal(np.asarray(g_leg), np.asarray(g_fast))
    for a, b in zip(jax.tree_util.tree_leaves((st_leg, te_leg)),
                    jax.tree_util.tree_leaves((st_fast, te_fast))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # Resume at an interval boundary: two fast-path calls glue bitwise.
    cut = 7 * k
    st = pdu.init_state(deg, tr[0])
    g1, st, _ = pdu.condition(
        deg, st, tr[:cut], qp_iters=20,
        faults=s.faults, chunk_start=0, fault_edge=edge,
    )
    g2, st, _ = pdu.condition(
        deg, st, tr[cut:], qp_iters=20,
        faults=s.faults, chunk_start=cut, fault_edge=edge,
    )
    np.testing.assert_array_equal(
        np.asarray(g_leg), np.concatenate([np.asarray(g1), np.asarray(g2)])
    )
    for a, b in zip(jax.tree_util.tree_leaves(st_leg),
                    jax.tree_util.tree_leaves(st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_apply_failures_matches_fault_engine():
    """The legacy helper is now a shim over the schedule machinery."""
    traces = jnp.ones((100, 3), jnp.float32) * 0.8
    out = np.asarray(
        __import__("repro.core.fleet", fromlist=["fleet"]).apply_failures(
            traces, jnp.asarray([-1, 40, 70]), p_idle=0.1
        )
    )
    assert np.all(out[:, 0] == np.float32(0.8))
    assert np.all(out[:40, 1] == np.float32(0.8)) and np.all(
        out[40:, 1] == np.float32(0.1)
    )
    assert np.all(out[70:, 2] == np.float32(0.1))
