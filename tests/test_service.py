"""Operator-service tests (ISSUE 6): resume-safe conditioning with an
append-only audit log.

The crash-resume contract is bitwise: checkpoint at an interval boundary,
kill the service, restore in a fresh process-equivalent instance, and the
glued telemetry must equal the uninterrupted run array-for-array (same
cached engine, same floats).
"""
import json

import numpy as np
import pytest

from repro.core import compliance, pdu
from repro.power import faults as FLT, scenario as SC
from repro.serve import AuditLog, ConditionerService

pytestmark = pytest.mark.service

_HZ = 100.0
_SPEC = compliance.GridSpec.create()


def _scenario(duration_s=60.0, n_racks=5, faulty=True):
    s = SC.mixed_campus(
        n_racks, ("llama3_2_1b", "qwen1_5_4b"),
        duration_s=duration_s, sample_hz=_HZ, seed=4,
    )
    if faulty:
        proc = FLT.FaultProcess.create(
            ess_mtbf_s=25.0, ess_mttr_s=10.0,
            sensor_mtbf_s=30.0, sensor_mttr_s=5.0,
        )
        s = SC.attach_faults(s, proc, seed=17)
    return s


def _service(s, **kw):
    cfg = pdu.make_pdu(sample_dt=1.0 / _HZ, degraded_mode=True)
    return ConditionerService(cfg, s, _SPEC, chunk_intervals=4, **kw)


def _drain(svc):
    rack, grid, frac = [], [], []
    while not svc.exhausted:
        r = svc.advance()
        rack.append(np.asarray(r.campus_rack))
        grid.append(np.asarray(r.campus_grid))
        frac.append(np.asarray(r.ess_online_frac))
    return tuple(np.concatenate(x) for x in (rack, grid, frac))


def test_crash_resume_is_bitwise(tmp_path):
    s = _scenario()
    ref = _drain(_service(s))

    svc = _service(s)
    out = [[], [], []]

    def take(r):
        for buf, x in zip(out, (r.campus_rack, r.campus_grid, r.ess_online_frac)):
            buf.append(np.asarray(x))

    take(svc.advance())
    take(svc.advance())
    ck = svc.checkpoint(tmp_path / "mid_outage.npz")
    del svc  # crash

    svc2 = _service(s)
    svc2.restore(ck)
    while not svc2.exhausted:
        take(svc2.advance())
    got = tuple(np.concatenate(x) for x in out)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


def test_restore_validates_geometry(tmp_path):
    s = _scenario()
    svc = _service(s)
    svc.advance()
    ck = svc.checkpoint(tmp_path / "ck.npz")
    other = _service(_scenario(n_racks=3))
    with pytest.raises(ValueError):
        other.restore(ck)


def test_manual_fault_injection_round_trip():
    s = _scenario(faulty=False, duration_s=40.0)
    svc = _service(s)
    svc.inject_fault([0, 2])
    r = svc.advance()
    assert float(np.asarray(r.ess_online_frac).max()) == pytest.approx(3.0 / 5.0)
    assert svc.status()["manual_offline_racks"] == [0, 2]
    svc.clear_fault([0, 2])
    r = svc.advance()
    np.testing.assert_array_equal(np.asarray(r.ess_online_frac), 1.0)
    events = [e["event"] for e in svc.audit.tail(20)]
    for must in ("manual_fault_injected", "manual_fault_cleared",
                 "degraded_enter", "degraded_exit"):
        assert must in events
    with pytest.raises(ValueError):
        svc.inject_fault(7)


def test_audit_log_is_strict_jsonl(tmp_path):
    path = tmp_path / "audit.jsonl"
    s = _scenario()
    svc = _service(s, audit_path=path)
    while not svc.exhausted:
        svc.advance()
    lines = path.read_text().splitlines()
    assert len(lines) == len(svc.audit)
    parsed = [json.loads(l) for l in lines]  # every line strict JSON
    kinds = {p["event"] for p in parsed}
    assert {"service_start", "window"} <= kinds
    assert {"fault", "repair"} <= kinds  # scheduled episodes made it in
    # scheduled fault events carry channel + rack + sample provenance
    ev = next(p for p in parsed if p["event"] == "fault")
    assert {"channel", "rack", "sample"} <= set(ev)


def test_status_is_json_safe():
    s = _scenario()
    svc = _service(s)
    svc.advance()
    st = svc.status()
    assert json.loads(json.dumps(st, allow_nan=False)) == st
    # untracked health -> infinite projected life must clamp to null
    assert st["health"]["projected_life_years_min"] is None


def test_advance_past_end_raises():
    s = _scenario(duration_s=20.0, faulty=False)
    svc = _service(s)
    while not svc.exhausted:
        svc.advance()
    with pytest.raises(RuntimeError):
        svc.advance()


def test_audit_log_standalone(tmp_path):
    log = AuditLog(tmp_path / "a.jsonl")
    log.append("x", n=1)
    log.append("y", n=2)
    assert len(log) == 2
    assert [e["event"] for e in log.tail(1)] == ["y"]
    with pytest.raises(ValueError):
        log.append("bad", v=float("inf"))  # strict JSON enforced at write
