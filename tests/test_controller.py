"""Controller (paper §6, Appendix B) tests: QP solver correctness, deadband,
convergence (Fig. 12), outer-loop storage mode, feasibility property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st  # optional-hypothesis shim

from repro.core import controller as ctrl
from repro.core.ess import ESSParams


def _cfg(**kw):
    return ctrl.ControllerConfig.create(**kw)


def _ess(**kw):
    kw.setdefault("q_max_seconds", 40.0)
    return ESSParams.create(**kw)


# ----------------------------------------------------------------- QP solver


def test_qp_solver_box_only():
    """min (x-2)^2 s.t. 0 <= x <= 1  ->  x = 1."""
    p = jnp.eye(1) * 2.0
    q = jnp.array([-4.0])
    a = jnp.eye(1)
    sol = ctrl.solve_qp_admm(p, q, a, jnp.array([0.0]), jnp.array([1.0]))
    assert float(sol.x[0]) == pytest.approx(1.0, abs=1e-4)


def test_qp_solver_matches_analytic():
    """Random strongly-convex QP with inactive constraints = unconstrained."""
    key = jax.random.key(3)
    k1, k2 = jax.random.split(key)
    m = jax.random.normal(k1, (6, 6))
    p = m @ m.T + 6 * jnp.eye(6)
    q = jax.random.normal(k2, (6,))
    a = jnp.eye(6)
    sol = ctrl.solve_qp_admm(p, q, a, -1e3 * jnp.ones(6), 1e3 * jnp.ones(6), iters=400)
    x_star = jnp.linalg.solve(p, -q)
    np.testing.assert_allclose(np.asarray(sol.x), np.asarray(x_star), atol=1e-3)


def test_qp_respects_soc_bounds():
    """Starting 1 deadband above safe max, commands must not push past it."""
    cfg = _cfg()
    es = _ess(soc_safe_max=0.9)
    out = ctrl.inner_loop_step(cfg, es, jnp.asarray(0.895), jnp.asarray(0.5), jnp.asarray(0.0))
    # must discharge (or do nothing), never charge:
    assert float(out.corrective_power) <= 1e-6


# ------------------------------------------------------------ deadband/inner


def test_deadband_zeroes_current():
    cfg = _cfg(deadband=0.01)
    es = _ess()
    out = ctrl.inner_loop_step(cfg, es, jnp.asarray(0.505), jnp.asarray(0.5), jnp.asarray(0.0))
    assert bool(out.in_deadband)
    assert float(out.corrective_power) == 0.0


def test_command_within_limits():
    cfg = _cfg()
    es = _ess()
    for soc in (0.2, 0.45, 0.62, 0.85):
        out = ctrl.inner_loop_step(cfg, es, jnp.asarray(soc), jnp.asarray(0.5), jnp.asarray(0.0))
        assert abs(float(out.corrective_power)) <= float(cfg.i_max) + 1e-9


def test_command_sign_tracks_error():
    cfg = _cfg()
    es = _ess()
    hi = ctrl.inner_loop_step(cfg, es, jnp.asarray(0.62), jnp.asarray(0.5), jnp.asarray(0.0))
    lo = ctrl.inner_loop_step(cfg, es, jnp.asarray(0.38), jnp.asarray(0.5), jnp.asarray(0.0))
    assert float(hi.corrective_power) < 0  # above target -> discharge
    assert float(lo.corrective_power) > 0  # below target -> charge


# -------------------------------------------------------- closed-loop (fig12)


def test_fig12_convergence_from_62pct():
    """Paper Fig. 12: drift to ~62% SoC corrected to S_mid = 0.5 in ~20 min,
    monotonic, and held once in the deadband."""
    cfg = _cfg(i_max=4e-3)
    es = _ess()
    out = ctrl.simulate_soc_management(cfg, es, 0.62, n_steps=400, qp_iters=80)
    soc = np.asarray(out["soc"])
    # converged to the deadband around 0.5
    assert abs(soc[-1] - 0.5) <= float(cfg.deadband) + 1e-3
    # time to reach deadband is tens of minutes (paper: ~20 min)
    hit = int(np.argmax(np.abs(soc - 0.5) <= float(cfg.deadband)))
    assert 5.0 <= hit * 5.0 / 60.0 <= 30.0
    # monotonic descent (within solver noise)
    assert np.all(np.diff(soc[: hit + 1]) <= 1e-4)


def test_drift_without_software():
    """Without corrective control a set-point bias drifts SoC toward the
    bound (paper Fig. 12 'without software' trace): pure integration."""
    es = _ess()
    dt, n, drift = 5.0, 600, 2e-3
    soc = 0.5 + np.arange(1, n + 1) * dt * drift * float(es.eta_c) / float(es.q_max)
    assert soc[-1] >= 0.57  # drifts up unchecked
    # and the deadband never stops it — monotone growth
    assert np.all(np.diff(soc) > 0)


def test_software_beats_drift():
    """With control enabled the same bias is rejected near S_mid."""
    cfg = _cfg(i_max=6e-3)
    es = _ess()
    out = ctrl.simulate_soc_management(cfg, es, 0.5, n_steps=600, drift_power=2e-3, qp_iters=60)
    soc = np.asarray(out["soc"])
    assert abs(soc[-1] - 0.5) < 0.03


# -------------------------------------------------------------- outer loop


def test_outer_loop_active_mode():
    cfg = _cfg()
    es = _ess()
    t = ctrl.select_target(cfg, es, jnp.asarray(0.0))
    assert float(t) == pytest.approx(0.5)


def test_outer_loop_storage_mode():
    cfg = _cfg(t_enter=1800.0, s_idle=0.3)
    es = _ess()
    t = ctrl.select_target(cfg, es, jnp.asarray(1e6))  # plenty of idle budget
    assert float(t) == pytest.approx(0.3, abs=1e-6)


def test_outer_loop_budget_raises_target():
    """As the idle window elapses the target must rise back toward S_mid
    and eventually revert (paper §6)."""
    cfg = _cfg(t_enter=1800.0, s_idle=0.3)
    es = _ess()
    idle = [1e6, 20_000.0, 5_000.0, 2_500.0, 0.0]
    targets = [float(ctrl.select_target(cfg, es, jnp.asarray(v))) for v in idle]
    assert all(targets[i] <= targets[i + 1] + 1e-9 for i in range(len(targets) - 1))
    assert targets[0] == pytest.approx(0.3, abs=1e-6)
    assert targets[-1] == pytest.approx(0.5)


def test_outer_loop_respects_safe_min():
    cfg = _cfg(s_idle=0.05, delta_s_max=0.6)
    es = _ess(soc_safe_min=0.2)
    t = ctrl.select_target(cfg, es, jnp.asarray(1e6))
    assert float(t) >= 0.2


# ---------------------------------------------------------------- property


@settings(max_examples=20, deadline=None)
@given(soc0=st.floats(0.12, 0.88), target=st.floats(0.3, 0.7))
def test_property_feasible_and_converging(soc0, target):
    """Paper §6: 'given any SoC within the hardware safe bounds, the inner
    loop is always feasible and converges to S* within a few control
    intervals' — we check the error is strictly reduced over 40 intervals
    (or already inside the deadband)."""
    cfg = _cfg(s_mid=target, i_max=8e-3)
    es = _ess(q_max_seconds=20.0)
    n_steps = 40
    out = ctrl.simulate_soc_management(cfg, es, soc0, n_steps=n_steps, qp_iters=60)
    soc = np.asarray(out["soc"])
    e0 = abs(soc0 - target)
    e1 = abs(soc[-1] - target)
    # max achievable reduction at the current limit over the window:
    reachable = 0.6 * float(cfg.i_max) / float(es.q_max) * n_steps * float(cfg.dt)
    assert e1 <= max(e0 - reachable, float(cfg.deadband) + 2e-3)
