"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
sweeping shapes and dtypes as required for every kernel in the package."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import filters, sizing
from repro.core.pdu import per_unit_filter
from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


def _assert_close(got, want, dtype=jnp.float32):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# ------------------------------------------------------------------ rmsnorm


@pytest.mark.parametrize("shape", [(8, 128), (37, 256), (4, 7, 512), (1, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, shape, dtype)
    w = jax.random.normal(k2, (shape[-1],), dtype)
    got = ops.rmsnorm(x, w, force="pallas")
    want = ref.rmsnorm(x, w)
    assert got.dtype == x.dtype
    _assert_close(got, want, dtype)


# ---------------------------------------------------------------- gemm_burn


@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 128, 512), (384, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_iters", [1, 4])
def test_gemm_burn(mnk, dtype, n_iters):
    m, n, k = mnk
    k1, k2 = jax.random.split(jax.random.key(1))
    a = jax.random.normal(k1, (m, k), dtype)
    b = jax.random.normal(k2, (k, n), dtype)
    got = ops.gemm_burn(a, b, n_iters, force="pallas", bm=128, bn=128, bk=128)
    want = ref.gemm_burn(a, b, n_iters)
    # tolerance scales with the K-dim accumulation length
    atol = 2e-3 * (k / 128) if dtype == jnp.float32 else 0.5 * (k / 128)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4, atol=atol,
    )


def test_gemm_burn_flop_knob_semantics():
    """n_iters must not change the value (only the work)."""
    k1, k2 = jax.random.split(jax.random.key(2))
    a = jax.random.normal(k1, (128, 128))
    b = jax.random.normal(k2, (128, 128))
    o1 = ops.gemm_burn(a, b, 1, force="pallas")
    o8 = ops.gemm_burn(a, b, 8, force="pallas")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o8), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- lc_filter


def _proto_filter(dt=1e-3):
    s = sizing.size_system(sizing.prototype_rack(), beta=0.0625)
    pp = per_unit_filter(s, sizing.prototype_rack())
    return filters.make_discrete_filter(pp, dt)


@pytest.mark.parametrize("t,r,block_t", [(1000, 8, 256), (513, 4, 128), (256, 128, 256), (100, 3, 512)])
def test_lc_filter(t, r, block_t):
    filt = _proto_filter()
    u = 0.5 + 0.3 * jax.random.uniform(jax.random.key(3), (t, r))
    x0 = jnp.tile(filters.steady_state(filt, jnp.array([1.0, 0.5])), (r, 1))
    want_y, want_xf = ref.lc_filter(filt.ad, filt.bd, filt.c[0], x0, u)
    got_y, got_xf = ops.lc_filter(
        filt.ad, filt.bd, filt.c[0], x0, u, force="pallas", block_t=block_t
    )
    _assert_close(got_y, want_y)
    _assert_close(got_xf, want_xf)


def test_lc_filter_matches_core_simulate():
    """Kernel == the core filters.simulate (the physics oracle)."""
    filt = _proto_filter()
    t, r = 400, 5
    u = 0.4 + 0.4 * jax.random.uniform(jax.random.key(4), (t, r))
    x0 = jnp.tile(filters.steady_state(filt, jnp.array([1.0, 0.4])), (r, 1))
    uu = jnp.stack([jnp.ones_like(u), u], axis=-1)
    y_core, xf_core = filters.simulate(filt, x0, uu)
    got_y, got_xf = ops.lc_filter(filt.ad, filt.bd, filt.c[0], x0, u, force="pallas")
    _assert_close(got_y, y_core[..., 0])
    _assert_close(got_xf, xf_core)


# ------------------------------------------------------------------ pdu_sim


PDU_KW = dict(
    beta=0.0625, dt=1e-3, q_max=40.0, eta_c=0.97, eta_d=0.97,
    p_max=1.0, soc_min=0.1, soc_max=0.9,
)


@pytest.mark.parametrize("t,r", [(1000, 8), (700, 128), (64, 2)])
def test_pdu_sim(t, r):
    filt = _proto_filter()
    u = 0.2 + 0.7 * jax.random.uniform(jax.random.key(5), (t, r))
    x0 = jnp.tile(filters.steady_state(filt, jnp.array([1.0, 0.5])), (r, 1))
    g0 = u[0]
    soc0 = jnp.full((r,), 0.5)
    corr = jnp.zeros((t, r))
    want = ref.pdu_sim(u, g0, soc0, x0, filt.ad, filt.bd, filt.c[0], corrective=corr, **PDU_KW)
    got = ops.pdu_sim(u, g0, soc0, x0, filt.ad, filt.bd, filt.c[0], corr,
                      force="pallas", block_t=256, **PDU_KW)
    _assert_close(got[0], want[0])  # grid
    _assert_close(got[1], want[1])  # soc
    for gf, wf in zip(got[2], want[2]):
        _assert_close(gf, wf)


def test_pdu_sim_saturation_path():
    """The nonlinear shed path (SoC bound hit) must match the oracle."""
    filt = _proto_filter()
    t, r = 2000, 4
    u = jnp.ones((t, r)) * 0.9
    u = u.at[500:].set(0.1)  # big drop charges the battery into the bound
    x0 = jnp.tile(filters.steady_state(filt, jnp.array([1.0, 0.9])), (r, 1))
    g0 = u[0]
    soc0 = jnp.full((r,), 0.88)  # nearly full: will saturate
    corr = jnp.zeros((t, r))
    kw = dict(PDU_KW, q_max=5.0)
    want = ref.pdu_sim(u, g0, soc0, x0, filt.ad, filt.bd, filt.c[0], corrective=corr, **kw)
    got = ops.pdu_sim(u, g0, soc0, x0, filt.ad, filt.bd, filt.c[0], corr,
                      force="pallas", block_t=512, **kw)
    assert float(jnp.max(got[1])) <= 0.9 + 1e-6
    _assert_close(got[0], want[0])
    _assert_close(got[1], want[1])


def test_pdu_sim_matches_unfused_pipeline():
    """Fused kernel == ESS simulate piped into LC simulate (the unfused
    paper-faithful path) — the fusion is a pure optimization."""
    from repro.core import ess as ess_mod

    filt = _proto_filter()
    t, r = 600, 6
    u = 0.3 + 0.5 * jax.random.uniform(jax.random.key(6), (t, r))
    x0 = jnp.tile(filters.steady_state(filt, jnp.array([1.0, 0.5])), (r, 1))
    ep = ess_mod.ESSParams.create(beta=PDU_KW["beta"], q_max_seconds=PDU_KW["q_max"])
    st = ess_mod.ESSState(g_filter=u[0], soc=jnp.full((r,), 0.5))
    node, soc_t, _ = ess_mod.simulate(ep, st, u, PDU_KW["dt"])
    uu = jnp.stack([jnp.ones_like(node), node], axis=-1)
    grid_unfused, _ = filters.simulate(filt, x0, uu)
    got = ops.pdu_sim(u, u[0], jnp.full((r,), 0.5), x0, filt.ad, filt.bd, filt.c[0],
                      jnp.zeros((t, r)), force="pallas", **PDU_KW)
    _assert_close(got[0], grid_unfused[..., 0])
    _assert_close(got[1], soc_t)


# ------------------------------------------------------------ flash attention


@pytest.mark.parametrize(
    "b,h,hkv,tq,tk,d",
    [(2, 4, 4, 256, 256, 64), (1, 8, 2, 256, 256, 128), (1, 4, 2, 128, 512, 64),
     (2, 2, 1, 512, 512, 64)],
)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, hkv, tq, tk, d, causal, dtype):
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (b, h, tq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, tk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, tk, d), dtype)
    got = ops.attention(q, k, v, causal=causal, force="pallas",
                        block_q=128, block_k=128)
    want = ref.attention(q, k, v, causal=causal)
    _assert_close(got, want, dtype)


def test_flash_attention_decode_offset():
    """Tq < Tk (decode/chunked prefill): causal offset must align to the
    END of the KV sequence."""
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 1024, 64))
    v = jax.random.normal(ks[2], (1, 2, 1024, 64))
    got = ops.attention(q, k, v, causal=True, force="pallas", block_q=128, block_k=128)
    want = ref.attention(q, k, v, causal=True)
    _assert_close(got, want)


def _attn_loss(fn):
    return lambda q, k, v: jnp.sum(jnp.sin(fn(q, k, v)))


@pytest.mark.pallas
@pytest.mark.parametrize(
    "b,h,hkv,tq,tk,d",
    [(1, 2, 2, 256, 256, 64), (1, 4, 2, 128, 128, 64), (1, 2, 2, 128, 512, 64)],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_backward(b, h, hkv, tq, tk, d, causal):
    """Fused dK/dV + dQ kernels == the dense lse-based backward (same math)
    == autodiff through the jnp reference — incl. GQA head-group reduction
    and the decode offset."""
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (b, h, tq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, tk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, tk, d), jnp.float32)

    def attn(algorithm):
        return lambda *a: ops.attention(
            *a, causal=causal, force="pallas", block_q=128, block_k=128,
            algorithm=algorithm,
        )

    g_kernel = jax.grad(_attn_loss(attn("auto")), argnums=(0, 1, 2))(q, k, v)
    g_oracle = jax.grad(_attn_loss(attn("reference")), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(
        _attn_loss(lambda *a: ref.attention(*a, causal=causal)), argnums=(0, 1, 2)
    )(q, k, v)
    for nm, a, b2 in zip("qkv", g_kernel, g_oracle):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b2), rtol=1e-4, atol=2e-5,
            err_msg=f"d{nm}: kernel vs lse-oracle",
        )
    for nm, a, b2 in zip("qkv", g_kernel, g_ref):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b2), rtol=1e-4, atol=5e-5,
            err_msg=f"d{nm}: kernel vs reference autodiff",
        )


def test_attention_auto_falls_back_on_ragged_shapes():
    """Sequences the tiles don't divide route to ref.attention and stay
    differentiable (no pallas assert trips through ops)."""
    ks = jax.random.split(jax.random.key(10), 3)
    q = jax.random.normal(ks[0], (1, 2, 100, 64))
    k = jax.random.normal(ks[1], (1, 2, 100, 64))
    v = jax.random.normal(ks[2], (1, 2, 100, 64))
    out = ops.attention(q, k, v, causal=True, force="pallas")
    _assert_close(out, ref.attention(q, k, v, causal=True))
    g = jax.grad(_attn_loss(
        lambda *a: ops.attention(*a, causal=True, force="pallas")
    ))(q, k, v)
    assert np.all(np.isfinite(np.asarray(g)))


# ----------------------------------------------------------------- rwkv6 scan


@pytest.mark.parametrize("b,h,t,d,block_t", [(2, 3, 200, 64, 64), (1, 2, 64, 128, 64), (1, 1, 257, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(b, h, t, d, block_t, dtype):
    ks = jax.random.split(jax.random.key(9), 5)
    r = (jax.random.normal(ks[0], (b, h, t, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, h, t, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, h, t, d)) * 0.5).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, d))) * 0.5 + 0.45).astype(dtype)
    u = (jax.random.normal(ks[4], (h, d)) * 0.3).astype(dtype)
    got, sf = ops.rwkv6_scan(r, k, v, w, u, force="pallas", block_t=block_t)
    want, sf_ref = ref.rwkv6_scan(r, k, v, w, u)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(sf, np.float32), np.asarray(sf_ref, np.float32), **tol)


def test_rwkv6_state_carry():
    """Chunked scan with carried state == one full scan (decode contract)."""
    b, h, t, d = 1, 2, 128, 64
    ks = jax.random.split(jax.random.key(10), 5)
    r = jax.random.normal(ks[0], (b, h, t, d)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, d)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, d))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    full, s_full = ref.rwkv6_scan(r, k, v, w, u)
    half = t // 2
    o1, s1 = ops.rwkv6_scan(r[:, :, :half], k[:, :, :half], v[:, :, :half],
                            w[:, :, :half], u, force="pallas", block_t=64)
    o2, s2 = ops.rwkv6_scan(r[:, :, half:], k[:, :, half:], v[:, :, half:],
                            w[:, :, half:], u, s1, force="pallas", block_t=64)
    _assert_close(jnp.concatenate([o1, o2], axis=2), full)
    _assert_close(s2, s_full)


# ------------------------------------------------------------- ops dispatch


def test_ops_ref_fallback_on_cpu():
    """On this CPU container, auto mode must pick the reference path."""
    x = jax.random.normal(jax.random.key(11), (4, 128))
    w = jnp.ones((128,))
    auto = ops.rmsnorm(x, w)  # no force
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(want), atol=0)


def test_rwkv6_chunked_extreme_decays_finite():
    """Adversarial decay regimes (found a fp32 overflow pre-clamp): the
    chunked path must stay finite everywhere and accurate within its
    documented envelope (mean per-step decay >= ~0.29 at chunk=32)."""
    b, h, t, d = 1, 2, 256, 64
    ks = jax.random.split(jax.random.key(42), 5)
    r = jax.random.normal(ks[0], (b, h, t, d)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, d)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, d)) * 0.5
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    for w_val, accurate in [(0.9999, True), (0.5, True), (0.3, True), (0.01, False)]:
        w = jnp.full((b, h, t, d), w_val, jnp.float32)
        o1, s1 = ref.rwkv6_scan(r, k, v, w, u)
        o2, s2 = ref.rwkv6_chunked(r, k, v, w, u, chunk=32)
        assert bool(jnp.all(jnp.isfinite(o2))), f"non-finite at w={w_val}"
        if accurate:
            np.testing.assert_allclose(
                np.asarray(o2), np.asarray(o1), atol=2e-4, rtol=1e-3
            )
