"""Factor-once controller plan tests: the precomputed ``ControllerPlan`` +
batched warm-started ADMM must reproduce the per-step ``_build_qp`` +
``solve_qp_admm`` oracle, and the warm-started PDU conditioning path must
match the cold-start path on the paper testbench."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import controller as ctrl, pdu
from repro.core.ess import ESSParams
from repro.power import trace


def _cfg(**kw):
    return ctrl.ControllerConfig.create(**kw)


def _ess(**kw):
    kw.setdefault("q_max_seconds", 40.0)
    return ESSParams.create(**kw)


# ----------------------------------------------------------- plan assembly


@pytest.mark.parametrize(
    "soc,tgt,up", [(0.62, 0.5, 0.0), (0.35, 0.5, 0.4), (0.88, 0.45, -1.0)]
)
def test_plan_matches_build_qp(soc, tgt, up):
    """P, A, q, lo, hi assembled from the plan == the per-step oracle."""
    cfg, es = _cfg(), _ess()
    plan = ctrl.make_plan(cfg, es)
    p, q, a, lo, hi = ctrl._build_qp(
        cfg, es, jnp.asarray(soc), jnp.asarray(tgt), jnp.asarray(up)
    )
    q2, lo2, hi2 = ctrl._qp_state_terms(
        plan, jnp.asarray(soc), jnp.asarray(tgt), jnp.asarray(up)
    )
    np.testing.assert_allclose(np.asarray(plan.p_mat), np.asarray(p), atol=1e-6)
    np.testing.assert_allclose(np.asarray(plan.a_mat), np.asarray(a), atol=1e-7)
    np.testing.assert_allclose(np.asarray(q2), np.asarray(q), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lo2), np.asarray(lo), atol=1e-7)
    np.testing.assert_allclose(np.asarray(hi2), np.asarray(hi), atol=1e-7)


@pytest.mark.parametrize(
    "soc,tgt,up", [(0.62, 0.5, 0.0), (0.35, 0.5, 0.4), (0.88, 0.45, -1.0)]
)
def test_plan_solve_matches_oracle(soc, tgt, up):
    """Prefactorized batched solve == per-step cho_factor solve to <= 1e-5."""
    cfg, es = _cfg(), _ess()
    plan = ctrl.make_plan(cfg, es)
    p, q, a, lo, hi = ctrl._build_qp(
        cfg, es, jnp.asarray(soc), jnp.asarray(tgt), jnp.asarray(up)
    )
    sol = ctrl.solve_qp_admm(p, q, a, lo, hi, iters=120)
    q2, lo2, hi2 = ctrl._qp_state_terms(
        plan, jnp.asarray(soc), jnp.asarray(tgt), jnp.asarray(up)
    )
    sol2, _ = ctrl.solve_qp_admm_plan(plan, q2, lo2, hi2, iters=120)
    np.testing.assert_allclose(np.asarray(sol2.x), np.asarray(sol.x), atol=1e-5)
    assert float(sol2.primal_residual) == pytest.approx(
        float(sol.primal_residual), abs=1e-5
    )


def test_batched_step_matches_vmapped_oracle():
    """One (2h, R)-RHS solve == R vmapped scalar solves."""
    cfg, es = _cfg(), _ess()
    plan = ctrl.make_plan(cfg, es)
    socs = jnp.asarray([0.2, 0.45, 0.62, 0.85])
    ups = jnp.asarray([0.0, 0.3, -0.2, 0.9])
    want = jax.vmap(
        lambda s, u: ctrl.inner_loop_step(
            cfg, es, s, jnp.asarray(0.5), u, qp_iters=120
        ).corrective_power
    )(socs, ups)
    out, _ = ctrl.inner_loop_step_plan(
        cfg, es, plan, socs, jnp.asarray(0.5), ups, qp_iters=120
    )
    np.testing.assert_allclose(
        np.asarray(out.corrective_power), np.asarray(want), atol=1e-5
    )
    assert out.corrective_power.shape == socs.shape


# ------------------------------------------------------------- warm start


def test_warm_start_matches_cold_residual_at_quarter_iters():
    """The headline claim: 30 warm iterations reach (or beat) the primal
    residual of 120 cold iterations once the closed loop is underway."""
    cfg, es = _cfg(), _ess()
    plan = ctrl.make_plan(cfg, es)
    socs = jnp.asarray([0.2, 0.45, 0.62, 0.85])
    ups = jnp.zeros((4,))
    tgt = jnp.asarray(0.5)
    # one interval of history, then compare on the next interval's problem
    _, warm = ctrl.inner_loop_step_plan(cfg, es, plan, socs, tgt, ups, qp_iters=120)
    socs2 = socs - 0.001  # SoC moved a little over one interval
    warm_out, _ = ctrl.inner_loop_step_plan(
        cfg, es, plan, socs2, tgt, ups, warm, qp_iters=30
    )
    cold_out, _ = ctrl.inner_loop_step_plan(
        cfg, es, plan, socs2, tgt, ups, qp_iters=120
    )
    assert np.all(
        np.asarray(warm_out.qp_primal_residual)
        <= np.asarray(cold_out.qp_primal_residual) * 1.05 + 1e-6
    )


def test_simulate_soc_management_warm_converges():
    """Warm-started closed loop still lands inside the deadband region."""
    cfg, es = _cfg(i_max=6e-3), _ess()
    out = ctrl.simulate_soc_management(
        cfg, es, 0.58, n_steps=400, qp_iters=40, warm_start=True
    )
    soc = np.asarray(out["soc"])
    assert abs(soc[-1] - 0.5) <= 2 * float(cfg.deadband)


# ----------------------------------------------- PDU warm path == cold path


@pytest.fixture(scope="module")
def testbench():
    sp = trace.TestbenchSpec(duration_s=60.0, sample_hz=250.0, terminate_at_s=50.0)
    return trace.testbench_trace(sp, jax.random.key(11))


def test_condition_plan_matches_per_step_path(testbench):
    """use_plan=True (factored, warm-started) vs use_plan=False (seed
    per-interval build+factor) on the testbench trace."""
    rack, dt = testbench
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, rack[0])
    grid_cold, _, telem_cold = pdu.condition(
        cfg, st, rack, qp_iters=120, use_plan=False
    )
    st2 = pdu.init_state(cfg, rack[0])
    grid_warm, _, telem_warm = pdu.condition(
        cfg, st2, rack, qp_iters=120, use_plan=True
    )
    # The two paths solve the same QPs but stop at different points on the
    # ADMM trajectory (warm iterates are more converged at equal iters), so
    # commands may differ at the sub-deadband level; the grid waveform and
    # SoC trajectory must agree to well under the compliance scales
    # (beta = 0.1/s, deadband = 5e-3).
    np.testing.assert_allclose(
        np.asarray(grid_warm), np.asarray(grid_cold), atol=1e-3
    )
    np.testing.assert_allclose(
        np.asarray(telem_warm.soc), np.asarray(telem_cold.soc), atol=1e-3
    )


def test_condition_warm_state_streams(testbench):
    """qp_warm rides in PDUState: chunked conditioning == one-shot, so the
    warm start cannot leak state across the streaming boundary."""
    rack, dt = testbench
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, rack[0])
    full, _, _ = pdu.condition(cfg, st, rack, qp_iters=30)
    st2 = pdu.init_state(cfg, rack[0])
    k = int(round(float(cfg.controller.dt) / cfg.sample_dt))
    cut = (rack.shape[0] // (2 * k)) * k
    a, st2, _ = pdu.condition(cfg, st2, rack[:cut], qp_iters=30)
    b, st2, _ = pdu.condition(cfg, st2, rack[cut:], qp_iters=30)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([a, b])), np.asarray(full), atol=1e-5
    )


def test_telemetry_reports_qp_residual(testbench):
    rack, dt = testbench
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, rack[0])
    _, _, telem = pdu.condition(cfg, st, rack, qp_iters=30)
    resid = np.asarray(telem.qp_residual)
    assert resid.shape == np.asarray(telem.soc).shape
    assert np.all(resid >= 0.0) and np.all(np.isfinite(resid))
