"""Interval-resident conditioning megakernel + batched-ADMM kernel parity.

Runs the Pallas kernels in interpret mode against their jnp oracles
(``ref.pdu_health_sim`` / ``ref.admm_iterate``) through the ``ops``
dispatch layer, pinning the PR-5 reproducibility contract:

* SoC path, ESS filter value and **every** health leaf: bitwise.
* Grid / LC filter state: bitwise on sublane-aligned intervals; a few
  ulp on ragged intervals (XLA contracts the LC mul-add chain into FMAs
  differently once the time axis is padded — see the kernel docstring).
* Degraded-mode weights w in {0, 1}: bitwise against the same masked
  reference path the engines run.
* The turning-point machine and block accumulators: bitwise under
  stream splits (kernel-of-halves == kernel-of-whole == reference).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import controller as ctrl, health as hlt, pdu
from repro.core.ess import ESSParams
from repro.kernels import ops, ref
from repro.power import scenario as SC

pytestmark = pytest.mark.pallas

R, HZ = 192, 200.0


def _setup(t, n_racks=R):
    s = SC.mixed_campus(
        n_racks, ("llama3_2_1b", "deepseek_v3_671b"),
        duration_s=30.0, sample_hz=HZ, seed=3, noise_seed=2,
    )
    chunk = jax.jit(lambda: SC.render(s, 0, t))()
    cfg = pdu.make_pdu(sample_dt=1.0 / HZ, track_health=True)
    st = pdu.init_state(cfg, chunk[0])
    ep = cfg.ess_params
    kw = dict(
        beta=float(ep.beta), dt=1.0 / HZ, q_max=float(ep.q_max),
        eta_c=float(ep.eta_c), eta_d=float(ep.eta_d), p_max=float(ep.p_max),
        soc_min=float(ep.soc_safe_min), soc_max=float(ep.soc_safe_max),
    )
    filt = st.filter_obj
    args = (st.ess_state.g_filter, st.ess_state.soc, st.filter_state,
            filt.ad, filt.bd, filt.c[0])
    health = (hlt.step_consts(cfg.health), tuple(st.health))
    return chunk, args, kw, health


def _slew(n_racks=R):
    applied = jnp.zeros((n_racks,), jnp.float32)
    target = 0.01 * jnp.ones((n_racks,), jnp.float32)
    return applied, target


def _bw(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))


def _assert_parity(r_ref, r_pl, *, grid_bitwise):
    grid_r, soc_r, (g_r, socf_r, x_r), h_r = r_ref
    grid_p, soc_p, (g_p, socf_p, x_p), h_p = r_pl
    assert _bw(soc_r, soc_p), "SoC path must be bitwise"
    assert _bw(g_r, g_p), "ESS filter final must be bitwise"
    assert _bw(socf_r, socf_p), "SoC final must be bitwise"
    if grid_bitwise:
        assert _bw(grid_r, grid_p), "grid must be bitwise on aligned intervals"
        assert _bw(x_r, x_p)
    else:
        np.testing.assert_allclose(
            np.asarray(grid_p), np.asarray(grid_r), rtol=0, atol=1e-5
        )
        np.testing.assert_allclose(np.asarray(x_p), np.asarray(x_r), rtol=0, atol=1e-5)
    if h_r is None:
        assert h_p is None
    else:
        for i, (a, b) in enumerate(zip(h_r, h_p)):
            assert _bw(a, b), f"health leaf {i} must be bitwise"


# ------------------------------------------------------------- megakernel


def test_unmasked_parity_bitwise():
    chunk, args, kw, health = _setup(40)
    r1 = ref.pdu_health_sim(*([chunk] + list(args)), slew=_slew(), health=health, **kw)
    r2 = ops.pdu_health_sim(
        *([chunk] + list(args)), slew=_slew(), health=health, force="pallas", **kw
    )
    _assert_parity(r1, r2, grid_bitwise=True)


def test_masked_binary_weights_bitwise():
    """w in {0, 1} (hard converter cutoff) — the degraded-mode contract."""
    chunk, args, kw, health = _setup(40)
    w = (jax.random.uniform(jax.random.key(7), (R,)) > 0.3).astype(jnp.float32)
    r1 = ref.pdu_health_sim(
        *([chunk] + list(args)), slew=_slew(), ess_on=w, health=health, **kw
    )
    r2 = ops.pdu_health_sim(
        *([chunk] + list(args)), slew=_slew(), ess_on=w, health=health,
        force="pallas", **kw
    )
    _assert_parity(r1, r2, grid_bitwise=True)


def test_fractional_winddown_weights_bitwise():
    """Per-sample fractional weights (converter wind-down ramp, 2-D path)."""
    chunk, args, kw, health = _setup(40)
    w = jnp.clip(jax.random.uniform(jax.random.key(8), (40, R)), 0.0, 1.0)
    r1 = ref.pdu_health_sim(
        *([chunk] + list(args)), slew=_slew(), ess_on=w, health=health, **kw
    )
    r2 = ops.pdu_health_sim(
        *([chunk] + list(args)), slew=_slew(), ess_on=w, health=health,
        force="pallas", **kw
    )
    _assert_parity(r1, r2, grid_bitwise=True)


def test_dense_and_scalar_corrective_parity():
    chunk, args, kw, health = _setup(40)
    corr = 0.02 * jax.random.normal(jax.random.key(9), (40, R), jnp.float32)
    for c in (corr, 0.0):
        r1 = ref.pdu_health_sim(*([chunk] + list(args)), corrective=c, health=health, **kw)
        r2 = ops.pdu_health_sim(
            *([chunk] + list(args)), corrective=c, health=health, force="pallas", **kw
        )
        _assert_parity(r1, r2, grid_bitwise=True)


def test_ragged_final_interval():
    """t = 37 stresses the sublane pad: the loop must stop at t, padding
    rows must never leak into the block reductions, and the contract
    degrades only on the grid/LC path (ulp; see kernel docstring)."""
    chunk, args, kw, health = _setup(37)
    r1 = ref.pdu_health_sim(*([chunk] + list(args)), slew=_slew(), health=health, **kw)
    r2 = ops.pdu_health_sim(
        *([chunk] + list(args)), slew=_slew(), health=health, force="pallas", **kw
    )
    _assert_parity(r1, r2, grid_bitwise=False)


def test_multi_tile_and_rack_padding():
    """R = 192 with r_blk = 64: three full tiles; r_blk = 128: one full +
    one padded tile.  Tiling must not change a single bit."""
    chunk, args, kw, health = _setup(40)
    r1 = ref.pdu_health_sim(*([chunk] + list(args)), slew=_slew(), health=health, **kw)
    for blk in (64, 128):
        r2 = ops.pdu_health_sim(
            *([chunk] + list(args)), slew=_slew(), health=health,
            force="pallas", r_blk=blk, **kw
        )
        _assert_parity(r1, r2, grid_bitwise=True)


def test_no_health_path():
    chunk, args, kw, _ = _setup(40)
    r1 = ref.pdu_health_sim(*([chunk] + list(args)), slew=_slew(), **kw)
    r2 = ops.pdu_health_sim(
        *([chunk] + list(args)), slew=_slew(), force="pallas", **kw
    )
    _assert_parity(r1, r2, grid_bitwise=True)


def test_stream_split_health_bitwise():
    """The PR-5 split-invariance contract, now for the megakernel: the
    turning-point machine carries (prev, last_ext, direction, half_cycles,
    cycle_damage, max_dod) and the sample count are bit-identical under
    ANY stream split; the block-reduction leaves (charge/discharge
    throughput, SoC sums) are bit-identical whenever both sides fold the
    same blocks — so kernel-chain == reference-chain bitwise on every
    leaf, and kernel-chain == one-shot bitwise on the machine leaves."""
    t = 40
    chunk, args, kw, health = _setup(t)
    g0, soc0, x0, ad, bd, c_row = args
    hc, h0 = health
    MACHINE = (0, 1, 2, 3, 4, 5, 10)

    one = ops.pdu_health_sim(
        chunk, g0, soc0, x0, ad, bd, c_row, slew=_slew(), health=(hc, h0),
        force="pallas", **kw
    )
    for cut in (8, 17, 32):
        # The slew ramp is interval-scoped, so splitting mid-interval
        # replays the same rendered corrective profile via the dense path.
        applied, target = _slew()
        ramp = jnp.arange(1, t + 1, dtype=jnp.float32) / t
        corr = applied + (target - applied) * ramp[:, None]

        def chain(fn, force=None):
            fkw = {} if force is None else {"force": force}
            _, _, (gf, sf, xf), ha = fn(
                chunk[:cut], g0, soc0, x0, ad, bd, c_row,
                corrective=corr[:cut], health=(hc, h0), **fkw, **kw
            )
            return fn(
                chunk[cut:], gf, sf, xf, ad, bd, c_row,
                corrective=corr[cut:], health=(hc, ha), **fkw, **kw
            )

        _, _, fin_k, hk = chain(ops.pdu_health_sim, force="pallas")
        _, _, _, hr = chain(ref.pdu_health_sim)
        for i, (x, y) in enumerate(zip(hk, hr)):
            assert _bw(x, y), f"cut={cut}: health leaf {i} drifts vs ref chain"
        for i in MACHINE:
            assert _bw(hk[i], one[3][i]), (
                f"cut={cut}: machine leaf {i} drifts vs one-shot"
            )
        assert _bw(fin_k[1], one[2][1])


# ------------------------------------------------------------ batched ADMM


def _plan_problem(n_racks=R, seed=0):
    cfg, es = ctrl.ControllerConfig.create(), ESSParams.create(q_max_seconds=40.0)
    plan = ctrl.make_plan(cfg, es)
    k1, k2 = jax.random.split(jax.random.key(seed))
    soc = jnp.clip(0.5 + 0.2 * jax.random.normal(k1, (n_racks,)), 0.15, 0.85)
    u_prev = 0.3 * jax.random.normal(k2, (n_racks,))
    q, lo, hi = ctrl._qp_state_terms(plan, soc, jnp.float32(0.5), u_prev)
    kq = plan.kkt_inv @ q
    x0 = jnp.zeros_like(q)
    z0 = jnp.clip(plan.a_mat @ x0, lo, hi)
    y0 = jnp.zeros_like(z0)
    kkt_stack = jnp.concatenate([plan.kkt_inv_sigma, plan.kkt_inv_at], axis=1)
    g_blk = plan.a_mat[2 * plan.horizon:]
    return plan, (kkt_stack, g_blk, kq, lo, hi, x0, z0, y0)


@pytest.mark.parametrize("iters", [1, 8, 30])
def test_admm_kernel_matches_reference(iters):
    """Real (contractive) controller plan: the kernel tracks the jnp
    reference through the whole loop — convergent ADMM damps the ulp-level
    FMA differences instead of amplifying them."""
    plan, ops_args = _plan_problem()
    r1 = ref.admm_iterate(*ops_args, rho=plan.rho, iters=iters)
    r2 = ops.admm_iterate(*ops_args, rho=plan.rho, iters=iters, force="pallas")
    for nm, a, b in zip("xzy", r1, r2):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=0, atol=2e-5,
            err_msg=f"{nm} after {iters} iters",
        )


def test_admm_kernel_rack_tiling():
    """Rack padding / multiple lane tiles must not change the solve."""
    plan, ops_args = _plan_problem(n_racks=300)
    r1 = ref.admm_iterate(*ops_args, rho=plan.rho, iters=20)
    r2 = ops.admm_iterate(
        *ops_args, rho=plan.rho, iters=20, force="pallas", r_blk=128
    )
    for a, b in zip(r1, r2):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=0, atol=2e-5)


def test_admm_kernel_unbatched_falls_back():
    """1-D (single-rack) solves take the reference path through ops."""
    plan, (kkt_stack, g_blk, kq, lo, hi, x0, z0, y0) = _plan_problem(n_racks=1)
    args1 = (kkt_stack, g_blk, kq[:, 0], lo[:, 0], hi[:, 0], x0[:, 0], z0[:, 0], y0[:, 0])
    r1 = ref.admm_iterate(*args1, rho=plan.rho, iters=10)
    r2 = ops.admm_iterate(*args1, rho=plan.rho, iters=10, force="pallas")
    for a, b in zip(r1, r2):
        assert _bw(a, b)
