"""Supervisory safe-mode control plane tests (ISSUE 9).

Three layers:

* pure state-machine unit tests (``core.safemode``) — trip/readmission
  hysteresis, NaN residual handling, quarantine event counting;
* engine end-to-end — injected ADMM divergence trips PASSTHROUGH and
  re-admits after the hysteresis window, injected NaN state corruption
  quarantines and reinitializes, and (the transparency contract)
  ``safemode=False`` is bitwise identical to a supervised clean run;
* interaction with degraded mode (PR 6) — a rack that is both
  ESS-offline AND QP-diverged resolves to exactly ONE passthrough path:
  the availability plane masks its residual to zero, so availability
  faults never read as solver faults.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance, fleet, pdu, safemode as smode
from repro.power import scenario as SC

_DT = 1e-2  # controller dt is 5 s -> k = 500 samples/interval


def _cfg(**kw):
    kw.setdefault("sample_dt", _DT)
    return pdu.make_pdu(**kw)


def _const_trace(n_intervals, n_racks, k=500, level=0.6):
    return jnp.full((n_intervals * k, n_racks), level, jnp.float32)


def _run(cfg, trace, state=None, **kw):
    st = state if state is not None else pdu.init_state(cfg, trace[0])
    return pdu.condition(cfg, st, trace, qp_iters=30, **kw)


def _poison_warm(st, racks, value=1e12):
    """Garbage ADMM iterates: the next warm-started solve diverges on
    these racks (residual stays enormous until the watchdog trips and
    cold-starts the probe)."""
    x = st.qp_warm.x.at[:, jnp.asarray(racks)].set(value)
    return st._replace(qp_warm=st.qp_warm._replace(x=x))


# ----------------------------------------------------------- state machine


def test_trip_requires_consecutive_intervals():
    cfg = smode.SafeModeConfig.create(resid_threshold=0.1, trip_intervals=3)
    st = smode.init_state((2,))
    bad = jnp.asarray([1.0, 0.0])  # rack 0 over threshold, rack 1 clean
    for i in range(2):
        st = smode.residual_update(cfg, st, bad)
        assert int(st.mode[0]) == smode.NORMAL, f"tripped early at {i}"
    st = smode.residual_update(cfg, st, bad)
    assert int(st.mode[0]) == smode.PASSTHROUGH
    assert int(st.mode[1]) == smode.NORMAL
    assert int(st.passthrough_entries[0]) == 1
    assert int(st.worst_streak[0]) == 3


def test_nonconsecutive_residuals_do_not_trip():
    cfg = smode.SafeModeConfig.create(resid_threshold=0.1, trip_intervals=2)
    st = smode.init_state(())
    for r in (1.0, 0.0, 1.0, 0.0, 1.0, 0.0):
        st = smode.residual_update(cfg, st, jnp.asarray(r))
    assert int(st.mode) == smode.NORMAL
    assert int(st.passthrough_entries) == 0
    assert int(st.worst_streak) == 1


def test_nan_residual_counts_as_bad():
    # NaN compares false against any threshold; the watchdog must treat a
    # non-finite residual as a diverged solver, not a clean one.
    cfg = smode.SafeModeConfig.create(resid_threshold=0.1, trip_intervals=2)
    st = smode.init_state(())
    for _ in range(2):
        st = smode.residual_update(cfg, st, jnp.asarray(jnp.nan))
    assert int(st.mode) == smode.PASSTHROUGH


def test_hysteretic_readmission():
    cfg = smode.SafeModeConfig.create(
        resid_threshold=0.1, trip_intervals=1, readmit_intervals=3
    )
    st = smode.init_state(())
    st = smode.residual_update(cfg, st, jnp.asarray(1.0))  # trip
    assert int(st.mode) == smode.PASSTHROUGH
    # A clean probe interrupted by one bad probe restarts the count.
    st = smode.residual_update(cfg, st, jnp.asarray(0.0))
    st = smode.residual_update(cfg, st, jnp.asarray(1.0))
    st = smode.residual_update(cfg, st, jnp.asarray(0.0))
    st = smode.residual_update(cfg, st, jnp.asarray(0.0))
    assert int(st.mode) == smode.PASSTHROUGH  # only 2 consecutive clean
    st = smode.residual_update(cfg, st, jnp.asarray(0.0))
    assert int(st.mode) == smode.NORMAL
    assert int(st.readmissions) == 1
    assert int(st.clean_streak) == 0  # reset on re-admission


def test_quarantine_counts_every_event_and_gates():
    st = smode.init_state((3,))
    corrupt = jnp.asarray([True, False, False])
    st = smode.quarantine(st, corrupt)
    st = smode.quarantine(st, corrupt)  # corrupted again while contained
    assert int(st.mode[0]) == smode.QUARANTINE
    assert int(st.quarantine_entries[0]) == 2
    np.testing.assert_array_equal(np.asarray(smode.gate(st)), [0.0, 1.0, 1.0])


def test_quarantined_rack_readmits_on_clean_probes():
    cfg = smode.SafeModeConfig.create(trip_intervals=1, readmit_intervals=2)
    st = smode.init_state(())
    st = smode.quarantine(st, jnp.asarray(True))
    st = smode.residual_update(cfg, st, jnp.asarray(0.0))
    st = smode.residual_update(cfg, st, jnp.asarray(0.0))
    assert int(st.mode) == smode.NORMAL
    assert int(st.readmissions) == 1


# ------------------------------------------------------- engine end-to-end


@pytest.mark.slow
def test_safemode_off_is_bitwise_identical():
    """Transparency contract: supervising a clean run changes nothing."""
    trace = _const_trace(6, 4) + 0.2 * jnp.sin(
        jnp.linspace(0.0, 40.0, 6 * 500)
    )[:, None] * jnp.linspace(0.5, 1.0, 4)[None, :]
    base_cfg = _cfg(track_health=True)
    sm_cfg = _cfg(track_health=True, safemode=True)
    g0, st0, t0 = jax.jit(lambda s, r: _run(base_cfg, r, state=s))(
        pdu.init_state(base_cfg, trace[0]), trace
    )
    g1, st1, t1 = jax.jit(lambda s, r: _run(sm_cfg, r, state=s))(
        pdu.init_state(sm_cfg, trace[0]), trace
    )
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    for a, b in zip(
        jax.tree_util.tree_leaves(st0), jax.tree_util.tree_leaves(st1)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in ("soc", "command", "qp_residual", "target"):
        np.testing.assert_array_equal(
            np.asarray(getattr(t0, name)), np.asarray(getattr(t1, name))
        )
    assert np.all(np.asarray(t1.safemode_mode) == smode.NORMAL)


@pytest.mark.slow
def test_divergence_trips_and_readmits():
    cfg = _cfg(
        safemode=True,
        safemode_params=smode.SafeModeConfig.create(
            resid_threshold=0.05, trip_intervals=2, readmit_intervals=3
        ),
    )
    trace = _const_trace(10, 6)
    st = _poison_warm(pdu.init_state(cfg, trace[0]), [1, 4])
    grid, st2, telem = jax.jit(lambda s, r: _run(cfg, r, state=s))(st, trace)
    mode = np.asarray(telem.safemode_mode)  # (10, 6)
    # Poisoned racks: diverge, trip after 2 bad intervals, probe clean
    # (cold-started) and re-admit after 3 clean intervals.
    for r in (1, 4):
        assert mode[0, r] == smode.NORMAL and mode[1, r] == smode.PASSTHROUGH
        assert np.any(mode[:, r] == smode.NORMAL) and mode[-1, r] == smode.NORMAL
        row = mode[:, r]
        first_normal = int(np.argmax(row[1:] == smode.NORMAL)) + 1
        assert np.all(row[1:first_normal] == smode.PASSTHROUGH)
    assert np.all(mode[:, [0, 2, 3, 5]] == smode.NORMAL)
    # Contained racks never command their battery; the output stays finite.
    cmd = np.asarray(telem.command)
    assert np.all(cmd[mode != smode.NORMAL] == 0.0)
    assert np.all(np.isfinite(np.asarray(grid)))
    sm = st2.safemode
    np.testing.assert_array_equal(
        np.asarray(sm.passthrough_entries), [0, 1, 0, 0, 1, 0]
    )
    np.testing.assert_array_equal(np.asarray(sm.readmissions), [0, 1, 0, 0, 1, 0])
    assert int(np.max(np.asarray(sm.worst_streak))) >= 2


@pytest.mark.slow
def test_nan_corruption_quarantines_and_reinitializes():
    cfg = _cfg(safemode=True, track_health=True)
    trace = _const_trace(4, 5)
    st = pdu.init_state(cfg, trace[0])
    soc = st.ess_state.soc.at[2].set(jnp.nan)
    st = st._replace(ess_state=st.ess_state._replace(soc=soc))
    grid, st2, telem = jax.jit(lambda s, r: _run(cfg, r, state=s))(st, trace)
    sm = st2.safemode
    np.testing.assert_array_equal(
        np.asarray(sm.quarantine_entries), [0, 0, 1, 0, 0]
    )
    mode = np.asarray(telem.safemode_mode)
    assert mode[0, 2] == smode.QUARANTINE
    # Every carried float leaf is finite again (the reinit worked) and the
    # grid trace never exported a non-finite sample.
    for leaf in jax.tree_util.tree_leaves(st2):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float64)))
    assert np.all(np.isfinite(np.asarray(grid)))
    assert np.all(np.asarray(telem.command)[mode != smode.NORMAL] == 0.0)


@pytest.mark.slow
def test_unsupervised_corruption_propagates():
    """Counter-test: without safe mode the same NaN poisons the stream —
    this is the failure the sanitizer exists for."""
    cfg = _cfg()
    trace = _const_trace(2, 3)
    st = pdu.init_state(cfg, trace[0])
    soc = st.ess_state.soc.at[0].set(jnp.nan)
    st = st._replace(ess_state=st.ess_state._replace(soc=soc))
    grid, st2, _ = jax.jit(lambda s, r: _run(cfg, r, state=s))(st, trace)
    assert not np.all(np.isfinite(np.asarray(st2.ess_state.soc)))


# -------------------------------------------- interaction with PR-6 plane


@pytest.mark.slow
def test_offline_and_diverged_is_exactly_one_passthrough_path():
    """A rack both ESS-offline AND QP-diverged must resolve to the
    availability plane alone: its residual arrives pre-masked to zero, so
    the solver watchdog never counts an availability fault as a solver
    fault — offline+poisoned is bitwise the plain offline run."""
    cfg = _cfg(degraded_mode=True, safemode=True)
    trace = _const_trace(5, 4)
    offline = jnp.ones((4,), jnp.float32).at[1].set(0.0)
    st_a = pdu.init_state(cfg, trace[0])
    st_b = _poison_warm(st_a, [1])
    run = jax.jit(
        lambda s, r: _run(cfg, r, state=s, ess_online=offline)
    )
    g_a, sa, ta = run(st_a, trace)
    g_b, sb, tb = run(st_b, trace)
    np.testing.assert_array_equal(np.asarray(g_a), np.asarray(g_b))
    np.testing.assert_array_equal(
        np.asarray(ta.safemode_mode), np.asarray(tb.safemode_mode)
    )
    assert np.all(np.asarray(tb.safemode_mode) == smode.NORMAL)
    assert int(np.sum(np.asarray(sb.safemode.passthrough_entries))) == 0
    # (The poisoned warm state itself is reset by the offline plane, so
    # even the carried iterates agree.)
    for a, b in zip(
        jax.tree_util.tree_leaves(sa), jax.tree_util.tree_leaves(sb)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_trip_then_offline_then_readmit_at_interval_boundaries():
    """Boundary regression for the combined planes: a rack trips the
    solver watchdog, goes ESS-offline while contained, comes back online,
    and re-admits — exactly one passthrough entry, commands zero for the
    whole containment, and the streamed (3-call) run matches the counters
    a single supervisor would produce."""
    cfg = _cfg(
        degraded_mode=True,
        safemode=True,
        safemode_params=smode.SafeModeConfig.create(
            resid_threshold=0.05, trip_intervals=1, readmit_intervals=2
        ),
    )
    k = 500
    st = _poison_warm(pdu.init_state(cfg, _const_trace(1, 3)[0]), [0])
    run = jax.jit(
        lambda s, r, on: _run(cfg, r, state=s, ess_online=on),
        static_argnums=(),
    )
    on = jnp.ones((3,), jnp.float32)
    off0 = on.at[0].set(0.0)
    modes, cmds = [], []
    # Window 1 (2 intervals, online): poisoned rack trips.
    g, st, t = run(st, _const_trace(2, 3), on)
    modes.append(np.asarray(t.safemode_mode)); cmds.append(np.asarray(t.command))
    assert np.asarray(t.safemode_mode)[0, 0] == smode.PASSTHROUGH
    # Window 2 (1 interval): the tripped rack also goes ESS-offline.  The
    # availability plane masks its residual, which counts as a clean probe
    # — no second entry, no quarantine.
    g, st, t = run(st, _const_trace(1, 3), off0)
    modes.append(np.asarray(t.safemode_mode)); cmds.append(np.asarray(t.command))
    # Window 3 (3 intervals, back online): clean probes complete the
    # hysteresis window and the rack re-admits.
    g, st, t = run(st, _const_trace(3, 3), on)
    modes.append(np.asarray(t.safemode_mode)); cmds.append(np.asarray(t.command))
    mode = np.concatenate(modes)
    cmd = np.concatenate(cmds)
    assert mode[-1, 0] == smode.NORMAL
    assert int(np.asarray(st.safemode.passthrough_entries)[0]) == 1
    assert int(np.asarray(st.safemode.quarantine_entries)[0]) == 0
    assert int(np.asarray(st.safemode.readmissions)[0]) == 1
    assert np.all(cmd[mode != smode.NORMAL] == 0.0)
    assert np.all(mode[:, 1:] == smode.NORMAL)


# --------------------------------------------------------- fleet plumbing


@pytest.mark.slow
def test_fleet_safemode_trace_and_summary():
    s = SC.mixed_campus(
        4, ("llama3_2_1b", "qwen1_5_4b"), duration_s=40.0, sample_hz=100.0,
        seed=7,
    )
    spec = compliance.GridSpec.create()
    cfg_on = pdu.make_pdu(sample_dt=1e-2, safemode=True)
    res = fleet.condition(
        s, cfg_on, spec, stream=fleet.StreamOptions(chunk_intervals=4),
        qp_iters=30,
    )
    trace = np.asarray(res.safemode_trace)
    assert trace.shape[1] == 6
    assert np.all(trace[:, 0] == 1.0)  # clean run: every rack NORMAL
    assert np.all(trace[:, 1:5] == 0.0)
    summ = res.safemode_summary()
    assert summ["n_normal"] == 4 and summ["n_quarantined"] == 0
    cfg_off = pdu.make_pdu(sample_dt=1e-2)
    res_off = fleet.condition(
        s, cfg_off, spec, stream=fleet.StreamOptions(chunk_intervals=4),
        qp_iters=30,
    )
    assert np.all(np.asarray(res_off.safemode_trace) == 0.0)
    np.testing.assert_array_equal(
        np.asarray(res.campus_grid), np.asarray(res_off.campus_grid)
    )
