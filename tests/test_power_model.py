"""Workload power-model tests (repro.power)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance
from repro.power import device, phases, trace


def test_device_ratios_match_paper():
    """Paper §2.2: H100 700->140 W (5:1), B200 1000->50 W (20:1)."""
    assert device.H100.peak_to_idle == pytest.approx(5.0)
    assert device.B200.peak_to_idle == pytest.approx(20.0)


def test_testbench_trace_structure():
    sp = trace.TestbenchSpec(duration_s=66.0, sample_hz=500.0, noise_std=0.0)
    p, dt = trace.testbench_trace(sp, None)
    assert p.shape == (33000,)
    assert float(p.max()) <= 1.0 and float(p.min()) >= 0.0
    # has both compute-level and comm-level power
    assert float(p.max()) > 0.85
    assert float(p.min()) < 0.3


def test_testbench_spectral_line_at_1_over_22hz():
    """Paper Fig. 3b: prominent peak near 1/22 Hz with S ~ 0.1."""
    p, dt = trace.choukse_testbench(None)
    freqs, s = compliance.normalized_spectrum(p, dt)
    band = (freqs > 1 / 30) & (freqs < 1 / 15)
    mags = jnp.where(band, s, 0.0)
    i = int(jnp.argmax(mags))
    assert abs(float(freqs[i]) - 1 / 22) < 0.01
    assert 0.05 < float(s[i]) < 0.3


def test_fault_trace_has_huge_ramp():
    """Fig. 13: the computation-fault drop is far beyond any generator."""
    p, dt = trace.cluster_fault_trace(None)
    r = float(compliance.max_abs_ramp(p, dt))
    assert r > 10.0  # >1000% of rated power per second


def test_phase_timeline_trace_lengths():
    durs = np.array([0.5, 0.25, 0.5])
    pows = np.array([1.0, 0.3, 1.0], np.float32)
    p, dt = trace.phase_timeline_trace(durs, pows, sample_hz=100.0, edge_time_s=0.0)
    assert p.shape[0] == 125
    assert float(p[0]) == 1.0 and float(p[60]) == pytest.approx(0.3)
    # with edges, transitions are linear ramps instead of steps
    p2, _ = trace.phase_timeline_trace(durs, pows, sample_hz=100.0, edge_time_s=0.1)
    assert float(jnp.max(jnp.abs(jnp.diff(p2)))) < float(jnp.max(jnp.abs(jnp.diff(p))))


def test_step_phases_durations():
    hw = phases.HardwareConstants(chips=256)
    cost = phases.StepCost(flops=1e18, hbm_bytes=1e15, collective_bytes=2e14)
    model = phases.PhaseModel(mfu=0.5, overlap=0.0)
    d, p = phases.step_phases(cost, hw, model)
    t_busy = 1e18 / (256 * 197e12 * 0.5)
    assert d[0] == pytest.approx(t_busy, rel=1e-6)
    assert p[0] == 1.0 and p[1] < 0.6


def test_training_timeline_has_checkpoint_stalls():
    hw = phases.HardwareConstants(chips=8)
    cost = phases.StepCost(flops=1e15, hbm_bytes=1e12, collective_bytes=1e11)
    model = phases.PhaseModel(checkpoint_every_steps=5, checkpoint_stall_s=2.0)
    d, p = phases.training_timeline(cost, hw, model, n_steps=10, warmup_s=1.0, warmup_levels=2)
    idle = model.device.p_idle_w / model.device.p_peak_w
    # two checkpoint stalls of 2 s at idle power
    stalls = [(di, pi) for di, pi in zip(d, p) if di == 2.0 and pi == pytest.approx(idle)]
    assert len(stalls) >= 2


def test_workload_trace_violates_then_conditioned(tmp_path):
    """The full pipeline: phase model -> trace -> EasyRider -> compliant."""
    from repro.core import pdu

    hw = phases.HardwareConstants(chips=256)
    cost = phases.StepCost(flops=5e18, hbm_bytes=2e15, collective_bytes=5e14)
    model = phases.PhaseModel(checkpoint_every_steps=8, checkpoint_stall_s=3.0)
    d, pw = phases.training_timeline(cost, hw, model, n_steps=24)
    p, dt = trace.phase_timeline_trace(d, pw, sample_hz=200.0)
    spec = compliance.GridSpec.create()
    assert not bool(compliance.check(p, dt, spec).ramp_ok)
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, p[0])
    grid, _, _ = pdu.condition(cfg, st, p, qp_iters=15)
    assert bool(compliance.check(grid, dt, spec).ramp_ok)
