"""Optional-``hypothesis`` shim (satellite of the plan/warm-start PR).

The seed image does not ship ``hypothesis``, which made five test modules
fail *collection* and abort the whole suite.  A bare
``pytest.importorskip("hypothesis")`` would skip those modules entirely,
losing every non-property test they contain.  Instead the modules import
``given``/``settings``/``strategies`` through this shim: with hypothesis
installed they get the real API; without it the property tests collect
normally and individually skip, while the plain tests keep running.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - depends on the image
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the decorated test is skipped anyway)."""

        def __getattr__(self, _name):
            def any_strategy(*_a, **_k):
                return None

            return any_strategy

    strategies = _AnyStrategy()
