"""Shared test configuration.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests and benchmarks must see the single real CPU device.  Only
``launch/dryrun.py`` (run as a script) forces 512 host devices.
"""
import os

# Keep XLA from eating every core during test runs; determinism matters more.
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import jax  # noqa: E402

jax.config.update("jax_default_prng_impl", "threefry2x32")
