"""Compliance-math tests (paper §3): spectrum normalization, ramp checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance


def test_spectrum_dc_is_mean():
    p = jnp.full((1000,), 0.73)
    freqs, s = compliance.normalized_spectrum(p, 1e-3)
    assert float(s[0]) == pytest.approx(0.73, rel=1e-5)
    # Hann window puts the DC line's sidelobe in bin 1 only; beyond that
    # a constant has no content.
    assert float(jnp.max(s[2:])) < 1e-5
    freqs, s_raw = compliance.normalized_spectrum(p, 1e-3, window=None)
    assert float(s_raw[0]) == pytest.approx(0.73, rel=1e-5)
    assert float(jnp.max(s_raw[1:])) < 1e-6


def test_spectrum_sinusoid_amplitude():
    """A sinusoid of amplitude A must read S = A at its frequency bin."""
    dt = 1e-3
    n = 10_000
    t = jnp.arange(n) * dt
    for f0, a in [(5.0, 0.2), (50.0, 0.01)]:
        p = 0.5 + a * jnp.sin(2 * jnp.pi * f0 * t)
        freqs, s = compliance.normalized_spectrum(p, dt)
        i = int(jnp.argmin(jnp.abs(freqs - f0)))
        assert float(s[i]) == pytest.approx(a, rel=1e-3)


def test_ramp_rate_of_linear_ramp():
    dt = 0.01
    p = jnp.arange(100) * dt * 0.05  # slope 0.05/s
    assert float(compliance.max_abs_ramp(p, dt)) == pytest.approx(0.05, rel=1e-4)


def test_check_flags_violations():
    spec = compliance.GridSpec.create(beta=0.1, alpha=1e-4, f_c=2.0)
    dt = 1e-3
    n = 20_000
    t = jnp.arange(n) * dt
    bad = 0.5 + 0.3 * jnp.sign(jnp.sin(2 * jnp.pi * 1.0 * t))  # square wave
    rep = compliance.check(bad, dt, spec)
    assert not bool(rep.ok)
    good = jnp.full((n,), 0.5)
    rep2 = compliance.check(good, dt, spec)
    assert bool(rep2.ok)


def test_check_batched_over_racks():
    spec = compliance.GridSpec.create()
    dt = 1e-3
    t = jnp.arange(8000) * dt
    flat = jnp.full_like(t, 0.6)
    square = 0.5 + 0.4 * jnp.sign(jnp.sin(2 * jnp.pi * 3.0 * t))
    p = jnp.stack([flat, square], axis=1)
    rep = compliance.check(p, dt, spec)
    assert rep.ok.shape == (2,)
    assert bool(rep.ok[0]) and not bool(rep.ok[1])


def test_violation_fraction():
    spec = compliance.GridSpec.create(beta=0.1)
    dt = 0.01
    p = jnp.zeros((1000,))
    p = p.at[500].set(1.0)  # one spike -> 2 bad forward diffs
    frac = float(compliance.violation_fraction(p, dt, spec))
    assert frac == pytest.approx(2.0 / 999.0, rel=1e-6)
