"""Sharding-rules tests: param spec assignment, divisibility fallback,
activation constraints as no-ops without a mesh, decode-state specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding import rules


@pytest.fixture(scope="module")
def mesh1():
    # single-device "production-shaped" mesh: axes exist, sizes are 1
    return rules.make_mesh((1, 1), ("data", "model"),
                           axis_types=(rules.AxisType.Auto,) * 2)


def _spec(path, shape, mesh):
    return rules.param_spec(tuple(path), tuple(shape), mesh)


def test_up_kernel_spec(mesh1):
    s = _spec(("blocks", "attn", "wq", "kernel"), (16, 2048, 4096), mesh1)
    assert s == P(None, "data", "model")


def test_down_kernel_spec(mesh1):
    s = _spec(("blocks", "ffn", "w_down", "kernel"), (16, 8192, 2048), mesh1)
    assert s == P(None, "model", "data")


def test_embedding_spec(mesh1):
    s = _spec(("embed", "embedding"), (128256, 2048), mesh1)
    assert s == P("model", "data")


def test_expert_spec(mesh1):
    s = _spec(("moe_blocks", "moe", "experts", "w_up"), (58, 256, 7168, 2048), mesh1)
    assert s == P(None, "model", "data", None)


def test_norm_and_bias_replicated(mesh1):
    assert _spec(("ln1", "scale"), (2048,), mesh1) == P()
    assert _spec(("attn", "wq", "bias"), (4096,), mesh1) == P()
    assert _spec(("moe", "router", "kernel"), (5120, 256), mesh1) == P()


def test_divisibility_fallback():
    mesh = rules.make_mesh((1,), ("model",), axis_types=(rules.AxisType.Auto,))
    # model axis size 1 always divides; emulate non-divisible via size check:
    # use the helper directly
    assert rules._fits(20, mesh, "model")  # 20 % 1 == 0


def test_batch_spec(mesh1):
    assert rules.batch_spec(mesh1, 256) == P("data")
    # batch=1 (long-context): unsharded
    mesh = mesh1
    s = rules.batch_spec(mesh, 1)
    assert s in (P("data"), P(None))  # data size 1 divides 1 -> either fine


def test_maybe_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = rules.maybe_constrain(x, "data", None)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_maybe_constrain_drops_nondivisible(mesh1):
    # under a mesh context, non-divisible dims must be dropped, not error
    with mesh1:
        x = jnp.ones((3, 8))  # 3 % 1 == 0 so fine; just exercise the path
        y = rules.constrain_activations(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_decode_state_specs_kv(mesh1):
    from repro.models.attention import KVCache

    st = {
        "blocks": KVCache(
            k=jax.ShapeDtypeStruct((16, 32, 4096, 8, 64), jnp.bfloat16),
            v=jax.ShapeDtypeStruct((16, 32, 4096, 8, 64), jnp.bfloat16),
            length=jax.ShapeDtypeStruct((), jnp.int32),
        )
    }
    specs = rules.decode_state_specs(st, mesh1)
    assert specs["blocks"].k == P(None, "data", None, "model", None)
    assert specs["blocks"].length == P()


def test_decode_state_specs_mla(mesh1):
    from repro.models.attention import KVCache

    st = KVCache(
        k=jax.ShapeDtypeStruct((61, 128, 32768, 512), jnp.bfloat16),  # c_kv
        v=jax.ShapeDtypeStruct((61, 128, 32768, 64), jnp.bfloat16),  # k_rope
        length=jax.ShapeDtypeStruct((), jnp.int32),
    )
    specs = rules.decode_state_specs(st, mesh1)
    assert specs.k == P(None, "data", "model", None)


def test_gathered_weight_constraint_under_mesh(mesh1):
    with mesh1:
        w = jnp.ones((64, 128))
        out = rules.constrain_gathered_weight(("blocks", "attn", "wq", "kernel"), w)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(w))
