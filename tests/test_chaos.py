"""Chaos harness (ISSUE 9): crash/fault injection against the supervised
service.

Two tiers:

* **fast deterministic subset** (unmarked — runs in tier-1): atomic
  checkpoint semantics, torn-file recovery, kill/resume bitwise equality,
  restore validation (dtype + fingerprint), audit durability/rotation,
  and one fixed fault drill;
* **randomized sweep** (``@pytest.mark.chaos`` — opt-in via
  ``pytest -m chaos``, deselected by default through ``addopts``):
  seeded random kill-points, fault soups (ADMM divergence + NaN
  corruption + scheduled ESS trips), and corruption injections, replayed
  deterministically per seed.

Invariants held everywhere: every carried state leaf is finite; SoC stays
inside the safe window; contained racks never command a live battery;
recovery reproduces the uninterrupted run bitwise; the audit log stays
parseable with monotone ``seq`` after any simulated crash.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance, pdu, safemode as smode
from repro.power import faults as FLT, scenario as SC
from repro.serve import AuditLog, ConditionerService

_HZ = 100.0
_SPEC = compliance.GridSpec.create()


def _scenario(duration_s=60.0, n_racks=5, faulty=False, seed=4):
    s = SC.mixed_campus(
        n_racks, ("llama3_2_1b", "qwen1_5_4b"),
        duration_s=duration_s, sample_hz=_HZ, seed=seed,
    )
    if faulty:
        proc = FLT.FaultProcess.create(
            ess_mtbf_s=25.0, ess_mttr_s=10.0,
            sensor_mtbf_s=30.0, sensor_mttr_s=5.0,
        )
        s = SC.attach_faults(s, proc, seed=17)
    return s


def _service(s, **kw):
    cfg = pdu.make_pdu(
        sample_dt=1.0 / _HZ, degraded_mode=True, safemode=True,
        safemode_params=smode.SafeModeConfig.create(
            trip_intervals=2, readmit_intervals=3
        ),
    )
    return ConditionerService(cfg, s, _SPEC, chunk_intervals=4, **kw)


def _poison_warm(st, racks, value=1e12):
    x = st.qp_warm.x.at[:, jnp.asarray(racks)].set(value)
    return st._replace(qp_warm=st.qp_warm._replace(x=x))


def _corrupt_soc(st, racks):
    soc = st.ess_state.soc.at[jnp.asarray(racks)].set(jnp.nan)
    return st._replace(ess_state=st.ess_state._replace(soc=soc))


def _assert_invariants(svc):
    import jax

    cfg = svc.cfg
    lo = float(cfg.ess_params.soc_safe_min)
    hi = float(cfg.ess_params.soc_safe_max)
    states = svc.state if svc._is_region else (svc.state,)
    for st in states:
        for leaf in jax.tree_util.tree_leaves(st):
            assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float64)))
        soc = np.asarray(st.ess_state.soc)
        assert np.all(soc >= lo - 1e-6) and np.all(soc <= hi + 1e-6)
        # Contained racks hold zero command toward their battery.
        gate = np.asarray(smode.gate(st.safemode))
        assert np.all(np.asarray(st.cmd_target)[gate == 0.0] == 0.0)
        assert np.all(np.asarray(st.u_prev)[gate == 0.0] == 0.0)


def _drain_by_window(svc):
    """Advance to exhaustion; {start_sample: campus_grid} per window."""
    out = {}
    while not svc.exhausted:
        start = svc.sample_pos
        res = svc.advance()
        out[start] = np.asarray(res.campus_grid)
    return out


# ------------------------------------------------- fast deterministic tier


def test_checkpoint_leaves_no_temp_residue(tmp_path):
    svc = _service(_scenario(duration_s=20.0))
    svc.advance()
    p = svc.checkpoint(tmp_path / "a.npz")
    assert os.path.exists(p)
    assert [f for f in os.listdir(tmp_path)] == ["a.npz"]


def test_interrupted_checkpoint_preserves_previous(tmp_path, monkeypatch):
    """A crash mid-checkpoint (simulated at the rename) must leave the
    previous checkpoint intact and loadable — the atomic-write contract."""
    s = _scenario(duration_s=60.0)
    svc = _service(s)
    svc.advance()
    p = svc.checkpoint(tmp_path / "a.npz")
    pos0 = svc.sample_pos
    svc.advance()

    real_replace = os.replace

    def boom(src, dst):
        os.remove(src)  # the temp file dies with the "process"
        raise OSError("simulated crash at rename")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError, match="simulated crash"):
        svc.checkpoint(tmp_path / "a.npz")
    monkeypatch.setattr(os, "replace", real_replace)

    svc2 = _service(s)
    svc2.restore(p)
    assert svc2.sample_pos == pos0
    assert [f for f in os.listdir(tmp_path)] == ["a.npz"]


def test_recover_skips_torn_files_and_picks_newest(tmp_path):
    s = _scenario(duration_s=30.0)
    svc = _service(s)
    svc.advance()
    svc.checkpoint(tmp_path / "ckpt_a.npz")
    svc.advance()
    p_new = svc.checkpoint(tmp_path / "ckpt_b.npz")
    pos = svc.sample_pos
    # Torn npz (truncated zip), zero-byte file, and a foreign npz.
    with open(tmp_path / "torn.npz", "wb") as f:
        f.write(b"PK\x03\x04" + b"\x00" * 32)
    (tmp_path / "empty.npz").write_bytes(b"")
    np.savez(tmp_path / "foreign.npz", sample_pos=np.int64(10**9))

    svc2 = _service(s)
    got = svc2.recover(tmp_path)
    assert got == str(p_new)
    assert svc2.sample_pos == pos
    skipped = [e for e in svc2.audit.tail(50) if e["event"] == "recover_skipped"]
    assert len(skipped) == 3


def test_recover_empty_dir_returns_none(tmp_path):
    svc = _service(_scenario(duration_s=20.0))
    assert svc.recover(tmp_path) is None
    assert svc.audit.tail(1)[0]["event"] == "recover_failed"


def test_kill_and_recover_resumes_bitwise(tmp_path):
    """Kill after an auto-checkpoint; a fresh service recovers and the
    glued per-window outputs equal the uninterrupted run bitwise."""
    s = _scenario(duration_s=60.0, faulty=True)
    ref = _drain_by_window(_service(s))

    svc = _service(s, checkpoint_dir=tmp_path / "ck", checkpoint_every=1)
    got = {}
    for _ in range(3):
        start = svc.sample_pos
        got[start] = np.asarray(svc.advance().campus_grid)
    del svc  # kill: no clean shutdown, no final checkpoint call

    svc2 = _service(s)
    assert svc2.recover(tmp_path / "ck") is not None
    got.update(_drain_by_window(svc2))
    assert got.keys() == ref.keys()
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k])


def test_restore_rejects_dtype_mismatch(tmp_path):
    """Satellite (b): a checkpoint whose leaf dtype disagrees with the
    live state must raise a config-mismatch error, not silently cast."""
    s = _scenario(duration_s=20.0)
    svc = _service(s)
    svc.advance()
    p = svc.checkpoint(tmp_path / "a.npz")
    with np.load(p) as z:
        data = {k: z[k] for k in z.files}
    data["leaf_0"] = data["leaf_0"].astype(np.float64)
    np.savez(tmp_path / "widened.npz", **data)
    svc2 = _service(s)
    with pytest.raises(ValueError, match="dtype.*config/scenario mismatch"):
        svc2.restore(tmp_path / "widened.npz")


def test_restore_rejects_fingerprint_mismatch(tmp_path):
    s = _scenario(duration_s=20.0)
    svc = _service(s)
    svc.advance()
    p = svc.checkpoint(tmp_path / "a.npz")
    other_spec = compliance.GridSpec.create(beta=0.2)
    cfg = svc.cfg
    svc2 = ConditionerService(cfg, s, other_spec, chunk_intervals=4)
    with pytest.raises(ValueError, match="fingerprint"):
        svc2.restore(p)


def test_audit_rotation_bounded_and_parseable(tmp_path):
    path = tmp_path / "audit.jsonl"
    log = AuditLog(path, fsync=True, max_bytes=600, backups=2)
    for i in range(60):
        log.append("tick", i=i, payload="x" * 40)
    files = sorted(os.listdir(tmp_path))
    assert str(path.name) in files
    assert f"{path.name}.1" in files and f"{path.name}.2" in files
    assert f"{path.name}.3" not in files  # bounded retention
    for f in files:
        seqs = []
        with open(tmp_path / f) as fh:
            for line in fh:
                seqs.append(json.loads(line)["seq"])  # every line parses
        assert seqs == sorted(seqs)  # monotone within each file


def test_audit_seq_continues_after_crash(tmp_path):
    path = tmp_path / "audit.jsonl"
    log = AuditLog(path, fsync=True)
    for i in range(5):
        log.append("tick", i=i)
    del log  # crash
    log2 = AuditLog(path, fsync=True)
    log2.append("after")
    with open(path) as f:
        seqs = [json.loads(l)["seq"] for l in f]
    assert seqs == list(range(6))


def test_fast_chaos_drill(tmp_path):
    """Fixed mini drill: divergence on one rack + NaN corruption on
    another + a manual ESS trip on a third, injected between windows.
    The service must contain all three, keep every invariant, log entries
    and exits, and still produce a strict-JSON status.  (No stochastic
    fault schedule here: a scheduled ESS outage on the poisoned rack
    would — correctly — reset its warm state through the availability
    plane and mask the divergence; the randomized sweep covers those
    interleavings.)"""
    s = _scenario(duration_s=60.0, faulty=False)
    svc = _service(s, audit_path=tmp_path / "audit.jsonl")
    svc.advance()
    _assert_invariants(svc)
    svc.state = _poison_warm(svc.state, [1])
    svc.state = _corrupt_soc(svc.state, [3])
    svc.inject_fault(0, reason="drill")
    while not svc.exhausted:
        res = svc.advance()
        _assert_invariants(svc)
        assert np.all(np.isfinite(np.asarray(res.campus_grid)))
    sm = np.asarray(svc.state.safemode.quarantine_entries)
    assert int(sm[3]) >= 1
    assert int(np.asarray(svc.state.safemode.passthrough_entries)[1]) >= 1
    events = [e["event"] for e in svc.audit.tail(200)]
    assert "safemode_enter" in events and "safemode_exit" in events
    st = svc.status()
    assert st["safemode"]["quarantine_entries"] >= 1
    json.dumps(st, allow_nan=False)


# ------------------------------------------------------- randomized sweep


@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_kill_points_resume_bitwise(tmp_path, seed):
    """Kill at a random window (optionally tearing the newest checkpoint,
    as a crash mid-write under a pre-atomic writer would); recovery must
    land on a valid checkpoint and the glued outputs must equal the
    uninterrupted run bitwise."""
    rng = np.random.default_rng(1000 + seed)
    s = _scenario(duration_s=60.0, faulty=True, seed=int(rng.integers(100)))
    ref = _drain_by_window(_service(s))
    n_windows = len(ref)

    ck = tmp_path / f"ck{seed}"
    svc = _service(s, checkpoint_dir=ck, checkpoint_every=1)
    kill_at = int(rng.integers(1, n_windows))
    got = {}
    for _ in range(kill_at):
        start = svc.sample_pos
        got[start] = np.asarray(svc.advance().campus_grid)
    del svc  # kill

    ckpts = sorted(os.listdir(ck))
    if len(ckpts) >= 2 and rng.random() < 0.5:
        # Crash tore the newest checkpoint: recovery falls back to older.
        p = ck / ckpts[-1]
        p.write_bytes(p.read_bytes()[: int(rng.integers(1, 200))])

    svc2 = _service(s)
    assert svc2.recover(ck) is not None
    assert svc2.sample_pos <= kill_at * 4 * svc2._k
    got.update(_drain_by_window(svc2))
    assert got.keys() == ref.keys()
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k])


@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_fault_soup_holds_invariants(tmp_path, seed):
    """Random soup per seed: scheduled ESS trips + randomized divergence
    poison + NaN corruption injected at random window boundaries.  Every
    window must keep the state finite, SoC in the safe window, contained
    racks silent; the run must end with the audit log parseable."""
    rng = np.random.default_rng(2000 + seed)
    n_racks = int(rng.integers(4, 8))
    s = _scenario(
        duration_s=60.0, n_racks=n_racks, faulty=bool(rng.random() < 0.7),
        seed=int(rng.integers(100)),
    )
    svc = _service(s, audit_path=tmp_path / f"audit{seed}.jsonl")
    while not svc.exhausted:
        if rng.random() < 0.4:
            svc.state = _poison_warm(
                svc.state, [int(rng.integers(n_racks))],
                value=float(rng.choice([1e9, 1e12, np.inf])),
            )
        if rng.random() < 0.3:
            svc.state = _corrupt_soc(svc.state, [int(rng.integers(n_racks))])
        if rng.random() < 0.2:
            svc.inject_fault(int(rng.integers(n_racks)), reason="chaos")
        res = svc.advance()
        _assert_invariants(svc)
        assert np.all(np.isfinite(np.asarray(res.campus_grid)))
        assert np.all(np.isfinite(np.asarray(res.campus_rack)))
    with open(tmp_path / f"audit{seed}.jsonl") as f:
        seqs = [json.loads(l)["seq"] for l in f]
    assert seqs == sorted(seqs)
    json.dumps(svc.status(), allow_nan=False)
