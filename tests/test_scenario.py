"""Scenario-engine tests: legacy golden equivalence, chunk bit-identity,
model-derived workloads, and the heterogeneous mixed campus."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance, fleet, pdu
from repro.power import phases, scenario as SC, trace


SPECS = {
    "default": trace.TestbenchSpec(duration_s=66.0, sample_hz=500.0),
    "choukse": trace.choukse_spec(),
    "titanx": trace.titanx_spec(),
    "cluster_fault": trace.cluster_fault_spec(),
}


# ------------------------------------------------------ golden: legacy parity


@pytest.mark.parametrize("name", sorted(SPECS))
def test_render_matches_legacy_testbench(name):
    """The scenario-wrapped testbenches must reproduce the legacy host-side
    implementation to float32 tolerance (diff = summation order of the edge
    boxcar only)."""
    spec = SPECS[name]
    got, dt_g = trace.testbench_trace(spec, None)
    want, dt_w = trace.testbench_trace_reference(spec, None)
    assert dt_g == dt_w
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_render_matches_legacy_with_noise_key():
    """The wrapper keeps the legacy whole-trace noise draw bit-compatible."""
    spec = trace.choukse_spec()
    got, _ = trace.testbench_trace(spec, jax.random.key(0))
    want, _ = trace.testbench_trace_reference(spec, jax.random.key(0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_phase_timeline_matches_legacy():
    durs = np.array([0.5, 0.25, 1.0, 0.125])
    pows = np.array([1.0, 0.3, 0.9, 0.1], np.float32)
    got, _ = trace.phase_timeline_trace(durs, pows, 200.0, edge_time_s=0.1)
    want, _ = trace.phase_timeline_trace_reference(durs, pows, 200.0, edge_time_s=0.1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_training_timeline_matches_legacy_loop():
    """The vectorized timeline compiler must equal the original O(n_steps)
    Python-list construction exactly."""
    hw = phases.HardwareConstants(chips=8)
    cost = phases.StepCost(flops=1e15, hbm_bytes=1e12, collective_bytes=1e11)
    model = phases.PhaseModel(checkpoint_every_steps=5, checkpoint_stall_s=2.0)

    def legacy(n_steps, warmup_s, warmup_levels, end_idle_s):
        d = model.device
        p_idle = d.p_idle_w / d.p_peak_w
        durs, pows = [], []
        step_d, step_p = phases.step_phases(cost, hw, model)
        p_avg = float(np.sum(step_d * step_p) / np.sum(step_d))
        for i in range(warmup_levels):
            durs.append(warmup_s / warmup_levels)
            pows.append(p_idle + (p_avg - p_idle) * (i + 1) / warmup_levels)
        for s in range(n_steps):
            durs.extend(step_d.tolist())
            pows.extend(step_p.tolist())
            if model.checkpoint_every_steps and (s + 1) % model.checkpoint_every_steps == 0:
                durs.append(model.checkpoint_stall_s)
                pows.append(p_idle)
        durs.append(end_idle_s)
        pows.append(p_idle)
        return np.asarray(durs), np.asarray(pows, np.float32)

    for n_steps in (1, 5, 10, 17):
        d1, p1 = phases.training_timeline(cost, hw, model, n_steps,
                                          warmup_s=1.0, warmup_levels=3)
        d2, p2 = legacy(n_steps, 1.0, 3, 10.0)
        np.testing.assert_allclose(d1, d2, rtol=1e-12)
        np.testing.assert_allclose(p1, p2, rtol=1e-6)


def test_training_scenario_renders_like_phase_timeline():
    hw = phases.HardwareConstants(chips=8)
    cost = phases.StepCost(flops=1e15, hbm_bytes=1e12, collective_bytes=1e11)
    model = phases.PhaseModel(checkpoint_every_steps=4, checkpoint_stall_s=2.0)
    s = phases.training_scenario(cost, hw, model, 8, sample_hz=100.0)
    got, dt = SC.render_trace(s)
    durs, pows = phases.training_timeline(cost, hw, model, 8)
    want, _ = trace.phase_timeline_trace_reference(durs, pows, 100.0)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ------------------------------------------------------- chunk bit-identity


@pytest.mark.parametrize("chunk_n", [257, 1999])
def test_chunked_render_bit_identical_parametric(chunk_n):
    """Chunked rendering concatenated == whole-trace rendering, bit-for-bit
    (including counter-based noise)."""
    s = trace.scenario_from_testbench(trace.titanx_spec(), noise_seed=3)
    whole = SC.render(s, 0, s.total_samples)
    parts = [
        SC.render(s, t0, min(chunk_n, s.total_samples - t0))
        for t0 in range(0, s.total_samples, chunk_n)
    ]
    assert bool(jnp.all(jnp.concatenate(parts) == whole))


def test_chunked_render_bit_identical_segments():
    durs = np.array([0.5, 0.25, 1.0, 0.125, 2.0])
    pows = np.array([1.0, 0.3, 0.9, 0.1, 0.8], np.float32)
    s = SC.from_phase_timeline(durs, pows, 400.0, edge_time_s=0.1, noise_seed=7)
    whole = SC.render(s, 0, s.total_samples)
    parts = [
        SC.render(s, t0, min(301, s.total_samples - t0))
        for t0 in range(0, s.total_samples, 301)
    ]
    assert bool(jnp.all(jnp.concatenate(parts) == whole))


def test_segment_noise_seed_is_not_a_noop():
    """Segment-table scenarios must honor noise_seed (regression: the noise
    std used to be forced to 0 whenever params was None)."""
    durs = np.array([0.5, 0.5])
    pows = np.array([0.9, 0.3], np.float32)
    quiet = SC.from_phase_timeline(durs, pows, 200.0, edge_time_s=0.0)
    noisy = SC.from_phase_timeline(durs, pows, 200.0, edge_time_s=0.0, noise_seed=7)
    a = SC.render(quiet, 0, quiet.total_samples)
    b = SC.render(noisy, 0, noisy.total_samples)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-4
    assert float(jnp.std(b - a)) == pytest.approx(0.01, rel=0.2)


def test_never_dip_period_disables_dips():
    """dip_period_s=NEVER must fully disable dips (regression: mod(te, NEVER)
    == te used to fire a spurious dip for the first dip_duration_s)."""
    base = dict(warmup_s=0.0, noise_std=0.0, comm_fraction=0.0)
    no_dip = SC.make_scenario(
        SC.workload(dip_period_s=SC.NEVER, dip_duration_s=3.0, **base),
        duration_s=10.0, sample_hz=100.0, edge_time_s=0.0,
    )
    p = SC.render(no_dip, 0, no_dip.total_samples)
    # p_compute everywhere (sample 0 is the warmup ramp's t=0 idle point)
    assert float(jnp.min(p[1:])) == pytest.approx(0.92)


def test_batched_render_matches_per_rack_scalar_renders():
    """Heterogeneous fleets are just vmapped parameter pytrees: column r of
    the batched render equals the scalar render of rack r's params."""
    s = SC.mixed_campus(
        5, ("llama3_2_1b", "stablelm_12b"), duration_s=30.0, sample_hz=100.0, seed=1
    )
    batched = SC.render(s, 0, s.total_samples)
    assert batched.shape == (s.total_samples, 5)
    for r in (0, 3, 4):
        one = dataclasses.replace(
            s, params=jax.tree_util.tree_map(lambda x: x[r], s.params)
        )
        col = SC.render(one, 0, s.total_samples)
        assert bool(jnp.all(col == batched[:, r]))


# ------------------------------------------------- model-derived workloads


def test_workload_from_model_covers_all_archs():
    from repro.configs.registry import ARCH_IDS

    periods = {}
    for arch in ARCH_IDS:
        w = SC.workload_from_model(arch)
        period = float(w.iteration_period_s)
        assert 0.01 < period < 120.0
        assert 0.0 < float(w.comm_fraction) < 0.5
        assert float(w.p_comm) < float(w.p_compute) <= 1.0
        periods[arch] = round(period, 4)
    # the 10 assigned configs give genuinely heterogeneous workloads
    assert len(set(periods.values())) >= 8


def test_scenario_from_model_renders():
    s = SC.scenario_from_model("qwen1_5_4b", duration_s=30.0, sample_hz=100.0)
    p, dt = SC.render_trace(s)
    assert p.shape == (3000,)
    assert float(p.max()) > 0.9 and float(p.min()) < 0.5  # wave + warmup from idle


# ------------------------------------------------------------- mixed campus


def test_mixed_campus_structure():
    duration = 60.0
    s = SC.mixed_campus(
        12,
        ("llama3_2_1b", "deepseek_v3_671b"),
        duration_s=duration,
        sample_hz=100.0,
        seed=0,
        inference_fraction=0.25,
        stagger_s=20.0,
        fault_rack_fraction=0.25,
        fault_at_s=30.0,
        fault_duration_s=20.0,
    )
    p = np.asarray(SC.render(s, 0, s.total_samples))
    assert p.shape == (6000, 12)
    # staggered starts: racks are still idling at t=1s while others ramped
    starts = np.asarray(s.params.t_start_s)
    assert starts.std() > 1.0
    # fault cascade: faulted racks sit at ~p_fault inside their window
    fault_at = np.asarray(s.params.fault_at_s)
    faulted = np.where(fault_at < SC.NEVER / 2)[0]
    assert len(faulted) == 3
    for r in faulted:
        i = int((fault_at[r] + 1.0) * 100)
        assert p[i, r] == pytest.approx(float(s.params.p_fault[r]) * float(s.params.scale[r]), abs=1e-5)
    # cascade ripples: fault onsets differ across the faulted range
    assert fault_at[faulted].std() > 0.1
    # diurnal inference racks swing slowly: their envelope varies far more
    # over minutes than a training rack's mean power
    amp = np.asarray(s.params.diurnal_amp)
    inf_racks = np.where(amp > 0)[0]
    assert len(inf_racks) == 3


def test_mixed_campus_streams_end_to_end():
    """Acceptance path (scaled down): heterogeneous campus with staggered
    starts + fault cascade conditions through condition_fleet_streaming via
    the on-device scenario chunk provider and comes out grid-compliant."""
    hz = 200.0
    s = SC.mixed_campus(
        8,
        ("llama3_2_1b", "chatglm3_6b", "whisper_large_v3"),
        duration_s=60.0,
        sample_hz=hz,
        seed=2,
        fault_rack_fraction=0.25,
        fault_at_s=35.0,
        noise_seed=7,
    )
    cfg = pdu.make_pdu(sample_dt=1.0 / hz)
    spec = compliance.GridSpec.create()
    res = fleet.condition_scenario_streaming(cfg, s, spec, qp_iters=20, chunk_intervals=4)
    assert res.campus_grid.shape == (s.total_samples,)
    assert not bool(res.report_rack.ramp_ok)  # raw campus violates beta
    assert bool(res.report_grid.ramp_ok)  # conditioned campus complies
    assert bool(res.report_grid.ok)


def test_condition_scenario_streaming_checks_sample_rate():
    s = SC.scenario_from_model("llama3_2_1b", duration_s=10.0, sample_hz=100.0)
    cfg = pdu.make_pdu(sample_dt=1e-3)
    with pytest.raises(ValueError, match="sample rate"):
        fleet.condition_scenario_streaming(cfg, s, compliance.GridSpec.create())


# ------------------------------------------------ streaming ragged-chunk fix


@pytest.mark.parametrize("duration_s", [32.5, 37.3])
def test_streaming_ragged_final_chunk_matches_one_shot(duration_s):
    """ZOH-padding the trailing partial chunk (so `step` compiles once) must
    not change the campus waveform — including when the tail is shorter
    than one controller interval (32.5 s case: final chunk is 500 samples
    against k = 1000)."""
    hz = 200.0
    sp = trace.TestbenchSpec(duration_s=duration_s, sample_hz=hz)
    t1, dt = trace.testbench_trace(sp, jax.random.key(5))
    traces = fleet.staggered_fleet(t1, 4, jax.random.key(6), max_offset_samples=300)
    cfg = pdu.make_pdu(sample_dt=dt)
    spec = compliance.GridSpec.create()
    full = fleet.condition_fleet(cfg, traces, spec, qp_iters=20)
    stream = fleet.condition_fleet_streaming(
        cfg, traces, spec, qp_iters=20, chunk_intervals=3
    )
    t_total = traces.shape[0]
    assert stream.campus_grid.shape == (t_total,)
    k = int(round(float(cfg.controller.dt) / dt))
    assert stream.soc_mean.shape == (-(-t_total // k),)
    np.testing.assert_allclose(
        np.asarray(stream.campus_grid), np.asarray(full.campus_grid), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(stream.campus_rack), np.asarray(full.campus_rack), atol=1e-6
    )


# ------------------------------------------------------- PowerSim satellites


def test_powersim_config_device_is_a_real_field():
    """`device` was a shared class attribute (no annotation); it must be a
    proper per-instance dataclass field threaded into phase rendering."""
    from repro.power.device import TITAN_X
    from repro.power.integration import PowerSim, PowerSimConfig

    names = {f.name for f in dataclasses.fields(PowerSimConfig)}
    assert "device" in names
    c1 = PowerSimConfig(device=TITAN_X)
    c2 = PowerSimConfig()
    assert c1.device is TITAN_X and c2.device is None

    cost = phases.StepCost(flops=5e18, hbm_bytes=2e15, collective_bytes=5e14)
    sim = PowerSim(cost, phases.HardwareConstants(), phases.PhaseModel(), c1)
    assert sim.model.device is TITAN_X  # threaded into phase rendering


def test_powersim_consumes_scenario_chunks():
    cost = phases.StepCost(flops=5e17, hbm_bytes=2e14, collective_bytes=5e13)
    from repro.power.integration import PowerSim

    sim = PowerSim(cost, phases.HardwareConstants(), phases.PhaseModel(checkpoint_every_steps=0))
    for _ in range(6):
        sim.on_step()
    rep = sim.report()
    assert rep["grid_max_ramp"] <= 0.1 + 1e-3
    assert rep["rack_max_ramp"] > rep["grid_max_ramp"]
