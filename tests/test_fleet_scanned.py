"""Engine-equivalence tests: scanned vs host-loop vs one-shot (ISSUE 4).

The scanned engine (`fleet.condition_scenario_scanned`) fuses on-device
chunk rendering and the chunk loop into one `lax.scan`-ned jit; the
host-loop engine walks the same chunks from Python; `condition_fleet` is
the one-shot whole-trace oracle.  All three share `pdu.condition_campus`,
so their per-chunk arithmetic is identical by construction.

Tolerance contract: XLA CPU contracts mul+add chains into FMAs differently
depending on the fusion context, so quantities that pass through the LC
filter recurrence (`campus_grid`, filter / warm-QP state) may differ by a
few ulps between the engines' separately compiled programs.  Aggregates
that do not touch the filter chain (`campus_rack`, `soc_mean`) must match
bit-for-bit, and everything else must agree to ~1e-6 absolute.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compliance, fleet, pdu
from repro.power import scenario as SC

_ULP = 1e-6  # few-ulp FMA-contraction slack for filter-chain outputs
_SPEC = compliance.GridSpec.create()
_HZ = 200.0


def _campus(n_racks=6, duration_s=44.0, seed=2, noise_seed=7):
    return SC.mixed_campus(
        n_racks,
        ("llama3_2_1b", "whisper_large_v3"),
        duration_s=duration_s,
        sample_hz=_HZ,
        seed=seed,
        fault_at_s=duration_s * 0.6,
        noise_seed=noise_seed,
    )


def _cfg():
    return pdu.make_pdu(sample_dt=1.0 / _HZ)


def _assert_results_match(a, b, *, grid_atol=_ULP):
    np.testing.assert_array_equal(np.asarray(a.campus_rack), np.asarray(b.campus_rack))
    np.testing.assert_array_equal(np.asarray(a.soc_mean), np.asarray(b.soc_mean))
    np.testing.assert_allclose(
        np.asarray(a.campus_grid), np.asarray(b.campus_grid), atol=grid_atol
    )
    np.testing.assert_allclose(
        np.asarray(a.max_qp_residual), np.asarray(b.max_qp_residual), atol=grid_atol
    )


def _assert_states_match(sa, sb, *, atol=_ULP):
    for la, lb in zip(jax.tree_util.tree_leaves(sa), jax.tree_util.tree_leaves(sb)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol)


def test_scanned_matches_host_loop():
    """Same scenario, same chunking: the one-dispatch scanned engine must
    reproduce the per-chunk host loop — including the final PDUState, so
    either engine's stream can be resumed by the other."""
    s = _campus()
    cfg = _cfg()
    a = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=20, chunk_intervals=2)
    b = fleet.condition_scenario_streaming(
        cfg, s, _SPEC, engine="host", qp_iters=20, chunk_intervals=2
    )
    assert a.campus_grid.shape == (s.total_samples,)
    _assert_results_match(a, b)
    _assert_states_match(a.state, b.state)
    assert bool(a.report_grid.ramp_ok)


@pytest.mark.slow
def test_scanned_matches_one_shot_condition_fleet():
    """Chunked-with-carried-warm-state == one whole-trace call at equal
    qp_iters (the PR-1 streaming contract, now via the scanned engine)."""
    s = _campus()
    cfg = _cfg()
    a = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=20, chunk_intervals=2)
    full = SC.render(s, 0, s.total_samples)
    res = fleet.condition_fleet(cfg, full, _SPEC, qp_iters=20)
    np.testing.assert_array_equal(np.asarray(a.campus_rack), np.asarray(res.campus_rack))
    np.testing.assert_allclose(
        np.asarray(a.campus_grid), np.asarray(res.campus_grid), atol=1e-5
    )
    # and the states match the one-shot pdu-level call
    st0 = pdu.init_state(cfg, full[0])
    _, st_f, _ = pdu.condition(cfg, st0, full, qp_iters=20)
    _assert_states_match(a.state, st_f, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("duration_s", [32.5, 37.3])
def test_scanned_ragged_final_chunk(duration_s):
    """The epilogue step's static-index ZOH pad must reproduce the host
    loop's explicit pad — including a tail shorter than one controller
    interval (32.5 s: 500-sample tail against k = 1000)."""
    s = _campus(n_racks=4, duration_s=duration_s)
    cfg = _cfg()
    a = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=15, chunk_intervals=2)
    b = fleet.condition_scenario_streaming(
        cfg, s, _SPEC, engine="host", qp_iters=15, chunk_intervals=2
    )
    k = int(round(float(cfg.controller.dt) * _HZ))
    assert a.campus_grid.shape == (s.total_samples,)
    assert a.soc_mean.shape == (-(-s.total_samples // k),)
    _assert_results_match(a, b)
    _assert_states_match(a.state, b.state)


@pytest.mark.slow
def test_scanned_chunk_intervals_invariance():
    """The warm ADMM state rides in PDUState across chunk boundaries, so
    the chunk size must not change the result."""
    s = _campus(n_racks=4)
    cfg = _cfg()
    a = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=15, chunk_intervals=2)
    b = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=15, chunk_intervals=4)
    _assert_results_match(a, b)
    _assert_states_match(a.state, b.state)


def test_scanned_resume_from_returned_state():
    """Splitting a scenario at a chunk boundary and resuming from the
    returned state must reproduce the unsplit run."""
    s = _campus(n_racks=4)
    cfg = _cfg()
    full = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=15, chunk_intervals=2)
    k = int(round(float(cfg.controller.dt) * _HZ))
    t_cut = 2 * 2 * k  # two chunks
    first = fleet.condition_scenario_scanned(
        cfg, s, _SPEC, qp_iters=15, chunk_intervals=2, stop_sample=t_cut
    )
    rest = fleet.condition_scenario_scanned(
        cfg, s, _SPEC, qp_iters=15, chunk_intervals=2,
        state=first.state, start_sample=t_cut,
    )
    assert rest.campus_grid.shape == (s.total_samples - t_cut,)
    glued = np.concatenate([np.asarray(first.campus_rack), np.asarray(rest.campus_rack)])
    np.testing.assert_array_equal(glued, np.asarray(full.campus_rack))
    glued = np.concatenate([np.asarray(first.campus_grid), np.asarray(rest.campus_grid)])
    np.testing.assert_allclose(glued, np.asarray(full.campus_grid), atol=_ULP)
    glued = np.concatenate([np.asarray(first.soc_mean), np.asarray(rest.soc_mean)])
    np.testing.assert_allclose(glued, np.asarray(full.soc_mean), atol=_ULP)
    _assert_states_match(rest.state, full.state)


def test_scanned_resume_past_end_raises():
    s = _campus(n_racks=2, duration_s=20.0)
    with pytest.raises(ValueError, match="past the scenario end"):
        fleet.condition_scenario_scanned(
            _cfg(), s, _SPEC, start_sample=s.total_samples
        )


def test_scanned_start_sample_must_be_interval_aligned():
    s = _campus(n_racks=2, duration_s=20.0)
    cfg = _cfg()
    for bad in (-1000, 137):  # negative, and not a multiple of k=1000
        with pytest.raises(ValueError, match="multiple of the controller interval"):
            fleet.condition_scenario_scanned(cfg, s, _SPEC, start_sample=bad)


def test_resume_state_is_not_consumed():
    """The engines donate their state argument internally, but a caller's
    checkpoint must survive to seed several continuations."""
    s = _campus(n_racks=2, duration_s=30.0)
    cfg = _cfg()
    k = int(round(float(cfg.controller.dt) * _HZ))
    first = fleet.condition_scenario_scanned(
        cfg, s, _SPEC, qp_iters=10, chunk_intervals=2, stop_sample=2 * k
    )
    a = fleet.condition_scenario_scanned(
        cfg, s, _SPEC, qp_iters=10, chunk_intervals=2,
        state=first.state, start_sample=2 * k,
    )
    b = fleet.condition_scenario_scanned(  # same checkpoint, second use
        cfg, s, _SPEC, qp_iters=10, chunk_intervals=2,
        state=first.state, start_sample=2 * k,
    )
    np.testing.assert_array_equal(np.asarray(a.campus_grid), np.asarray(b.campus_grid))
    # host-loop path: same contract
    tr = SC.render(s, 0, s.total_samples)
    h1 = fleet.condition_fleet_streaming(cfg, tr[: 2 * k], _SPEC, qp_iters=10)
    h2 = fleet.condition_fleet_streaming(cfg, tr[2 * k :], _SPEC, qp_iters=10, state=h1.state)
    h3 = fleet.condition_fleet_streaming(cfg, tr[2 * k :], _SPEC, qp_iters=10, state=h1.state)
    np.testing.assert_array_equal(np.asarray(h2.campus_grid), np.asarray(h3.campus_grid))


def test_scanned_unbatched_scenario_lifts_to_one_rack():
    s = SC.scenario_from_model("llama3_2_1b", duration_s=20.0, sample_hz=_HZ)
    res = fleet.condition_scenario_scanned(_cfg(), s, _SPEC, qp_iters=10)
    assert res.campus_grid.shape == (s.total_samples,)
    assert np.all(np.isfinite(np.asarray(res.campus_grid)))


def test_condition_scenario_streaming_rejects_unknown_engine():
    s = _campus(n_racks=2, duration_s=20.0)
    with pytest.raises(ValueError, match="unknown engine"):
        fleet.condition_scenario_streaming(_cfg(), s, _SPEC, engine="warp")


def test_condition_campus_is_the_reduced_condition():
    """pdu.condition_campus == pdu.condition + campus reductions."""
    cfg = _cfg()
    key = jax.random.key(0)
    tr = 0.5 + 0.3 * jax.random.uniform(key, (2400, 3))
    st = pdu.init_state(cfg, tr[0])
    st_a, ch = pdu.condition_campus(cfg, st, tr, qp_iters=10)
    st_b = pdu.init_state(cfg, tr[0])
    grid, st_b, telem = pdu.condition(cfg, st_b, tr, qp_iters=10)
    np.testing.assert_array_equal(np.asarray(ch.campus_rack), np.asarray(jnp.mean(tr, axis=1)))
    np.testing.assert_allclose(
        np.asarray(ch.campus_grid), np.asarray(jnp.mean(grid, axis=1)), atol=_ULP
    )
    np.testing.assert_allclose(
        np.asarray(ch.soc_mean), np.asarray(jnp.mean(telem.soc, axis=1)), atol=_ULP
    )
    assert ch.max_qp_residual.shape == ()


def test_render_padded_holds_final_sample():
    """In-range samples bit-match `render`; past-the-end rows hold the last
    in-range sample (the streaming engines' ZOH pad)."""
    s = _campus(n_racks=3, duration_s=10.0)
    t = s.total_samples
    chunk = 512
    t0 = t - 100  # 100 real samples, 412 pad rows
    padded = SC.render_padded(s, t0, chunk)
    plain = SC.render(s, t0, 100)
    np.testing.assert_array_equal(np.asarray(padded[:100]), np.asarray(plain))
    np.testing.assert_array_equal(
        np.asarray(padded[100:]),
        np.broadcast_to(np.asarray(plain[-1:]), (chunk - 100,) + plain.shape[1:]),
    )
    # traced t0 (the in-scan case) agrees with the static call (up to
    # FMA-contraction ulps: the wrapping jit compiles a different fusion)
    traced = jax.jit(lambda i: SC.render_padded(s, i, chunk))(jnp.int32(t0))
    np.testing.assert_allclose(np.asarray(traced), np.asarray(padded), atol=1e-7)


def test_chunk_count():
    s = _campus(n_racks=2, duration_s=10.0)  # 2000 samples
    assert SC.chunk_count(s, 500) == 4
    assert SC.chunk_count(s, 600) == 4
    assert SC.chunk_count(s, 2000) == 1
    with pytest.raises(ValueError):
        SC.chunk_count(s, 0)


def test_make_condition_step_is_cached_per_config():
    cfg = _cfg()
    a = fleet.make_condition_step(cfg, qp_iters=25)
    b = fleet.make_condition_step(pdu.make_pdu(sample_dt=1.0 / _HZ), qp_iters=25)
    c = fleet.make_condition_step(cfg, qp_iters=30)
    assert a is b  # equal config values -> same cached step
    assert a is not c


def test_shard_racks_in_jit_single_device_is_noop():
    """On a 1-device mesh the in-jit sharding constraint must not change
    the result (matches `rules.constrain_to_mesh`'s guard)."""
    from repro.sharding.rules import make_mesh

    s = _campus(n_racks=4, duration_s=20.0)
    cfg = _cfg()
    mesh = make_mesh((1,), ("data",))
    a = fleet.condition_scenario_scanned(
        cfg, s, _SPEC, qp_iters=10, chunk_intervals=2, mesh=mesh
    )
    b = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=10, chunk_intervals=2)
    np.testing.assert_array_equal(np.asarray(a.campus_rack), np.asarray(b.campus_rack))
    np.testing.assert_allclose(
        np.asarray(a.campus_grid), np.asarray(b.campus_grid), atol=_ULP
    )


# ----------------------------------------------- degraded mode (ISSUE 6)


def _faulty_campus(n_racks=5, duration_s=40.0, seed=2):
    from repro.power import faults as FLT

    s = _campus(n_racks=n_racks, duration_s=duration_s, seed=seed)
    proc = FLT.FaultProcess.create(
        rack_mtbf_s=30.0, rack_mttr_s=10.0,
        ess_mtbf_s=25.0, ess_mttr_s=8.0,
        sensor_mtbf_s=20.0, sensor_mttr_s=4.0,
    )
    return SC.attach_faults(s, proc, seed=13)


def _deg_cfg():
    return pdu.make_pdu(sample_dt=1.0 / _HZ, degraded_mode=True)


def test_faulty_scenario_requires_degraded_mode():
    s = _faulty_campus()
    with pytest.raises(ValueError):
        fleet.condition_scenario_scanned(_cfg(), s, _SPEC)
    with pytest.raises(ValueError):
        fleet.condition_scenario_streaming(_cfg(), s, _SPEC, engine="host")


@pytest.mark.slow
def test_degraded_engines_match_under_stochastic_schedule():
    """scanned == host == one-shot under a stochastic fault schedule, to
    the repo's standing tolerance contract (rack/soc/mask aggregates
    bitwise; filter-chain outputs within FMA-contraction slack)."""
    from repro.power import faults as FLT

    s = _faulty_campus()
    cfg = _deg_cfg()
    a = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=20, chunk_intervals=2)
    b = fleet.condition_scenario_streaming(
        cfg, s, _SPEC, engine="host", qp_iters=20, chunk_intervals=2
    )
    _assert_results_match(a, b)
    np.testing.assert_array_equal(
        np.asarray(a.ess_online_frac), np.asarray(b.ess_online_frac)
    )
    _assert_states_match(a.state, b.state)

    k = int(round(float(cfg.controller.dt) * _HZ))
    n_ctrl = -(-s.total_samples // k)
    on = FLT.interval_online(s.faults, 0, n_ctrl, k)
    wt = FLT.ess_weight(s.faults, 0, s.total_samples, s.edge_width)
    tr = SC.render(s, 0, s.total_samples)
    res = fleet.condition_fleet(
        cfg, tr, _SPEC, qp_iters=20, ess_online=on, ess_weight=wt
    )
    np.testing.assert_array_equal(
        np.asarray(a.campus_rack), np.asarray(res.campus_rack)
    )
    np.testing.assert_array_equal(
        np.asarray(a.ess_online_frac), np.asarray(res.ess_online_frac)
    )
    np.testing.assert_allclose(
        np.asarray(a.campus_grid), np.asarray(res.campus_grid), atol=1e-5
    )
    # masks really tripped something, and every output stayed finite
    assert float(np.asarray(a.ess_online_frac).min()) < 1.0
    assert np.all(np.isfinite(np.asarray(a.campus_grid)))
    assert np.all(np.isfinite(np.asarray(a.campus_rack)))


def test_degraded_fault_on_chunk_boundary():
    """A deterministic ESS outage whose edges land exactly on chunk
    boundaries must render identically at any chunking."""
    from repro.power import faults as FLT

    s = _campus(n_racks=4, duration_s=24.0)
    k = int(round(5.0 * _HZ))  # controller interval in samples
    chunk = 2 * k
    sched = FLT.schedule_from_episodes(
        4, ess=[(1, chunk, 2 * chunk), (2, 2 * chunk, 3 * chunk)],
        sensor=[(3, chunk, chunk + k)],
    )
    s = SC.attach_faults(s, sched)
    cfg = _deg_cfg()
    a = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=15, chunk_intervals=2)
    b = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=15, chunk_intervals=4)
    _assert_results_match(a, b)
    np.testing.assert_array_equal(
        np.asarray(a.ess_online_frac), np.asarray(b.ess_online_frac)
    )
    # the scheduled outage shows in the mask at exactly the right intervals:
    # interval 2 has rack 1's ESS tripped AND rack 3 measurement-blind
    # (finite-guard), then rack 1 alone, then rack 2 alone.
    np.testing.assert_array_equal(
        np.asarray(a.ess_online_frac), [1.0, 1.0, 0.5, 0.75, 0.75]
    )


@pytest.mark.slow
def test_degraded_resume_mid_outage():
    """Stop/resume inside an active fault episode: the glued stream must be
    bitwise identical to the uninterrupted run (mask and bridge state are
    pure in the absolute sample index; last_good rides in PDUState)."""
    s = _faulty_campus()
    cfg = _deg_cfg()
    k = int(round(float(cfg.controller.dt) * _HZ))
    full = fleet.condition_scenario_scanned(cfg, s, _SPEC, qp_iters=20, chunk_intervals=2)
    cut = 4 * k  # resume point: interval-aligned, inside the fault soup
    a = fleet.condition_scenario_scanned(
        cfg, s, _SPEC, qp_iters=20, chunk_intervals=2, stop_sample=cut
    )
    b = fleet.condition_scenario_scanned(
        cfg, s, _SPEC, qp_iters=20, chunk_intervals=2,
        state=a.state, start_sample=cut,
    )
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(a.campus_rack), np.asarray(b.campus_rack)]),
        np.asarray(full.campus_rack),
    )
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(a.ess_online_frac), np.asarray(b.ess_online_frac)]),
        np.asarray(full.ess_online_frac),
    )
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(a.soc_mean), np.asarray(b.soc_mean)]),
        np.asarray(full.soc_mean),
    )


def test_fleet_summary_json_safe_round_trip():
    """An untracked config's infinite projected life must JSON-serialize
    under allow_nan=False once clamped."""
    import json

    from repro.core import health as hlt

    s = _campus(n_racks=3, duration_s=20.0)
    cfg = _cfg()  # track_health off -> empty history -> inf lifetime
    tr = SC.render(s, 0, s.total_samples)
    res = fleet.condition_fleet(cfg, tr, _SPEC, qp_iters=10)
    raw = hlt.fleet_summary(res.health)
    assert raw["projected_life_years_min"] == float("inf")
    with pytest.raises(ValueError):
        json.dumps(raw, allow_nan=False)
    safe = hlt.fleet_summary(res.health, json_safe=True)
    assert safe["projected_life_years_min"] is None
    assert json.loads(json.dumps(safe, allow_nan=False)) == safe
