"""Input-filter (paper §5.1/§5.4) unit tests: exact discretization,
analytic Bode agreement, -40 dB/dec rolloff, damping, DC transparency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import filters, sizing


@pytest.fixture(scope="module")
def proto():
    return sizing.prototype_filter()


def _per_unit(p_phys, rack):
    from repro.core.pdu import per_unit_filter

    s = sizing.size_system(rack, beta=0.1)
    return per_unit_filter(s, rack)


def test_cutoff_frequency(proto):
    assert float(proto.cutoff_hz()) == pytest.approx(4.0, rel=1e-3)


def test_dc_gain_unity(proto):
    mag = filters.transfer_function_rack_to_grid(proto, jnp.asarray(1e-3))
    assert float(mag) == pytest.approx(1.0, abs=1e-4)


def test_rolloff_40db_per_decade(proto):
    """Paper §5.4: attenuation by up to 100x per 10x frequency above f_f."""
    f = jnp.array([40.0, 400.0, 4000.0])
    m = np.asarray(filters.transfer_function_rack_to_grid(proto, f))
    assert m[0] / m[1] == pytest.approx(100.0, rel=0.05)
    assert m[1] / m[2] == pytest.approx(100.0, rel=0.05)


def test_paper_example_1000x_at_1khz(proto):
    """Paper §5.4: 'a fluctuation at f = 1000 Hz will be cut by a factor of
    ~1000' (with f_f ~ 4 Hz the ideal asymptote gives a bit more; we check
    the attenuation is at least 1000x)."""
    m = float(filters.transfer_function_rack_to_grid(proto, jnp.asarray(1000.0)))
    assert m < 1e-3


def test_1hz_not_dampened(proto):
    """Paper §5.4: 'a sinusoidal change ... with f = 1 Hz will not be
    dampened at all by the input filter'."""
    m = float(filters.transfer_function_rack_to_grid(proto, jnp.asarray(1.0)))
    assert 0.8 < m < 1.3


def test_damping_bounds_resonant_peak(proto):
    peak_db = float(filters.resonance_peak_db(proto))
    assert peak_db < 7.0  # damped: no runaway resonance


def test_undamped_filter_rings():
    """Without the damping leg the resonance is essentially unbounded."""
    p = sizing.prototype_filter()
    undamped = filters.LCFilterParams.create(
        l_f=float(p.l_f), c_f=float(p.c_f), r_da=1e9, l_da=float(p.l_da)
    )
    assert float(filters.resonance_peak_db(undamped)) > 20.0


@pytest.mark.parametrize("f_test", [0.5, 2.0, 10.0])
def test_discrete_sim_matches_analytic_bode(proto, f_test):
    dt = 1e-3
    filt = filters.make_discrete_filter(proto, dt)
    n = int(round(40 / f_test / dt))
    t = jnp.arange(n) * dt
    iload = 0.5 + 0.1 * jnp.sin(2 * jnp.pi * f_test * t)
    u = jnp.stack([jnp.ones_like(iload), iload], -1)
    x0 = filters.steady_state(filt, jnp.array([1.0, 0.5]))
    y, _ = filters.simulate(filt, x0, u)
    y = np.asarray(y[n // 2 :, 0])
    gain = (y.max() - y.min()) / 2.0 / 0.1
    ana = float(filters.transfer_function_rack_to_grid(proto, jnp.asarray(f_test)))
    assert gain == pytest.approx(ana, rel=0.02)


def test_steady_state_passes_load(proto):
    """At steady state the grid supplies exactly the load (lossless filter)."""
    filt = filters.make_discrete_filter(proto, 1e-3)
    x = filters.steady_state(filt, jnp.array([1.0, 0.7]))
    y = x @ filt.c.T
    assert float(y[0]) == pytest.approx(0.7, abs=1e-5)


def test_zoh_exactness_across_sample_rates(proto):
    """The discretization is exact: halving dt must not change the sampled
    trajectory at common timestamps (up to float32 accumulation)."""
    f1 = filters.make_discrete_filter(proto, 2e-3)
    f2 = filters.make_discrete_filter(proto, 1e-3)
    # ZOH-hold input constant per 2 ms so both grids see identical u(t).
    key = jax.random.key(0)
    steps = 400
    u_coarse = jax.random.uniform(key, (steps,)) * 0.5 + 0.4
    u1 = jnp.stack([jnp.ones_like(u_coarse), u_coarse], -1)
    u_fine = jnp.repeat(u_coarse, 2)
    u2 = jnp.stack([jnp.ones_like(u_fine), u_fine], -1)
    x0 = filters.steady_state(f1, jnp.array([1.0, float(u_coarse[0])]))
    y1, _ = filters.simulate(f1, x0, u1)
    y2, _ = filters.simulate(f2, x0, u2)
    np.testing.assert_allclose(np.asarray(y1[:, 0]), np.asarray(y2[::2, 0]), atol=2e-4)


def test_simulate_broadcasts_over_racks(proto):
    filt = filters.make_discrete_filter(proto, 1e-3)
    racks = 5
    u = jnp.ones((100, racks, 2)) * jnp.array([1.0, 0.6])
    x0 = jnp.tile(filters.steady_state(filt, jnp.array([1.0, 0.6])), (racks, 1))
    y, xf = filters.simulate(filt, x0, u)
    assert y.shape == (100, racks, 1)
    np.testing.assert_allclose(np.asarray(y[-1, :, 0]), 0.6, atol=1e-4)
