"""Reproduce the paper's evaluation figures numerically (Figs. 7/9/10/11/12/13)
and run a heterogeneous mixed campus through the streaming conditioner.

    PYTHONPATH=src python examples/power_conditioning.py

Prints the headline number for each figure next to the paper's claim.  All
traces come from the declarative scenario engine (`repro.power.scenario`):
the figure testbenches compile to parametric workload IR via
``trace.scenario_from_testbench``, and the mixed campus is a per-rack
parameter batch (different model workloads, staggered starts, an
inference-diurnal block, a fault cascade) rendered on-device chunk by chunk
— the (T, R) campus trace is never materialized on the host.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import burn, compliance, controller as ctrl, ess, fleet, pdu
from repro.power import scenario as SC, trace


def fig9_fig10():
    spec = compliance.GridSpec.create()
    cfg = pdu.make_pdu(sample_dt=1e-3)
    # the legacy testbench call is now a thin wrapper over the scenario IR
    scen = trace.scenario_from_testbench(trace.choukse_spec(), noise_seed=0)
    rack, dt = SC.render_trace(scen)
    st = pdu.init_state(cfg, rack[0])
    grid, _, _ = pdu.condition(cfg, st, rack, qp_iters=40)
    b = compliance.check(rack, dt, spec)
    a = compliance.check(grid, dt, spec)
    print(f"[Fig 9 ] ramp: rack {float(b.max_ramp):7.2f}/s -> grid "
          f"{float(a.max_ramp):7.4f}/s   (spec beta=0.1, paper: within +/-10%)")
    print(f"[Fig 10] S(f>=2Hz): rack {float(b.worst_high_freq_mag):.2e} -> grid "
          f"{float(a.worst_high_freq_mag):.2e} (spec alpha=1e-4)")


def fig7():
    cfg = pdu.make_pdu()
    for f, what in [(0.001, "passband"), (1.0, "ESS band"), (100.0, "LC band")]:
        h = float(pdu.combined_transfer_function(cfg, jnp.asarray(f)))
        print(f"[Fig 7 ] |H({f:7.3f} Hz)| = {h:.2e}  ({what})")


def fig11():
    tb, dt = trace.titanx_testbench(jax.random.key(2))
    cal = burn.calibrate(jax.random.key(3), p_idle=0.06, p_peak=1.0)
    sched = burn.burn_schedule(tb, dt, beta=0.1, cal=cal)
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, tb[0])
    gez, _, telem = pdu.condition(cfg, st, tb, qp_iters=40)
    soc = np.asarray(telem.soc)
    nwarm = sched.conditioned.shape[0] - tb.shape[0]
    cmp = burn.compare_energy(
        tb, gez, sched.conditioned[nwarm:], dt,
        soc_delta=float(soc[-1]) - 0.5, q_max_seconds=float(cfg.ess_params.q_max))
    print(f"[Fig 11] software burn uses {float(cmp['burn_vs_easyrider_frac'])*100:.1f}% "
          f"more energy than rack+EasyRider (paper: 19%)")


def fig12():
    cfg = ctrl.ControllerConfig.create(i_max=4e-3)
    es = ess.ESSParams.create(q_max_seconds=40.0)
    out = ctrl.simulate_soc_management(cfg, es, 0.62, n_steps=400, qp_iters=80)
    soc = np.asarray(out["soc"])
    hit = int(np.argmax(np.abs(soc - 0.5) <= float(cfg.deadband)))
    print(f"[Fig 12] SoC 0.62 -> {soc[-1]:.3f} in {hit*5/60:.1f} min "
          f"(paper: converges to S_mid=0.5 in ~20 min), monotone={bool(np.all(np.diff(soc[:hit+1])<=1e-4))}")


def fig13():
    rack, dt = trace.cluster_fault_trace(jax.random.key(4))
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, rack[0])
    grid, _, _ = pdu.condition(cfg, st, rack, qp_iters=20)
    w = max(int(0.2 / dt), 1)
    rr = float(jnp.max(jnp.abs(rack[w:] - rack[:-w]))) / 0.2 * 40
    rg = float(compliance.max_abs_ramp(grid, dt)) * 40
    print(f"[Fig 13] 40 MW cluster fault: unconditioned {rr:6.1f} MW/s "
          f"(paper: 193.7) -> conditioned {rg:.2f} MW/s (limit 4.0)")


def mixed_campus():
    """Beyond the paper: a heterogeneous campus as one declarative scenario.

    64 racks: three assigned-model training workloads (each rack's
    compute/communicate wave derived from its model's step cost) plus an
    inference block riding a diurnal envelope, staggered job starts, a few
    early terminations, and a mid-trace fault cascade.  Conditioned by the
    scanned streaming engine (the default): chunk rendering and the chunk
    loop are fused into one ``lax.scan``-ned jit, so the whole campus
    trace is synthesized and conditioned in a single dispatch — with the
    battery wear state machine (``core.health``) and the streaming
    compliance observers (cross-chunk ramp + Goertzel line bank) riding
    inside the same jit."""
    from repro.core import health as hlt

    hz = 200.0
    archs = ("llama3_2_1b", "deepseek_v3_671b", "whisper_large_v3")
    scen = SC.mixed_campus(
        64, archs, duration_s=120.0, sample_hz=hz, seed=0,
        inference_fraction=0.25, stagger_s=20.0,
        fault_rack_fraction=0.1, fault_at_s=70.0, noise_seed=1,
    )
    cfg = pdu.make_pdu(sample_dt=1.0 / hz, track_health=True)
    spec = compliance.GridSpec.create()
    res = fleet.condition_scenario_streaming(cfg, scen, spec, qp_iters=30,
                                             chunk_intervals=4)
    print(f"[Campus] 64 racks x {{{', '.join(archs)}, inference-diurnal}}: "
          f"raw ramp {float(res.report_rack.max_ramp):.2f}/s "
          f"(ok={bool(res.report_rack.ramp_ok)}) -> conditioned "
          f"{float(res.report_grid.max_ramp):.4f}/s "
          f"(ok={bool(res.report_grid.ramp_ok)}, beta=0.1)")
    g = res.report_grid
    print(f"[Campus] streaming compliance verdict: ramp_ok={bool(g.ramp_ok)} "
          f"spec_lines_ok={bool(g.spectrum_ok)} "
          f"(worst S(f>=2Hz)={float(g.worst_high_freq_mag):.2e} vs alpha=1e-4) "
          f"-> ok={bool(g.ok)}")
    h = hlt.fleet_summary(res.health)
    print(f"[Campus] fleet battery health over {scen.duration_s:.0f}s: "
          f"EFC mean {h['efc_mean']:.3f} / max {h['efc_max']:.3f}, "
          f"worst-rack DoD {h['worst_dod']:.3f}, "
          f"fade max {h['fade_max']:.2e}, "
          f"projected life >= {h['projected_life_years_min']:.1f} y")


if __name__ == "__main__":
    fig7()
    fig9_fig10()
    fig11()
    fig12()
    fig13()
    mixed_campus()
