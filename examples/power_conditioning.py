"""Reproduce the paper's evaluation figures numerically (Figs. 7/9/10/11/12/13)
and run a heterogeneous mixed campus through the streaming conditioner.

    PYTHONPATH=src python examples/power_conditioning.py

Prints the headline number for each figure next to the paper's claim.  All
traces come from the declarative scenario engine (`repro.power.scenario`):
the figure testbenches compile to parametric workload IR via
``trace.scenario_from_testbench``, and the mixed campus is a per-rack
parameter batch (different model workloads, staggered starts, an
inference-diurnal block, a fault cascade) rendered on-device chunk by chunk
— the (T, R) campus trace is never materialized on the host.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import burn, compliance, controller as ctrl, ess, fleet, pdu
from repro.power import scenario as SC, trace


def fig9_fig10():
    spec = compliance.GridSpec.create()
    cfg = pdu.make_pdu(sample_dt=1e-3)
    # the legacy testbench call is now a thin wrapper over the scenario IR
    scen = trace.scenario_from_testbench(trace.choukse_spec(), noise_seed=0)
    rack, dt = SC.render_trace(scen)
    st = pdu.init_state(cfg, rack[0])
    grid, _, _ = pdu.condition(cfg, st, rack, qp_iters=40)
    b = compliance.check(rack, dt, spec)
    a = compliance.check(grid, dt, spec)
    print(f"[Fig 9 ] ramp: rack {float(b.max_ramp):7.2f}/s -> grid "
          f"{float(a.max_ramp):7.4f}/s   (spec beta=0.1, paper: within +/-10%)")
    print(f"[Fig 10] S(f>=2Hz): rack {float(b.worst_high_freq_mag):.2e} -> grid "
          f"{float(a.worst_high_freq_mag):.2e} (spec alpha=1e-4)")


def fig7():
    cfg = pdu.make_pdu()
    for f, what in [(0.001, "passband"), (1.0, "ESS band"), (100.0, "LC band")]:
        h = float(pdu.combined_transfer_function(cfg, jnp.asarray(f)))
        print(f"[Fig 7 ] |H({f:7.3f} Hz)| = {h:.2e}  ({what})")


def fig11():
    tb, dt = trace.titanx_testbench(jax.random.key(2))
    cal = burn.calibrate(jax.random.key(3), p_idle=0.06, p_peak=1.0)
    sched = burn.burn_schedule(tb, dt, beta=0.1, cal=cal)
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, tb[0])
    gez, _, telem = pdu.condition(cfg, st, tb, qp_iters=40)
    soc = np.asarray(telem.soc)
    nwarm = sched.conditioned.shape[0] - tb.shape[0]
    cmp = burn.compare_energy(
        tb, gez, sched.conditioned[nwarm:], dt,
        soc_delta=float(soc[-1]) - 0.5, q_max_seconds=float(cfg.ess_params.q_max))
    print(f"[Fig 11] software burn uses {float(cmp['burn_vs_easyrider_frac'])*100:.1f}% "
          f"more energy than rack+EasyRider (paper: 19%)")


def fig12():
    cfg = ctrl.ControllerConfig.create(i_max=4e-3)
    es = ess.ESSParams.create(q_max_seconds=40.0)
    out = ctrl.simulate_soc_management(cfg, es, 0.62, n_steps=400, qp_iters=80)
    soc = np.asarray(out["soc"])
    hit = int(np.argmax(np.abs(soc - 0.5) <= float(cfg.deadband)))
    print(f"[Fig 12] SoC 0.62 -> {soc[-1]:.3f} in {hit*5/60:.1f} min "
          f"(paper: converges to S_mid=0.5 in ~20 min), monotone={bool(np.all(np.diff(soc[:hit+1])<=1e-4))}")


def fig13():
    rack, dt = trace.cluster_fault_trace(jax.random.key(4))
    cfg = pdu.make_pdu(sample_dt=dt)
    st = pdu.init_state(cfg, rack[0])
    grid, _, _ = pdu.condition(cfg, st, rack, qp_iters=20)
    w = max(int(0.2 / dt), 1)
    rr = float(jnp.max(jnp.abs(rack[w:] - rack[:-w]))) / 0.2 * 40
    rg = float(compliance.max_abs_ramp(grid, dt)) * 40
    print(f"[Fig 13] 40 MW cluster fault: unconditioned {rr:6.1f} MW/s "
          f"(paper: 193.7) -> conditioned {rg:.2f} MW/s (limit 4.0)")


def mixed_campus():
    """Beyond the paper: a heterogeneous campus as one declarative scenario.

    64 racks: three assigned-model training workloads (each rack's
    compute/communicate wave derived from its model's step cost) plus an
    inference block riding a diurnal envelope, staggered job starts, a few
    early terminations, and a mid-trace fault cascade.  Conditioned by the
    scanned streaming engine (the default): chunk rendering and the chunk
    loop are fused into one ``lax.scan``-ned jit, so the whole campus
    trace is synthesized and conditioned in a single dispatch — with the
    battery wear state machine (``core.health``) and the streaming
    compliance observers (cross-chunk ramp + Goertzel line bank) riding
    inside the same jit."""
    from repro.core import health as hlt

    hz = 200.0
    archs = ("llama3_2_1b", "deepseek_v3_671b", "whisper_large_v3")
    scen = SC.mixed_campus(
        64, archs, duration_s=120.0, sample_hz=hz, seed=0,
        inference_fraction=0.25, stagger_s=20.0,
        fault_rack_fraction=0.1, fault_at_s=70.0, noise_seed=1,
    )
    cfg = pdu.make_pdu(sample_dt=1.0 / hz, track_health=True)
    spec = compliance.GridSpec.create()
    res = fleet.condition_scenario_streaming(cfg, scen, spec, qp_iters=30,
                                             chunk_intervals=4)
    print(f"[Campus] 64 racks x {{{', '.join(archs)}, inference-diurnal}}: "
          f"raw ramp {float(res.report_rack.max_ramp):.2f}/s "
          f"(ok={bool(res.report_rack.ramp_ok)}) -> conditioned "
          f"{float(res.report_grid.max_ramp):.4f}/s "
          f"(ok={bool(res.report_grid.ramp_ok)}, beta=0.1)")
    g = res.report_grid
    print(f"[Campus] streaming compliance verdict: ramp_ok={bool(g.ramp_ok)} "
          f"spec_lines_ok={bool(g.spectrum_ok)} "
          f"(worst S(f>=2Hz)={float(g.worst_high_freq_mag):.2e} vs alpha=1e-4) "
          f"-> ok={bool(g.ok)}")
    h = hlt.fleet_summary(res.health)
    print(f"[Campus] fleet battery health over {scen.duration_s:.0f}s: "
          f"EFC mean {h['efc_mean']:.3f} / max {h['efc_max']:.3f}, "
          f"worst-rack DoD {h['worst_dod']:.3f}, "
          f"fade max {h['fade_max']:.2e}, "
          f"projected life >= {h['projected_life_years_min']:.1f} y")


def degraded_campus_service():
    """Failure engine + operator service: a campus under a stochastic
    fault soup, driven window-by-window by ``serve.ConditionerService``.

    A ``FaultProcess`` samples exponential fault/repair episodes into the
    scenario IR (rack power losses, ESS trips, sensor-dropout NaN
    windows); the degraded-mode conditioner masks tripped ESS units into
    LC passthrough — with the per-sample converter wind-down weight so
    trips land at their true sample — and bridges sensor-dark samples.
    Mid-stream, the operator checkpoints during an outage, trips two more
    racks manually (the audited kill switch), and a second service
    restores the checkpoint bitwise to finish the stream.  Every fault
    edge, degraded entry/exit, manual override, checkpoint, and window
    verdict lands in the append-only audit log."""
    import tempfile, os as _os

    from repro.power import faults as FLT
    from repro.serve import ConditionerService

    hz = 200.0
    duration = 60.0
    scen = SC.mixed_campus(
        32, ("llama3_2_1b", "chatglm3_6b"), duration_s=duration,
        sample_hz=hz, seed=5, fault_rack_fraction=0.0, edge_pad="clamp",
        noise_seed=4,
    )
    proc = FLT.FaultProcess.create(
        rack_mtbf_s=duration * 4.0, rack_mttr_s=duration * 0.2,
        ess_mtbf_s=duration * 2.0, ess_mttr_s=duration * 0.4,
        sensor_mtbf_s=duration * 3.0, sensor_mttr_s=duration * 0.1,
    )
    sched = FLT.sample_schedule(proc, 32, scen.total_samples, hz, seed=9)
    scen = SC.attach_faults(scen, sched)
    cfg = pdu.make_pdu(sample_dt=1.0 / hz, degraded_mode=True)
    spec = compliance.GridSpec.create()

    with tempfile.TemporaryDirectory() as td:
        svc = ConditionerService(
            cfg, scen, spec, chunk_intervals=2, qp_iters=30,
            audit_path=_os.path.join(td, "audit.jsonl"),
        )
        svc.advance()  # windows 1-2: ride into the fault soup
        svc.advance()
        ckpt = svc.checkpoint(_os.path.join(td, "mid_outage.ckpt"))
        svc.inject_fault([3, 7], reason="breaker inspection")
        svc.advance()
        st = svc.status()
        print(f"[Serve ] {st['n_racks']} racks at t={st['position_s']:.0f}s: "
              f"degraded={st['degraded_active']} "
              f"manual_offline={st['manual_offline_racks']} "
              f"audit_events={st['audit_events']}")

        # A fresh service restores the mid-outage checkpoint (state and
        # stream position, taken before the manual trip) and finishes the
        # stream — bitwise-identical to never having crashed.
        svc2 = ConditionerService(
            cfg, scen, spec, chunk_intervals=2, qp_iters=30,
            audit_path=_os.path.join(td, "audit2.jsonl"),
        )
        svc2.restore(ckpt)
        worst = 1.0
        while not svc2.exhausted:
            res = svc2.advance()
            worst = min(worst, float(np.asarray(res.ess_online_frac).min()))
        viol = sum(1 for ev in svc2.audit.tail(10 ** 6)
                   if ev.get("event") == "compliance_violation")
        # At 32 racks a heavy fault soup CAN break the campus ramp spec —
        # per-rack passthrough transients don't average out in a small
        # fleet (the 1024-rack acceptance bench holds the spec at ~30%
        # offline).  The service's job is to catch and audit exactly that.
        print(f"[Serve ] resumed from {ckpt.split('/')[-1]} and finished: "
              f"worst window online_frac={worst:.2f}, "
              f"compliance violations audited={viol}")
        print("[Serve ] audit tail:")
        for ev in svc2.audit.tail(4):
            keys = {k: v for k, v in ev.items() if k not in ("ts",)}
            print(f"         {keys}")


if __name__ == "__main__":
    fig7()
    fig9_fig10()
    fig11()
    fig12()
    fig13()
    mixed_campus()
    degraded_campus_service()
