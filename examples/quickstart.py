"""Quickstart: EasyRider conditioning in ~40 lines.

Synthesizes the paper's testbench training trace (Fig. 3/9), runs it
through a sized EasyRider PDU, and checks grid compliance — the paper's
central result, end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.core import compliance, pdu
from repro.power import trace


def main():
    # 1. Grid spec the operator imposes (paper §7.2 benchmark spec).
    spec = compliance.GridSpec.create(beta=0.1, alpha=1e-4, f_c=2.0)

    # 2. Size an EasyRider PDU for the prototype rack (10 kW, 400 V).
    cfg = pdu.make_pdu(grid=spec, sample_dt=2e-3)
    print(f"sized: f_f={float(cfg.filter_params.cutoff_hz()):.2f} Hz, "
          f"f_b={float(cfg.ess_params.cutoff_hz()):.4f} Hz, "
          f"battery={float(cfg.ess_params.q_max):.0f} s x P_RATED")

    # 3. A training job's rack power: compute/communicate swings, checkpoint
    #    dips, abrupt termination.
    rack, dt = trace.testbench_trace(
        trace.TestbenchSpec(duration_s=240.0, sample_hz=500.0, terminate_at_s=210.0),
        jax.random.key(0),
    )

    # 4. Condition it (hardware path + SoC-managing software path).
    state = pdu.init_state(cfg, rack[0])
    grid, state, telem = pdu.condition(cfg, state, rack, qp_iters=40)

    # 5. Compliance before/after.
    before = compliance.check(rack, dt, spec)
    after = compliance.check(grid, dt, spec)
    print(f"rack : ramp {float(before.max_ramp):8.3f}/s  "
          f"S(f>=2Hz) {float(before.worst_high_freq_mag):.2e}  ok={bool(before.ok)}")
    print(f"grid : ramp {float(after.max_ramp):8.4f}/s  "
          f"S(f>=2Hz) {float(after.worst_high_freq_mag):.2e}  ok={bool(after.ok)}")
    soc = telem.soc
    print(f"SoC stayed in [{float(soc.min()):.2f}, {float(soc.max()):.2f}] "
          f"(safe band [0.10, 0.90])")
    assert bool(after.ok), "conditioned trace must meet the grid spec"
    print("OK: the rack rides through every transient within grid limits.")


if __name__ == "__main__":
    main()
