"""Serve a small model with batched requests through the prefill/decode
engine, with the decode workload's (much flatter) power profile conditioned
by EasyRider — showing the sizing consequence: decode racks need a fraction
of the battery (Appendix A.1, smaller epsilon).

    PYTHONPATH=src python examples/serve.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import compliance, pdu, sizing
from repro.models import transformer as T
from repro.power import trace
from repro.serve import ServeEngine


def main():
    cfg = smoke_config("llama3_2_1b")
    params = T.init(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, max_len=96)

    prompts = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
    out = engine.generate(prompts, n_tokens=24)
    print(f"served batch of {out.shape[0]} requests, {out.shape[1]} tokens each")
    print("sample continuation token ids:", np.asarray(out[0, 16:26]))

    # decode-shape power profile: shallow swings (epsilon ~ 0.35 not 0.8)
    sp = trace.TestbenchSpec(
        duration_s=120.0, sample_hz=200.0, iteration_period_s=1.0,
        comm_fraction=0.25, p_compute=0.72, p_comm=0.52,
        dip_period_s=30.0, dip_duration_s=0.8, p_dip=0.45, warmup_s=4.0,
        edge_time_s=0.3,
    )
    rack, dt = trace.testbench_trace(sp, jax.random.key(2))
    steady = rack[int(6.0 / dt):]  # epsilon of the serving steady state
    eps_serve = float(steady.max() - steady.min())
    serve_rack = sizing.RackRating(p_rated_w=10_000, p_min_w=10_000 * (1 - eps_serve))
    s = sizing.size_system(serve_rack, beta=0.1)
    s_train = sizing.size_system(sizing.prototype_rack(), beta=0.1)
    print(f"serving epsilon={eps_serve:.2f}: battery {s.battery_energy_j/1e3:.0f} kJ vs "
          f"training {s_train.battery_energy_j/1e3:.0f} kJ "
          f"({s.battery_energy_j/s_train.battery_energy_j:.0%} of the training pack)")

    # Appendix A.1: "the cutoff frequency is chosen such that the grid power
    # harmonic content is acceptable" — serving cycles at ~1 Hz put harmonics
    # right at f_c = 2 Hz, so size f_f from THIS workload's spectrum.
    freqs, mags = compliance.normalized_spectrum(rack, dt)
    f_f = sizing.filter_cutoff_for_workload(
        (np.asarray(freqs), np.asarray(mags)), beta=0.1, alpha=1e-4, f_c=2.0)
    print(f"workload-informed LC cutoff: f_f = {f_f:.2f} Hz (prototype default: 4 Hz)")
    cfg_p = pdu.make_pdu(rack=serve_rack, sample_dt=dt, f_f_hz=min(f_f, 4.0))
    st = pdu.init_state(cfg_p, rack[0])
    grid, _, _ = pdu.condition(cfg_p, st, rack, qp_iters=20)
    rep = compliance.check(grid, dt, compliance.GridSpec.create())
    print(f"serving rack conditioned: ramp {float(rep.max_ramp):.4f}/s "
          f"S(f>=2Hz)={float(rep.worst_high_freq_mag):.2e} ok={bool(rep.ok)}")


if __name__ == "__main__":
    main()
