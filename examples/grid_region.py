"""Grid region: wide-area oscillation from synchronized checkpoints.

Builds two 4-campus regions running the *same* checkpoint schedule —
once in lockstep, once with campus c offset by c/N of the period —
conditions both through ``fleet.condition``, and prints the POI view:
ramp compliance, swing-model frequency excursion, and the per-band
wide-area mode verdicts.  Both schedules pass the ramp spec; only the
mode bank separates them (EXPERIMENTS §Grid-region).

    PYTHONPATH=src python examples/grid_region.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import compliance, fleet, grid, pdu


def main():
    hz = 50.0
    spec = compliance.GridSpec.create()
    cfg = pdu.make_pdu(sample_dt=1.0 / hz)

    for label, build in (
        ("synchronized", grid.synchronized_region),
        ("staggered", grid.staggered_region),
    ):
        reg = build(n_campuses=4, n_racks=16, duration_s=200.0, sample_hz=hz)
        res = fleet.condition(reg, cfg, spec)
        rep = res.report_poi
        print(f"\n== {label} checkpoints "
              f"({reg.n_campuses} campuses x {reg.n_racks[0]} racks) ==")
        print(f"POI ramp: {float(rep.max_ramp):.4f}/s "
              f"(ok={bool(rep.ramp_ok)})")
        print(f"max |df|: "
              f"{float(np.max(np.abs(np.asarray(res.poi_freq_dev)))):.3f} Hz, "
              f"max |dV|: "
              f"{float(np.max(np.abs(np.asarray(res.poi_volt_dev)))):.4f} pu")
        for i, band in enumerate(reg.bands):
            mag = float(np.asarray(rep.mode_mags)[i])
            ok = bool(np.asarray(rep.mode_ok)[i])
            print(f"  {band.name:12s} [{band.lo_hz:.1f}, {band.hi_hz:.1f}) Hz"
                  f"  mag={mag:.2e}  thr={band.threshold:.0e}  "
                  f"{'ok' if ok else 'FLAGGED'}")
        print(f"region verdict: "
              f"{'compliant' if bool(rep.ok) else 'NON-COMPLIANT'}")


if __name__ == "__main__":
    main()
