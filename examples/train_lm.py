"""End-to-end driver: train a ~100M-parameter llama-style model for a few
hundred steps on synthetic data, with checkpointing, fault tolerance, and
EasyRider power simulation in the loop.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 512]

The power report at the end shows the rack trace this training job *would*
create on the production mesh (phase timeline derived from the model's cost
profile) and that the PDU kept the grid side compliant throughout.
"""
import argparse
import sys

sys.path.insert(0, "src")

import dataclasses

from repro.configs import smoke_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.power.integration import PowerSim
from repro.power.phases import HardwareConstants, PhaseModel, StepCost
from repro.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = smoke_config("llama3_2_1b")
    cfg = dataclasses.replace(
        cfg, n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1), n_kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 4, head_dim=64, vocab_size=8192, pad_vocab_multiple=256,
    )
    n_params = cfg.param_count()
    print(f"model: {cfg.n_layers}L d={cfg.d_model} ~{n_params/1e6:.0f}M params")

    # Power model: what this job looks like on the 256-chip target.
    sim = PowerSim(
        StepCost(flops=6.0 * n_params * args.batch * args.seq * 1e3,  # scaled-up proxy
                 hbm_bytes=2e15, collective_bytes=4e14),
        HardwareConstants(chips=256),
        PhaseModel(checkpoint_every_steps=50, checkpoint_stall_s=3.0),
    )

    res = train(
        cfg,
        DataConfig(batch=args.batch, seq_len=args.seq, vocab_size=cfg.vocab_size),
        AdamWConfig(lr=1e-3),
        TrainConfig(steps=args.steps, log_every=25, checkpoint_every=100,
                    checkpoint_dir=args.ckpt_dir),
        power_sim=sim,
    )
    print(f"\nloss: {res['first_loss']:.3f} -> {res['last_loss']:.3f}")
    print("power report:", res["power_report"])
    assert res["last_loss"] < res["first_loss"]
    assert res["power_report"]["grid_ramp_ok"]
    print("OK: trained with grid-compliant (simulated) power draw.")


if __name__ == "__main__":
    main()
