"""Serving substrate: prefill/decode step builders + batched generation."""
from repro.serve.engine import ServeEngine, build_prefill_step, build_decode_step

__all__ = ["ServeEngine", "build_prefill_step", "build_decode_step"]
