"""Serving substrate: prefill/decode step builders + batched generation,
plus the resume-safe power-conditioner operator service."""
from repro.serve.conditioner import AuditLog, ConditionerService
from repro.serve.engine import ServeEngine, build_prefill_step, build_decode_step

__all__ = [
    "AuditLog",
    "ConditionerService",
    "ServeEngine",
    "build_prefill_step",
    "build_decode_step",
]
