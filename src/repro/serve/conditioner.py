"""Resume-safe operator service for the power conditioner (ISSUE 6).

``ConditionerService`` wraps the scanned streaming engine (via the
``fleet.condition`` facade) in the loop a campus operator actually runs:
advance the stream window by window, checkpoint the carried ``PDUState``
at controller-interval boundaries, restore after a crash and continue
with *bitwise identical* downstream telemetry, and keep an append-only
JSONL audit log of everything that happened — scheduled faults/repairs
from the scenario's fault schedule, degraded-mode entry and exit, manual
ESS trips injected by the operator, compliance verdicts, and
checkpoint/restore events.

The service also runs whole grid regions (``core.grid.GridRegion``): the
carried state becomes the tuple of per-campus ``PDUState``s, rack indices
in ``inject_fault``/``clear_fault`` are global across the region (mapped
to (campus, local) internally), ``status()`` grows POI and per-campus
aggregates, and wide-area mode-band violations land in the audit log as
first-class ``mode_band_violation`` events.

Resume safety comes from two facts the engines already guarantee:

  * Window aggregates of ``[start, stop)`` are pure in the absolute sample
    index (renderer, fault schedule, and availability mask all are), so a
    restored service re-enters the stream exactly where it left off.
  * Fixed-size windows share one cached compiled engine, so the resumed
    run is not just numerically close but the *same program on the same
    floats* — the crash-resume test asserts bitwise equality.

The audit log is strict JSON (``allow_nan=False``): health summaries are
clamped via ``health.fleet_summary(..., json_safe=True)``, so an empty
wear history's infinite projected lifetime becomes ``null`` instead of the
non-standard ``Infinity`` literal that breaks downstream parsers.
"""
from __future__ import annotations

import glob as _glob
import hashlib
import json
import os
import zipfile
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compliance, fleet, health as hlt, pdu, safemode as smode

_CKPT_MAGIC = "easyrider-conditioner-ckpt-v2"


def _fingerprint(cfg, scenario, grid_spec) -> str:
    """sha256 over the full service configuration: config, scenario, and
    grid spec pytrees — treedefs (which carry every static field) plus
    each leaf's shape, dtype, and bytes.  Stored in checkpoints and
    validated on restore, so a checkpoint can never be silently loaded
    into a service built over different physics, fleet geometry, or
    compliance limits.  Deliberately excludes ``chunk_intervals`` and the
    carried state: resume is chunk-size invariant, and the state is the
    payload being restored, not part of the identity.
    """
    h = hashlib.sha256()
    for obj in (cfg, scenario, grid_spec):
        leaves, treedef = jax.tree_util.tree_flatten(obj)
        h.update(str(treedef).encode())
        for leaf in leaves:
            arr = np.asarray(leaf)
            h.update(str(arr.shape).encode())
            h.update(str(arr.dtype).encode())
            h.update(arr.tobytes())
    return h.hexdigest()


class AuditLog:
    """Append-only JSONL event log (in-memory ring + optional file).

    Every record is one line of strict JSON (``allow_nan=False``), flushed
    on write — the file is valid and tail-able at any crash point, which is
    the whole point of an audit log.  Each record carries a ``seq`` number,
    monotone within its log file (a restarted service continues from the
    line count of the existing file), so a parser can assert no record was
    lost or reordered across a crash.

    ``fsync=True`` makes every append durable (``flush`` + ``os.fsync``)
    so the log survives power loss, not just process death.  ``max_bytes``
    turns on size-based rotation: when the file would exceed the limit it
    is shifted to ``<path>.1`` (older generations move to ``.2``, ``.3``,
    ... up to ``backups``; the oldest is dropped) and the main file starts
    fresh — unattended multi-week runs never grow one unbounded JSONL.
    """

    def __init__(
        self,
        path: str | os.PathLike | None = None,
        *,
        fsync: bool = False,
        max_bytes: int | None = None,
        backups: int = 3,
    ):
        self.path = os.fspath(path) if path is not None else None
        self.fsync = bool(fsync)
        self.max_bytes = None if max_bytes is None else int(max_bytes)
        self.backups = int(backups)
        self._events: list[dict] = []
        self._seq = 0
        if self.path is not None and os.path.exists(self.path):
            # Continue the per-file seq after a restart over the same log.
            with open(self.path) as f:
                self._seq = sum(1 for _ in f)

    def append(self, event: str, **fields) -> dict:
        if (
            self.path is not None
            and self.max_bytes is not None
            and os.path.exists(self.path)
        ):
            probe = json.dumps(
                dict(event=event, seq=self._seq, **fields),
                sort_keys=True, allow_nan=False,
            )
            if os.path.getsize(self.path) + len(probe) + 1 > self.max_bytes:
                self._rotate()  # resets seq; assign it only after this
        rec = dict(event=event, seq=self._seq, **fields)
        line = json.dumps(rec, sort_keys=True, allow_nan=False)
        self._events.append(rec)
        self._seq += 1
        if self.path is not None:
            with open(self.path, "a") as f:
                f.write(line + "\n")
                if self.fsync:
                    f.flush()
                    os.fsync(f.fileno())
        return rec

    def _rotate(self) -> None:
        if self.backups <= 0:
            os.remove(self.path)
        else:
            for i in range(self.backups - 1, 0, -1):
                src = f"{self.path}.{i}"
                if os.path.exists(src):
                    os.replace(src, f"{self.path}.{i + 1}")
            os.replace(self.path, f"{self.path}.1")
        # seq restarts with the fresh file (monotone is per file).
        self._seq = 0

    def tail(self, n: int = 10) -> list[dict]:
        return self._events[-n:]

    def __len__(self) -> int:
        return len(self._events)


class ConditionerService:
    """Operator loop over the scanned conditioning engine.

    ``scenario`` may be a single ``power.scenario.Scenario`` (one campus)
    or a ``core.grid.GridRegion`` (N campuses aggregated at a POI); both
    run through the ``fleet.condition`` facade.  The service owns the
    carried state — one ``PDUState``, or a tuple of per-campus states for
    a region — and the absolute stream position (in samples), both of
    which ride in checkpoints.  ``mesh`` (optional, regions only) runs
    the campuses in parallel under ``shard_map``.
    """

    def __init__(
        self,
        cfg: pdu.PDUConfig,
        scenario,
        grid_spec: compliance.GridSpec,
        *,
        chunk_intervals: int = 16,
        qp_iters: int = 30,
        soc0: float = 0.5,
        mesh=None,
        audit_path: str | os.PathLike | None = None,
        audit_fsync: bool = False,
        audit_max_bytes: int | None = None,
        checkpoint_dir: str | os.PathLike | None = None,
        checkpoint_every: int | None = None,
        keep_checkpoints: int = 3,
    ):
        from repro.core.fleet import _check_scenario_faults, _check_scenario_rate
        from repro.power import scenario as SC

        self.cfg = cfg
        self.scenario = scenario
        self.grid_spec = grid_spec
        self.chunk_intervals = int(chunk_intervals)
        self.qp_iters = int(qp_iters)
        self.mesh = mesh
        self._k = max(int(round(float(cfg.controller.dt) / cfg.sample_dt)), 1)
        self.sample_pos = 0
        self.audit = AuditLog(
            audit_path, fsync=audit_fsync, max_bytes=audit_max_bytes
        )
        self.checkpoint_dir = (
            os.fspath(checkpoint_dir) if checkpoint_dir is not None else None
        )
        self.checkpoint_every = (
            None if checkpoint_every is None else int(checkpoint_every)
        )
        if self.checkpoint_every is not None and self.checkpoint_dir is None:
            raise ValueError("checkpoint_every requires checkpoint_dir")
        self.keep_checkpoints = int(keep_checkpoints)
        self._windows_since_ckpt = 0
        self._degraded_now = False
        self._last_result: fleet.ConditioningResult | None = None
        self._is_region = hasattr(scenario, "campuses")
        self.fingerprint = _fingerprint(cfg, scenario, grid_spec)
        self._sm_prev = (0, 0, 0)  # (passthrough_entries, quarantine_entries, readmissions)

        if self._is_region:
            campuses = scenario.campuses
            states = []
            for c in campuses:
                _check_scenario_rate(c, cfg)
                _check_scenario_faults(c, cfg)
                r0 = SC.render(c, 0, 1)[0]
                if r0.ndim == 0:
                    r0 = r0[None]
                states.append(pdu.init_state(cfg, r0, soc0=soc0))
            self.state = tuple(states)
            self._campus_racks = [
                int(np.asarray(st.ess_online).shape[0]) for st in states
            ]
            self._campus_offsets = np.concatenate(
                [[0], np.cumsum(self._campus_racks)]
            ).astype(np.int64)
            self.n_racks = int(self._campus_offsets[-1])
            has_faults = any(
                getattr(c, "faults", None) is not None for c in campuses
            )
        else:
            if mesh is not None:
                raise ValueError(
                    "mesh is only meaningful for GridRegion targets"
                )
            _check_scenario_rate(scenario, cfg)
            _check_scenario_faults(scenario, cfg)
            r0 = SC.render(scenario, 0, 1)[0]
            if r0.ndim == 0:
                r0 = r0[None]
            self.state = pdu.init_state(cfg, r0, soc0=soc0)
            self.n_racks = int(np.asarray(self.state.ess_online).shape[0])
            has_faults = getattr(scenario, "faults", None) is not None
        # Which availability path the engine will take, for the operator's
        # perf expectations: "compiled" = interval-compiled episode tables
        # rendered inside the conditioning scan (faulty windows cost about
        # the same as clean ones); "streamed" = the safe-mode supervisor
        # needs materialized per-sample masks, so faulty windows pay the
        # legacy streaming tax.
        fault_path = None
        if cfg.degraded_mode and has_faults:
            fault_path = (
                "streamed" if getattr(cfg, "safemode", None) else "compiled"
            )
        self.audit.append(
            "service_start",
            sample=0,
            n_racks=self.n_racks,
            n_campuses=scenario.n_campuses if self._is_region else 1,
            total_samples=int(scenario.total_samples),
            sample_hz=float(scenario.sample_hz),
            degraded_mode=bool(cfg.degraded_mode),
            has_fault_schedule=has_faults,
            fault_path=fault_path,
        )

    # ------------------------------------------------------------- position

    @property
    def position_s(self) -> float:
        return self.sample_pos / float(self.scenario.sample_hz)

    @property
    def exhausted(self) -> bool:
        return self.sample_pos >= int(self.scenario.total_samples)

    # -------------------------------------------------------------- advance

    def advance(self, n_intervals: int | None = None) -> fleet.ConditioningResult:
        """Condition the next ``n_intervals`` controller intervals.

        Defaults to one chunk (``chunk_intervals``); fixed-size windows
        reuse one cached compiled engine, so steady-state advancing never
        retraces.  Returns the window's ``ConditioningResult`` and logs
        the window's scheduled fault/repair edges, degraded entry/exit,
        the compliance verdict, and (regions) mode-band violations.
        """
        if self.exhausted:
            raise RuntimeError(
                f"stream exhausted at sample {self.sample_pos}; nothing to advance"
            )
        n = self.chunk_intervals if n_intervals is None else int(n_intervals)
        if n <= 0:
            raise ValueError(f"n_intervals must be positive, got {n}")
        start = self.sample_pos
        stop = min(start + n * self._k, int(self.scenario.total_samples))
        res = fleet.condition(
            self.scenario,
            self.cfg,
            self.grid_spec,
            mesh=self.mesh,
            stream=fleet.StreamOptions(
                chunk_intervals=self.chunk_intervals,
                state=self.state,
                start_sample=start,
                stop_sample=stop,
            ),
            qp_iters=self.qp_iters,
        )
        self.state = res.state
        self.sample_pos = stop
        self._last_result = res
        self._log_window(start, stop, res)
        if getattr(self.cfg, "safemode", False):
            self._log_safemode(start)
        if self.checkpoint_every is not None:
            self._windows_since_ckpt += 1
            if self._windows_since_ckpt >= self.checkpoint_every:
                self._auto_checkpoint()
        return res

    # ------------------------------------------------------------- safe mode

    def _sm_totals(self) -> tuple[int, int, int]:
        """Fleet-wide (passthrough_entries, quarantine_entries, readmissions)
        summed over racks (and campuses for a region)."""
        states = self.state if self._is_region else (self.state,)
        tot = [0, 0, 0]
        for st in states:
            sm = st.safemode
            if sm is None:
                continue
            tot[0] += int(np.asarray(sm.passthrough_entries).sum())
            tot[1] += int(np.asarray(sm.quarantine_entries).sum())
            tot[2] += int(np.asarray(sm.readmissions).sum())
        return tuple(tot)

    def _sm_racks(self, mode: int) -> list[int]:
        """Global rack indices currently in the given safe-mode state."""
        states = self.state if self._is_region else (self.state,)
        out = []
        off = 0
        for st in states:
            m = np.asarray(st.safemode.mode)
            out.extend(int(i) + off for i in np.flatnonzero(m == mode))
            off += m.shape[0]
        return out

    def _log_safemode(self, start: int) -> None:
        """Audit counter deltas from the supervisory state machine: each
        window that tripped new racks into passthrough/quarantine gets a
        ``safemode_enter`` event (with the racks currently contained), and
        each window with hysteretic re-admissions a ``safemode_exit``."""
        pt, qr, ra = self._sm_totals()
        d_pt, d_qr = pt - self._sm_prev[0], qr - self._sm_prev[1]
        d_ra = ra - self._sm_prev[2]
        self._sm_prev = (pt, qr, ra)
        if d_pt or d_qr:
            self.audit.append(
                "safemode_enter", sample=start,
                new_passthrough=d_pt, new_quarantine=d_qr,
                passthrough_racks=self._sm_racks(smode.PASSTHROUGH),
                quarantined_racks=self._sm_racks(smode.QUARANTINE),
            )
        if d_ra:
            self.audit.append(
                "safemode_exit", sample=start, readmissions=d_ra,
                still_contained=self._sm_racks(smode.PASSTHROUGH)
                + self._sm_racks(smode.QUARANTINE),
            )

    def _auto_checkpoint(self) -> None:
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        path = os.path.join(
            self.checkpoint_dir, f"ckpt_{self.sample_pos:012d}.npz"
        )
        self.checkpoint(path)
        self._windows_since_ckpt = 0
        # Prune oldest auto-checkpoints beyond the retention window.
        kept = sorted(
            _glob.glob(os.path.join(self.checkpoint_dir, "ckpt_*.npz"))
        )
        for old in kept[: max(0, len(kept) - self.keep_checkpoints)]:
            os.remove(old)

    def _log_window(self, start: int, stop: int, res: fleet.ConditioningResult):
        from repro.power import faults as FLT

        if self._is_region:
            for c, scen in enumerate(self.scenario.campuses):
                sched = getattr(scen, "faults", None)
                if sched is None:
                    continue
                off = int(self._campus_offsets[c])
                for ev in FLT.episodes_in_window(sched, start, stop):
                    ev["rack"] += off
                    self.audit.append(
                        campus=self.scenario.names[c], **ev
                    )
        else:
            sched = getattr(self.scenario, "faults", None)
            if sched is not None:
                for ev in FLT.episodes_in_window(sched, start, stop):
                    self.audit.append(**ev)
        frac = np.asarray(res.ess_online_frac)
        degraded = bool(frac.size) and float(frac.min()) < 1.0
        if degraded and not self._degraded_now:
            self.audit.append(
                "degraded_enter", sample=start, min_online_frac=float(frac.min())
            )
        elif self._degraded_now and not degraded:
            self.audit.append("degraded_exit", sample=start)
        self._degraded_now = degraded
        rep = res.report_grid
        ramp_ok = bool(np.asarray(rep.ramp_ok))
        spec_ok = bool(np.asarray(rep.spectrum_ok))
        modes_ok = (
            bool(np.asarray(rep.modes_ok)) if rep.modes_ok is not None else True
        )
        window = dict(
            sample=start,
            stop=stop,
            ramp_ok=ramp_ok,
            spectrum_ok=spec_ok,
            min_online_frac=float(frac.min()) if frac.size else 1.0,
            max_qp_residual=float(np.asarray(res.max_qp_residual)),
        )
        if rep.modes_ok is not None:
            window["modes_ok"] = modes_ok
        self.audit.append("window", **window)
        if rep.mode_ok is not None and self._is_region:
            mode_ok = np.asarray(rep.mode_ok)
            mags = np.asarray(rep.mode_mags)
            for i, band in enumerate(self.scenario.bands):
                if not bool(mode_ok[i]):
                    self.audit.append(
                        "mode_band_violation", sample=start, stop=stop,
                        band=band.name, lo_hz=float(band.lo_hz),
                        hi_hz=float(band.hi_hz),
                        magnitude=float(mags[i]),
                        threshold=float(band.threshold),
                    )
        if not (ramp_ok and spec_ok and modes_ok):
            self.audit.append(
                "compliance_violation", sample=start, stop=stop,
                ramp_ok=ramp_ok, spectrum_ok=spec_ok, modes_ok=modes_ok,
            )

    # ----------------------------------------------------- manual overrides

    def inject_fault(self, racks: Sequence[int] | int, *, reason: str = "manual"):
        """Trip the given racks' ESS units offline until ``clear_fault``.

        This is the operator's kill switch: it writes the persistent
        ``PDUState.ess_online`` override, which every engine multiplies
        into the effective availability mask — independent of (and in
        addition to) the scenario's stochastic schedule.
        """
        racks = self._check_racks(racks)
        self._set_ess_online(racks, 0.0)
        self.audit.append(
            "manual_fault_injected", sample=self.sample_pos, racks=racks,
            reason=reason,
        )

    def clear_fault(self, racks: Sequence[int] | int):
        """Return manually tripped racks to service."""
        racks = self._check_racks(racks)
        self._set_ess_online(racks, 1.0)
        self.audit.append(
            "manual_fault_cleared", sample=self.sample_pos, racks=racks
        )

    def _set_ess_online(self, racks: list[int], value: float) -> None:
        if not self._is_region:
            self.state = self.state._replace(
                ess_online=self.state.ess_online.at[jnp.asarray(racks)].set(value)
            )
            return
        # Region: global rack index -> (campus, local) through the offsets.
        states = list(self.state)
        for r in racks:
            c = int(np.searchsorted(self._campus_offsets, r, side="right")) - 1
            local = r - int(self._campus_offsets[c])
            states[c] = states[c]._replace(
                ess_online=states[c].ess_online.at[local].set(value)
            )
        self.state = tuple(states)

    def _check_racks(self, racks) -> list[int]:
        racks = [int(r) for r in np.atleast_1d(np.asarray(racks, dtype=np.int64))]
        bad = [r for r in racks if not 0 <= r < self.n_racks]
        if bad:
            raise ValueError(f"rack indices {bad} outside fleet of {self.n_racks}")
        return racks

    # ------------------------------------------------------ checkpoint/restore

    def checkpoint(self, path: str | os.PathLike) -> str:
        """Write the carried state + stream position to ``path`` (.npz),
        atomically.

        The archive is written to a same-directory temp file, flushed and
        fsync'd, then ``os.replace``'d over the target (the directory is
        fsync'd too) — a crash at any point leaves either the previous
        checkpoint intact or the complete new one, never a torn file at
        the target path.  The archive carries the service's config/scenario
        fingerprint, validated on restore.

        Only valid at an interval boundary, which every ``advance`` stop
        is — the state *is* the interval-boundary carry, so no mid-interval
        capture is possible by construction.
        """
        path = os.fspath(path)
        if not path.endswith(".npz"):
            path += ".npz"  # keep the real filename predictable
        leaves = jax.tree_util.tree_leaves(self.state)
        payload = dict(
            magic=np.asarray(_CKPT_MAGIC),
            fingerprint=np.asarray(self.fingerprint),
            sample_pos=np.int64(self.sample_pos),
            n_leaves=np.int64(len(leaves)),
            **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)},
        )
        tmp = f"{path}.tmp-{os.getpid()}"
        try:
            with open(tmp, "wb") as f:
                np.savez(f, **payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
        dfd = os.open(os.path.dirname(os.path.abspath(path)), os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
        self.audit.append(
            "checkpoint_saved", sample=self.sample_pos, path=path,
        )
        return path

    def restore(self, path: str | os.PathLike) -> None:
        """Load a checkpoint written by ``checkpoint`` into this service.

        The service must be constructed over the same config and scenario
        (the checkpoint stores leaves, the treedef comes from the live
        state); the stored config/scenario fingerprint plus every leaf's
        count, shape, AND dtype are validated, so a checkpoint from a
        different fleet, physics config, or float width is rejected as a
        config mismatch instead of silently corrupting the resumed stream.
        Continuing with ``advance`` reproduces the uninterrupted run
        bitwise — the crash-resume regression test holds this to array
        equality.
        """
        path = os.fspath(path)
        with np.load(path) as z:
            if "fingerprint" in z.files:
                fp = str(z["fingerprint"])
                if fp != self.fingerprint:
                    raise ValueError(
                        f"checkpoint fingerprint {fp[:12]}... does not match "
                        f"this service's {self.fingerprint[:12]}... — it was "
                        "written under a different config/scenario/grid spec"
                    )
            n = int(z["n_leaves"])
            template = jax.tree_util.tree_leaves(self.state)
            if n != len(template):
                raise ValueError(
                    f"checkpoint has {n} leaves; this service's state has "
                    f"{len(template)} — config/scenario mismatch"
                )
            leaves = []
            for i, t in enumerate(template):
                arr = z[f"leaf_{i}"]
                t_arr = np.asarray(t)
                if arr.shape != t_arr.shape:
                    raise ValueError(
                        f"checkpoint leaf {i} shape {arr.shape} != expected "
                        f"{t_arr.shape} — config/scenario mismatch"
                    )
                if arr.dtype != t_arr.dtype:
                    raise ValueError(
                        f"checkpoint leaf {i} dtype {arr.dtype} != expected "
                        f"{t_arr.dtype} — config/scenario mismatch"
                    )
                leaves.append(jnp.asarray(arr))
            treedef = jax.tree_util.tree_structure(self.state)
            self.state = jax.tree_util.tree_unflatten(treedef, leaves)
            self.sample_pos = int(z["sample_pos"])
        self._last_result = None
        self._sm_prev = (
            self._sm_totals()
            if getattr(self.cfg, "safemode", False)
            else (0, 0, 0)
        )
        self.audit.append("restored", sample=self.sample_pos, path=path)

    def recover(self, ckpt_dir: str | os.PathLike) -> str | None:
        """Restore from the newest valid checkpoint under ``ckpt_dir``.

        Candidates (``*.npz``, non-recursive) are probed newest-first by
        their stored ``sample_pos``; torn or unreadable files — a truncated
        archive from a crash mid-write under a non-atomic writer, a
        zero-byte file, a foreign npz — are skipped with a
        ``recover_skipped`` audit event rather than aborting recovery.
        Returns the path restored from, or ``None`` (with a
        ``recover_failed`` event) when no candidate was valid; the service
        is left at its pre-call state in that case.
        """
        ckpt_dir = os.fspath(ckpt_dir)
        candidates = []
        for p in _glob.glob(os.path.join(ckpt_dir, "*.npz")):
            try:
                with np.load(p) as z:
                    if "magic" in z.files and str(z["magic"]) != _CKPT_MAGIC:
                        raise ValueError("not a conditioner checkpoint")
                    pos = int(z["sample_pos"])
            except (
                OSError, ValueError, KeyError, zipfile.BadZipFile, EOFError,
            ) as e:
                self.audit.append(
                    "recover_skipped", path=p, error=f"{type(e).__name__}: {e}"
                )
                continue
            candidates.append((pos, p))
        for _, p in sorted(candidates, reverse=True):
            try:
                self.restore(p)
            except (
                OSError, ValueError, KeyError, zipfile.BadZipFile, EOFError,
            ) as e:
                self.audit.append(
                    "recover_skipped", path=p, error=f"{type(e).__name__}: {e}"
                )
                continue
            self.audit.append("recovered", sample=self.sample_pos, path=p)
            return p
        self.audit.append("recover_failed", dir=ckpt_dir)
        return None

    # --------------------------------------------------------------- status

    def status(self) -> dict:
        """JSON-safe streaming snapshot for dashboards/health endpoints.

        For a grid region the snapshot additionally carries the POI view
        of the last window (peak power, frequency/voltage excursions,
        per-band mode magnitudes and verdicts) and per-campus aggregates.
        """
        if self._is_region:
            online = np.concatenate(
                [np.asarray(st.ess_online) for st in self.state]
            )
        else:
            online = np.asarray(self.state.ess_online)
        manual_off = [int(i) for i in np.flatnonzero(online <= 0.0)]
        out = dict(
            sample_pos=self.sample_pos,
            position_s=self.position_s,
            total_samples=int(self.scenario.total_samples),
            exhausted=self.exhausted,
            n_racks=self.n_racks,
            degraded_active=self._degraded_now,
            manual_offline_racks=manual_off,
            audit_events=len(self.audit),
        )
        if self._is_region:
            out["region"] = dict(
                n_campuses=int(self.scenario.n_campuses),
                campus_names=list(self.scenario.names),
                campus_racks=list(self._campus_racks),
            )
        res = self._last_result
        if res is not None:
            frac = np.asarray(res.ess_online_frac)
            rep = res.report_grid
            last = dict(
                ramp_ok=bool(np.asarray(rep.ramp_ok)),
                spectrum_ok=bool(np.asarray(rep.spectrum_ok)),
                min_online_frac=float(frac.min()) if frac.size else 1.0,
                mean_online_frac=float(frac.mean()) if frac.size else 1.0,
                max_qp_residual=float(np.asarray(res.max_qp_residual)),
            )
            out["last_window"] = last
            if self._is_region:
                last["modes_ok"] = (
                    bool(np.asarray(rep.modes_ok))
                    if rep.modes_ok is not None else True
                )
                mags = np.asarray(rep.mode_mags)
                mode_ok = np.asarray(rep.mode_ok)
                out["poi"] = dict(
                    peak_power_pu=float(np.max(np.asarray(res.poi_grid))),
                    max_freq_dev_hz=float(
                        np.max(np.abs(np.asarray(res.poi_freq_dev)))
                    ),
                    max_volt_dev=float(
                        np.max(np.abs(np.asarray(res.poi_volt_dev)))
                    ),
                    mode_bands=[
                        dict(
                            band=b.name,
                            magnitude=float(mags[i]),
                            threshold=float(b.threshold),
                            ok=bool(mode_ok[i]),
                        )
                        for i, b in enumerate(self.scenario.bands)
                    ],
                )
                camp_grid = np.asarray(res.campus_grid)
                camp_frac = np.asarray(res.ess_online_frac)
                out["campuses"] = [
                    dict(
                        name=self.scenario.names[c],
                        n_racks=int(self._campus_racks[c]),
                        weight=float(np.asarray(res.weights)[c]),
                        peak_power_pu=float(camp_grid[c].max()),
                        min_online_frac=float(camp_frac[c].min())
                        if camp_frac.size else 1.0,
                        ramp_ok=bool(
                            np.asarray(res.per_campus[c].report_grid.ramp_ok)
                        ),
                    )
                    for c in range(int(self.scenario.n_campuses))
                ]
            else:
                out["health"] = hlt.fleet_summary(res.health, json_safe=True)
        if getattr(self.cfg, "safemode", False):
            states = self.state if self._is_region else (self.state,)
            per = [smode.summary(st.safemode) for st in states]
            sm = dict(
                n_normal=sum(p["n_normal"] for p in per),
                n_passthrough=sum(p["n_passthrough"] for p in per),
                n_quarantined=sum(p["n_quarantined"] for p in per),
                passthrough_racks=self._sm_racks(smode.PASSTHROUGH),
                quarantined_racks=self._sm_racks(smode.QUARANTINE),
                passthrough_entries=sum(p["passthrough_entries"] for p in per),
                quarantine_entries=sum(p["quarantine_entries"] for p in per),
                readmissions=sum(p["readmissions"] for p in per),
                worst_resid_streak=max(p["worst_resid_streak"] for p in per),
            )
            out["safemode"] = sm
        # Strict-JSON guarantee: this must always survive allow_nan=False.
        json.dumps(out, allow_nan=False)
        return out
