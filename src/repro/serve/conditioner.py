"""Resume-safe operator service for the power conditioner (ISSUE 6).

``ConditionerService`` wraps the scanned streaming engine
(``fleet.condition_scenario_scanned``) in the loop a campus operator
actually runs: advance the stream window by window, checkpoint the carried
``PDUState`` at controller-interval boundaries, restore after a crash and
continue with *bitwise identical* downstream telemetry, and keep an
append-only JSONL audit log of everything that happened — scheduled
faults/repairs from the scenario's fault schedule, degraded-mode entry and
exit, manual ESS trips injected by the operator, compliance verdicts, and
checkpoint/restore events.

Resume safety comes from two facts the engines already guarantee:

  * Window aggregates of ``[start, stop)`` are pure in the absolute sample
    index (renderer, fault schedule, and availability mask all are), so a
    restored service re-enters the stream exactly where it left off.
  * Fixed-size windows share one cached compiled engine, so the resumed
    run is not just numerically close but the *same program on the same
    floats* — the crash-resume test asserts bitwise equality.

The audit log is strict JSON (``allow_nan=False``): health summaries are
clamped via ``health.fleet_summary(..., json_safe=True)``, so an empty
wear history's infinite projected lifetime becomes ``null`` instead of the
non-standard ``Infinity`` literal that breaks downstream parsers.
"""
from __future__ import annotations

import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compliance, fleet, health as hlt, pdu


class AuditLog:
    """Append-only JSONL event log (in-memory ring + optional file).

    Every record is one line of strict JSON (``allow_nan=False``), flushed
    on write — the file is valid and tail-able at any crash point, which is
    the whole point of an audit log.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = os.fspath(path) if path is not None else None
        self._events: list[dict] = []

    def append(self, event: str, **fields) -> dict:
        rec = dict(event=event, **fields)
        line = json.dumps(rec, sort_keys=True, allow_nan=False)
        self._events.append(rec)
        if self.path is not None:
            with open(self.path, "a") as f:
                f.write(line + "\n")
        return rec

    def tail(self, n: int = 10) -> list[dict]:
        return self._events[-n:]

    def __len__(self) -> int:
        return len(self._events)


class ConditionerService:
    """Operator loop over the scanned conditioning engine.

    Parameters mirror ``fleet.condition_scenario_scanned``; the service
    owns the carried ``PDUState`` and the absolute stream position (in
    samples), both of which ride in checkpoints.
    """

    def __init__(
        self,
        cfg: pdu.PDUConfig,
        scenario,
        grid_spec: compliance.GridSpec,
        *,
        chunk_intervals: int = 16,
        qp_iters: int = 30,
        soc0: float = 0.5,
        audit_path: str | os.PathLike | None = None,
    ):
        from repro.core.fleet import _check_scenario_faults, _check_scenario_rate
        from repro.power import scenario as SC

        _check_scenario_rate(scenario, cfg)
        _check_scenario_faults(scenario, cfg)
        self.cfg = cfg
        self.scenario = scenario
        self.grid_spec = grid_spec
        self.chunk_intervals = int(chunk_intervals)
        self.qp_iters = int(qp_iters)
        self._k = max(int(round(float(cfg.controller.dt) / cfg.sample_dt)), 1)
        self.sample_pos = 0
        self.audit = AuditLog(audit_path)
        self._degraded_now = False
        self._last_result: fleet.StreamingFleetResult | None = None

        r0 = SC.render(scenario, 0, 1)[0]
        if r0.ndim == 0:
            r0 = r0[None]
        self.state = pdu.init_state(cfg, r0, soc0=soc0)
        self.n_racks = int(np.asarray(self.state.ess_online).shape[0])
        self.audit.append(
            "service_start",
            sample=0,
            n_racks=self.n_racks,
            total_samples=int(scenario.total_samples),
            sample_hz=float(scenario.sample_hz),
            degraded_mode=bool(cfg.degraded_mode),
            has_fault_schedule=getattr(scenario, "faults", None) is not None,
        )

    # ------------------------------------------------------------- position

    @property
    def position_s(self) -> float:
        return self.sample_pos / float(self.scenario.sample_hz)

    @property
    def exhausted(self) -> bool:
        return self.sample_pos >= int(self.scenario.total_samples)

    # -------------------------------------------------------------- advance

    def advance(self, n_intervals: int | None = None) -> fleet.StreamingFleetResult:
        """Condition the next ``n_intervals`` controller intervals.

        Defaults to one chunk (``chunk_intervals``); fixed-size windows
        reuse one cached compiled engine, so steady-state advancing never
        retraces.  Returns the window's ``StreamingFleetResult`` and logs
        the window's scheduled fault/repair edges, degraded entry/exit,
        and the compliance verdict.
        """
        if self.exhausted:
            raise RuntimeError(
                f"stream exhausted at sample {self.sample_pos}; nothing to advance"
            )
        n = self.chunk_intervals if n_intervals is None else int(n_intervals)
        if n <= 0:
            raise ValueError(f"n_intervals must be positive, got {n}")
        start = self.sample_pos
        stop = min(start + n * self._k, int(self.scenario.total_samples))
        res = fleet.condition_scenario_scanned(
            self.cfg,
            self.scenario,
            self.grid_spec,
            qp_iters=self.qp_iters,
            chunk_intervals=self.chunk_intervals,
            state=self.state,
            start_sample=start,
            stop_sample=stop,
        )
        self.state = res.state
        self.sample_pos = stop
        self._last_result = res
        self._log_window(start, stop, res)
        return res

    def _log_window(self, start: int, stop: int, res: fleet.StreamingFleetResult):
        sched = getattr(self.scenario, "faults", None)
        if sched is not None:
            from repro.power import faults as FLT

            for ev in FLT.episodes_in_window(sched, start, stop):
                self.audit.append(**ev)
        frac = np.asarray(res.ess_online_frac)
        degraded = bool(frac.size) and float(frac.min()) < 1.0
        if degraded and not self._degraded_now:
            self.audit.append(
                "degraded_enter", sample=start, min_online_frac=float(frac.min())
            )
        elif self._degraded_now and not degraded:
            self.audit.append("degraded_exit", sample=start)
        self._degraded_now = degraded
        ramp_ok = bool(np.asarray(res.report_grid.ramp_ok))
        spec_ok = bool(np.asarray(res.report_grid.spectrum_ok))
        self.audit.append(
            "window",
            sample=start,
            stop=stop,
            ramp_ok=ramp_ok,
            spectrum_ok=spec_ok,
            min_online_frac=float(frac.min()) if frac.size else 1.0,
            max_qp_residual=float(np.asarray(res.max_qp_residual)),
        )
        if not (ramp_ok and spec_ok):
            self.audit.append(
                "compliance_violation", sample=start, stop=stop,
                ramp_ok=ramp_ok, spectrum_ok=spec_ok,
            )

    # ----------------------------------------------------- manual overrides

    def inject_fault(self, racks: Sequence[int] | int, *, reason: str = "manual"):
        """Trip the given racks' ESS units offline until ``clear_fault``.

        This is the operator's kill switch: it writes the persistent
        ``PDUState.ess_online`` override, which every engine multiplies
        into the effective availability mask — independent of (and in
        addition to) the scenario's stochastic schedule.
        """
        racks = self._check_racks(racks)
        self.state = self.state._replace(
            ess_online=self.state.ess_online.at[jnp.asarray(racks)].set(0.0)
        )
        self.audit.append(
            "manual_fault_injected", sample=self.sample_pos, racks=racks,
            reason=reason,
        )

    def clear_fault(self, racks: Sequence[int] | int):
        """Return manually tripped racks to service."""
        racks = self._check_racks(racks)
        self.state = self.state._replace(
            ess_online=self.state.ess_online.at[jnp.asarray(racks)].set(1.0)
        )
        self.audit.append(
            "manual_fault_cleared", sample=self.sample_pos, racks=racks
        )

    def _check_racks(self, racks) -> list[int]:
        racks = [int(r) for r in np.atleast_1d(np.asarray(racks, dtype=np.int64))]
        bad = [r for r in racks if not 0 <= r < self.n_racks]
        if bad:
            raise ValueError(f"rack indices {bad} outside fleet of {self.n_racks}")
        return racks

    # ------------------------------------------------------ checkpoint/restore

    def checkpoint(self, path: str | os.PathLike) -> str:
        """Write the carried state + stream position to ``path`` (.npz).

        Only valid at an interval boundary, which every ``advance`` stop
        is — the state *is* the interval-boundary carry, so no mid-interval
        capture is possible by construction.
        """
        path = os.fspath(path)
        if not path.endswith(".npz"):
            path += ".npz"  # np.savez appends it; return the real filename
        leaves = jax.tree_util.tree_leaves(self.state)
        np.savez(
            path,
            sample_pos=np.int64(self.sample_pos),
            n_leaves=np.int64(len(leaves)),
            **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)},
        )
        self.audit.append(
            "checkpoint_saved", sample=self.sample_pos, path=path,
        )
        return path

    def restore(self, path: str | os.PathLike) -> None:
        """Load a checkpoint written by ``checkpoint`` into this service.

        The service must be constructed over the same config and scenario
        geometry (the checkpoint stores leaves, the treedef comes from the
        live state); leaf count and shapes are validated.  Continuing with
        ``advance`` reproduces the uninterrupted run bitwise — the
        crash-resume regression test holds this to array equality.
        """
        path = os.fspath(path)
        with np.load(path) as z:
            n = int(z["n_leaves"])
            template = jax.tree_util.tree_leaves(self.state)
            if n != len(template):
                raise ValueError(
                    f"checkpoint has {n} leaves; this service's state has "
                    f"{len(template)} — config/scenario mismatch"
                )
            leaves = []
            for i, t in enumerate(template):
                arr = z[f"leaf_{i}"]
                if arr.shape != np.asarray(t).shape:
                    raise ValueError(
                        f"checkpoint leaf {i} shape {arr.shape} != expected "
                        f"{np.asarray(t).shape} — config/scenario mismatch"
                    )
                leaves.append(jnp.asarray(arr))
            treedef = jax.tree_util.tree_structure(self.state)
            self.state = jax.tree_util.tree_unflatten(treedef, leaves)
            self.sample_pos = int(z["sample_pos"])
        self._last_result = None
        self.audit.append("restored", sample=self.sample_pos, path=path)

    # --------------------------------------------------------------- status

    def status(self) -> dict:
        """JSON-safe streaming snapshot for dashboards/health endpoints."""
        manual_off = [
            int(i) for i in np.flatnonzero(np.asarray(self.state.ess_online) <= 0.0)
        ]
        out = dict(
            sample_pos=self.sample_pos,
            position_s=self.position_s,
            total_samples=int(self.scenario.total_samples),
            exhausted=self.exhausted,
            n_racks=self.n_racks,
            degraded_active=self._degraded_now,
            manual_offline_racks=manual_off,
            audit_events=len(self.audit),
        )
        res = self._last_result
        if res is not None:
            frac = np.asarray(res.ess_online_frac)
            out.update(
                last_window=dict(
                    ramp_ok=bool(np.asarray(res.report_grid.ramp_ok)),
                    spectrum_ok=bool(np.asarray(res.report_grid.spectrum_ok)),
                    min_online_frac=float(frac.min()) if frac.size else 1.0,
                    mean_online_frac=float(frac.mean()) if frac.size else 1.0,
                    max_qp_residual=float(np.asarray(res.max_qp_residual)),
                ),
                health=hlt.fleet_summary(res.health, json_safe=True),
            )
        # Strict-JSON guarantee: this must always survive allow_nan=False.
        json.dumps(out, allow_nan=False)
        return out
