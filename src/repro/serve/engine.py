"""Prefill/decode serving engine.

``build_prefill_step``/``build_decode_step`` return the pure functions the
dry-run lowers per (arch x decode shape); ``ServeEngine`` wraps them into a
batched greedy/temperature generation loop with a KV-cache pool — the
"serve a small model with batched requests" example driver uses it.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as ED
from repro.models import transformer as T


def build_prefill_step(cfg: ModelConfig) -> Callable:
    if cfg.family == "audio":
        def prefill(params, tokens, frames):
            state = ED.init_decode_state(params, cfg, frames, tokens.shape[0], tokens.shape[1])
            logits, state = ED.decode_step(params, cfg, tokens, state, jnp.asarray(0, jnp.int32), prefill=True)
            return logits, state
        return prefill

    def prefill(params, tokens, max_len: int):
        state = T.init_decode_state(cfg, tokens.shape[0], max_len)
        logits, state = T.decode_step(params, cfg, tokens, state, jnp.asarray(0, jnp.int32), prefill=True)
        return logits, state

    return prefill


def build_decode_step(cfg: ModelConfig) -> Callable:
    mod = ED if cfg.family == "audio" else T

    def decode(params, token, state, pos):
        return mod.decode_step(params, cfg, token, state, pos)

    return decode


class ServeEngine:
    """Batched greedy generation over the decode step (CPU-scale demos)."""

    def __init__(self, cfg: ModelConfig, params: Any, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, tok, st, pos: build_decode_step(cfg)(p, tok, st, pos)
        )

    def generate(
        self, prompts: jax.Array, n_tokens: int, *, frames: jax.Array | None = None,
        temperature: float = 0.0, key: jax.Array | None = None,
    ) -> jax.Array:
        cfg = self.cfg
        b, t0 = prompts.shape
        if cfg.family == "audio":
            state = ED.init_decode_state(self.params, cfg, frames, b, self.max_len)
        else:
            state = T.init_decode_state(cfg, b, self.max_len)
        logits, state = self._decode(self.params, prompts, state, jnp.asarray(0, jnp.int32))
        out = [prompts]
        tok = self._sample(logits[:, -1:], temperature, key, 0)
        for i in range(n_tokens - 1):
            out.append(tok)
            logits, state = self._decode(self.params, tok, state, jnp.asarray(t0 + i, jnp.int32))
            tok = self._sample(logits[:, -1:], temperature, key, i + 1)
        out.append(tok)
        return jnp.concatenate(out, axis=1)

    def _sample(self, logits, temperature, key, i):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(k, logits / temperature, axis=-1).astype(jnp.int32)
