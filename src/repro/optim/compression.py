"""int8 gradient compression with error feedback.

At 1000+ node scale the cross-pod (DCN) gradient all-reduce is the
bandwidth bottleneck; int8 quantization cuts it 4x vs fp32 (2x vs bf16).
Error feedback (Seide et al. / 1-bit SGD lineage) accumulates the
quantization residual locally and re-injects it next step, which keeps
SGD/Adam convergence intact (validated in tests on a quadratic and on the
synthetic LM).

Usage inside a train step:
    q, state = compress_int8(grads, state)     # before the DCN all-reduce
    grads = decompress_int8(q)                 # after
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    values: Any  # int8 pytree
    scales: Any  # fp32 per-leaf scale


class CompressionState(NamedTuple):
    error: Any  # fp32 residual pytree


def init_compression(params: Any) -> CompressionState:
    return CompressionState(
        error=jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def compress_int8(grads: Any, state: CompressionState) -> tuple[Quantized, CompressionState]:
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_e = g32 - q.astype(jnp.float32) * scale
        return q, scale, new_e

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = td.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        Quantized(
            values=td.unflatten([o[0] for o in out]),
            scales=td.unflatten([o[1] for o in out]),
        ),
        CompressionState(error=td.unflatten([o[2] for o in out])),
    )


def decompress_int8(q: Quantized) -> Any:
    return jax.tree_util.tree_map(
        lambda v, s: v.astype(jnp.float32) * s, q.values, q.scales
    )
