"""AdamW with global-norm clipping and configurable state dtype.

State dtype matters at scale: fp32 m/v for <100B models; bf16 m/v for the
deepseek-scale MoEs where optimizer HBM dominates (EXPERIMENTS.md §Dry-run
reports both).  The update math always runs in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"  # "bfloat16" for huge models


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
) -> tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    dt = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32) * jnp.ones(())}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
