"""Optimizer substrate: AdamW (configurable state dtype), global-norm
clipping, LR schedules, int8 gradient compression with error feedback."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule, linear_warmup
from repro.optim.compression import compress_int8, decompress_int8, CompressionState

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update",
    "cosine_schedule", "linear_warmup",
    "compress_int8", "decompress_int8", "CompressionState",
]
