"""LR schedules (return a multiplier on the base LR)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int):
    s = jnp.asarray(step, jnp.float32)
    return jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1))


def cosine_schedule(step, total_steps: int, warmup_steps: int = 0, final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, warmup_steps)
    frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return warm * cos
