"""zamba2-2.7b: hybrid 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf]"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab_size=32000,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=64),
        hybrid=HybridConfig(shared_every=6, shared_block_heads=32),
        norm="rmsnorm", dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16),
        hybrid=HybridConfig(shared_every=2, shared_block_heads=4),
        norm="rmsnorm", pad_vocab_multiple=64,
    )
