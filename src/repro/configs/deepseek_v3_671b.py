"""deepseek-v3-671b: MoE 61L d_model=7168 128H d_expert=2048 vocab=129280,
256 routed top-8, 1 shared — MLA, aux-loss-free sigmoid router, MTP
[arXiv:2412.19437; hf]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432, vocab_size=129280,
        attention="mla",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_experts=256, experts_per_token=8, n_shared_experts=1,
                      d_expert=2048, first_dense_layers=3,
                      router="sigmoid_bias", capacity_factor=1.25),
        mtp_depth=1,
        ffn="swiglu", norm="rmsnorm", dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512,
        attention="mla",
        mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                      qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(n_experts=8, experts_per_token=2, n_shared_experts=1,
                      d_expert=64, first_dense_layers=1,
                      router="sigmoid_bias", capacity_factor=4.0),
        mtp_depth=1,
        ffn="swiglu", norm="rmsnorm", pad_vocab_multiple=64,
    )
