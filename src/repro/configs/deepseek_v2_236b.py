"""deepseek-v2-236b: MoE 60L d_model=5120 128H d_expert=1536 vocab=102400,
160 routed experts top-6, 2 shared — MLA kv_lora=512  [arXiv:2405.04434; hf]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=12288, vocab_size=102400,
        attention="mla",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_experts=160, experts_per_token=6, n_shared_experts=2,
                      d_expert=1536, first_dense_layers=1,
                      router="softmax_topk", capacity_factor=1.25),
        ffn="swiglu", norm="rmsnorm", dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke", family="moe",
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512,
        attention="mla",
        mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                      qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(n_experts=8, experts_per_token=2, n_shared_experts=2,
                      d_expert=64, first_dense_layers=1,
                      router="softmax_topk", capacity_factor=4.0),
        ffn="swiglu", norm="rmsnorm", pad_vocab_multiple=64,
    )
