"""chatglm3-6b: dense 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d (half-dim), GQA  [arXiv:2406.12793; hf]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab_size=65024,
        rope_fraction=0.5, ffn="swiglu", norm="rmsnorm",
        qkv_bias=True, dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512,
        rope_fraction=0.5, qkv_bias=True,
        pad_vocab_multiple=64,
    )
