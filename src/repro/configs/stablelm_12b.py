"""stablelm-12b: dense 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352  [hf:stabilityai/stablelm-2-12b family; hf]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=13824, vocab_size=100352,
        qkv_bias=False, ffn="swiglu", norm="layernorm",
        rope_theta=10_000.0, dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512,
        qkv_bias=False, ffn="swiglu", norm="layernorm",
        pad_vocab_multiple=64,
    )
