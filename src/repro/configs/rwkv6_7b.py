"""rwkv6-7b (Finch): attention-free 32L d_model=4096 d_ff=14336
vocab=65536 — data-dependent decay  [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, RWKVConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
        d_ff=14336, vocab_size=65536,
        attention="none",
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
        norm="rmsnorm", dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        d_ff=256, vocab_size=512,
        attention="none",
        rwkv=RWKVConfig(head_dim=64, decay_lora=16, mix_lora=8),
        norm="rmsnorm", pad_vocab_multiple=64,
    )
