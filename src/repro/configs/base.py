"""Model/config system for the assigned architectures.

One ``ModelConfig`` describes every family (dense / MoE+MLA / SSM / hybrid /
VLM / enc-dec audio); family-specific knobs live in optional sub-blocks.
Configs are plain frozen dataclasses — hashable, printable, diffable — and
each assigned architecture file in this package exports

    full()   -> the exact published configuration (dry-run only)
    smoke()  -> a reduced same-family configuration (CPU tests)

Shapes for the dry-run grid come from ``repro.configs.shapes``.
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""

    q_lora_rank: int | None  # None = full-rank queries
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    n_shared_experts: int = 0
    d_expert: int = 0  # expert hidden dim (deepseek "moe_intermediate_size")
    first_dense_layers: int = 1  # leading layers with dense FFN
    router: Literal["softmax_topk", "sigmoid_bias"] = "softmax_topk"
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD parameters (zamba2)."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64  # LoRA rank of the data-dependent decay
    mix_lora: int = 32  # LoRA rank of the token-shift mixers


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: SSM backbone + a shared attention block every k layers.

    The shared block's weights are reused at every application (one copy);
    its input is concat(hidden, initial embedding) projected back down.
    """

    shared_every: int = 6
    shared_block_heads: int = 32


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder."""

    encoder_layers: int = 32
    encoder_seq: int = 1500  # mel frames after the (stubbed) conv frontend
    frontend: Literal["stub"] = "stub"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # attention
    attention: Literal["gqa", "mla", "none"] = "gqa"
    head_dim: int | None = None  # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # chatglm applies RoPE to half the head dim
    # blocks
    ffn: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    qk_norm: bool = False  # chameleon
    tie_embeddings: bool = False
    # family sub-configs
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    hybrid: HybridConfig | None = None
    encdec: EncDecConfig | None = None
    # multi-token prediction (deepseek-v3)
    mtp_depth: int = 0
    # housekeeping
    pad_vocab_multiple: int = 256
    scan_layers: bool = True
    remat: Literal["none", "block"] = "block"
    dtype: str = "float32"  # activation/param dtype ("bfloat16" for dry-run)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        return -(-self.vocab_size // m) * m

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6*N*D accounting."""
        d = self.d_model
        v = self.padded_vocab
        hd = self.resolved_head_dim
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        enc_layers = self.encdec.encoder_layers if self.encdec else 0
        for _ in range(enc_layers):
            n += 4 * d * d + 3 * d * self.d_ff  # enc block (swiglu approx)
        per_layer = 0
        if self.attention == "gqa" and self.family != "hybrid":
            # hybrid backbones are attention-free; the shared block's
            # attention is counted once below
            q = self.n_heads * hd
            kv = self.n_kv_heads * hd
            per_layer += d * q + 2 * d * kv + q * d
        elif self.attention == "mla":
            m = self.mla
            qd = (m.qk_rope_head_dim + m.qk_nope_head_dim) * self.n_heads
            if m.q_lora_rank:
                per_layer += d * m.q_lora_rank + m.q_lora_rank * qd
            else:
                per_layer += d * qd
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        if self.rwkv is not None:
            per_layer += 4 * d * d + 2 * d * self.d_ff  # time-mix + channel-mix
        elif self.ssm is not None and self.family in ("ssm", "hybrid"):
            di = self.ssm.expand * d
            per_layer += d * 2 * di + di * d + di * (2 * self.ssm.d_state)
        gates = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.ffn]
        if self.family == "hybrid":
            # Mamba2 backbone layers carry no FFN; the FFN lives in the ONE
            # shared attention block (weights reused at every application).
            n += self.n_layers * per_layer
            hd_s = d // self.hybrid.shared_block_heads
            shared = 4 * d * d + gates * d * self.d_ff + 2 * d * d
            n += shared
        elif self.moe is None:
            if self.rwkv is None:
                per_layer += gates * d * self.d_ff
            n += self.n_layers * per_layer
        else:
            mo = self.moe
            dense_ffn = gates * d * self.d_ff
            expert_ffn = gates * d * mo.d_expert
            moe_ffn = (mo.n_experts + mo.n_shared_experts) * expert_ffn
            n += mo.first_dense_layers * (per_layer + dense_ffn)
            n += (self.n_layers - mo.first_dense_layers) * (per_layer + moe_ffn)
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: routed experts_per_token)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        mo = self.moe
        gates = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.ffn]
        expert_ffn = gates * self.d_model * mo.d_expert
        n_moe_layers = self.n_layers - mo.first_dense_layers
        inactive = n_moe_layers * (mo.n_experts - mo.experts_per_token) * expert_ffn
        return full - inactive
