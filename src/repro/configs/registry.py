"""Architecture registry: --arch <id> -> (full config, smoke config)."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_IDS = (
    "stablelm_12b",
    "llama3_2_1b",
    "qwen1_5_4b",
    "chatglm3_6b",
    "deepseek_v2_236b",
    "deepseek_v3_671b",
    "rwkv6_7b",
    "zamba2_2_7b",
    "chameleon_34b",
    "whisper_large_v3",
)

# CLI aliases with the original punctuation
ALIASES = {
    "stablelm-12b": "stablelm_12b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen1.5-4b": "qwen1_5_4b",
    "chatglm3-6b": "chatglm3_6b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "rwkv6-7b": "rwkv6_7b",
    "zamba2-2.7b": "zamba2_2_7b",
    "chameleon-34b": "chameleon_34b",
    "whisper-large-v3": "whisper_large_v3",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS + tuple(ALIASES))}")
    return importlib.import_module(f"repro.configs.{name}")


def full_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).full()
    return _override(cfg, overrides)


def smoke_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).smoke()
    return _override(cfg, overrides)


def step_cost(arch: str, *, tokens_per_step: float = 2**20, opt_bytes: float = 18.0):
    """Per-step aggregate cost of training ``arch``: the bridge from the 10
    assigned model configs to the power layer's phase/scenario models.

    FLOPs use the standard 6*N_active*tokens accounting; HBM traffic is the
    per-step parameter/gradient/optimizer sweep (``opt_bytes`` bytes per
    parameter ~ bf16 params+grads + fp32 m/v read+write, amortized);
    collective bytes are a 2-pass bf16 ring all-reduce of the gradients.
    Returns ``repro.power.phases.StepCost``.
    """
    from repro.power.phases import StepCost

    cfg = full_config(arch)
    n_full = cfg.param_count()
    n_active = cfg.active_param_count()
    return StepCost(
        flops=6.0 * n_active * tokens_per_step,
        hbm_bytes=opt_bytes * n_full,
        collective_bytes=4.0 * n_full,
    )


def _override(cfg: ModelConfig, overrides) -> ModelConfig:
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
