"""Architecture registry: --arch <id> -> (full config, smoke config)."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_IDS = (
    "stablelm_12b",
    "llama3_2_1b",
    "qwen1_5_4b",
    "chatglm3_6b",
    "deepseek_v2_236b",
    "deepseek_v3_671b",
    "rwkv6_7b",
    "zamba2_2_7b",
    "chameleon_34b",
    "whisper_large_v3",
)

# CLI aliases with the original punctuation
ALIASES = {
    "stablelm-12b": "stablelm_12b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen1.5-4b": "qwen1_5_4b",
    "chatglm3-6b": "chatglm3_6b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "rwkv6-7b": "rwkv6_7b",
    "zamba2-2.7b": "zamba2_2_7b",
    "chameleon-34b": "chameleon_34b",
    "whisper-large-v3": "whisper_large_v3",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS + tuple(ALIASES))}")
    return importlib.import_module(f"repro.configs.{name}")


def full_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).full()
    return _override(cfg, overrides)


def smoke_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).smoke()
    return _override(cfg, overrides)


def _override(cfg: ModelConfig, overrides) -> ModelConfig:
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
