"""Assigned input shapes and the (arch x shape) dry-run grid.

  train_4k     seq 4,096   global_batch 256   (training)
  prefill_32k  seq 32,768  global_batch 32    (inference prefill)
  decode_32k   seq 32,768  global_batch 128   (decode: 1 new token, full cache)
  long_500k    seq 524,288 global_batch 1     (long-context decode)

``long_500k`` requires sub-quadratic attention: it runs for the SSM/hybrid
archs (rwkv6-7b, zamba2-2.7b) and is SKIPPED for pure full-attention archs
(see DESIGN.md §Arch-applicability).  Whisper is enc-dec (decoder present),
so decode shapes apply with the cross-memory fixed at 1500 frames.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

# Families allowed to run long_500k (sub-quadratic sequence mixing).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shapes_for(family: str) -> tuple[ShapeSpec, ...]:
    if family in SUBQUADRATIC_FAMILIES:
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)


def cell_id(arch: str, shape: ShapeSpec) -> str:
    return f"{arch}/{shape.name}"
