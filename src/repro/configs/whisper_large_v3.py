"""whisper-large-v3: enc-dec audio, 32L decoder d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866 — conv frontend STUB (input_specs provides frame
embeddings)  [arXiv:2212.04356; unverified]"""
from repro.configs.base import EncDecConfig, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        encdec=EncDecConfig(encoder_layers=32, encoder_seq=1500),
        rope_fraction=0.0, ffn="gelu", norm="layernorm", dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512,
        encdec=EncDecConfig(encoder_layers=2, encoder_seq=64),
        rope_fraction=0.0, ffn="gelu", norm="layernorm", pad_vocab_multiple=64,
    )
