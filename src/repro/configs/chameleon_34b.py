"""chameleon-34b: early-fusion VLM 48L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=65536 — VQ image tokens share the text vocabulary, so the
modality frontend is the (stub) tokenizer; qk-norm for stability
[arXiv:2405.09818; unverified]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=65536,
        qk_norm=True, ffn="swiglu", norm="rmsnorm", dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke", family="vlm",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512,
        qk_norm=True, ffn="swiglu", norm="rmsnorm", pad_vocab_multiple=64,
    )
