"""qwen1.5-4b: dense 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias  [hf:Qwen/Qwen1.5-4B family; hf]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
        d_ff=6912, vocab_size=151936,
        qkv_bias=True, ffn="swiglu", norm="rmsnorm",
        rope_theta=1_000_000.0, dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b-smoke", family="dense",
        n_layers=2, d_model=120, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512,
        qkv_bias=True, ffn="swiglu", norm="rmsnorm",
        pad_vocab_multiple=64,
    )
