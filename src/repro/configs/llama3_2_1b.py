"""llama3.2-1b: dense 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3  [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab_size=128256,
        head_dim=64, ffn="swiglu", norm="rmsnorm",
        rope_theta=500_000.0, tie_embeddings=True, dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32,
        ffn="swiglu", norm="rmsnorm", tie_embeddings=True,
        pad_vocab_multiple=64,
    )
