"""Architecture configs (assigned pool) + shapes + registry."""
from repro.configs.base import ModelConfig
from repro.configs.registry import ALIASES, ARCH_IDS, full_config, smoke_config
from repro.configs.shapes import ALL_SHAPES, ShapeSpec, shapes_for

__all__ = [
    "ModelConfig", "ARCH_IDS", "ALIASES", "full_config", "smoke_config",
    "ALL_SHAPES", "ShapeSpec", "shapes_for",
]
