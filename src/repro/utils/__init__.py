"""Small shared utilities: pytree dataclasses, registries, logging."""
from repro.utils.structures import pytree_dataclass, static_field
from repro.utils.registry import Registry

__all__ = ["pytree_dataclass", "static_field", "Registry"]
