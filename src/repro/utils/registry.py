"""Tiny name -> factory registry used for architectures, kernels, etc."""
from __future__ import annotations

from typing import Callable, Dict, Generic, Iterator, TypeVar

T = TypeVar("T")


class Registry(Generic[T]):
    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, T] = {}

    def register(self, name: str) -> Callable[[T], T]:
        def deco(obj: T) -> T:
            if name in self._items:
                raise ValueError(f"duplicate {self.kind} registration: {name!r}")
            self._items[name] = obj
            return obj

        return deco

    def __getitem__(self, name: str) -> T:
        try:
            return self._items[name]
        except KeyError:
            known = ", ".join(sorted(self._items))
            raise KeyError(f"unknown {self.kind} {name!r}; known: {known}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._items))

    def names(self) -> list[str]:
        return sorted(self._items)
