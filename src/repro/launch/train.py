"""Training launcher: --arch <id> picks any assigned architecture (smoke
scale on CPU; the full configs are exercised via dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 50
"""
from __future__ import annotations

import argparse

from repro.configs import smoke_config
from repro.configs.registry import ALIASES, ARCH_IDS
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.power.integration import PowerSim
from repro.power.phases import HardwareConstants, PhaseModel, StepCost
from repro.train import TrainConfig, train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    help=f"one of {sorted(ALIASES) + list(ARCH_IDS)}")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--power-sim", action="store_true")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    sim = None
    if args.power_sim:
        n = cfg.param_count()
        sim = PowerSim(
            StepCost(flops=6.0 * n * args.batch * args.seq * 1e3,
                     hbm_bytes=1e15, collective_bytes=2e14),
            HardwareConstants(chips=256),
            PhaseModel(),
        )
    res = train(
        cfg,
        DataConfig(batch=args.batch, seq_len=args.seq, vocab_size=cfg.vocab_size),
        AdamWConfig(lr=args.lr),
        TrainConfig(steps=args.steps, log_every=max(args.steps // 10, 1),
                    checkpoint_dir=args.ckpt_dir, resume=args.resume,
                    microbatches=args.microbatches),
        power_sim=sim,
    )
    for rec in res["history"]:
        print(rec)
    if sim is not None:
        print("power:", res["power_report"])


if __name__ == "__main__":
    main()
