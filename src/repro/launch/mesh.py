"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run forces 512 host devices before any
jax import; tests and benches see the single real device).
"""
from __future__ import annotations

import jax

from repro.sharding.rules import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip pod, or 2x16x16 = 512-chip two-pod mesh.

    DP runs over ("pod","data") — cross-pod traffic is only the small
    gradient/optimizer reduction over the pod axis (DCN-friendly); TP/EP
    stay inside a pod on the "model" axis (ICI).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1):
    """Debug/test mesh over whatever devices exist (usually 1 CPU)."""
    n = jax.device_count()
    mp = min(model_parallel, n)
    return make_mesh(
        (n // mp, mp), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )
