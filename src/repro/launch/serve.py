"""Serving launcher: batched generation with any assigned architecture
(smoke scale on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --requests 4
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    key = jax.random.key(0)
    params = (ED if cfg.family == "audio" else T).init(key, cfg)
    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.gen_tokens + 8)
    prompts = jax.random.randint(
        jax.random.key(1), (args.requests, args.prompt_len), 0, cfg.vocab_size
    )
    frames = None
    if cfg.family == "audio":
        frames = (jax.random.normal(
            jax.random.key(2), (args.requests, cfg.encdec.encoder_seq, cfg.d_model)
        ) * 0.02).astype(cfg.dtype)
    out = engine.generate(prompts, args.gen_tokens, frames=frames,
                          temperature=args.temperature, key=jax.random.key(3))
    for i in range(args.requests):
        print(f"req{i}: {np.asarray(out[i])[-args.gen_tokens:].tolist()}")


if __name__ == "__main__":
    main()
