"""Launchers: production mesh, multi-pod dry-run, train/serve entry points.

NOTE: do not import repro.launch.dryrun from library code — it force-sets
the XLA device count at import (dry-run only).
"""
