"""Roofline analysis from compiled HLO (EXPERIMENTS.md §Roofline).

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically — flops identical for 2- and 8-layer scans), so scanned-layer
models need execution-count-aware accounting.  This module parses the
post-SPMD compiled HLO text:

  * builds the computation call graph (entry -> while bodies/conds,
    fusions, calls) with **while trip counts** recovered from the largest
    integer constant in each loop's condition computation (JAX scans lower
    to ``lt(i, L)``);
  * FLOPs: every ``dot`` op -> 2 * prod(output) * K (K = contracted size
    from the operand symbol table), times its computation's execution
    multiplier; convolutions counted analogously;
  * HBM bytes: operand + output bytes of top-level (post-fusion) ops,
    skipping pure aliasing ops (bitcast/tuple/get-tuple-element/parameter);
  * collective bytes: ring-model wire volume per device —
      all-gather        (g-1) * input
      reduce-scatter    (g-1)/g * input
      all-reduce        2 (g-1)/g * buffer
      all-to-all        (g-1)/g * input
      collective-permute input
    with the group size g parsed from ``replica_groups=[n,g]<=[...]``.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  Terms are reported in seconds; the max of the three is
the bottleneck.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_ALIAS_OPS = {
    "bitcast", "tuple", "get-tuple-element", "parameter", "constant",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "optimization-barrier",
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link
    chips: int = 256


@dataclasses.dataclass
class RooflineTerms:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


# ------------------------------------------------------------- HLO parse --


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, summing tuple elements."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class _Op:
    var: str
    opcode: str
    type_str: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list[_Op]
    vars: dict  # var -> type_str


# The type can be a simple shaped type (f32[16,256]{1,0}) or a TUPLE type
# with spaces ((s32[], f32[16,256]{1,0}, ...)) — while/tuple ops use these.
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)\((.*)$"
)
# Computation headers: `%name (params...) -> type {` — params may contain
# nested parens (tuple types), so match greedily to the trailing `-> ... {`.
_COMP_HEAD = re.compile(r"^\s*(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def parse_hlo(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_HEAD.match(line)
        if m and not line.lstrip().startswith("%param"):
            cur = _Computation(name=m.group(1), ops=[], vars={})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_LINE.match(line)
        if not om:
            continue
        var, type_str, opcode, rest = om.groups()
        operands = re.findall(r"(%[\w.\-]+)", rest.split(", metadata=")[0])
        cur.ops.append(_Op(var=var, opcode=opcode, type_str=type_str,
                           operands=operands, line=line))
        cur.vars[var] = type_str
    return comps


def _cond_names(comps: dict[str, _Computation]) -> set[str]:
    """Names of computations used as a while condition."""
    out = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                m = re.search(r"condition=(%[\w.\-]+)", op.line)
                if m:
                    out.add(m.group(1))
    return out


def _while_trip_counts(comps: dict[str, _Computation]) -> dict[str, int]:
    """cond-computation name -> trip count.

    Only computations actually referenced as ``condition=`` of a while op
    are considered (a naive constant sweep would pick up vocab-size
    constants from unrelated fusions).  JAX scans compare the counter
    against the bound with LT, so the bound is the max scalar int constant
    reachable from the condition (including via its fusions).
    """
    conds = _cond_names(comps)
    out = {}
    for name in conds:
        comp = comps.get(name)
        if comp is None:
            continue
        consts: list[int] = []

        def collect(c: _Computation, depth=0):
            if depth > 4:
                return
            for op in c.ops:
                if op.opcode == "constant":
                    m = re.search(r"constant\((-?\d+)\)", op.line)
                    if m:
                        consts.append(int(m.group(1)))
                for cal in re.findall(r"(?:calls|to_apply)=(%[\w.\-]+)", op.line):
                    if cal in comps:
                        collect(comps[cal], depth + 1)
                # fusions may reference constants defined in this computation
                # (already collected) or pass them as operands (also here).

        collect(comp)
        if consts:
            out[name] = max(consts)
    return out


def _multipliers(
    comps: dict[str, _Computation], entry: str
) -> tuple[dict[str, float], set[str]]:
    """Execution count per computation, walking whiles/fusions/calls.

    Returns (multipliers, hbm_comps): the latter is the set of computations
    whose ops are *top-level* (entry, while bodies/conds, calls) — fusion
    and to_apply callees execute in registers/VMEM and must not contribute
    to the HBM-bytes estimate (their dots still count FLOPs).
    """
    trip = _while_trip_counts(comps)
    mult: dict[str, float] = defaultdict(float)
    hbm_comps: set[str] = set()

    def visit(name: str, m: float, depth=0, top=True):
        if name not in comps or depth > 50:
            return
        mult[name] += m
        if top:
            hbm_comps.add(name)
        for op in comps[name].ops:
            if op.opcode == "while":
                cm = re.search(r"condition=(%[\w.\-]+)", op.line)
                bm = re.search(r"body=(%[\w.\-]+)", op.line)
                t = max(trip.get(cm.group(1), 1) if cm else 1, 1)
                if bm:
                    visit(bm.group(1), m * t, depth + 1, top)
                if cm:
                    visit(cm.group(1), m * (t + 1), depth + 1, top)
            elif op.opcode == "call":
                for cal in re.findall(r"to_apply=(%[\w.\-]+)", op.line):
                    visit(cal, m, depth + 1, top)
            elif op.opcode in ("fusion", "custom-call", "map", "reduce",
                               "reduce-window", "sort", "scatter",
                               "select-and-scatter", "all-reduce",
                               "reduce-scatter"):
                for cal in re.findall(r"(?:calls|to_apply)=(%[\w.\-]+)", op.line):
                    visit(cal, m, depth + 1, False)
        return

    visit(entry, 1.0)
    return dict(mult), hbm_comps


def _entry_name(comps: dict[str, _Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+(%[\w.\-]+)", text, re.M)
    if m:
        return m.group(1)
    # fall back: computation named like main
    for name in comps:
        if "main" in name:
            return name
    return next(iter(comps))


@dataclasses.dataclass
class HLOCosts:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: dict
    n_collectives: int
    while_trip_counts: dict


def analyze_compiled_hlo(text: str) -> HLOCosts:
    comps = parse_hlo(text)
    entry = _entry_name(comps, text)
    mult, hbm_comps = _multipliers(comps, entry)

    flops = 0.0
    hbm = 0.0
    coll = 0.0
    coll_break: dict[str, float] = defaultdict(float)
    n_coll = 0

    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        count_hbm = name in hbm_comps
        for op in comp.ops:
            out_bytes = _shape_bytes(op.type_str)
            opc = op.opcode
            if opc == "dot":
                _, out_dims = _shape_dims(op.type_str)
                lhs_t = comp.vars.get(op.operands[0] if op.operands else "", "")
                _, lhs_dims = _shape_dims(lhs_t)
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
                k = 1
                if cdims and lhs_dims:
                    for d in cdims.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            k *= lhs_dims[int(d)]
                flops += m * 2.0 * math.prod(out_dims or [0]) * k
            elif opc == "convolution":
                # rough: 2 * output * (kernel_elems * in_ch) — parse kernel operand
                _, out_dims = _shape_dims(op.type_str)
                rhs_t = comp.vars.get(op.operands[1] if len(op.operands) > 1 else "", "")
                _, rhs_dims = _shape_dims(rhs_t)
                flops += m * 2.0 * math.prod(out_dims or [0]) * max(
                    math.prod(rhs_dims or [1]) // max(out_dims[-1] if out_dims else 1, 1), 1
                )
            if opc in COLLECTIVES or any(opc.startswith(c) for c in COLLECTIVES):
                base = opc.split(".")[0]
                gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.line)
                g = int(gm.group(2)) if gm else 1
                in_bytes = sum(
                    _shape_bytes(comp.vars.get(o, "")) for o in op.operands
                    if o in comp.vars
                ) or out_bytes
                if base == "all-gather":
                    wire = (g - 1) * in_bytes
                elif base == "all-reduce":
                    wire = 2.0 * (g - 1) / max(g, 1) * out_bytes
                elif base == "reduce-scatter":
                    wire = (g - 1) / max(g, 1) * in_bytes
                elif base == "all-to-all":
                    wire = (g - 1) / max(g, 1) * in_bytes
                else:  # collective-permute
                    wire = in_bytes
                coll += m * wire
                coll_break[base] += m * wire
                n_coll += 1
            if count_hbm and opc not in _ALIAS_OPS and opc != "while":
                # Op-aware traffic model: write output once, read operands
                # once — except ops that only touch a slice-sized window of
                # a big operand (dynamic-slice reads its output's worth;
                # dynamic-update-slice writes the update in place) and ops
                # that generate rather than read (broadcast/iota).
                if opc == "dynamic-slice":
                    traffic = 2 * out_bytes
                elif opc == "dynamic-update-slice":
                    upd = (
                        _shape_bytes(comp.vars.get(op.operands[1], ""))
                        if len(op.operands) > 1
                        else out_bytes
                    )
                    traffic = 2 * upd
                elif opc in ("broadcast", "iota"):
                    traffic = out_bytes
                else:
                    in_bytes = sum(
                        _shape_bytes(comp.vars.get(o, "")) for o in op.operands
                        if o in comp.vars
                    )
                    traffic = out_bytes + in_bytes
                hbm += m * traffic

    return HLOCosts(
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        collective_bytes_per_device=coll,
        collective_breakdown=dict(coll_break),
        n_collectives=n_coll,
        while_trip_counts=_while_trip_counts(comps),
    )


# ------------------------------------------------------------- terms ------


def roofline_terms(costs: HLOCosts, hw: HardwareModel, *, ici_links: int = 4) -> RooflineTerms:
    """Three roofline terms in seconds (per chip; the mesh is SPMD)."""
    compute_s = costs.flops_per_device / hw.peak_flops
    memory_s = costs.hbm_bytes_per_device / hw.hbm_bw
    collective_s = costs.collective_bytes_per_device / (hw.ici_bw * ici_links)
    return RooflineTerms(
        flops_per_chip=costs.flops_per_device,
        hbm_bytes_per_chip=costs.hbm_bytes_per_device,
        collective_bytes_per_chip=costs.collective_bytes_per_device,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
    )


def model_flops(cfg, shape, *, backward: bool) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (forward-only), N = active params."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                   (shape.seq_len if shape.kind == "prefill" else 1))
    per_tok = 6.0 * n if backward else 2.0 * n
    return per_tok * tokens
