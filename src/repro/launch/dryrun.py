import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production meshes and extract roofline inputs.

The two lines above MUST run before any jax import: jax locks the device
count at first init, and the dry-run needs 512 placeholder host devices to
build the 16x16 single-pod and 2x16x16 multi-pod meshes.  (Only this
script forces the device count — tests/benches see the real device.)

Per cell this script:
  1. builds the step function (train_step with full AdamW update /
     serve prefill / serve decode against a full cache),
  2. jit-lowers it with in/out shardings from ``repro.sharding.rules``
     against ShapeDtypeStruct inputs (no allocation anywhere),
  3. compiles, prints ``memory_analysis()`` (fits-or-not) and
     ``cost_analysis()``,
  4. parses the compiled HLO for trip-count-corrected FLOPs / HBM bytes /
     collective wire bytes (see ``repro.launch.roofline``),
  5. emits one JSON record under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only-cells N]
"""
import argparse


import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import full_config, shapes_for
from repro.configs.registry import ALIASES, ARCH_IDS
from repro.configs.shapes import ShapeSpec
from repro.data import make_batch_specs
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as RL
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.sharding import rules
from repro.train.step import build_train_step


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _param_shapes(cfg):
    init_fn = ED.init if cfg.family == "audio" else T.init
    return jax.eval_shape(lambda k: init_fn(k, cfg), jax.random.key(0))


def build_cell(cfg, shape: ShapeSpec, mesh, *, opt_dtype: str, microbatches: int = 8,
               gather_once: bool = False):
    """-> (fn, arg_specs (ShapeDtypeStructs), in_shardings, out_shardings)."""
    pspec = _param_shapes(cfg)
    notes: list = []
    param_sh = _named(mesh, rules.param_specs(pspec, mesh, notes=notes))
    batch_specs = make_batch_specs(cfg, shape)
    b_sh = NamedSharding(mesh, rules.batch_spec(mesh, shape.global_batch, pod="pod" in mesh.shape))
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_cfg = AdamWConfig(state_dtype=opt_dtype)
        ostate = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), pspec)
        opt_sh = type(ostate)(step=repl, m=param_sh, v=param_sh)
        # Gradient accumulation: 8 microbatches keeps the live activation
        # set at ~2 sequences/device (how one actually trains these sizes);
        # the scan multiplies the per-layer collective schedule, which the
        # roofline parser accounts for via while trip counts.
        step_fn = build_train_step(cfg, opt_cfg, microbatches=microbatches,
                                   gather_small_weights_once=gather_once)
        args = (pspec, ostate, batch_specs, jax.ShapeDtypeStruct((), jnp.int32))
        batch_sh = {k: b_sh for k in batch_specs}
        in_sh = (param_sh, opt_sh, batch_sh, repl)
        out_sh = (param_sh, opt_sh, None)
        return step_fn, args, in_sh, out_sh, notes

    if shape.kind == "prefill":
        if cfg.family == "audio":
            def fn(params, tokens, frames):
                state = ED.init_decode_state(params, cfg, frames, tokens.shape[0], shape.seq_len)
                logits, state = ED.decode_step(params, cfg, tokens, state,
                                               jnp.asarray(0, jnp.int32), prefill=True)
                return logits[:, -1:], state

            args = (pspec, batch_specs["tokens"], batch_specs["frames"])
            in_sh = (param_sh, b_sh, b_sh)
        else:
            def fn(params, tokens):
                state = T.init_decode_state(cfg, tokens.shape[0], shape.seq_len)
                logits, state = T.decode_step(params, cfg, tokens, state,
                                              jnp.asarray(0, jnp.int32), prefill=True)
                return logits[:, -1:], state

            args = (pspec, batch_specs["tokens"])
            in_sh = (param_sh, b_sh)
        state_shape = jax.eval_shape(fn, *args)[1]
        st_sh = _named(mesh, rules.decode_state_specs(state_shape, mesh))
        out_sh = (b_sh, st_sh)
        return fn, args, in_sh, out_sh, notes

    # decode: one token against a cache filled to seq_len
    if cfg.family == "audio":
        frames = batch_specs["frames"]
        state_shape = jax.eval_shape(
            lambda p, f: ED.init_decode_state(p, cfg, f, shape.global_batch, shape.seq_len),
            pspec, frames,
        )

        def fn(params, tokens, state):
            return ED.decode_step(params, cfg, tokens, state,
                                  jnp.asarray(shape.seq_len - 1, jnp.int32))
    else:
        state_shape = jax.eval_shape(
            lambda: T.init_decode_state(cfg, shape.global_batch, shape.seq_len)
        )

        def fn(params, tokens, state):
            return T.decode_step(params, cfg, tokens, state,
                                 jnp.asarray(shape.seq_len - 1, jnp.int32))

    st_sh = _named(mesh, rules.decode_state_specs(state_shape, mesh))
    args = (pspec, batch_specs["tokens"], state_shape)
    in_sh = (param_sh, b_sh, st_sh)
    out_sh = (b_sh, st_sh)
    return fn, args, in_sh, out_sh, notes


def run_cell(arch: str, shape: ShapeSpec, *, multi_pod: bool, hw: RL.HardwareModel,
             out_dir: str = "experiments/dryrun", microbatches: int = 8,
             gather_once: bool = False) -> dict:
    opt_dtype = "bfloat16" if "deepseek" in arch else "float32"
    cfg = full_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, in_sh, out_sh, notes = build_cell(cfg, shape, mesh, opt_dtype=opt_dtype,
                                                microbatches=microbatches,
                                                gather_once=gather_once)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    try:
        ca = compiled.cost_analysis() or {}
    except Exception:
        ca = {}
    hlo = compiled.as_text()
    costs = RL.analyze_compiled_hlo(hlo)
    terms = RL.roofline_terms(costs, hw)
    mf = RL.model_flops(cfg, shape, backward=(shape.kind == "train"))
    n_dev = mesh.size
    record = {
        "arch": arch,
        "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "compile_s": round(time.time() - t0, 1),
        "bytes_per_device": {
            "arguments": int(mem.argument_size_in_bytes),
            "temp": int(mem.temp_size_in_bytes),
            "output": int(mem.output_size_in_bytes),
            "total_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30, 3
            ),
        },
        "fits_16gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes) < 16 * 2**30,
        "xla_cost_analysis_raw": {k: ca.get(k) for k in ("flops", "bytes accessed")},
        "hlo": {
            "flops_per_chip": terms.flops_per_chip,
            "hbm_bytes_per_chip": terms.hbm_bytes_per_chip,
            "collective_bytes_per_chip": terms.collective_bytes_per_chip,
            "collective_breakdown": costs.collective_breakdown,
            "n_collectives": costs.n_collectives,
            "while_trip_counts": costs.while_trip_counts,
        },
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "bottleneck": terms.bottleneck,
            "step_time_s": terms.step_time_s,
        },
        "model_flops_total": mf,
        "model_flops_per_chip": mf / n_dev,
        "useful_flop_ratio": (mf / n_dev) / max(terms.flops_per_chip, 1.0),
        "sharding_notes": notes,
        "opt_state_dtype": opt_dtype,
        "microbatches": microbatches if shape.kind == "train" else None,
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape.name}__{record['mesh'].replace('x', '_')}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1, default=float)
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--gather-once", action="store_true")
    args = ap.parse_args(argv)

    hw = RL.HardwareModel()
    cells: list[tuple[str, ShapeSpec]] = []
    archs = ARCH_IDS if (args.all or not args.arch) else (ALIASES.get(args.arch, args.arch),)
    for arch in archs:
        cfg = full_config(arch)
        for shape in shapes_for(cfg.family):
            if args.shape and shape.name != args.shape:
                continue
            cells.append((arch, shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}/{shape.name}/{'2x16x16' if mp else '16x16'}"
            try:
                rec = run_cell(arch, shape, multi_pod=mp, hw=hw, out_dir=args.out_dir,
                               microbatches=args.microbatches, gather_once=args.gather_once)
                r = rec["roofline"]
                print(
                    f"[ok] {tag}: compile {rec['compile_s']}s  "
                    f"mem/dev {rec['bytes_per_device']['total_gb']} GB  "
                    f"compute {r['compute_s']:.4f}s memory {r['memory_s']:.4f}s "
                    f"collective {r['collective_s']:.4f}s -> {r['bottleneck']}",
                    flush=True,
                )
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e}", flush=True)
                traceback.print_exc()
    print(f"\n{len(cells) * len(meshes) - len(failures)} passed, {len(failures)} failed")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
