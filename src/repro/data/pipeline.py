"""Deterministic, shardable synthetic LM data.

Real frontier-training data loaders are out of scope for a power paper;
what the framework needs from a pipeline is exactly what this provides:

  * determinism keyed by (seed, step) — restart/elastic-reshard safe: batch
    content is a pure function of the step, so resuming at step k on a
    different host count reproduces the same stream (the fault-tolerance
    tests rely on this);
  * structured, learnable sequences (orders of magnitude easier than
    uniform noise, so loss-goes-down tests are meaningful): a mixture of
    arithmetic-progression and repeated-motif sequences over the vocab;
  * host prefetch with a background thread (overlap data with compute);
  * per-shape batch specs for the dry-run (ShapeDtypeStructs, no data).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq_len: int = 128
    vocab_size: int = 512
    prefetch: int = 2


class SyntheticLMDataset:
    """Deterministic step -> batch mapping with optional prefetch thread."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
        b, t = cfg.batch, cfg.seq_len
        v = cfg.vocab_size
        kinds = rng.integers(0, 2, size=(b,))
        tokens = np.empty((b, t + 1), np.int32)
        for i in range(b):
            if kinds[i] == 0:  # arithmetic progression mod vocab
                start = rng.integers(0, v)
                stride = rng.integers(1, 7)
                tokens[i] = (start + stride * np.arange(t + 1)) % v
            else:  # repeated motif
                mlen = int(rng.integers(4, 17))
                motif = rng.integers(0, v, size=(mlen,))
                reps = -(-(t + 1) // mlen)
                tokens[i] = np.tile(motif, reps)[: t + 1]
        return {
            "tokens": jnp.asarray(tokens[:, :-1]),
            "labels": jnp.asarray(tokens[:, 1:]),
        }

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        """Prefetching iterator starting at ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        th = threading.Thread(target=worker, daemon=True)
        th.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch, shape) cell's inputs."""
    b, t = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, t), jnp.int32),
        }
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, t, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return specs
    # decode: one new token against a cache of seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encdec.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return specs
