"""Model zoo: composable layers + the 10 assigned architectures."""
from repro.models import attention, blocks, encdec, layers, mamba2, moe, rwkv6, transformer

__all__ = ["attention", "blocks", "encdec", "layers", "mamba2", "moe", "rwkv6", "transformer"]
