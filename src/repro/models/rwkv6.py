"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent token-shift
mixing + data-dependent decay time-mix, and the squared-ReLU channel-mix.

The recurrence itself runs through ``kernels.ops.rwkv6_scan`` (Pallas on
TPU, jnp oracle elsewhere).  Decode carries (shift_tm, shift_cm, wkv state).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L


class RWKVState(NamedTuple):
    shift_tm: jax.Array  # (B, 1, D) last token for time-mix token shift
    shift_cm: jax.Array  # (B, 1, D) last token for channel-mix token shift
    wkv: jax.Array  # (B, H, hd, hd) recurrence state
    length: jax.Array


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.rwkv.head_dim
    return cfg.d_model // hd, hd


def init_rwkv6(key, cfg: ModelConfig, dtype) -> L.Params:
    d = cfg.d_model
    h, hd = _heads(cfg)
    r = cfg.rwkv
    ks = jax.random.split(key, 16)
    lora = lambda k, rank, out: {
        "a": L.truncated_normal(jax.random.fold_in(k, 0), (d, rank), 0.02, dtype),
        "b": L.truncated_normal(jax.random.fold_in(k, 1), (rank, out), 0.02, dtype),
    }
    return {
        "time": {
            # token-shift base mixers (mu) + data-dependent LoRA deltas
            "mu_base": L.truncated_normal(ks[0], (5, d), 0.02, dtype),
            "mix_lora_a": L.truncated_normal(ks[1], (d, 5 * r.mix_lora), 0.02, dtype),
            "mix_lora_b": L.truncated_normal(ks[2], (5, r.mix_lora, d), 0.02, dtype),
            "wr": L.init_linear(ks[3], d, d, dtype),
            "wk": L.init_linear(ks[4], d, d, dtype),
            "wv": L.init_linear(ks[5], d, d, dtype),
            "wg": L.init_linear(ks[6], d, d, dtype),
            "decay_base": jnp.full((d,), -6.0, jnp.float32),
            "decay_lora": lora(ks[7], r.decay_lora, d),
            "u_bonus": L.truncated_normal(ks[8], (h, hd), 0.1, jnp.float32),
            "ln_x": L.init_norm(d, "rmsnorm", dtype),  # group-norm stand-in
            "wo": L.init_linear(ks[9], d, d, dtype),
        },
        "channel": {
            "mu_k": L.truncated_normal(ks[10], (d,), 0.02, dtype),
            "mu_r": L.truncated_normal(ks[11], (d,), 0.02, dtype),
            "wk": L.init_linear(ks[12], d, cfg.d_ff, dtype),
            "wv": L.init_linear(ks[13], cfg.d_ff, d, dtype),
            "wr": L.init_linear(ks[14], d, d, dtype),
        },
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x shifted right by one: [prev, x_0, ..., x_{T-2}]."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def time_mix_fwd(p, cfg, x, state: RWKVState | None):
    b, t, d = x.shape
    h, hd = _heads(cfg)
    prev = state.shift_tm if state is not None else jnp.zeros((b, 1, d), x.dtype)
    xs = _token_shift(x, prev)
    delta = xs - x

    # data-dependent mixing coefficients (5 heads: r, k, v, w, g)
    lora_in = jnp.tanh(x @ p["mix_lora_a"]).reshape(b, t, 5, -1)
    mix = p["mu_base"][None, None] + jnp.einsum("btfr,frd->btfd", lora_in, p["mix_lora_b"])
    xr, xk, xv, xw, xg = [x + delta * mix[:, :, i] for i in range(5)]

    r = L.linear(p["wr"], xr).reshape(b, t, h, hd)
    k = L.linear(p["wk"], xk).reshape(b, t, h, hd)
    v = L.linear(p["wv"], xv).reshape(b, t, h, hd)
    g = jax.nn.silu(L.linear(p["wg"], xg))

    dec_in = jnp.tanh(xw @ p["decay_lora"]["a"]) @ p["decay_lora"]["b"]
    w_log = p["decay_base"][None, None] + dec_in.astype(jnp.float32)  # (B,T,D)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, t, h, hd)  # decay in (0,1)

    wkv0 = state.wkv if state is not None else jnp.zeros((b, h, hd, hd), jnp.float32)
    out, wkv_f = ops.rwkv6_scan(
        r.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        w.astype(x.dtype).transpose(0, 2, 1, 3), p["u_bonus"].astype(x.dtype), wkv0,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    out = L.norm_fwd(p["ln_x"], out, "rmsnorm", cfg.norm_eps) * g
    return L.linear(p["wo"], out), x[:, -1:], wkv_f


def channel_mix_fwd(p, cfg, x, state: RWKVState | None):
    b, t, d = x.shape
    prev = state.shift_cm if state is not None else jnp.zeros((b, 1, d), x.dtype)
    xs = _token_shift(x, prev)
    delta = xs - x
    xk = x + delta * p["mu_k"][None, None]
    xr = x + delta * p["mu_r"][None, None]
    k = L.linear(p["wk"], xk)
    k = jnp.square(jax.nn.relu(k))
    kv = L.linear(p["wv"], k)
    return jax.nn.sigmoid(L.linear(p["wr"], xr)) * kv, x[:, -1:]


def rwkv6_block_fwd(
    p: L.Params,
    cfg: ModelConfig,
    x: jax.Array,
    norms: L.Params,
    state: RWKVState | None = None,
) -> tuple[jax.Array, RWKVState]:
    h1 = L.norm_fwd(norms["ln1"], x, cfg.norm, cfg.norm_eps)
    tm, shift_tm, wkv_f = time_mix_fwd(p["time"], cfg, h1, state)
    x = x + tm
    h2 = L.norm_fwd(norms["ln2"], x, cfg.norm, cfg.norm_eps)
    cm, shift_cm = channel_mix_fwd(p["channel"], cfg, h2, state)
    x = x + cm
    length = (state.length if state is not None else jnp.asarray(0, jnp.int32)) + x.shape[1]
    # NOTE: shift states must hold the NORMED stream the mixes consume.
    new_state = RWKVState(shift_tm=h1[:, -1:], shift_cm=h2[:, -1:], wkv=wkv_f, length=length)
    return x, new_state


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> RWKVState:
    h, hd = _heads(cfg)
    d = cfg.d_model
    return RWKVState(
        shift_tm=jnp.zeros((batch, 1, d), dtype),
        shift_cm=jnp.zeros((batch, 1, d), dtype),
        wkv=jnp.zeros((batch, h, hd, hd), jnp.float32),
        length=jnp.asarray(0, jnp.int32),
    )
