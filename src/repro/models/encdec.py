"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, D) straight into the encoder.
Encoder blocks are bidirectional (layernorm + GELU FFN); decoder blocks add
cross-attention to the encoder memory.  Positions are learned embeddings
(rope_fraction = 0 in the whisper config).

Decode state: per-layer self-attn KV cache + the per-layer cross K/V
(computed once from the encoder memory at prefill).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import transformer as T


MAX_DEC_POS = 32_832  # learned decoder positions (whisper: 448; decode_32k needs 32768)


def init_cross_attention(key, cfg: ModelConfig, dtype) -> L.Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.init_linear(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": L.init_linear(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": L.init_linear(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": L.init_linear(ks[3], cfg.n_heads * hd, d, dtype),
    }


def cross_attention_fwd(
    p: L.Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, Tq, D) decoder stream
    memory: jax.Array | None,  # (B, Tm, D) encoder output (prefill)
    kv: tuple[jax.Array, jax.Array] | None,  # precomputed cross K/V (decode)
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.linear(p["wq"], x).reshape(b, t, cfg.n_heads, hd)
    if kv is None:
        k = L.linear(p["wk"], memory).reshape(b, memory.shape[1], cfg.n_kv_heads, hd)
        v = L.linear(p["wv"], memory).reshape(b, memory.shape[1], cfg.n_kv_heads, hd)
    else:
        k, v = kv
    out = _full_attention(q, k, v, 1.0 / math.sqrt(hd))
    return L.linear(p["wo"], out.reshape(b, t, cfg.n_heads * hd)), (k, v)


def _full_attention(q, k, v, scale):
    b, t, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, t, hkv, g, hd)
    logits = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(jnp.float32) * scale
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgts,bshe->bthge", probs.astype(v.dtype), v)
    return out.reshape(b, t, h, hd)


# ------------------------------------------------------------- encoder ----


def init_encoder_block(key, cfg: ModelConfig, dtype) -> L.Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "attn": A.init_gqa(ks[0], cfg, dtype),
        "ln2": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "ffn": L.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn, dtype),
    }


def encoder_block_fwd(p, cfg, x, positions):
    h = L.norm_fwd(p["ln1"], x, cfg.norm, cfg.norm_eps)
    attn_out, _ = A.gqa_fwd(p["attn"], cfg, h, positions, causal=False)
    x = x + attn_out
    h = L.norm_fwd(p["ln2"], x, cfg.norm, cfg.norm_eps)
    return x + L.ffn_fwd(p["ffn"], h, cfg.ffn)


# ------------------------------------------------------------- decoder ----


def init_decoder_block(key, cfg: ModelConfig, dtype) -> L.Params:
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "attn": A.init_gqa(ks[0], cfg, dtype),
        "ln_x": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "xattn": init_cross_attention(ks[1], cfg, dtype),
        "ln2": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "ffn": L.init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn, dtype),
    }


def decoder_block_fwd(p, cfg, x, positions, memory=None, self_cache=None, cross_kv=None):
    h = L.norm_fwd(p["ln1"], x, cfg.norm, cfg.norm_eps)
    attn_out, new_cache = A.gqa_fwd(p["attn"], cfg, h, positions, self_cache)
    x = x + attn_out
    h = L.norm_fwd(p["ln_x"], x, cfg.norm, cfg.norm_eps)
    xout, new_cross = cross_attention_fwd(p["xattn"], cfg, h, memory, cross_kv)
    x = x + xout
    h = L.norm_fwd(p["ln2"], x, cfg.norm, cfg.norm_eps)
    return x + L.ffn_fwd(p["ffn"], h, cfg.ffn), new_cache, new_cross


# ------------------------------------------------------------ full model --


def init(key, cfg: ModelConfig) -> L.Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "embed": L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "dec_pos": L.truncated_normal(ks[1], (MAX_DEC_POS, cfg.d_model), 0.02, dtype),
        "enc_blocks": T._stack_init(
            ks[2], cfg.encdec.encoder_layers, lambda k: init_encoder_block(k, cfg, dtype)
        ),
        "ln_enc": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "dec_blocks": T._stack_init(
            ks[3], cfg.n_layers, lambda k: init_decoder_block(k, cfg, dtype)
        ),
        "ln_f": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "lm_head": L.init_linear(ks[4], cfg.d_model, cfg.padded_vocab, dtype),
    }


def encode(p, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, D) stub-frontend embeddings -> encoder memory."""
    b, t, _ = frames.shape
    # sinusoidal positions (whisper encoder)
    pos = jnp.arange(t)[:, None]
    dim = jnp.arange(cfg.d_model // 2)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / (cfg.d_model // 2))
    pe = jnp.concatenate([jnp.sin(pos * inv), jnp.cos(pos * inv)], axis=-1)
    x = frames + pe[None].astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    from repro.sharding.rules import constrain_activations

    def body(h, bp):
        return constrain_activations(encoder_block_fwd(bp, cfg, constrain_activations(h), positions)), None

    f = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = jax.lax.scan(f, x, p["enc_blocks"])
    return L.norm_fwd(p["ln_enc"], x, cfg.norm, cfg.norm_eps)


def forward(
    p,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, T_dec)
    frames: jax.Array,  # (B, T_enc, D)
) -> T.ForwardOut:
    memory = encode(p, cfg, frames)
    b, t = tokens.shape
    x = L.embed(p["embed"], tokens) + p["dec_pos"][None, :t]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    from repro.sharding.rules import constrain_activations

    def body(h, bp):
        h2, _, _ = decoder_block_fwd(bp, cfg, constrain_activations(h), positions, memory=memory)
        return constrain_activations(h2), None

    f = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = jax.lax.scan(f, x, p["dec_blocks"])
    h_final = L.norm_fwd(p["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = T._readout(p, cfg, h_final)
    return T.ForwardOut(logits=logits, aux_losses={}, mtp_logits=None)


def lm_loss(p, cfg, tokens, labels, frames):
    out = forward(p, cfg, tokens, frames)
    loss, denom = T._xent(out.logits, labels)
    return loss, {"lm_loss": loss, "tokens": denom, "total_loss": loss}


class EncDecState(NamedTuple):
    self_kv: A.KVCache  # stacked over layers
    cross_k: jax.Array  # (L, B, Tm, Hkv, hd)
    cross_v: jax.Array


def init_decode_state(p, cfg: ModelConfig, frames: jax.Array, batch: int, max_len: int) -> EncDecState:
    """Encode once and precompute per-layer cross K/V."""
    dtype = jnp.dtype(cfg.dtype)
    memory = encode(p, cfg, frames)
    hd = cfg.resolved_head_dim
    b, tm, _ = memory.shape

    def cross_kv(bp):
        k = L.linear(bp["xattn"]["wk"], memory).reshape(b, tm, cfg.n_kv_heads, hd)
        v = L.linear(bp["xattn"]["wv"], memory).reshape(b, tm, cfg.n_kv_heads, hd)
        return k, v

    ck, cv = jax.vmap(cross_kv)(p["dec_blocks"])
    kv = A.init_gqa_cache(cfg, batch, max_len, dtype)
    stacked = A.KVCache(
        k=jnp.zeros((cfg.n_layers,) + kv.k.shape, dtype),
        v=jnp.zeros((cfg.n_layers,) + kv.v.shape, dtype),
        length=jnp.asarray(0, jnp.int32),
    )
    return EncDecState(self_kv=stacked, cross_k=ck, cross_v=cv)


def decode_step(p, cfg: ModelConfig, tokens: jax.Array, state: EncDecState, pos_offset,
                *, prefill: bool = False):
    b, t = tokens.shape
    x = L.embed(p["embed"], tokens) + jax.lax.dynamic_slice_in_dim(
        p["dec_pos"], pos_offset, t, axis=0
    )[None]
    positions = pos_offset + jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    kvs = state.self_kv

    from repro.sharding.rules import constrain_activations

    def body(h, inp):
        bp, k_l, v_l, ck_l, cv_l = inp
        if prefill:
            h2, fresh, _ = decoder_block_fwd(
                bp, cfg, constrain_activations(h), positions,
                self_cache=None, cross_kv=(ck_l, cv_l)
            )
            k_n = jax.lax.dynamic_update_slice_in_dim(
                k_l, fresh.k.astype(k_l.dtype), kvs.length, axis=1)
            v_n = jax.lax.dynamic_update_slice_in_dim(
                v_l, fresh.v.astype(v_l.dtype), kvs.length, axis=1)
            return constrain_activations(h2), (k_n, v_n)
        cache_l = A.KVCache(k=k_l, v=v_l, length=kvs.length)
        h2, nc, _ = decoder_block_fwd(
            bp, cfg, constrain_activations(h), positions,
            self_cache=cache_l, cross_kv=(ck_l, cv_l)
        )
        return constrain_activations(h2), (nc.k, nc.v)

    x, (ks, vs) = jax.lax.scan(
        body, x, (p["dec_blocks"], kvs.k, kvs.v, state.cross_k, state.cross_v)
    )
    h_final = L.norm_fwd(p["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = T._readout(p, cfg, h_final)
    new_state = EncDecState(
        self_kv=A.KVCache(k=ks, v=vs, length=kvs.length + t),
        cross_k=state.cross_k, cross_v=state.cross_v,
    )
    return logits, new_state
