"""Mamba-2 (SSD) block — the zamba2 backbone.

Implements the state-space dual form with scalar-per-head decay:

    h_t = a_t * h_{t-1} + b_t x_t^T     (per head: state (d_state, head_dim))
    y_t = c_t^T h_t  + D x_t

computed chunkwise (intra-chunk quadratic + inter-chunk recurrence), the
standard SSD algorithm, entirely in jnp (scan over chunks).  A causal
short conv (d_conv) precedes the SSM as in the reference architecture.

Decode carries (conv_state (B, d_conv-1, d_inner+2*d_state), ssm_state
(B, H, d_state, head_dim)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


class SSMState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, conv_channels)
    ssm: jax.Array  # (B, H, d_state, head_dim) fp32
    length: jax.Array


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.d_state
    return s, d_inner, n_heads, conv_ch


def init_mamba2(key, cfg: ModelConfig, dtype) -> L.Params:
    s, d_inner, n_heads, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in": L.init_linear(ks[0], d, 2 * d_inner + 2 * s.d_state + n_heads, dtype),
        "conv": {"kernel": L.truncated_normal(ks[1], (s.d_conv, conv_ch), 0.5, dtype)},
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "out_norm": L.init_norm(d_inner, "rmsnorm", dtype),
        "w_out": L.init_linear(ks[2], d_inner, d, dtype),
    }


def _ssd_chunked(x, a, b, c, chunk: int, h0: jax.Array):
    """SSD scan.  x: (B, T, H, P); a: (B, T, H) in (0,1]; b,c: (B, T, N).

    Returns y (B, T, H, P), h_final (B, H, N, P).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    pad = -t % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = a.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)

    la = jnp.log(jnp.maximum(ac, 1e-20)).astype(jnp.float32)  # (B,nc,L,H)
    cum = jnp.cumsum(la, axis=2)  # inclusive cumulative log-decay

    # intra-chunk: y_intra[t] = sum_{s<=t} decay(s->t) * (c_t.b_s) x_s
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,L_t,L_s,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # Clamp BEFORE exp: masked (t<s) entries have dec>0 and would overflow
    # to inf, poisoning gradients through the where (0 * inf = NaN in vjp).
    gamma = jnp.exp(jnp.where(mask, dec, -1e30))
    cb = jnp.einsum("bgtn,bgsn->bgts", cc, bc)  # (B,nc,L,L)
    y_intra = jnp.einsum("bgts,bgtsh,bgshp->bgthp", cb, gamma, xc.astype(jnp.float32))

    # chunk summaries: state contribution of each chunk
    tail = cum[:, :, -1:, :] - cum  # decay from step s to chunk end
    bx = jnp.einsum("bgsn,bgshp,bgsh->bghnp", bc, xc.astype(jnp.float32), jnp.exp(tail))
    a_chunk = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) total chunk decay

    def scan_chunks(hprev, inp):
        bx_g, a_g = inp
        hnew = hprev * a_g[..., None, None] + bx_g
        return hnew, hprev

    (h_final, h_prevs) = jax.lax.scan(
        scan_chunks,
        h0.astype(jnp.float32),
        (jnp.moveaxis(bx, 1, 0), jnp.moveaxis(a_chunk, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,N,P) state entering chunk

    # inter-chunk: y_inter[t] = (c_t decay(0->t)) . h_prev
    y_inter = jnp.einsum(
        "bgtn,bgth,bghnp->bgthp", cc, jnp.exp(cum), h_prevs
    )
    y = (y_intra + y_inter).reshape(bsz, nc * chunk, h, p)[:, :t]
    return y, h_final


def mamba2_fwd(
    p: L.Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, T, D)
    state: SSMState | None = None,
) -> tuple[jax.Array, SSMState | None]:
    s, d_inner, n_heads, conv_ch = _dims(cfg)
    bsz, t, _ = x.shape

    zxbcdt = L.linear(p["w_in"], x)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * s.d_state], axis=-1)
    # wait: layout is [z, x, B, C, dt] with x,B,C going through the conv
    # xbc = [x (d_inner), B (N), C (N)]
    if state is None:
        conv_in = xbc
        prev = jnp.zeros((bsz, s.d_conv - 1, conv_ch), xbc.dtype)
        h0 = jnp.zeros((bsz, n_heads, s.d_state, s.head_dim), jnp.float32)
    else:
        prev = state.conv
        conv_in = xbc
        h0 = state.ssm

    full = jnp.concatenate([prev, conv_in], axis=1)  # (B, T+dc-1, CH)
    kernel = p["conv"]["kernel"]  # (dc, CH)
    idx = jnp.arange(t)[:, None] + jnp.arange(s.d_conv)[None, :]  # (T, dc)
    windows = full[:, idx, :]  # (B, T, dc, CH)
    conv_out = jax.nn.silu(jnp.einsum("btkc,kc->btc", windows, kernel))
    new_conv = full[:, -(s.d_conv - 1):, :] if s.d_conv > 1 else full[:, :0, :]

    xs, b_in, c_in = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)
    xs = xs.reshape(bsz, t, n_heads, s.head_dim)
    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    a = jnp.exp(-dt_act * jnp.exp(p["a_log"]))  # decay in (0,1)
    x_scaled = xs.astype(jnp.float32) * dt_act[..., None]

    y, h_f = _ssd_chunked(x_scaled, a, b_in.astype(jnp.float32), c_in.astype(jnp.float32), s.chunk, h0)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, t, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = L.norm_fwd(p["out_norm"], y, "rmsnorm", cfg.norm_eps)
    out = L.linear(p["w_out"], y)
    length = (state.length if state is not None else jnp.asarray(0, jnp.int32)) + t
    return out, SSMState(conv=new_conv, ssm=h_f, length=length)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    s, d_inner, n_heads, conv_ch = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, n_heads, s.d_state, s.head_dim), jnp.float32),
        length=jnp.asarray(0, jnp.int32),
    )
