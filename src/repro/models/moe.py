"""Mixture-of-experts FFN (DeepSeek-V2/V3 style).

  * fine-grained routed experts + shared experts (DeepSeekMoE)
  * two routers: softmax top-k with load-balance aux loss (V2) and
    sigmoid scoring with a learned-bias aux-loss-free balancer (V3 —
    the bias enters routing only, gates use the raw scores)
  * SPMD-friendly capacity-bounded dispatch: tokens -> (expert, slot)
    one-hot einsum, experts sharded over the ``model`` mesh axis (EP);
    the dispatch/combine einsums lower to all-to-alls under GSPMD.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    expert_load: jax.Array  # (E,) fraction of tokens routed per expert
    dropped_fraction: jax.Array


def init_moe(key, cfg: ModelConfig, dtype) -> L.Params:
    mo = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    e = mo.n_experts
    de = mo.d_expert

    def stack_init(k, d_in, d_out):
        kk = jax.random.split(k, e)
        return jnp.stack([L.init_linear(ki, d_in, d_out, dtype)["kernel"] for ki in kk])

    p: L.Params = {
        "router": {
            "kernel": L.truncated_normal(ks[0], (d, e), 0.02, jnp.float32),
        },
        "experts": {
            "w_gate": stack_init(ks[1], d, de),
            "w_up": stack_init(ks[2], d, de),
            "w_down": stack_init(ks[3], de, d),
        },
    }
    if mo.router == "sigmoid_bias":
        # aux-loss-free balancing bias (updated outside the gradient path)
        p["router"]["bias"] = jnp.zeros((e,), jnp.float32)
    if mo.n_shared_experts:
        p["shared"] = L.init_ffn(
            jax.random.fold_in(key, 7), d, de * mo.n_shared_experts, cfg.ffn, dtype
        )
    return p


def _route(p, cfg: ModelConfig, x_flat: jax.Array):
    """-> (weights (N, k), indices (N, k), scores (N, E), logits)."""
    mo = cfg.moe
    logits = (x_flat.astype(jnp.float32)) @ p["router"]["kernel"]  # (N, E)
    if mo.router == "sigmoid_bias":
        scores = jax.nn.sigmoid(logits)
        select = scores + p["router"]["bias"][None, :]
        _, idx = jax.lax.top_k(select, mo.experts_per_token)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(scores, mo.experts_per_token)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx, scores, logits


def moe_fwd(
    p: L.Params, cfg: ModelConfig, x: jax.Array, *, group_size: int = 256
) -> tuple[jax.Array, MoEAux]:
    """GShard-style grouped capacity dispatch (SPMD-exact, EP-friendly).

    Tokens are tiled into groups of ``group_size``; capacity and slot
    assignment are per-group, so dispatch/combine tensors are
    O(S * E * C) per group with C = cf * S * k / E — linear in tokens
    overall (a flat one-hot dispatch is quadratic and blows up at the 1M-
    token prefill shapes).  Groups map to the data axis and experts to the
    model axis; the (G, E, C, d) <-> (E, G*C, d) reshape around the expert
    FFN is where GSPMD inserts the all-to-alls.
    """
    mo = cfg.moe
    b, t, d = x.shape
    n = b * t
    e = mo.n_experts
    k = mo.experts_per_token
    x_flat = x.reshape(n, d)

    w, idx, scores, logits = _route(p, cfg, x_flat)

    s = min(group_size, n)
    pad = -n % s
    if pad:
        x_g = jnp.concatenate([x_flat, jnp.zeros((pad, d), x.dtype)])
        idx_g = jnp.concatenate([idx, jnp.zeros((pad, k), idx.dtype)])
        w_g = jnp.concatenate([w, jnp.zeros((pad, k), w.dtype)])
        valid = jnp.concatenate([jnp.ones((n,), x.dtype), jnp.zeros((pad,), x.dtype)])
    else:
        x_g, idx_g, w_g = x_flat, idx, w
        valid = jnp.ones((n,), x.dtype)
    g = (n + pad) // s
    capacity = max(int(mo.capacity_factor * s * k / e), k)

    xg = x_g.reshape(g, s, d)
    idxg = idx_g.reshape(g, s, k)
    wg = (w_g * valid[:, None]).reshape(g, s, k)

    # per-group slot assignment
    onehot = jax.nn.one_hot(idxg, e, dtype=jnp.int32)  # (G, S, k, E)
    flatoh = onehot.reshape(g, s * k, e)
    pre = jnp.cumsum(flatoh, axis=1) - flatoh  # tokens ahead in this expert
    slot = jnp.sum(pre.reshape(g, s, k, e) * onehot, axis=-1)  # (G, S, k)
    keep = slot < capacity
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    slot_oh = jax.nn.one_hot(jnp.where(keep, slot, capacity), capacity, dtype=x.dtype)
    oh = onehot.astype(x.dtype)
    # dispatch: (G, S, E, C); combine adds the gate weights
    disp = jnp.einsum("gske,gskc->gsec", oh, slot_oh)
    comb = jnp.einsum("gske,gskc->gsec", oh * wg[..., None].astype(x.dtype), slot_oh)

    xe = jnp.einsum("gsd,gsec->gecd", xg, disp)  # (G, E, C, d)
    # EP boundary: groups ride "data", experts ride "model" — this reshape
    # is the all-to-all under GSPMD.
    xe = xe.transpose(1, 0, 2, 3).reshape(e, g * capacity, d)

    we = p["experts"]

    def expert(xc, wgate, wup, wdown):
        if cfg.ffn == "swiglu":
            h = jax.nn.silu(xc @ wgate) * (xc @ wup)
        else:
            h = jax.nn.gelu(xc @ wgate) * (xc @ wup)
        return h @ wdown

    ye = jax.vmap(expert)(xe, we["w_gate"], we["w_up"], we["w_down"])  # (E, G*C, d)
    ye = ye.reshape(e, g, capacity, d).transpose(1, 0, 2, 3)  # (G, E, C, d)
    y = jnp.einsum("gecd,gsec->gsd", ye, comb)
    y = y.reshape(g * s, d)[:n]

    if mo.n_shared_experts:
        y = y + L.ffn_fwd(p["shared"], x_flat, cfg.ffn)

    # aux losses (over real tokens)
    load = jnp.mean(
        jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(axis=1), axis=0
    )  # (E,) expected assignments per token
    importance = jnp.mean(scores, axis=0)
    lb = e * jnp.sum(load / k * importance) if mo.router == "softmax_topk" else jnp.asarray(0.0)
    zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = MoEAux(
        load_balance_loss=lb.astype(jnp.float32),
        router_z_loss=zl.astype(jnp.float32),
        expert_load=load,
        dropped_fraction=dropped,
    )
    return y.reshape(b, t, d), aux


def update_router_bias(p: L.Params, cfg: ModelConfig, expert_load: jax.Array, lr: float = 1e-3) -> L.Params:
    """V3 aux-loss-free balancer: nudge the routing bias against load skew
    (outside the gradient path; called from the train step)."""
    if "bias" not in p["router"]:
        return p
    mo = cfg.moe
    target = mo.experts_per_token / mo.n_experts
    err = expert_load - target
    new_bias = p["router"]["bias"] - lr * jnp.sign(err)
    out = dict(p)
    out["router"] = dict(p["router"], bias=new_bias)
    return out
