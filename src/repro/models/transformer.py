"""Top-level language models for every assigned family.

``init(key, cfg)`` builds the param pytree; ``forward(params, cfg, tokens)``
returns logits (+aux); ``decode_step`` advances one token against a cache
pytree.  Layers are scanned (``jax.lax.scan`` over stacked params) so HLO
size and compile time are depth-independent — required for the 61-layer
dry-runs — with ``jax.checkpoint`` (remat) around each block.

Families:
  dense / vlm        — homogeneous decoder blocks (chameleon = qk_norm)
  moe                — leading dense layers + scanned MoE layers (deepseek)
  ssm (rwkv6)        — scanned RWKV6 blocks
  hybrid (zamba2)    — grouped scan: k Mamba2 layers per shared-attn visit
  audio (whisper)    — see ``repro.models.encdec``

MTP (deepseek-v3): one extra scanned-depth-1 block predicting token t+2
from [h_final ; emb(t+1)] (simplified single-depth MTP head), used as an
auxiliary loss during training only.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models import rwkv6 as R


class ForwardOut(NamedTuple):
    logits: jax.Array  # (B, T, V) float32
    aux_losses: dict
    mtp_logits: jax.Array | None


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _stack_init(key, n: int, init_fn) -> L.Params:
    """Initialize n copies of a block and stack leaves (scan layout)."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _maybe_remat(f, cfg: ModelConfig):
    return jax.checkpoint(f) if cfg.remat == "block" else f


# ============================================================ init ========


def init(key, cfg: ModelConfig) -> L.Params:
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 8)
    p: L.Params = {
        "embed": L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "ln_f": L.init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.init_linear(ks[1], cfg.d_model, cfg.padded_vocab, dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["blocks"] = _stack_init(
            ks[2], cfg.n_layers,
            lambda k: B.init_decoder_block(k, cfg, dtype, use_moe=False),
        )
    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        p["dense_blocks"] = _stack_init(
            ks[2], nd, lambda k: B.init_decoder_block(k, cfg, dtype, use_moe=False)
        )
        p["moe_blocks"] = _stack_init(
            ks[3], cfg.n_layers - nd,
            lambda k: B.init_decoder_block(k, cfg, dtype, use_moe=True),
        )
        if cfg.mtp_depth:
            p["mtp"] = {
                "proj": L.init_linear(ks[4], 2 * cfg.d_model, cfg.d_model, dtype),
                "block": B.init_decoder_block(ks[5], cfg, dtype, use_moe=False),
                "ln": L.init_norm(cfg.d_model, cfg.norm, dtype),
            }
    elif fam == "ssm":
        p["blocks"] = _stack_init(
            ks[2], cfg.n_layers,
            lambda k: {
                "ln1": L.init_norm(cfg.d_model, cfg.norm, dtype),
                "ln2": L.init_norm(cfg.d_model, cfg.norm, dtype),
                **R.init_rwkv6(k, cfg, dtype),
            },
        )
    elif fam == "hybrid":
        k_every = cfg.hybrid.shared_every
        n_groups = cfg.n_layers // k_every
        p["groups"] = _stack_init(
            ks[2], n_groups,
            lambda k: _stack_init(k, k_every, lambda kk: B.init_mamba_block(kk, cfg, dtype)),
        )
        p["shared"] = B.init_shared_block(ks[3], cfg, dtype)
    else:
        raise ValueError(f"family {fam} handled in repro.models.encdec")
    return p


# ========================================================= forward ========


def forward(
    p: L.Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, T) int32
    *,
    embeddings: jax.Array | None = None,  # modality-stub path (B, T, D)
    collect_aux: bool = True,
) -> ForwardOut:
    b, t = tokens.shape[:2]
    x = L.embed(p["embed"], tokens) if embeddings is None else embeddings
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    aux: dict = {}

    from repro.sharding.rules import constrain_activations

    fam = cfg.family
    if fam in ("dense", "vlm"):
        def body(h, bp):
            h2, _, _ = B.decoder_block_fwd(bp, cfg, constrain_activations(h), positions)
            return constrain_activations(h2), None

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, p["blocks"])
    elif fam == "moe":
        def dense_body(h, bp):
            h2, _, _ = B.decoder_block_fwd(bp, cfg, constrain_activations(h), positions)
            return constrain_activations(h2), None

        x, _ = jax.lax.scan(_maybe_remat(dense_body, cfg), x, p["dense_blocks"])

        def moe_body(h, bp):
            h2, _, a = B.decoder_block_fwd(bp, cfg, constrain_activations(h), positions)
            h2 = constrain_activations(h2)
            return h2, (a.load_balance_loss, a.router_z_loss, a.expert_load, a.dropped_fraction)

        x, (lb, zl, load, drop) = jax.lax.scan(_maybe_remat(moe_body, cfg), x, p["moe_blocks"])
        if collect_aux:
            aux["load_balance"] = jnp.mean(lb) * cfg.moe.router_aux_weight
            aux["router_z"] = jnp.mean(zl) * cfg.moe.router_z_weight
            aux["expert_load"] = jnp.mean(load, axis=0)
            aux["dropped_fraction"] = jnp.mean(drop)
    elif fam == "ssm":
        def rwkv_body(h, bp):
            norms = {"ln1": bp["ln1"], "ln2": bp["ln2"]}
            h2, _ = R.rwkv6_block_fwd({"time": bp["time"], "channel": bp["channel"]},
                                      cfg, constrain_activations(h), norms, None)
            return constrain_activations(h2), None

        x, _ = jax.lax.scan(_maybe_remat(rwkv_body, cfg), x, p["blocks"])
    elif fam == "hybrid":
        emb0 = x

        def group_body(h, gp):
            def inner(hh, bp):
                hh2, _ = B.mamba_block_fwd(bp, cfg, constrain_activations(hh))
                return constrain_activations(hh2), None

            h, _ = jax.lax.scan(inner, h, gp)
            h, _ = B.shared_block_fwd(p["shared"], cfg, h, emb0, positions)
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(group_body, cfg), x, p["groups"])
    else:
        raise ValueError(fam)

    h_final = L.norm_fwd(p["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = _readout(p, cfg, h_final)

    mtp_logits = None
    if cfg.mtp_depth and "mtp" in p and cfg.family == "moe":
        # MTP: predict token t+2 from [h_t ; emb(token_{t+1})]
        emb_next = jnp.roll(L.embed(p["embed"], tokens), -1, axis=1)
        hm = L.linear(p["mtp"]["proj"], jnp.concatenate([h_final, emb_next], axis=-1))
        hm, _, _ = B.decoder_block_fwd(p["mtp"]["block"], cfg, hm, positions)
        hm = L.norm_fwd(p["mtp"]["ln"], hm, cfg.norm, cfg.norm_eps)
        mtp_logits = _readout(p, cfg, hm)

    return ForwardOut(logits=logits, aux_losses=aux, mtp_logits=mtp_logits)


def _readout(p, cfg, h):
    from repro.sharding.rules import maybe_constrain

    if cfg.tie_embeddings:
        logits = L.unembed(p["embed"], h)
    else:
        logits = L.linear(p["lm_head"], h).astype(jnp.float32)
    # fp32 (B, T, V) is the largest activation in the program: keep vocab
    # sharded on "model" and batch on "data" through the loss.
    return maybe_constrain(logits, ("pod", "data"), None, "model")


# ============================================================ loss ========


def lm_loss(
    p: L.Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, T)
    labels: jax.Array,  # (B, T), -100 = ignore
    *,
    embeddings: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    out = forward(p, cfg, tokens, embeddings=embeddings)
    loss, denom = _xent(out.logits, labels)
    metrics = {"lm_loss": loss, "tokens": denom}
    total = loss
    for k, v in out.aux_losses.items():
        if k in ("load_balance", "router_z"):
            total = total + v
        metrics[k] = v
    if out.mtp_logits is not None:
        mtp_labels = jnp.roll(labels, -1, axis=1).at[:, -1].set(-100)
        mtp_loss, _ = _xent(out.mtp_logits, mtp_labels)
        total = total + 0.3 * mtp_loss
        metrics["mtp_loss"] = mtp_loss
    metrics["total_loss"] = total
    return total, metrics


def _xent(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # Label gather as a masked reduction over the vocab axis: unlike
    # take_along_axis this stays partitioned when vocab is sharded on
    # "model" (GSPMD reduces partial sums; a gather would all-gather the
    # full fp32 logits onto every device — tens of GB at assigned shapes).
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == safe[..., None], logits, 0.0), axis=-1
    )
    nll = (logz - gold) * valid
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / denom, denom


# ====================================================== decode caches =====


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Per-layer cache pytree matching the forward structure."""
    dtype = _dtype(cfg)
    fam = cfg.family

    def stacked_kv(n):
        c = A.init_cache(cfg, batch, max_len, dtype)
        return A.KVCache(
            k=jnp.zeros((n,) + c.k.shape, dtype),
            v=jnp.zeros((n,) + c.v.shape, dtype),
            length=jnp.asarray(0, jnp.int32),
        )

    if fam in ("dense", "vlm"):
        return {"blocks": stacked_kv(cfg.n_layers)}
    if fam == "moe":
        nd = cfg.moe.first_dense_layers
        return {"dense": stacked_kv(nd), "moe": stacked_kv(cfg.n_layers - nd)}
    if fam == "ssm":
        s = R.init_rwkv_state(cfg, batch, dtype)
        n = cfg.n_layers
        return {
            "blocks": R.RWKVState(
                shift_tm=jnp.zeros((n,) + s.shift_tm.shape, dtype),
                shift_cm=jnp.zeros((n,) + s.shift_cm.shape, dtype),
                wkv=jnp.zeros((n,) + s.wkv.shape, jnp.float32),
                length=jnp.asarray(0, jnp.int32),
            )
        }
    if fam == "hybrid":
        k_every = cfg.hybrid.shared_every
        n_groups = cfg.n_layers // k_every
        s = M.init_ssm_state(cfg, batch, dtype)
        return {
            "groups": M.SSMState(
                conv=jnp.zeros((n_groups, k_every) + s.conv.shape, dtype),
                ssm=jnp.zeros((n_groups, k_every) + s.ssm.shape, jnp.float32),
                length=jnp.asarray(0, jnp.int32),
            ),
            "shared": stacked_kv(n_groups),
        }
    raise ValueError(fam)


def decode_step(
    p: L.Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, T_new) — T_new=1 for decode, >1 for prefill
    state: Any,
    pos_offset: jax.Array,  # () int32 — absolute position of tokens[:, 0]
    *,
    prefill: bool = False,
) -> tuple[jax.Array, Any]:
    """Advance the model over tokens with caches; returns (logits, state).

    ``prefill=True`` (static) computes attention through the training path
    (query-chunked, O(chunk*T) memory) and then writes the fresh K/V into
    the preallocated cache — the decode path's full (T x S) logits would be
    tens of GB at the 32k prefill shapes.
    """
    b, t = tokens.shape
    x = L.embed(p["embed"], tokens)
    positions = pos_offset + jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    fam = cfg.family

    from repro.sharding.rules import constrain_activations

    def scan_kv(blocks_p, kvs: A.KVCache, h):
        def body(carry, inp):
            hh = carry
            bp, k_l, v_l = inp
            if prefill:
                hh2, fresh, _ = B.decoder_block_fwd(
                    bp, cfg, constrain_activations(hh), positions, None
                )
                k_n = jax.lax.dynamic_update_slice_in_dim(
                    k_l, fresh.k.astype(k_l.dtype), kvs.length, axis=1
                )
                v_n = jax.lax.dynamic_update_slice_in_dim(
                    v_l, fresh.v.astype(v_l.dtype), kvs.length, axis=1
                )
                return constrain_activations(hh2), (k_n, v_n)
            cache_l = A.KVCache(k=k_l, v=v_l, length=kvs.length)
            hh2, new_cache, _ = B.decoder_block_fwd(
                bp, cfg, constrain_activations(hh), positions, cache_l
            )
            return constrain_activations(hh2), (new_cache.k, new_cache.v)

        h, (ks, vs) = jax.lax.scan(body, h, (blocks_p, kvs.k, kvs.v))
        return h, A.KVCache(k=ks, v=vs, length=kvs.length + t)

    if fam in ("dense", "vlm"):
        x, new_kv = scan_kv(p["blocks"], state["blocks"], x)
        new_state = {"blocks": new_kv}
    elif fam == "moe":
        x, nd_kv = scan_kv(p["dense_blocks"], state["dense"], x)
        x, mo_kv = scan_kv(p["moe_blocks"], state["moe"], x)
        new_state = {"dense": nd_kv, "moe": mo_kv}
    elif fam == "ssm":
        st: R.RWKVState = state["blocks"]

        def body(carry, inp):
            hh = carry
            bp, s_tm, s_cm, s_wkv = inp
            norms = {"ln1": bp["ln1"], "ln2": bp["ln2"]}
            layer_state = R.RWKVState(shift_tm=s_tm, shift_cm=s_cm, wkv=s_wkv, length=st.length)
            hh2, ns = R.rwkv6_block_fwd(
                {"time": bp["time"], "channel": bp["channel"]}, cfg, hh, norms, layer_state
            )
            return hh2, (ns.shift_tm, ns.shift_cm, ns.wkv)

        x, (tm, cm, wkv) = jax.lax.scan(body, x, (p["blocks"], st.shift_tm, st.shift_cm, st.wkv))
        new_state = {"blocks": R.RWKVState(shift_tm=tm, shift_cm=cm, wkv=wkv, length=st.length + t)}
    elif fam == "hybrid":
        emb0 = x
        gs: M.SSMState = state["groups"]
        sh: A.KVCache = state["shared"]

        def group_body(carry, inp):
            hh = carry
            gp, conv_g, ssm_g, k_g, v_g = inp

            def inner(c2, inp2):
                hh2 = c2
                bp, conv_l, ssm_l = inp2
                ls = M.SSMState(conv=conv_l, ssm=ssm_l, length=gs.length)
                hh3, ns = B.mamba_block_fwd(bp, cfg, hh2, ls)
                return hh3, (ns.conv, ns.ssm)

            hh, (conv_n, ssm_n) = jax.lax.scan(inner, hh, (gp, conv_g, ssm_g))
            if prefill:
                hh, fresh = B.shared_block_fwd(p["shared"], cfg, hh, emb0, positions, None)
                k_n = jax.lax.dynamic_update_slice_in_dim(
                    k_g, fresh.k.astype(k_g.dtype), sh.length, axis=1)
                v_n = jax.lax.dynamic_update_slice_in_dim(
                    v_g, fresh.v.astype(v_g.dtype), sh.length, axis=1)
                return hh, (conv_n, ssm_n, k_n, v_n)
            cache_l = A.KVCache(k=k_g, v=v_g, length=sh.length)
            hh, nc = B.shared_block_fwd(p["shared"], cfg, hh, emb0, positions, cache_l)
            return hh, (conv_n, ssm_n, nc.k, nc.v)

        x, (conv_n, ssm_n, ks, vs) = jax.lax.scan(
            group_body, x, (p["groups"], gs.conv, gs.ssm, sh.k, sh.v)
        )
        new_state = {
            "groups": M.SSMState(conv=conv_n, ssm=ssm_n, length=gs.length + t),
            "shared": A.KVCache(k=ks, v=vs, length=sh.length + t),
        }
    else:
        raise ValueError(fam)

    h_final = L.norm_fwd(p["ln_f"], x, cfg.norm, cfg.norm_eps)
    return _readout(p, cfg, h_final), new_state
