"""Block compositions: pre-norm decoder block (dense/MoE), Mamba2 block
wrapper, and the Zamba2 shared-attention hybrid pattern."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE


# ------------------------------------------------------- decoder block ----


def init_decoder_block(key, cfg: ModelConfig, dtype, *, use_moe: bool) -> L.Params:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "attn": A.init_attention(ks[0], cfg, dtype),
        "ln2": L.init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if use_moe:
        p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = L.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn, dtype)
    return p


def decoder_block_fwd(
    p: L.Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: A.KVCache | None = None,
    *,
    causal: bool = True,
) -> tuple[jax.Array, A.KVCache | None, MOE.MoEAux | None]:
    h = L.norm_fwd(p["ln1"], x, cfg.norm, cfg.norm_eps)
    attn_out, new_cache = A.attention_fwd(p["attn"], cfg, h, positions, cache, causal=causal)
    x = x + attn_out
    h = L.norm_fwd(p["ln2"], x, cfg.norm, cfg.norm_eps)
    aux = None
    if "moe" in p:
        ffn_out, aux = MOE.moe_fwd(p["moe"], cfg, h)
    else:
        ffn_out = L.ffn_fwd(p["ffn"], h, cfg.ffn)
    return x + ffn_out, new_cache, aux


# ------------------------------------------------------- mamba2 block -----


def init_mamba_block(key, cfg: ModelConfig, dtype) -> L.Params:
    return {
        "ln": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "mamba": M.init_mamba2(key, cfg, dtype),
    }


def mamba_block_fwd(p, cfg, x, state: M.SSMState | None = None):
    h = L.norm_fwd(p["ln"], x, cfg.norm, cfg.norm_eps)
    out, new_state = M.mamba2_fwd(p["mamba"], cfg, h, state)
    return x + out, new_state


# -------------------------------------------------- zamba2 shared block ---
#
# One transformer block whose weights are shared across all its applications
# (every ``hybrid.shared_every`` backbone layers).  Its input is
# concat(hidden, initial_embedding) projected down (the Zamba2 concatenated
# residual), its output added back to the backbone stream.


def init_shared_block(key, cfg: ModelConfig, dtype) -> L.Params:
    ks = jax.random.split(key, 3)
    sub = ModelConfig(
        name=cfg.name + "-shared", family="dense",
        n_layers=1, d_model=cfg.d_model, n_heads=cfg.hybrid.shared_block_heads,
        n_kv_heads=cfg.hybrid.shared_block_heads, d_ff=cfg.d_ff,
        vocab_size=cfg.vocab_size, norm=cfg.norm, norm_eps=cfg.norm_eps,
        ffn=cfg.ffn, rope_theta=cfg.rope_theta, dtype=cfg.dtype,
    )
    return {
        "w_concat": L.init_linear(ks[0], 2 * cfg.d_model, cfg.d_model, dtype),
        "block": init_decoder_block(ks[1], sub, dtype, use_moe=False),
        "_sub_heads": jnp.zeros((0,)),  # marker leaf (keeps tree static)
    }


def shared_block_fwd(p, cfg: ModelConfig, x, emb0, positions, cache: A.KVCache | None = None):
    sub = ModelConfig(
        name=cfg.name + "-shared", family="dense",
        n_layers=1, d_model=cfg.d_model, n_heads=cfg.hybrid.shared_block_heads,
        n_kv_heads=cfg.hybrid.shared_block_heads, d_ff=cfg.d_ff,
        vocab_size=cfg.vocab_size, norm=cfg.norm, norm_eps=cfg.norm_eps,
        ffn=cfg.ffn, rope_theta=cfg.rope_theta, dtype=cfg.dtype,
    )
    h = L.linear(p["w_concat"], jnp.concatenate([x, emb0], axis=-1))
    out, new_cache, _ = decoder_block_fwd(p["block"], sub, h, positions, cache)
    return x + out, new_cache
