"""Attention variants: GQA (w/ bias, partial RoPE, qk-norm) and DeepSeek MLA.

Both expose the interface used by the train/serve substrate:

  * ``init_*``                   — parameters
  * ``*_fwd(..., cache=None)``   — training / prefill (returns fresh cache)
  * ``*_fwd(..., cache=state)``  — token decode against a preallocated cache

KV caches:
  * GQA: (k, v) each (B, S, Hkv, Dh)
  * MLA: compressed — k slot holds c_kv (B, S, kv_lora_rank), v slot holds
    the shared k_rope (B, S, qk_rope_head_dim).  The decode path uses the
    *absorbed* formulation (W_uk folded into the query, W_uv into the
    output) so per-token decode cost scales with kv_lora_rank rather than
    n_heads * head_dim — the property that makes MLA caches ~1/10 of GQA.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L


class KVCache(NamedTuple):
    k: jax.Array
    v: jax.Array
    length: jax.Array  # () int32 — filled prefix


def _grouped_softmax_attention(
    q: jax.Array,  # (B, T, H, Dh)
    k: jax.Array,  # (B, S, Hkv, Dh)
    v: jax.Array,  # (B, S, Hkv, Dv)
    q_start: jax.Array,  # () int32: absolute position of q[:, 0]
    scale: float,
) -> jax.Array:
    """Decode/chunked-prefill attention with GQA grouping.

    Causal across the whole cache: query i (absolute q_start + i) attends
    keys at positions <= its own — correct for both one-token decode and
    multi-token chunked prefill.
    """
    b, t, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, t, hkv, g, dh)
    logits = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(jnp.float32) * scale
    rows = q_start + jnp.arange(t)  # absolute query positions
    cols = jnp.arange(s)
    mask = cols[None, :] <= rows[:, None]  # (t, s)
    logits = jnp.where(mask[None, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgts,bshe->bthge", probs.astype(v.dtype), v)
    return out.reshape(b, t, h, v.shape[-1])


# ================================================================== GQA ====


def init_gqa(key, cfg: ModelConfig, dtype) -> L.Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.init_linear(ks[0], d, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": L.init_linear(ks[1], d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": L.init_linear(ks[2], d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": L.init_linear(ks[3], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.init_norm(hd, "rmsnorm", dtype)
        p["k_norm"] = L.init_norm(hd, "rmsnorm", dtype)
    return p


def gqa_fwd(
    p: L.Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, T, D)
    positions: jax.Array,  # (B, T) absolute positions
    cache: KVCache | None = None,
    *,
    causal: bool = True,
) -> tuple[jax.Array, KVCache | None]:
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.linear(p["wq"], x).reshape(b, t, cfg.n_heads, hd)
    k = L.linear(p["wk"], x).reshape(b, t, cfg.n_kv_heads, hd)
    v = L.linear(p["wv"], x).reshape(b, t, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.norm_fwd(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = L.norm_fwd(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    if cfg.rope_fraction > 0:
        sin, cos = L.rope_frequencies(
            int(hd * cfg.rope_fraction), cfg.rope_theta, positions
        )
        q = L.apply_rope(q, sin, cos, cfg.rope_fraction)
        k = L.apply_rope(k, sin, cos, cfg.rope_fraction)

    if cache is None:
        out = ops.attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=causal,
        ).transpose(0, 2, 1, 3)
        new_cache = KVCache(k=k, v=v, length=jnp.asarray(t, jnp.int32))
    else:
        idx = cache.length
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), idx, axis=1)
        out = _grouped_softmax_attention(q, ck, cv, idx, 1.0 / math.sqrt(hd))
        new_cache = KVCache(k=ck, v=cv, length=idx + t)
    o = out.reshape(b, t, cfg.n_heads * hd)
    return L.linear(p["wo"], o), new_cache


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        length=jnp.asarray(0, jnp.int32),
    )


# ================================================================== MLA ====


def init_mla(key, cfg: ModelConfig, dtype) -> L.Params:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    p: L.Params = {}
    if m.q_lora_rank:
        p["wq_a"] = L.init_linear(ks[0], d, m.q_lora_rank, dtype)
        p["q_a_norm"] = L.init_norm(m.q_lora_rank, "rmsnorm", dtype)
        p["wq_b"] = L.init_linear(ks[1], m.q_lora_rank, h * qk_dim, dtype)
    else:
        p["wq"] = L.init_linear(ks[0], d, h * qk_dim, dtype)
    # joint KV compression + decoupled rope key
    p["wkv_a"] = L.init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype)
    p["kv_a_norm"] = L.init_norm(m.kv_lora_rank, "rmsnorm", dtype)
    p["wk_b"] = L.init_linear(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim, dtype)
    p["wv_b"] = L.init_linear(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype)
    p["wo"] = L.init_linear(ks[5], h * m.v_head_dim, d, dtype)
    return p


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        q = L.linear(p["wq_a"], x)
        q = L.norm_fwd(p["q_a_norm"], q, "rmsnorm", cfg.norm_eps)
        q = L.linear(p["wq_b"], q)
    else:
        q = L.linear(p["wq"], x)
    q = q.reshape(b, t, h, qk_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    sin, cos = L.rope_frequencies(m.qk_rope_head_dim, cfg.rope_theta, positions)
    q_rope = L.apply_rope(q_rope, sin, cos, 1.0)
    return q_nope, q_rope, (sin, cos)


def mla_fwd(
    p: L.Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: KVCache | None = None,
) -> tuple[jax.Array, KVCache | None]:
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope, (sin, cos) = _mla_q(p, cfg, x, positions)

    kv_a = L.linear(p["wkv_a"], x)  # (B, T, R + rope)
    c_kv, k_rope = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank :]
    c_kv = L.norm_fwd(p["kv_a_norm"], c_kv, "rmsnorm", cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], sin, cos, 1.0)[:, :, 0, :]  # shared

    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    if cache is None:
        # training/prefill: expand keys/values (FLOP-optimal at long T)
        k_nope = L.linear(p["wk_b"], c_kv).reshape(b, t, h, m.qk_nope_head_dim)
        v = L.linear(p["wv_b"], c_kv).reshape(b, t, h, m.v_head_dim)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, t, h, m.qk_rope_head_dim))],
            axis=-1,
        )
        out = ops.attention(
            q_full.transpose(0, 2, 1, 3), k_full.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, scale=scale,
        ).transpose(0, 2, 1, 3)
        o = out.reshape(b, t, h * m.v_head_dim)
        new_cache = KVCache(k=c_kv, v=k_rope, length=jnp.asarray(t, jnp.int32))
        return L.linear(p["wo"], o), new_cache

    # ---- decode: absorbed formulation over the compressed cache ----------
    idx = cache.length
    cc = jax.lax.dynamic_update_slice_in_dim(cache.k, c_kv.astype(cache.k.dtype), idx, axis=1)
    cr = jax.lax.dynamic_update_slice_in_dim(cache.v, k_rope.astype(cache.v.dtype), idx, axis=1)
    s = cc.shape[1]
    # absorb W_uk: q_c[b,t,h,R] = q_nope . W_uk[h]  (W_uk from wk_b kernel)
    wk_b = p["wk_b"]["kernel"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_c = jnp.einsum("bthd,rhd->bthr", q_nope, wk_b)
    logits = (
        jnp.einsum("bthr,bsr->bhts", q_c, cc)
        + jnp.einsum("bthd,bsd->bhts", q_rope, cr)
    ).astype(jnp.float32) * scale
    rows = idx + jnp.arange(t)
    mask = jnp.arange(s)[None, :] <= rows[:, None]  # (t, s) causal
    logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhts,bsr->bthr", probs, cc)  # (B, T, H, R)
    # absorb W_uv into the output projection
    wv_b = p["wv_b"]["kernel"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bthr,rhe->bthe", ctx, wv_b).reshape(b, t, h * m.v_head_dim)
    new_cache = KVCache(k=cc, v=cr, length=idx + t)
    return L.linear(p["wo"], out), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    m = cfg.mla
    return KVCache(
        k=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        v=jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        length=jnp.asarray(0, jnp.int32),
    )


def init_attention(key, cfg: ModelConfig, dtype) -> L.Params:
    return init_mla(key, cfg, dtype) if cfg.attention == "mla" else init_gqa(key, cfg, dtype)


def attention_fwd(p, cfg, x, positions, cache=None, *, causal: bool = True):
    if cfg.attention == "mla":
        return mla_fwd(p, cfg, x, positions, cache)
    return gqa_fwd(p, cfg, x, positions, cache, causal=causal)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    if cfg.attention == "mla":
        return init_mla_cache(cfg, batch, max_len, dtype)
    return init_gqa_cache(cfg, batch, max_len, dtype)
