"""Shared neural-net layers (pure functions over param pytrees).

Conventions:
  * params are nested dicts of jnp arrays; ``init_*`` builds them,
    ``*_fwd``/apply functions consume them.
  * leaf names are load-bearing: ``repro.sharding.rules`` pattern-matches
    them to assign PartitionSpecs (MaxText-style logical axes).
  * activations are computed in the config dtype; normalization and
    softmax statistics in float32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops

Params = dict


def truncated_normal(key, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def init_linear(key, d_in: int, d_out: int, dtype, *, bias: bool = False, name_scale: float | None = None) -> Params:
    scale = name_scale if name_scale is not None else 1.0 / math.sqrt(d_in)
    p = {"kernel": truncated_normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["kernel"]
    if "bias" in p:
        y = y + p["bias"]
    return y


# ------------------------------------------------------------------- norms


def init_norm(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_fwd(p: Params, x: jax.Array, kind: str, eps: float) -> jax.Array:
    if kind == "rmsnorm":
        return ops.rmsnorm(x, p["scale"], eps)
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# -------------------------------------------------------------------- RoPE


def rope_frequencies(head_dim: int, theta: float, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) of shape (..., T, head_dim//2) for integer positions."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., T, half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array, fraction: float = 1.0) -> jax.Array:
    """Rotate the first ``fraction`` of the head dim (chatglm: 0.5).

    x: (B, T, H, D); sin/cos: (B?, T, rot//2) broadcastable.
    Pairing is interleaved-free (llama-style half-split within the rotated
    span).
    """
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    # sin/cos arrive as (T, half') or (B, T, half') -> insert a head axis.
    s = sin[..., :half][..., None, :]
    c = cos[..., :half][..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * c - xf2 * s
    o2 = xf2 * c + xf1 * s
    return jnp.concatenate([o1.astype(x.dtype), o2.astype(x.dtype), x_pass], axis=-1)


# --------------------------------------------------------------------- FFN


def init_ffn(key, d_model: int, d_ff: int, kind: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": init_linear(ks[0], d_model, d_ff, dtype),
            "w_up": init_linear(ks[1], d_model, d_ff, dtype),
            "w_down": init_linear(ks[2], d_ff, d_model, dtype),
        }
    return {
        "w_up": init_linear(ks[0], d_model, d_ff, dtype),
        "w_down": init_linear(ks[1], d_ff, d_model, dtype),
    }


def ffn_fwd(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return linear(p["w_down"], jax.nn.silu(linear(p["w_gate"], x)) * linear(p["w_up"], x))
    if kind == "geglu":
        return linear(p["w_down"], jax.nn.gelu(linear(p["w_gate"], x)) * linear(p["w_up"], x))
    return linear(p["w_down"], jax.nn.gelu(linear(p["w_up"], x)))


# --------------------------------------------------------------- embedding


def init_embedding(key, vocab: int, d_model: int, dtype) -> Params:
    # GPT-style 0.02: keeps tied-readout logits O(1) at init.
    return {"embedding": truncated_normal(key, (vocab, d_model), 0.02, dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["embedding"][tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Tied readout: logits = x @ E^T (float32 for the softmax)."""
    return (x @ p["embedding"].T.astype(x.dtype)).astype(jnp.float32)
