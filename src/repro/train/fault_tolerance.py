"""Fault tolerance for 1000+-node operation.

Three mechanisms, each exercised by tests:

  * **StragglerMonitor** — per-host step-time EMA with robust (MAD-based)
    outlier detection; flags persistent stragglers so the launcher can
    drop/replace the host and the data shards get reassigned
    (``reassign_shards``).  Power tie-in: a host whose rack PDU reports a
    saturated battery is treated as degraded before it even slows down.

  * **Elastic remesh** — resume a checkpoint on a different device count:
    checkpoints are stored unsharded and re-placed under the new mesh
    (see ``checkpoint.Checkpointer.restore``); the data pipeline is
    step-keyed so the batch stream continues identically.

  * **PowerAwareCheckpointer** — EasyRider SoC telemetry drives emergency
    checkpoints: if the battery leaves its safe band (grid event in
    progress; the rack may be about to brown out), save NOW rather than at
    the next scheduled interval.  This is the integration the paper enables
    but does not build: the PDU's BMS is a failure *predictor* visible to
    software with seconds of warning.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.train.checkpoint import Checkpointer


# ------------------------------------------------------------ stragglers --


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    ema_alpha: float = 0.2
    threshold: float = 3.0  # MAD multiples above median
    patience: int = 3  # consecutive flags before declaring

    def __post_init__(self):
        self._ema = np.zeros(self.n_hosts)
        self._count = np.zeros(self.n_hosts, np.int64)
        self._flags = np.zeros(self.n_hosts, np.int64)
        self._forced: set[int] = set()

    def observe(self, step_times_s: Sequence[float]) -> list[int]:
        """Feed per-host durations for one step; returns declared stragglers.

        Outlier-ness is judged on the CURRENT step time (robust median/MAD
        across hosts) so a single transient blip cannot poison the verdict
        through the EMA; the EMA is kept for reporting.  Declaration needs
        ``patience`` consecutive outlier steps — or a power-degradation
        mark, which persists until cleared.
        """
        t = np.asarray(step_times_s, np.float64)
        first = self._count == 0
        self._ema = np.where(first, t, (1 - self.ema_alpha) * self._ema + self.ema_alpha * t)
        self._count += 1
        med = np.median(t)
        mad = np.median(np.abs(t - med)) + 1e-9
        outlier = t > med + self.threshold * mad * 1.4826
        self._flags = np.where(outlier, self._flags + 1, 0)
        declared = set(int(i) for i in np.nonzero(self._flags >= self.patience)[0])
        return sorted(declared | self._forced)

    def mark_power_degraded(self, host: int) -> None:
        """A rack PDU reporting SoC saturation = imminent trouble."""
        self._forced.add(host)

    def clear(self, host: int) -> None:
        self._forced.discard(host)
        self._flags[host] = 0


def reassign_shards(n_shards: int, healthy_hosts: Sequence[int]) -> dict[int, list[int]]:
    """Deterministic round-robin remap of data shards to surviving hosts."""
    healthy = sorted(healthy_hosts)
    if not healthy:
        raise ValueError("no healthy hosts")
    out: dict[int, list[int]] = {h: [] for h in healthy}
    for s in range(n_shards):
        out[healthy[s % len(healthy)]].append(s)
    return out


# --------------------------------------------------- power-aware saving ---


class PowerAwareCheckpointer:
    """Checkpointer wrapper that adds SoC-triggered emergency saves."""

    def __init__(
        self,
        ckpt: Checkpointer,
        *,
        every_steps: int = 200,
        soc_window: tuple[float, float] = (0.15, 0.85),
        cooldown_steps: int = 20,
    ):
        self.ckpt = ckpt
        self.every_steps = every_steps
        self.soc_window = soc_window
        self.cooldown_steps = cooldown_steps
        self._last_emergency = -(10**9)
        self.emergency_saves = 0

    def maybe_save(self, step: int, tree, *, soc: float | None = None) -> str | None:
        """Returns "scheduled" | "emergency" | None."""
        if soc is not None and not (self.soc_window[0] <= soc <= self.soc_window[1]):
            if step - self._last_emergency >= self.cooldown_steps:
                self.ckpt.save(step, tree)
                self._last_emergency = step
                self.emergency_saves += 1
                return "emergency"
        if self.every_steps and step > 0 and step % self.every_steps == 0:
            self.ckpt.save(step, tree)
            return "scheduled"
        return None
