"""Training substrate: step builder, loop, checkpointing, fault tolerance."""
from repro.train.checkpoint import Checkpointer
from repro.train.fault_tolerance import PowerAwareCheckpointer, StragglerMonitor, reassign_shards
from repro.train.loop import TrainConfig, train
from repro.train.step import build_train_step

__all__ = [
    "Checkpointer", "PowerAwareCheckpointer", "StragglerMonitor",
    "reassign_shards", "TrainConfig", "train", "build_train_step",
]
