"""Checkpointing: atomic, async, reshard-on-restore.

Design (scaled-down single-host implementation of the multi-host pattern):

  * **atomic**: write to ``<dir>/tmp-<step>`` then ``os.replace`` to
    ``<dir>/step-<step>`` — a crash mid-write never corrupts the latest
    checkpoint (restore scans for the newest complete directory).
  * **async**: device->host transfer happens on the caller thread (cheap),
    serialization + fsync on a background thread so the train loop resumes
    immediately; ``wait()`` joins before the next save or at exit.
  * **reshard-on-restore (elastic)**: arrays are stored unsharded
    (host-gathered); restore places them under ANY mesh/sharding, so a job
    checkpointed on mesh A resumes on mesh B (elastic scaling).  At real
    multi-pod scale the same API is backed by per-host shard files; the
    manifest format already records per-leaf shapes/dtypes to support that.
  * **retention**: keep the last ``keep`` checkpoints.
  * **emergency saves**: ``PowerAwareCheckpointer`` (fault_tolerance.py)
    triggers an immediate save on EasyRider battery-SoC excursions.

Format: one ``manifest.json`` (tree structure, shapes, dtypes, step) + one
``.npz`` with flattened leaves keyed by path.
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "name", getattr(k, "key", getattr(k, "idx", k)))) for k in path
        )
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "biufc":  # bf16/f8 etc: npz can't round-trip
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save --

    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        self.wait()
        flat = _flatten(tree)  # device->host on caller thread
        manifest = {
            "step": int(step),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        }

        def write():
            tmp = os.path.join(self.directory, f"tmp-{step}")
            final = os.path.join(self.directory, f"step-{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            import shutil

            shutil.rmtree(os.path.join(self.directory, f"step-{s:09d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore --

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step-(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int | None,
        like: Any,
        *,
        shardings: Any | None = None,
    ) -> tuple[int, Any]:
        """Restore into the structure of ``like``; optionally place each
        leaf under the given sharding pytree (reshard-on-restore)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        d = os.path.join(self.directory, f"step-{step:09d}")
        arrays = np.load(os.path.join(d, "arrays.npz"))

        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        flat_shard = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(paths)
        )
        leaves = []
        for (path, leaf), sh in zip(paths, flat_shard):
            key = "/".join(
                str(getattr(k, "name", getattr(k, "key", getattr(k, "idx", k)))) for k in path
            )
            arr = arrays[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
            if sh is not None:
                leaves.append(jax.device_put(arr.astype(leaf.dtype), sh))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return step, treedef.unflatten(leaves)
