"""The training loop: jit'd step + data + checkpoints + fault tolerance +
EasyRider PowerSim, composed.

``train()`` is used both by examples/train_lm.py (end-to-end ~100M run) and
the integration tests (short runs, restart-resume, emergency checkpoint).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.power.integration import PowerSim
from repro.train.checkpoint import Checkpointer
from repro.train.fault_tolerance import PowerAwareCheckpointer, StragglerMonitor
from repro.train.step import build_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    microbatches: int = 1
    seed: int = 0
    resume: bool = False


def train(
    cfg: ModelConfig,
    data_cfg: DataConfig,
    opt_cfg: AdamWConfig,
    tc: TrainConfig,
    *,
    power_sim: PowerSim | None = None,
    callbacks: list[Callable] | None = None,
) -> dict:
    key = jax.random.key(tc.seed)
    init_fn = ED.init if cfg.family == "audio" else T.init
    params = init_fn(key, cfg)
    opt_state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(
        build_train_step(
            cfg, opt_cfg, microbatches=tc.microbatches, total_steps=tc.steps,
            warmup_steps=max(tc.steps // 10, 1),
        ),
        donate_argnums=(0, 1),
    )

    start_step = 0
    ckpt = None
    if tc.checkpoint_dir:
        ckpt = PowerAwareCheckpointer(
            Checkpointer(tc.checkpoint_dir), every_steps=tc.checkpoint_every
        )
        if tc.resume and ckpt.ckpt.all_steps():
            start_step, (params, opt_state) = ckpt.ckpt.restore(None, (params, opt_state))
            start_step += 1

    ds = SyntheticLMDataset(data_cfg)
    monitor = StragglerMonitor(n_hosts=max(jax.process_count(), 1))
    history: list[dict] = []
    losses = []
    t_prev = time.monotonic()
    for step in range(start_step, tc.steps):
        batch = ds.batch_at(step)
        if cfg.family == "audio":
            rng = np.random.default_rng(step)
            batch["frames"] = jnp.asarray(
                rng.normal(scale=0.02, size=(data_cfg.batch, cfg.encdec.encoder_seq, cfg.d_model)),
                jnp.dtype(cfg.dtype),
            )
        params, opt_state, metrics = step_fn(params, opt_state, batch, jnp.asarray(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        now = time.monotonic()
        monitor.observe([now - t_prev])
        t_prev = now

        is_ckpt_step = bool(
            tc.checkpoint_dir and tc.checkpoint_every and (step + 1) % tc.checkpoint_every == 0
        )
        if power_sim is not None:
            power_sim.on_step(checkpoint_stall=is_ckpt_step)
        if ckpt is not None:
            soc = power_sim.soc if power_sim is not None else None
            ckpt.maybe_save(step, (params, opt_state), soc=soc)
        if step % tc.log_every == 0 or step == tc.steps - 1:
            rec = {"step": step, "loss": loss, "grad_norm": float(metrics["grad_norm"])}
            history.append(rec)
        for cb in callbacks or []:
            cb(step, metrics)

    if ckpt is not None:
        ckpt.ckpt.save(tc.steps - 1, (params, opt_state), blocking=True)
    out = {
        "params": params,
        "opt_state": opt_state,
        "history": history,
        "first_loss": losses[0] if losses else None,
        "last_loss": float(np.mean(losses[-5:])) if losses else None,
    }
    if power_sim is not None:
        out["power_report"] = power_sim.report()
    return out
