"""Train-step builder: loss + grad + AdamW, with microbatch gradient
accumulation (lax.scan) and the V3 aux-free router-bias update.

``build_train_step(cfg, opt_cfg, microbatches)`` returns a pure function

    step(params, opt_state, batch, step_idx) -> (params, opt_state, metrics)

suitable for jax.jit with in/out shardings from ``repro.sharding.rules``.
Microbatching splits the global batch on the leading axis and accumulates
grads in fp32 across a scan — the standard memory/efficiency trade that
also amortizes the DP collective schedule.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as ED
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_update
from repro.optim.schedules import cosine_schedule


def loss_fn(params, cfg: ModelConfig, batch: dict):
    if cfg.family == "audio":
        return ED.lm_loss(params, cfg, batch["tokens"], batch["labels"], batch["frames"])
    return T.lm_loss(params, cfg, batch["tokens"], batch["labels"])


def build_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    total_steps: int = 10_000,
    warmup_steps: int = 100,
    gather_small_weights_once: bool = False,
) -> Callable:
    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch
        )
        return loss, metrics, grads

    def train_step(params, opt_state, batch, step_idx):
        compute_params = params
        if gather_small_weights_once and microbatches > 1:
            # FSDP re-gathers every weight once per microbatch; for the
            # small non-expert weights (attention/norm/router) that is pure
            # waste — constrain them to model-only sharding so the data-
            # axis all-gather happens ONCE per step, amortized over all
            # microbatches (EXPERIMENTS §Perf-3 it.3).  Expert weights stay
            # FSDP (too large to hold gathered).
            from repro.sharding.rules import constrain_gathered_weight

            def gather(path, leaf):
                names = tuple(str(getattr(k, "name", getattr(k, "key", k))) for k in path)
                if "experts" in names or leaf.ndim < 2:
                    return leaf
                return constrain_gathered_weight(names, leaf)

            compute_params = jax.tree_util.tree_map_with_path(gather, params)
        if microbatches > 1:
            def slice_mb(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            mb = jax.tree_util.tree_map(slice_mb, batch)

            def acc_body(carry, mb_batch):
                acc, loss_acc = carry
                loss, metrics, grads = grads_of(compute_params, mb_batch)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches, acc, grads
                )
                return (acc, loss_acc + loss / microbatches), metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), metrics = jax.lax.scan(
                acc_body, (zeros, jnp.zeros(())), mb
            )
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        lr_scale = cosine_schedule(step_idx, total_steps, warmup_steps)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg, lr_scale
        )
        # deepseek-v3 aux-loss-free balancing: bias nudge outside the grads
        if cfg.moe is not None and cfg.moe.router == "sigmoid_bias":
            load = metrics.get("expert_load")
            if load is not None:
                # router bias lives inside the scanned moe blocks
                bias = params["moe_blocks"]["moe"]["router"].get("bias")
                if bias is not None:
                    target = cfg.moe.experts_per_token / cfg.moe.n_experts
                    err = load - target
                    new_bias = bias - 1e-3 * jnp.sign(err)[None, :]
                    params = _set_in(params, ("moe_blocks", "moe", "router", "bias"), new_bias)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def _set_in(tree: dict, path: tuple[str, ...], value):
    if len(path) == 1:
        out = dict(tree)
        out[path[0]] = value
        return out
    out = dict(tree)
    out[path[0]] = _set_in(tree[path[0]], path[1:], value)
    return out
