"""Parameter/activation sharding rules (MaxText-style logical axes).

Leaf *paths* in the param pytree are pattern-matched to logical roles, and
roles map to mesh axes per the parallelism config:

  * FSDP+TP for weights: 2D kernels shard (in_dim -> "data", out_dim ->
    "model") for up-projections and (in -> "model", out -> "data") for
    down/output projections; GSPMD then inserts the per-layer all-gathers
    (FSDP) and the TP collectives automatically.
  * Experts: leading expert dim -> "model" (EP), inner in-dim -> "data".
  * Embeddings: vocab -> "model", d_model -> "data".
  * Scan-stacked params have a leading layer axis -> always unsharded.
  * Vectors (norm scales, biases) replicate.

Divisibility is checked at spec-construction time; any dim that does not
divide its assigned axis falls back to unsharded (correct, just less
distributed) with a note collected for the dry-run report.
"""
from __future__ import annotations

import enum
import inspect
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ----------------------------------------------------------- version compat --
# jax added ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
# ``jax.make_mesh``) well after 0.4.x; this repo targets both sides of that
# drift.  All mesh construction goes through ``make_mesh`` below, which
# forwards ``axis_types`` only when the installed jax understands it.

try:  # jax >= 0.5.x
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on the installed jax

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Fallback for ``jax.sharding.AxisType`` on older jax: carries the
        same member names so call sites are version-agnostic; the value is
        simply dropped by ``make_mesh`` (old jax treats every axis as Auto)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_JAX_MAKE_MESH = getattr(jax, "make_mesh", None)
_MAKE_MESH_TAKES_AXIS_TYPES = _JAX_MAKE_MESH is not None and (
    "axis_types" in inspect.signature(_JAX_MAKE_MESH).parameters
)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None) -> Mesh:
    """``jax.make_mesh`` across the ``axis_types`` API drift.

    Also covers jax releases predating ``jax.make_mesh`` itself by falling
    back to a plain ``Mesh`` over a reshaped device array."""
    if _JAX_MAKE_MESH is None:  # pragma: no cover - depends on installed jax
        devs = np.asarray(devices if devices is not None else jax.devices())
        return Mesh(devs.reshape(tuple(axis_shapes)), tuple(axis_names))
    kw: dict[str, Any] = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and _MAKE_MESH_TAKES_AXIS_TYPES:
        kw["axis_types"] = tuple(axis_types)
    return _JAX_MAKE_MESH(tuple(axis_shapes), tuple(axis_names), **kw)


# role -> (axis assignment per tensor dim, counted from the LAST dim)
# (in_axis, out_axis) for 2D kernels.
_UP_KERNELS = (
    "wq", "wk", "wv", "wg", "w_gate", "w_up", "wq_a", "wq_b", "wkv_a",
    "wk_b", "wv_b", "w_in", "wr", "mix_lora_a", "a",
)
_DOWN_KERNELS = ("wo", "w_down", "w_out", "w_concat", "b", "wv_cm")
_REPLICATE = ("scale", "bias", "a_log", "dt_bias", "d_skip", "decay_base",
              "mu_base", "mu_k", "mu_r", "u_bonus", "_sub_heads", "dec_pos")


def _role_of(path: tuple[str, ...], ndim: int) -> str:
    names = [p for p in path]
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    if leaf == "embedding" or (leaf == "kernel" and parent == "lm_head"):
        return "embed"
    if leaf in _REPLICATE or parent in ("conv",):
        return "replicate"
    if parent == "router":
        return "replicate"
    if "experts" in names:
        return "expert"
    if leaf == "kernel":
        if parent in _UP_KERNELS:
            return "up"
        if parent in _DOWN_KERNELS:
            return "down"
        return "replicate"
    if parent in ("mix_lora_b", "decay_lora"):
        return "replicate"
    if leaf in _UP_KERNELS or leaf in _DOWN_KERNELS:
        # raw arrays named like kernels (lora a/b mats)
        return "up" if leaf in _UP_KERNELS else "down"
    return "replicate"


def _fits(dim: int, mesh: Mesh, axis: str | None) -> bool:
    if axis is None:
        return True
    return dim % mesh.shape[axis] == 0


def param_spec(
    path: tuple[str, ...],
    shape: tuple[int, ...],
    mesh: Mesh,
    *,
    data_axis: str = "data",
    model_axis: str = "model",
    notes: list | None = None,
) -> P:
    """PartitionSpec for one parameter leaf."""
    ndim = len(shape)
    role = _role_of(path, ndim)
    none_prefix = (None,) * (ndim - 2)

    def note(msg):
        if notes is not None:
            notes.append(f"{'/'.join(path)}: {msg}")

    if role == "replicate" or ndim == 0:
        return P()
    if role == "embed":
        # (vocab, d) -> vocab on model (always padded to divide), d on data
        v_ax = model_axis if _fits(shape[-2], mesh, model_axis) else None
        d_ax = data_axis if _fits(shape[-1], mesh, data_axis) else None
        if v_ax is None:
            note("vocab dim not divisible; replicated")
        return P(*none_prefix, v_ax, d_ax)
    if role == "expert":
        # (..., E, in, out): E -> model (EP), in -> data (FSDP).
        # NOTE: pure EP over BOTH axes (1 expert/device, zero weight
        # gathers) was tried and REFUTED under GSPMD — the partitioner
        # cannot infer the 256-way token all-to-all from the dispatch
        # reshape and falls back to full rematerialization (~10x more
        # collective bytes, EXPERIMENTS §Perf-3 it.1).  Doing it properly
        # requires explicit shard_map all-to-alls (future work).
        if ndim < 3:
            return P()
        e_ax = model_axis if _fits(shape[-3], mesh, model_axis) else None
        i_ax = data_axis if _fits(shape[-2], mesh, data_axis) else None
        if e_ax is None:
            note("expert dim not divisible; replicated")
        return P(*(None,) * (ndim - 3), e_ax, i_ax, None)
    if ndim == 1:
        return P()
    if role == "up":
        i_ax = data_axis if _fits(shape[-2], mesh, data_axis) else None
        o_ax = model_axis if _fits(shape[-1], mesh, model_axis) else None
        if o_ax is None:
            note("up out-dim not divisible; unsharded")
        return P(*none_prefix, i_ax, o_ax)
    # down
    i_ax = model_axis if _fits(shape[-2], mesh, model_axis) else None
    o_ax = data_axis if _fits(shape[-1], mesh, data_axis) else None
    return P(*none_prefix, i_ax, o_ax)


def param_specs(shapes: Any, mesh: Mesh, **kw) -> Any:
    """PartitionSpec pytree parallel to a ShapeDtypeStruct/array pytree."""
    notes: list[str] = kw.pop("notes", None) or []

    def visit(path, leaf):
        names = tuple(
            k.name if hasattr(k, "name") else str(getattr(k, "key", k)) for k in path
        )
        return param_spec(names, tuple(leaf.shape), mesh, notes=notes, **kw)

    return jax.tree_util.tree_map_with_path(visit, shapes)


def shardings(shapes: Any, mesh: Mesh, **kw) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(shapes, mesh, **kw)
    )


# ------------------------------------------------------------ activations --


def _clean_spec(m: Mesh, spec: tuple, shape: tuple[int, ...]) -> P:
    """Drop spec axes absent from ``m`` or not dividing their dim."""
    names = set(m.axis_names)

    def keep(s, dim):
        if s is None:
            return None
        if isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if a in names)
            if not kept:
                return None
            total = 1
            for a in kept:
                total *= m.shape[a]
            return kept if dim % total == 0 else None
        if s not in names:
            return None
        return s if dim % m.shape[s] == 0 else None

    spec = spec + (None,) * (len(shape) - len(spec))
    return P(*(keep(s, d) for s, d in zip(spec, shape)))


def maybe_constrain(x: jax.Array, *spec) -> jax.Array:
    """``with_sharding_constraint`` that is a no-op without a mesh context.

    Model code calls this at activation boundaries — without it GSPMD can
    "win" by keeping the d_model contraction sharded and the BATCH
    replicated (observed: 16x activation blow-up through attention), and
    the (B, T, V) fp32 logits must shard over vocab on "model" or the loss
    alone is tens of GB per device at the assigned shapes.  Axis names
    absent from the ambient mesh and axes that do not divide their dim are
    dropped, so smoke tests (no mesh), debug meshes, and batch-1 long-
    context shapes run unchanged.
    """
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty or m.size == 1:
        return x
    cleaned = _clean_spec(m, spec, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, cleaned))


def constrain_to_mesh(x: jax.Array, mesh: Mesh, *spec) -> jax.Array:
    """``with_sharding_constraint`` against an *explicit* mesh.

    Unlike ``maybe_constrain`` this needs no ambient mesh context, so it
    works inside any jit given a mesh object — the fleet engines use it to
    express rack sharding of streamed chunks *inside* the step instead of
    staging every chunk through a host-side ``device_put``.  The same
    guards apply: a single-device mesh is a no-op, and axes that are
    missing or do not divide their dim are dropped.
    """
    if mesh.empty or mesh.size == 1:
        return x
    cleaned = _clean_spec(mesh, spec, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, cleaned))


def shard_racks(traces: jax.Array, mesh: Mesh, axis: str = "data") -> jax.Array:
    """Place the rack axis of a host-resident (T, R) trace array across a
    mesh axis (``device_put``) so fleet conditioning runs data-parallel
    across devices.  Inside a jit, use ``shard_racks_in_jit`` instead —
    arrays already on device never need the host staging this call forces.

    (Moved here from ``core.fleet``: these are mesh utilities, not fleet
    logic; ``fleet`` re-exports both names for compatibility.)"""
    return jax.device_put(traces, NamedSharding(mesh, P(None, axis)))


def shard_racks_in_jit(
    traces: jax.Array, mesh: Mesh, axis: str = "data"
) -> jax.Array:
    """In-jit variant of ``shard_racks``: expresses the rack sharding as a
    ``with_sharding_constraint`` against an explicit mesh, so streamed
    chunks (rendered or passed as jit arguments) are partitioned by GSPMD
    without a per-chunk host ``device_put`` round-trip."""
    return constrain_to_mesh(traces, mesh, None, axis)


# --------------------------------------------------------------- shard_map --

try:  # jax >= 0.6 exposes it at the top level
    _shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # pragma: no cover - depends on the installed jax
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs, *, check_rep=False):
    """``shard_map`` across the export-location API drift.

    ``check_rep=False`` is the repo default: the grid-region engine returns
    ``psum``-reduced POI aggregates under ``out_specs=P()`` — genuinely
    replicated, but the 0.4.x replication checker cannot prove it through
    ``lax.scan`` carries.  Do NOT pass ``auto=`` axes or call
    ``with_sharding_constraint`` inside the mapped body: on jax 0.4.x that
    combination aborts the *process* inside XLA's SPMD partitioner
    (``Check failed: sharding.IsManualSubgroup()``) — it is not a catchable
    error, so there is no runtime fallback (EXPERIMENTS §Grid-region).
    """
    return _shard_map(
        f, mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_rep
    )


def region_mesh(
    n_campuses: int,
    *,
    campus_axis: str = "campus",
    rack_axis: str = "data",
    devices=None,
) -> Mesh:
    """2-D (campus, data) mesh over the available devices.

    The campus axis gets exactly ``n_campuses`` shards (one campus per
    shard keeps the in-scan ``psum`` reduction order equal to the
    sequential left-to-right campus sum — the bitwise-parity contract);
    every remaining device folds into the trailing rack/data axis.
    """
    devs = list(devices) if devices is not None else jax.devices()
    if n_campuses <= 0:
        raise ValueError(f"n_campuses must be positive, got {n_campuses}")
    if len(devs) % n_campuses:
        raise ValueError(
            f"{len(devs)} devices do not tile {n_campuses} campuses; pass "
            "an explicit device subset whose size is a campus multiple"
        )
    return make_mesh(
        (n_campuses, len(devs) // n_campuses),
        (campus_axis, rack_axis),
        devices=np.asarray(devs),
    )


def constrain_activations(x: jax.Array) -> jax.Array:
    """Standard (B, T, D) activation constraint: batch on ("pod","data")."""
    return maybe_constrain(x, ("pod", "data"))


def constrain_gathered_weight(path_names: tuple[str, ...], leaf: jax.Array) -> jax.Array:
    """Re-constrain a parameter leaf to its rules-assigned sharding WITHOUT
    the data (FSDP) axis — i.e. "gather once, keep TP".  Used to amortize
    FSDP all-gathers across microbatches for small weights."""
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty or m.size == 1 or "model" not in m.axis_names:
        return leaf
    # The rules-assigned spec with every non-"model" axis dropped: same
    # TP orientation, FSDP axis gathered.
    spec = param_spec(path_names, tuple(leaf.shape), m)
    padded = (tuple(spec) + (None,) * leaf.ndim)[: leaf.ndim]
    cleaned = P(*(s if s == "model" else None for s in padded))
    return jax.lax.with_sharding_constraint(leaf, NamedSharding(m, cleaned))


def batch_spec(mesh: Mesh, batch: int, *, pod: bool = False) -> P:
    """Sharding for (B, T, ...) activations/token batches.

    Batch shards over ("pod","data") when it divides; a batch of 1
    (long-context decode) leaves batch unsharded and relies on
    head/sequence sharding inside the model.
    """
    axes: tuple[str, ...] = ()
    if pod and "pod" in mesh.shape:
        axes = ("pod", "data")
    else:
        axes = ("data",)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % total == 0:
        return P(axes if len(axes) > 1 else axes[0])
    if batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def decode_state_specs(state_shapes: Any, mesh: Mesh) -> Any:
    """PartitionSpecs for a decode-state pytree (KV caches / SSM states).

    Leaf-name driven: KV ``k``/``v`` (stacked (L, B, S, H, hd) or MLA
    (L, B, S, R)) shard batch on "data" and heads on "model" when they
    divide, else the sequence dim; SSM/RWKV states shard heads/channels on
    "model"; tiny shift/length leaves replicate.  Any non-divisible dim
    falls back to unsharded.
    """
    dp = mesh.shape["data"]
    tp = mesh.shape["model"]

    def fit(dim, ax, n):
        return ax if dim % n == 0 and dim >= n else None

    def visit(path, leaf):
        name = str(getattr(path[-1], "name", getattr(path[-1], "key", path[-1])))
        shp = tuple(leaf.shape)
        nd = len(shp)
        if name in ("length",) or nd <= 1:
            return P()
        if name in ("k", "v", "cross_k", "cross_v"):
            if nd == 5:  # (L, B, S, H, hd)
                b_ax = fit(shp[1], "data", dp)
                h_ax = fit(shp[3], "model", tp)
                s_ax = None if h_ax else fit(shp[2], "model", tp)
                return P(None, b_ax, s_ax, h_ax, None)
            if nd == 4:  # MLA (L, B, S, R)
                b_ax = fit(shp[1], "data", dp)
                s_ax = fit(shp[2], "model", tp)
                return P(None, b_ax, s_ax, None)
            return P()
        if name == "wkv":  # (L, B, H, hd, hd)
            return P(None, fit(shp[1], "data", dp), fit(shp[2], "model", tp), None, None)
        if name == "ssm":  # (G, K, B, H, N, Ph)
            return P(None, None, fit(shp[2], "data", dp), fit(shp[3], "model", tp), None, None)
        if name == "conv":  # (G, K, B, W, CH)
            return P(None, None, fit(shp[2], "data", dp), None, fit(shp[4], "model", tp))
        if name in ("shift_tm", "shift_cm"):  # (L, B, 1, D)
            return P(None, fit(shp[1], "data", dp), None, fit(shp[3], "model", tp))
        # default: try batch-ish second dim
        if nd >= 2:
            return P(None, fit(shp[1], "data", dp), *([None] * (nd - 2)))
        return P()

    return jax.tree_util.tree_map_with_path(visit, state_shapes)


def cache_spec(mesh: Mesh, batch: int, kv_heads_or_none: int | None) -> P:
    """KV cache (B, S, H, D) or MLA (B, S, R): shard batch on data; heads on
    model when divisible, else the sequence dim."""
    b_ax = "data" if batch % mesh.shape["data"] == 0 else None
    if kv_heads_or_none is not None and kv_heads_or_none % mesh.shape["model"] == 0:
        return P(b_ax, None, "model", None)
    if kv_heads_or_none is None:
        return P(b_ax, "model", None)
    return P(b_ax, "model", None, None)
