"""Sharding rules: logical axes -> PartitionSpecs (see rules.py)."""
from repro.sharding.rules import (
    AxisType,
    batch_spec,
    cache_spec,
    make_mesh,
    param_spec,
    param_specs,
    shardings,
)

__all__ = [
    "AxisType",
    "batch_spec",
    "cache_spec",
    "make_mesh",
    "param_spec",
    "param_specs",
    "shardings",
]
