"""Sharding rules: logical axes -> PartitionSpecs (see rules.py)."""
from repro.sharding.rules import batch_spec, cache_spec, param_spec, param_specs, shardings

__all__ = ["batch_spec", "cache_spec", "param_spec", "param_specs", "shardings"]
