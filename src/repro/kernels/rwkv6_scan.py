"""RWKV-6 (Finch) time-mix recurrence Pallas TPU kernel.

The rwkv6-7b architecture's hot loop — and the reason the `long_500k`
cells are tractable at all: the recurrence carries a per-head (D x D)
state with O(T) work instead of O(T^2) attention.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

TPU mapping: one (batch*head) per grid row; the (D x D) fp32 state lives in
VMEM scratch across the sequential time-block axis; within a block the
per-token outer products and matvecs run on the VPU/MXU with D = 64 lanes.
The data-dependent decay ``w_t`` makes this inexpressible as a plain
associative matmul scan without materializing (D x D) per token — the
in-VMEM sequential formulation avoids that HBM blow-up entirely (that IS
the TPU adaptation of the CUDA wkv kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sf_ref, state,
    *, block_t: int, t_total: int,
):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        state[...] = s0_ref[0]

    u = u_ref[0].astype(jnp.float32)  # (1, D) bonus row

    n_valid = jnp.minimum(block_t, t_total - pl.program_id(1) * block_t)

    def step(t, s):
        r_t = r_ref[0, t, :].astype(jnp.float32)[None, :]  # (1, D)
        k_t = k_ref[0, t, :].astype(jnp.float32)[None, :]
        v_t = v_ref[0, t, :].astype(jnp.float32)[None, :]
        w_t = w_ref[0, t, :].astype(jnp.float32)[None, :]
        kv = k_t.T @ v_t  # (D, D) outer product
        out = r_t @ (s + u.T * kv)  # (1, D)
        o_ref[0, t, :] = out[0].astype(o_ref.dtype)
        return w_t.T * s + kv

    state[...] = jax.lax.fori_loop(0, n_valid, step, state[...])
    sf_ref[0] = state[...]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(
    r: jax.Array,  # (B, H, T, D)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay in (0, 1)
    u: jax.Array,  # (H, D)
    state0: jax.Array | None = None,  # (B, H, D, D)
    *,
    block_t: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, h, t, d = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, d, d), jnp.float32)
    block_t = min(block_t, t)
    pad_t = -t % block_t

    def flat(x):
        x = x.reshape(b * h, t, d)
        if pad_t:
            x = jnp.concatenate([x, jnp.zeros((b * h, pad_t, d), x.dtype)], axis=1)
        return x

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    uf = jnp.tile(u[None, :, :], (b, 1, 1)).reshape(b * h, 1, d)
    s0 = state0.reshape(b * h, d, d)
    grid = (b * h, (t + pad_t) // block_t)
    o, sf = pl.pallas_call(
        functools.partial(_rwkv6_kernel, block_t=block_t, t_total=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_t, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_t, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_t, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, d, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d, d), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, t + pad_t, d), r.dtype),
            jax.ShapeDtypeStruct((b * h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0)
    return o[:, :t].reshape(b, h, t, d), sf.reshape(b, h, d, d)
