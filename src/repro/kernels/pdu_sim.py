"""Fused EasyRider PDU hardware path as a single Pallas TPU kernel.

Beyond-paper optimization: the reference pipeline makes three passes over
the trace (ESS ramp filter -> SoC integration -> LC filter), each reading
and writing HBM.  Fusing them keeps the full per-rack state — ESS filter
value g, state of charge, and the 3-vector LC state — resident in VMEM and
makes exactly one HBM read (rack trace + corrective) and two writes (grid
trace, SoC telemetry) per sample.  Arithmetic intensity triples and the
power-sim roofline moves from memory-bound toward compute-bound (see
EXPERIMENTS.md §Perf).

Layout identical to ``lc_filter``: racks in lanes, time blocked, state in
persistent VMEM scratch (5 rows: g, soc, x0, x1, x2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _pdu_kernel(
    *refs,
    block_t: int,
    t_total: int,
    alpha: float,
    dt: float,
    q_max: float,
    eta_c: float,
    eta_d: float,
    p_max: float,
    soc_min: float,
    soc_max: float,
    masked: bool,
    mask_2d: bool = False,
):
    if masked:
        (ad_ref, bd_ref, c_ref, s0_ref, r_ref, corr_ref, on_ref,
         grid_ref, soc_ref, sf_ref, state) = refs
        w_row = None if mask_2d else on_ref[0, :]
    else:
        (ad_ref, bd_ref, c_ref, s0_ref, r_ref, corr_ref,
         grid_ref, soc_ref, sf_ref, state) = refs

    @pl.when(pl.program_id(0) == 0)
    def _init():
        state[...] = s0_ref[...]

    n_valid = jnp.minimum(block_t, t_total - pl.program_id(0) * block_t)

    a = ad_ref[...]
    b = bd_ref[...]
    c = c_ref[...]

    def step(t, s):
        g, soc, x0, x1, x2 = s[0], s[1], s[2], s[3], s[4]
        r_t = r_ref[t, :]
        c_t = corr_ref[t, :]
        if masked:
            w_t = on_ref[t, :] if mask_2d else w_row
        # --- ESS ramp control (paper Eq. 2, exact ZOH) --------------------
        g_new = g + alpha * (r_t - g)
        if masked:
            # Offline units track the rack (soft re-engage on recovery).
            g_new = jnp.where(w_t > 0, g_new, r_t)
        p_batt = jnp.clip(g_new - r_t + c_t, -p_max, p_max)
        if masked:
            # Converter wind-down: deliver the weighted fraction (w = 1 is
            # an exact multiply; w = 0 is the hard passthrough, bitwise).
            p_batt = p_batt * w_t
        # --- SoC integration with efficiency asymmetry (Eq. 14) -----------
        charge = jnp.maximum(p_batt, 0.0)
        discharge = jnp.maximum(-p_batt, 0.0)
        soc_new = soc + (dt / q_max) * (eta_c * charge - discharge / eta_d)
        over_hi = jnp.maximum(soc_new - soc_max, 0.0)
        over_lo = jnp.maximum(soc_min - soc_new, 0.0)
        p_batt = p_batt - over_hi * q_max / (eta_c * dt) + over_lo * q_max * eta_d / dt
        soc_new = jnp.clip(soc_new, soc_min, soc_max)
        if masked:
            # LC passthrough: SoC frozen while the unit is dark.
            soc_new = jnp.where(w_t > 0, soc_new, soc)
        node = r_t + p_batt
        # --- LC filter (grid current out, state update) --------------------
        grid_ref[t, :] = (c[0, 0] * x0 + c[0, 1] * x1 + c[0, 2] * x2).astype(
            grid_ref.dtype
        )
        soc_ref[t, :] = soc_new.astype(soc_ref.dtype)
        x0n = a[0, 0] * x0 + a[0, 1] * x1 + a[0, 2] * x2 + b[0, 1] * node + b[0, 0]
        x1n = a[1, 0] * x0 + a[1, 1] * x1 + a[1, 2] * x2 + b[1, 1] * node + b[1, 0]
        x2n = a[2, 0] * x0 + a[2, 1] * x1 + a[2, 2] * x2 + b[2, 1] * node + b[2, 0]
        return jnp.stack([g_new, soc_new, x0n, x1n, x2n], axis=0)

    state[...] = jax.lax.fori_loop(0, n_valid, step, state[...])
    sf_ref[...] = state[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "beta", "dt", "q_max", "eta_c", "eta_d", "p_max", "soc_min", "soc_max",
        "block_t", "interpret",
    ),
)
def pdu_sim(
    rack_power: jax.Array,  # (T, R)
    g0: jax.Array,  # (R,)
    soc0: jax.Array,  # (R,)
    x0: jax.Array,  # (R, 3)
    ad: jax.Array,
    bd: jax.Array,
    c_row: jax.Array,
    corrective: jax.Array,  # (T, R)
    *,
    beta: float,
    dt: float,
    q_max: float,
    eta_c: float,
    eta_d: float,
    p_max: float,
    soc_min: float,
    soc_max: float,
    block_t: int = 512,
    interpret: bool = False,
    ess_on: jax.Array | None = None,  # (R,) or (T, R) availability weight
) -> tuple[jax.Array, jax.Array, tuple[jax.Array, jax.Array, jax.Array]]:
    """Fused hardware-path sim.  Returns (grid (T,R), soc (T,R), finals).

    ``ess_on`` (degraded mode) is an availability weight in [0, 1] — a
    ``(R,)`` row or a ``(T, R)`` per-sample series — see ``ref.pdu_sim``
    for the exact semantics; both paths match bitwise.
    """
    import math

    t, r = rack_power.shape
    masked = ess_on is not None
    mask_2d = masked and ess_on.ndim == 2
    block_t = min(block_t, t)
    pad_t = -t % block_t
    rp = rack_power.astype(jnp.float32)
    cp = corrective.astype(jnp.float32)
    if pad_t:
        rp = jnp.concatenate([rp, jnp.tile(rp[-1:], (pad_t, 1))], axis=0)
        cp = jnp.concatenate([cp, jnp.tile(cp[-1:], (pad_t, 1))], axis=0)
    s0 = jnp.stack(
        [g0.astype(jnp.float32), soc0.astype(jnp.float32)]
        + [x0[:, i].astype(jnp.float32) for i in range(3)],
        axis=0,
    )  # (5, R)
    grid = ((t + pad_t) // block_t,)
    alpha = 1.0 - math.exp(-beta * dt)
    in_specs = [
        pl.BlockSpec((3, 3), lambda i: (0, 0)),
        pl.BlockSpec((3, 2), lambda i: (0, 0)),
        pl.BlockSpec((1, 3), lambda i: (0, 0)),
        pl.BlockSpec((5, r), lambda i: (0, 0)),
        pl.BlockSpec((block_t, r), lambda i: (i, 0)),
        pl.BlockSpec((block_t, r), lambda i: (i, 0)),
    ]
    operands = [
        ad.astype(jnp.float32),
        bd.astype(jnp.float32),
        c_row.reshape(1, 3).astype(jnp.float32),
        s0,
        rp,
        cp,
    ]
    if mask_2d:
        wp = ess_on.astype(jnp.float32)
        if pad_t:
            wp = jnp.concatenate([wp, jnp.tile(wp[-1:], (pad_t, 1))], axis=0)
        in_specs.append(pl.BlockSpec((block_t, r), lambda i: (i, 0)))
        operands.append(wp)
    elif masked:
        in_specs.append(pl.BlockSpec((1, r), lambda i: (0, 0)))
        operands.append(ess_on.reshape(1, r).astype(jnp.float32))
    y, soc_t, sf = pl.pallas_call(
        functools.partial(
            _pdu_kernel,
            block_t=block_t, t_total=t, alpha=alpha, dt=dt, q_max=q_max,
            eta_c=eta_c, eta_d=eta_d, p_max=p_max, soc_min=soc_min,
            soc_max=soc_max, masked=masked, mask_2d=mask_2d,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_t, r), lambda i: (i, 0)),
            pl.BlockSpec((block_t, r), lambda i: (i, 0)),
            pl.BlockSpec((5, r), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t + pad_t, r), rack_power.dtype),
            jax.ShapeDtypeStruct((t + pad_t, r), jnp.float32),
            jax.ShapeDtypeStruct((5, r), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((5, r), jnp.float32)],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*operands)
    g_f, soc_f, x_f = sf[0], sf[1], sf[2:5].T
    return y[:t], soc_t[:t], (g_f, soc_f, x_f)
