"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel lives in <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), with a pure-jnp oracle in ref.py and a backend-dispatching public
wrapper in ops.py.  Validated in interpret mode on CPU; compiled on TPU.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
