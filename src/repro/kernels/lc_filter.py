"""LC input-filter state-space IIR as a Pallas TPU kernel.

Conditioning hours of kHz-rate traces for thousands of racks is the power
layer's compute hot spot: a 1-hour fleet simulation at 1 kHz over 10k racks
is 3.6e10 recurrence steps.  The recurrence is sequential in time but
embarrassingly parallel across racks, which maps perfectly onto the TPU
vector unit:

  * racks ride the 128-wide **lane** dimension,
  * time is blocked through VMEM (``block_t`` samples per grid step),
  * the 3-vector filter state lives in a VMEM scratch that persists across
    the sequential grid (dimension_semantics = "arbitrary"),
  * the 3x3 state matrix is unrolled into 9 scalar*vector FMAs per sample
    (no MXU involvement — this is a VPU kernel).

HBM traffic is exactly one read of the node trace + one write of the grid
trace; all state stays resident.  The pure-jnp oracle is ``ref.lc_filter``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _lc_kernel(
    ad_ref, bd_ref, x0_ref, u_ref, c_ref, y_ref, xf_ref, state,
    *, block_t: int, t_total: int,
):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        state[...] = x0_ref[...]

    # Last block may be partial: only advance through the valid samples so
    # the final state corresponds to exactly t_total steps.
    n_valid = jnp.minimum(block_t, t_total - pl.program_id(0) * block_t)

    a = ad_ref[...]  # (3, 3)
    b = bd_ref[...]  # (3, 2)
    c = c_ref[...]  # (1, 3)

    def step(t, x):
        # x: (3, R) f32
        u_t = u_ref[t, :]  # (R,)
        y_ref[t, :] = (c[0, 0] * x[0] + c[0, 1] * x[1] + c[0, 2] * x[2]).astype(
            y_ref.dtype
        )
        x0n = a[0, 0] * x[0] + a[0, 1] * x[1] + a[0, 2] * x[2] + b[0, 1] * u_t + b[0, 0]
        x1n = a[1, 0] * x[0] + a[1, 1] * x[1] + a[1, 2] * x[2] + b[1, 1] * u_t + b[1, 0]
        x2n = a[2, 0] * x[0] + a[2, 1] * x[1] + a[2, 2] * x[2] + b[2, 1] * u_t + b[2, 0]
        return jnp.stack([x0n, x1n, x2n], axis=0)

    state[...] = jax.lax.fori_loop(0, n_valid, step, state[...])
    xf_ref[...] = state[...]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def lc_filter(
    ad: jax.Array,  # (3, 3)
    bd: jax.Array,  # (3, 2)
    c_row: jax.Array,  # (3,)
    x0: jax.Array,  # (R, 3)
    node_power: jax.Array,  # (T, R)
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (grid (T, R), x_final (R, 3)); v_in fixed at 1 per-unit."""
    t, r = node_power.shape
    block_t = min(block_t, t)
    pad_t = -t % block_t
    u = node_power.astype(jnp.float32)
    if pad_t:
        u = jnp.concatenate([u, jnp.tile(u[-1:], (pad_t, 1))], axis=0)
    grid = ((t + pad_t) // block_t,)
    y, xf = pl.pallas_call(
        functools.partial(_lc_kernel, block_t=block_t, t_total=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, 3), lambda i: (0, 0)),
            pl.BlockSpec((3, 2), lambda i: (0, 0)),
            pl.BlockSpec((3, r), lambda i: (0, 0)),
            pl.BlockSpec((block_t, r), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, r), lambda i: (i, 0)),
            pl.BlockSpec((3, r), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t + pad_t, r), node_power.dtype),
            jax.ShapeDtypeStruct((3, r), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((3, r), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(
        ad.astype(jnp.float32),
        bd.astype(jnp.float32),
        x0.T.astype(jnp.float32),
        u,
        c_row.reshape(1, 3).astype(jnp.float32),
    )
    return y[:t], xf.T
