"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret
mode on CPU, compiled on TPU) and the implementations the public ``ops``
wrappers fall back to on non-TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- lc_filter


def lc_filter(
    ad: jax.Array,  # (3, 3) discrete state matrix
    bd: jax.Array,  # (3, 2) discrete input matrix
    c_row: jax.Array,  # (3,) output row (grid current)
    x0: jax.Array,  # (R, 3) initial state per rack
    node_power: jax.Array,  # (T, R) per-unit node power (i_load input)
) -> tuple[jax.Array, jax.Array]:
    """State-space IIR filter over a trace; v_in is fixed at 1.0 per-unit.

    Returns (grid (T, R), x_final (R, 3)).
    """
    b_vin = bd[:, 0]  # constant drive from v_in = 1
    b_load = bd[:, 1]

    def step(x, u_t):
        y = x @ c_row
        x_next = x @ ad.T + u_t[:, None] * b_load[None, :] + b_vin[None, :]
        return x_next, y

    x_f, y = jax.lax.scan(step, x0, node_power)
    return y, x_f


# ------------------------------------------------------------------- pdu_sim


def pdu_sim(
    rack_power: jax.Array,  # (T, R)
    g0: jax.Array,  # (R,) ESS filter state
    soc0: jax.Array,  # (R,)
    x0: jax.Array,  # (R, 3) LC filter state
    ad: jax.Array,
    bd: jax.Array,
    c_row: jax.Array,
    *,
    beta: float,
    dt: float,
    q_max: float,
    eta_c: float,
    eta_d: float,
    p_max: float,
    soc_min: float,
    soc_max: float,
    corrective: jax.Array | float = 0.0,  # scalar or (T, R)
    ess_on: jax.Array | None = None,  # (R,) or (T, R) availability weight
) -> tuple[jax.Array, jax.Array, tuple]:
    """Fused EasyRider hardware path: ESS ramp control + SoC + LC filter.

    Semantically identical to ``core.ess.simulate`` piped into
    ``core.filters.simulate``; implemented as a single scan so the fused
    Pallas kernel has a one-pass oracle. Returns (grid (T,R), soc (T,R),
    (g_f, soc_f, x_f)).

    ``ess_on`` is a per-rack ESS availability *weight* in [0, 1] — a
    ``(R,)`` row held for the whole call or a ``(T, R)`` per-sample array.
    Weight 0 puts a rack in LC passthrough (p_batt = 0, SoC frozen, the
    node sees the raw rack power) while the ramp filter keeps *tracking*
    the rack so a recovering unit re-engages softly from g = r rather
    than slamming a stale setpoint.  Fractional weights scale the battery
    power (converter wind-down/soft-start around a trip), with the SoC
    integrating the scaled power.  With ``ess_on=None`` (or all ones) the
    computation is bitwise identical to the unmasked path, and binary
    weights are bitwise identical to the legacy boolean-mask semantics.
    """
    alpha = 1.0 - jnp.exp(-jnp.asarray(beta) * dt)
    corr = jnp.broadcast_to(jnp.asarray(corrective, rack_power.dtype), rack_power.shape)
    masked = ess_on is not None
    w_all = (
        jnp.broadcast_to(ess_on.astype(rack_power.dtype), rack_power.shape)
        if masked
        else None
    )
    # Unpacked state columns + scalar*vector FMAs instead of a per-step
    # (R,3)@(3,3) dot: measured +7% wall clock on host (EXPERIMENTS §Perf-1
    # it.3) and matches the Pallas kernel's formulation exactly.
    a = ad
    bl = bd[:, 1]
    bv = bd[:, 0]

    def step(carry, inp):
        g, soc, s0, s1, s2 = carry
        if masked:
            r_t, c_t, w_t = inp
        else:
            r_t, c_t = inp
        g_new = g + alpha * (r_t - g)
        if masked:
            g_new = jnp.where(w_t > 0, g_new, r_t)
        p_batt = jnp.clip(g_new - r_t + c_t, -p_max, p_max)
        if masked:
            # Converter wind-down: battery delivers the weighted fraction
            # of the commanded power (w = 1 is an exact multiply, w = 0
            # reproduces the hard passthrough bitwise).
            p_batt = p_batt * w_t
        charge = jnp.maximum(p_batt, 0.0)
        discharge = jnp.maximum(-p_batt, 0.0)
        d_soc = (dt / q_max) * (eta_c * charge - discharge / eta_d)
        soc_new = soc + d_soc
        over_hi = jnp.maximum(soc_new - soc_max, 0.0)
        over_lo = jnp.maximum(soc_min - soc_new, 0.0)
        p_batt = p_batt - over_hi * q_max / (eta_c * dt) + over_lo * q_max * eta_d / dt
        soc_new = jnp.clip(soc_new, soc_min, soc_max)
        if masked:
            soc_new = jnp.where(w_t > 0, soc_new, soc)
        node = r_t + p_batt
        y = c_row[0] * s0 + c_row[1] * s1 + c_row[2] * s2
        n0 = a[0, 0] * s0 + a[0, 1] * s1 + a[0, 2] * s2 + bl[0] * node + bv[0]
        n1 = a[1, 0] * s0 + a[1, 1] * s1 + a[1, 2] * s2 + bl[1] * node + bv[1]
        n2 = a[2, 0] * s0 + a[2, 1] * s1 + a[2, 2] * s2 + bl[2] * node + bv[2]
        return (g_new, soc_new, n0, n1, n2), (y, soc_new)

    carry0 = (g0, soc0, x0[:, 0], x0[:, 1], x0[:, 2])
    xs = (rack_power, corr, w_all) if masked else (rack_power, corr)
    (g_f, soc_f, s0, s1, s2), (grid, soc_t) = jax.lax.scan(step, carry0, xs)
    x_f = jnp.stack([s0, s1, s2], axis=-1)
    return grid, soc_t, (g_f, soc_f, x_f)


# ------------------------------------------------------------- pdu_health_sim


def pdu_health_sim(
    rack_power: jax.Array,  # (T, R)
    g0: jax.Array,  # (R,)
    soc0: jax.Array,  # (R,)
    x0: jax.Array,  # (R, 3)
    ad: jax.Array,
    bd: jax.Array,
    c_row: jax.Array,
    *,
    beta: float,
    dt: float,
    q_max: float,
    eta_c: float,
    eta_d: float,
    p_max: float,
    soc_min: float,
    soc_max: float,
    corrective: jax.Array | float = 0.0,  # scalar or (T, R)
    slew: tuple[jax.Array, jax.Array] | None = None,  # (applied, target) rows
    ess_on: jax.Array | None = None,  # (R,) or (T, R) availability weight
    ess_events: tuple | None = None,  # (starts, ends, base, i0, t_last)
    ess_edge: int = 1,
    health: tuple | None = None,  # ((c0, c1, eps, kappa), state_leaves)
) -> tuple[jax.Array, jax.Array, tuple, tuple | None]:
    """One-call oracle for the interval-resident conditioning megakernel.

    Extends ``pdu_sim`` with the fusions the megakernel performs per
    controller interval:

    * **In-scan command slew** — ``slew=(applied, target)`` renders the
      corrective-power ramp ``applied + (target - applied) * (t+1)/T``
      per step from two ``(R,)`` rows instead of consuming a materialized
      ``(T, R)`` profile.  Each element evaluates the identical fused
      expression, so the output is bitwise equal to passing the broadcast
      profile via ``corrective`` (and to the pre-fusion pipeline).
    * **Fused health fold** — ``health=(step_consts, state_leaves)`` folds
      the battery-wear telemetry of ``core.health.update_consts`` in the
      same call: the 5-carry turning-point machine rides its own scan and
      the throughput/stress integrals stay whole-interval ``jnp.sum``
      block reductions over the simulated SoC path.  Every leaf is
      bitwise identical to ``update_consts`` on ``pdu_sim``'s SoC output
      (this reference keeps that hybrid formulation verbatim — it is the
      profiled CPU optimum); the Pallas megakernel instead carries the
      previous sample through its single step loop, which evaluates the
      same per-step expressions on the same values and so matches
      bitwise.  Preserving the PR-5 split-invariance contract was the
      design constraint: per-sample accumulator carries and per-block
      partial sums both change the reduction order — measured 1-ulp
      drift — so neither is used anywhere.  ``state_leaves`` is the flat
      ``HealthState`` tuple; the kernels layer stays free of ``core``
      imports.
    * **In-scan ESS weight rendering** — ``ess_events=(starts, ends, base,
      i0, t_last)`` replaces the streamed ``(T, R)`` availability block
      with a compact episode-table operand: sorted ``(E, R)`` int32
      start/end boundary tables (padded with empty intervals), a ``(R,)``
      base availability row (interval online-mask x sensed-mask), and the
      scalar absolute index ``i0`` of the first sample plus ``t_last``,
      the absolute index of the last *real* sample (per-step indices clamp
      to it so zero-order-hold padding replicates the last real weight,
      matching the streamed path's repeat-pad).  Each step renders
      ``w_t = (1 - edge_intensity(idx_t)) * base`` with the identical
      clip/where arithmetic as ``faults.ess_weight`` — the same two float
      ops on the same inputs as the precomputed ``weight * base`` product,
      so the result is bitwise equal to streaming that product via
      ``ess_on``.  ``ess_edge`` is the static wind-down width in samples
      (``<= 1`` renders binary membership exactly).

    Returns ``(grid, soc_t, (g_f, soc_f, x_f), health_leaves_or_None)``.
    """
    alpha = 1.0 - jnp.exp(-jnp.asarray(beta) * dt)
    t = rack_power.shape[0]
    events = ess_events is not None
    if events and ess_on is not None:
        raise ValueError("pass either ess_on or ess_events, not both")
    masked = ess_on is not None or events
    w_all = (
        jnp.broadcast_to(ess_on.astype(rack_power.dtype), rack_power.shape)
        if ess_on is not None
        else None
    )
    if events:
        ev_st, ev_en, ev_base, ev_i0, ev_tlast = ess_events
        ev_st = jnp.asarray(ev_st, jnp.int32)  # (E, R) sorted along axis 0
        ev_en = jnp.asarray(ev_en, jnp.int32)
        idxvec = jnp.minimum(
            jnp.asarray(ev_i0, jnp.int32) + jnp.arange(t, dtype=jnp.int32),
            jnp.asarray(ev_tlast, jnp.int32),
        )

        def events_weight(idx_t):
            # Rows are sorted along the episode axis, so "entry j is
            # at-or-before idx" is exactly "count >= j+1" — the unrolled
            # compares below select the same boundaries (and the same
            # cnt>0 gate) as faults._select_boundaries, bitwise.
            started = [ev_st[j] <= idx_t for j in range(ev_st.shape[0])]
            if ess_edge <= 1:
                s_cnt = sum(s.astype(jnp.int32) for s in started)
                e_cnt = sum(
                    (ev_en[j] <= idx_t).astype(jnp.int32)
                    for j in range(ev_en.shape[0])
                )
                intensity = ((s_cnt - e_cnt) > 0).astype(jnp.float32)
            else:
                inv = 1.0 / float(ess_edge)
                st_sel, en_sel = ev_st[0], ev_en[0]
                for j in range(1, ev_st.shape[0]):
                    st_sel = jnp.where(started[j], ev_st[j], st_sel)
                    en_sel = jnp.where(started[j], ev_en[j], en_sel)
                a = (idx_t - st_sel).astype(jnp.float32)
                b = (idx_t - en_sel).astype(jnp.float32)
                w = jnp.clip((a + 1.0) * inv, 0.0, 1.0) - jnp.clip(
                    (b + 1.0) * inv, 0.0, 1.0
                )
                intensity = jnp.where(started[0], w, 0.0)
            return (1.0 - intensity) * ev_base
    if slew is not None:
        applied, target = slew
        diff = target - applied
        ramp01 = jnp.arange(1, t + 1, dtype=jnp.float32) / t
        corr_parts, corr = (applied, diff, ramp01), None
    else:
        corr = jnp.broadcast_to(
            jnp.asarray(corrective, rack_power.dtype), rack_power.shape
        )
        corr_parts = None
    a = ad
    bl = bd[:, 1]
    bv = bd[:, 0]

    def step(carry, inp):
        g, soc, s0, s1, s2 = carry
        if slew is not None:
            (r_t, ramp_t, *rest) = inp
            c_t = corr_parts[0] + corr_parts[1] * ramp_t
        else:
            (r_t, c_t, *rest) = inp
        if masked:
            w_t = events_weight(rest[0]) if events else rest[0]
        g_new = g + alpha * (r_t - g)
        if masked:
            g_new = jnp.where(w_t > 0, g_new, r_t)
        p_batt = jnp.clip(g_new - r_t + c_t, -p_max, p_max)
        if masked:
            p_batt = p_batt * w_t
        charge = jnp.maximum(p_batt, 0.0)
        discharge = jnp.maximum(-p_batt, 0.0)
        soc_new = soc + (dt / q_max) * (eta_c * charge - discharge / eta_d)
        over_hi = jnp.maximum(soc_new - soc_max, 0.0)
        over_lo = jnp.maximum(soc_min - soc_new, 0.0)
        p_batt = p_batt - over_hi * q_max / (eta_c * dt) + over_lo * q_max * eta_d / dt
        soc_new = jnp.clip(soc_new, soc_min, soc_max)
        if masked:
            soc_new = jnp.where(w_t > 0, soc_new, soc)
        node = r_t + p_batt
        y = c_row[0] * s0 + c_row[1] * s1 + c_row[2] * s2
        n0 = a[0, 0] * s0 + a[0, 1] * s1 + a[0, 2] * s2 + bl[0] * node + bv[0]
        n1 = a[1, 0] * s0 + a[1, 1] * s1 + a[1, 2] * s2 + bl[1] * node + bv[1]
        n2 = a[2, 0] * s0 + a[2, 1] * s1 + a[2, 2] * s2 + bl[2] * node + bv[2]
        return (g_new, soc_new, n0, n1, n2), (y, soc_new)

    carry0 = (g0, soc0, x0[:, 0], x0[:, 1], x0[:, 2])
    xs = [rack_power, ramp01 if slew is not None else corr]
    if masked:
        xs.append(idxvec if events else w_all)
    (g_f, soc_f, s0, s1, s2), (grid, soc_t) = jax.lax.scan(
        step, carry0, tuple(xs)
    )
    x_f = jnp.stack([s0, s1, s2], axis=-1)
    if health is None:
        return grid, soc_t, (g_f, soc_f, x_f), None
    (c0, c1, eps, kappa), hs = health
    (prev_soc, last_ext, direction, half_cycles, cycle_damage, max_dod,
     charge_soc, discharge_soc, soc_sum, soc_sq_sum, samples) = hs
    prev_t = jnp.concatenate(
        [jnp.broadcast_to(prev_soc, soc_t[:1].shape), soc_t[:-1]], axis=0
    )
    delta = soc_t - prev_t
    step_dir = jnp.where(delta > eps, 1.0, jnp.where(delta < -eps, -1.0, 0.0))

    def hbody(carry, inp):
        last_ext, direction, half_cycles, damage, max_dod = carry
        prev, sd = inp
        rev = (sd * direction) < 0.0
        revf = jnp.where(rev, 1.0, 0.0)
        depth = jnp.abs(prev - last_ext)
        half_w = jnp.maximum(c0 + c1 * (prev + last_ext), 0.0)
        if float(kappa) == 1.0:
            powd = depth
        elif float(kappa).is_integer() and 2 <= int(kappa) <= 4:
            powd = depth
            for _ in range(int(kappa) - 1):
                powd = powd * depth
        else:
            powd = jnp.power(depth, kappa)
        dmg = half_w * powd
        return (
            jnp.where(rev, prev, last_ext),
            jnp.where(sd != 0.0, sd, direction),
            half_cycles + revf,
            damage + revf * dmg,
            jnp.maximum(max_dod, revf * depth),
        ), None

    (last_ext, direction, half_cycles, damage, max_dod), _ = jax.lax.scan(
        hbody,
        (last_ext, direction, half_cycles, cycle_damage, max_dod),
        (prev_t, step_dir),
    )
    h_out = (
        soc_t[-1], last_ext, direction, half_cycles, damage, max_dod,
        charge_soc + jnp.sum(jnp.maximum(delta, 0.0), axis=0),
        discharge_soc + jnp.sum(jnp.maximum(-delta, 0.0), axis=0),
        soc_sum + jnp.sum(soc_t, axis=0),
        soc_sq_sum + jnp.sum(soc_t * soc_t, axis=0),
        samples + jnp.int32(t),
    )
    return grid, soc_t, (g_f, soc_f, x_f), h_out


# -------------------------------------------------------------- admm_iterate


def admm_iterate(
    kkt_stack: jax.Array,  # (2h, 5h) [sigma K^-1 | K^-1 A'] stacked
    g_blk: jax.Array,  # (h, 2h) SoC-constraint rows of A (A = [I; G])
    kq: jax.Array,  # (2h, ...) hoisted K^-1 q
    lo: jax.Array,  # (3h, ...)
    hi: jax.Array,
    x0: jax.Array,  # (2h, ...)
    z0: jax.Array,  # (3h, ...)
    y0: jax.Array,  # (3h, ...)
    *,
    rho: float,
    iters: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused batched-ADMM iteration loop (the controller QP inner loop).

    Exploits the plan's constraint structure ``A = [I_2h; G]``: the
    x-update's two K^-1 GEMMs collapse into one stacked
    ``(2h, 5h) @ (5h, R)`` product, and ``A x`` needs only the ``(h, 2h)``
    SoC block — the box rows of ``A x`` are ``x`` itself (exactly: the
    identity block contributes bitwise-equal rows).  Per iteration this is
    12h^2 R MACs versus 16h^2 R for the unfused pair, with x/z/y staying
    in one fused loop body (no per-iteration HBM round-trips on the Pallas
    path).  The stacked GEMM reassociates each output dot (one 5h-term sum
    instead of 2h- and 3h-term partials added), so x agrees with the
    unfused formulation to GEMM rounding, not bitwise — the controller
    equivalence tests bound this against the build-per-step oracle.
    """
    rho = jnp.float32(rho)

    def body(carry, _):
        x, z, y = carry
        x_new = kkt_stack @ jnp.concatenate([x, rho * z - y], axis=0) - kq
        ax = jnp.concatenate([x_new, g_blk @ x_new], axis=0)
        z_new = jnp.clip(ax + y / rho, lo, hi)
        y_new = y + rho * (ax - z_new)
        return (x_new, z_new, y_new), None

    (x, z, y), _ = jax.lax.scan(body, (x0, z0, y0), None, length=iters)
    return x, z, y


# ------------------------------------------------------------------- rmsnorm


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis: x * w / rms(x)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------- gemm_burn


def gemm_burn(a: jax.Array, b: jax.Array, n_iters: int = 1) -> jax.Array:
    """Burn-kernel semantics: the mean of ``n_iters`` evaluations of A @ B.

    Numerically equal to A @ B; the iteration count is the duty-cycle knob
    that makes the kernel burn n_iters x the FLOPs (the compiler cannot
    elide the loop because each term is accumulated).
    """
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)

    def body(i, acc):
        return acc + jnp.dot(a, b, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, n_iters, body, acc)
    return (acc / n_iters).astype(a.dtype)


# ----------------------------------------------------- flash attention (fwd)


def attention(
    q: jax.Array,  # (B, H, Tq, D)
    k: jax.Array,  # (B, Hkv, Tk, D)
    v: jax.Array,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    bias: jax.Array | None = None,  # broadcastable to (B, H, Tq, Tk)
    chunk_q: int = 1024,
) -> jax.Array:
    """Reference softmax attention with GQA (H a multiple of Hkv).

    For long sequences (Tq > chunk_q, no bias) queries are processed in
    scanned, rematerialized blocks so peak memory is O(chunk_q * Tk)
    rather than O(Tq * Tk) — this is the compile path for the 32k-token
    dry-run shapes on the CPU/fallback backend (the Pallas kernel covers
    TPU execution).
    """
    b, h, tq, d = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    groups = h // hkv
    kx = jnp.repeat(k, groups, axis=1)
    vx = jnp.repeat(v, groups, axis=1)
    tk = kx.shape[2]

    def block(q_blk, q_offset):
        # q_blk: (B, H, Bq, D); absolute position = q_offset + row + (tk - tq)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q_blk, kx).astype(jnp.float32) * scale
        if bias is not None:
            logits = logits + bias.astype(jnp.float32)
        if causal:
            rows = q_offset + jnp.arange(q_blk.shape[2]) + (tk - tq)
            mask = jnp.arange(tk)[None, :] <= rows[:, None]
            logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vx)

    if tq <= chunk_q or tq % chunk_q != 0 or bias is not None:
        return block(q, jnp.asarray(0))

    qb = q.reshape(b, h, tq // chunk_q, chunk_q, d).transpose(2, 0, 1, 3, 4)

    @jax.checkpoint
    def body(i, q_blk):
        return i + chunk_q, block(q_blk, i)

    _, out = jax.lax.scan(body, jnp.asarray(0), qb)
    # output feature dim follows V (MLA: q/k are 192-dim, v is 128-dim)
    return out.transpose(1, 2, 0, 3, 4).reshape(b, h, tq, vx.shape[-1])


# ----------------------------------------------------------------- rwkv6 scan


def rwkv6_chunked(
    r: jax.Array,  # (B, H, T, D)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay in (0, 1)
    u: jax.Array,  # (H, D)
    state0: jax.Array | None = None,  # (B, H, D, D)
    *,
    chunk: int = 32,
) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel RWKV-6 (EXPERIMENTS §Perf-2).

    Mathematically identical to ``rwkv6_scan`` but restructured so the
    (D, D) state is read/written once per *chunk* instead of once per
    *step* (memory term / chunk) and the inner work becomes (L, D) x (D, L)
    matmuls (MXU-friendly) instead of per-step outer products:

      A[t,s]   = (r_t * W_{t-1}) . (k_s / W_s)          s < t   (intra)
      o_t      = tril(A,-1) @ v + (r_t*u*k_t).v_t + (r_t*W_{t-1}) @ S_in
      S_out    = diag(W_L) S_in + (k_s * W_L/W_s)^T v

    with W_t = prod_{s<=t} w_s (per channel, fp32 logs for stability;
    ``chunk`` bounds the exponent range).

    Numerics: the factored intermediates exp(±cum) can overflow fp32 when
    per-step decay is extreme (found by adversarial testing at w=0.01 over
    a 64-chunk).  Exponents are clamped to ±CLAMP: any pair whose TRUE
    relative decay is below e^-CLAMP contributes ~0 and stays ~0 after
    clamping, so accuracy holds whenever per-chunk total decay
    >= e^-CLAMP, i.e. mean per-step w >= exp(-CLAMP/chunk) (~0.29 at
    chunk=32) — far below any decay this architecture's parameterization
    reaches in practice; the sequential oracle remains available via
    ``ops.rwkv6_scan(algorithm="sequential")`` for pathological regimes.
    """
    b, h, t, d = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, d, d), jnp.float32)
    if t % chunk != 0 or t <= chunk:
        return rwkv6_scan(r, k, v, w, u, state0)

    nc = t // chunk
    shp = (b, h, nc, chunk, d)
    rc = r.astype(jnp.float32).reshape(shp)
    kc = k.astype(jnp.float32).reshape(shp)
    vc = v.astype(jnp.float32).reshape(shp)
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30)).reshape(shp)
    cum = jnp.cumsum(lw, axis=3)  # inclusive
    cum_prev = cum - lw  # exclusive: W_{t-1}
    total = cum[:, :, :, -1:, :]  # log W_L

    clamp = 40.0
    r_tilde = rc * jnp.exp(jnp.clip(cum_prev, -clamp, clamp))  # r_t * W_{t-1}
    k_tilde = kc * jnp.exp(jnp.clip(-cum, -clamp, clamp))  # k_s / W_s
    k_tail = kc * jnp.exp(jnp.clip(total - cum, -clamp, clamp))  # k_s W_L/W_s

    # intra-chunk attention-like matrix (strictly lower triangular)
    a_mat = jnp.einsum("bhctd,bhcsd->bhcts", r_tilde, k_tilde)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    a_mat = jnp.where(mask[None, None, None], a_mat, 0.0)
    o_intra = jnp.einsum("bhcts,bhcsd->bhctd", a_mat, vc)
    # current-token bonus
    o_diag = jnp.einsum("bhctd,bhctd->bhct", rc * u[None, :, None, None, :], kc)[
        ..., None
    ] * vc
    # chunk state contributions
    s_add = jnp.einsum("bhcsd,bhcse->bhcde", k_tail, vc)  # (B,H,nc,D,D)
    w_chunk = jnp.exp(total[:, :, :, 0, :])  # (B,H,nc,D)

    def scan_chunks(s, inp):
        s_a, w_c, r_t = inp  # (B,H,D,D), (B,H,D), (B,H,L,D)
        o_inter = jnp.einsum("bhtd,bhde->bhte", r_t, s)
        s_next = w_c[..., :, None] * s + s_a
        return s_next, o_inter

    s_f, o_inter = jax.lax.scan(
        scan_chunks,
        state0.astype(jnp.float32),
        (jnp.moveaxis(s_add, 2, 0), jnp.moveaxis(w_chunk, 2, 0),
         jnp.moveaxis(r_tilde, 2, 0)),
    )
    o_inter = jnp.moveaxis(o_inter, 0, 2)  # (B,H,nc,L,D)
    out = (o_intra + o_diag + o_inter).reshape(b, h, t, d)
    return out.astype(r.dtype), s_f


def rwkv6_scan(
    r: jax.Array,  # (B, H, T, D) receptance
    k: jax.Array,  # (B, H, T, D) key
    v: jax.Array,  # (B, H, T, D) value
    w: jax.Array,  # (B, H, T, D) per-channel decay in (0, 1): exp(-exp(...))
    u: jax.Array,  # (H, D) bonus for the current token
    state0: jax.Array | None = None,  # (B, H, D, D)
    *,
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 (Finch) time-mix recurrence.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t        (outer product, (D, D))
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

    Shapes follow the head-major layout; returns (out (B,H,T,D), S_T).
    """
    b, h, t, d = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, d, d), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, D) each
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, D, D)
        out = jnp.einsum("bhd,bhde->bhe", r_t, s + u[None, :, :, None] * kv)
        s_next = w_t[..., :, None] * s + kv
        return s_next, out

    xs = tuple(jnp.moveaxis(a, 2, 0).astype(jnp.float32) for a in (r, k, v, w))

    # Chunked remat: without it the backward pass stores the (D, D) state
    # for every timestep (hundreds of GB at 4k+ tokens); chunking stores one
    # state per ``chunk`` steps and recomputes inside.
    if t % chunk == 0 and t > chunk:
        n_chunks = t // chunk
        xs_c = tuple(a.reshape((n_chunks, chunk) + a.shape[1:]) for a in xs)

        @jax.checkpoint
        def chunk_body(s, inp):
            return jax.lax.scan(step, s, inp)

        s_f, out = jax.lax.scan(chunk_body, state0, xs_c)
        out = out.reshape((t,) + out.shape[2:])
    else:
        s_f, out = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(out, 0, 2).astype(r.dtype), s_f
