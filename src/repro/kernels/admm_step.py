"""Batched controller-QP ADMM iteration loop as one Pallas TPU kernel.

``controller.solve_qp_admm_plan`` runs a fixed number of OSQP-style ADMM
iterations whose per-iteration work is two small precomputed-``K^-1``
GEMMs plus the z-projection and y dual update — at fleet width each
iteration round-trips the (2h, R) / (3h, R) iterates through HBM.  This
kernel runs the whole loop with x, z, y resident in VMEM: the x-update is
the single stacked ``(2h, 5h) @ (5h, r_blk)`` MXU product of
``[sigma K^-1 | K^-1 A']`` against ``[x; rho z - y]``, and ``A x``
exploits the plan's structure ``A = [I; G]`` (box rows of ``A x`` are
``x`` itself, exactly), so only the (h, 2h) SoC block multiplies.

Racks tile across lanes (grid = rack tiles); the plan matrices are a few
KB and ride along each tile.  Matches ``ref.admm_iterate`` (the jnp
fallback) to GEMM rounding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _admm_kernel(
    ks_ref, g_ref, kq_ref, lo_ref, hi_ref, x0_ref, z0_ref, y0_ref,
    x_ref, z_ref, y_ref,
    *,
    rho: float,
    iters: int,
):
    ks = ks_ref[...]  # (2h, 5h)
    g = g_ref[...]  # (h, 2h)
    kq = kq_ref[...]
    lo = lo_ref[...]
    hi = hi_ref[...]

    def body(_, carry):
        x, z, y = carry
        rhs = jnp.concatenate([x, rho * z - y], axis=0)  # (5h, r)
        x_new = jnp.dot(ks, rhs, preferred_element_type=jnp.float32) - kq
        ax = jnp.concatenate(
            [x_new, jnp.dot(g, x_new, preferred_element_type=jnp.float32)],
            axis=0,
        )
        # y / rho, not y * (1/rho): the reciprocal multiply is a different
        # rounding and ADMM clip boundaries amplify the ulp over the loop.
        z_new = jnp.clip(ax + y / rho, lo, hi)
        y_new = y + rho * (ax - z_new)
        return (x_new, z_new, y_new)

    x, z, y = jax.lax.fori_loop(
        0, iters, body, (x0_ref[...], z0_ref[...], y0_ref[...])
    )
    x_ref[...] = x
    z_ref[...] = z
    y_ref[...] = y


@functools.partial(jax.jit, static_argnames=("rho", "iters", "r_blk", "interpret"))
def admm_iterate(
    kkt_stack: jax.Array,  # (2h, 5h)
    g_blk: jax.Array,  # (h, 2h)
    kq: jax.Array,  # (2h, R)
    lo: jax.Array,  # (3h, R)
    hi: jax.Array,
    x0: jax.Array,
    z0: jax.Array,
    y0: jax.Array,
    *,
    rho: float,
    iters: int,
    r_blk: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Run ``iters`` fused ADMM steps; returns final ``(x, z, y)``."""
    n2, r = kq.shape
    n3 = lo.shape[0]
    r_blk = min(r_blk, max(-(-r // 128) * 128, 128))
    r_pad = -r % r_blk
    f32 = jnp.float32

    def pad(x):
        x = x.astype(f32)
        return jnp.pad(x, ((0, 0), (0, r_pad))) if r_pad else x

    row_spec = lambda n: pl.BlockSpec(n.shape, lambda i: (0, 0))
    batched = [pad(kq), pad(lo), pad(hi), pad(x0), pad(z0), pad(y0)]
    x, z, y = pl.pallas_call(
        functools.partial(_admm_kernel, rho=float(rho), iters=int(iters)),
        grid=((r + r_pad) // r_blk,),
        in_specs=[
            row_spec(kkt_stack),
            row_spec(g_blk),
            pl.BlockSpec((n2, r_blk), lambda i: (0, i)),
            pl.BlockSpec((n3, r_blk), lambda i: (0, i)),
            pl.BlockSpec((n3, r_blk), lambda i: (0, i)),
            pl.BlockSpec((n2, r_blk), lambda i: (0, i)),
            pl.BlockSpec((n3, r_blk), lambda i: (0, i)),
            pl.BlockSpec((n3, r_blk), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n2, r_blk), lambda i: (0, i)),
            pl.BlockSpec((n3, r_blk), lambda i: (0, i)),
            pl.BlockSpec((n3, r_blk), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n2, r + r_pad), f32),
            jax.ShapeDtypeStruct((n3, r + r_pad), f32),
            jax.ShapeDtypeStruct((n3, r + r_pad), f32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(kkt_stack.astype(f32), g_blk.astype(f32), *batched)
    return x[:, :r], z[:, :r], y[:, :r]
