"""Fused RMSNorm Pallas TPU kernel.

Memory-bound: one HBM read + one write per element (vs separate
mean/rsqrt/mul HLOs).  Rows ride the sublane dimension in blocks of
``block_rows``; the feature dimension must be lane-aligned (multiple of
128) — model dims in the assigned architectures all are.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,  # (..., D)
    weight: jax.Array,  # (D,)
    eps: float = 1e-6,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    n = 1
    for s in orig_shape[:-1]:
        n *= s
    x2 = x.reshape(n, d)
    rows = min(block_rows, n)
    # pad rows to a multiple of the block
    n_pad = -n % rows
    if n_pad:
        x2 = jnp.concatenate([x2, jnp.zeros((n_pad, d), x.dtype)], axis=0)
    grid = (x2.shape[0] // rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, weight)
    if n_pad:
        out = out[:n]
    return out.reshape(orig_shape)
