"""MXU burn kernel (paper Appendix C.1, adapted GPU->TPU).

The software-burn baseline needs a kernel whose FLOP count is a precise
knob: the duty-cycle controller converts a power target into an amount of
matrix work.  On TPU the analogue of the paper's CUDA GEMM loop is an
MXU-aligned tiled matmul that re-accumulates its product ``n_iters`` times:
FLOPs = n_iters * 2 * M * N * K, while the result stays numerically equal
to A @ B (mean of identical accumulations), so correctness is testable.

Tiling: (bm x bk) @ (bk x bn) blocks, MXU-aligned (multiples of 128), fp32
accumulator scratch in VMEM; the k-loop rides the innermost grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _burn_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_iters: int, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]

    def body(i, acc):
        return acc + jnp.dot(a, b, preferred_element_type=jnp.float32)

    acc_ref[...] += jax.lax.fori_loop(0, n_iters, body, jnp.zeros_like(acc_ref))

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / n_iters).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_iters", "bm", "bn", "bk", "interpret")
)
def gemm_burn(
    a: jax.Array,  # (M, K)
    b: jax.Array,  # (K, N)
    n_iters: int = 1,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, "MXU-aligned shapes only"
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_burn_kernel, n_iters=n_iters, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
