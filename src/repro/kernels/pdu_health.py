"""Interval-resident conditioning megakernel (Pallas TPU).

One launch conditions an entire controller interval: the fused PDU
hardware path of ``pdu_sim`` (ESS ramp filter -> SoC integration -> LC
filter) **plus** the corrective-command slew and the battery-health fold
that previously ran as separate passes around it.  The full per-rack
state — ESS filter value ``g``, SoC, the 3-vector LC state, the
per-sample fault/degraded weight path, and the battery-wear turning-point
machine (previous sample, last extremum, direction, half-cycle count,
cycle damage, max DoD) — stays resident in VMEM for the whole interval,
so the rack trace is read from HBM exactly once per sample and no
intermediate (T, R) block (the slewed corrective profile, the wear
machine's delta stream) round-trips through HBM at all.

Layout: racks tile across lanes (grid = rack tiles of ``r_blk`` lanes;
one grid step owns its tile end-to-end), time rides the sublane axis with
the whole interval resident per tile.  VMEM budget per tile at the fleet
design point (T = 1000 samples, r_blk = 128 lanes, fp32): trace in +
grid/SoC out = 3 x T x r_blk x 4 B = 1.5 MB, plus (5 + 2x6 + 5) x r_blk
x 4 B < 12 KB of state — ~1.5 MB single-buffered (~3 MB with the
pipeline's double buffering, and +0.5 MB each for an optional per-sample
weight or dense corrective operand), comfortably inside the ~16 MB/core
VMEM.  Per lane that is ~12 KB of streaming buffer and 88 B of carried
state — the PR-5 "14-carry spill" was an XLA:CPU *register/L1* pathology
of one wide scan body; here the carries are explicit VMEM rows and never
touch the stack.

Bitwise contract (the PR-5 reproducibility contract, verified in
``tests/test_pdu_health_kernel.py`` against ``ref.pdu_health_sim`` in
interpret mode): the SoC path, the ESS filter value, and every health
leaf are bit-identical to the reference — the turning-point machine
folds sample-by-sample in the step loop (bit-identical under any stream
split), and the throughput / SoC-stress accumulators are whole-interval
``jnp.sum`` reductions evaluated in the wrapper's epilogue over the
kernel's bitwise SoC output, at the exact (t, r) reduce shape the
reference uses — the same single-block reduction, NOT per-sample
accumulator carries or padded-tile reductions (both change the reduction
order; the latter was measured 1 ulp off at narrow widths).  The grid
output and LC filter state agree to a few ulp rather than bitwise: the
LC update is a mul-add chain and XLA contracts it into FMAs differently
across the two loop structures (measured ~4e-7 max on O(1) outputs, a
handful of lanes) — evaluation-order source parity cannot pin that down,
and nothing downstream keys on grid bits (campus aggregation is
tolerance-checked).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _megakernel(
    *refs,
    t_total: int,
    dt: float,
    q_max: float,
    eta_c: float,
    eta_d: float,
    p_max: float,
    soc_min: float,
    soc_max: float,
    masked: bool,
    mask_2d: bool,
    events: bool,
    ess_edge: int,
    slew: bool,
    track_health: bool,
    hconsts: tuple | None,
):
    it = iter(refs)
    ad_ref, bd_ref, c_ref, al_ref, s0_ref, r_ref, corr_ref = (
        next(it) for _ in range(7)
    )
    on_ref = next(it) if (masked and not events) else None
    if events:
        ev_st_ref, ev_en_ref, base_ref, iev_ref = (next(it) for _ in range(4))
    h0_ref = next(it) if track_health else None
    grid_ref, soc_ref, sf_ref = (next(it) for _ in range(3))
    hf_ref = next(it) if track_health else None

    a = ad_ref[...]
    b = bd_ref[...]
    c = c_ref[...]
    alpha = al_ref[0, 0]
    w_row = on_ref[0, :] if (masked and not mask_2d and not events) else None
    if events:
        # Compact episode-table operand: (E, r_blk) sorted int32 boundary
        # tables + a (r_blk,) base availability row, resident in VMEM for
        # the whole interval — replaces the streamed (T, r_blk) weight
        # block (HBM traffic O(E + 1) rows instead of O(T)).
        ev_st = ev_st_ref[...]
        ev_en = ev_en_ref[...]
        ev_base = base_ref[0, :]
        ev_i0 = iev_ref[0, 0]
        ev_tlast = iev_ref[0, 1]
    if slew:
        applied = corr_ref[0, :]
        diff = corr_ref[1, :]
    if track_health:
        c0, c1, eps, kappa = hconsts

    def events_weight(t):
        # Per-step ESS availability from boundary events, the identical
        # clip/where arithmetic as faults.ess_weight (rows sorted, so
        # "entry j <= idx" == "count >= j+1" — same boundary selection as
        # faults._select_boundaries, bitwise).  Clamping the absolute
        # index to the last real sample replicates the streamed path's
        # zero-order-hold repeat-padding.
        idx_t = jnp.minimum(ev_i0 + t, ev_tlast)
        started = [ev_st[j, :] <= idx_t for j in range(ev_st.shape[0])]
        if ess_edge <= 1:
            s_cnt = sum(s.astype(jnp.int32) for s in started)
            e_cnt = sum(
                (ev_en[j, :] <= idx_t).astype(jnp.int32)
                for j in range(ev_en.shape[0])
            )
            intensity = ((s_cnt - e_cnt) > 0).astype(jnp.float32)
        else:
            inv = 1.0 / float(ess_edge)
            st_sel, en_sel = ev_st[0, :], ev_en[0, :]
            for j in range(1, ev_st.shape[0]):
                st_sel = jnp.where(started[j], ev_st[j, :], st_sel)
                en_sel = jnp.where(started[j], ev_en[j, :], en_sel)
            wa = (idx_t - st_sel).astype(jnp.float32)
            wb = (idx_t - en_sel).astype(jnp.float32)
            w = jnp.clip((wa + 1.0) * inv, 0.0, 1.0) - jnp.clip(
                (wb + 1.0) * inv, 0.0, 1.0
            )
            intensity = jnp.where(started[0], w, 0.0)
        return (1.0 - intensity) * ev_base

    def step(t, carry):
        g, soc, x0, x1, x2, hm = carry
        r_t = r_ref[t, :]
        if slew:
            # ramp = (t+1)/T, the identical fused expression the reference
            # evaluates from its arange — the slewed corrective profile is
            # rendered in-register instead of streamed from HBM.
            c_t = applied + diff * ((t + 1).astype(jnp.float32) / t_total)
        else:
            c_t = corr_ref[t, :]
        if masked:
            if events:
                w_t = events_weight(t)
            else:
                w_t = on_ref[t, :] if mask_2d else w_row
        # --- ESS ramp control (paper Eq. 2, exact ZOH) --------------------
        g_new = g + alpha * (r_t - g)
        if masked:
            g_new = jnp.where(w_t > 0, g_new, r_t)
        p_batt = jnp.clip(g_new - r_t + c_t, -p_max, p_max)
        if masked:
            p_batt = p_batt * w_t
        # --- SoC integration with efficiency asymmetry (Eq. 14) -----------
        charge = jnp.maximum(p_batt, 0.0)
        discharge = jnp.maximum(-p_batt, 0.0)
        soc_new = soc + (dt / q_max) * (eta_c * charge - discharge / eta_d)
        over_hi = jnp.maximum(soc_new - soc_max, 0.0)
        over_lo = jnp.maximum(soc_min - soc_new, 0.0)
        p_batt = p_batt - over_hi * q_max / (eta_c * dt) + over_lo * q_max * eta_d / dt
        soc_new = jnp.clip(soc_new, soc_min, soc_max)
        if masked:
            soc_new = jnp.where(w_t > 0, soc_new, soc)
        node = r_t + p_batt
        # --- LC filter (grid current out, state update) --------------------
        grid_ref[t, :] = (c[0, 0] * x0 + c[0, 1] * x1 + c[0, 2] * x2).astype(
            grid_ref.dtype
        )
        soc_ref[t, :] = soc_new
        x0n = a[0, 0] * x0 + a[0, 1] * x1 + a[0, 2] * x2 + b[0, 1] * node + b[0, 0]
        x1n = a[1, 0] * x0 + a[1, 1] * x1 + a[1, 2] * x2 + b[1, 1] * node + b[1, 0]
        x2n = a[2, 0] * x0 + a[2, 1] * x1 + a[2, 2] * x2 + b[2, 1] * node + b[2, 0]
        # --- wear turning-point machine (core.health semantics) ------------
        if track_health:
            prev, last_ext, dirn, half, dmg_acc, mdod = hm
            # prev is the wear stream's previous sample (seeded from the
            # health state, == the ESS carry thereafter), so delta matches
            # the reference's prev_soc-relative first step by construction.
            delta = soc_new - prev
            sd = jnp.where(delta > eps, 1.0, jnp.where(delta < -eps, -1.0, 0.0))
            rev = (sd * dirn) < 0.0
            revf = jnp.where(rev, 1.0, 0.0)
            depth = jnp.abs(prev - last_ext)
            half_w = jnp.maximum(c0 + c1 * (prev + last_ext), 0.0)
            if float(kappa) == 1.0:
                powd = depth
            elif float(kappa).is_integer() and 2 <= int(kappa) <= 4:
                powd = depth
                for _ in range(int(kappa) - 1):
                    powd = powd * depth
            else:
                powd = jnp.power(depth, kappa)
            hm = (
                soc_new,
                jnp.where(rev, prev, last_ext),
                jnp.where(sd != 0.0, sd, dirn),
                half + revf,
                dmg_acc + revf * (half_w * powd),
                jnp.maximum(mdod, revf * depth),
            )
        return (g_new, soc_new, x0n, x1n, x2n, hm)

    hm0 = tuple(h0_ref[i, :] for i in range(6)) if track_health else ()
    carry0 = (s0_ref[0, :], s0_ref[1, :], s0_ref[2, :], s0_ref[3, :], s0_ref[4, :], hm0)
    g, soc, x0, x1, x2, hm = jax.lax.fori_loop(0, t_total, step, carry0)
    sf_ref[...] = jnp.stack([g, soc, x0, x1, x2], axis=0)
    if track_health:
        hf_ref[...] = jnp.stack([hm[0], hm[1], hm[2], hm[3], hm[4], hm[5]], axis=0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "beta", "dt", "q_max", "eta_c", "eta_d", "p_max", "soc_min", "soc_max",
        "health_consts", "ess_edge", "r_blk", "interpret",
    ),
)
def pdu_health_sim(
    rack_power: jax.Array,  # (T, R)
    g0: jax.Array,  # (R,)
    soc0: jax.Array,  # (R,)
    x0: jax.Array,  # (R, 3)
    ad: jax.Array,
    bd: jax.Array,
    c_row: jax.Array,
    *,
    beta: float,
    dt: float,
    q_max: float,
    eta_c: float,
    eta_d: float,
    p_max: float,
    soc_min: float,
    soc_max: float,
    corrective: jax.Array | float = 0.0,
    slew: tuple[jax.Array, jax.Array] | None = None,
    ess_on: jax.Array | None = None,
    ess_events: tuple | None = None,  # (starts, ends, base, i0, t_last)
    ess_edge: int = 1,
    health_consts: tuple | None = None,  # (c0, c1, eps, kappa) host floats
    health_state: tuple | None = None,  # 11 HealthState leaves, (R,) each
    r_blk: int = 128,
    interpret: bool = False,
):
    """Interval-resident megakernel.  Same contract as ``ref.pdu_health_sim``
    (health passed as the split ``health_consts`` / ``health_state`` so the
    consts stay static; ``ess_events``/``ess_edge`` render the per-sample
    availability weight in-kernel from sorted (E, R) boundary tables, see
    the reference docstring).  Returns
    ``(grid (T,R), soc (T,R), (g_f, soc_f, x_f), health_leaves_or_None)``.
    """
    t, r = rack_power.shape
    track_health = health_state is not None
    events = ess_events is not None
    if events and ess_on is not None:
        raise ValueError("pass either ess_on or ess_events, not both")
    masked = ess_on is not None or events
    mask_2d = ess_on is not None and ess_on.ndim == 2
    r_pad = -r % r_blk
    rp_w = r + r_pad
    t_pad = -t % 8  # sublane-align the time axis; the loop stops at t
    f32 = jnp.float32

    def pad_tr(x):  # (T, R) operand -> (T + t_pad, R + r_pad)
        x = x.astype(f32)
        if r_pad:
            x = jnp.pad(x, ((0, 0), (0, r_pad)))
        if t_pad:
            x = jnp.pad(x, ((0, t_pad), (0, 0)))
        return x

    def pad_r(x):  # (R,) row -> (R + r_pad,)
        x = jnp.broadcast_to(x, (r,)).astype(f32)
        return jnp.pad(x, (0, r_pad)) if r_pad else x

    # alpha is traced with the exact expression the reference evaluates —
    # a 1-ulp difference (e.g. from host-side float64 exp) shows up as ulp
    # drift across the whole grid/LC path.
    alpha = (1.0 - jnp.exp(-jnp.asarray(beta, jnp.float32) * dt)).reshape(1, 1)
    s0 = jnp.stack([pad_r(g0), pad_r(soc0)] + [pad_r(x0[:, i]) for i in range(3)])
    const_specs = [
        pl.BlockSpec((3, 3), lambda i: (0, 0)),
        pl.BlockSpec((3, 2), lambda i: (0, 0)),
        pl.BlockSpec((1, 3), lambda i: (0, 0)),
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
    ]
    operands = [ad.astype(f32), bd.astype(f32), c_row.reshape(1, 3).astype(f32), alpha]
    in_specs = const_specs + [
        pl.BlockSpec((5, r_blk), lambda i: (0, i)),
        pl.BlockSpec((t + t_pad, r_blk), lambda i: (0, i)),
    ]
    operands += [s0, pad_tr(rack_power)]
    if slew is not None:
        applied, target = slew
        applied = pad_r(applied)
        corr_op = jnp.stack([applied, pad_r(target) - applied], axis=0)  # (2, Rp)
        in_specs.append(pl.BlockSpec((2, r_blk), lambda i: (0, i)))
    else:
        corr_op = pad_tr(jnp.broadcast_to(jnp.asarray(corrective, f32), (t, r)))
        in_specs.append(pl.BlockSpec((t + t_pad, r_blk), lambda i: (0, i)))
    operands.append(corr_op)
    if mask_2d:
        in_specs.append(pl.BlockSpec((t + t_pad, r_blk), lambda i: (0, i)))
        operands.append(pad_tr(ess_on))
    elif masked and not events:
        in_specs.append(pl.BlockSpec((1, r_blk), lambda i: (0, i)))
        operands.append(pad_r(ess_on).reshape(1, rp_w))
    if events:
        ev_st, ev_en, ev_base, ev_i0, ev_tlast = ess_events

        def pad_ri(x):  # (E, R) int32 table -> (E, R + r_pad), pad = never
            x = jnp.asarray(x, jnp.int32)
            if r_pad:
                x = jnp.pad(
                    x, ((0, 0), (0, r_pad)),
                    constant_values=jnp.iinfo(jnp.int32).max,
                )
            return x

        n_ev = ev_st.shape[0]
        in_specs += [
            pl.BlockSpec((n_ev, r_blk), lambda i: (0, i)),
            pl.BlockSpec((n_ev, r_blk), lambda i: (0, i)),
            pl.BlockSpec((1, r_blk), lambda i: (0, i)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ]
        operands += [
            pad_ri(ev_st),
            pad_ri(ev_en),
            pad_r(ev_base).reshape(1, rp_w),
            jnp.stack(
                [jnp.asarray(ev_i0, jnp.int32), jnp.asarray(ev_tlast, jnp.int32)]
            ).reshape(1, 2),
        ]
    if track_health:
        h0 = jnp.stack([pad_r(l) for l in health_state[:6]], axis=0)  # (6, Rp)
        in_specs.append(pl.BlockSpec((6, r_blk), lambda i: (0, i)))
        operands.append(h0)

    out_specs = [
        pl.BlockSpec((t + t_pad, r_blk), lambda i: (0, i)),
        pl.BlockSpec((t + t_pad, r_blk), lambda i: (0, i)),
        pl.BlockSpec((5, r_blk), lambda i: (0, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((t + t_pad, rp_w), rack_power.dtype),
        jax.ShapeDtypeStruct((t + t_pad, rp_w), f32),
        jax.ShapeDtypeStruct((5, rp_w), f32),
    ]
    if track_health:
        out_specs.append(pl.BlockSpec((6, r_blk), lambda i: (0, i)))
        out_shape.append(jax.ShapeDtypeStruct((6, rp_w), f32))

    outs = pl.pallas_call(
        functools.partial(
            _megakernel,
            t_total=t, dt=dt, q_max=q_max, eta_c=eta_c,
            eta_d=eta_d, p_max=p_max, soc_min=soc_min, soc_max=soc_max,
            masked=masked, mask_2d=mask_2d, events=events, ess_edge=ess_edge,
            slew=slew is not None,
            track_health=track_health, hconsts=health_consts,
        ),
        grid=(rp_w // r_blk,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*operands)
    grid_t, soc_t, sf = outs[0][:t, :r], outs[1][:t, :r], outs[2][:, :r]
    finals = (sf[0], sf[1], sf[2:5].T)
    if not track_health:
        return grid_t, soc_t, finals, None
    hf = outs[3][:, :r]
    # Block accumulators: the reference's whole-interval reductions,
    # verbatim, over the sliced (t, r) SoC path — deliberately OUTSIDE the
    # kernel so the reduce shape (and therefore XLA's accumulator
    # splitting) matches the reference for every fleet width; reducing the
    # padded (t, r_blk) tile in-kernel reassociates by 1 ulp at narrow
    # widths.  XLA fuses this epilogue with the kernel's soc_t output.
    prev_soc = jnp.broadcast_to(health_state[0], (r,)).astype(f32)
    prev_t = jnp.concatenate(
        [jnp.broadcast_to(prev_soc, soc_t[:1].shape), soc_t[:-1]], axis=0
    )
    delta = soc_t - prev_t
    h_out = tuple(hf[i] for i in range(6)) + (
        health_state[6] + jnp.sum(jnp.maximum(delta, 0.0), axis=0),
        health_state[7] + jnp.sum(jnp.maximum(-delta, 0.0), axis=0),
        health_state[8] + jnp.sum(soc_t, axis=0),
        health_state[9] + jnp.sum(soc_t * soc_t, axis=0),
        jnp.broadcast_to(health_state[10], (r,)) + jnp.int32(t),
    )
    return grid_t, soc_t, finals, h_out
