"""Pallas-TPU version compat.

``pltpu.CompilerParams`` is the current name of the Mosaic compiler-options
dataclass; older jax releases (<= 0.4.x) ship it as
``pltpu.TPUCompilerParams`` with the same fields.  Kernels import
``CompilerParams`` from here so one source tree runs on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
