"""Public kernel API with backend dispatch.

On TPU backends the Pallas kernels run compiled; elsewhere (this CPU
container, or any host platform) the mathematically identical pure-jnp
references run instead.  ``force`` overrides: "pallas" (interpret=True off
TPU — used by tests), "ref", or None (auto).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import gemm_burn as _gb
from repro.kernels import lc_filter as _lc
from repro.kernels import pdu_sim as _pd
from repro.kernels import rmsnorm as _rn
from repro.kernels import rwkv6_scan as _rw


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force: str | None) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    if force == "ref":
        return False, False
    if force == "pallas":
        return True, not _on_tpu()
    return _on_tpu(), False


def rmsnorm(x, weight, eps: float = 1e-6, *, force: str | None = None):
    use, interp = _mode(force)
    if use:
        return _rn.rmsnorm(x, weight, eps, interpret=interp)
    return _ref.rmsnorm(x, weight, eps)


def gemm_burn(a, b, n_iters: int = 1, *, force: str | None = None, **kw):
    use, interp = _mode(force)
    if use:
        return _gb.gemm_burn(a, b, n_iters, interpret=interp, **kw)
    return _ref.gemm_burn(a, b, n_iters)


def lc_filter(ad, bd, c_row, x0, node_power, *, force: str | None = None, **kw):
    use, interp = _mode(force)
    if use:
        return _lc.lc_filter(ad, bd, c_row, x0, node_power, interpret=interp, **kw)
    return _ref.lc_filter(ad, bd, c_row, x0, node_power)


def pdu_sim(rack_power, g0, soc0, x0, ad, bd, c_row, corrective, *, force=None, **kw):
    use, interp = _mode(force)
    if use:
        return _pd.pdu_sim(
            rack_power, g0, soc0, x0, ad, bd, c_row, corrective,
            interpret=interp, **kw,
        )
    return _ref.pdu_sim(
        rack_power, g0, soc0, x0, ad, bd, c_row, corrective=corrective, **kw
    )


def attention(q, k, v, *, causal=True, scale=None, force=None, **kw):
    use, interp = _mode(force)
    if use:
        return _fa.flash_attention(
            q, k, v, causal=causal, scale=scale, interpret=interp, **kw
        )
    return _ref.attention(q, k, v, causal=causal, scale=scale)


def rwkv6_scan(r, k, v, w, u, state0=None, *, force=None, algorithm="auto", **kw):
    """RWKV-6 recurrence.  ``algorithm``: "auto" picks the chunk-parallel
    formulation on the jnp path for long sequences (28x fwd / 6.6x bwd on
    host, EXPERIMENTS §Perf-2) and the Pallas kernel on TPU; "sequential"
    forces the step-by-step scan (oracle)."""
    use, interp = _mode(force)
    if use:
        return _rw.rwkv6_scan(r, k, v, w, u, state0, interpret=interp, **kw)
    t = r.shape[2]
    if algorithm == "auto" and t > 32 and t % 32 == 0:
        return _ref.rwkv6_chunked(r, k, v, w, u, state0, chunk=32)
    return _ref.rwkv6_scan(r, k, v, w, u, state0)
