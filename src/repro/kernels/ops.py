"""Public kernel API with backend dispatch.

On TPU backends the Pallas kernels run compiled; elsewhere (this CPU
container, or any host platform) the mathematically identical pure-jnp
references run instead.  ``force`` overrides: "pallas" (interpret=True off
TPU — used by tests), "ref", or None (auto).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import admm_step as _ad
from repro.kernels import flash_attention as _fa
from repro.kernels import gemm_burn as _gb
from repro.kernels import lc_filter as _lc
from repro.kernels import pdu_health as _ph
from repro.kernels import pdu_sim as _pd
from repro.kernels import rmsnorm as _rn
from repro.kernels import rwkv6_scan as _rw


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force: str | None) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    if force == "ref":
        return False, False
    if force == "pallas":
        return True, not _on_tpu()
    return _on_tpu(), False


def rmsnorm(x, weight, eps: float = 1e-6, *, force: str | None = None):
    use, interp = _mode(force)
    if use:
        return _rn.rmsnorm(x, weight, eps, interpret=interp)
    return _ref.rmsnorm(x, weight, eps)


def gemm_burn(a, b, n_iters: int = 1, *, force: str | None = None, **kw):
    use, interp = _mode(force)
    if use:
        return _gb.gemm_burn(a, b, n_iters, interpret=interp, **kw)
    return _ref.gemm_burn(a, b, n_iters)


def lc_filter(ad, bd, c_row, x0, node_power, *, force: str | None = None, **kw):
    use, interp = _mode(force)
    if use:
        return _lc.lc_filter(ad, bd, c_row, x0, node_power, interpret=interp, **kw)
    return _ref.lc_filter(ad, bd, c_row, x0, node_power)


def pdu_sim(rack_power, g0, soc0, x0, ad, bd, c_row, corrective, *, force=None, **kw):
    use, interp = _mode(force)
    if use:
        return _pd.pdu_sim(
            rack_power, g0, soc0, x0, ad, bd, c_row, corrective,
            interpret=interp, **kw,
        )
    return _ref.pdu_sim(
        rack_power, g0, soc0, x0, ad, bd, c_row, corrective=corrective, **kw
    )


def pdu_health_sim(
    rack_power, g0, soc0, x0, ad, bd, c_row, *,
    health=None, guard=False, force=None, **kw
):
    """Interval-resident conditioning megakernel: ``pdu_sim`` + in-kernel
    command slew (``slew=(applied, target)``) + fused battery-health fold
    (``health=(step_consts, state_leaves)``) + in-kernel ESS availability
    rendering (``ess_events=(starts, ends, base, i0, t_last)`` with static
    ``ess_edge``, replacing the streamed ``(T, R)`` ``ess_on`` weight
    block with a compact fault-schedule boundary-event operand).  One
    launch per controller interval; see ``ref.pdu_health_sim`` for the
    exact semantics and the bitwise contract.

    ``guard=True`` (the safe-mode output guard) replaces any non-finite
    sample of the conditioned grid trace with the corresponding raw rack
    sample — the grid-facing waveform degrades to passthrough instead of
    exporting NaN toward protection equipment.  Applied in the dispatch
    wrapper so both backends share it (on TPU it fuses as an elementwise
    epilogue); identity on finite outputs, so the guarded clean path is
    bitwise-identical to ``guard=False``.  The carried machine state is
    deliberately NOT guarded: the supervisor's sanitizer quarantines the
    rack from the poisoned carry on the next interval, which is the
    observable event an operator needs counted.
    """
    use, interp = _mode(force)
    if use:
        hc, hs = health if health is not None else (None, None)
        out = _ph.pdu_health_sim(
            rack_power, g0, soc0, x0, ad, bd, c_row,
            health_consts=hc, health_state=hs, interpret=interp, **kw,
        )
    else:
        out = _ref.pdu_health_sim(
            rack_power, g0, soc0, x0, ad, bd, c_row, health=health, **kw
        )
    if guard:
        grid, soc_path, machine, h_leaves = out
        grid = jnp.where(jnp.isfinite(grid), grid, rack_power)
        out = (grid, soc_path, machine, h_leaves)
    return out


def admm_iterate(
    kkt_stack, g_blk, kq, lo, hi, x0, z0, y0, *, rho, iters, force=None, **kw
):
    """Fused batched-ADMM iteration loop for the prefactorized controller
    QP (see ``ref.admm_iterate``).  The Pallas kernel needs a rack batch
    in the trailing axis; unbatched solves take the reference path."""
    use, interp = _mode(force)
    if use and kq.ndim == 2:
        return _ad.admm_iterate(
            kkt_stack, g_blk, kq, lo, hi, x0, z0, y0,
            rho=rho, iters=iters, interpret=interp, **kw,
        )
    return _ref.admm_iterate(
        kkt_stack, g_blk, kq, lo, hi, x0, z0, y0, rho=rho, iters=iters
    )


def attention(q, k, v, *, causal=True, scale=None, force=None, algorithm="auto", **kw):
    """Softmax attention with GQA.  Differentiable on every path:
    the Pallas route pairs the online-softmax forward with the fused
    FlashAttention-2 backward kernels (``algorithm="auto"``) or the dense
    lse-based jnp backward (``"reference"``, the oracle); sequences the
    256-tiles do not divide — and the host path — fall back to
    ``ref.attention`` (plain XLA autodiff)."""
    use, interp = _mode(force)
    if use:
        bq = kw.get("block_q", 256)
        bk = kw.get("block_k", 256)
        tq, tk = q.shape[2], k.shape[2]
        if tq % min(bq, tq) == 0 and tk % min(bk, tk) == 0:
            return _fa.flash_attention(
                q, k, v, causal=causal, scale=scale, interpret=interp,
                algorithm=algorithm, **kw
            )
    return _ref.attention(q, k, v, causal=causal, scale=scale)


def rwkv6_scan(r, k, v, w, u, state0=None, *, force=None, algorithm="auto", **kw):
    """RWKV-6 recurrence.  ``algorithm``: "auto" picks the chunk-parallel
    formulation on the jnp path for long sequences (28x fwd / 6.6x bwd on
    host, EXPERIMENTS §Perf-2) and the Pallas kernel on TPU; "sequential"
    forces the step-by-step scan (oracle)."""
    use, interp = _mode(force)
    if use:
        return _rw.rwkv6_scan(r, k, v, w, u, state0, interpret=interp, **kw)
    t = r.shape[2]
    if algorithm == "auto" and t > 32 and t % 32 == 0:
        return _ref.rwkv6_chunked(r, k, v, w, u, state0, chunk=32)
    return _ref.rwkv6_scan(r, k, v, w, u, state0)
