"""Block-wise online-softmax attention (forward) Pallas TPU kernel.

The training stack's compute hot spot.  Standard FlashAttention-style
tiling adapted to TPU: query blocks of ``block_q`` ride the grid with the
KV sequence as the innermost (sequential) axis; the running max / sum /
accumulator live in VMEM scratch.  Causal masking skips fully-masked KV
blocks via ``pl.when`` (no work issued), and only the diagonal blocks pay
for per-element masks.

GQA is handled by the wrapper (queries grouped per KV head).  Backward is
provided by ``jax.custom_vjp`` recomputation against the reference
(numerically identical); a fused backward kernel is an optimization left
on the table and documented in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_k: int, seq_k: int, causal: bool, scale: float, q_offset: int,
):
    del seq_k
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    k_steps = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: query global index = q_offset + qi*block_q + row; key index =
    # ki*block_k + col.  Skip blocks with k_start > q_end entirely.
    q_start = q_offset + qi * block_q
    q_end = q_start + block_q - 1
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    if causal:
        pl.when(k_start <= q_end)(_compute)
    else:
        _compute()

    @pl.when(ki == k_steps - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _flash_fwd(
    q: jax.Array,  # (BH, Tq, D)
    k: jax.Array,  # (BH, Tk, D)
    v: jax.Array,  # (BH, Tk, D)
    *,
    causal: bool,
    scale: float,
    block_q: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    bh, tq, d = q.shape
    tk = k.shape[1]
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    assert tq % block_q == 0 and tk % block_k == 0, "pad sequences to block size"
    # decode-style offset: query i is at absolute position i + (tk - tq)
    q_offset = tk - tq if causal else 0
    grid = (bh, tq // block_q, tk // block_k)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_q=block_q, block_k=block_k, seq_k=tk, causal=causal,
            scale=scale, q_offset=q_offset,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, H, Tq, D)
    k: jax.Array,  # (B, Hkv, Tk, D)
    v: jax.Array,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Public wrapper: GQA head grouping + flatten to (BH, T, D)."""
    b, h, tq, d = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    groups = h // hkv
    kx = jnp.repeat(k, groups, axis=1).reshape(b * h, -1, d)
    vx = jnp.repeat(v, groups, axis=1).reshape(b * h, -1, d)
    out = _flash_fwd(
        q.reshape(b * h, tq, d), kx, vx,
        causal=causal, scale=scale, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return out.reshape(b, h, tq, d)
