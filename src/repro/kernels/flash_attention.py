"""Block-wise online-softmax attention (fwd + bwd) Pallas TPU kernels.

The training stack's compute hot spot.  Standard FlashAttention-style
tiling adapted to TPU: query blocks of ``block_q`` ride the grid with the
KV sequence as the innermost (sequential) axis; the running max / sum /
accumulator live in VMEM scratch.  Causal masking skips fully-masked KV
blocks via ``pl.when`` (no work issued), and only the diagonal blocks pay
for per-element masks.  The forward kernel also emits the per-row
log-sum-exp, which makes the backward a pure recompute: no (Tq, Tk)
probability matrix is ever materialized in HBM.

Backward is the FlashAttention-2 split — one kernel accumulates dK/dV
with the query sequence innermost (sequential), a second accumulates dQ
with the KV sequence innermost — both recomputing ``p = exp(s - lse)``
per tile from VMEM-resident operands.  ``jax.custom_vjp`` wires them in;
``algorithm="reference"`` swaps the backward for the mathematically
identical dense jnp formulation (the test oracle, and the fallback for
shapes the tiles do not divide).

GQA is handled by the wrapper (queries grouped per KV head) *outside*
the custom-vjp boundary, so the head-group reduction of dK/dV falls out
of the ``jnp.repeat`` VJP for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_k: int, seq_k: int, causal: bool, scale: float, q_offset: int,
):
    del seq_k
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    k_steps = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: query global index = q_offset + qi*block_q + row; key index =
    # ki*block_k + col.  Skip blocks with k_start > q_end entirely.
    q_start = q_offset + qi * block_q
    q_end = q_start + block_q - 1
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    if causal:
        pl.when(k_start <= q_end)(_compute)
    else:
        _compute()

    @pl.when(ki == k_steps - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, :] = (m_scr[...] + jnp.log(l))[:, 0]


def _flash_fwd(
    q: jax.Array,  # (BH, Tq, D)
    k: jax.Array,  # (BH, Tk, D)
    v: jax.Array,  # (BH, Tk, D)
    *,
    causal: bool,
    scale: float,
    block_q: int,
    block_k: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    bh, tq, d = q.shape
    tk = k.shape[1]
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    assert tq % block_q == 0 and tk % block_k == 0, "pad sequences to block size"
    # decode-style offset: query i is at absolute position i + (tk - tq)
    q_offset = tk - tq if causal else 0
    grid = (bh, tq // block_q, tk // block_k)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_q=block_q, block_k=block_k, seq_k=tk, causal=causal,
            scale=scale, q_offset=q_offset,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, tq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, tq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)


def _flash_bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_scr, dv_scr,
    *, block_q: int, block_k: int, causal: bool, scale: float, q_offset: int,
):
    kj = pl.program_id(1)
    qi = pl.program_id(2)
    q_steps = pl.num_programs(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = q_offset + qi * block_q
    q_end = q_start + block_q - 1
    k_start = kj * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0, :]  # (bq,)
        delta = delta_ref[0, :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # masked entries: exp(-inf) == 0
        dv_scr[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dk_scr[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32)

    if causal:
        pl.when(q_end >= k_start)(_compute)
    else:
        _compute()

    @pl.when(qi == q_steps - 1)
    def _finish():
        dk_ref[0] = (dk_scr[...] * scale).astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _flash_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_scr,
    *, block_q: int, block_k: int, causal: bool, scale: float, q_offset: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    k_steps = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_start = q_offset + qi * block_q
    q_end = q_start + block_q - 1
    k_start = kj * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0, :]
        delta = delta_ref[0, :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dq_scr[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32)

    if causal:
        pl.when(q_end >= k_start)(_compute)
    else:
        _compute()

    @pl.when(kj == k_steps - 1)
    def _finish():
        dq_ref[0] = (dq_scr[...] * scale).astype(dq_ref.dtype)


def _flash_bwd(
    q, k, v, o, lse, do,
    *, causal, scale, block_q, block_k, interpret,
):
    bh, tq, d = q.shape
    tk = k.shape[1]
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    q_offset = tk - tq if causal else 0
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    common = dict(causal=causal, scale=scale, q_offset=q_offset)
    row = lambda: pl.BlockSpec((1, block_q), lambda b, i, j: (b, i))
    dk, dv = pl.pallas_call(
        functools.partial(
            _flash_bwd_dkv_kernel, block_q=block_q, block_k=block_k, **common
        ),
        grid=(bh, tk // block_k, tq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, tk, d), k.dtype),
            jax.ShapeDtypeStruct((bh, tk, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dq = pl.pallas_call(
        functools.partial(
            _flash_bwd_dq_kernel, block_q=block_q, block_k=block_k, **common
        ),
        grid=(bh, tq // block_q, tk // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            row(), row(),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


def _bwd_reference(q, k, v, o, lse, do, *, causal, scale):
    """Dense lse-based backward: the exact math the tiled kernels evaluate
    (p recomputed from the saved log-sum-exp), as one jnp expression."""
    f32 = jnp.float32
    tq, tk = q.shape[1], k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(f32), k.astype(f32)) * scale
    if causal:
        rows = jnp.arange(tq)[:, None] + (tk - tq)
        s = jnp.where(rows >= jnp.arange(tk)[None, :], s, NEG_INF)
    p = jnp.exp(s - lse[..., None])
    dof = do.astype(f32)
    dv = jnp.einsum("bqk,bqd->bkd", p, dof)
    dp = jnp.einsum("bqd,bkd->bqk", dof, v.astype(f32))
    delta = jnp.sum(dof * o.astype(f32), axis=-1)
    ds = p * (dp - delta[..., None])
    dq = jnp.einsum("bqk,bkd->bqd", ds, k.astype(f32)) * scale
    dk = jnp.einsum("bqk,bqd->bkd", ds, q.astype(f32)) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_core(q, k, v, causal, scale, block_q, block_k, interpret, algorithm):
    out, _ = _flash_fwd(
        q, k, v, causal=causal, scale=scale, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return out


def _flash_core_fwd(q, k, v, causal, scale, block_q, block_k, interpret, algorithm):
    out, lse = _flash_fwd(
        q, k, v, causal=causal, scale=scale, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return out, (q, k, v, out, lse)


def _flash_core_bwd(causal, scale, block_q, block_k, interpret, algorithm, res, do):
    q, k, v, out, lse = res
    if algorithm == "reference":
        return _bwd_reference(q, k, v, out, lse, do, causal=causal, scale=scale)
    return _flash_bwd(
        q, k, v, out, lse, do,
        causal=causal, scale=scale, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret", "algorithm"),
)
def flash_attention(
    q: jax.Array,  # (B, H, Tq, D)
    k: jax.Array,  # (B, Hkv, Tk, D)
    v: jax.Array,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
    algorithm: str = "auto",
) -> jax.Array:
    """Public wrapper: GQA head grouping + flatten to (BH, T, D).

    Differentiable: ``algorithm="auto"`` backs the VJP with the fused
    Pallas dK/dV + dQ kernels; ``"reference"`` uses the dense lse-based
    jnp backward (same math, the test oracle).  The GQA ``jnp.repeat``
    sits outside the custom-vjp boundary, so dK/dV head-group reduction
    is handled by its VJP."""
    b, h, tq, d = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    groups = h // hkv
    kx = jnp.repeat(k, groups, axis=1).reshape(b * h, -1, d)
    vx = jnp.repeat(v, groups, axis=1).reshape(b * h, -1, d)
    out = _flash_core(
        q.reshape(b * h, tq, d), kx, vx,
        causal, scale, block_q, block_k, interpret, algorithm,
    )
    return out.reshape(b, h, tq, d)
