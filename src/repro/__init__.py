"""repro: EasyRider — power-transient-safe datacenter-scale training in JAX."""
__version__ = "0.1.0"
