"""Workload power modeling: device states, phase timelines, trace synthesis."""
from repro.power import device, phases, trace

__all__ = ["device", "phases", "trace"]
