"""Workload power modeling: device states, phase timelines, scenario engine,
fault engine, trace synthesis."""
from repro.power import device, faults, phases, scenario, trace

__all__ = ["device", "faults", "phases", "scenario", "trace"]
