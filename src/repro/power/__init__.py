"""Workload power modeling: device states, phase timelines, scenario engine,
trace synthesis."""
from repro.power import device, phases, scenario, trace

__all__ = ["device", "phases", "scenario", "trace"]
