"""Workload power-trace synthesis (paper §7.1 testbench, Fig. 3/9/13).

Cluster-scale traces of frontier training jobs are not public; like the
paper we synthesize a testbench trace matching the published structure of
Choukse et al. [12] Fig. 1: iteration-level compute/communicate square waves
(1-10 Hz), deeper periodic dips at ~22 s intervals (the prominent 1/22 Hz
line in paper Fig. 3b), a warm-up ramp, an abrupt job termination, and
optional mid-trace fault events (paper Fig. 13's 193.7 MW/s drop).

All traces are per-unit (fractions of rated rack power) at a configurable
sample rate.  Synthesis itself lives in the declarative scenario engine
(`repro.power.scenario`); this module keeps the legacy entry points as thin
wrappers over that IR — ``TestbenchSpec`` compiles to a parametric
``scenario.WorkloadParams`` and ``phase_timeline_trace`` to a segment-table
scenario.  The original host-side implementations are preserved as
``*_reference`` golden oracles for the scenario↔legacy equivalence tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.power import scenario as SC


@dataclasses.dataclass(frozen=True)
class TestbenchSpec:
    duration_s: float = 240.0
    sample_hz: float = 1000.0
    # Iteration structure.  The paper's testbench (its Fig. 3, from Choukse
    # et al. Fig. 1) has its largest dips at ~22-second intervals — the
    # compute/communicate cycle of a very large synchronous job — putting
    # the prominent spectral line at 1/22 Hz with magnitude ~0.1.
    iteration_period_s: float = 22.0  # compute+communicate cycle
    comm_fraction: float = 0.114  # fraction of the iteration spent in comms (~2.5 s)
    p_compute: float = 0.92  # per-unit power while computing
    p_comm: float = 0.25  # per-unit power during exposed communication
    # Deeper checkpoint stalls every few iterations.
    dip_period_s: float = 110.0
    dip_duration_s: float = 3.0
    p_dip: float = 0.15
    # Job envelope.
    warmup_s: float = 8.0
    p_idle: float = 0.10
    terminate_at_s: float | None = None  # abrupt drop to idle (job end)
    # Fault event (paper Fig. 13: near-instantaneous full drop).
    fault_at_s: float | None = None
    fault_duration_s: float = 20.0
    # Transition edge time: cluster power moves over "hundreds of
    # milliseconds" (Choukse et al. / paper §2.2), not instantaneously —
    # board-level regulation already smooths the <1 ms content.  Applied as
    # a boxcar so steps become linear ramps of this width.  Fault events
    # bypass it (their near-instant drop is the point of Fig. 13).
    edge_time_s: float = 0.25
    # Measurement noise.
    noise_std: float = 0.01


def scenario_from_testbench(
    spec: TestbenchSpec, *, noise_seed: int | None = None
) -> SC.Scenario:
    """Compile a ``TestbenchSpec`` into the scenario IR."""
    params = SC.workload(
        iteration_period_s=spec.iteration_period_s,
        comm_fraction=spec.comm_fraction,
        p_compute=spec.p_compute,
        p_comm=spec.p_comm,
        dip_period_s=spec.dip_period_s,
        dip_duration_s=spec.dip_duration_s,
        p_dip=spec.p_dip,
        warmup_s=spec.warmup_s,
        p_idle=spec.p_idle,
        t_end_s=SC.NEVER if spec.terminate_at_s is None else spec.terminate_at_s,
        fault_at_s=SC.NEVER if spec.fault_at_s is None else spec.fault_at_s,
        fault_duration_s=spec.fault_duration_s,
        noise_std=spec.noise_std,
    )
    return SC.make_scenario(
        params,
        duration_s=spec.duration_s,
        sample_hz=spec.sample_hz,
        edge_time_s=spec.edge_time_s,
        noise_seed=noise_seed,
    )


def testbench_trace(spec: TestbenchSpec, key: jax.Array | None = None) -> tuple[jax.Array, float]:
    """Synthesize the testbench trace.  Returns (trace (T,), dt).

    Thin wrapper over ``scenario.render`` (golden-tested against
    ``testbench_trace_reference``).  Noise from an explicit ``key`` keeps
    the legacy whole-trace draw for bit-compatibility; chunk-invariant
    counter-based noise is available via ``scenario_from_testbench(...,
    noise_seed=...)``.
    """
    s = scenario_from_testbench(spec)
    p, dt = SC.render_trace(s)
    if key is not None and spec.noise_std > 0:
        p = p + spec.noise_std * jax.random.normal(key, p.shape)
        p = jnp.clip(p, 0.0, 1.0)
    return p.astype(jnp.float32), dt


def testbench_trace_reference(
    spec: TestbenchSpec, key: jax.Array | None = None
) -> tuple[jax.Array, float]:
    """The original host-side implementation, kept verbatim as the golden
    oracle for the scenario-engine equivalence tests."""
    dt = 1.0 / spec.sample_hz
    t = jnp.arange(int(round(spec.duration_s * spec.sample_hz))) * dt

    # Iteration square wave: comm window at the end of each iteration.
    phase = jnp.mod(t, spec.iteration_period_s) / spec.iteration_period_s
    in_comm = phase >= (1.0 - spec.comm_fraction)
    p = jnp.where(in_comm, spec.p_comm, spec.p_compute)

    # Deep dips every dip_period_s.
    dip_phase = jnp.mod(t, spec.dip_period_s)
    in_dip = dip_phase < spec.dip_duration_s
    p = jnp.where(in_dip, spec.p_dip, p)

    # Warm-up ramp from idle.
    ramp = jnp.clip(t / jnp.maximum(spec.warmup_s, dt), 0.0, 1.0)
    p = spec.p_idle + ramp * (p - spec.p_idle)

    # Abrupt termination.
    if spec.terminate_at_s is not None:
        p = jnp.where(t >= spec.terminate_at_s, spec.p_idle, p)

    # Finite edge times (see TestbenchSpec.edge_time_s).
    if spec.edge_time_s > 0:
        width = max(int(round(spec.edge_time_s * spec.sample_hz)), 1)
        kernel = jnp.ones((width,), p.dtype) / width
        p = jnp.convolve(p, kernel, mode="same")

    # Fault event: near-instantaneous drop to (almost) zero, then recovery.
    # Applied after edge smoothing — faults are genuinely abrupt.
    if spec.fault_at_s is not None:
        in_fault = (t >= spec.fault_at_s) & (t < spec.fault_at_s + spec.fault_duration_s)
        p = jnp.where(in_fault, 0.02, p)

    if key is not None and spec.noise_std > 0:
        p = p + spec.noise_std * jax.random.normal(key, p.shape)
        p = jnp.clip(p, 0.0, 1.0)
    return p.astype(jnp.float32), dt


def choukse_spec() -> TestbenchSpec:
    return TestbenchSpec(duration_s=240.0, terminate_at_s=210.0)


def choukse_testbench(key: jax.Array | None = None) -> tuple[jax.Array, float]:
    """The default trace used throughout the evaluation (paper Fig. 3/9)."""
    return testbench_trace(choukse_spec(), key)


def titanx_spec() -> TestbenchSpec:
    """A 2-GPU Titan-X-style GPT-125M profile (paper §7.1): slower steps,
    checkpoint stalls, normalized to blade TDP."""
    return TestbenchSpec(
        duration_s=300.0,
        sample_hz=200.0,
        iteration_period_s=1.2,
        comm_fraction=0.15,
        p_compute=0.88,
        p_comm=0.55,
        dip_period_s=30.0,
        dip_duration_s=4.0,
        p_dip=0.22,
        warmup_s=5.0,
        p_idle=0.06,  # 15 W / 250 W
        terminate_at_s=280.0,
    )


def titanx_testbench(key: jax.Array | None = None) -> tuple[jax.Array, float]:
    return testbench_trace(titanx_spec(), key)


def cluster_fault_spec() -> TestbenchSpec:
    """Paper Fig. 13: 40 MW cluster (scaled from H100 measurements) with a
    computation fault around t = 400 s causing a near-instant full drop."""
    return TestbenchSpec(
        duration_s=600.0,
        sample_hz=500.0,
        iteration_period_s=4.0,
        comm_fraction=0.2,
        p_compute=0.95,
        p_comm=0.42,
        dip_period_s=60.0,
        dip_duration_s=2.0,
        p_dip=0.3,
        warmup_s=20.0,
        fault_at_s=400.0,
        fault_duration_s=25.0,
        terminate_at_s=560.0,
    )


def cluster_fault_trace(key: jax.Array | None = None) -> tuple[jax.Array, float]:
    return testbench_trace(cluster_fault_spec(), key)


def phase_timeline_trace(
    durations_s: np.ndarray | jax.Array,  # (P,) phase durations
    powers: np.ndarray | jax.Array,  # (P,) per-unit power per phase
    sample_hz: float,
    *,
    edge_time_s: float = 0.1,
) -> tuple[jax.Array, float]:
    """Render an explicit phase timeline to a sampled trace.

    Phase transitions get ``edge_time_s`` linear edges (real rack power
    moves over ~100 ms; the sub-ms content is absorbed by board-level
    regulation, paper §2.2).  Thin wrapper over the scenario engine's
    segment table (golden-tested against ``phase_timeline_trace_reference``).
    """
    s = SC.from_phase_timeline(durations_s, powers, sample_hz, edge_time_s=edge_time_s)
    return SC.render_trace(s)


def phase_timeline_trace_reference(
    durations_s, powers, sample_hz: float, *, edge_time_s: float = 0.1
) -> tuple[jax.Array, float]:
    """Original numpy implementation (golden oracle for equivalence tests)."""
    durations = np.asarray(durations_s, np.float64)
    powers_np = np.asarray(powers, np.float32)
    counts = np.maximum(np.round(durations * sample_hz).astype(np.int64), 1)
    trace = np.repeat(powers_np, counts)
    if edge_time_s > 0:
        width = max(int(round(edge_time_s * sample_hz)), 1)
        kernel = np.ones((width,), np.float32) / width
        trace = np.convolve(trace, kernel, mode="same")
    return jnp.asarray(trace), 1.0 / sample_hz
