"""PowerSim: EasyRider in the training loop.

Each training step contributes a phase timeline (compute -> exposed
collective; checkpoint stalls when they happen) derived from the step's
cost model.  PowerSim renders those phases to a rack power trace at
``sample_hz``, streams it through the EasyRider PDU (state carried across
steps), monitors compliance online, and exposes battery SoC + wear
telemetry — which the fault-tolerance layer uses for emergency
checkpoints.

Monitoring is fully streaming: cross-chunk ramp observers (the boundary
sample between consecutive conditioned chunks is carried, so a step
landing exactly on a chunk boundary is never missed) and an online
Goertzel line bank replace the old host-side trace accumulation — an
arbitrarily long training run holds O(1) monitoring state instead of the
whole rack/grid waveform.  Battery health (cycle counting + aging) rides
inside the conditioning scan via ``core.health``.

This is the "no software changes required" property in practice: the
trainer does nothing but *report* when steps happen; conditioning runs
entirely in the PDU model.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import compliance, fleet, health as hlt, pdu
from repro.power import phases as P
from repro.power import scenario as SC
from repro.power.device import DevicePower


@dataclasses.dataclass
class PowerSimConfig:
    sample_hz: float = 200.0
    grid: compliance.GridSpec | None = None
    # Accelerator power model driving phase rendering (idle/comm power
    # fractions); None keeps the PhaseModel's own device (default TPU_V5E).
    device: DevicePower | None = None
    # Battery wear telemetry folded into the conditioning scan.
    track_health: bool = True


class PowerSim:
    def __init__(
        self,
        cost: P.StepCost,
        hw: P.HardwareConstants,
        model: P.PhaseModel,
        cfg: PowerSimConfig | None = None,
    ):
        self.cfg = cfg or PowerSimConfig()
        self.grid_spec = self.cfg.grid or compliance.GridSpec.create()
        if self.cfg.device is not None:
            model = dataclasses.replace(model, device=self.cfg.device)
        self.cost = cost
        self.hw = hw
        self.model = model
        self.pdu_cfg = pdu.make_pdu(
            sample_dt=1.0 / self.cfg.sample_hz,
            track_health=self.cfg.track_health,
        )
        self.state = None
        self.soc = 0.5
        # Streaming monitors: O(1) state however long the run.  The run's
        # total length is unknown up front, so the spectral bank runs
        # open-ended (rectangular window, fixed operator line grid).
        self._ramp_rack = compliance.ramp_observer_init()
        self._ramp_grid = compliance.ramp_observer_init()
        self._bank = compliance.make_online_bank(
            1.0 / self.cfg.sample_hz, float(np.asarray(self.grid_spec.f_c))
        )
        self._spec_rack = compliance.spectrum_observer_init(self._bank)
        self._spec_grid = compliance.spectrum_observer_init(self._bank)
        # Streaming contract: pdu.condition advances whole controller
        # intervals (k samples); sub-interval chunks would desync the
        # carried state, so we buffer until a full interval is available.
        self._k = max(
            int(round(float(self.pdu_cfg.controller.dt) * self.cfg.sample_hz)), 1
        )
        self._pending = jnp.zeros((0,), jnp.float32)
        # The fleet engines' cached single-chunk step: jitted once per
        # config (not per PowerSim instance, not per call) with the carried
        # PDUState donated — the seed path re-traced an un-jitted
        # pdu.condition on every training step.
        self._step = fleet.make_condition_step(self.pdu_cfg, qp_iters=25)

    @property
    def max_ramp_seen(self) -> float:
        return float(np.asarray(self._ramp_grid.max_ramp))

    def _condition(self, chunk: jnp.ndarray, dt: float) -> None:
        # Device-resident buffering: rendered step chunks stay on device
        # through concatenation, conditioning, slicing, and the streaming
        # observers; the only host transfer is the scalar SoC readout.
        self._pending = jnp.concatenate([self._pending, chunk])
        n = (self._pending.shape[0] // self._k) * self._k
        if n == 0:
            return
        trace, self._pending = self._pending[:n], self._pending[n:]
        if self.state is None:
            self.state = pdu.init_state(self.pdu_cfg, trace[0])
        grid, self.state, telem = self._step(self.state, trace)
        self.soc = float(np.asarray(telem.soc)[-1])
        self._ramp_rack = compliance.ramp_observer_update(self._ramp_rack, trace, dt)
        self._ramp_grid = compliance.ramp_observer_update(self._ramp_grid, grid, dt)
        self._spec_rack = compliance.spectrum_observer_update(
            self._bank, self._spec_rack, trace
        )
        self._spec_grid = compliance.spectrum_observer_update(
            self._bank, self._spec_grid, grid
        )

    def on_step(self, *, checkpoint_stall: bool = False) -> None:
        durs, pows = P.step_phases(self.cost, self.hw, self.model)
        if checkpoint_stall:
            durs = np.append(durs, self.model.checkpoint_stall_s)
            d = self.model.device
            pows = np.append(pows, d.p_idle_w / d.p_peak_w)
        # Compile the step's phases into the scenario IR and render the
        # chunk on-device (steps share a shape, so `render` stays cached).
        s = SC.from_phase_timeline(durs, pows, self.cfg.sample_hz)
        chunk, dt = SC.render_trace(s)
        self._condition(chunk, dt)

    def report(self) -> dict:
        rep_rack = compliance.report_from_observers(
            self.grid_spec, self._ramp_rack, self._bank, self._spec_rack
        )
        rep_grid = compliance.report_from_observers(
            self.grid_spec, self._ramp_grid, self._bank, self._spec_grid
        )
        out = {
            "rack_max_ramp": float(rep_rack.max_ramp),
            "grid_max_ramp": float(rep_grid.max_ramp),
            "grid_ramp_ok": bool(rep_grid.ramp_ok),
            "grid_worst_hf": float(rep_grid.worst_high_freq_mag),
            "final_soc": self.soc,
        }
        if self.cfg.track_health and self.state is not None:
            rep = hlt.report(
                self.pdu_cfg.health, self.pdu_cfg.ess_params,
                self.state.health, 1.0 / self.cfg.sample_hz,
            )
            out.update(
                battery_efc=float(np.asarray(rep.efc)),
                battery_half_cycles=float(np.asarray(rep.half_cycles)),
                battery_max_dod=float(np.asarray(rep.max_dod)),
                battery_capacity_fade=float(np.asarray(rep.capacity_fade)),
                battery_projected_life_years=float(
                    np.asarray(rep.projected_life_s) / (365.25 * 86400.0)
                ),
            )
        return out
