"""PowerSim: EasyRider in the training loop.

Each training step contributes a phase timeline (compute -> exposed
collective; checkpoint stalls when they happen) derived from the step's
cost model.  PowerSim renders those phases to a rack power trace at
``sample_hz``, streams it through the EasyRider PDU (state carried across
steps), monitors compliance online, and exposes battery SoC telemetry —
which the fault-tolerance layer uses for emergency checkpoints.

This is the "no software changes required" property in practice: the
trainer does nothing but *report* when steps happen; conditioning runs
entirely in the PDU model.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import compliance, fleet, pdu
from repro.power import phases as P
from repro.power import scenario as SC
from repro.power.device import DevicePower


@dataclasses.dataclass
class PowerSimConfig:
    sample_hz: float = 200.0
    grid: compliance.GridSpec | None = None
    # Accelerator power model driving phase rendering (idle/comm power
    # fractions); None keeps the PhaseModel's own device (default TPU_V5E).
    device: DevicePower | None = None


class PowerSim:
    def __init__(
        self,
        cost: P.StepCost,
        hw: P.HardwareConstants,
        model: P.PhaseModel,
        cfg: PowerSimConfig | None = None,
    ):
        self.cfg = cfg or PowerSimConfig()
        self.grid_spec = self.cfg.grid or compliance.GridSpec.create()
        if self.cfg.device is not None:
            model = dataclasses.replace(model, device=self.cfg.device)
        self.cost = cost
        self.hw = hw
        self.model = model
        self.pdu_cfg = pdu.make_pdu(sample_dt=1.0 / self.cfg.sample_hz)
        self.state = None
        self.max_ramp_seen = 0.0
        self.worst_hf_seen = 0.0
        self.soc = 0.5
        self.grid_trace_chunks: list[np.ndarray] = []
        self.rack_trace_chunks: list[np.ndarray] = []
        # Streaming contract: pdu.condition advances whole controller
        # intervals (k samples); sub-interval chunks would desync the
        # carried state, so we buffer until a full interval is available.
        self._k = max(
            int(round(float(self.pdu_cfg.controller.dt) * self.cfg.sample_hz)), 1
        )
        self._pending = jnp.zeros((0,), jnp.float32)
        # The fleet engines' cached single-chunk step: jitted once per
        # config (not per PowerSim instance, not per call) with the carried
        # PDUState donated — the seed path re-traced an un-jitted
        # pdu.condition on every training step.
        self._step = fleet.make_condition_step(self.pdu_cfg, qp_iters=25)

    def _condition(self, chunk: jnp.ndarray, dt: float) -> None:
        # Device-resident buffering: rendered step chunks stay on device
        # through concatenation, conditioning, and slicing; the only
        # host transfers are the np.asarray bookkeeping copies for report().
        self._pending = jnp.concatenate([self._pending, chunk])
        n = (self._pending.shape[0] // self._k) * self._k
        if n == 0:
            return
        trace, self._pending = self._pending[:n], self._pending[n:]
        if self.state is None:
            self.state = pdu.init_state(self.pdu_cfg, trace[0])
        grid, self.state, telem = self._step(self.state, trace)
        self.soc = float(np.asarray(telem.soc)[-1])
        self.max_ramp_seen = max(
            self.max_ramp_seen, float(compliance.max_abs_ramp(grid, dt))
        )
        self.rack_trace_chunks.append(np.asarray(trace))
        self.grid_trace_chunks.append(np.asarray(grid))

    def on_step(self, *, checkpoint_stall: bool = False) -> None:
        durs, pows = P.step_phases(self.cost, self.hw, self.model)
        if checkpoint_stall:
            durs = np.append(durs, self.model.checkpoint_stall_s)
            d = self.model.device
            pows = np.append(pows, d.p_idle_w / d.p_peak_w)
        # Compile the step's phases into the scenario IR and render the
        # chunk on-device (steps share a shape, so `render` stays cached).
        s = SC.from_phase_timeline(durs, pows, self.cfg.sample_hz)
        chunk, dt = SC.render_trace(s)
        self._condition(chunk, dt)

    def report(self) -> dict:
        rack = np.concatenate(self.rack_trace_chunks) if self.rack_trace_chunks else np.zeros(1)
        grid = np.concatenate(self.grid_trace_chunks) if self.grid_trace_chunks else np.zeros(1)
        dt = 1.0 / self.cfg.sample_hz
        rep_rack = compliance.check(jnp.asarray(rack), dt, self.grid_spec)
        rep_grid = compliance.check(jnp.asarray(grid), dt, self.grid_spec)
        return {
            "rack_max_ramp": float(rep_rack.max_ramp),
            "grid_max_ramp": float(rep_grid.max_ramp),
            "grid_ramp_ok": bool(rep_grid.ramp_ok),
            "grid_worst_hf": float(rep_grid.worst_high_freq_mag),
            "final_soc": self.soc,
        }
