"""Declarative scenario engine: workload events -> batched, chunk-renderable traces.

The legacy trace layer (`repro.power.trace`) synthesizes one homogeneous
square-wave per call with host-side Python branches, and fleet heterogeneity
is bolted on by rolling copies of that one trace.  This module replaces the
construction with *data*: a scenario is a small struct-of-arrays IR —

  * ``WorkloadParams``: the parametric per-rack workload (warmup ramp,
    iteration compute/communicate wave, periodic checkpoint dips, job
    start/stop envelope, fault window, diurnal inference envelope, noise)
    with every knob a float32 leaf, so a heterogeneous fleet is just a
    ``WorkloadParams`` whose leaves carry a trailing rack axis ``(R,)``;
  * an optional explicit segment table (``seg_bounds``/``seg_powers``) for
    compiled phase timelines (`repro.power.phases`), piecewise-constant
    power looked up by sample index.

``render(scenario, t0, n)`` is a pure jit-ed function of the *absolute*
sample index: every output sample depends only on its own index (the edge
smoothing is an explicit zero-padded window mean with a fixed reduction
order), so chunked rendering is **bit-identical** to whole-trace rendering
and the signature plugs directly into
``fleet.condition_fleet_streaming``'s chunk provider — campus-scale traces
are synthesized on-device per chunk and never materialized as (T, R).

Workload parameters for the assigned model architectures are derived from
their step cost (``workload_from_model`` / ``scenario_from_model``), and
``mixed_campus`` builds the paper's heterogeneous-campus evaluation: many
models, staggered job starts/stops, an inference-diurnal block, and a
mid-trace fault cascade.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import pytree_dataclass, static_field

# "never happens" sentinel for event times; float32-representable and far
# beyond any trace, so `t >= NEVER` comparisons are exactly False in-band.
NEVER = 1e30


@pytree_dataclass
class WorkloadParams:
    """Parametric per-rack workload (struct-of-arrays).

    Every field is a float32 leaf of shape ``()`` (one rack) or ``(R,)``
    (per-rack batch); heterogeneous fleets fall out of broadcasting the
    time axis against the trailing rack axis.  Defaults mirror
    ``trace.TestbenchSpec`` (Choukse et al. Fig. 1 structure).
    """

    # Iteration wave: compute plateau with a comm window at the cycle end.
    iteration_period_s: jax.Array
    comm_fraction: jax.Array
    p_compute: jax.Array
    p_comm: jax.Array
    # Periodic deep dips (checkpoint stalls).
    dip_period_s: jax.Array
    dip_duration_s: jax.Array
    p_dip: jax.Array
    # Job envelope: idle -> warmup ramp at t_start, drop to idle at t_end.
    warmup_s: jax.Array
    p_idle: jax.Array
    t_start_s: jax.Array
    t_end_s: jax.Array
    # Fault window: near-instant drop, bypasses edge smoothing (Fig. 13).
    fault_at_s: jax.Array
    fault_duration_s: jax.Array
    p_fault: jax.Array
    # Diurnal inference envelope: amp=0 disables (exact no-op); amp in
    # (0, 1] swings the load between full and (1-amp) of its workload
    # excursion over p_idle, with period diurnal_period_s.
    diurnal_period_s: jax.Array
    diurnal_amp: jax.Array
    diurnal_phase_s: jax.Array
    # Per-rack output scale and measurement-noise level.
    scale: jax.Array
    noise_std: jax.Array


def _concrete(x) -> np.ndarray | None:
    """Host view of a value for construction-time validation; None if the
    value is a tracer (validation is skipped inside jit — the builders are
    host-side constructors in every supported path)."""
    try:
        return np.asarray(x)
    except Exception:
        return None


def _validate_workload(w: WorkloadParams) -> WorkloadParams:
    fd = _concrete(w.fault_duration_s)
    if fd is not None and np.any(fd < 0.0):
        raise ValueError(
            f"fault_duration_s must be >= 0, got {fd} — a negative window "
            "would silently render as no fault at all"
        )
    fa = _concrete(w.fault_at_s)
    if fa is not None and np.any(fa < 0.0):
        raise ValueError(
            f"fault_at_s must be >= 0 (or NEVER to disable), got {fa}"
        )
    return w


def workload(
    *,
    iteration_period_s=22.0,
    comm_fraction=0.114,
    p_compute=0.92,
    p_comm=0.25,
    dip_period_s=110.0,
    dip_duration_s=3.0,
    p_dip=0.15,
    warmup_s=8.0,
    p_idle=0.10,
    t_start_s=0.0,
    t_end_s=NEVER,
    fault_at_s=NEVER,
    fault_duration_s=20.0,
    p_fault=0.02,
    diurnal_period_s=NEVER,
    diurnal_amp=0.0,
    diurnal_phase_s=0.0,
    scale=1.0,
    noise_std=0.01,
) -> WorkloadParams:
    """Build ``WorkloadParams`` from keyword knobs (scalars or (R,) arrays)."""
    as32 = lambda x: jnp.asarray(x, jnp.float32)
    return _validate_workload(WorkloadParams(
        iteration_period_s=as32(iteration_period_s),
        comm_fraction=as32(comm_fraction),
        p_compute=as32(p_compute),
        p_comm=as32(p_comm),
        dip_period_s=as32(dip_period_s),
        dip_duration_s=as32(dip_duration_s),
        p_dip=as32(p_dip),
        warmup_s=as32(warmup_s),
        p_idle=as32(p_idle),
        t_start_s=as32(t_start_s),
        t_end_s=as32(t_end_s),
        fault_at_s=as32(fault_at_s),
        fault_duration_s=as32(fault_duration_s),
        p_fault=as32(p_fault),
        diurnal_period_s=as32(diurnal_period_s),
        diurnal_amp=as32(diurnal_amp),
        diurnal_phase_s=as32(diurnal_phase_s),
        scale=as32(scale),
        noise_std=as32(noise_std),
    ))


def stack_workloads(params_list: list[WorkloadParams]) -> WorkloadParams:
    """Stack per-rack scalar params into one (R,)-batched ``WorkloadParams``."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.broadcast_to(x, ()) for x in xs]), *params_list
    )


@pytree_dataclass
class Scenario:
    """A renderable scenario: parametric workloads and/or a segment table.

    If ``seg_powers`` is present the base waveform is the piecewise-constant
    segment lookup (``seg_bounds`` holds int32 start-sample indices,
    ``seg_bounds[0] == 0``; ``seg_powers`` is ``(K,)`` shared or ``(R, K)``
    per-rack); otherwise it is the parametric ``params`` workload.  Static
    fields (sample rate, length, smoothing width, noise seed) are jit aux
    data, so one compiled ``render`` serves every chunk.
    """

    params: WorkloadParams | None
    seg_bounds: jax.Array | None
    seg_powers: jax.Array | None
    # Noise level for segment-table scenarios (parametric scenarios carry
    # theirs in ``params.noise_std``); None = 0.
    seg_noise_std: jax.Array | None = None
    # Compiled stochastic fault schedule (``power.faults.FaultSchedule``);
    # the rack-power-loss and sensor-dropout channels apply at render time,
    # the ESS-trip channel is consumed by the fleet engines' per-interval
    # availability mask.  None = fault-free.
    faults: object | None = None
    # Optional uint32 scalar XORed into the noise lane hash — a *traced*
    # leaf, so campuses stacked for the sharded grid-region engine (which
    # must share every static field, including ``noise_seed``) can still
    # draw decorrelated measurement noise.  None keeps the legacy stream
    # bit-for-bit (see ``with_noise_salt``).
    noise_salt: jax.Array | None = None
    sample_hz: float = static_field(default=1000.0)
    total_samples: int = static_field(default=0)
    # Edge smoothing window in samples (0/1 = off): steps become linear
    # ramps of ~edge_width*dt, identical to the legacy boxcar convolution.
    edge_width: int = static_field(default=0)
    # Boundary handling for the smoothing window: "zero" (legacy boxcar —
    # samples beyond the trace read as 0, so power decays to ~p/2 across
    # the first/last half-window) or "clamp" (edge replication — no
    # fabricated transient at the trace boundaries).  "zero" keeps every
    # existing trace bitwise; "clamp" is what compliance-bearing campus
    # benches want, since the zero-pad decay is synchronized fleet-wide
    # and reads as a phantom campus-scale power step.
    edge_pad: str = static_field(default="zero")
    # Counter-based noise: sample i draws from fold_in(key(seed), i), so
    # noise is chunk-invariant.  None disables noise entirely.
    noise_seed: int | None = static_field(default=None)

    @property
    def duration_s(self) -> float:
        return self.total_samples / self.sample_hz

    @property
    def dt(self) -> float:
        return 1.0 / self.sample_hz

    @property
    def n_racks(self) -> int | None:
        """Rack batch size, or None for an unbatched (T,) scenario."""
        if self.seg_powers is not None and self.seg_powers.ndim == 2:
            return self.seg_powers.shape[0]
        if self.params is not None:
            for leaf in jax.tree_util.tree_leaves(self.params):
                if jnp.ndim(leaf) == 1:
                    return leaf.shape[0]
        return None


def make_scenario(
    params: WorkloadParams,
    *,
    duration_s: float,
    sample_hz: float,
    edge_time_s: float = 0.25,
    edge_pad: str = "zero",
    noise_seed: int | None = None,
    faults=None,
) -> Scenario:
    """Wrap parametric workloads into a renderable ``Scenario``.

    A scripted ``fault_at_s`` must land inside the trace: a window starting
    at or past ``duration_s`` would silently render as a no-op, so it is
    rejected here (use ``NEVER`` to disable the fault).
    """
    total = int(round(duration_s * sample_hz))
    if edge_pad not in ("zero", "clamp"):
        raise ValueError(
            f"edge_pad must be 'zero' or 'clamp', got {edge_pad!r}"
        )
    fa = _concrete(params.fault_at_s)
    if fa is not None:
        scripted = fa < 0.5 * NEVER
        if np.any(scripted & (fa * sample_hz >= total)):
            bad = np.asarray(fa)[np.asarray(scripted & (fa * sample_hz >= total))]
            raise ValueError(
                f"fault_at_s {np.unique(bad)} is past the scenario end "
                f"({duration_s} s = {total} samples); use NEVER to disable"
            )
    return Scenario(
        params=params,
        seg_bounds=None,
        seg_powers=None,
        faults=faults,
        sample_hz=float(sample_hz),
        total_samples=total,
        edge_width=_edge_width(edge_time_s, sample_hz),
        edge_pad=edge_pad,
        noise_seed=noise_seed,
    )


def attach_faults(
    s: Scenario,
    process_or_schedule,
    *,
    seed: int = 0,
    max_episodes: int | None = None,
) -> Scenario:
    """Return ``s`` with a stochastic fault schedule attached.

    Accepts a ``faults.FaultProcess`` (sampled here against the scenario's
    geometry with counter-based draws) or a pre-built
    ``faults.FaultSchedule`` (rack count must match).  A pre-built
    schedule's episode tables are validated host-side
    (``faults.validate_tables``): the interval-compiled fault path selects
    episode boundaries by rank, which assumes sorted, coalesced,
    sentinel-padded rows — hand-built tables that violate this would
    silently render the wrong availability.
    """
    from repro.power import faults as FLT

    n = s.n_racks or 1
    if isinstance(process_or_schedule, FLT.FaultSchedule):
        sched = process_or_schedule
        FLT.validate_tables(sched)
    else:
        sched = FLT.sample_schedule(
            process_or_schedule, n, s.total_samples, s.sample_hz,
            seed=seed, max_episodes=max_episodes,
        )
    if sched.n_racks != n:
        raise ValueError(
            f"fault schedule covers {sched.n_racks} racks but the scenario "
            f"has {n}"
        )
    return s.replace(faults=sched)


def with_noise_salt(s: Scenario, salt: int | jax.Array) -> Scenario:
    """Return ``s`` drawing a decorrelated measurement-noise stream.

    The salt is a *traced* uint32 leaf XORed into the counter hash's lane
    seed, so scenarios that must share every static field (campuses stacked
    for the sharded grid-region engine share one ``noise_seed`` aux datum)
    still get independent noise.  A scenario without noise is returned
    unchanged — salting silence would only force a treedef change.
    """
    if s.noise_seed is None:
        return s
    return s.replace(noise_salt=jnp.asarray(salt, jnp.uint32))


def _edge_width(edge_time_s: float, sample_hz: float) -> int:
    return max(int(round(edge_time_s * sample_hz)), 1) if edge_time_s > 0 else 0


# ------------------------------------------------------------------ rendering


def _floor_mod(x: jax.Array, y: jax.Array) -> jax.Array:
    """Bitwise-exact ``jnp.mod(x, y)`` for ``y > 0`` without libm ``fmod``.

    ``jnp.mod`` lowers to an elementwise ``remainder`` that XLA:CPU serves
    with a scalar libm call — by far the hottest op in ``_parametric_base``
    (the two phase mods were ~74% of the pre-smoothing render).  This
    computes the same value with vectorizable arithmetic:

      k  = trunc(x / y)            # candidate C-style quotient
      r  = x - k*y                 # exact via a Dekker-split product
      k += (r >= y) - (r < 0)      # division rounding puts k off by <= 1
      r  = x - k*y                 # exact C remainder (representable)
      m  = r + y if r < 0 else r   # numpy floor-mod fixup (one rounding)

    The subtraction ``x - k*y`` is exact because the true C remainder is
    representable (the classical fmod invariant) and the split product
    recovers the low bits of ``k*y``; the final fixup performs the same
    single rounding numpy's ``fmod -> m += y`` path does.  Verified
    bitwise against ``jnp.mod`` over 2M values per period covering the
    workload range (negative job-local times, exact multiples, boundary
    neighbours, ``NEVER`` sentinels).
    """
    c = jnp.float32(4097.0)  # 2^12 + 1 Dekker splitter

    def sub_prod(x, k, y):
        ck = c * k
        k_hi = ck - (ck - k)
        k_lo = k - k_hi
        cy = c * y
        y_hi = cy - (cy - y)
        y_lo = y - y_hi
        p_hi = k * y
        p_lo = ((k_hi * y_hi - p_hi) + k_hi * y_lo + k_lo * y_hi) + k_lo * y_lo
        return (x - p_hi) - p_lo

    k = jnp.trunc(x / y)
    r1 = sub_prod(x, k, y)
    k = k + (r1 >= y).astype(x.dtype) - (r1 < 0).astype(x.dtype)
    rc = sub_prod(x, k, y)
    return jnp.where(rc < 0, rc + y, rc)


def _parametric_base(w: WorkloadParams, t: jax.Array, dt: float) -> jax.Array:
    """Per-sample base power at times ``t`` (seconds); pure and elementwise.

    Ordering matches the legacy ``testbench_trace`` exactly (wave -> dips ->
    warmup ramp -> envelope) so that with default start/diurnal/scale the
    pre-smoothing samples are bitwise-identical to the legacy path.
    """
    batched = any(jnp.ndim(x) == 1 for x in jax.tree_util.tree_leaves(w))
    if batched:
        t = t[:, None]
    te = t - w.t_start_s  # job-local time (staggered starts)

    phase = _floor_mod(te, w.iteration_period_s) / w.iteration_period_s
    p = jnp.where(phase >= 1.0 - w.comm_fraction, w.p_comm, w.p_compute)
    # NEVER disables dips entirely (mod(te, NEVER) == te would otherwise
    # fire a spurious dip for the first dip_duration_s of every job).
    in_dip = (_floor_mod(te, w.dip_period_s) < w.dip_duration_s) & (
        w.dip_period_s < 0.5 * NEVER
    )
    p = jnp.where(in_dip, w.p_dip, p)
    ramp = jnp.clip(te / jnp.maximum(w.warmup_s, dt), 0.0, 1.0)
    p = w.p_idle + ramp * (p - w.p_idle)
    # Diurnal inference envelope (amp=0 keeps p bitwise-unchanged).
    period = jnp.maximum(w.diurnal_period_s, dt)
    env = 1.0 - w.diurnal_amp * 0.5 * (
        1.0 - jnp.cos(2.0 * jnp.pi * (t - w.diurnal_phase_s) / period)
    )
    p = jnp.where(w.diurnal_amp > 0.0, w.p_idle + env * (p - w.p_idle), p)
    # Outside the job window the rack idles (termination is abrupt).
    return jnp.where((te < 0.0) | (t >= w.t_end_s), w.p_idle, p)


def _segment_base(s: Scenario, idx: jax.Array) -> jax.Array:
    j = jnp.clip(
        jnp.searchsorted(s.seg_bounds, idx, side="right") - 1,
        0,
        s.seg_bounds.shape[0] - 1,
    )
    if s.seg_powers.ndim == 2:
        return s.seg_powers[:, j].T  # (n, R)
    return s.seg_powers[j]


def _base(s: Scenario, idx: jax.Array) -> jax.Array:
    if s.seg_powers is not None:
        return _segment_base(s, idx)
    return _parametric_base(s.params, idx.astype(jnp.float32) * s.dt, s.dt)


def _window_mean(base: jax.Array, n: int, w: int) -> jax.Array:
    """Mean over the ``w``-sample boxcar via shared dyadic partial sums.

    A window of overlapping boxcars shares its partial sums: one add per
    dyadic level builds ``s_k[i] = sum(base[i:i+k])`` for ``k = 2, 4, ...``
    and the binary digits of ``w`` then stitch each window from
    ``popcount(w)`` slices — ``O(log w)`` full-array adds instead of the
    ``w - 1`` a per-shift reduction pays (w=50 at fleet width: 7 passes vs
    49, about half the render's smoothing time).  Every partial is indexed
    by absolute position and the stitch topology is fixed by ``w`` alone,
    so chunked rendering stays bit-identical to the whole trace — the same
    contract the old fixed-topology pairwise tree provided (the two differ
    by ulp-level reassociation, covered by the legacy-compare tolerance)."""
    levels = {1: base}
    k = 1
    while 2 * k <= w:
        s = levels[k]
        levels[2 * k] = s[:-k] + s[k:]
        k *= 2
    acc, off, rem = None, 0, w
    while rem:
        p = 1 << (rem.bit_length() - 1)
        part = levels[p][off : off + n]
        acc = part if acc is None else acc + part
        off += p
        rem -= p
    return acc / w


def _fmix32(x: jax.Array) -> jax.Array:
    """murmur3's 32-bit avalanche finalizer (full-avalanche integer mix)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _hash_normal(
    seed: int, idx: jax.Array, tail: tuple[int, ...], salt: jax.Array | None = None
) -> jax.Array:
    """Counter-hashed standard-normal measurement noise, pure in the
    absolute sample index.

    ``noise[t, r] = sqrt(2) * erfinv(2 u - 1)`` with the uniform ``u``
    drawn from a murmur3-finalizer hash of ``(seed, t, r)`` — exact normal
    marginals through the inverse CDF, one fused elementwise pass.  The
    per-rack term is hashed once per rack and XORed into the per-sample
    counter, so the hot loop is a single ``_fmix32`` per sample; that
    replaces the previous per-row ``fold_in`` + threefry draw at ~3x less
    render time (threefry's 20-round block cipher is the wrong tool for
    measurement noise — any full-avalanche counter hash gives the same
    chunk-bitwise contract).  ``u`` is centered to ``[2^-25, 1 - 2^-25]``
    so ``erfinv`` never sees ``+/-1``.

    ``salt`` (a traced uint32 scalar) is XORed into the per-rack lane seed
    before the avalanche mix, giving a decorrelated stream per salt value
    at zero extra per-sample cost; ``salt=None`` is bitwise-identical to
    the unsalted path."""
    s = jnp.uint32(seed)
    r = tail[0] if tail else 1
    lane_seed = (
        jnp.arange(r, dtype=jnp.uint32) * jnp.uint32(0x9E3779B9)
        ^ (s * jnp.uint32(0x85EBCA6B) + jnp.uint32(0x2545F491))
    )
    if salt is not None:
        lane_seed = lane_seed ^ jnp.asarray(salt, jnp.uint32)
    lane = _fmix32(lane_seed)
    h = _fmix32(idx.astype(jnp.uint32)[:, None] ^ lane[None, :])
    u = (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
    u = u + jnp.float32(2.0**-25)
    z = jnp.float32(np.sqrt(2.0)) * jax.scipy.special.erfinv(2.0 * u - 1.0)
    return z if tail else z[:, 0]


def _render_impl(s: Scenario, t0: jax.Array, n: int) -> jax.Array:
    t0 = jnp.asarray(t0, jnp.int32)
    idx = t0 + jnp.arange(n, dtype=jnp.int32)
    w = s.edge_width
    if w > 1:
        # Zero-padded window mean over [i-(w-1-c), i+c], c=(w-1)//2 — the
        # exact window of jnp.convolve(p, ones(w)/w, mode="same").
        c = (w - 1) // 2
        lo = w - 1 - c
        eidx = (t0 - lo) + jnp.arange(n + w - 1, dtype=jnp.int32)
        if s.edge_pad == "clamp":
            # Edge replication: the window reads the first/last sample
            # instead of zeros, so the trace boundaries carry no phantom
            # decay.  Still pure in the absolute index -> chunk-bitwise.
            base = _base(s, jnp.clip(eidx, 0, s.total_samples - 1))
        else:
            base = _base(s, eidx)
            valid = (eidx >= 0) & (eidx < s.total_samples)
            base = jnp.where(
                valid if base.ndim == 1 else valid[:, None], base, 0.0
            )
        p = _window_mean(base, n, w)
    else:
        p = _base(s, idx)

    wp = s.params
    if wp is not None:
        # Fault window bypasses edge smoothing: the near-instant drop is the
        # point (paper Fig. 13).
        t = idx.astype(jnp.float32) * s.dt
        tb = t[:, None] if p.ndim == 2 else t
        in_fault = (tb >= wp.fault_at_s) & (tb < wp.fault_at_s + wp.fault_duration_s)
        p = jnp.where(in_fault, wp.p_fault, p)

    if s.faults is not None:
        # Stochastic rack power loss: the collapse/recovery is linearised
        # over the scenario's edge window (PSU bulk caps + staggered server
        # shutdown — see faults.fault_weight), still pure in the absolute
        # sample index, so chunked rendering stays bit-identical.
        from repro.power import faults as _flt

        wgt = _flt.fault_weight(s.faults, t0, n, max(w, 1))  # (n, R)
        pf = s.faults.p_fault
        if p.ndim == 1:
            wgt, pf = wgt[:, 0], pf[0]
        p = p + wgt * (pf - p)

    if s.noise_seed is not None:
        noise = _hash_normal(s.noise_seed, idx, p.shape[1:], s.noise_salt)
        if wp is not None:
            std = wp.noise_std
        else:
            std = s.seg_noise_std if s.seg_noise_std is not None else 0.0
        p = jnp.clip(p + std * noise, 0.0, 1.0)

    if wp is not None:
        p = p * wp.scale

    if s.faults is not None:
        # Sensor dropout is a *measurement* fault, so it lands last: the
        # telemetry consumer sees NaN where the sensor went dark.  The fleet
        # engines bridge these with a last-good-sample hold before any state
        # update, so NaN never enters the conditioning scan.
        from repro.power import faults as _flt

        dead = _flt.sensor_down(s.faults, t0, n)
        if p.ndim == 1:
            dead = dead[:, 0]
        p = jnp.where(dead, jnp.nan, p)
    return p.astype(jnp.float32)


render = jax.jit(_render_impl, static_argnames="n")
render.__doc__ = """Render ``n`` samples starting at absolute sample ``t0``.

Returns ``(n,)`` for an unbatched scenario or ``(n, R)`` for a per-rack
batch.  Pure in the absolute index: ``render(s, 0, T)`` equals the
concatenation of any chunking ``render(s, t0, n)`` bit-for-bit, so it
serves directly as a streaming chunk provider (``chunk_provider``).
"""


def _render_padded_impl(s: Scenario, t0: jax.Array, n: int) -> jax.Array:
    tr = _render_impl(s, t0, n)
    t0 = jnp.asarray(t0, jnp.int32)
    idx = t0 + jnp.arange(n, dtype=jnp.int32)
    # Position of the last in-range sample within this chunk; holding it for
    # every out-of-range row reproduces exactly the ZOH pad the host-loop
    # engine applies to a ragged trailing chunk (repeat of tr[-1:]).
    last = jnp.clip(jnp.int32(s.total_samples - 1) - t0, 0, n - 1)
    hold = jax.lax.dynamic_index_in_dim(tr, last, axis=0, keepdims=True)
    valid = idx < s.total_samples
    return jnp.where(valid if tr.ndim == 1 else valid[:, None], tr, hold)


render_padded = jax.jit(_render_padded_impl, static_argnames="n")
render_padded.__doc__ = """``render`` with ZOH padding past the scenario end.

Samples at absolute indices ``>= total_samples`` hold the chunk's last
in-range sample, so every chunk of a fixed-shape chunk walk
(``chunk_count`` chunks of ``n`` samples) renders with one static shape —
including the ragged final chunk.  ``t0`` may be a traced value (e.g. a
``lax.scan`` chunk counter); in-range samples are bit-identical to
``render`` at the same indices.  Requires ``t0 < total_samples`` (at
least one in-range sample per chunk) — the walk ``chunk_count``
prescribes never violates this.

This is the entry point for *external* fixed-shape pipelines (e.g. a
pre-sized ring buffer).  The scanned fleet engine itself conditions the
ragged tail at its natural length instead (``pdu.condition`` pads the
trailing partial controller interval internally), so its state and
aggregates never see whole pad intervals.
"""


def chunk_count(s: Scenario, chunk_samples: int) -> int:
    """Static number of ``chunk_samples``-sample chunks covering the
    scenario — the fixed walk length for ``render_padded`` pipelines or a
    ``lax.scan`` over same-shaped chunks."""
    if chunk_samples <= 0:
        raise ValueError(f"chunk_samples must be positive, got {chunk_samples}")
    return -(-s.total_samples // int(chunk_samples))


def render_trace(s: Scenario) -> tuple[jax.Array, float]:
    """Render the whole scenario; returns ``(trace, dt)`` like the legacy API."""
    return render(s, 0, s.total_samples), s.dt


def chunk_provider(s: Scenario):
    """A ``f(t0, n) -> (n, R)`` chunk provider for
    ``fleet.condition_fleet_streaming`` — chunks are synthesized on-device,
    never materialized as (T, R) on the host."""

    def provider(t0: int, n: int) -> jax.Array:
        return render(s, t0, int(n))

    return provider


# ------------------------------------------------- compiled phase timelines


def from_phase_timeline(
    durations_s,
    powers,
    sample_hz: float,
    *,
    edge_time_s: float = 0.1,
    noise_seed: int | None = None,
    noise_std: float = 0.01,
) -> Scenario:
    """Compile an explicit phase timeline into a segment-table scenario.

    Matches ``trace.phase_timeline_trace``'s discretization: each phase gets
    ``max(round(duration*hz), 1)`` samples and transitions get boxcar edges.
    ``powers`` may be ``(K,)`` or a per-rack ``(R, K)``.  Measurement noise
    at ``noise_std`` is enabled by passing ``noise_seed``.
    """
    durations = np.asarray(durations_s, np.float64)
    counts = np.maximum(np.round(durations * sample_hz).astype(np.int64), 1)
    bounds = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int32)
    powers = jnp.asarray(powers, jnp.float32)
    return Scenario(
        params=None,
        seg_bounds=jnp.asarray(bounds),
        seg_powers=powers,
        seg_noise_std=jnp.asarray(noise_std, jnp.float32),
        sample_hz=float(sample_hz),
        total_samples=int(counts.sum()),
        edge_width=_edge_width(edge_time_s, sample_hz),
        noise_seed=noise_seed,
    )


# ------------------------------------------------------- model-derived racks


def workload_from_model(
    arch: str,
    *,
    hw=None,
    phase_model=None,
    tokens_per_step: float = 2**20,
    min_exposed_fraction: float = 0.08,
    **overrides,
) -> WorkloadParams:
    """Derive a rack workload from an assigned model config's step cost.

    Uses ``configs.registry.step_cost`` (6*N*tokens FLOPs and
    parameter-traffic byte counts) through ``phases.step_phases`` to place
    the iteration wave: the compute plateau lasts the step's busy time and
    the comm window its exposed-collective time.  Well-overlapped small
    models would expose almost nothing, which would erase the square wave
    the grid actually sees (paper Fig. 3), so the exposed fraction is
    floored at ``min_exposed_fraction`` of the busy time.  Checkpoint stalls
    become the periodic deep dips.
    """
    from repro.configs import registry
    from repro.power import phases as P

    hw = hw or P.HardwareConstants()
    pm = phase_model or P.PhaseModel()
    cost = registry.step_cost(arch, tokens_per_step=tokens_per_step)
    d, pw = P.step_phases(cost, hw, pm)
    t_busy = float(d[0])
    t_exposed = max(float(d[1]), min_exposed_fraction * t_busy)
    period = t_busy + t_exposed
    dev = pm.device
    p_idle = dev.p_idle_w / dev.p_peak_w
    knobs = dict(
        iteration_period_s=period,
        comm_fraction=t_exposed / period,
        p_compute=float(pw[0]),
        p_comm=float(pw[1]),
        dip_period_s=(
            pm.checkpoint_every_steps * period if pm.checkpoint_every_steps else NEVER
        ),
        dip_duration_s=pm.checkpoint_stall_s,
        p_dip=p_idle,
        p_idle=p_idle,
        warmup_s=10.0,
    )
    knobs.update(overrides)
    return workload(**knobs)


def scenario_from_model(
    arch: str,
    *,
    duration_s: float = 240.0,
    sample_hz: float = 200.0,
    edge_time_s: float = 0.25,
    noise_seed: int | None = None,
    **kwargs,
) -> Scenario:
    """One rack running one assigned model, as a renderable scenario."""
    return make_scenario(
        workload_from_model(arch, **kwargs),
        duration_s=duration_s,
        sample_hz=sample_hz,
        edge_time_s=edge_time_s,
        noise_seed=noise_seed,
    )


def inference_workload(
    *,
    p_idle: float = 0.15,
    p_peak: float = 0.75,
    diurnal_period_s: float = 600.0,
    diurnal_amp: float = 0.85,
    diurnal_phase_s: float = 0.0,
    iteration_period_s: float = 0.5,
    comm_fraction: float = 0.2,
    **overrides,
) -> WorkloadParams:
    """A serving rack: fast shallow batching ripple under a deep diurnal
    envelope (the Ko & Zhu / Li et al. grid-risk profile)."""
    knobs = dict(
        iteration_period_s=iteration_period_s,
        comm_fraction=comm_fraction,
        p_compute=p_peak,
        p_comm=p_peak * 0.8,
        dip_period_s=NEVER,
        dip_duration_s=0.0,
        p_dip=p_idle,
        p_idle=p_idle,
        warmup_s=5.0,
        diurnal_period_s=diurnal_period_s,
        diurnal_amp=diurnal_amp,
        diurnal_phase_s=diurnal_phase_s,
    )
    knobs.update(overrides)
    return workload(**knobs)


def mixed_campus(
    n_racks: int,
    archs: tuple[str, ...],
    *,
    duration_s: float = 240.0,
    sample_hz: float = 200.0,
    seed: int = 0,
    inference_fraction: float = 0.25,
    stagger_s: float = 30.0,
    stop_fraction: float = 0.15,
    fault_rack_fraction: float = 0.1,
    fault_at_s: float | None = None,
    fault_cascade_s: float = 5.0,
    fault_duration_s: float = 30.0,
    edge_time_s: float = 0.25,
    edge_pad: str = "zero",
    noise_seed: int | None = None,
) -> Scenario:
    """A heterogeneous campus: training racks cycling different assigned
    models, an inference-diurnal block, staggered job starts, a subset of
    early job terminations, and a mid-trace fault cascade rippling across a
    contiguous rack range.  Entirely data — one (R,)-batched scenario."""
    import dataclasses

    rng = np.random.default_rng(seed)
    n_inf = int(round(n_racks * inference_fraction))
    n_train = n_racks - n_inf

    # Assemble the per-rack parameter columns on the host (numpy) and
    # convert each leaf exactly once — a 1024-rack campus is 19 transfers,
    # not 19 x (R+1) tiny device ops.
    as_floats = lambda w: jax.tree_util.tree_map(float, w)
    train_templates = [as_floats(workload_from_model(a)) for a in archs]
    inf_template = as_floats(inference_workload(diurnal_period_s=duration_s / 1.5))
    cols: dict[str, np.ndarray] = {}
    for f in dataclasses.fields(WorkloadParams):
        train_vals = [
            getattr(train_templates[i % len(train_templates)], f.name)
            for i in range(n_train)
        ]
        cols[f.name] = np.asarray(
            train_vals + [getattr(inf_template, f.name)] * n_inf, np.float32
        )
    cols["diurnal_phase_s"][n_train:] = rng.uniform(0.0, duration_s, n_inf)

    cols["t_start_s"] = rng.uniform(0.0, stagger_s, n_racks).astype(np.float32)
    n_stop = int(round(n_racks * stop_fraction))
    stop_idx = rng.choice(n_racks, size=n_stop, replace=False)
    cols["t_end_s"][stop_idx] = rng.uniform(0.7, 0.95, n_stop) * duration_s

    n_fault = int(round(n_racks * fault_rack_fraction))
    if n_fault:
        f0 = duration_s * 0.6 if fault_at_s is None else fault_at_s
        lo = int(rng.integers(0, max(n_racks - n_fault, 1)))
        # cascade: the fault ripples across the contiguous rack range
        cols["fault_at_s"][lo : lo + n_fault] = f0 + np.linspace(
            0.0, fault_cascade_s, n_fault, dtype=np.float32
        )
    cols["fault_duration_s"] = np.full(n_racks, fault_duration_s, np.float32)
    cols["scale"] = (1.0 + 0.05 * rng.uniform(-1.0, 1.0, n_racks)).astype(np.float32)
    params = WorkloadParams(**{k: jnp.asarray(v) for k, v in cols.items()})
    return make_scenario(
        params,
        duration_s=duration_s,
        sample_hz=sample_hz,
        edge_time_s=edge_time_s,
        edge_pad=edge_pad,
        noise_seed=noise_seed,
    )
