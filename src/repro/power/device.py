"""Accelerator power-state models (paper §2.2 numbers).

Published peak/idle figures the paper cites:
  H100:    700 W peak / 140 W idle  (5:1)
  B200:   1000 W peak /  50 W idle  (20:1)
  TitanX:  250 W peak /  15 W idle  (the paper's 2-GPU testbed)
  v5e:     ~220 W peak / ~60 W idle (TPU target; public board figures)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DevicePower:
    name: str
    p_peak_w: float
    p_idle_w: float
    p_comm_w: float  # draw during exposed communication (HBM+NIC, no MXU)

    @property
    def peak_to_idle(self) -> float:
        return self.p_peak_w / self.p_idle_w

    def fraction(self, watts: float) -> float:
        return watts / self.p_peak_w


H100 = DevicePower("h100", 700.0, 140.0, 220.0)
B200 = DevicePower("b200", 1000.0, 50.0, 180.0)
TITAN_X = DevicePower("titan_x", 250.0, 15.0, 40.0)
TPU_V5E = DevicePower("tpu_v5e", 220.0, 60.0, 95.0)

DEVICES = {d.name: d for d in (H100, B200, TITAN_X, TPU_V5E)}
