"""Stochastic fault/repair processes as first-class scenario data.

The paper evaluates EasyRider against one scripted fault cascade (Fig. 13);
production racks fail continuously and asynchronously — PDUs brown out,
ESS units trip offline, sensors drop samples — and exactly these
uncoordinated partial-fleet events excite the grid-side oscillation modes
operators fear most (PAPERS.md, "Wide-Area Power System Oscillations from
Large-Scale AI Workloads").  This module compiles per-rack alternating
renewal processes into a **struct-of-arrays fault schedule**:

  * geometric up/down durations drawn once at construction time with
    counter-based ``random.fold_in`` keys (same determinism discipline as
    the scenario noise path: channel and rack index are folded into the
    key, so a schedule is a pure function of ``(seed, rates, geometry)``);
  * three independent channels per rack — **rack power loss** (the rack
    drops to ``p_fault``), **ESS-unit trips** (the battery branch goes
    offline and the PDU falls back to LC passthrough), and **sensor
    dropout** (the rack telemetry renders as NaN and the PDU bridges it
    with a last-good-sample hold);
  * episodes stored as sorted ``(R, K)`` start/end sample-index arrays, so
    membership at any absolute sample is two ``searchsorted`` counts —
    pure in the absolute index, which is what keeps chunked rendering
    bit-identical to whole-trace rendering and fault state resume-safe.

The schedule rides in ``Scenario.faults`` (see ``power.scenario``) and is
consumed by the renderer (rack/sensor channels) and by the fleet engines'
per-interval ESS availability mask (``interval_online``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import pytree_dataclass

# "never happens" sentinel, same convention as ``scenario.NEVER`` (defined
# here as well so this module stays import-cycle-free: scenario imports
# faults for render integration).
NEVER = 1e30

# Episode-count cap per (rack, channel): a backstop against absurd rates,
# far above anything a realistic MTBF/MTTR pair produces over one scenario.
MAX_EPISODES = 512


@pytree_dataclass
class FaultProcess:
    """Per-channel alternating-renewal rates (seconds; scalars or (R,)).

    ``NEVER`` (or any MTBF beyond ~1e29 s) disables a channel.  Mean up
    time = MTBF, mean down time = MTTR; durations are geometric in samples
    (the discrete-time memoryless process), floored at one sample.
    """

    rack_mtbf_s: jax.Array
    rack_mttr_s: jax.Array
    ess_mtbf_s: jax.Array
    ess_mttr_s: jax.Array
    sensor_mtbf_s: jax.Array
    sensor_mttr_s: jax.Array
    p_fault: jax.Array  # rack power while a rack-loss episode is active

    @staticmethod
    def create(
        *,
        rack_mtbf_s=NEVER,
        rack_mttr_s=30.0,
        ess_mtbf_s=NEVER,
        ess_mttr_s=60.0,
        sensor_mtbf_s=NEVER,
        sensor_mttr_s=5.0,
        p_fault=0.02,
    ) -> "FaultProcess":
        for name, mtbf, mttr in (
            ("rack", rack_mtbf_s, rack_mttr_s),
            ("ess", ess_mtbf_s, ess_mttr_s),
            ("sensor", sensor_mtbf_s, sensor_mttr_s),
        ):
            if np.any(np.asarray(mtbf, np.float64) <= 0.0):
                raise ValueError(
                    f"{name}_mtbf_s must be > 0 (got {mtbf}); use "
                    f"faults.NEVER to disable the channel"
                )
            if np.any(np.asarray(mttr, np.float64) <= 0.0):
                raise ValueError(f"{name}_mttr_s must be > 0 (got {mttr})")
        f = lambda v: jnp.asarray(v, jnp.float32)
        return FaultProcess(
            rack_mtbf_s=f(rack_mtbf_s),
            rack_mttr_s=f(rack_mttr_s),
            ess_mtbf_s=f(ess_mtbf_s),
            ess_mttr_s=f(ess_mttr_s),
            sensor_mtbf_s=f(sensor_mtbf_s),
            sensor_mttr_s=f(sensor_mttr_s),
            p_fault=f(p_fault),
        )


@pytree_dataclass
class FaultSchedule:
    """Compiled struct-of-arrays fault schedule (concrete at construction).

    Each channel holds sorted ``(R, K)`` int32 absolute sample indices:
    episode ``j`` of rack ``r`` is active over ``[start[r, j], end[r, j])``.
    Unused slots are padded with ``start == end`` (empty interval), so
    membership tests need no validity mask.  The schedule is an ordinary
    pytree and rides inside ``Scenario`` as traced jit data.
    """

    rack_start: jax.Array  # (R, K) int32
    rack_end: jax.Array
    ess_start: jax.Array
    ess_end: jax.Array
    sensor_start: jax.Array
    sensor_end: jax.Array
    p_fault: jax.Array  # (R,) float32 rack power during a rack-loss episode

    @property
    def n_racks(self) -> int:
        return self.rack_start.shape[0]


# ------------------------------------------------------------- construction


def _geometric_samples(u: np.ndarray, mean_s, sample_hz: float) -> np.ndarray:
    """Geometric durations (in samples, >= 1) with mean ``mean_s`` seconds.

    Float64 throughout: a disabled channel (mean = NEVER) yields ~1e32
    samples, far past any trace but comfortably inside float64 — the
    boundaries are clamped to the trace before the int32 cast.
    """
    n_bar = np.maximum(np.asarray(mean_s, np.float64) * sample_hz, 1.0)
    p = 1.0 / n_bar
    # n = floor(ln u / ln(1-p)) + 1 ~ Geometric(p) on {1, 2, ...}
    return np.floor(np.log(u) / np.log1p(-p)) + 1.0


def _channel_episodes(
    key, tag: int, n_racks: int, total_samples: int, sample_hz: float,
    mtbf_s, mttr_s, max_episodes: int | None,
) -> tuple[np.ndarray, np.ndarray]:
    """Sample one channel's (R, K) sorted start/end sample indices.

    The process starts in the up state (a rack is healthy at sample 0),
    alternates geometric up/down durations, and is truncated at the trace
    end.  Draw counter-based: ``fold_in(fold_in(key, tag), rack)`` keys a
    (K, 2) uniform block per rack, so the schedule for rack r is invariant
    to the fleet size and to every other channel.
    """
    mtbf = np.broadcast_to(np.asarray(mtbf_s, np.float64), (n_racks,))
    mttr = np.broadcast_to(np.asarray(mttr_s, np.float64), (n_racks,))
    if max_episodes is None:
        cycle = (np.min(mtbf) + np.min(mttr)) * sample_hz
        expect = total_samples / max(cycle, 1.0)
        max_episodes = int(np.clip(np.ceil(3.0 * expect + 4.0), 1, MAX_EPISODES))
    k = int(max_episodes)
    ck = jax.random.fold_in(key, tag)
    u = np.asarray(
        jax.vmap(
            lambda r: jax.random.uniform(
                jax.random.fold_in(ck, r), (k, 2), jnp.float32,
                minval=1e-7, maxval=1.0,
            )
        )(jnp.arange(n_racks, dtype=jnp.int32)),
        np.float64,
    )  # (R, K, 2)
    up = _geometric_samples(u[:, :, 0], mtbf[:, None], sample_hz)
    down = _geometric_samples(u[:, :, 1], mttr[:, None], sample_hz)
    start = np.cumsum(up, axis=1) + np.concatenate(
        [np.zeros((n_racks, 1)), np.cumsum(down, axis=1)[:, :-1]], axis=1
    )
    end = start + down
    t = float(total_samples)
    start = np.clip(start, 0.0, t)
    end = np.clip(end, 0.0, t)
    return start.astype(np.int32), end.astype(np.int32)


def sample_schedule(
    process: FaultProcess,
    n_racks: int,
    total_samples: int,
    sample_hz: float,
    *,
    seed: int,
    max_episodes: int | None = None,
) -> FaultSchedule:
    """Compile a ``FaultProcess`` into a concrete ``FaultSchedule``."""
    if total_samples <= 0:
        raise ValueError(f"total_samples must be positive, got {total_samples}")
    if n_racks <= 0:
        raise ValueError(f"n_racks must be positive, got {n_racks}")
    key = jax.random.key(seed)
    rs, re = _channel_episodes(
        key, 0, n_racks, total_samples, sample_hz,
        process.rack_mtbf_s, process.rack_mttr_s, max_episodes,
    )
    es, ee = _channel_episodes(
        key, 1, n_racks, total_samples, sample_hz,
        process.ess_mtbf_s, process.ess_mttr_s, max_episodes,
    )
    ss, se = _channel_episodes(
        key, 2, n_racks, total_samples, sample_hz,
        process.sensor_mtbf_s, process.sensor_mttr_s, max_episodes,
    )
    return FaultSchedule(
        rack_start=jnp.asarray(rs), rack_end=jnp.asarray(re),
        ess_start=jnp.asarray(es), ess_end=jnp.asarray(ee),
        sensor_start=jnp.asarray(ss), sensor_end=jnp.asarray(se),
        p_fault=jnp.broadcast_to(
            jnp.asarray(process.p_fault, jnp.float32), (n_racks,)
        ),
    )


def schedule_from_episodes(
    n_racks: int,
    *,
    rack: list[tuple[int, int, int]] = (),
    ess: list[tuple[int, int, int]] = (),
    sensor: list[tuple[int, int, int]] = (),
    p_fault=0.02,
) -> FaultSchedule:
    """Scripted schedule from explicit ``(rack_idx, start, end)`` episodes
    (sample indices, end exclusive) — deterministic fault injection for
    tests, benches, and the ``fleet.apply_failures`` compatibility shim."""

    def pack(eps):
        per: list[list[tuple[int, int]]] = [[] for _ in range(n_racks)]
        for r, s, e in eps:
            if not 0 <= r < n_racks:
                raise ValueError(f"rack index {r} outside fleet of {n_racks}")
            if e < s or s < 0:
                raise ValueError(f"bad episode [{s}, {e}) for rack {r}")
            per[r].append((int(s), int(e)))
        k = max(max((len(p) for p in per), default=0), 1)
        # Pad unused slots *after* the real episodes with an empty interval
        # at int32 max so every row stays sorted — the searchsorted
        # membership tests silently misbehave on unsorted rows.
        pad = np.iinfo(np.int32).max
        start = np.full((n_racks, k), pad, np.int32)
        end = np.full((n_racks, k), pad, np.int32)
        for r, p in enumerate(per):
            for j, (s, e) in enumerate(sorted(p)):
                start[r, j], end[r, j] = s, e
        return jnp.asarray(start), jnp.asarray(end)

    rs, re = pack(rack)
    es, ee = pack(ess)
    ss, se = pack(sensor)
    return FaultSchedule(
        rack_start=rs, rack_end=re, ess_start=es, ess_end=ee,
        sensor_start=ss, sensor_end=se,
        p_fault=jnp.broadcast_to(jnp.asarray(p_fault, jnp.float32), (n_racks,)),
    )


def inject_episodes(
    s: FaultSchedule,
    *,
    rack: list[tuple[int, int, int]] = (),
    ess: list[tuple[int, int, int]] = (),
    sensor: list[tuple[int, int, int]] = (),
) -> FaultSchedule:
    """Merge scripted ``(rack_idx, start, end)`` episodes into an existing
    schedule, returning a new ``FaultSchedule``.

    This is how a deterministic event — a scripted cascade, a planned
    maintenance window — rides alongside a stochastically sampled
    background process: the injected episodes are unioned with each rack's
    existing episodes (overlaps coalesce), rows are re-sorted, and the
    invariants the membership tests rely on (sorted, non-overlapping,
    empty-interval padding) are re-established.
    """

    def merge(starts, ends, extra):
        st = np.asarray(starts)
        en = np.asarray(ends)
        per: dict[int, list[tuple[int, int]]] = {}
        for r, a, b in extra:
            if not 0 <= r < st.shape[0]:
                raise ValueError(
                    f"rack index {r} outside fleet of {st.shape[0]}"
                )
            if b < a or a < 0:
                raise ValueError(f"bad episode [{a}, {b}) for rack {r}")
            per.setdefault(int(r), []).append((int(a), int(b)))
        if not per:
            return jnp.asarray(st), jnp.asarray(en)
        rows: list[list[tuple[int, int]]] = []
        for r in range(st.shape[0]):
            real = en[r] > st[r]
            eps = sorted(
                [(int(a), int(b)) for a, b in zip(st[r][real], en[r][real])]
                + per.get(r, [])
            )
            out: list[tuple[int, int]] = []
            for a, b in eps:  # union of intervals
                if out and a <= out[-1][1]:
                    out[-1] = (out[-1][0], max(out[-1][1], b))
                else:
                    out.append((a, b))
            rows.append(out)
        k = max(max(len(r) for r in rows), 1)
        pad = np.iinfo(np.int32).max
        ns = np.full((st.shape[0], k), pad, np.int32)
        ne = np.full((st.shape[0], k), pad, np.int32)
        for r, eps in enumerate(rows):
            for j, (a, b) in enumerate(eps):
                ns[r, j], ne[r, j] = a, b
        return jnp.asarray(ns), jnp.asarray(ne)

    rs, re = merge(s.rack_start, s.rack_end, rack)
    es, ee = merge(s.ess_start, s.ess_end, ess)
    ss, se = merge(s.sensor_start, s.sensor_end, sensor)
    return FaultSchedule(
        rack_start=rs, rack_end=re, ess_start=es, ess_end=ee,
        sensor_start=ss, sensor_end=se, p_fault=s.p_fault,
    )


# --------------------------------------------------------------- membership


def _active(starts: jax.Array, ends: jax.Array, idx: jax.Array) -> jax.Array:
    """(n, R) bool: is any episode of each rack active at each sample?

    Episode rows are sorted and non-overlapping (alternating process), so
    membership is ``#started - #ended > 0`` — two searchsorted counts per
    rack, no (n, R, K) materialization.
    """
    def per_rack(st, en):
        return (
            jnp.searchsorted(st, idx, side="right")
            - jnp.searchsorted(en, idx, side="right")
        )

    return (jax.vmap(per_rack)(starts, ends) > 0).T  # (R, n) -> (n, R)


def rack_down(s: FaultSchedule, t0: jax.Array, n: int) -> jax.Array:
    """(n, R) bool: rack-power-loss membership for samples [t0, t0+n)."""
    idx = jnp.asarray(t0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    return _active(s.rack_start, s.rack_end, idx)


def _edge_intensity(
    starts: jax.Array, ends: jax.Array, idx: jax.Array, edge: int
) -> jax.Array:
    """(n, R) float32 episode intensity in [0, 1] with linearised edges:
    ramps 0 -> 1 over the ``edge`` samples following an episode start and
    1 -> 0 over the ``edge`` samples following its end.  ``edge <= 1``
    reduces exactly to binary membership.

    Each sample's intensity depends only on its absolute index and the
    static schedule (episode rows are sorted and non-overlapping, so the
    most recent start fully determines the local ramp), which keeps
    chunked evaluation bit-identical to whole-trace evaluation.
    """
    if edge <= 1:
        return _active(starts, ends, idx).astype(jnp.float32)

    inv = 1.0 / float(edge)

    def per_rack(st, en):
        j = jnp.searchsorted(st, idx, side="right") - 1
        jc = jnp.clip(j, 0, st.shape[0] - 1)
        a = (idx - st[jc]).astype(jnp.float32)
        b = (idx - en[jc]).astype(jnp.float32)
        w = jnp.clip((a + 1.0) * inv, 0.0, 1.0) - jnp.clip(
            (b + 1.0) * inv, 0.0, 1.0
        )
        return jnp.where(j >= 0, w, 0.0)

    return jax.vmap(per_rack)(starts, ends).T  # (R, n) -> (n, R)


def fault_weight(
    s: FaultSchedule, t0: jax.Array, n: int, edge: int
) -> jax.Array:
    """(n, R) float32 rack power-loss intensity in [0, 1].

    ``rack_down`` with the fault edges linearised over ``edge`` samples.
    A breaker trip is not a zero-time event at the PDU — PSU bulk
    capacitance and the staggered shutdown of servers inside the rack
    spread the collapse over the same transition window the renderer
    already applies to workload edges, and a one-sample cliff would put
    an unphysical ``p_step/dt`` impulse on the grid ramp metric.
    """
    idx = jnp.asarray(t0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    return _edge_intensity(s.rack_start, s.rack_end, idx, edge)


def ess_weight(
    s: FaultSchedule, t0: jax.Array, n: int, edge: int
) -> jax.Array:
    """(n, R) float32 *per-sample* ESS availability weight in [0, 1]:
    1 = battery branch fully engaged, 0 = tripped offline, fractional
    during the ``edge``-sample converter wind-down/soft-start around each
    trip/repair.

    This is the hardware plane's view of the ESS channel.  The software
    plane (`interval_online`) quantises trips to controller-interval
    boundaries, which is right for QP admission but would synchronise
    every trip handoff in the same 5 s interval onto one sample — a
    fabricated campus-scale step.  The hardware weight keeps each trip at
    its scheduled sample and winds the converter down over ``edge``
    samples (a protective BMS shutdown ramps the converter; the stored LC
    energy rides through), so concurrent trips decorrelate exactly as the
    sampled schedule says they do.  Pure in the absolute sample index —
    chunked, resumed, and one-shot conditioning see identical weights.
    """
    idx = jnp.asarray(t0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    return 1.0 - _edge_intensity(s.ess_start, s.ess_end, idx, edge)


def sensor_down(s: FaultSchedule, t0: jax.Array, n: int) -> jax.Array:
    """(n, R) bool: sensor-dropout membership for samples [t0, t0+n)."""
    idx = jnp.asarray(t0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    return _active(s.sensor_start, s.sensor_end, idx)


def interval_online(
    s: FaultSchedule, start_sample: jax.Array, n_intervals: int, k: int
) -> jax.Array:
    """(n_intervals, R) float32 ESS availability mask, one row per
    controller interval starting at ``start_sample``.

    Trips are quantized to the controller interval they start in (the unit
    is considered offline for interval ``i`` iff an ESS episode covers the
    interval's first sample) — a pure function of the absolute interval
    index, so chunked, resumed, and one-shot conditioning see the same
    mask bit-for-bit.
    """
    idx = jnp.asarray(start_sample, jnp.int32) + k * jnp.arange(
        n_intervals, dtype=jnp.int32
    )
    down = _active(s.ess_start, s.ess_end, idx)
    return 1.0 - down.astype(jnp.float32)


def episodes_in_window(
    s: FaultSchedule, start_sample: int, stop_sample: int
) -> list[dict]:
    """Host-side event extraction for audit logs: every fault/repair edge
    in ``[start_sample, stop_sample)``, sorted by sample index."""
    out: list[dict] = []
    for channel, st, en in (
        ("rack_power", s.rack_start, s.rack_end),
        ("ess", s.ess_start, s.ess_end),
        ("sensor", s.sensor_start, s.sensor_end),
    ):
        st = np.asarray(st)
        en = np.asarray(en)
        real = en > st
        for r, j in np.argwhere(real & (st >= start_sample) & (st < stop_sample)):
            out.append(dict(event="fault", channel=channel, rack=int(r),
                            sample=int(st[r, j]), until=int(en[r, j])))
        for r, j in np.argwhere(real & (en >= start_sample) & (en < stop_sample)):
            out.append(dict(event="repair", channel=channel, rack=int(r),
                            sample=int(en[r, j])))
    out.sort(key=lambda d: (d["sample"], d["rack"], d["event"]))
    return out
