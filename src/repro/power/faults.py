"""Stochastic fault/repair processes as first-class scenario data.

The paper evaluates EasyRider against one scripted fault cascade (Fig. 13);
production racks fail continuously and asynchronously — PDUs brown out,
ESS units trip offline, sensors drop samples — and exactly these
uncoordinated partial-fleet events excite the grid-side oscillation modes
operators fear most (PAPERS.md, "Wide-Area Power System Oscillations from
Large-Scale AI Workloads").  This module compiles per-rack alternating
renewal processes into a **struct-of-arrays fault schedule**:

  * geometric up/down durations drawn once at construction time with
    counter-based ``random.fold_in`` keys (same determinism discipline as
    the scenario noise path: channel and rack index are folded into the
    key, so a schedule is a pure function of ``(seed, rates, geometry)``);
  * three independent channels per rack — **rack power loss** (the rack
    drops to ``p_fault``), **ESS-unit trips** (the battery branch goes
    offline and the PDU falls back to LC passthrough), and **sensor
    dropout** (the rack telemetry renders as NaN and the PDU bridges it
    with a last-good-sample hold);
  * episodes stored as sorted ``(R, K)`` start/end sample-index arrays, so
    membership at any absolute sample is a pair of boundary-event counts —
    pure in the absolute index, which is what keeps chunked rendering
    bit-identical to whole-trace rendering and fault state resume-safe.

Every derived signal funnels through ONE membership primitive
(``_started``: how many boundary events of a sorted row are at-or-before
an index) with two interchangeable backends: the **legacy** per-sample
``searchsorted`` pair (the oracle), and the **compiled** evaluation that
unrolls the tiny episode axis (K is single-digit for realistic
MTBF/MTTR over one scenario) into K elementwise compares — no gathers,
no binary-search chains, so XLA fuses the whole rendering into its
consumer instead of duplicating a searchsorted DAG per use site
(EXPERIMENTS.md §Perf-8).  The two backends produce identical integer
counts and select identical boundary values, so every float that follows
is bitwise the same; ``method="auto"`` picks the compiled form whenever
``K <= _UNROLL_MAX``.

The schedule rides in ``Scenario.faults`` (see ``power.scenario``) and is
consumed by the renderer (rack/sensor channels), by the fleet engines'
per-interval ESS availability mask (``interval_online``), and by the
degraded-mode fast path (``interval_sensed`` / ``sensor_dark_hold`` plus
the megakernel's compact episode-table operand, see ``core.pdu``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import pytree_dataclass

# "never happens" sentinel, same convention as ``scenario.NEVER`` (defined
# here as well so this module stays import-cycle-free: scenario imports
# faults for render integration).
NEVER = 1e30

# Episode-count cap per (rack, channel): a backstop against absurd rates,
# far above anything a realistic MTBF/MTTR pair produces over one scenario.
MAX_EPISODES = 512

# Widest episode axis the compiled membership path unrolls into elementwise
# compares; beyond it, ``method="auto"`` falls back to the searchsorted
# oracle (O(K) compares would start to lose to O(log K) binary search, and
# schedules that busy are outside the regime the fast path is tuned for).
_UNROLL_MAX = 32


@pytree_dataclass
class FaultProcess:
    """Per-channel alternating-renewal rates (seconds; scalars or (R,)).

    ``NEVER`` (or any MTBF beyond ~1e29 s) disables a channel.  Mean up
    time = MTBF, mean down time = MTTR; durations are geometric in samples
    (the discrete-time memoryless process), floored at one sample.
    """

    rack_mtbf_s: jax.Array
    rack_mttr_s: jax.Array
    ess_mtbf_s: jax.Array
    ess_mttr_s: jax.Array
    sensor_mtbf_s: jax.Array
    sensor_mttr_s: jax.Array
    p_fault: jax.Array  # rack power while a rack-loss episode is active

    @staticmethod
    def create(
        *,
        rack_mtbf_s=NEVER,
        rack_mttr_s=30.0,
        ess_mtbf_s=NEVER,
        ess_mttr_s=60.0,
        sensor_mtbf_s=NEVER,
        sensor_mttr_s=5.0,
        p_fault=0.02,
    ) -> "FaultProcess":
        for name, mtbf, mttr in (
            ("rack", rack_mtbf_s, rack_mttr_s),
            ("ess", ess_mtbf_s, ess_mttr_s),
            ("sensor", sensor_mtbf_s, sensor_mttr_s),
        ):
            if np.any(np.asarray(mtbf, np.float64) <= 0.0):
                raise ValueError(
                    f"{name}_mtbf_s must be > 0 (got {mtbf}); use "
                    f"faults.NEVER to disable the channel"
                )
            if np.any(np.asarray(mttr, np.float64) <= 0.0):
                raise ValueError(f"{name}_mttr_s must be > 0 (got {mttr})")
        f = lambda v: jnp.asarray(v, jnp.float32)
        return FaultProcess(
            rack_mtbf_s=f(rack_mtbf_s),
            rack_mttr_s=f(rack_mttr_s),
            ess_mtbf_s=f(ess_mtbf_s),
            ess_mttr_s=f(ess_mttr_s),
            sensor_mtbf_s=f(sensor_mtbf_s),
            sensor_mttr_s=f(sensor_mttr_s),
            p_fault=f(p_fault),
        )


@pytree_dataclass
class FaultSchedule:
    """Compiled struct-of-arrays fault schedule (concrete at construction).

    Each channel holds sorted ``(R, K)`` int32 absolute sample indices:
    episode ``j`` of rack ``r`` is active over ``[start[r, j], end[r, j])``.
    Unused slots are padded with ``start == end`` (empty interval), so
    membership tests need no validity mask.  The schedule is an ordinary
    pytree and rides inside ``Scenario`` as traced jit data.
    """

    rack_start: jax.Array  # (R, K) int32
    rack_end: jax.Array
    ess_start: jax.Array
    ess_end: jax.Array
    sensor_start: jax.Array
    sensor_end: jax.Array
    p_fault: jax.Array  # (R,) float32 rack power during a rack-loss episode

    @property
    def n_racks(self) -> int:
        return self.rack_start.shape[0]


# ------------------------------------------------------------- construction


def _geometric_samples(u: np.ndarray, mean_s, sample_hz: float) -> np.ndarray:
    """Geometric durations (in samples, >= 1) with mean ``mean_s`` seconds.

    Float64 throughout: a disabled channel (mean = NEVER) yields ~1e32
    samples, far past any trace but comfortably inside float64 — the
    boundaries are clamped to the trace before the int32 cast.
    """
    n_bar = np.maximum(np.asarray(mean_s, np.float64) * sample_hz, 1.0)
    p = 1.0 / n_bar
    # n = floor(ln u / ln(1-p)) + 1 ~ Geometric(p) on {1, 2, ...}
    return np.floor(np.log(u) / np.log1p(-p)) + 1.0


def _channel_episodes(
    key, tag: int, n_racks: int, total_samples: int, sample_hz: float,
    mtbf_s, mttr_s, max_episodes: int | None,
) -> tuple[np.ndarray, np.ndarray]:
    """Sample one channel's (R, K) sorted start/end sample indices.

    The process starts in the up state (a rack is healthy at sample 0),
    alternates geometric up/down durations, and is truncated at the trace
    end.  Draw counter-based: ``fold_in(fold_in(key, tag), rack)`` keys a
    (K, 2) uniform block per rack, so the schedule for rack r is invariant
    to the fleet size and to every other channel.
    """
    mtbf = np.broadcast_to(np.asarray(mtbf_s, np.float64), (n_racks,))
    mttr = np.broadcast_to(np.asarray(mttr_s, np.float64), (n_racks,))
    if max_episodes is None:
        cycle = (np.min(mtbf) + np.min(mttr)) * sample_hz
        expect = total_samples / max(cycle, 1.0)
        max_episodes = int(np.clip(np.ceil(3.0 * expect + 4.0), 1, MAX_EPISODES))
    k = int(max_episodes)
    ck = jax.random.fold_in(key, tag)
    u = np.asarray(
        jax.vmap(
            lambda r: jax.random.uniform(
                jax.random.fold_in(ck, r), (k, 2), jnp.float32,
                minval=1e-7, maxval=1.0,
            )
        )(jnp.arange(n_racks, dtype=jnp.int32)),
        np.float64,
    )  # (R, K, 2)
    up = _geometric_samples(u[:, :, 0], mtbf[:, None], sample_hz)
    down = _geometric_samples(u[:, :, 1], mttr[:, None], sample_hz)
    start = np.cumsum(up, axis=1) + np.concatenate(
        [np.zeros((n_racks, 1)), np.cumsum(down, axis=1)[:, :-1]], axis=1
    )
    end = start + down
    t = float(total_samples)
    start = np.clip(start, 0.0, t)
    end = np.clip(end, 0.0, t)
    return start.astype(np.int32), end.astype(np.int32)


def sample_schedule(
    process: FaultProcess,
    n_racks: int,
    total_samples: int,
    sample_hz: float,
    *,
    seed: int,
    max_episodes: int | None = None,
) -> FaultSchedule:
    """Compile a ``FaultProcess`` into a concrete ``FaultSchedule``."""
    if total_samples <= 0:
        raise ValueError(f"total_samples must be positive, got {total_samples}")
    if n_racks <= 0:
        raise ValueError(f"n_racks must be positive, got {n_racks}")
    key = jax.random.key(seed)
    rs, re = _channel_episodes(
        key, 0, n_racks, total_samples, sample_hz,
        process.rack_mtbf_s, process.rack_mttr_s, max_episodes,
    )
    es, ee = _channel_episodes(
        key, 1, n_racks, total_samples, sample_hz,
        process.ess_mtbf_s, process.ess_mttr_s, max_episodes,
    )
    ss, se = _channel_episodes(
        key, 2, n_racks, total_samples, sample_hz,
        process.sensor_mtbf_s, process.sensor_mttr_s, max_episodes,
    )
    return FaultSchedule(
        rack_start=jnp.asarray(rs), rack_end=jnp.asarray(re),
        ess_start=jnp.asarray(es), ess_end=jnp.asarray(ee),
        sensor_start=jnp.asarray(ss), sensor_end=jnp.asarray(se),
        p_fault=jnp.broadcast_to(
            jnp.asarray(process.p_fault, jnp.float32), (n_racks,)
        ),
    )


def _coalesce(eps: list[tuple[int, int]]) -> list[tuple[int, int]]:
    """Union of sorted ``(start, end)`` intervals (overlaps/adjacency merge)."""
    out: list[tuple[int, int]] = []
    for a, b in eps:
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return out


def schedule_from_episodes(
    n_racks: int,
    *,
    rack: list[tuple[int, int, int]] = (),
    ess: list[tuple[int, int, int]] = (),
    sensor: list[tuple[int, int, int]] = (),
    p_fault=0.02,
) -> FaultSchedule:
    """Scripted schedule from explicit ``(rack_idx, start, end)`` episodes
    (sample indices, end exclusive) — deterministic fault injection for
    tests, benches, and the ``fleet.apply_failures`` compatibility shim."""

    def pack(eps):
        per: list[list[tuple[int, int]]] = [[] for _ in range(n_racks)]
        for r, s, e in eps:
            if not 0 <= r < n_racks:
                raise ValueError(f"rack index {r} outside fleet of {n_racks}")
            if e < s or s < 0:
                raise ValueError(f"bad episode [{s}, {e}) for rack {r}")
            per[r].append((int(s), int(e)))
        # Union-coalesce overlapping/adjacent episodes per rack, the same
        # normalization ``inject_episodes`` applies: every consumer (and in
        # particular the compiled dark-hold bridge, which assumes the
        # sample before an episode start is outside every episode) relies
        # on rows being sorted AND non-overlapping.
        per = [_coalesce(sorted(p)) for p in per]
        k = max(max((len(p) for p in per), default=0), 1)
        # Pad unused slots *after* the real episodes with an empty interval
        # at int32 max so every row stays sorted — the membership counts
        # silently misbehave on unsorted rows.
        pad = np.iinfo(np.int32).max
        start = np.full((n_racks, k), pad, np.int32)
        end = np.full((n_racks, k), pad, np.int32)
        for r, p in enumerate(per):
            for j, (s, e) in enumerate(p):
                start[r, j], end[r, j] = s, e
        return jnp.asarray(start), jnp.asarray(end)

    rs, re = pack(rack)
    es, ee = pack(ess)
    ss, se = pack(sensor)
    return FaultSchedule(
        rack_start=rs, rack_end=re, ess_start=es, ess_end=ee,
        sensor_start=ss, sensor_end=se,
        p_fault=jnp.broadcast_to(jnp.asarray(p_fault, jnp.float32), (n_racks,)),
    )


def inject_episodes(
    s: FaultSchedule,
    *,
    rack: list[tuple[int, int, int]] = (),
    ess: list[tuple[int, int, int]] = (),
    sensor: list[tuple[int, int, int]] = (),
) -> FaultSchedule:
    """Merge scripted ``(rack_idx, start, end)`` episodes into an existing
    schedule, returning a new ``FaultSchedule``.

    This is how a deterministic event — a scripted cascade, a planned
    maintenance window — rides alongside a stochastically sampled
    background process: the injected episodes are unioned with each rack's
    existing episodes (overlaps coalesce), rows are re-sorted, and the
    invariants the membership tests rely on (sorted, non-overlapping,
    empty-interval padding) are re-established.
    """

    def merge(starts, ends, extra):
        st = np.asarray(starts)
        en = np.asarray(ends)
        per: dict[int, list[tuple[int, int]]] = {}
        for r, a, b in extra:
            if not 0 <= r < st.shape[0]:
                raise ValueError(
                    f"rack index {r} outside fleet of {st.shape[0]}"
                )
            if b < a or a < 0:
                raise ValueError(f"bad episode [{a}, {b}) for rack {r}")
            per.setdefault(int(r), []).append((int(a), int(b)))
        if not per:
            return jnp.asarray(st), jnp.asarray(en)
        rows: list[list[tuple[int, int]]] = []
        for r in range(st.shape[0]):
            real = en[r] > st[r]
            eps = sorted(
                [(int(a), int(b)) for a, b in zip(st[r][real], en[r][real])]
                + per.get(r, [])
            )
            rows.append(_coalesce(eps))
        k = max(max(len(r) for r in rows), 1)
        pad = np.iinfo(np.int32).max
        ns = np.full((st.shape[0], k), pad, np.int32)
        ne = np.full((st.shape[0], k), pad, np.int32)
        for r, eps in enumerate(rows):
            for j, (a, b) in enumerate(eps):
                ns[r, j], ne[r, j] = a, b
        return jnp.asarray(ns), jnp.asarray(ne)

    rs, re = merge(s.rack_start, s.rack_end, rack)
    es, ee = merge(s.ess_start, s.ess_end, ess)
    ss, se = merge(s.sensor_start, s.sensor_end, sensor)
    return FaultSchedule(
        rack_start=rs, rack_end=re, ess_start=es, ess_end=ee,
        sensor_start=ss, sensor_end=se, p_fault=s.p_fault,
    )


def validate_tables(s: FaultSchedule) -> None:
    """Host-side check that every episode table satisfies the invariants
    the membership primitives assume: rows sorted ascending; real episodes
    (``end > start``) non-overlapping with at least one clean sample
    between them (the dark-hold bridge reads the sample *before* each
    episode start); padding — empty ``end <= start`` slots, whether the
    int32-max sentinel of ``schedule_from_episodes`` or the clamped
    trace-end slots of ``sample_schedule`` — only *after* the real
    episodes.  Schedules built by the module's own constructors hold these
    by construction; hand-built tables are checked when a concrete
    schedule is attached to a scenario.  A traced schedule (built inside a
    jit) is skipped — invariants cannot be inspected there.
    """
    for name in ("rack", "ess", "sensor"):
        st, en = getattr(s, f"{name}_start"), getattr(s, f"{name}_end")
        if isinstance(st, jax.core.Tracer) or isinstance(en, jax.core.Tracer):
            return
        st, en = np.asarray(st), np.asarray(en)
        if st.shape != en.shape or st.ndim != 2:
            raise ValueError(
                f"{name} episode tables must be matching (R, K) arrays, got "
                f"{st.shape} / {en.shape}"
            )
        if np.any(en < st):
            raise ValueError(
                f"{name} table has an inverted episode (end < start); "
                "episodes are [start, end) with end >= start"
            )
        real = en > st
        if st.shape[1] > 1:
            if np.any(st[:, 1:] < st[:, :-1]):
                raise ValueError(
                    f"{name} table rows must be sorted ascending by start "
                    "(the membership counts silently misbehave on unsorted "
                    "rows)"
                )
            if np.any(real[:, 1:] & ~real[:, :-1]):
                raise ValueError(
                    f"{name} table has a real episode after an empty "
                    "padding slot; pad unused slots only after the real "
                    "episodes"
                )
            if np.any(real[:, 1:] & (st[:, 1:] <= en[:, :-1])):
                raise ValueError(
                    f"{name} table rows must be non-overlapping with a gap "
                    "of at least one sample between episodes (coalesce "
                    "overlapping/adjacent episodes, as "
                    "schedule_from_episodes does)"
                )


# --------------------------------------------------------------- membership
#
# ONE membership primitive (``_started``), two backends.  Everything below
# — binary membership, edge-linearised intensity, interval masks, the
# dark-hold bridge index — derives from "how many boundary events are
# at-or-before this sample" plus "which episode started most recently",
# so the legacy-vs-compiled bitwise contract reduces to those two integer
# quantities being identical (tests/test_faults.py, fault-path
# equivalence suite).


def _resolve_method(method: str, k: int) -> str:
    if method == "auto":
        return "compiled" if k <= _UNROLL_MAX else "legacy"
    if method not in ("compiled", "legacy"):
        raise ValueError(
            f"method must be 'auto', 'compiled' or 'legacy', got {method!r}"
        )
    return method


def _started(table: jax.Array, idx: jax.Array, method: str) -> jax.Array:
    """(R, n) int32: per rack, how many entries of the sorted ``(R, K)``
    boundary table are at-or-before each absolute sample index.

    The single membership primitive.  ``legacy`` is a per-rack
    ``searchsorted(side="right")``; ``compiled`` unrolls the episode axis
    into K elementwise compares — identical counts (both are the exact
    cardinality ``#{j : table[r, j] <= idx}``), but the compiled form is
    pure fuseable arithmetic with no gather/binary-search chain.
    """
    if _resolve_method(method, table.shape[1]) == "legacy":
        return jax.vmap(
            lambda row: jnp.searchsorted(row, idx, side="right")
        )(table).astype(jnp.int32)
    cnt = jnp.zeros((table.shape[0], idx.shape[0]), jnp.int32)
    for j in range(table.shape[1]):
        cnt = cnt + (table[:, j : j + 1] <= idx[None, :]).astype(jnp.int32)
    return cnt


def _select_boundaries(
    starts: jax.Array, ends: jax.Array, idx: jax.Array, method: str
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``(cnt, st_sel, en_sel)``, each (R, n): the start-boundary count at
    each index plus the boundaries of the most recently started episode
    (row 0's boundaries where none has started yet — callers gate on
    ``cnt > 0``, matching the legacy clipped gather exactly)."""
    if _resolve_method(method, starts.shape[1]) == "legacy":

        def per_rack(st, en):
            cnt = jnp.searchsorted(st, idx, side="right").astype(jnp.int32)
            jc = jnp.clip(cnt - 1, 0, st.shape[0] - 1)
            return cnt, st[jc], en[jc]

        return jax.vmap(per_rack)(starts, ends)
    cnt = _started(starts, idx, method)
    st_sel = jnp.broadcast_to(starts[:, :1], cnt.shape)
    en_sel = jnp.broadcast_to(ends[:, :1], cnt.shape)
    for j in range(1, starts.shape[1]):
        pick = cnt >= (j + 1)
        st_sel = jnp.where(pick, starts[:, j : j + 1], st_sel)
        en_sel = jnp.where(pick, ends[:, j : j + 1], en_sel)
    return cnt, st_sel, en_sel


def _active(
    starts: jax.Array, ends: jax.Array, idx: jax.Array, method: str = "auto"
) -> jax.Array:
    """(n, R) bool: is any episode of each rack active at each sample?

    Episode rows are sorted and non-overlapping (alternating process), so
    membership is ``#started - #ended > 0`` — two boundary counts per
    rack, no (n, R, K) materialization.
    """
    started = _started(starts, idx, method)
    ended = _started(ends, idx, method)
    return (started - ended > 0).T  # (R, n) -> (n, R)


def rack_down(
    s: FaultSchedule, t0: jax.Array, n: int, *, method: str = "auto"
) -> jax.Array:
    """(n, R) bool: rack-power-loss membership for samples [t0, t0+n)."""
    idx = jnp.asarray(t0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    return _active(s.rack_start, s.rack_end, idx, method)


def _edge_intensity(
    starts: jax.Array,
    ends: jax.Array,
    idx: jax.Array,
    edge: int,
    method: str = "auto",
) -> jax.Array:
    """(n, R) float32 episode intensity in [0, 1] with linearised edges:
    ramps 0 -> 1 over the ``edge`` samples following an episode start and
    1 -> 0 over the ``edge`` samples following its end.  ``edge <= 1``
    reduces exactly to binary membership.

    Each sample's intensity depends only on its absolute index and the
    static schedule (episode rows are sorted and non-overlapping, so the
    most recent start fully determines the local ramp), which keeps
    chunked evaluation bit-identical to whole-trace evaluation.  Both
    membership backends select the same boundary integers, and the ramp
    arithmetic that follows is the identical elementwise expression, so
    ``compiled`` and ``legacy`` intensities are bitwise equal.
    """
    if edge <= 1:
        return _active(starts, ends, idx, method).astype(jnp.float32)

    inv = 1.0 / float(edge)
    cnt, st_sel, en_sel = _select_boundaries(starts, ends, idx, method)
    a = (idx[None, :] - st_sel).astype(jnp.float32)
    b = (idx[None, :] - en_sel).astype(jnp.float32)
    w = jnp.clip((a + 1.0) * inv, 0.0, 1.0) - jnp.clip(
        (b + 1.0) * inv, 0.0, 1.0
    )
    return jnp.where(cnt > 0, w, 0.0).T  # (R, n) -> (n, R)


def fault_weight(
    s: FaultSchedule, t0: jax.Array, n: int, edge: int, *, method: str = "auto"
) -> jax.Array:
    """(n, R) float32 rack power-loss intensity in [0, 1].

    ``rack_down`` with the fault edges linearised over ``edge`` samples.
    A breaker trip is not a zero-time event at the PDU — PSU bulk
    capacitance and the staggered shutdown of servers inside the rack
    spread the collapse over the same transition window the renderer
    already applies to workload edges, and a one-sample cliff would put
    an unphysical ``p_step/dt`` impulse on the grid ramp metric.
    """
    idx = jnp.asarray(t0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    return _edge_intensity(s.rack_start, s.rack_end, idx, edge, method)


def ess_weight(
    s: FaultSchedule, t0: jax.Array, n: int, edge: int, *, method: str = "auto"
) -> jax.Array:
    """(n, R) float32 *per-sample* ESS availability weight in [0, 1]:
    1 = battery branch fully engaged, 0 = tripped offline, fractional
    during the ``edge``-sample converter wind-down/soft-start around each
    trip/repair.

    This is the hardware plane's view of the ESS channel.  The software
    plane (`interval_online`) quantises trips to controller-interval
    boundaries, which is right for QP admission but would synchronise
    every trip handoff in the same 5 s interval onto one sample — a
    fabricated campus-scale step.  The hardware weight keeps each trip at
    its scheduled sample and winds the converter down over ``edge``
    samples (a protective BMS shutdown ramps the converter; the stored LC
    energy rides through), so concurrent trips decorrelate exactly as the
    sampled schedule says they do.  Pure in the absolute sample index —
    chunked, resumed, and one-shot conditioning see identical weights.
    """
    idx = jnp.asarray(t0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    return 1.0 - _edge_intensity(s.ess_start, s.ess_end, idx, edge, method)


def sensor_down(
    s: FaultSchedule, t0: jax.Array, n: int, *, method: str = "auto"
) -> jax.Array:
    """(n, R) bool: sensor-dropout membership for samples [t0, t0+n)."""
    idx = jnp.asarray(t0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    return _active(s.sensor_start, s.sensor_end, idx, method)


def interval_online(
    s: FaultSchedule,
    start_sample: jax.Array,
    n_intervals: int,
    k: int,
    *,
    method: str = "auto",
) -> jax.Array:
    """(n_intervals, R) float32 ESS availability mask, one row per
    controller interval starting at ``start_sample``.

    Trips are quantized to the controller interval they start in (the unit
    is considered offline for interval ``i`` iff an ESS episode covers the
    interval's first sample) — a pure function of the absolute interval
    index, so chunked, resumed, and one-shot conditioning see the same
    mask bit-for-bit.
    """
    idx = jnp.asarray(start_sample, jnp.int32) + k * jnp.arange(
        n_intervals, dtype=jnp.int32
    )
    down = _active(s.ess_start, s.ess_end, idx, method)
    return 1.0 - down.astype(jnp.float32)


def interval_sensed(
    s: FaultSchedule,
    start_sample: jax.Array,
    n_intervals: int,
    k: int,
    *,
    stop: jax.Array | None = None,
    method: str = "auto",
) -> jax.Array:
    """(n_intervals, R) bool: does each controller interval contain at
    least one finite (non-dark) sample for each rack?

    Schedule-side equivalent of the degraded path's
    ``any(isfinite(chunk))`` per-interval reduction over the rendered
    trace: interval ``i`` (samples ``[i0, i0 + k)`` with
    ``i0 = start_sample + i*k``) is fully dark iff one sensor episode
    covers it entirely, i.e. the episode active at ``i0`` ends at or
    after ``min(i0 + k, stop)``.  ``stop`` is where real samples end
    (``start_sample + n`` for an ``n``-sample chunk); the trailing
    zero-order-hold padding of a partial final interval replicates the
    last real sample, so only coverage up to ``stop`` matters — exactly
    how the rendered-trace reduction sees it.
    """
    i0 = jnp.asarray(start_sample, jnp.int32) + k * jnp.arange(
        n_intervals, dtype=jnp.int32
    )
    hi = i0 + k if stop is None else jnp.minimum(i0 + k, jnp.asarray(stop, jnp.int32))
    cnt, st_sel, en_sel = _select_boundaries(
        s.sensor_start, s.sensor_end, i0, method
    )
    active = (cnt - _started(s.sensor_end, i0, method)) > 0
    covered = active & (en_sel >= hi[None, :])
    del st_sel
    return (~covered).T  # (R, n_intervals) -> (n_intervals, R)


def sensor_dark_hold(
    s: FaultSchedule, idx: jax.Array, *, method: str = "auto"
) -> tuple[jax.Array, jax.Array]:
    """``(dark, hold)``, each (n, R): per-sample sensor-dropout membership
    plus the absolute index of the last finite sample before the covering
    episode (``start - 1``; arbitrary where ``dark`` is False).

    This is the schedule-side form of the rendered-trace NaN bridge
    (``pdu.bridge_sensors``): because episode rows are coalesced
    (non-overlapping with >= 1 healthy sample between episodes — the
    alternating process draws up-times >= 1 sample, and scripted
    injection unions overlaps), the sample at ``start - 1`` is always
    finite, so holding it reproduces the associative-scan last-good
    bridge bit-for-bit wherever ``start - 1`` falls inside the window at
    hand; earlier starts fall through to the caller's carried last-good
    row, which is the same cross-chunk hold value the legacy bridge
    carries.
    """
    cnt, st_sel, en_sel = _select_boundaries(
        s.sensor_start, s.sensor_end, idx, method
    )
    del en_sel
    # Rows are paired and non-overlapping, so "started more often than
    # ended" already pins idx inside the most recently started episode.
    dark = (cnt - _started(s.sensor_end, idx, method)) > 0
    return dark.T, (st_sel - 1).T  # (R, n) -> (n, R)


def episodes_in_window(
    s: FaultSchedule, start_sample: int, stop_sample: int
) -> list[dict]:
    """Host-side event extraction for audit logs: every fault/repair edge
    in ``[start_sample, stop_sample)``, sorted by sample index."""
    out: list[dict] = []
    for channel, st, en in (
        ("rack_power", s.rack_start, s.rack_end),
        ("ess", s.ess_start, s.ess_end),
        ("sensor", s.sensor_start, s.sensor_end),
    ):
        st = np.asarray(st)
        en = np.asarray(en)
        real = en > st
        for r, j in np.argwhere(real & (st >= start_sample) & (st < stop_sample)):
            out.append(dict(event="fault", channel=channel, rack=int(r),
                            sample=int(st[r, j]), until=int(en[r, j])))
        for r, j in np.argwhere(real & (en >= start_sample) & (en < stop_sample)):
            out.append(dict(event="repair", channel=channel, rack=int(r),
                            sample=int(en[r, j])))
    out.sort(key=lambda d: (d["sample"], d["rack"], d["event"]))
    return out
