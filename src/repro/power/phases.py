"""Workload -> power-phase modeling (paper §2.2).

Synchronous training is a loop of phases with sharply different power:

    compute (MXU busy, ~peak) -> exposed collective (idle-ish) -> compute ...
    every K steps: checkpoint stall (idle)
    job start: staggered ramp;  job end / fault: instant drop

Given a compiled step's cost analysis (FLOPs, HBM bytes, collective bytes —
the same numbers the roofline uses, see launch/dryrun.py) and hardware
constants, this module derives the per-step phase timeline that drives the
power trace: this is how the *actual* assigned-architecture workloads are
mapped onto EasyRider's testbench, rather than hand-picking frequencies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.power.device import DevicePower, TPU_V5E


@dataclasses.dataclass(frozen=True)
class HardwareConstants:
    """TPU v5e roofline constants (per chip), also used by launch/dryrun."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s/link (~per-direction per link)
    chips: int = 256


@dataclasses.dataclass(frozen=True)
class StepCost:
    """Per-step aggregate cost (whole mesh)."""

    flops: float
    hbm_bytes: float
    collective_bytes: float


@dataclasses.dataclass(frozen=True)
class PhaseModel:
    """Timing/power knobs for the phase derivation."""

    mfu: float = 0.5  # achieved fraction of peak during compute
    comm_efficiency: float = 0.7  # achieved fraction of link bandwidth
    overlap: float = 0.6  # fraction of collective hidden under compute
    checkpoint_every_steps: int = 200
    checkpoint_stall_s: float = 4.0
    device: DevicePower = TPU_V5E


def step_phases(
    cost: StepCost, hw: HardwareConstants, model: PhaseModel
) -> tuple[np.ndarray, np.ndarray]:
    """One training step -> (durations_s, per-unit powers).

    The compute phase runs at ~peak power; the *exposed* part of the
    collective (not hidden under compute) runs at comm power.  Memory time
    is folded into compute (TPU compute phases are themselves a
    compute/memory mix; the power difference within that mix is smoothed by
    board-level regulation, paper §2.2 — only the >=10 ms structure
    matters to the grid).
    """
    t_compute = cost.flops / (hw.chips * hw.peak_flops * model.mfu)
    t_mem = cost.hbm_bytes / (hw.chips * hw.hbm_bw)
    t_busy = max(t_compute, t_mem)
    t_coll = cost.collective_bytes / (hw.chips * hw.ici_bw * model.comm_efficiency)
    t_exposed = max(t_coll - model.overlap * t_busy, 0.0)

    d = model.device
    p_busy = 1.0  # per-unit of rack rated power
    p_comm = d.p_comm_w / d.p_peak_w
    durations = np.array([t_busy, max(t_exposed, 1e-4)])
    powers = np.array([p_busy, p_comm], np.float32)
    return durations, powers


def training_timeline(
    cost: StepCost,
    hw: HardwareConstants,
    model: PhaseModel,
    n_steps: int,
    *,
    warmup_s: float = 10.0,
    warmup_levels: int = 20,
    end_idle_s: float = 10.0,
) -> tuple[np.ndarray, np.ndarray]:
    """A full job timeline: warmup ramp, steps (+checkpoint stalls), end drop.

    Fully vectorized phase-list construction (tile + insert); compile the
    result into the renderable scenario IR with ``training_scenario``.
    """
    d = model.device
    p_idle = d.p_idle_w / d.p_peak_w

    # Staggered warm-up ramp (control planes stagger job starts, §2.2).
    step_d, step_p = step_phases(cost, hw, model)
    p_avg = float(np.sum(step_d * step_p) / np.sum(step_d))
    levels = np.arange(1, warmup_levels + 1, dtype=np.float64)
    warm_d = np.full(warmup_levels, warmup_s / warmup_levels)
    warm_p = p_idle + (p_avg - p_idle) * levels / warmup_levels

    durs = np.tile(step_d, n_steps)
    pows = np.tile(step_p.astype(np.float64), n_steps)
    c = model.checkpoint_every_steps
    if c:
        n_stalls = n_steps // c
        # insert a stall after every c-th step (each step = len(step_d) phases)
        at = np.arange(1, n_stalls + 1) * c * step_d.shape[0]
        durs = np.insert(durs, at, model.checkpoint_stall_s)
        pows = np.insert(pows, at, p_idle)

    durs = np.concatenate([warm_d, durs, [end_idle_s]])
    pows = np.concatenate([warm_p, pows, [p_idle]])
    return durs, pows.astype(np.float32)


def training_scenario(
    cost: StepCost,
    hw: HardwareConstants,
    model: PhaseModel,
    n_steps: int,
    sample_hz: float,
    *,
    edge_time_s: float = 0.1,
    **timeline_kwargs,
):
    """Compile a training job's phase timeline straight into the scenario IR
    (`repro.power.scenario`): returns a renderable segment-table Scenario."""
    from repro.power import scenario as SC

    durs, pows = training_timeline(cost, hw, model, n_steps, **timeline_kwargs)
    return SC.from_phase_timeline(durs, pows, sample_hz, edge_time_s=edge_time_s)


def step_fundamental_hz(cost: StepCost, hw: HardwareConstants, model: PhaseModel) -> float:
    """The iteration frequency — where the workload's spectral line sits."""
    d, _ = step_phases(cost, hw, model)
    return 1.0 / float(np.sum(d))
