"""Grid compliance checks (paper §3): ramp rate and frequency content.

The grid operator supplies a spec (beta, alpha, f_c):

  * |dP/dt| <= beta            for all t      (P normalized to rated power)
  * S(f)    <= alpha           for all f >= f_c

where S(f) is the one-sided normalized DFT magnitude of the power trace —
scaled so S(0) is the trace mean and each bin is interpretable as the
fraction of rated power oscillating at that frequency (paper Fig. 3b shows
S(1/22 Hz) ~= 0.1 for the testbench trace).

Two interfaces:

  * ``check`` — whole-trace oracle (forward-difference ramp + windowed FFT).
  * **Streaming observers** — constant-size state folded chunk-by-chunk
    inside the conditioning engines, so an unbounded campus stream reports
    compliance online without materializing the trace: ``RampObserver``
    carries the last sample across chunk boundaries (a per-chunk
    ``jnp.diff`` silently drops the boundary ramp — the classic streaming
    blind spot), and ``SpectrumObserver`` runs a Goertzel bank over the
    operator's spec lines ``f >= f_c`` as per-chunk second-order
    recurrences folded with exact integer bin-phase rotations (grid
    operators watch specific spectral lines continuously; see "Wide-Area
    Power System Oscillations from Large-Scale AI Workloads").
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import pytree_dataclass


@pytree_dataclass
class GridSpec:
    beta: jax.Array  # max ramp rate [fraction of rated power / s]
    alpha: jax.Array  # spectral cap above f_c
    f_c: jax.Array  # cutoff frequency [Hz]

    @staticmethod
    def create(beta: float = 0.1, alpha: float = 1e-4, f_c: float = 2.0) -> "GridSpec":
        f = lambda v: jnp.asarray(v, jnp.float32)
        return GridSpec(beta=f(beta), alpha=f(alpha), f_c=f(f_c))


def ramp_rate(power: jax.Array, dt: float) -> jax.Array:
    """dP/dt via forward differences; shape (T-1, ...)."""
    return jnp.diff(power, axis=0) / dt


def max_abs_ramp(power: jax.Array, dt: float) -> jax.Array:
    return jnp.max(jnp.abs(ramp_rate(power, dt)), axis=0)


def normalized_spectrum(
    power: jax.Array, dt: float, *, window: str | None = "hann"
) -> tuple[jax.Array, jax.Array]:
    """One-sided normalized magnitude spectrum.

    Returns (freqs [Hz], S) with S[0] ~= mean(power) and interior bins
    scaled so that a sinusoid of amplitude A (fraction of rated power)
    produces S = A at its frequency.

    A Hann window (coherent-gain corrected) is applied by default: grid
    operators estimate spectra over finite measurement windows, and an
    unwindowed DFT of a non-periodic trace leaks its end-discontinuity
    across all bins (~|p(T)-p(0)|/(pi*k)), which would mis-report broadband
    violations that no PSD estimate would show.  ``window=None`` gives the
    raw DFT.
    """
    n = power.shape[0]
    if window == "hann":
        w = 0.5 - 0.5 * jnp.cos(2.0 * jnp.pi * jnp.arange(n) / n)
    elif window is None:
        w = jnp.ones((n,), power.dtype)
    else:
        raise ValueError(f"unknown window {window!r}")
    coherent_gain = jnp.mean(w)
    wshape = (-1,) + (1,) * (power.ndim - 1)
    spec = jnp.abs(jnp.fft.rfft(power * w.reshape(wshape), axis=0)) / (n * coherent_gain)
    # Double interior bins (one-sided); DC and possible Nyquist stay single.
    scale = jnp.ones((spec.shape[0],), power.dtype) * 2.0
    scale = scale.at[0].set(1.0)
    if n % 2 == 0:
        scale = scale.at[-1].set(1.0)
    spec = spec * scale.reshape(wshape)
    freqs = jnp.fft.rfftfreq(n, d=dt)
    return freqs, spec


class ComplianceReport(NamedTuple):
    max_ramp: jax.Array
    ramp_ok: jax.Array
    worst_high_freq_mag: jax.Array
    spectrum_ok: jax.Array
    ok: jax.Array
    # Wide-area oscillation-mode verdicts (grid-region POI reports only;
    # trailing defaults keep every existing constructor and unpack site
    # working).  ``mode_mags``/``mode_ok`` are (B,) per-band arrays aligned
    # with the detector's band table; ``modes_ok`` is the all-bands verdict
    # and is already folded into ``ok`` when present.  None = not tracked.
    mode_mags: jax.Array | None = None
    mode_ok: jax.Array | None = None
    modes_ok: jax.Array | None = None


def with_mode_verdicts(
    report: ComplianceReport, mode_mags: jax.Array, mode_ok: jax.Array
) -> ComplianceReport:
    """Fold per-band oscillation-mode verdicts into a report.

    ``ok`` becomes the conjunction of the ramp, spectrum, and all-bands
    mode verdicts — a POI that rings a wide-area mode band is non-compliant
    even when its ramp and high-frequency lines pass.
    """
    modes_ok = jnp.all(mode_ok)
    return report._replace(
        mode_mags=mode_mags,
        mode_ok=mode_ok,
        modes_ok=modes_ok,
        ok=report.ok & modes_ok,
    )


def check(power: jax.Array, dt: float, spec: GridSpec) -> ComplianceReport:
    """Full compliance check of a normalized power trace (T,) or (T, racks)."""
    mr = max_abs_ramp(power, dt)
    ramp_ok = mr <= spec.beta

    freqs, s = normalized_spectrum(power, dt)
    above = freqs >= spec.f_c
    shape = (-1,) + (1,) * (power.ndim - 1)
    masked = jnp.where(above.reshape(shape), s, 0.0)
    worst = jnp.max(masked, axis=0)
    spectrum_ok = worst <= spec.alpha

    return ComplianceReport(
        max_ramp=mr,
        ramp_ok=ramp_ok,
        worst_high_freq_mag=worst,
        spectrum_ok=spectrum_ok,
        ok=ramp_ok & spectrum_ok,
    )


def violation_fraction(power: jax.Array, dt: float, spec: GridSpec) -> jax.Array:
    """Fraction of time steps whose local ramp exceeds beta (diagnostics)."""
    r = jnp.abs(ramp_rate(power, dt))
    return jnp.mean((r > spec.beta).astype(jnp.float32), axis=0)


# ------------------------------------------------------- streaming observers


class RampObserver(NamedTuple):
    """Cross-chunk running max-ramp: carries the last sample seen so the
    boundary difference between consecutive chunks is never dropped."""

    last: jax.Array  # last sample of the previous chunk
    n: jax.Array  # int32 samples seen
    max_ramp: jax.Array  # running max |dP/dt|


def ramp_observer_init(batch_shape: tuple[int, ...] = ()) -> RampObserver:
    return RampObserver(
        last=jnp.zeros(batch_shape, jnp.float32),
        n=jnp.zeros((), jnp.int32),
        max_ramp=jnp.zeros(batch_shape, jnp.float32),
    )


def ramp_observer_update(
    obs: RampObserver, chunk: jax.Array, dt: float
) -> RampObserver:
    """Fold one (T, ...) chunk.  The first chunk contributes T-1 diffs (the
    carried "previous sample" is seeded with the chunk's own first sample,
    adding an exact zero diff), every later chunk contributes T including
    the boundary — so the running max equals the whole-trace
    ``max_abs_ramp`` bit-for-bit.
    """
    prev = jnp.where(obs.n > 0, obs.last, chunk[0])
    ext = jnp.concatenate([prev[None], chunk], axis=0)
    mr = jnp.max(jnp.abs(jnp.diff(ext, axis=0)), axis=0) / dt
    return RampObserver(
        last=chunk[-1],
        n=obs.n + jnp.int32(chunk.shape[0]),
        max_ramp=jnp.maximum(obs.max_ramp, mr),
    )


@dataclasses.dataclass(frozen=True)
class SpectrumBank:
    """Static configuration of a Goertzel line bank (hashable: rides in jit
    closures and engine cache keys, not in the traced pytree).

    ``bins`` are integer line indices on a length-``modulus`` DFT grid:
    line frequency = ``bin / (modulus * dt)``.  For whole-trace-equivalent
    monitoring set ``modulus = n_total`` and ``window="hann"`` — every line
    is then a bin of the length-``n_total`` DFT and the finalized
    magnitudes match ``normalized_spectrum`` at those bins.  For open-ended
    online monitoring (total length unknown) use ``window=None`` with any
    modulus: lines are fixed operator frequencies and magnitudes normalize
    by the samples seen so far.
    """

    bins: tuple[int, ...]
    modulus: int
    dt: float
    window: str | None = "hann"

    @property
    def freqs(self) -> np.ndarray:
        return np.asarray(self.bins, np.float64) / (self.modulus * self.dt)


def spec_lines(
    n_total: int, dt: float, f_c: float, n_lines: int = 48
) -> tuple[int, ...]:
    """Log-spaced DFT bins of a length-``n_total`` trace covering
    [f_c, Nyquist] — the operator's monitored spec lines."""
    k_lo = max(int(np.ceil(f_c * n_total * dt)), 1)
    k_hi = n_total // 2
    if k_lo > k_hi:
        return ()
    ks = np.round(
        np.logspace(np.log10(k_lo), np.log10(max(k_hi, k_lo)), max(n_lines, 1))
    ).astype(np.int64)
    return tuple(int(k) for k in np.unique(ks))


def make_bank(
    n_total: int, dt: float, f_c: float, *, n_lines: int = 48
) -> SpectrumBank:
    """Whole-trace-equivalent bank: Hann window, lines on the trace's bins."""
    return SpectrumBank(
        bins=spec_lines(n_total, dt, f_c, n_lines),
        modulus=int(n_total),
        dt=float(dt),
        window="hann",
    )


def make_online_bank(
    dt: float, f_c: float, *, n_lines: int = 24, modulus: int = 1 << 15
) -> SpectrumBank:
    """Open-ended bank (total length unknown): rectangular window, lines on
    a fixed length-``modulus`` frequency grid."""
    return SpectrumBank(
        bins=spec_lines(modulus, dt, f_c, n_lines),
        modulus=int(modulus),
        dt=float(dt),
        window=None,
    )


class SpectrumObserver(NamedTuple):
    """Running Goertzel-bank state: complex line accumulators + the exact
    integer bin phase of the next sample (kept mod ``modulus`` so the
    cross-chunk rotation never loses precision, however long the stream)."""

    acc_re: jax.Array  # (L,)
    acc_im: jax.Array  # (L,)
    phase: jax.Array  # (L,) int32: (bin * samples_seen) mod modulus
    n: jax.Array  # int32 samples seen


def spectrum_observer_init(bank: SpectrumBank) -> SpectrumObserver:
    l = len(bank.bins)
    return SpectrumObserver(
        acc_re=jnp.zeros((l,), jnp.float32),
        acc_im=jnp.zeros((l,), jnp.float32),
        phase=jnp.zeros((l,), jnp.int32),
        n=jnp.zeros((), jnp.int32),
    )


def spectrum_observer_update(
    bank: SpectrumBank, obs: SpectrumObserver, chunk: jax.Array
) -> SpectrumObserver:
    """Fold one (T,) chunk: a local Goertzel recurrence per line (float32
    error stays bounded by the chunk length, not the stream length), then
    rotate the local DFT onto the absolute stream position with the exact
    integer bin phase carried in the state."""
    if not bank.bins:
        return SpectrumObserver(
            obs.acc_re, obs.acc_im, obs.phase,
            obs.n + jnp.int32(chunk.shape[0]),
        )
    m = chunk.shape[0]
    mod = bank.modulus
    bins = np.asarray(bank.bins, np.int64)
    omega = (2.0 * np.pi / mod) * bins.astype(np.float64)
    coeff = jnp.asarray(2.0 * np.cos(omega), jnp.float32)  # (L,)

    if bank.window == "hann":
        # Hann value at the *absolute* index: exact integer phase mod N.
        wp = jnp.mod(obs.n + jnp.arange(m, dtype=jnp.int32), mod)
        w = 0.5 - 0.5 * jnp.cos(
            wp.astype(jnp.float32) * jnp.float32(2.0 * np.pi / mod)
        )
        x = chunk * w
    elif bank.window is None:
        x = chunk
    else:
        raise ValueError(f"unknown window {bank.window!r}")

    def body(carry, xv):
        s1, s2 = carry
        s0 = xv + coeff * s1 - s2
        return (s0, s1), None

    zeros = jnp.zeros((len(bank.bins),), jnp.float32)
    (s1, s2), _ = jax.lax.scan(body, (zeros, zeros), x.astype(jnp.float32))

    # Local block DFT: X_b = (s_{M-1} - s_{M-2} e^{-iw}) e^{-iw(M-1)}.
    e_re = jnp.asarray(np.cos(omega), jnp.float32)
    e_im = jnp.asarray(-np.sin(omega), jnp.float32)
    xb_re = s1 - (s2 * e_re)
    xb_im = -(s2 * e_im)
    tail = np.exp(-1j * omega * (m - 1))
    t_re = jnp.asarray(tail.real, jnp.float32)
    t_im = jnp.asarray(tail.imag, jnp.float32)
    xb_re, xb_im = xb_re * t_re - xb_im * t_im, xb_re * t_im + xb_im * t_re

    # Rotate onto the absolute position: e^{-2pi i * phase / modulus} with
    # the exact integer phase carried in the observer.
    ang = obs.phase.astype(jnp.float32) * jnp.float32(2.0 * np.pi / mod)
    r_re, r_im = jnp.cos(ang), -jnp.sin(ang)
    acc_re = obs.acc_re + (xb_re * r_re - xb_im * r_im)
    acc_im = obs.acc_im + (xb_re * r_im + xb_im * r_re)

    # Advance the bin phase by m samples, exactly (int32 mod arithmetic:
    # both operands already < modulus, so the product path is avoided).
    adv = jnp.asarray((bins * (m % mod)) % mod, jnp.int32)
    phase = jnp.mod(obs.phase + adv, mod)
    return SpectrumObserver(
        acc_re=acc_re, acc_im=acc_im, phase=phase,
        n=obs.n + jnp.int32(m),
    )


def spectrum_observer_finalize(
    bank: SpectrumBank, obs: SpectrumObserver
) -> tuple[np.ndarray, jax.Array]:
    """(freqs [Hz], S) at the bank lines, normalized exactly like
    ``normalized_spectrum`` (coherent-gain corrected, one-sided doubling).
    Hann banks normalize by the configured total length; rectangular
    (online) banks by the samples seen so far."""
    if not bank.bins:
        return np.zeros((0,)), jnp.zeros((0,), jnp.float32)
    mag = jnp.sqrt(obs.acc_re**2 + obs.acc_im**2)
    if bank.window == "hann":
        n = bank.modulus
        w = 0.5 - 0.5 * jnp.cos(2.0 * jnp.pi * jnp.arange(n) / n)
        norm = n * jnp.mean(w)
    else:
        norm = jnp.maximum(obs.n.astype(jnp.float32), 1.0)
    bins = np.asarray(bank.bins, np.int64)
    # One-sided doubling, except DC and the Nyquist line (bin modulus/2 of
    # an even grid is its own conjugate — single-sided in any real DFT).
    nyq = bank.modulus % 2 == 0
    scale = np.where((bins > 0) & ~(nyq & (bins == bank.modulus // 2)), 2.0, 1.0)
    return bank.freqs, mag * jnp.asarray(scale, jnp.float32) / norm


def report_from_observers(
    spec: GridSpec,
    ramp: RampObserver,
    bank: SpectrumBank,
    sob: SpectrumObserver,
) -> ComplianceReport:
    """ComplianceReport from streaming state: the ramp bound is exact; the
    spectral bound is evaluated at the bank's monitored lines (all >= f_c
    by construction) rather than every DFT bin."""
    _, s = spectrum_observer_finalize(bank, sob)
    worst = jnp.max(s, initial=0.0)
    ramp_ok = ramp.max_ramp <= spec.beta
    spectrum_ok = worst <= spec.alpha
    return ComplianceReport(
        max_ramp=ramp.max_ramp,
        ramp_ok=ramp_ok,
        worst_high_freq_mag=worst,
        spectrum_ok=spectrum_ok,
        ok=ramp_ok & spectrum_ok,
    )
