"""Grid compliance checks (paper §3): ramp rate and frequency content.

The grid operator supplies a spec (beta, alpha, f_c):

  * |dP/dt| <= beta            for all t      (P normalized to rated power)
  * S(f)    <= alpha           for all f >= f_c

where S(f) is the one-sided normalized DFT magnitude of the power trace —
scaled so S(0) is the trace mean and each bin is interpretable as the
fraction of rated power oscillating at that frequency (paper Fig. 3b shows
S(1/22 Hz) ~= 0.1 for the testbench trace).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass


@pytree_dataclass
class GridSpec:
    beta: jax.Array  # max ramp rate [fraction of rated power / s]
    alpha: jax.Array  # spectral cap above f_c
    f_c: jax.Array  # cutoff frequency [Hz]

    @staticmethod
    def create(beta: float = 0.1, alpha: float = 1e-4, f_c: float = 2.0) -> "GridSpec":
        f = lambda v: jnp.asarray(v, jnp.float32)
        return GridSpec(beta=f(beta), alpha=f(alpha), f_c=f(f_c))


def ramp_rate(power: jax.Array, dt: float) -> jax.Array:
    """dP/dt via forward differences; shape (T-1, ...)."""
    return jnp.diff(power, axis=0) / dt


def max_abs_ramp(power: jax.Array, dt: float) -> jax.Array:
    return jnp.max(jnp.abs(ramp_rate(power, dt)), axis=0)


def normalized_spectrum(
    power: jax.Array, dt: float, *, window: str | None = "hann"
) -> tuple[jax.Array, jax.Array]:
    """One-sided normalized magnitude spectrum.

    Returns (freqs [Hz], S) with S[0] ~= mean(power) and interior bins
    scaled so that a sinusoid of amplitude A (fraction of rated power)
    produces S = A at its frequency.

    A Hann window (coherent-gain corrected) is applied by default: grid
    operators estimate spectra over finite measurement windows, and an
    unwindowed DFT of a non-periodic trace leaks its end-discontinuity
    across all bins (~|p(T)-p(0)|/(pi*k)), which would mis-report broadband
    violations that no PSD estimate would show.  ``window=None`` gives the
    raw DFT.
    """
    n = power.shape[0]
    if window == "hann":
        w = 0.5 - 0.5 * jnp.cos(2.0 * jnp.pi * jnp.arange(n) / n)
    elif window is None:
        w = jnp.ones((n,), power.dtype)
    else:
        raise ValueError(f"unknown window {window!r}")
    coherent_gain = jnp.mean(w)
    wshape = (-1,) + (1,) * (power.ndim - 1)
    spec = jnp.abs(jnp.fft.rfft(power * w.reshape(wshape), axis=0)) / (n * coherent_gain)
    # Double interior bins (one-sided); DC and possible Nyquist stay single.
    scale = jnp.ones((spec.shape[0],), power.dtype) * 2.0
    scale = scale.at[0].set(1.0)
    if n % 2 == 0:
        scale = scale.at[-1].set(1.0)
    spec = spec * scale.reshape(wshape)
    freqs = jnp.fft.rfftfreq(n, d=dt)
    return freqs, spec


class ComplianceReport(NamedTuple):
    max_ramp: jax.Array
    ramp_ok: jax.Array
    worst_high_freq_mag: jax.Array
    spectrum_ok: jax.Array
    ok: jax.Array


def check(power: jax.Array, dt: float, spec: GridSpec) -> ComplianceReport:
    """Full compliance check of a normalized power trace (T,) or (T, racks)."""
    mr = max_abs_ramp(power, dt)
    ramp_ok = mr <= spec.beta

    freqs, s = normalized_spectrum(power, dt)
    above = freqs >= spec.f_c
    shape = (-1,) + (1,) * (power.ndim - 1)
    masked = jnp.where(above.reshape(shape), s, 0.0)
    worst = jnp.max(masked, axis=0)
    spectrum_ok = worst <= spec.alpha

    return ComplianceReport(
        max_ramp=mr,
        ramp_ok=ramp_ok,
        worst_high_freq_mag=worst,
        spectrum_ok=spectrum_ok,
        ok=ramp_ok & spectrum_ok,
    )


def violation_fraction(power: jax.Array, dt: float, spec: GridSpec) -> jax.Array:
    """Fraction of time steps whose local ramp exceeds beta (diagnostics)."""
    r = jnp.abs(ramp_rate(power, dt))
    return jnp.mean((r > spec.beta).astype(jnp.float32), axis=0)
