"""Auxiliary energy storage system (paper §5.3, Appendix A.1).

The battery branch is controlled so that the battery current obeys

    d/dt i_B + beta * i_B + d/dt i_R = 0                       (paper Eq. 2)

Writing the grid-facing current g = i_R + i_B, this is equivalent to

    dg/dt = beta * (i_R - g),

a first-order low-pass of the rack current with time constant 1/beta and
cutoff f_b = beta / (2*pi).  Two properties follow immediately and are the
paper's central guarantees:

  * |dg/dt| <= beta * |i_R - g| <= beta * I_RATED: the grid never sees a
    ramp steeper than ``beta`` (as a fraction of rated power per second),
    even if the rack power steps instantaneously from rated to zero.
  * Above f_b, fluctuations are attenuated 10x per decade (-20 dB/dec).

We discretize the first-order system exactly (ZOH):

    g[t+1] = g[t] + (1 - exp(-beta*dt)) * (i_R[t] - g[t]).

State of charge integrates the battery current with asymmetric
charge/discharge efficiencies (eta_c, eta_d), saturating at the safe bounds.
When the battery saturates, the un-absorbed current passes straight through
to the grid — this "residual" is exactly what Appendix A.1's sizing bound
is designed to make impossible, and tests verify the bound.

All functions broadcast over leading "rack" dimensions so a fleet of racks
is simulated with one vectorized call (see ``core/fleet.py``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field


@pytree_dataclass
class ESSParams:
    """Battery + control parameters (normalized to rated rack power).

    Currents/powers are expressed as fractions of rated rack power (the
    DC-DC stage holds the bus voltage constant, so current and power are
    proportional, paper Eq. 1).
    """

    beta: jax.Array  # grid ramp limit [1/s] (fraction of rated power per s)
    q_max: jax.Array  # usable energy capacity [s] (energy / P_RATED)
    eta_c: jax.Array  # charge efficiency in (0, 1]
    eta_d: jax.Array  # discharge efficiency in (0, 1]
    p_max: jax.Array  # max |battery power| as fraction of rated power
    soc_safe_min: jax.Array
    soc_safe_max: jax.Array

    @staticmethod
    def create(
        beta: float = 0.1,
        q_max_seconds: float = 60.0,
        eta_c: float = 0.97,
        eta_d: float = 0.97,
        p_max: float = 1.0,
        soc_safe_min: float = 0.1,
        soc_safe_max: float = 0.9,
    ) -> "ESSParams":
        f = lambda v: jnp.asarray(v, jnp.float32)
        return ESSParams(
            beta=f(beta),
            q_max=f(q_max_seconds),
            eta_c=f(eta_c),
            eta_d=f(eta_d),
            p_max=f(p_max),
            soc_safe_min=f(soc_safe_min),
            soc_safe_max=f(soc_safe_max),
        )

    def cutoff_hz(self) -> jax.Array:
        return self.beta / (2.0 * jnp.pi)


class ESSState(NamedTuple):
    g_filter: jax.Array  # first-order filter state tracking rack power
    soc: jax.Array  # state of charge in [0, 1]


def init_state(p: ESSParams, rack_power0: jax.Array, soc0: float | jax.Array = 0.5) -> ESSState:
    return ESSState(
        g_filter=jnp.broadcast_to(jnp.asarray(rack_power0, jnp.float32), jnp.shape(rack_power0)),
        soc=jnp.broadcast_to(jnp.asarray(soc0, jnp.float32), jnp.shape(rack_power0)),
    )


def soc_increment(p: ESSParams, battery_power: jax.Array, dt: float) -> jax.Array:
    """SoC change for one step of (signed) battery power.

    battery_power > 0 means charging.  Charging stores eta_c of the energy;
    discharging removes 1/eta_d per unit delivered (paper Eq. 14).
    """
    charge = jnp.maximum(battery_power, 0.0)
    discharge = jnp.maximum(-battery_power, 0.0)
    return (dt / p.q_max) * (p.eta_c * charge - discharge / p.eta_d)


def battery_power_from_soc_delta(
    p: ESSParams, d_soc: jax.Array, dt: float
) -> jax.Array:
    """Inverse of ``soc_increment``: the (signed, terminal-side) battery
    power implied by an observed per-sample SoC step.

    The sign of the step selects the efficiency branch, so the inversion is
    exact for any post-saturation SoC trajectory: a BMS that only sees SoC
    can still coulomb-count terminal throughput (``core.health`` uses this
    for the Ah-throughput accumulator)."""
    q = d_soc * (p.q_max / dt)
    return jnp.where(d_soc > 0, q / p.eta_c, q * p.eta_d)


def step(
    p: ESSParams,
    state: ESSState,
    rack_power: jax.Array,
    dt: float,
    corrective_power: jax.Array | float = 0.0,
    online: jax.Array | None = None,
) -> tuple[ESSState, jax.Array]:
    """Advance one sample: returns (new_state, grid_power_out).

    ``corrective_power`` is the (milliamp-scale) SoC-maintenance command from
    the software controller; positive = extra charging.  Crucially it
    commands *battery current directly* — it does NOT enter the ramp-filter
    state, so even a wildly wrong software command perturbs the grid by at
    most its own (tiny) magnitude, reproducing the paper's fault-isolation
    claim ("the controller cannot interfere with the hardware's filtering
    even if it issues an incorrect command").
    Saturation: if the battery cannot absorb/supply (SoC at a safe bound or
    power beyond p_max), the excess passes through to the grid.

    ``online`` is a per-unit ESS availability *weight* in [0, 1] (degraded
    mode): weight 0 passes the raw rack power straight to the grid
    (p_batt = 0, SoC frozen) while the ramp filter keeps tracking the
    rack so a recovering unit re-engages softly from g = rack_power;
    fractional weights scale the delivered battery power (converter
    wind-down/soft-start around a trip) with the SoC integrating the
    scaled power.  ``online=None`` (or all ones) is bitwise identical to
    the unmasked path, binary weights are bitwise identical to the legacy
    boolean-mask semantics, and all of it matches the fused kernel
    (``kernels.ref.pdu_sim`` with ``ess_on``) exactly.
    """
    w = online
    alpha = 1.0 - jnp.exp(-p.beta * dt)
    g_new = state.g_filter + alpha * (rack_power - state.g_filter)
    if w is not None:
        g_new = jnp.where(w > 0, g_new, rack_power)

    # Battery power implied by the control law (+corrective charge).
    p_batt = g_new - rack_power + corrective_power
    # Power rating limit (paper Eq. 9 sizing makes this inactive if sized right).
    p_batt = jnp.clip(p_batt, -p.p_max, p.p_max)
    if w is not None:
        # Converter wind-down: deliver the weighted fraction (w = 1 is an
        # exact multiply; w = 0 reproduces the hard passthrough bitwise).
        p_batt = p_batt * w

    # Energy limit: can't charge past soc_safe_max or discharge below min.
    d_soc = soc_increment(p, p_batt, dt)
    new_soc = state.soc + d_soc
    overshoot_hi = jnp.maximum(new_soc - p.soc_safe_max, 0.0)
    overshoot_lo = jnp.maximum(p.soc_safe_min - new_soc, 0.0)
    # Convert SoC overshoot back to un-absorbable power and shed it.
    shed_charge = overshoot_hi * p.q_max / (p.eta_c * dt)
    shed_discharge = overshoot_lo * p.q_max * p.eta_d / dt
    p_batt = p_batt - shed_charge + shed_discharge
    new_soc = jnp.clip(new_soc, p.soc_safe_min, p.soc_safe_max)
    if w is not None:
        new_soc = jnp.where(w > 0, new_soc, state.soc)

    grid_power = rack_power + p_batt
    return ESSState(g_filter=g_new, soc=new_soc), grid_power


def simulate(
    p: ESSParams,
    state: ESSState,
    rack_power: jax.Array,  # (T, ...) fraction of rated power
    dt: float,
    corrective_power: jax.Array | float = 0.0,  # scalar or (T, ...)
    online: jax.Array | None = None,  # (...) or (T, ...) availability weight
) -> tuple[jax.Array, jax.Array, ESSState]:
    """Vectorized trace simulation.

    ``online`` accepts a constant ``(...)`` weight or a per-sample
    ``(T, ...)`` weight series (see ``step``).
    Returns (grid_power (T, ...), soc (T, ...), final_state).
    """
    corr = jnp.broadcast_to(jnp.asarray(corrective_power, jnp.float32), rack_power.shape)
    per_sample = online is not None and jnp.ndim(online) == rack_power.ndim

    def body(s, inputs):
        if per_sample:
            r_t, c_t, w_t = inputs
            s2, g = step(p, s, r_t, dt, c_t, online=w_t)
        else:
            r_t, c_t = inputs
            s2, g = step(p, s, r_t, dt, c_t, online=online)
        return s2, (g, s2.soc)

    xs = (rack_power, corr, online) if per_sample else (rack_power, corr)
    final, (g, soc) = jax.lax.scan(body, state, xs)
    return g, soc, final


def transfer_function(p: ESSParams, f_hz: jax.Array) -> jax.Array:
    """|H(j2πf)| of the ESS stage: first-order low-pass at f_b = beta/2π."""
    s = 2j * jnp.pi * f_hz
    return jnp.abs(p.beta / (s + p.beta))


def worst_case_energy_swing(p: ESSParams, epsilon: jax.Array | float) -> jax.Array:
    """Appendix A.1 Eq. 7: |ΔE_B| <= (ε/β) · P_RATED, in seconds·P_RATED."""
    return jnp.asarray(epsilon) / p.beta


def required_capacity_seconds(
    beta: float, epsilon: float, gamma: float
) -> float:
    """Appendix A.1 Eq. 8: E_B >= ε/(γβ) · P_RATED (normalized: seconds)."""
    return epsilon / (gamma * beta)


def required_power_fraction(epsilon: float) -> float:
    """Appendix A.1 Eq. 9: P_B >= ε · P_RATED."""
    return epsilon
