"""EasyRider core: the paper's contribution as composable JAX modules.

  filters     — passive LC input filter + damping leg (§5.1)
  ess         — battery ESS ramp-ODE control + SoC dynamics (§5.3, App. A)
  controller  — outer/inner SoC management loops (§6, App. B)
  compliance  — grid ramp-rate + frequency-content checks (§3),
                streaming ramp/Goertzel observers
  health      — online battery wear: half-cycle counting + aging (§2, §6)
  sizing      — component sizing from grid spec (App. A.1)
  burn        — software GPU-burn baseline (§7.3, App. C)
  pdu         — the composed EasyRider PDU, streaming conditioner (§4)
  fleet       — campus-scale aggregation (App. D), the ``condition`` facade
  grid        — grid-region scale-out: POI aggregation, swing coupling,
                wide-area mode detection, shard_map region engine
"""
from repro.core import (
    burn, compliance, controller, ess, filters, fleet, grid, health, pdu,
    sizing,
)

__all__ = [
    "burn", "compliance", "controller", "ess", "filters", "fleet", "grid",
    "health", "pdu", "sizing",
]
