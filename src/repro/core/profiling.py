"""Host-side phase profiling for the conditioning engines.

``benchmarks/run.py --profile`` needs a per-bench phase breakdown
(render / solve / kernel / host-sync) without dragging in the TensorBoard
profile toolchain: this module keeps a process-global span accumulator the
engine host loops annotate.  Spans are no-ops unless ``enable()`` was
called, so the instrumented sites cost nothing in normal runs; when
enabled, each span also opens a ``jax.profiler.TraceAnnotation`` so a full
``jax.profiler.trace`` capture (for deep dives) carries the same phase
names on its host timeline.

Measurement model: JAX dispatch is asynchronous, so a wall-clock span
around a jitted call measures dispatch, not execution.  ``span(name)``
therefore blocks on the value returned from its body (``sync=...``) before
closing the clock — profiling deliberately serializes the phases it
measures.  That makes the phase *sum* close to (slightly above) the
unprofiled wall clock, which is the right tradeoff for attribution.

Only the phases that exist as host-visible stages can be timed this way:
the streaming host engine renders chunks, dispatches the conditioning
step, and assembles results on the host, so it is the engine ``--profile``
re-runs.  Inside the step, the controller solve and the hardware megakernel
fuse into one program; their split is estimated separately (see
``benchmarks/run.py``) by timing one eagerly-executed kernel interval.
"""
from __future__ import annotations

import contextlib
import time

import jax

_ENABLED = False
_PHASES: dict[str, float] = {}


def enable() -> None:
    """Turn spans on and clear any accumulated phase times."""
    global _ENABLED
    _ENABLED = True
    _PHASES.clear()


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def phases() -> dict[str, float]:
    """Accumulated seconds per phase since ``enable()``."""
    return dict(_PHASES)


@contextlib.contextmanager
def span(name: str):
    """Accumulate wall time under ``name`` (no-op unless enabled).

    The body may hand back a value to block on before the clock closes::

        with profiling.span("solve") as sync:
            out = step(...)
            sync(out)
    """
    if not _ENABLED:
        yield lambda x: x
        return
    blocked = []

    def sync(x):
        blocked.append(True)
        return jax.block_until_ready(x)

    t0 = time.perf_counter()
    with jax.profiler.TraceAnnotation(f"repro.{name}"):
        try:
            yield sync
        finally:
            _PHASES[name] = _PHASES.get(name, 0.0) + (time.perf_counter() - t0)
