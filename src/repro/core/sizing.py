"""Component sizing from grid spec + rack rating (paper Appendix A.1).

Given the grid spec (beta, alpha, f_c) and the rack's rated power and
peak-to-idle swing epsilon = (P_RATED - P_MIN)/P_RATED, this module derives:

  * minimum battery capacity      E_B >= eps/(gamma*beta) * P_RATED  (Eq. 8)
  * minimum battery power rating  P_B >= eps * P_RATED               (Eq. 9)
  * LC values for a target filter cutoff f_f = 1/(2*pi*sqrt(LC))     (Eq. 10)
  * an R-L damping leg sized to bound the resonant peak.

It also computes the filter cutoff needed to push a workload's residual
spectrum under alpha: the ESS stage attenuates by (f_b/f) above
f_b = beta/2pi (-20 dB/dec) and the LC stage by (f_f/f)^2 above f_f
(-40 dB/dec); their product must map the worst-case rack magnitude at every
f >= f_c below alpha.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.filters import LCFilterParams


@dataclasses.dataclass(frozen=True)
class RackRating:
    p_rated_w: float  # rack TDP [W]
    p_min_w: float  # minimum rack power [W]
    v_dc: float = 400.0  # bus voltage [V]

    @property
    def epsilon(self) -> float:
        """Maximum power swing as a fraction of rated power (Eq. 5)."""
        return (self.p_rated_w - self.p_min_w) / self.p_rated_w

    @property
    def i_rated(self) -> float:
        return self.p_rated_w / self.v_dc


@dataclasses.dataclass(frozen=True)
class SizingResult:
    battery_energy_j: float  # Eq. 8 (with usable-window derating gamma)
    battery_power_w: float  # Eq. 9
    battery_capacity_ah: float  # at v_dc
    l_f: float
    c_f: float
    r_da: float
    l_da: float
    f_f_hz: float
    f_b_hz: float


def lc_from_cutoff(f_f_hz: float, z0_ohm: float) -> tuple[float, float]:
    """L, C with cutoff f_f and characteristic impedance Z0 = sqrt(L/C)."""
    w = 2.0 * math.pi * f_f_hz
    l = z0_ohm / w
    c = 1.0 / (w * z0_ohm)
    return l, c


def damping_leg(l_f: float, c_f: float, n: float = 0.5) -> tuple[float, float]:
    """R-L damping leg in parallel with L_F (Erickson Rf-Lb damping).

    L_da = n * L_f; R is chosen by direct numerical minimization of the
    worst-case transfer-function peak (robust to formula-misremembering —
    the resulting peak is asserted in tests).  Smaller n damps better but
    shifts the high-frequency asymptote from L_f to L_f*n/(1+n); n = 0.5
    gives a ~6 dB-max peak while keeping the -40 dB/dec rolloff within
    a factor ~3 of f_f.
    """
    import numpy as np

    z0 = math.sqrt(l_f / c_f)
    l_da = n * l_f
    f0 = 1.0 / (2.0 * math.pi * math.sqrt(l_f * c_f))
    f = np.logspace(math.log10(f0 / 30.0), math.log10(f0 * 30.0), 1200)
    s = 2j * np.pi * f

    def peak(r: float) -> float:
        z_c = 1.0 / (s * c_f)
        z_lf = s * l_f
        z_d = r + s * l_da
        z_series = z_lf * z_d / (z_lf + z_d)
        return float(np.max(np.abs(z_c / (z_c + z_series))))

    rs = z0 * np.logspace(-2.0, 2.0, 160)
    peaks = np.array([peak(r) for r in rs])
    r_best = float(rs[int(np.argmin(peaks))])
    return r_best, l_da


def size_system(
    rack: RackRating,
    beta: float,
    f_f_hz: float = 4.0,
    gamma: float = 0.5,
    z0_ohm: float | None = None,
) -> SizingResult:
    """Full Appendix A.1 sizing for a rack and ramp limit beta."""
    eps = rack.epsilon
    e_b = eps / (gamma * beta) * rack.p_rated_w  # joules
    p_b = eps * rack.p_rated_w
    ah = e_b / (rack.v_dc * 3600.0)
    if z0_ohm is None:
        # Characteristic impedance a fraction of the load impedance keeps the
        # filter stiff under load steps; 1/4 of R_load is a common choice.
        r_load = rack.v_dc**2 / rack.p_rated_w
        z0_ohm = r_load / 4.0
    l_f, c_f = lc_from_cutoff(f_f_hz, z0_ohm)
    r_da, l_da = damping_leg(l_f, c_f)
    return SizingResult(
        battery_energy_j=e_b,
        battery_power_w=p_b,
        battery_capacity_ah=ah,
        l_f=l_f,
        c_f=c_f,
        r_da=r_da,
        l_da=l_da,
        f_f_hz=f_f_hz,
        f_b_hz=beta / (2.0 * math.pi),
    )


def filter_cutoff_for_workload(
    rack_spectrum: "tuple",  # (freqs_hz ndarray, magnitudes ndarray)
    beta: float,
    alpha: float,
    f_c: float,
    *,
    peak_margin: float = 2.0,
    safety: float = 2.0,
    f_min: float = 0.2,
    f_max: float = 50.0,
) -> float:
    """Workload-informed LC cutoff (Appendix A.1: "the cutoff frequency is
    chosen such that the grid power harmonic content is acceptable").

    The ESS contributes |H_ess(f)| = f_b/f above f_b = beta/2pi; the LC
    contributes ~(f_f/f)^2 above f_f (with up to ``peak_margin`` of
    resonant magnification near f_f).  We return the largest f_f such that
    every rack spectral line at f >= f_c lands below alpha after both
    stages — larger f_f means smaller (cheaper) passives, so we take the
    max feasible.
    """
    import numpy as np

    freqs, mags = rack_spectrum
    freqs = np.asarray(freqs, np.float64)
    mags = np.asarray(mags, np.float64)
    sel = freqs >= f_c
    freqs, mags = freqs[sel], mags[sel]
    if freqs.size == 0:
        return f_max
    f_b = beta / (2.0 * math.pi)
    h_ess = np.minimum(f_b / freqs, 1.0)

    candidates = np.logspace(math.log10(f_min), math.log10(f_max), 400)
    feasible = f_min
    for f_f in candidates:
        h_lc = np.minimum((f_f / freqs) ** 2, 1.0) * peak_margin
        h_lc = np.minimum(h_lc, peak_margin)
        if np.all(mags * h_ess * np.minimum(h_lc, 1.0 * peak_margin) <= alpha / safety):
            feasible = float(f_f)
    return feasible


def prototype_rack() -> RackRating:
    """The paper's 10 kW, 400 V_DC prototype (§7.1)."""
    return RackRating(p_rated_w=10_000.0, p_min_w=2_000.0, v_dc=400.0)


def mw_rack() -> RackRating:
    """A 1 MW future rack (OCP Mt. Diablo regime, §2.3) with an 80% swing."""
    return RackRating(p_rated_w=1_000_000.0, p_min_w=200_000.0, v_dc=400.0)


def prototype_filter(f_f_hz: float = 4.0) -> LCFilterParams:
    rack = prototype_rack()
    s = size_system(rack, beta=0.1, f_f_hz=f_f_hz)
    return LCFilterParams.create(l_f=s.l_f, c_f=s.c_f, r_da=s.r_da, l_da=s.l_da)
