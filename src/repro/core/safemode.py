"""Supervisory safe-mode control plane (ISSUE 9).

The conditioner sits between training racks and grid protection equipment,
so *its own* failures are grid-safety events: a diverged QP or a
NaN-corrupted SoC leaf applies a garbage battery command at exactly the
moment transients are worst.  This module is the per-rack supervisor that
detects, contains, and recovers from those internal failures, entirely
in-jit (it rides the conditioning interval scan):

    NORMAL ──(ADMM residual over threshold for ``trip_intervals``)──▶ PASSTHROUGH
    NORMAL / PASSTHROUGH ──(non-finite state leaf)──▶ QUARANTINE
    PASSTHROUGH / QUARANTINE ──(``readmit_intervals`` clean probes)──▶ NORMAL

Two watchdogs drive the transitions:

* **ADMM divergence watchdog** — the per-rack QP primal residual is
  compared against ``resid_threshold`` every control interval; a rack over
  threshold for ``trip_intervals`` *consecutive* intervals trips to
  PASSTHROUGH: its corrective command is zeroed and its warm-started ADMM
  iterates are reset through the same software-admission plane degraded
  mode uses (``ess_online``).  The *autonomous* hardware ramp filter
  stays engaged — it needs no solver, and parking a healthy battery
  would expose raw training bursts (5% of racks unconditioned already
  breaks the campus ramp limit), i.e. hard LC passthrough on a software
  fault injects the very transient the conditioner exists to prevent.
  A non-finite residual counts as over threshold (NaN compares false
  against any threshold, which is exactly how a diverged solver would
  otherwise hide from the watchdog).
* **State-corruption sanitizer** — a non-finite leaf anywhere in a rack's
  carried state (SoC, LC filter state, warm iterates, command slew pair,
  health carries) quarantines the rack: its state slice is reinitialized
  to a clean steady state and the event is counted.  Detection runs at the
  *start* of each interval, so corruption injected between windows (or
  produced by the previous interval) never reaches the hardware path.
  QUARANTINE is the only mode that drops the hardware plane to LC
  passthrough (via the degraded-mode ``ess_on`` weight, with converter
  wind-down/soft-start so the transition never steps the waveform):
  a rack whose SoC/filter tracking went non-finite cannot be trusted to
  run its converter until the reinitialized state survives the
  hysteresis window.

Re-admission is hysteretic: a tripped rack keeps *probing* — its QP still
solves every interval (cold-started; the warm reset makes the probe
deterministic) while its command stays zeroed — and only after
``readmit_intervals`` consecutive clean probes does it return to NORMAL.

Everything is per-rack and vectorized; ``SafeModeState`` rides in
``PDUState`` so chunked/resumed streams supervise identically to one-shot
runs.  With ``PDUConfig.safemode=False`` none of this executes and the
engines are bitwise identical to the unsupervised build.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field

# Mode encoding (int32 per rack).  Order matters: higher = more contained.
NORMAL = 0
PASSTHROUGH = 1  # divergence trip: LC passthrough, command zeroed, probing
QUARANTINE = 2  # state corruption: slice reinitialized, LC passthrough


@pytree_dataclass
class SafeModeConfig:
    """Watchdog knobs.  ``resid_threshold`` is in the units of the QP
    primal residual (the warm-started plan path converges to ~5e-3 on the
    acceptance campus; the default trips at 10x that).  The interval
    counts are static so the state machine compiles into the scan."""

    resid_threshold: jax.Array
    trip_intervals: int = static_field(default=3)
    readmit_intervals: int = static_field(default=8)

    @staticmethod
    def create(
        resid_threshold: float = 0.05,
        trip_intervals: int = 3,
        readmit_intervals: int = 8,
    ) -> "SafeModeConfig":
        if trip_intervals < 1:
            raise ValueError(f"trip_intervals must be >= 1, got {trip_intervals}")
        if readmit_intervals < 1:
            raise ValueError(
                f"readmit_intervals must be >= 1, got {readmit_intervals}")
        return SafeModeConfig(
            resid_threshold=jnp.asarray(resid_threshold, jnp.float32),
            trip_intervals=int(trip_intervals),
            readmit_intervals=int(readmit_intervals),
        )


class SafeModeState(NamedTuple):
    """Per-rack supervisor state carried across intervals/chunks/resumes.

    Counter/streak leaves are int32 with the rack batch shape.
    ``worst_streak`` is telemetry (the longest over-threshold residual run
    ever observed); the three counters are monotone event totals an
    operator can diff across windows to detect entries/exits.
    ``hw_weight`` is the float32 ESS availability weight the hardware
    plane actually applied at the end of the last interval — the engine
    slews it linearly across each interval toward the supervisor's gate
    (converter wind-down on containment, soft-start on re-admission), so
    a rack entering or leaving LC passthrough never steps the node
    waveform from the smoothed setpoint to raw rack power in one sample.
    """

    mode: jax.Array  # NORMAL / PASSTHROUGH / QUARANTINE
    resid_streak: jax.Array  # consecutive over-threshold intervals
    clean_streak: jax.Array  # consecutive clean probes while tripped
    worst_streak: jax.Array  # max resid_streak ever seen (telemetry)
    passthrough_entries: jax.Array  # divergence trips (total)
    quarantine_entries: jax.Array  # corruption events (total)
    readmissions: jax.Array  # re-admissions to NORMAL (total)
    hw_weight: jax.Array  # f32 applied ESS weight (wind-down / soft-start)


def init_state(batch_shape: tuple[int, ...] = ()) -> SafeModeState:
    # Distinct buffers per leaf: donated engines reject the same array
    # appearing twice in one argument list.
    return SafeModeState(
        *(jnp.zeros(batch_shape, jnp.int32) for _ in range(7)),
        jnp.ones(batch_shape, jnp.float32),
    )


def gate(st: SafeModeState) -> jax.Array:
    """1.0 where the rack may command its battery (NORMAL), else 0.0 —
    the software-admission multiplier (same semantics as degraded-mode
    ``ess_online``).  The hardware plane gates separately: only
    QUARANTINE winds the converter down to LC passthrough; PASSTHROUGH
    keeps the autonomous ramp filter smoothing under a zeroed command."""
    return (st.mode == NORMAL).astype(jnp.float32)


def quarantine(st: SafeModeState, corrupt: jax.Array) -> SafeModeState:
    """Mode update for racks whose carried state went non-finite.

    Every corruption event is counted (a rack corrupted again while
    already quarantined re-counts: each event is a distinct reinit), the
    rack's streaks reset, and the mode latches to QUARANTINE.  The caller
    is responsible for actually reinitializing the state slice.
    """
    corrupt = corrupt.astype(bool)
    zero = jnp.zeros_like(st.resid_streak)
    return st._replace(
        mode=jnp.where(corrupt, QUARANTINE, st.mode).astype(jnp.int32),
        resid_streak=jnp.where(corrupt, zero, st.resid_streak),
        clean_streak=jnp.where(corrupt, zero, st.clean_streak),
        quarantine_entries=st.quarantine_entries + corrupt.astype(jnp.int32),
    )


def residual_update(
    cfg: SafeModeConfig, st: SafeModeState, resid: jax.Array
) -> SafeModeState:
    """Watchdog fold after the interval's QP solve.

    ``resid`` is the raw per-rack primal residual — *unmasked* by safe
    mode, so tripped racks keep probing (degraded-mode ESS-offline racks
    arrive pre-masked to zero, which is correct: an offline rack is the
    availability plane's problem, not a solver failure).  Non-finite
    residuals count as over threshold.  Trips happen strictly from
    NORMAL; re-admission requires ``readmit_intervals`` consecutive clean
    probes from either contained mode.
    """
    bad = (resid > cfg.resid_threshold) | ~jnp.isfinite(resid)
    streak = jnp.where(bad, st.resid_streak + 1, 0)
    worst = jnp.maximum(st.worst_streak, streak)
    trip = (st.mode == NORMAL) & (streak >= cfg.trip_intervals)
    mode = jnp.where(trip, PASSTHROUGH, st.mode)
    tripped = mode != NORMAL
    clean = jnp.where(tripped & ~bad, st.clean_streak + 1, 0)
    readmit = tripped & (clean >= cfg.readmit_intervals)
    mode = jnp.where(readmit, NORMAL, mode)
    return st._replace(
        mode=mode.astype(jnp.int32),
        resid_streak=streak.astype(jnp.int32),
        clean_streak=jnp.where(readmit, 0, clean).astype(jnp.int32),
        worst_streak=worst.astype(jnp.int32),
        passthrough_entries=st.passthrough_entries + trip.astype(jnp.int32),
        readmissions=st.readmissions + readmit.astype(jnp.int32),
    )


def chunk_snapshot(st: SafeModeState) -> jax.Array:
    """(6,) float32 campus aggregate at a chunk boundary:
    [frac_normal, n_passthrough, n_quarantined, entries_total,
    readmissions_total, worst_resid_streak] — the supervisor telemetry a
    campus operator would chart next to ``ess_online_frac``."""
    f = jnp.float32
    return jnp.stack([
        jnp.mean((st.mode == NORMAL).astype(f)),
        jnp.sum((st.mode == PASSTHROUGH).astype(f)),
        jnp.sum((st.mode == QUARANTINE).astype(f)),
        jnp.sum(st.passthrough_entries + st.quarantine_entries).astype(f),
        jnp.sum(st.readmissions).astype(f),
        jnp.max(st.worst_streak).astype(f),
    ])


def summary(st: SafeModeState) -> dict:
    """JSON-safe host-side summary of one fleet's supervisor state."""
    import numpy as np

    mode = np.asarray(st.mode)
    return dict(
        n_normal=int(np.sum(mode == NORMAL)),
        n_passthrough=int(np.sum(mode == PASSTHROUGH)),
        n_quarantined=int(np.sum(mode == QUARANTINE)),
        passthrough_racks=[int(i) for i in np.flatnonzero(mode == PASSTHROUGH)],
        quarantined_racks=[int(i) for i in np.flatnonzero(mode == QUARANTINE)],
        passthrough_entries=int(np.sum(np.asarray(st.passthrough_entries))),
        quarantine_entries=int(np.sum(np.asarray(st.quarantine_entries))),
        readmissions=int(np.sum(np.asarray(st.readmissions))),
        worst_resid_streak=int(np.max(np.asarray(st.worst_streak))),
    )
