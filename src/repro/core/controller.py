"""Battery lifetime management controller (paper §6, Appendix B).

Two loops:

  * **Outer loop** (minutes; on regime change): selects the SoC target S*.
    Active mode tracks S_mid; storage mode (long idle windows) drops toward
    S_idle, subject to the usable-idle-budget rule: as the idle window
    elapses, the reachable SoC reduction shrinks and the target rises back
    toward S_mid automatically (paper §6 "Outer Loop").

  * **Inner loop** (every 5 s): a receding-horizon convex program (paper
    Eq. 13-17) over H intervals.  We split the corrective current
    i_k = c_k - d_k with c_k, d_k >= 0 so the efficiency-asymmetric SoC
    dynamics (Eq. 14) become linear, yielding a standard box/inequality
    constrained QP.  We solve it with a fixed-iteration OSQP-style ADMM
    written entirely in ``jax.lax`` — jittable, vmappable across racks,
    and ~microseconds per solve (the paper budget is 10 ms on a Pi 5).

The controller command is *power-normalized* like everything else in
``repro.core``: currents are fractions of rated rack power (the DC bus
voltage is regulated constant).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ess import ESSParams
from repro.kernels import ops
from repro.utils import pytree_dataclass, static_field


# --------------------------------------------------------------------------
# Generic small-QP ADMM solver:  min 1/2 x'Px + q'x  s.t.  l <= Ax <= u
# --------------------------------------------------------------------------


class QPSolution(NamedTuple):
    x: jax.Array
    primal_residual: jax.Array
    dual_residual: jax.Array


def solve_qp_admm(
    p_mat: jax.Array,
    q: jax.Array,
    a_mat: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    rho: float = 1.0,
    sigma: float = 1e-6,
    iters: int = 250,
) -> QPSolution:
    """OSQP-style ADMM with a pre-factorized KKT system.

    Small dense problems only (n, m ~ tens): we Cholesky-factor
    (P + sigma*I + rho*A'A) once and iterate a fixed number of steps so the
    whole solve is a single XLA loop with no data-dependent control flow.
    """
    n = q.shape[0]
    kkt = p_mat + sigma * jnp.eye(n) + rho * (a_mat.T @ a_mat)
    chol = jax.scipy.linalg.cho_factor(kkt)

    def body(carry, _):
        x, z, y = carry
        rhs = sigma * x - q + a_mat.T @ (rho * z - y)
        x_new = jax.scipy.linalg.cho_solve(chol, rhs)
        ax = a_mat @ x_new
        z_new = jnp.clip(ax + y / rho, lo, hi)
        y_new = y + rho * (ax - z_new)
        return (x_new, z_new, y_new), None

    x0 = jnp.zeros_like(q)
    z0 = jnp.clip(a_mat @ x0, lo, hi)
    y0 = jnp.zeros_like(z0)
    (x, z, y), _ = jax.lax.scan(body, (x0, z0, y0), None, length=iters)
    ax = a_mat @ x
    primal = jnp.max(jnp.abs(ax - jnp.clip(ax, lo, hi)))
    dual = jnp.max(jnp.abs(p_mat @ x + q + a_mat.T @ y))
    return QPSolution(x=x, primal_residual=primal, dual_residual=dual)


# --------------------------------------------------------------------------
# Controller configuration
# --------------------------------------------------------------------------


@pytree_dataclass
class ControllerConfig:
    # Outer loop policy.
    s_mid: jax.Array  # mid-band target during training
    s_idle: jax.Array  # storage-mode target during long idle
    t_enter: jax.Array  # [s] minimum predicted idle to enter storage mode
    delta_s_min: jax.Array  # minimum useful SoC shift to bother
    delta_s_max: jax.Array  # max allowed downward shift
    # Inner loop.
    horizon: int = static_field(default=12)
    dt: jax.Array = None  # control interval [s], default 5 s
    i_max: jax.Array = None  # max corrective current (fraction of rated power)
    deadband: jax.Array = None  # epsilon: |S - S*| below which current = 0
    lam_i: jax.Array = None  # maintenance-current magnitude weight
    lam_delta: jax.Array = None  # command smoothness weight
    lam_term: jax.Array = None  # terminal tracking weight
    meas_tau: jax.Array = None  # BMS SoC measurement EMA time constant [s]
    # Health-aware outer loop: scales the storage-mode excursion with the
    # battery's consumed cycle life (0.0 = off, bit-identical to the
    # wear-blind policy).
    wear_gain: jax.Array = None

    @staticmethod
    def create(
        s_mid: float = 0.5,
        s_idle: float = 0.3,
        t_enter: float = 1800.0,
        delta_s_min: float = 0.05,
        delta_s_max: float = 0.25,
        horizon: int = 12,
        dt: float = 5.0,
        i_max: float = 5e-3,
        deadband: float = 5e-3,
        lam_i: float = 1e-2,
        lam_delta: float = 1e-1,
        lam_term: float = 4.0,
        meas_tau: float = 60.0,
        wear_gain: float = 0.0,
    ) -> "ControllerConfig":
        f = lambda v: jnp.asarray(v, jnp.float32)
        return ControllerConfig(
            s_mid=f(s_mid),
            s_idle=f(s_idle),
            t_enter=f(t_enter),
            delta_s_min=f(delta_s_min),
            delta_s_max=f(delta_s_max),
            horizon=int(horizon),
            dt=f(dt),
            i_max=f(i_max),
            deadband=f(deadband),
            lam_i=f(lam_i),
            lam_delta=f(lam_delta),
            lam_term=f(lam_term),
            meas_tau=f(meas_tau),
            wear_gain=f(wear_gain),
        )


# --------------------------------------------------------------------------
# Outer loop: SoC target selection (paper §6, Eq. 11)
# --------------------------------------------------------------------------


def select_target(
    cfg: ControllerConfig,
    ess: ESSParams,
    idle_remaining_s: jax.Array,
    wear: jax.Array | float = 0.0,
) -> jax.Array:
    """Target S* given the predicted remaining idle time.

    Active mode (idle_remaining < t_enter): S* = S_mid.
    Storage mode: drop toward S_idle, bounded by Eq. 11 and by the usable
    idle budget — the time left minus the time needed to charge back to
    S_mid at the maximum corrective rate.  When the budget can no longer
    cover the return charge, the target reverts to S_mid.

    ``wear`` is the battery's consumed cycle-life fraction (per rack; see
    ``core.health.cycle_life_fraction``).  With ``cfg.wear_gain > 0`` the
    allowed storage-mode excursion shrinks as cycle damage accumulates —
    an aging battery is cycled progressively shallower, the paper's
    "maximize lifetime" knob.  A negative gain *widens* the excursion
    instead (calendar-dominated installs that want to park lower for
    longer).  ``wear_gain = 0`` (default) multiplies the excursion by
    exactly 1.0, so the wear-blind policy is reproduced bit-for-bit.
    """
    # Max SoC rate of change at the corrective current limit.
    charge_rate = cfg.i_max * ess.eta_c / ess.q_max  # [1/s] charging
    discharge_rate = cfg.i_max / (ess.eta_d * ess.q_max)  # [1/s] discharging

    # Eq. 11 floor, with the wear-scaled excursion.
    delta_s_eff = cfg.delta_s_max * jnp.maximum(1.0 - cfg.wear_gain * wear, 0.0)
    s_floor = jnp.maximum(
        jnp.maximum(cfg.s_idle, cfg.s_mid - delta_s_eff), ess.soc_safe_min
    )

    # Usable budget: descend for t_down, return for t_up; t_down+t_up<=idle.
    # With delta = s_mid - target: t_down = delta/discharge_rate,
    # t_up = delta/charge_rate  =>  delta_max_budget solves the equality.
    delta_budget = idle_remaining_s / (1.0 / discharge_rate + 1.0 / charge_rate)
    s_budget = cfg.s_mid - delta_budget

    target = jnp.maximum(s_floor, s_budget)
    useful = (cfg.s_mid - target) >= cfg.delta_s_min
    in_storage = (idle_remaining_s >= cfg.t_enter) & useful
    return jnp.where(in_storage, target, cfg.s_mid)


# --------------------------------------------------------------------------
# Inner loop: receding-horizon QP (paper Eq. 13-17)
# --------------------------------------------------------------------------


def _build_qp(
    cfg: ControllerConfig,
    ess: ESSParams,
    soc_now: jax.Array,
    s_target: jax.Array,
    u_prev: jax.Array,
):
    """Assemble (P, q, A, lo, hi) for variables x = [c_0..c_{H-1}, d_0..d_{H-1}].

    SoC trajectory: S_k = S_0 + (dt/Q) (eta_c * cumsum(c) - cumsum(d)/eta_d),
    normalized error e_k = (S_k - S*) / dS_ref, command u_k = (c_k - d_k)/imax.
    Objective (paper Eq. 13):
        sum_k e_{k+1}^2 + lam_i*(c_k^2 + d_k^2)/imax^2
              + lam_delta*(u_k - u_{k-1})^2  + lam_term * e_H^2.
    (The magnitude penalty on c^2 + d^2 — rather than (c-d)^2 — also
    suppresses the simultaneous charge/discharge "efficiency leak" of the
    split formulation.)
    """
    h = cfg.horizon
    dt = cfg.dt
    # Error normalization (paper Eq. 12).  Floored so a degenerate config
    # (s_mid == s_idle) keeps the QP well-conditioned in float32.
    ds_ref = jnp.maximum(jnp.abs(cfg.s_mid - cfg.s_idle), 0.05)

    # S_{k+1} = S_0 + rows of L @ (eta_c c - d/eta_d) * dt/Q,  L = lower tri ones.
    ltri = jnp.tril(jnp.ones((h, h), jnp.float32))
    g_c = (dt / ess.q_max) * ess.eta_c * ltri  # (h, h): S_{k+1} coeffs on c
    g_d = -(dt / ess.q_max) / ess.eta_d * ltri
    g = jnp.concatenate([g_c, g_d], axis=1)  # (h, 2h): S_{1..H} = S0 + G x

    e0 = (soc_now - s_target) / ds_ref  # scalar offset
    # e_{k+1} = e0 + (G x)_k / ds_ref
    w = jnp.ones((h,), jnp.float32).at[h - 1].add(cfg.lam_term)  # stage + terminal
    ge = g / ds_ref
    p_track = 2.0 * (ge.T * w) @ ge
    q_track = 2.0 * ge.T @ (w * e0)

    # Magnitude penalty lam_i * (c^2 + d^2) / imax^2.
    p_mag = 2.0 * cfg.lam_i / (cfg.i_max**2) * jnp.eye(2 * h)

    # Smoothness on u = (c - d)/imax: D u with first row including u_prev.
    diff = jnp.eye(h, dtype=jnp.float32) - jnp.eye(h, k=-1, dtype=jnp.float32)
    sel = jnp.concatenate([jnp.eye(h), -jnp.eye(h)], axis=1) / cfg.i_max  # u = S x
    dmat = diff @ sel  # (h, 2h)
    p_smooth = 2.0 * cfg.lam_delta * dmat.T @ dmat
    q_smooth = -2.0 * cfg.lam_delta * dmat.T @ (jnp.eye(h, dtype=jnp.float32)[:, 0] * u_prev)

    p_mat = p_track + p_mag + p_smooth
    q_vec = q_track + q_smooth

    # Constraints: 0 <= c,d <= imax;  soc_safe_min <= S_k <= soc_safe_max.
    a_box = jnp.eye(2 * h)
    lo_box = jnp.zeros((2 * h,))
    hi_box = jnp.full((2 * h,), cfg.i_max)
    a_soc = g
    lo_soc = jnp.full((h,), ess.soc_safe_min) - soc_now
    hi_soc = jnp.full((h,), ess.soc_safe_max) - soc_now
    a_mat = jnp.concatenate([a_box, a_soc], axis=0)
    lo = jnp.concatenate([lo_box, lo_soc])
    hi = jnp.concatenate([hi_box, hi_soc])
    return p_mat, q_vec, a_mat, lo, hi


# --------------------------------------------------------------------------
# Factor-once plan: config-only QP precomputation + batched warm-started ADMM
# --------------------------------------------------------------------------
#
# ``_build_qp`` + ``cho_factor`` depend on the *state* (soc_now, s_target,
# u_prev) only through q, lo, hi — and those are rank-1 updates of fixed
# vectors.  P, A and the ADMM KKT Cholesky factor are pure functions of the
# static config, so at fleet scale (R racks x n_ctrl intervals) rebuilding
# and refactoring them per rack per interval is O(n_ctrl * R * h^3) of
# redundant work.  ``ControllerPlan`` hoists all of it into one
# precomputation; the per-iteration solve then becomes a single
# (2h, 2h) x (2h, R) triangular-solve/matmul pair across the whole rack
# batch, and warm-starting the (x, z, y) iterates across control intervals
# reaches the cold-start residual in ~1/4 the iterations.


class QPWarmState(NamedTuple):
    """ADMM iterates carried across control intervals (warm start).

    Shapes: ``x`` (2h, *batch), ``z``/``y`` (3h, *batch)."""

    x: jax.Array
    z: jax.Array
    y: jax.Array


@pytree_dataclass
class ControllerPlan:
    """Config-only precomputation of the inner-loop QP (factor once).

    ``q = q_e0 * e0 + q_du * u_prev`` with ``e0 = (soc - S*) / ds_ref``;
    ``lo/hi = {lo,hi}_base - soc_rows * soc`` — the only state-dependent
    pieces of the Eq. 13-17 QP.  Everything else, including the ADMM KKT
    Cholesky factor, is shared by every rack and every control interval.
    """

    p_mat: jax.Array  # (2h, 2h) quadratic cost
    a_mat: jax.Array  # (3h, 2h) stacked box + SoC constraints
    kkt_chol: jax.Array  # (2h, 2h) lower Cholesky of P + sigma I + rho A'A
    kkt_inv_sigma: jax.Array  # (2h, 2h) sigma * K^-1 (x-update, x term)
    kkt_inv_at: jax.Array  # (2h, 3h) K^-1 A' (x-update, rho z - y term)
    kkt_inv: jax.Array  # (2h, 2h) K^-1 (x-update, hoisted -K^-1 q term)
    q_e0: jax.Array  # (2h,) dq / d e0
    q_du: jax.Array  # (2h,) dq / d u_prev
    lo_base: jax.Array  # (3h,) constraint lower bounds at soc = 0
    hi_base: jax.Array  # (3h,) constraint upper bounds at soc = 0
    soc_rows: jax.Array  # (3h,) 1.0 on the SoC-constraint rows
    ds_ref: jax.Array  # scalar error normalization (Eq. 12)
    horizon: int = static_field(default=12)
    rho: float = static_field(default=1.0)
    sigma: float = static_field(default=1e-6)


def make_plan(
    cfg: ControllerConfig,
    ess: ESSParams,
    *,
    rho: float = 1.0,
    sigma: float = 1e-6,
) -> ControllerPlan:
    """Precompute the config-only QP pieces (same math as ``_build_qp``).

    Deliberately does NOT share code with ``_build_qp``: the per-step
    assembly is kept as an independent oracle so
    ``tests/test_controller_plan.py`` pins this refactoring against it.
    A change to the QP (Eq. 13-17) must be made in both and the
    equivalence tests re-run."""
    h = cfg.horizon
    dt = cfg.dt
    ds_ref = jnp.maximum(jnp.abs(cfg.s_mid - cfg.s_idle), 0.05)

    ltri = jnp.tril(jnp.ones((h, h), jnp.float32))
    g_c = (dt / ess.q_max) * ess.eta_c * ltri
    g_d = -(dt / ess.q_max) / ess.eta_d * ltri
    g = jnp.concatenate([g_c, g_d], axis=1)  # (h, 2h)

    w = jnp.ones((h,), jnp.float32).at[h - 1].add(cfg.lam_term)
    ge = g / ds_ref
    p_track = 2.0 * (ge.T * w) @ ge
    p_mag = 2.0 * cfg.lam_i / (cfg.i_max**2) * jnp.eye(2 * h)
    diff = jnp.eye(h, dtype=jnp.float32) - jnp.eye(h, k=-1, dtype=jnp.float32)
    sel = jnp.concatenate([jnp.eye(h), -jnp.eye(h)], axis=1) / cfg.i_max
    dmat = diff @ sel
    p_smooth = 2.0 * cfg.lam_delta * dmat.T @ dmat
    p_mat = p_track + p_mag + p_smooth

    q_e0 = 2.0 * ge.T @ w  # q_track = q_e0 * e0
    q_du = -2.0 * cfg.lam_delta * dmat[0]  # q_smooth = q_du * u_prev

    a_mat = jnp.concatenate([jnp.eye(2 * h), g], axis=0)  # (3h, 2h)
    lo_base = jnp.concatenate(
        [jnp.zeros((2 * h,)), jnp.full((h,), ess.soc_safe_min)]
    )
    hi_base = jnp.concatenate(
        [jnp.full((2 * h,), cfg.i_max), jnp.full((h,), ess.soc_safe_max)]
    )
    soc_rows = jnp.concatenate([jnp.zeros((2 * h,)), jnp.ones((h,))])

    kkt = p_mat + sigma * jnp.eye(2 * h) + rho * (a_mat.T @ a_mat)
    kkt_chol = jnp.linalg.cholesky(kkt)
    # Explicit K^-1 (tiny, SPD, well-conditioned: P is PSD + sigma I + rho
    # A'A): the ADMM x-update becomes two small GEMMs instead of a pair of
    # LAPACK triangular solves per iteration — at fleet scale the (2h, R)
    # TRSM pair was the single hottest op in the conditioning path.
    kkt_inv = jax.scipy.linalg.cho_solve((kkt_chol, True), jnp.eye(2 * h))
    return ControllerPlan(
        p_mat=p_mat,
        a_mat=a_mat,
        kkt_chol=kkt_chol,
        kkt_inv_sigma=sigma * kkt_inv,
        kkt_inv_at=kkt_inv @ a_mat.T,
        kkt_inv=kkt_inv,
        q_e0=q_e0,
        q_du=q_du,
        lo_base=lo_base,
        hi_base=hi_base,
        soc_rows=soc_rows,
        ds_ref=ds_ref,
        horizon=int(h),
        rho=float(rho),
        sigma=float(sigma),
    )


def _qp_state_terms(
    plan: ControllerPlan,
    soc_now: jax.Array,  # () or (R,)
    s_target: jax.Array,
    u_prev: jax.Array,
):
    """(q, lo, hi) from the state: rank-1 updates of the plan's bases."""
    e0 = (soc_now - s_target) / plan.ds_ref
    if jnp.ndim(e0) > 0:
        soc = jnp.broadcast_to(soc_now, e0.shape)
        u = jnp.broadcast_to(u_prev, e0.shape)
        q = plan.q_e0[:, None] * e0[None, :] + plan.q_du[:, None] * u[None, :]
        lo = plan.lo_base[:, None] - plan.soc_rows[:, None] * soc[None, :]
        hi = plan.hi_base[:, None] - plan.soc_rows[:, None] * soc[None, :]
    else:
        q = plan.q_e0 * e0 + plan.q_du * u_prev
        lo = plan.lo_base - plan.soc_rows * soc_now
        hi = plan.hi_base - plan.soc_rows * soc_now
    return q, lo, hi


def solve_qp_admm_plan(
    plan: ControllerPlan,
    q: jax.Array,  # (2h,) or (2h, R)
    lo: jax.Array,  # (3h,) or (3h, R)
    hi: jax.Array,
    warm: QPWarmState | None = None,
    *,
    iters: int = 30,
) -> tuple[QPSolution, QPWarmState]:
    """Batched ADMM against a prefactorized plan.

    The rack batch rides in the trailing axis: the x-update
    ``x = K^-1 (sigma x - q + A'(rho z - y))`` is evaluated against the
    plan's precomputed ``K^-1`` as two (2h, .) x (., R) GEMMs — with the
    state-only ``K^-1 q`` term hoisted out of the iteration loop — instead
    of a per-iteration pair of batched triangular solves (or R vmapped
    scalar solves).  ``warm`` seeds (x, z, y) from the previous control
    interval; residuals are returned per rack so callers can verify
    matched convergence.
    """
    rho = plan.rho
    a_mat = plan.a_mat
    if warm is None:
        x0 = jnp.zeros_like(q)
        z0 = jnp.clip(a_mat @ x0, lo, hi)
        y0 = jnp.zeros_like(z0)
    else:
        x0, z0, y0 = warm.x, warm.z, warm.y
    kq = plan.kkt_inv @ q  # state-only: constant across iterations

    # Fused iteration loop (ops.admm_iterate): the stacked x-update GEMM
    # and the structure-exploiting A x (A = [I; G]) — one Pallas kernel on
    # TPU, the jnp reference elsewhere.  The stacked operand is loop-
    # invariant; XLA hoists the concatenate out of the iteration scan.
    kkt_stack = jnp.concatenate([plan.kkt_inv_sigma, plan.kkt_inv_at], axis=1)
    x, z, y = ops.admm_iterate(
        kkt_stack, a_mat[2 * plan.horizon :], kq, lo, hi, x0, z0, y0,
        rho=rho, iters=iters,
    )
    ax = a_mat @ x
    primal = jnp.max(jnp.abs(ax - jnp.clip(ax, lo, hi)), axis=0)
    dual = jnp.max(jnp.abs(plan.p_mat @ x + q + a_mat.T @ y), axis=0)
    return (
        QPSolution(x=x, primal_residual=primal, dual_residual=dual),
        QPWarmState(x=x, z=z, y=y),
    )


def init_warm(
    plan: ControllerPlan | int, batch_shape: tuple[int, ...] = ()
) -> QPWarmState:
    """Zero warm state (== cold start while the SoC is inside the safe band).

    Accepts a plan or a bare horizon, so state containers can allocate the
    warm buffers without building the plan first."""
    h = plan if isinstance(plan, int) else plan.horizon
    return QPWarmState(
        x=jnp.zeros((2 * h,) + tuple(batch_shape), jnp.float32),
        z=jnp.zeros((3 * h,) + tuple(batch_shape), jnp.float32),
        y=jnp.zeros((3 * h,) + tuple(batch_shape), jnp.float32),
    )


def reset_warm_where(warm: QPWarmState, reset: jax.Array) -> QPWarmState:
    """Zero the ADMM iterates of the masked entries (cold start).

    ``reset`` carries the batch shape; it broadcasts against the leading
    iterate axis of each ``(n_iterates, *batch)`` leaf.  Shared by the
    degraded-mode QP admission mask and the safe-mode supervisor, so "this
    rack re-enters with a valid cold start" means the same thing on every
    path.  An all-false mask is bitwise identity.
    """
    keep = ~reset.astype(bool)
    return QPWarmState(
        x=jnp.where(keep, warm.x, 0.0),
        z=jnp.where(keep, warm.z, 0.0),
        y=jnp.where(keep, warm.y, 0.0),
    )


class ControllerOutput(NamedTuple):
    corrective_power: jax.Array  # applied first action (fraction of rated)
    s_target: jax.Array
    in_deadband: jax.Array
    qp_primal_residual: jax.Array


def inner_loop_step(
    cfg: ControllerConfig,
    ess: ESSParams,
    soc_now: jax.Array,
    s_target: jax.Array,
    u_prev: jax.Array,
    *,
    qp_iters: int = 250,
) -> ControllerOutput:
    """One 5-second control step: solve the QP, apply the first action.

    Inside the deadband |S - S*| <= eps the current is forced to zero
    (paper §6: "a narrow margin of error around the target brings the
    current to zero").
    """
    p_mat, q_vec, a_mat, lo, hi = _build_qp(cfg, ess, soc_now, s_target, u_prev)
    sol = solve_qp_admm(p_mat, q_vec, a_mat, lo, hi, iters=qp_iters)
    h = cfg.horizon
    i0 = sol.x[0] - sol.x[h]  # c_0 - d_0
    # Physical saturation: the command is a current limit; ADMM's x iterate
    # may slightly exceed the box before full convergence.
    i0 = jnp.clip(i0, -cfg.i_max, cfg.i_max)
    in_deadband = jnp.abs(soc_now - s_target) <= cfg.deadband
    i0 = jnp.where(in_deadband, 0.0, i0)
    return ControllerOutput(
        corrective_power=i0,
        s_target=s_target,
        in_deadband=in_deadband,
        qp_primal_residual=sol.primal_residual,
    )


def inner_loop_step_plan(
    cfg: ControllerConfig,
    ess: ESSParams,
    plan: ControllerPlan,
    soc_now: jax.Array,  # any batch shape (trailing rack axes), or scalar
    s_target: jax.Array,
    u_prev: jax.Array,
    warm: QPWarmState | None = None,
    *,
    qp_iters: int = 30,
    active: jax.Array | None = None,
) -> tuple[ControllerOutput, QPWarmState]:
    """Factor-free batched control step against a precomputed plan.

    Same semantics as ``inner_loop_step`` (first action, physical clip,
    deadband), but the QP assembly is two rank-1 updates, the solve is
    batched over every rack at once, and the returned ``QPWarmState`` seeds
    the next control interval.

    ``active`` masks degraded racks whose ESS unit is offline: their
    command and reported residual are zeroed and — critically for
    warm-started operation — their warm iterates are reset, so a unit that
    trips and later recovers re-enters with a valid cold start rather than
    ADMM iterates frozen from the pre-fault problem.  ``active=None`` is
    bitwise identical to the unmasked step.
    """
    h = plan.horizon
    batch_shape = jnp.shape(soc_now)

    def flat(a):
        return jnp.reshape(a, (a.shape[0], -1)) if batch_shape else a

    def unflat(a):
        return jnp.reshape(a, (a.shape[0],) + batch_shape) if batch_shape else a

    if batch_shape:
        soc = jnp.reshape(soc_now, (-1,))
        tgt = jnp.reshape(jnp.broadcast_to(s_target, batch_shape), (-1,))
        up = jnp.reshape(jnp.broadcast_to(u_prev, batch_shape), (-1,))
    else:
        soc, tgt, up = soc_now, s_target, u_prev
    act = None
    if active is not None:
        act = jnp.broadcast_to(active, batch_shape)
        act = (jnp.reshape(act, (-1,)) if batch_shape else act) > 0

    q, lo, hi = _qp_state_terms(plan, soc, tgt, up)
    w = None if warm is None else QPWarmState(flat(warm.x), flat(warm.z), flat(warm.y))
    sol, w2 = solve_qp_admm_plan(plan, q, lo, hi, w, iters=qp_iters)
    i0 = jnp.clip(sol.x[0] - sol.x[h], -cfg.i_max, cfg.i_max)
    in_deadband = jnp.abs(soc - tgt) <= cfg.deadband
    i0 = jnp.where(in_deadband, 0.0, i0)
    resid = sol.primal_residual
    if act is not None:
        i0 = jnp.where(act, i0, 0.0)
        resid = jnp.where(act, resid, 0.0)
        w2 = reset_warm_where(w2, ~act)

    def back(a):
        return jnp.reshape(a, batch_shape) if batch_shape else a

    out = ControllerOutput(
        corrective_power=back(i0),
        s_target=back(tgt) if batch_shape else s_target,
        in_deadband=back(in_deadband),
        qp_primal_residual=back(resid),
    )
    return out, QPWarmState(x=unflat(w2.x), z=unflat(w2.z), y=unflat(w2.y))


def simulate_soc_management(
    cfg: ControllerConfig,
    ess: ESSParams,
    soc0: jax.Array,
    n_steps: int,
    *,
    idle_remaining_s: jax.Array | float = 0.0,
    drift_power: jax.Array | float = 0.0,
    qp_iters: int = 120,
    warm_start: bool = False,
) -> dict:
    """Closed-loop SoC trajectory under the controller (paper Fig. 12).

    ``drift_power`` models the hardware path's set-point bias / round-trip
    losses as a constant parasitic charge(+)/discharge(-) power.
    The QP plan is factored once outside the scan (the dominant per-step
    cost at the seed); ``warm_start=True`` additionally carries the ADMM
    iterates across intervals.  The Fig. 12 repro defaults to cold starts:
    a fixed-iteration cold solve lands slightly *above* the true optimum's
    command magnitude near the target, and the paper's ~20 min convergence
    matches that regime (a fully-converged solve creeps into the deadband
    ~1.5x slower).  Fleet conditioning (``pdu.condition``), where solver
    throughput actually matters, uses the warm-started path.
    Returns dict of (n_steps,) arrays: soc, command, target.
    """
    idle = jnp.asarray(idle_remaining_s, jnp.float32)
    drift = jnp.asarray(drift_power, jnp.float32)
    plan = make_plan(cfg, ess)

    def body(carry, k):
        soc, u_prev, warm = carry
        idle_left = jnp.maximum(idle - k * cfg.dt, 0.0)
        s_target = select_target(cfg, ess, idle_left)
        out, warm2 = inner_loop_step_plan(
            cfg, ess, plan, soc, s_target, u_prev,
            warm if warm_start else None, qp_iters=qp_iters,
        )
        p_batt = out.corrective_power + drift
        charge = jnp.maximum(p_batt, 0.0)
        discharge = jnp.maximum(-p_batt, 0.0)
        soc_next = soc + (cfg.dt / ess.q_max) * (
            ess.eta_c * charge - discharge / ess.eta_d
        )
        soc_next = jnp.clip(soc_next, ess.soc_safe_min, ess.soc_safe_max)
        u_prev_next = out.corrective_power / cfg.i_max
        return (soc_next, u_prev_next, warm2), (
            soc_next, out.corrective_power, s_target,
        )

    (_, _, _), (soc, cmd, tgt) = jax.lax.scan(
        body,
        (
            jnp.asarray(soc0, jnp.float32),
            jnp.asarray(0.0, jnp.float32),
            init_warm(plan),
        ),
        jnp.arange(n_steps, dtype=jnp.float32),
    )
    return {"soc": soc, "command": cmd, "target": tgt}
