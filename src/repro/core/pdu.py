"""The composed EasyRider PDU (paper §4-§6): filter + ESS + controller.

Signal chain (per-unit, powers as fractions of rated rack power):

    rack power --(ESS ramp control, Eq. 2)--> node power g
               --(passive LC + damping)-----> grid power

The ESS stage removes low-frequency content (>= f_b = beta/2pi); the LC
stage removes high-frequency content (>= f_f).  The total response is the
product of the two transfer functions (paper Fig. 7).  The software
controller runs every ``cfg.dt`` (5 s) seconds of simulated time and issues
milliamp-scale corrective currents that nudge the battery SoC toward the
outer-loop target without perturbing the grid-facing waveform.

Everything is per-unit: physical component values from ``sizing`` are
converted with the rack base impedance so one code path serves the 10 kW
prototype and 1 MW racks identically.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import compliance, controller as ctrl, ess, filters, health as hlt, \
    safemode as smode, sizing
from repro.kernels import ops
from repro.power import faults as flt
from repro.utils import pytree_dataclass, static_field


@pytree_dataclass
class PDUConfig:
    filter_params: filters.LCFilterParams  # per-unit
    ess_params: ess.ESSParams
    controller: ctrl.ControllerConfig
    health: hlt.HealthParams = None  # aging model (used when track_health)
    safemode_params: smode.SafeModeConfig = None  # watchdog knobs (when safemode)
    sample_dt: float = static_field(default=1e-3)  # trace sample period [s]
    software_enabled: bool = static_field(default=True)
    # Fold per-sample battery wear telemetry (core.health) into the
    # conditioning scan.  Pure observation — grid/SoC outputs are
    # unchanged — but it costs a second per-sample scan, so it is opt-in.
    track_health: bool = static_field(default=False)
    # Degraded-mode conditioning: honor per-interval ESS availability masks
    # (offline units run in LC passthrough), bridge NaN sensor dropouts
    # with a last-good-sample hold, and trip measurement-blind racks into
    # passthrough.  Static so the fault-free path stays structurally (and
    # bitwise) identical to builds without this feature.
    degraded_mode: bool = static_field(default=False)
    # Supervisory safe mode (core.safemode): per-rack NORMAL → PASSTHROUGH →
    # QUARANTINE state machine driven in-jit by the ADMM divergence watchdog
    # and the state-corruption sanitizer.  Static for the same reason as
    # degraded_mode: with safemode=False the compiled program is
    # structurally (and bitwise) identical to the unsupervised build.
    safemode: bool = static_field(default=False)


def per_unit_filter(s: sizing.SizingResult, rack: sizing.RackRating) -> filters.LCFilterParams:
    """Convert physical component values to the per-unit system."""
    z = rack.v_dc**2 / rack.p_rated_w
    return filters.LCFilterParams.create(
        l_f=s.l_f / z, c_f=s.c_f * z, r_da=s.r_da / z, l_da=s.l_da * (1.0 / z)
    )


def make_pdu(
    rack: sizing.RackRating | None = None,
    grid: compliance.GridSpec | None = None,
    *,
    sample_dt: float = 1e-3,
    f_f_hz: float = 4.0,
    soc_window: tuple[float, float] = (0.1, 0.9),
    capacity_margin: float = 4.0,
    ramp_margin: float = 1.6,
    software_enabled: bool = True,
    controller_cfg: ctrl.ControllerConfig | None = None,
    health_params: hlt.HealthParams | None = None,
    track_health: bool = False,
    degraded_mode: bool = False,
    safemode: bool = False,
    safemode_params: smode.SafeModeConfig | None = None,
) -> PDUConfig:
    """Size and assemble an EasyRider PDU for a rack + grid spec.

    Default parameters reproduce the paper's prototype design point:
    beta = 0.1/s, alpha = 1e-4, f_c = 2 Hz, f_f ~= 4 Hz.

    Capacity: Appendix A.1 Eq. 8 with gamma = usable SoC window gives the
    floor for a *single* worst-case transient starting at the favorable
    window edge.  Operating mid-band for symmetric headroom (paper §6)
    doubles the need, and ongoing iteration cycling adds more; like the
    paper's intentionally oversized 74 Ah pack we apply ``capacity_margin``
    (default 4x) on top of the Eq. 8 floor.  Tests verify both the Eq. 8
    bound itself and that the margined design rides the testbench without
    SoC saturation.

    Ramp margin: the damped LC stage transiently amplifies the *slope* of
    ramp-limited kinks by up to ~1.5x near its resonance, so the ESS is
    designed to beta/ramp_margin; the composed grid-facing ramp then meets
    the spec beta with margin (verified end-to-end in tests).
    """
    rack = rack or sizing.prototype_rack()
    grid = grid or compliance.GridSpec.create()
    beta = float(grid.beta) / ramp_margin
    gamma = soc_window[1] - soc_window[0]
    s = sizing.size_system(rack, beta=beta, f_f_hz=f_f_hz, gamma=gamma)
    q_max_seconds = capacity_margin * s.battery_energy_j / rack.p_rated_w
    ess_params = ess.ESSParams.create(
        beta=beta,
        q_max_seconds=q_max_seconds,
        p_max=max(rack.epsilon * 1.25, 1.0),
        soc_safe_min=soc_window[0],
        soc_safe_max=soc_window[1],
    )
    return PDUConfig(
        filter_params=per_unit_filter(s, rack),
        ess_params=ess_params,
        controller=controller_cfg or ctrl.ControllerConfig.create(),
        health=health_params or hlt.HealthParams.create(),
        safemode_params=(
            (safemode_params or smode.SafeModeConfig.create()) if safemode else None
        ),
        sample_dt=sample_dt,
        software_enabled=software_enabled,
        track_health=track_health,
        degraded_mode=degraded_mode,
        safemode=safemode,
    )


class PDUState(NamedTuple):
    filter_state: jax.Array  # (..., 3)
    filter_obj: filters.DiscreteFilter
    ess_state: ess.ESSState
    u_prev: jax.Array  # last normalized controller command
    cmd_applied: jax.Array  # corrective power applied at the last sample
    cmd_target: jax.Array  # corrective power to slew toward this interval
    soc_ema: jax.Array  # BMS measurement filter (slow SoC estimate)
    qp_warm: ctrl.QPWarmState  # ADMM iterates carried across intervals/chunks
    health: hlt.HealthState  # battery wear telemetry (zeros unless tracked)
    # Degraded-mode state (always present so the carry structure is uniform):
    # operator/manual ESS availability override (1 = available) and the last
    # finite sample seen per rack (seeds the sensor-dropout bridge).
    ess_online: jax.Array = None
    last_good: jax.Array = None
    # Supervisory safe-mode state machine (always present so the carry
    # structure is uniform; all-NORMAL zeros unless cfg.safemode).
    safemode: smode.SafeModeState = None


def init_state(cfg: PDUConfig, rack_power0: jax.Array, soc0: float = 0.5) -> PDUState:
    """Steady-state initialization at a constant starting power.

    NaN entries in ``rack_power0`` (a rack whose sensor is dark at the very
    first sample) seed from the fleet's finite mean instead — a no-op for
    clean traces, and it keeps every engine's seeding identical under
    sensor-dropout fault schedules.
    """
    filt = filters.make_discrete_filter(cfg.filter_params, cfg.sample_dt)
    r0 = jnp.asarray(rack_power0, jnp.float32)
    finite = jnp.isfinite(r0)
    r0 = jnp.where(finite, r0, jnp.nan_to_num(jnp.nanmean(r0), nan=0.5))
    u0 = jnp.stack([jnp.ones_like(r0), r0], axis=-1)  # [v_in=1, i_load=r0]
    x0 = jnp.vectorize(lambda u: filters.steady_state(filt, u), signature="(m)->(n)")(u0)
    return PDUState(
        filter_state=x0,
        filter_obj=filt,
        ess_state=ess.ESSState(g_filter=r0, soc=jnp.full_like(r0, soc0)),
        u_prev=jnp.zeros_like(r0),
        cmd_applied=jnp.zeros_like(r0),
        cmd_target=jnp.zeros_like(r0),
        soc_ema=jnp.full_like(r0, soc0),
        qp_warm=ctrl.init_warm(cfg.controller.horizon, r0.shape),
        health=hlt.init_state(jnp.full_like(r0, soc0)),
        ess_online=jnp.ones_like(r0),
        # Distinct buffer from ess_state.g_filter: donated engines reject
        # the same array appearing twice in one argument list.
        last_good=jnp.copy(r0),
        safemode=smode.init_state(r0.shape),
    )


class Telemetry(NamedTuple):
    soc: jax.Array  # (n_ctrl, ...) SoC at each control interval
    command: jax.Array  # corrective power commanded per interval
    target: jax.Array  # outer-loop SoC target per interval
    qp_residual: jax.Array  # QP primal residual per interval (0 if sw off)
    # Campus means, computed INSIDE the interval scan from its materialized
    # operands.  A top-level ``jnp.mean(rack_power)`` next to the scan gives
    # XLA a second consumer of the rendered chunk, and its fusion pass
    # duplicates the whole producer chain (measured: the noise transform
    # ran twice per chunk in the scanned engine's fused jit) — reducing
    # over the scan's xs/output buffers instead keeps the producer
    # single-consumer while yielding bitwise-identical values (the rack
    # reduction of row t does not depend on which rows share the array).
    rack_mean: jax.Array = None  # (T,) mean of the (bridged) input trace
    grid_mean: jax.Array = None  # (T,) mean of the conditioned grid trace
    # Degraded-mode extra (None unless cfg.degraded_mode):
    ess_online: jax.Array = None  # (n_ctrl, ...) effective availability mask
    # Safe-mode extra (None unless cfg.safemode): the post-watchdog
    # supervisor mode per interval (0 NORMAL / 1 PASSTHROUGH / 2 QUARANTINE).
    safemode_mode: jax.Array = None


def bridge_sensors(
    last_good: jax.Array, rack_power: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Replace NaN (sensor-dropout) samples with the most recent finite
    sample per rack — ``last_good`` seeds racks whose first samples are
    dark.  Returns ``(bridged, new_last_good)``.

    The fill is a pure gather of the last finite sample at-or-before each
    index, so chunked bridging with the carried ``last_good`` reproduces
    whole-trace bridging bit-for-bit.
    """
    t = rack_power.shape[0]
    finite = jnp.isfinite(rack_power)
    idx = jnp.arange(t, dtype=jnp.int32).reshape((t,) + (1,) * (rack_power.ndim - 1))
    last_pos = jax.lax.associative_scan(
        jnp.maximum, jnp.where(finite, idx, -1), axis=0
    )
    vals = jnp.where(finite, rack_power, 0.0)
    held = jnp.take_along_axis(
        vals, jnp.broadcast_to(jnp.maximum(last_pos, 0), rack_power.shape), axis=0
    )
    bridged = jnp.where(last_pos >= 0, held, last_good)
    return bridged, bridged[-1]


def condition(
    cfg: PDUConfig,
    state: PDUState,
    rack_power: jax.Array,  # (T, ...) per-unit rack power
    *,
    idle_remaining_s: jax.Array | float = 0.0,
    qp_iters: int = 120,
    use_plan: bool = True,
    ess_online: jax.Array | None = None,
    ess_weight: jax.Array | None = None,
    faults: flt.FaultSchedule | None = None,
    chunk_start: jax.Array | int = 0,
    fault_edge: int = 1,
) -> tuple[jax.Array, PDUState, Telemetry]:
    """Condition a trace chunk; carries state across calls (streaming).

    The outer scan advances one controller interval (cfg.controller.dt
    seconds = k samples) at a time: the hardware path is simulated for k
    samples while the corrective command slews linearly from the previously
    applied value toward the latest controller output (battery converters
    ramp; command updates must not inject steps into the grid waveform),
    then one QP solve — fed the EMA-filtered BMS state-of-charge, so the
    software tracks slow drift rather than chasing per-iteration workload
    cycling — produces the next slew target.  If T is not a multiple of k
    the trace is zero-order-hold padded and the pad discarded.

    ``use_plan=True`` (default) factors the controller QP once outside the
    scan (``ctrl.make_plan``), solves all racks as one batched ADMM, and
    warm-starts each interval from ``state.qp_warm`` — the warm state rides
    in ``PDUState`` so chunked (streaming) calls stay bit-identical to one
    whole-trace call.  ``use_plan=False`` keeps the original per-interval
    build + factor + vmapped-solve path (the oracle for equivalence tests
    and the cold-start baseline for benchmarks).

    Degraded mode (``cfg.degraded_mode``): ``ess_online`` is a per-interval
    availability mask — ``(n_ctrl, ...)`` rows, or a single ``(...)`` mask
    applied to every interval — marking racks whose ESS unit has tripped
    offline; those racks condition in LC passthrough with zeroed controller
    commands and reset QP warm state.  NaN samples (sensor dropout) are
    bridged with a last-good-sample hold (``bridge_sensors``, seeded from
    ``state.last_good``), and a rack whose sensor is dark for an *entire*
    control interval trips a finite-guard: it is forced into passthrough
    for that interval regardless of the mask, so a blind controller never
    commands a live battery.  The effective mask actually applied and the
    per-sample mean of the bridged trace ride out in ``Telemetry``.

    Safe mode (``cfg.safemode``): the supervisory state machine of
    ``core.safemode`` rides the same scan.  Each interval starts with the
    state-corruption sanitizer (non-finite carry leaves quarantine the
    rack and reinitialize its slice to steady state), racks not in NORMAL
    mode are excluded from the hardware plane through the same
    ``ess_on`` weight degraded mode uses (LC passthrough), and after the
    QP solve the divergence watchdog folds the raw per-rack primal
    residual: racks over ``safemode_params.resid_threshold`` for
    ``trip_intervals`` consecutive intervals trip to PASSTHROUGH — their
    command is zeroed and their warm iterates reset — then probe their
    (cold-started) solve every interval until ``readmit_intervals``
    consecutive clean probes re-admit them.  ``Telemetry.safemode_mode``
    carries the post-watchdog per-interval mode rows.

    ``ess_weight`` (optional, shaped like ``rack_power``) is the hardware
    plane's *per-sample* availability weight: trips land at their true
    sample and the converter winds down/soft-starts over the schedule's
    edge window (``faults.ess_weight``) instead of snapping at the
    controller-interval boundary — without it, every trip in an interval
    hands its battery power to the grid on the same sample, a fabricated
    campus-synchronized step.  When given, the hardware path follows
    ``ess_weight`` (composed with the manual-override state and the
    finite-guard) while ``ess_online`` keeps governing the software plane
    (QP admission, command zeroing, telemetry).

    ``faults`` (mutually exclusive with explicit ``ess_online`` /
    ``ess_weight`` arrays) is the compiled fast path for the same
    semantics: pass the ``FaultSchedule`` itself plus the chunk's absolute
    ``chunk_start`` sample and the scenario's ``fault_edge`` width, and
    every degraded-mode signal is rendered from O(episodes) boundary
    events instead of streamed ``(T, R)`` blocks — the interval
    online/sensed masks are tiny ``(n_ctrl, R)`` schedule lookups, the
    NaN sensor bridge becomes a per-interval hold-index gather *inside*
    the scan body (on the materialized xs slice, so the rendered trace
    keeps a single consumer chain — EXPERIMENTS §Perf-8 records the
    producer-duplication pathology this avoids), and the per-sample ESS
    weight is rendered inside the megakernel from the episode tables
    (``ops.pdu_health_sim`` ``ess_events``).  Outputs are bit-identical
    to the streamed-array path at any chunk split and resume point.
    Safe-mode cfgs fall back to the streamed derivation (the supervisor
    composes its own per-sample hardware-weight ramps).
    """
    degraded = cfg.degraded_mode
    safemode = cfg.safemode
    if (ess_online is not None or ess_weight is not None) and not degraded:
        raise ValueError(
            "ess_online/ess_weight require a cfg with degraded_mode=True"
        )
    if faults is not None:
        if not degraded:
            raise ValueError("faults requires a cfg with degraded_mode=True")
        if ess_online is not None or ess_weight is not None:
            raise ValueError(
                "pass either a FaultSchedule or explicit ess_online/"
                "ess_weight arrays, not both"
            )
        if rack_power.ndim < 2:
            raise ValueError("the fault fast path needs a batched (T, R) trace")
    dt = cfg.sample_dt
    k = max(int(round(float(cfg.controller.dt) / dt)), 1)
    t = rack_power.shape[0]
    n_ctrl = -(-t // k)
    pad = n_ctrl * k - t
    batch = rack_power.shape[1:]

    fast = faults is not None and not safemode
    if faults is not None and safemode:
        # The supervisor slews its own per-sample hardware weight across
        # each interval; composing that ramp with in-kernel event
        # rendering would need a second weight operand, so safe-mode runs
        # keep the streamed derivation (identical values by the faults
        # equivalence contract).
        ess_online = flt.interval_online(faults, chunk_start, n_ctrl, k)
        ess_weight = flt.ess_weight(faults, chunk_start, t, fault_edge)
        faults = None

    if fast:
        cs = jnp.asarray(chunk_start, jnp.int32)
        t_last = cs + (t - 1)
        # Software plane + finite-guard, straight from the episode tables:
        # no isfinite/bridge pass over the rendered trace before the scan,
        # so the render's only consumer is the scan's xs buffer.
        sensed = flt.interval_sensed(faults, cs, n_ctrl, k, stop=cs + t)
        arg_rows = flt.interval_online(faults, cs, n_ctrl, k)
        hw_base = jnp.broadcast_to(
            state.ess_online, (n_ctrl,) + batch
        ) * sensed.astype(jnp.float32)
        on_rows = arg_rows * hw_base
        # Compact megakernel operand: (E, R) boundary tables + per-interval
        # absolute start samples (the per-sample weight renders in-kernel).
        ev_st = faults.ess_start.T
        ev_en = faults.ess_end.T
        i0_rows = cs + k * jnp.arange(n_ctrl, dtype=jnp.int32)
    elif degraded:
        finite = jnp.isfinite(rack_power)
        fpad = (
            jnp.concatenate([finite, jnp.repeat(finite[-1:], pad, axis=0)], axis=0)
            if pad
            else finite
        )
        # Finite-guard tripwire: an interval with zero finite samples means
        # the rack was measurement-blind for the whole control period.
        sensed = jnp.any(fpad.reshape((n_ctrl, k) + batch), axis=1)
        rack_power, last_good2 = bridge_sensors(state.last_good, rack_power)
        if ess_online is None:
            arg_rows = jnp.ones((n_ctrl,) + batch, jnp.float32)
        else:
            ess_online = jnp.asarray(ess_online, jnp.float32)
            if ess_online.ndim == rack_power.ndim - 1:  # one mask, all intervals
                ess_online = jnp.broadcast_to(ess_online, (n_ctrl,) + batch)
            arg_rows = ess_online
        # Manual-override state x finite-guard: applies to both planes.
        hw_base = jnp.broadcast_to(
            state.ess_online, (n_ctrl,) + batch
        ) * sensed.astype(jnp.float32)
        on_rows = arg_rows * hw_base
        if ess_weight is None:
            # Hardware follows the interval mask (legacy/manual path).
            hw_chunks = on_rows[:, None]
        else:
            ess_weight = jnp.asarray(ess_weight, jnp.float32)
            wpad = (
                jnp.concatenate(
                    [ess_weight, jnp.repeat(ess_weight[-1:], pad, axis=0)],
                    axis=0,
                )
                if pad
                else ess_weight
            )
            hw_chunks = (
                wpad.reshape((n_ctrl, k) + batch) * hw_base[:, None]
            )
    else:
        last_good2 = state.last_good

    padded = (
        jnp.concatenate([rack_power, jnp.repeat(rack_power[-1:], pad, axis=0)], axis=0)
        if pad
        else rack_power
    )
    chunks = padded.reshape((n_ctrl, k) + rack_power.shape[1:])

    filt = state.filter_obj
    meas_w = min(float(cfg.controller.dt) / float(cfg.controller.meas_tau), 1.0)

    if safemode:
        sm_cfg = (
            cfg.safemode_params
            if cfg.safemode_params is not None
            else smode.SafeModeConfig.create()
        )
        s_mid = jnp.asarray(cfg.controller.s_mid, jnp.float32)
        # Steady-state map for quarantine reinit: x_ss(r) = (I-Ad)^-1 Bd
        # [1, r] — hoisted out of the scan, shared by every rack.
        eye = jnp.eye(filt.ad.shape[0], dtype=filt.ad.dtype)
        ss_mat = jnp.linalg.solve(eye - filt.ad, filt.bd)  # (3, 2)

    ep = cfg.ess_params
    # Factor-once plan: P, A and the KKT Cholesky depend only on config, so
    # they are hoisted out of the interval scan (and shared by every rack).
    plan = ctrl.make_plan(cfg.controller, cfg.ess_params) if (
        cfg.software_enabled and use_plan
    ) else None
    hw_kw = dict(
        beta=float(ep.beta), dt=dt, q_max=float(ep.q_max),
        eta_c=float(ep.eta_c), eta_d=float(ep.eta_d),
        p_max=float(ep.p_max), soc_min=float(ep.soc_safe_min),
        soc_max=float(ep.soc_safe_max),
    )
    hconsts = hlt.step_consts(cfg.health) if cfg.track_health else None

    def interval(carry, xs):
        if safemode:
            carry, sm = carry
        else:
            sm = None
        if fast:
            (
                x_f, es, u_prev, cmd_applied, cmd_target, soc_ema, warm,
                hstate, step_idx, lg,
            ) = carry
        else:
            (
                x_f, es, u_prev, cmd_applied, cmd_target, soc_ema, warm,
                hstate, step_idx,
            ) = carry
        if fast:
            rack_chunk, on_row, base_row, i0 = xs
            # --- in-body sensor bridge (schedule-compiled) ---------------
            # Operates on the materialized (k, R) xs slice: dark samples
            # take the raw value at the covering episode's ``start - 1``
            # (always finite — episodes are coalesced with >= 1 healthy
            # sample between them), or the carried last-good row when that
            # index precedes this interval.  Bit-identical to running
            # ``bridge_sensors`` over the whole chunk (the associative-scan
            # bridge gathers the same raw samples), without giving the
            # pre-scan render a second consumer.  Indices clamp to the
            # last real sample so ZOH pad rows replicate its bridge.
            idx = jnp.minimum(i0 + jnp.arange(k, dtype=jnp.int32), t_last)
            dark, hold = flt.sensor_dark_hold(faults, idx)
            loc = hold - i0
            held = jnp.take_along_axis(
                jnp.where(dark, 0.0, rack_chunk), jnp.clip(loc, 0, k - 1), axis=0
            )
            rack_chunk = jnp.where(
                dark, jnp.where(loc >= 0, held, lg), rack_chunk
            )
            lg = rack_chunk[-1]
        elif degraded:
            rack_chunk, on_row, hw_chunk = xs
        else:
            rack_chunk = xs

        # --- safe mode: state-corruption sanitizer -----------------------
        # Runs at the START of the interval, so non-finite state — whether
        # injected between windows or produced by the previous interval —
        # is quarantined and reinitialized before it can reach the
        # hardware path or the solver.
        if safemode:
            r0 = rack_chunk[0]
            r0 = jnp.where(jnp.isfinite(r0), r0, 0.5)
            nonfin = lambda a: ~jnp.isfinite(a)
            corrupt = (
                nonfin(es.soc) | nonfin(es.g_filter)
                | jnp.any(nonfin(x_f), axis=-1)
                | nonfin(u_prev) | nonfin(cmd_applied) | nonfin(cmd_target)
                | nonfin(soc_ema)
                | jnp.any(nonfin(warm.x), axis=0)
                | jnp.any(nonfin(warm.z), axis=0)
                | jnp.any(nonfin(warm.y), axis=0)
            )
            for leaf in hstate:
                if jnp.issubdtype(leaf.dtype, jnp.floating):
                    corrupt = corrupt | nonfin(leaf)
            fin = lambda a, v: jnp.where(jnp.isfinite(a), a, v)
            x_ss = ss_mat[:, 0] + r0[..., None] * ss_mat[:, 1]
            # Reinit is per-LEAF where the leaf itself went non-finite:
            # hardware-continuous leaves (LC filter state, grid filter,
            # applied command) keep their finite values so containment
            # never steps the grid waveform, while the corrupted leaves
            # land on the clean steady state.  Supervisor-internal leaves
            # (warm iterates, wear accumulators, controller reference) do
            # reset for the whole corrupted rack — a deterministic
            # cold-started probe needs them clean, and they never touch
            # the waveform directly.
            es = ess.ESSState(
                g_filter=fin(es.g_filter, r0), soc=fin(es.soc, s_mid)
            )
            x_f = fin(x_f, x_ss)
            u_prev = jnp.where(corrupt, 0.0, u_prev)
            cmd_applied = fin(cmd_applied, 0.0)
            cmd_target = jnp.where(corrupt, 0.0, cmd_target)
            soc_ema = fin(soc_ema, s_mid)
            warm = ctrl.reset_warm_where(warm, corrupt)
            hstate = hlt.reinit_where(hstate, corrupt, s_mid)
            sm = smode.quarantine(sm, corrupt)
            # Hardware admission reads the PRE-watchdog mode: a rack that
            # only trips at this interval's solve still conditioned this
            # interval (the trip gates its NEXT command), exactly like the
            # degraded-mode interval-boundary semantics.  Containment is
            # two-tier, matching what actually failed:
            #
            # * PASSTHROUGH (diverged QP) contains the SOFTWARE plane only
            #   — command zeroed, warm reset, probing — while the
            #   autonomous hardware ramp filter keeps smoothing (it needs
            #   no solver).  Parking a healthy battery would expose raw
            #   training bursts: ~5% of racks unconditioned already breaks
            #   the campus ramp limit, i.e. the containment would inject
            #   the very transient the conditioner exists to prevent.
            # * QUARANTINE (corrupted state) falls all the way to LC
            #   passthrough: the rack's SoC/filter tracking cannot be
            #   trusted until the reinitialized state survives the
            #   hysteresis window.  The fall is GRACEFUL — the hardware
            #   plane stays live while the last applied command slews to
            #   zero (one interval), then the converter winds down.
            sm_gate = jnp.where(
                (sm.mode == smode.QUARANTINE)
                & (cmd_applied == 0.0) & (cmd_target == 0.0),
                0.0,
                1.0,
            )
            # Converter wind-down / soft-start: the applied ESS weight
            # slews linearly across the interval from its carried value
            # to the gate target.  At weight 0 the node sees RAW rack
            # power (LC passthrough drops the smoothed setpoint g), so a
            # hard 0/1 flip would step the campus waveform in one sample
            # — exactly the transient the conditioner exists to prevent.
            # Clean racks compute 1 + (1-1)*ramp == 1.0 exactly, keeping
            # the supervised clean path bitwise identical.
            ramp_w = (jnp.arange(1, k + 1, dtype=jnp.float32) / k).reshape(
                (k,) + (1,) * sm_gate.ndim
            )
            sm_w = sm.hw_weight + (sm_gate - sm.hw_weight) * ramp_w
            sm = sm._replace(hw_weight=sm_gate)

        # --- hardware path: interval-resident megakernel -----------------
        # One call simulates the whole interval: fused ESS + SoC + LC
        # (1.6x over the staged pipeline, EXPERIMENTS §Perf-1), with the
        # corrective-command slew rendered per step from the (applied,
        # target) rows — the (k, R) ramp profile is never materialized —
        # and, when track_health, the battery-wear fold computed in the
        # same launch (Pallas kernel on TPU keeps all of it in VMEM;
        # the jnp reference preserves the bitwise fold contract, see
        # ref.pdu_health_sim / EXPERIMENTS §Perf-7).
        batched = rack_chunk.ndim > 1
        lift = (lambda x: x) if batched else (lambda x: x[None])
        rc = rack_chunk if batched else rack_chunk[:, None]
        g0, s0, xf0 = lift(es.g_filter), lift(es.soc), lift(x_f)
        if fast:
            # Per-sample ESS weight rendered in-kernel from the episode
            # tables (same boundary selection + clip arithmetic as
            # faults.ess_weight, so bitwise vs the streamed product).
            mask_kw = dict(
                ess_events=(ev_st, ev_en, base_row, i0, t_last),
                ess_edge=fault_edge,
            )
        elif degraded:
            hw = jnp.broadcast_to(hw_chunk, (k,) + batch)
            if safemode:
                hw = hw * sm_w
            mask_kw = dict(ess_on=hw if batched else hw[:, None])
        elif safemode:
            # Same two-plane machinery as degraded mode: non-NORMAL racks
            # wind down to LC passthrough.  An all-ones weight is bitwise-
            # identical to the unmasked kernel path (PR-6 contract), so a
            # clean run with supervision on matches supervision off bit
            # for bit.
            hw = jnp.broadcast_to(sm_w, (k,) + batch)
            mask_kw = dict(ess_on=hw if batched else hw[:, None])
        else:
            mask_kw = {}
        if cfg.track_health:
            health_in = (hconsts, tuple(lift(leaf) for leaf in hstate))
        else:
            health_in = None
        grid, _soc_path, (g_f, soc_f, x_new), h_leaves = ops.pdu_health_sim(
            rc, g0, s0, xf0, filt.ad, filt.bd, filt.c[0],
            slew=(lift(cmd_applied), lift(cmd_target)),
            health=health_in, guard=safemode, **mask_kw, **hw_kw,
        )
        # Campus means over the scan-resident buffers (see Telemetry).
        rack_mean_row = jnp.mean(rc, axis=1)
        grid_mean_row = jnp.mean(grid, axis=1)
        if not batched:
            grid, g_f, soc_f, x_new = grid[:, 0], g_f[0], soc_f[0], x_new[0]
            if cfg.track_health:
                h_leaves = tuple(leaf[0] for leaf in h_leaves)
        es2 = ess.ESSState(g_filter=g_f, soc=soc_f)
        x_f2 = x_new

        # --- health telemetry (folded inside the megakernel) --------------
        if cfg.track_health:
            hstate2 = hlt.HealthState(*h_leaves)
            # Wear feedback reads the PRE-interval state: one control
            # interval (5 s) of staleness is nothing on aging timescales,
            # and it takes the wear fold off the controller's critical
            # path.
            wear = hlt.cycle_life_fraction(cfg.health, hstate)
        else:
            hstate2 = hstate
            wear = jnp.asarray(0.0, jnp.float32)

        # --- software path: one controller step --------------------------
        idle_left = jnp.maximum(
            jnp.asarray(idle_remaining_s, jnp.float32) - step_idx * k * dt, 0.0
        )
        s_target = ctrl.select_target(
            cfg.controller, cfg.ess_params, idle_left, wear
        )
        soc_meas = soc_ema + meas_w * (es2.soc - soc_ema)

        def run_ctrl(soc, up, tgt):
            out = ctrl.inner_loop_step(
                cfg.controller, cfg.ess_params, soc, tgt, up, qp_iters=qp_iters
            )
            return out.corrective_power, out.qp_primal_residual

        if cfg.software_enabled and plan is not None:
            out, warm2 = ctrl.inner_loop_step_plan(
                cfg.controller, cfg.ess_params, plan, soc_meas, s_target,
                u_prev, warm, qp_iters=qp_iters,
                active=on_row if degraded else None,
            )
            new_cmd = out.corrective_power
            resid = out.qp_primal_residual
        elif cfg.software_enabled:
            vec_ctrl = run_ctrl
            for _ in range(soc_meas.ndim):
                vec_ctrl = jax.vmap(vec_ctrl)
            new_cmd, resid = vec_ctrl(
                soc_meas, jnp.broadcast_to(u_prev, soc_meas.shape),
                jnp.broadcast_to(s_target, soc_meas.shape),
            )
            if degraded:
                new_cmd = jnp.where(on_row > 0, new_cmd, 0.0)
                resid = jnp.where(on_row > 0, resid, 0.0)
            warm2 = warm
        else:
            new_cmd = jnp.zeros_like(soc_meas)
            resid = jnp.zeros_like(soc_meas)
            warm2 = warm

        # --- safe mode: ADMM divergence watchdog -------------------------
        soc_row = es2.soc
        if safemode:
            # The watchdog folds the RAW residual (tripped racks keep
            # probing; degraded-offline racks arrive pre-masked to zero so
            # availability faults never read as solver faults), then the
            # post-update mode gates the software plane: no non-NORMAL
            # rack ever commands a live battery, and its warm iterates are
            # reset so the next probe is a deterministic cold start.
            # Per-interval command veto: an over-threshold (or non-finite)
            # solve never gets its command applied, even before the trip
            # streak completes — the rack HOLDS its last accepted command
            # (still approximately right for one interval) instead of
            # slewing toward a diverged iterate.  On clean runs the
            # predicate is never true, so the supervised clean path stays
            # bitwise identical.
            bad_now = (resid > sm_cfg.resid_threshold) | ~jnp.isfinite(resid)
            new_cmd = jnp.where(bad_now, cmd_target, new_cmd)
            sm = smode.residual_update(sm_cfg, sm, resid)
            sm_ok = sm.mode == smode.NORMAL
            new_cmd = jnp.where(sm_ok, new_cmd, 0.0)
            resid = jnp.where(sm_ok, resid, 0.0)
            warm2 = ctrl.reset_warm_where(warm2, ~sm_ok)
            # Telemetry guard: a SoC driven non-finite by this interval's
            # sim stays in the carry (the sanitizer quarantines it next
            # interval) but never reaches campus aggregates.
            soc_row = jnp.where(jnp.isfinite(soc_row), soc_row, s_mid)
        new_u_prev = new_cmd / cfg.controller.i_max

        telem = (
            soc_row, new_cmd, jnp.broadcast_to(s_target, soc_meas.shape), resid,
            # In degraded mode this is the mean of the *bridged* trace (NaN
            # never reaches campus aggregates).
            rack_mean_row, grid_mean_row,
        )
        if degraded:
            # The mask actually applied this interval.
            telem = telem + (on_row,)
        if safemode:
            telem = telem + (sm.mode,)
        carry2 = (
            x_f2, es2, new_u_prev, cmd_target, new_cmd, soc_meas,
            warm2, hstate2, step_idx + 1,
        )
        if fast:
            carry2 = carry2 + (lg,)
        if safemode:
            carry2 = (carry2, sm)
        return carry2, (grid, telem)

    carry0 = (
        state.filter_state, state.ess_state, state.u_prev,
        state.cmd_applied, state.cmd_target, state.soc_ema, state.qp_warm,
        state.health, jnp.asarray(0.0, jnp.float32),
    )
    if fast:
        carry0 = carry0 + (state.last_good,)
        scan_xs = (chunks, on_rows, hw_base, i0_rows)
    elif degraded:
        scan_xs = (chunks, on_rows, hw_chunks)
    else:
        scan_xs = chunks
    if safemode:
        carry0 = (carry0, state.safemode)
    final_carry, (grid_chunks, telem) = jax.lax.scan(interval, carry0, scan_xs)
    if safemode:
        final_carry, sm_f = final_carry
    else:
        sm_f = state.safemode
    if fast:
        last_good2 = final_carry[-1]
        final_carry = final_carry[:-1]
    (x_f, es_f, u_prev, cmd_applied, cmd_target, soc_ema, warm_f, h_f, _) = (
        final_carry
    )
    grid = grid_chunks.reshape((n_ctrl * k,) + rack_power.shape[1:])[:t]
    new_state = PDUState(
        filter_state=x_f, filter_obj=filt, ess_state=es_f, u_prev=u_prev,
        cmd_applied=cmd_applied, cmd_target=cmd_target, soc_ema=soc_ema,
        qp_warm=warm_f, health=h_f,
        ess_online=state.ess_online, last_good=last_good2,
        safemode=sm_f,
    )
    extra = {}
    ti = 6
    if degraded:
        extra["ess_online"] = telem[ti]
        ti += 1
    if safemode:
        extra["safemode_mode"] = telem[ti]
    return grid, new_state, Telemetry(
        soc=telem[0], command=telem[1], target=telem[2], qp_residual=telem[3],
        rack_mean=telem[4].reshape((n_ctrl * k,))[:t],
        grid_mean=telem[5].reshape((n_ctrl * k,))[:t],
        **extra,
    )


class CampusChunk(NamedTuple):
    """Campus aggregates of one conditioned (T, R) chunk (per-unit means)."""

    campus_rack: jax.Array  # (T,) mean unconditioned campus load
    campus_grid: jax.Array  # (T,) mean conditioned campus load
    soc_mean: jax.Array  # (n_ctrl,) fleet-mean SoC per control interval
    max_qp_residual: jax.Array  # () worst QP primal residual in the chunk
    health: jax.Array  # (3,) [mean EFC, max fade, max DoD] at chunk end
    # Fraction of ESS units online per control interval (ones unless the
    # cfg runs degraded_mode) — the honest ramp-budget denominator: a
    # campus passing spec with 30% of units dark is a different claim than
    # one passing at full strength, and this is where that shows.
    ess_online_frac: jax.Array = None
    # Safe-mode supervisor snapshot at chunk end (zeros unless the cfg runs
    # safemode): (6,) [frac_normal, n_passthrough, n_quarantined,
    # entries_total, readmissions_total, worst_resid_streak].
    safemode: jax.Array = None


def condition_campus(
    cfg: PDUConfig,
    state: PDUState,
    rack_power: jax.Array,  # (T, R) per-unit rack traces
    *,
    qp_iters: int = 30,
    use_plan: bool = True,
    ess_online: jax.Array | None = None,
    ess_weight: jax.Array | None = None,
    faults: flt.FaultSchedule | None = None,
    chunk_start: jax.Array | int = 0,
    fault_edge: int = 1,
) -> tuple[PDUState, CampusChunk]:
    """One streaming-campus step: condition a chunk, reduce to aggregates.

    The per-rack grid waveform is reduced to campus means *inside* the same
    computation (XLA fuses the reduction into the conditioning scan), so a
    streaming engine that only needs campus-level compliance never
    materializes the conditioned (T, R) block outside the step.  Shared by
    the host-loop and scanned fleet engines so their per-chunk arithmetic
    is identical by construction.  ``health`` is the fleet wear snapshot at
    the chunk's end (zeros unless ``cfg.track_health``) — the online
    telemetry a campus operator would chart.
    """
    grid, state2, telem = condition(
        cfg, state, rack_power, qp_iters=qp_iters, use_plan=use_plan,
        ess_online=ess_online, ess_weight=ess_weight,
        faults=faults, chunk_start=chunk_start, fault_edge=fault_edge,
    )
    if cfg.track_health:
        hsnap = hlt.chunk_aggregates(cfg.health, state2.health, cfg.sample_dt)
    else:
        hsnap = jnp.zeros((3,), jnp.float32)
    if cfg.degraded_mode:
        # The raw chunk may carry NaN sensor dropouts; the bridged mean
        # from the conditioning scan is the honest campus-load signal.
        on_frac = jnp.mean(telem.ess_online, axis=1)
    else:
        on_frac = jnp.ones(telem.soc.shape[0], jnp.float32)
    # Means come from inside the conditioning scan (see Telemetry): values
    # are bitwise-identical to reducing the (T, R) blocks here, but the
    # rendered chunk keeps a single consumer (no producer duplication) and
    # a campus-only engine never reads the (T, R) grid block at all.
    if cfg.safemode:
        smsnap = smode.chunk_snapshot(state2.safemode)
    else:
        smsnap = jnp.zeros((6,), jnp.float32)
    return state2, CampusChunk(
        campus_rack=telem.rack_mean,
        campus_grid=telem.grid_mean,
        soc_mean=jnp.mean(telem.soc, axis=1),
        max_qp_residual=jnp.max(telem.qp_residual),
        health=hsnap,
        ess_online_frac=on_frac,
        safemode=smsnap,
    )


def combined_transfer_function(cfg: PDUConfig, f_hz: jax.Array) -> jax.Array:
    """|H_total| = |H_ESS| * |H_LC| (paper Fig. 7)."""
    return ess.transfer_function(cfg.ess_params, f_hz) * filters.transfer_function_rack_to_grid(
        cfg.filter_params, f_hz
    )
