"""Grid-region layer: multi-campus conditioning at a point of interconnection.

The paper conditions power at the rack level; what the *grid* sees is the
aggregate of many campuses at a point of interconnection (POI).  This
module scales the scanned conditioner from one campus to a region:

* ``GridRegion`` — N campuses (each a ``power.scenario.Scenario``, with
  heterogeneous rack counts and fault soups) plus their POI weights, the
  POI coupling constants, and the wide-area oscillation band table.
* ``condition_region`` — the region engines behind the ``fleet.condition``
  facade.  The *sequential* engine loops campuses through the scanned
  conditioner and accumulates the POI left-to-right; the *sharded* engine
  stacks the campuses and runs them in parallel under ``shard_map`` over a
  2-D (campus, data) mesh, reducing campus→POI aggregates with in-scan
  ``psum`` collectives.  One campus per campus-shard keeps the ``psum``
  reduction order equal to the sequential left-to-right sum, so the two
  engines are bitwise identical on the POI aggregates (the parity suite
  pins this on a forced 8-device CPU mesh).  The rack axis stays whole
  per campus: per-rack ``psum`` reassociates the campus mean and breaks
  bitwise parity (EXPERIMENTS §Grid-region), and on jax 0.4.x mixing
  ``shard_map`` auto axes with in-body sharding constraints aborts the
  process outright — so the "data" axis is reserved for the GSPMD
  ``shard_racks`` paths and left unmentioned (replicated) here.
* ``poi_response`` — first-order grid coupling: a swing-equation style
  frequency-deviation sensitivity and a proportional voltage-deviation
  estimate at the POI.
* Mode detection — a second Goertzel ``compliance.SpectrumBank`` dense
  over sub-Hz wide-area oscillation bands; per-band verdicts are folded
  into the POI compliance report (``compliance.with_mode_verdicts``).
  Synchronized checkpoint stalls across campuses ring the inter-area band;
  staggering the campus schedules cancels it (see ``checkpoint_region``
  and EXPERIMENTS §Grid-region).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import compliance, fleet, pdu
from repro.sharding import rules
from repro.utils import pytree_dataclass, static_field


# ------------------------------------------------------------ POI coupling


@dataclasses.dataclass(frozen=True)
class POIConfig:
    """First-order coupling constants at the interconnection node.

    Hashable static config (like ``compliance.SpectrumBank``), not traced
    data: it rides in jit closures and engine cache keys.  The swing-style
    model is deliberately first-order — enough to translate a per-unit POI
    power excursion into the frequency/voltage deviations an operator
    would meter, not a network simulation.
    """

    inertia_s: float = 8.0  # M: effective inertia constant [s]
    damping: float = 1.5  # D: load-frequency damping [pu power / pu freq]
    f0_hz: float = 60.0  # nominal system frequency
    v_sens: float = 0.05  # |dV| / dP voltage sensitivity [pu/pu, local bus]
    # The region's rated power as a fraction of the interconnection's
    # frequency-responsive capacity: frequency is a system-wide state, so
    # the region's per-unit excursion is scaled by this before it forces
    # the swing dynamics (voltage deviation stays on the local bus base).
    region_fraction: float = 0.01

    @staticmethod
    def create(**kw) -> "POIConfig":
        return POIConfig(**kw)


class POIResponse(NamedTuple):
    freq_dev_hz: jax.Array  # (T,) frequency deviation at the POI [Hz]
    volt_dev: jax.Array  # (T,) voltage deviation at the POI [pu]
    max_freq_dev_hz: jax.Array  # () worst |freq_dev|
    max_volt_dev: jax.Array  # () worst |volt_dev|


def poi_response(
    poi_power: jax.Array,
    poi: POIConfig,
    dt: float,
    p_ref: jax.Array | None = None,
) -> POIResponse:
    """Swing-style POI sensitivity:  M df/dt = -(ΔP + D·f),  ΔV = -k_v·ΔP.

    ``poi_power`` is the per-unit POI trace; deviations are taken against
    ``p_ref`` (default: the trace mean — the scheduled interchange a
    balanced dispatch would net out).  Per-unit frequency integrates
    through a forward-Euler scan and scales by ``f0_hz``.
    """

    def build():
        @jax.jit
        def run(p, ref):
            dp = p - ref
            a = jnp.float32(dt / poi.inertia_s)
            damp = jnp.float32(poi.damping)
            dp_sys = jnp.float32(poi.region_fraction) * dp

            def step(f, d):
                f2 = f + a * (-d - damp * f)
                return f2, f2

            _, fdev = jax.lax.scan(step, jnp.float32(0.0), dp_sys)
            freq = fdev * jnp.float32(poi.f0_hz)
            volt = -jnp.float32(poi.v_sens) * dp
            return POIResponse(
                freq_dev_hz=freq,
                volt_dev=volt,
                max_freq_dev_hz=jnp.max(jnp.abs(freq)),
                max_volt_dev=jnp.max(jnp.abs(volt)),
            )

        return run

    run = fleet._cached_engine(("poi_response", poi, float(dt)), build)
    poi_power = jnp.asarray(poi_power, jnp.float32)
    ref = jnp.mean(poi_power) if p_ref is None else jnp.asarray(p_ref, jnp.float32)
    return run(poi_power, ref)


# ----------------------------------------------------------- mode detector


@dataclasses.dataclass(frozen=True)
class ModeBand:
    """One wide-area oscillation band: flag when any monitored line inside
    [lo_hz, hi_hz) exceeds ``threshold`` (normalized one-sided magnitude,
    same units as ``compliance.normalized_spectrum``)."""

    name: str
    lo_hz: float
    hi_hz: float
    threshold: float


# Classic wide-area ranges: inter-area modes live well below 1 Hz, local /
# intra-plant modes up to a few Hz.  Thresholds are per-unit-of-rating
# magnitudes calibrated on the synchronized-checkpoint scenario
# (EXPERIMENTS §Grid-region): synchronized campuses ring the inter-area
# band an order of magnitude above threshold; staggered campuses sit well
# below it.
DEFAULT_MODE_BANDS = (
    ModeBand("inter_area", 0.1, 1.0, 0.005),
    ModeBand("local_plant", 1.0, 3.0, 0.005),
)


def mode_bank(
    n_total: int,
    dt: float,
    bands: tuple[ModeBand, ...] = DEFAULT_MODE_BANDS,
    *,
    max_lines_per_band: int = 96,
) -> compliance.SpectrumBank:
    """A Goertzel bank dense over the mode bands of a length-``n_total``
    trace: every DFT bin inside each band (evenly strided down to
    ``max_lines_per_band`` lines when a band spans more bins), Hann
    windowed so finalized magnitudes match ``normalized_spectrum``."""
    bins: set[int] = set()
    for b in bands:
        k_lo = max(int(np.ceil(b.lo_hz * n_total * dt)), 1)
        k_hi = min(int(np.floor(b.hi_hz * n_total * dt)), n_total // 2)
        if k_hi < k_lo:
            continue
        ks = np.arange(k_lo, k_hi + 1, dtype=np.int64)
        if ks.size > max_lines_per_band:
            ks = np.unique(
                np.round(np.linspace(k_lo, k_hi, max_lines_per_band)).astype(np.int64)
            )
        bins.update(int(x) for x in ks)
    return compliance.SpectrumBank(
        bins=tuple(sorted(bins)), modulus=int(n_total), dt=float(dt), window="hann"
    )


def mode_verdicts(
    bank: compliance.SpectrumBank,
    obs: compliance.SpectrumObserver,
    bands: tuple[ModeBand, ...],
) -> tuple[jax.Array, jax.Array]:
    """(mags, ok) per band: worst monitored-line magnitude inside each band
    and its threshold verdict.  A band with no line on this trace's grid
    (trace too short to resolve it) reports magnitude 0 and passes."""
    freqs, mags = compliance.spectrum_observer_finalize(bank, obs)
    out_m, out_ok = [], []
    for b in bands:
        sel = (freqs >= b.lo_hz) & (freqs < b.hi_hz)
        if not np.any(sel):
            out_m.append(jnp.float32(0.0))
            out_ok.append(jnp.asarray(True))
            continue
        m = jnp.max(jnp.where(jnp.asarray(sel), mags, 0.0))
        out_m.append(m)
        out_ok.append(m <= b.threshold)
    return jnp.stack(out_m), jnp.stack(out_ok)


# -------------------------------------------------------------- GridRegion


@pytree_dataclass
class GridRegion:
    """N campuses aggregated at a point of interconnection.

    ``campuses`` is a tuple of per-campus ``Scenario`` pytrees (traced
    children — heterogeneous rack counts and fault soups are fine for the
    sequential engine; the sharded engine additionally needs the campuses
    stackable: same statics, rack count, and fault-schedule shape).
    ``weights`` is the (C,) per-unit POI share of each campus (the POI
    trace is ``sum_c w_c * campus_c``); POI coupling and the mode-band
    table are static config.  Build with ``region(...)``.
    """

    campuses: tuple
    weights: jax.Array
    names: tuple = static_field(default=())
    poi: POIConfig = static_field(default=POIConfig())
    bands: tuple = static_field(default=DEFAULT_MODE_BANDS)

    @property
    def n_campuses(self) -> int:
        return len(self.campuses)

    @property
    def sample_hz(self) -> float:
        return self.campuses[0].sample_hz

    @property
    def total_samples(self) -> int:
        return self.campuses[0].total_samples

    @property
    def n_racks(self) -> tuple:
        return tuple(c.n_racks or 1 for c in self.campuses)


def region(
    campuses,
    *,
    weights=None,
    names=None,
    poi: POIConfig | None = None,
    bands: tuple[ModeBand, ...] = DEFAULT_MODE_BANDS,
    salt_noise: bool = True,
) -> GridRegion:
    """Build a ``GridRegion`` from per-campus scenarios.

    Campuses must share the sample rate and trace length (one POI clock).
    ``weights`` defaults to the rack-count share, so the POI trace is the
    per-unit mean over the region's racks.  ``salt_noise`` XORs a distinct
    ``noise_salt`` into each campus that has measurement noise but no salt
    yet — campuses built from the same workload spec then draw
    decorrelated noise even though the sharded engine requires them to
    share the static ``noise_seed``.
    """
    from repro.power import scenario as SC

    campuses = tuple(campuses)
    if not campuses:
        raise ValueError("a region needs at least one campus")
    hz, total = campuses[0].sample_hz, campuses[0].total_samples
    for i, c in enumerate(campuses[1:], 1):
        if c.sample_hz != hz or c.total_samples != total:
            raise ValueError(
                f"campus {i} runs {c.sample_hz} Hz x {c.total_samples} "
                f"samples but campus 0 runs {hz} Hz x {total}; one POI "
                "clock requires a shared rate and length"
            )
    if salt_noise:
        campuses = tuple(
            c if (c.noise_seed is None or c.noise_salt is not None)
            else SC.with_noise_salt(c, i)
            for i, c in enumerate(campuses)
        )
    if weights is None:
        w = np.asarray([c.n_racks or 1 for c in campuses], np.float32)
        weights = w / w.sum()
    weights = jnp.asarray(weights, jnp.float32)
    if weights.shape != (len(campuses),):
        raise ValueError(
            f"weights shape {weights.shape} != ({len(campuses)},)")
    names = tuple(names) if names else tuple(
        f"campus{i}" for i in range(len(campuses)))
    if len(names) != len(campuses):
        raise ValueError(f"{len(names)} names for {len(campuses)} campuses")
    return GridRegion(
        campuses=campuses,
        weights=weights,
        names=names,
        poi=poi if poi is not None else POIConfig(),
        bands=tuple(bands),
    )


def checkpoint_region(
    n_campuses: int = 4,
    n_racks: int = 64,
    *,
    duration_s: float = 200.0,
    sample_hz: float = 50.0,
    dip_period_s: float = 8.0,
    dip_duration_s: float = 2.0,
    p_dip: float = 0.12,
    stagger: bool = False,
    noise_seed: int | None = 0,
    poi: POIConfig | None = None,
    bands: tuple[ModeBand, ...] = DEFAULT_MODE_BANDS,
) -> GridRegion:
    """The wide-area oscillation testbench: N identical campuses whose only
    periodic structure is the checkpoint stall (compute plateau, no
    comm wave), checkpointing every ``dip_period_s``.

    ``stagger=False`` checkpoints every campus in lockstep — the POI rings
    the dip fundamental (1/``dip_period_s``, inside the inter-area band at
    the defaults) and its harmonics.  ``stagger=True`` offsets campus c's
    schedule by ``c/N`` of the dip period, cancelling every harmonic that
    is not a multiple of N (and the N-th falls on a sinc null of the dip
    duty cycle at the defaults) — the mode detector passes.
    """
    from repro.power import scenario as SC

    campuses = []
    for c in range(n_campuses):
        off = (c * dip_period_s / n_campuses) if stagger else 0.0
        w = SC.workload(
            comm_fraction=0.0,
            p_comm=0.92,
            dip_period_s=dip_period_s,
            dip_duration_s=dip_duration_s,
            p_dip=p_dip,
            warmup_s=2.0,
            t_start_s=np.full((n_racks,), off, np.float32),
        )
        campuses.append(SC.make_scenario(
            w, duration_s=duration_s, sample_hz=sample_hz,
            edge_pad="clamp", noise_seed=noise_seed,
        ))
    return region(campuses, poi=poi, bands=bands)


def synchronized_region(**kw) -> GridRegion:
    """``checkpoint_region`` with lockstep campus checkpoints (rings the
    inter-area mode band)."""
    return checkpoint_region(stagger=False, **kw)


def staggered_region(**kw) -> GridRegion:
    """``checkpoint_region`` with campus checkpoints staggered across the
    dip period (the mode cancels at the POI)."""
    return checkpoint_region(stagger=True, **kw)


# ---------------------------------------------------------- POI observers


class _POIObservers(NamedTuple):
    """Streaming compliance state for the POI traces: ramp + spec-line
    observers on the unconditioned/conditioned POI, plus the mode-band
    Goertzel fold on the conditioned POI."""

    ramp_rack: compliance.RampObserver
    ramp_grid: compliance.RampObserver
    spec_rack: compliance.SpectrumObserver
    spec_grid: compliance.SpectrumObserver
    modes: compliance.SpectrumObserver


def _poi_observers_init(bank, mbank) -> _POIObservers:
    return _POIObservers(
        ramp_rack=compliance.ramp_observer_init(),
        ramp_grid=compliance.ramp_observer_init(),
        spec_rack=compliance.spectrum_observer_init(bank),
        spec_grid=compliance.spectrum_observer_init(bank),
        modes=compliance.spectrum_observer_init(mbank),
    )


def _poi_observers_update(po, bank, mbank, pr, pg, dt) -> _POIObservers:
    return _POIObservers(
        ramp_rack=compliance.ramp_observer_update(po.ramp_rack, pr, dt),
        ramp_grid=compliance.ramp_observer_update(po.ramp_grid, pg, dt),
        spec_rack=compliance.spectrum_observer_update(bank, po.spec_rack, pr),
        spec_grid=compliance.spectrum_observer_update(bank, po.spec_grid, pg),
        modes=compliance.spectrum_observer_update(mbank, po.modes, pg),
    )


def _poi_fold(bank, mbank, chunk, n_full, rem, dt):
    """Cached jitted fold of the POI observers over materialized POI traces
    with the SAME chunk partition the sharded engine folds in-scan — the
    Goertzel accumulation is chunk-partition sensitive, so matching the
    partition is part of the bitwise parity contract."""

    def build():
        @jax.jit
        def run(pr, pg):
            po = _poi_observers_init(bank, mbank)
            if n_full:
                def body(po, xs):
                    cr, cg = xs
                    return _poi_observers_update(
                        po, bank, mbank, cr, cg, dt), None

                po, _ = jax.lax.scan(
                    body, po,
                    (pr[: n_full * chunk].reshape(n_full, chunk),
                     pg[: n_full * chunk].reshape(n_full, chunk)),
                )
            if rem:
                po = _poi_observers_update(
                    po, bank, mbank,
                    pr[n_full * chunk:], pg[n_full * chunk:], dt,
                )
            return po

        return run

    return fleet._cached_engine(
        ("poi_fold", bank, mbank, chunk, n_full, rem, dt), build)


# -------------------------------------------------------------- engines


def _chunk_geometry(cfg, region_or_scen, chunk_intervals, start, stop):
    k = max(int(round(float(cfg.controller.dt) / cfg.sample_dt)), 1)
    chunk = max(int(chunk_intervals), 1) * k
    total = region_or_scen.total_samples
    stop = total if stop is None else int(stop)
    start = int(start)
    if not 0 <= stop <= total:
        raise ValueError(f"stop_sample {stop} outside the region ({total} samples)")
    if start < 0 or start % k:
        raise ValueError(
            f"start_sample {start} must be a non-negative multiple of the "
            f"controller interval ({k} samples)")
    t_total = stop - start
    if t_total <= 0:
        raise ValueError(f"start_sample {start} is past the region end ({stop})")
    n_full, rem = divmod(t_total, chunk)
    n_ctrl = -(-t_total // k)
    return k, chunk, start, stop, t_total, n_full, rem, n_ctrl


def _assemble_region_result(
    cfg, reg, grid_spec, per, campus_rack, campus_grid, soc_mean,
    health_trace, ess_frac, max_qp, poi_rack, poi_grid, po, bank, mbank,
    sm_trace=None,
) -> fleet.ConditioningResult:
    rep_rack = compliance.report_from_observers(
        grid_spec, po.ramp_rack, bank, po.spec_rack)
    rep_grid = compliance.report_from_observers(
        grid_spec, po.ramp_grid, bank, po.spec_grid)
    mags, ok = mode_verdicts(mbank, po.modes, reg.bands)
    rep_poi = compliance.with_mode_verdicts(rep_grid, mags, ok)
    resp = poi_response(poi_grid, reg.poi, cfg.sample_dt)
    return fleet.ConditioningResult(
        campus_rack=campus_rack,
        campus_grid=campus_grid,
        report_rack=rep_rack,
        report_grid=rep_poi,
        soc_mean=soc_mean,
        state=tuple(p.state for p in per),
        max_qp_residual=max_qp,
        health_trace=health_trace,
        ess_online_frac=ess_frac,
        safemode_trace=sm_trace,
        poi_rack=poi_rack,
        poi_grid=poi_grid,
        report_poi=rep_poi,
        poi_freq_dev=resp.freq_dev_hz,
        poi_volt_dev=resp.volt_dev,
        per_campus=tuple(per),
        weights=reg.weights,
        grid_spec=grid_spec,
        bank=bank,
        observers=fleet._Observers(
            po.ramp_rack, po.ramp_grid, po.spec_rack, po.spec_grid),
    )


def _oracle_mesh() -> jax.sharding.Mesh:
    """A (campus=1, data=1) mesh on the first local device — exists on any
    host, so the sequential oracle can run each campus through the same
    shard_map-compiled engine the sharded path uses.  XLA compiles a
    shard_map body slightly differently from the plain-jit scanned engine
    (~1 ulp drift in the conditioned trace on CPU), so staying inside
    shard_map for BOTH region engines is what makes them bitwise identical
    on campus and POI aggregates (the parity contract)."""
    return rules.region_mesh(1, devices=jax.devices()[:1])


def condition_region_sequential(
    cfg: pdu.PDUConfig,
    reg: GridRegion,
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 30,
    chunk_intervals: int = 16,
    states=None,
    start_sample: int = 0,
    stop_sample: int | None = None,
) -> fleet.ConditioningResult:
    """The region oracle: each campus through the region engine in turn on
    a single device, POI accumulated left-to-right (the order the sharded
    engine's ``psum`` reduces in), POI observers folded with the engines'
    shared chunk partition.  Handles heterogeneous rack counts; wall-clock
    scales with N campuses.  Bitwise identical to
    ``condition_region_sharded`` on campus and POI aggregates."""
    C = reg.n_campuses
    states = (None,) * C if states is None else tuple(states)
    if len(states) != C:
        raise ValueError(f"{len(states)} states for {C} campuses")
    k, chunk, start, stop, t_total, n_full, rem, n_ctrl = _chunk_geometry(
        cfg, reg, chunk_intervals, start_sample, stop_sample)
    mesh1 = _oracle_mesh()
    one = jnp.ones((1,), jnp.float32)
    per = []
    for c, scen in enumerate(reg.campuses):
        sub = GridRegion(
            campuses=(scen,), weights=one, names=(reg.names[c],),
            poi=reg.poi, bands=reg.bands,
        )
        r = condition_region_sharded(
            cfg, sub, grid_spec, mesh1, soc0=soc0, qp_iters=qp_iters,
            chunk_intervals=chunk_intervals, states=(states[c],),
            start_sample=start, stop_sample=stop,
        )
        per.append(r.per_campus[0])
    w = reg.weights
    add = lambda a, b: a + b
    poi_rack = functools.reduce(
        add, [w[c] * per[c].campus_rack for c in range(C)])
    poi_grid = functools.reduce(
        add, [w[c] * per[c].campus_grid for c in range(C)])
    bank = fleet._make_bank(grid_spec, cfg, t_total)
    mbank = mode_bank(t_total, cfg.sample_dt, reg.bands)
    po = _poi_fold(bank, mbank, chunk, n_full, rem, cfg.sample_dt)(
        poi_rack, poi_grid)
    return _assemble_region_result(
        cfg, reg, grid_spec, per,
        campus_rack=jnp.stack([p.campus_rack for p in per]),
        campus_grid=jnp.stack([p.campus_grid for p in per]),
        soc_mean=jnp.stack([p.soc_mean for p in per]),
        health_trace=jnp.stack([p.health_trace for p in per]),
        ess_frac=jnp.stack([p.ess_online_frac for p in per]),
        max_qp=functools.reduce(
            jnp.maximum, [p.max_qp_residual for p in per]),
        poi_rack=poi_rack, poi_grid=poi_grid, po=po, bank=bank, mbank=mbank,
        sm_trace=jnp.stack([p.safemode_trace for p in per]),
    )


def _stack_campuses(reg: GridRegion):
    try:
        return jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *reg.campuses)
    except (ValueError, TypeError) as e:
        raise ValueError(
            "the sharded region engine stacks campuses into one batched "
            "scenario, which requires every campus to share its structure "
            "(statics, rack count, fault-schedule shape); heterogeneous "
            f"regions run the sequential engine (mesh=None): {e}"
        ) from None


def _region_engine(cfg, qp_iters, chunk, k, n_full, rem, mesh, bank, mbank):
    """Cached jitted shard_map engine: every campus's scan runs in parallel
    on its own campus-shard; per-chunk POI aggregates reduce with in-scan
    ``psum`` over the "campus" axis (bitwise equal to the left-to-right
    sequential sum — one campus per shard).  Everything is *manual* over
    the campus axis and replicated over the rest of the mesh: no auto
    axes, no in-body sharding constraints (jax 0.4.x aborts the process
    on that combination — see ``rules.shard_map_compat``)."""
    caxis = "campus"

    def build():
        def shard_body(scen_s, st_s, w_s, start):
            take0 = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
            scen, st, wl = take0(scen_s), take0(st_s), w_s[0]
            obs = fleet._observers_init(bank)
            po = _poi_observers_init(bank, mbank)

            def fold(st, obs, po, t0, n):
                st2, ch = fleet._condition_chunk(
                    cfg, scen, st, t0, n, k=k, qp_iters=qp_iters)
                obs2 = fleet._observers_update(obs, bank, ch, cfg.sample_dt)
                pr = jax.lax.psum(wl * ch.campus_rack, caxis)
                pg = jax.lax.psum(wl * ch.campus_grid, caxis)
                po2 = _poi_observers_update(
                    po, bank, mbank, pr, pg, cfg.sample_dt)
                return st2, obs2, po2, ch, pr, pg

            parts, prs, pgs, worst, htrace, strace = [], [], [], [], [], []
            if n_full:
                def body(carry, c_idx):
                    st, obs, po = carry
                    st2, obs2, po2, ch, pr, pg = fold(
                        st, obs, po, start + c_idx * chunk, chunk)
                    return (st2, obs2, po2), (ch, pr, pg)

                (st, obs, po), (ch, pr, pg) = jax.lax.scan(
                    body, (st, obs, po),
                    jnp.arange(n_full, dtype=jnp.int32))
                parts.append(pdu.CampusChunk(
                    ch.campus_rack.reshape(-1), ch.campus_grid.reshape(-1),
                    ch.soc_mean.reshape(-1), None, None,
                    ch.ess_online_frac.reshape(-1),
                ))
                prs.append(pr.reshape(-1))
                pgs.append(pg.reshape(-1))
                worst.append(jnp.max(ch.max_qp_residual))
                htrace.append(ch.health)
                strace.append(ch.safemode)
            if rem:
                st, obs, po, ch, pr, pg = fold(
                    st, obs, po, start + n_full * chunk, rem)
                parts.append(ch)
                prs.append(pr)
                pgs.append(pg)
                worst.append(ch.max_qp_residual)
                htrace.append(ch.health[None])
                strace.append(ch.safemode[None])
            cat = lambda xs: xs[0] if len(xs) == 1 else jnp.concatenate(xs)
            camp = pdu.CampusChunk(
                campus_rack=cat([p.campus_rack for p in parts]),
                campus_grid=cat([p.campus_grid for p in parts]),
                soc_mean=cat([p.soc_mean for p in parts]),
                max_qp_residual=functools.reduce(jnp.maximum, worst),
                health=cat(htrace),
                ess_online_frac=cat([p.ess_online_frac for p in parts]),
                safemode=cat(strace),
            )
            lift = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
            return lift(st), lift(camp), lift(obs), cat(prs), cat(pgs), po

        f = rules.shard_map_compat(
            shard_body, mesh,
            in_specs=(P(caxis), P(caxis), P(caxis), P()),
            out_specs=(P(caxis), P(caxis), P(caxis), P(), P(), P()),
        )
        return jax.jit(f, donate_argnums=(1,))

    return fleet._cached_engine(
        fleet._engine_key(
            cfg, "region", qp_iters, chunk, k, n_full, rem, mesh, bank, mbank
        ),
        build,
    )


def condition_region_sharded(
    cfg: pdu.PDUConfig,
    reg: GridRegion,
    grid_spec: compliance.GridSpec,
    mesh: jax.sharding.Mesh,
    *,
    soc0: float = 0.5,
    qp_iters: int = 30,
    chunk_intervals: int = 16,
    states=None,
    start_sample: int = 0,
    stop_sample: int | None = None,
) -> fleet.ConditioningResult:
    """Every campus in parallel under ``shard_map``: one jitted dispatch
    conditions the whole region, with the POI reduced by in-scan ``psum``.
    Requires a mesh with a "campus" axis of exactly ``n_campuses`` shards
    (``rules.region_mesh``) and stackable campuses; bitwise equal to
    ``condition_region_sequential`` on campus and POI aggregates."""
    from repro.power import scenario as SC

    C = reg.n_campuses
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if "campus" not in axis_sizes:
        raise ValueError(
            f"mesh axes {mesh.axis_names} lack the 'campus' axis; build the "
            "region mesh with rules.region_mesh(n_campuses)")
    if axis_sizes["campus"] != C:
        raise ValueError(
            f"mesh has {axis_sizes['campus']} campus shards for {C} "
            "campuses; exactly one campus per shard keeps the psum "
            "reduction order equal to the sequential left-to-right sum "
            "(the bitwise-parity contract)")
    for scen in reg.campuses:
        fleet._check_scenario_rate(scen, cfg)
        fleet._check_scenario_faults(scen, cfg)
    k, chunk, start, stop, t_total, n_full, rem, n_ctrl = _chunk_geometry(
        cfg, reg, chunk_intervals, start_sample, stop_sample)

    states = (None,) * C if states is None else tuple(states)
    if len(states) != C:
        raise ValueError(f"{len(states)} states for {C} campuses")
    if any(s is None for s in states):
        if not all(s is None for s in states):
            raise ValueError(
                "per-campus resume states must be all-None (fresh start) "
                "or all present")

        def init_one(scen):
            r0 = SC.render(scen, start, 1)[0]
            if r0.ndim == 0:
                r0 = r0[None]
            return pdu.init_state(cfg, r0, soc0=soc0)

        states = tuple(init_one(scen) for scen in reg.campuses)
    # Stacking copies, so the donated stacked state never aliases the
    # caller's checkpoint.
    st_s = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
    scen_s = _stack_campuses(reg)

    bank = fleet._make_bank(grid_spec, cfg, t_total)
    mbank = mode_bank(t_total, cfg.sample_dt, reg.bands)
    run = _region_engine(
        cfg, qp_iters, chunk, k, n_full, rem, mesh, bank, mbank)
    st_f, camp, obs_s, poi_rack, poi_grid, po = run(
        scen_s, st_s, reg.weights, jnp.asarray(start, jnp.int32))

    take = lambda t, c: jax.tree_util.tree_map(lambda x: x[c], t)
    campus_rack = camp.campus_rack[:, :t_total]
    campus_grid = camp.campus_grid[:, :t_total]
    soc_mean = camp.soc_mean[:, :n_ctrl]
    ess_frac = camp.ess_online_frac[:, :n_ctrl]
    per = [
        fleet._finish_streaming(
            cfg, grid_spec, take(st_f, c),
            campus_rack[c], campus_grid[c], soc_mean[c],
            camp.max_qp_residual[c], bank, take(obs_s, c),
            camp.health[c], ess_frac[c], camp.safemode[c],
        )
        for c in range(C)
    ]
    return _assemble_region_result(
        cfg, reg, grid_spec, per,
        campus_rack=campus_rack,
        campus_grid=campus_grid,
        soc_mean=soc_mean,
        health_trace=camp.health,
        ess_frac=ess_frac,
        max_qp=jnp.max(camp.max_qp_residual),
        poi_rack=poi_rack[:t_total],
        poi_grid=poi_grid[:t_total],
        po=po, bank=bank, mbank=mbank,
        sm_trace=camp.safemode,
    )


def condition_region(
    cfg: pdu.PDUConfig,
    reg: GridRegion,
    grid_spec: compliance.GridSpec,
    *,
    mesh: jax.sharding.Mesh | None = None,
    **kwargs,
) -> fleet.ConditioningResult:
    """Region dispatch behind ``fleet.condition``: a mesh selects the
    sharded shard_map engine, ``mesh=None`` the sequential oracle."""
    if mesh is not None:
        return condition_region_sharded(cfg, reg, grid_spec, mesh, **kwargs)
    return condition_region_sequential(cfg, reg, grid_spec, **kwargs)
