"""Fleet-scale aggregation (paper Appendix D, Fig. 13).

The campus load is the sum of per-rack loads; the DFT is linear, so for N
racks in synchrony  P_IT(t) = N * P_i(t)  and  S_IT(f) = N * S_i(f).
Per-rack compliance therefore composes: a hall of EasyRider racks meets the
same (beta, alpha, f_c) budget in aggregate.

This module simulates heterogeneous fleets — per-rack phase offsets
(staggered schedulers), per-rack power scales, rack failures mid-trace —
with the rack dimension vectorized (racks ride in the trailing axis of
every core function, which the Pallas kernels map onto the 128-wide lane
dimension).  For very large fleets the rack axis can be sharded over the
same device mesh the trainer uses (`shard_racks`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compliance, health as hlt, pdu, profiling as _prof, \
    safemode as smode
from repro.sharding.rules import shard_racks, shard_racks_in_jit  # noqa: F401
# (mesh utilities live in ``sharding.rules`` now; re-exported here for
# compatibility — ``fleet.shard_racks`` keeps working.)


def synchronous_aggregate(rack_power: jax.Array, n_racks: int) -> jax.Array:
    """Eq. 19: P_IT = N * P_i for lockstep racks (per-unit of campus rating)."""
    return rack_power  # per-unit traces are scale-invariant (Eq. 20)


def staggered_fleet(
    rack_trace: jax.Array,  # (T,)
    n_racks: int,
    key: jax.Array,
    *,
    max_offset_samples: int = 0,
    scale_jitter: float = 0.0,
) -> jax.Array:
    """(T, n_racks) traces: rolled copies with optional per-rack scaling."""
    k1, k2 = jax.random.split(key)
    if max_offset_samples > 0:
        offsets = jax.random.randint(k1, (n_racks,), 0, max_offset_samples)
    else:
        offsets = jnp.zeros((n_racks,), jnp.int32)
    scales = 1.0 + scale_jitter * jax.random.uniform(k2, (n_racks,), minval=-1.0, maxval=1.0)

    def one(off, sc):
        return jnp.roll(rack_trace, off) * sc

    return jax.vmap(one, out_axes=1)(offsets, scales)


def apply_failures(
    traces: jax.Array,  # (T, R)
    fail_times: jax.Array,  # (R,) sample index at which the rack drops to idle
    p_idle: float = 0.1,
) -> jax.Array:
    """Racks drop to idle power at their failure time (-1 = never).

    Compatibility shim: scripted rack power loss is first-class scenario
    data now (``power.faults`` — attach a ``FaultSchedule`` to the scenario
    and the renderer applies it chunk-bitwise).  This helper packs the old
    fail-time vector into a single-episode schedule and stamps it onto an
    already-materialized trace block; prefer ``scenario.attach_faults`` for
    anything new.
    """
    from repro.power import faults as FLT

    t, r = traces.shape
    ft = np.asarray(fail_times)
    sched = FLT.schedule_from_episodes(
        r, rack=[(i, int(ft[i]), t) for i in range(r) if ft[i] >= 0],
        p_fault=p_idle,
    )
    return jnp.where(FLT.rack_down(sched, 0, t), p_idle, traces)


class ConditioningResult(NamedTuple):
    """The one result type every conditioning engine returns.

    Optional fields are ``None`` when the producing engine does not track
    them: the one-shot engine has no streaming state or observers, the
    streaming engines never materialize per-rack grid traces, and the POI /
    per-campus fields exist only for grid regions (``core.grid``, where the
    campus aggregates gain a leading ``(C,)`` campus axis).
    """

    campus_rack: jax.Array = None  # (T,) mean per-unit unconditioned load
    campus_grid: jax.Array = None  # (T,) mean per-unit conditioned load
    report_rack: compliance.ComplianceReport = None
    report_grid: compliance.ComplianceReport = None
    # Per-rack wear report; when the config does not track health this is
    # the report of an empty history (zero cycles/fade, INFINITE projected
    # lifetime — serialize via ``health.fleet_summary(..., json_safe=True)``).
    health: hlt.HealthReport = None
    # --- one-shot engine extras
    grid_traces: jax.Array = None  # (T, R) conditioned per-rack
    # --- streaming engine extras
    soc_mean: jax.Array = None  # (n_ctrl,) fleet-mean SoC per interval
    state: pdu.PDUState = None  # final PDU state (the stream can resume);
    #   a grid region carries a tuple of per-campus states instead.
    max_qp_residual: jax.Array = None  # worst QP primal residual seen
    health_trace: jax.Array = None  # (n_chunks, 3) [mean EFC, max fade, max DoD]
    # (n_ctrl,) fraction of ESS units online per control interval (ones
    # unless the cfg runs degraded_mode under a fault schedule).
    ess_online_frac: jax.Array = None
    # (n_chunks, 6) safe-mode supervisor snapshot per chunk — the
    # ``pdu.CampusChunk.safemode`` rows (zeros unless the cfg runs
    # safemode; grid regions carry a leading campus axis).
    safemode_trace: jax.Array = None
    # --- grid-region extras (``core.grid``)
    poi_rack: jax.Array = None  # (T,) POI unconditioned (weighted campus sum)
    poi_grid: jax.Array = None  # (T,) POI conditioned
    report_poi: compliance.ComplianceReport = None  # POI report + mode verdicts
    poi_freq_dev: jax.Array = None  # (T,) swing-model frequency deviation [Hz]
    poi_volt_dev: jax.Array = None  # (T,) first-order voltage deviation [pu]
    per_campus: tuple = None  # per-campus ConditioningResults
    weights: jax.Array = None  # (C,) campus POI weights
    # --- observability handles (streaming engines) backing ``.report()``
    grid_spec: compliance.GridSpec = None
    bank: compliance.SpectrumBank = None
    observers: "_Observers" = None

    def report(self, which: str = "grid") -> compliance.ComplianceReport:
        """Compliance report, re-derived from the streaming observers.

        ``which`` selects the stream: ``"rack"`` (unconditioned),
        ``"grid"`` (conditioned — the default), or ``"poi"`` (grid regions;
        the conditioned POI stream with mode-band verdicts folded in).
        Engines without observers (the one-shot path) return their stored
        whole-trace report unchanged.
        """
        stored = {"rack": self.report_rack, "grid": self.report_grid,
                  "poi": self.report_poi}
        if which not in stored:
            raise ValueError(
                f"which={which!r} (expected 'rack', 'grid' or 'poi')")
        pre = stored[which]
        if self.observers is None or self.bank is None or self.grid_spec is None:
            return pre
        key = "grid" if which == "poi" else which
        rep = compliance.report_from_observers(
            self.grid_spec,
            getattr(self.observers, f"ramp_{key}"),
            self.bank,
            getattr(self.observers, f"spec_{key}"),
        )
        if pre is not None and pre.mode_mags is not None:
            rep = compliance.with_mode_verdicts(rep, pre.mode_mags, pre.mode_ok)
        return rep

    def safemode_summary(self) -> dict | None:
        """Host-side safe-mode supervisor summary from the final state(s).

        ``None`` when the engine carried no state or the config did not run
        safemode; a grid region sums the per-campus states and keys the
        rack lists by campus index.
        """
        if self.state is None:
            return None
        # NB: PDUState is itself a NamedTuple — only a *plain* tuple means
        # a grid region's per-campus states.
        states = (
            (self.state,)
            if isinstance(self.state, pdu.PDUState)
            else tuple(self.state)
        )
        if any(getattr(st, "safemode", None) is None for st in states):
            return None
        parts = [smode.summary(st.safemode) for st in states]
        out = dict(parts[0])
        if len(parts) > 1:
            for key in ("n_normal", "n_passthrough", "n_quarantined",
                        "passthrough_entries", "quarantine_entries",
                        "readmissions"):
                out[key] = sum(p[key] for p in parts)
            out["worst_resid_streak"] = max(
                p["worst_resid_streak"] for p in parts)
            out["passthrough_racks"] = {
                c: p["passthrough_racks"] for c, p in enumerate(parts)}
            out["quarantined_racks"] = {
                c: p["quarantined_racks"] for c, p in enumerate(parts)}
        return out


# Deprecated aliases: every engine returns ``ConditioningResult`` now, with
# the former FleetResult / StreamingFleetResult fields as a subset.  New
# code should name ``ConditioningResult`` (or just use the facade).
FleetResult = ConditioningResult
StreamingFleetResult = ConditioningResult


def _condition_fleet_impl(
    cfg: pdu.PDUConfig,
    traces: jax.Array,  # (T, R) per-unit rack traces
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 60,
    use_plan: bool = True,
    ess_online: jax.Array | None = None,
    ess_weight: jax.Array | None = None,
) -> ConditioningResult:
    """Condition every rack with its own PDU; check campus compliance.

    The per-rack state is fully vectorized (rack axis rides through the
    scans), so this is one fused XLA computation whatever R is.
    ``use_plan=False`` selects the per-rack build+factor controller path
    (the seed cold-start baseline used by benchmarks).

    ``ess_online`` (requires ``cfg.degraded_mode``) is the per-interval ESS
    availability mask — ``(n_ctrl, R)`` rows or one ``(R,)`` mask — with
    the same semantics as ``pdu.condition``; ``ess_weight`` is the
    optional per-sample ``(T, R)`` hardware availability weight
    (``faults.ess_weight``).  NaN sensor-dropout samples in ``traces`` are
    bridged before conditioning, so campus aggregates and compliance stay
    finite under any fault schedule.
    """
    r0 = traces[0]  # init_state bridges NaN (sensor-dark) entries itself
    state = pdu.init_state(cfg, r0, soc0=soc0)
    grid, state_f, telem = pdu.condition(
        cfg, state, traces, qp_iters=qp_iters, use_plan=use_plan,
        ess_online=ess_online, ess_weight=ess_weight,
    )
    if cfg.degraded_mode:
        campus_rack = telem.rack_mean
        on_frac = jnp.mean(telem.ess_online, axis=1)
    else:
        campus_rack = jnp.mean(traces, axis=1)
        on_frac = jnp.ones(telem.soc.shape[0], jnp.float32)
    campus_grid = jnp.mean(grid, axis=1)
    return ConditioningResult(
        grid_traces=grid,
        campus_rack=campus_rack,
        campus_grid=campus_grid,
        report_rack=compliance.check(campus_rack, cfg.sample_dt, grid_spec),
        report_grid=compliance.check(campus_grid, cfg.sample_dt, grid_spec),
        health=hlt.report(
            _health_params(cfg), cfg.ess_params, state_f.health, cfg.sample_dt
        ),
        ess_online_frac=on_frac,
        safemode_trace=(
            smode.chunk_snapshot(state_f.safemode)[None]
            if cfg.safemode else jnp.zeros((1, 6), jnp.float32)
        ),
    )


def _health_params(cfg: pdu.PDUConfig) -> hlt.HealthParams:
    return cfg.health if cfg.health is not None else hlt.HealthParams.create()


# ----------------------------------------------------------------- streaming


class _Observers(NamedTuple):
    """Streaming compliance state folded inside the engines' jitted steps:
    reports come from these, not from re-diffing/FFT-ing materialized
    campus arrays — so compliance is available online however long the
    stream runs (and the cross-chunk boundary ramp is never dropped)."""

    ramp_rack: compliance.RampObserver
    ramp_grid: compliance.RampObserver
    spec_rack: compliance.SpectrumObserver
    spec_grid: compliance.SpectrumObserver


def _observers_init(bank: compliance.SpectrumBank) -> _Observers:
    return _Observers(
        ramp_rack=compliance.ramp_observer_init(),
        ramp_grid=compliance.ramp_observer_init(),
        spec_rack=compliance.spectrum_observer_init(bank),
        spec_grid=compliance.spectrum_observer_init(bank),
    )


def _observers_update(
    obs: _Observers, bank: compliance.SpectrumBank, ch: pdu.CampusChunk, dt: float
) -> _Observers:
    return _Observers(
        ramp_rack=compliance.ramp_observer_update(obs.ramp_rack, ch.campus_rack, dt),
        ramp_grid=compliance.ramp_observer_update(obs.ramp_grid, ch.campus_grid, dt),
        spec_rack=compliance.spectrum_observer_update(bank, obs.spec_rack, ch.campus_rack),
        spec_grid=compliance.spectrum_observer_update(bank, obs.spec_grid, ch.campus_grid),
    )


def _make_bank(
    grid_spec: compliance.GridSpec, cfg: pdu.PDUConfig, n_total: int
) -> compliance.SpectrumBank:
    return compliance.make_bank(
        n_total, cfg.sample_dt, float(np.asarray(grid_spec.f_c))
    )


class _CampusAccum(NamedTuple):
    """Preallocated on-device output buffers for the host-loop engine."""

    campus_rack: jax.Array  # (n_chunks * chunk,)
    campus_grid: jax.Array  # (n_chunks * chunk,)
    soc_mean: jax.Array  # (n_chunks * chunk_intervals,)
    worst: jax.Array  # () running max QP primal residual
    health_trace: jax.Array  # (n_chunks, 3) fleet wear snapshot per chunk
    ess_frac: jax.Array  # (n_chunks * chunk_intervals,) online fraction
    sm_trace: jax.Array  # (n_chunks, 6) safe-mode snapshot per chunk
    obs: _Observers  # streaming compliance state


# The streaming engines close their jitted steps over a concrete PDUConfig
# (pdu.condition bakes config scalars into the kernel via float(...)), so
# the jit wrapper must be cached *outside* the engine call or every
# invocation would retrace and recompile from scratch — which is exactly
# the per-call recompile the pre-scanned benches were paying.  PDUConfig
# leaves are config scalars, so a value-based key is exact; anything
# non-scalar falls back to an uncached (per-call) jit.
_ENGINE_CACHE: dict = {}


def _cfg_cache_key(cfg) -> tuple | None:
    try:
        leaves, treedef = jax.tree_util.tree_flatten(cfg)
        return treedef, tuple(np.asarray(leaf).item() for leaf in leaves)
    except (TypeError, ValueError):  # non-scalar or non-hashable leaf
        return None


def _engine_key(cfg, *rest) -> tuple | None:
    cfg_key = _cfg_cache_key(cfg)
    return None if cfg_key is None else (cfg_key,) + rest


def _cached_engine(key, build):
    if key is None:  # un-keyable config: fall back to a per-call jit
        return build()
    fn = _ENGINE_CACHE.get(key)
    if fn is None:
        fn = _ENGINE_CACHE[key] = build()
    return fn


def make_condition_step(cfg: pdu.PDUConfig, *, qp_iters: int = 30, donate: bool = True):
    """A cached, jitted ``(state, trace) -> (grid, state, telemetry)`` step.

    The single-chunk building block of the streaming engines, exposed for
    callers (e.g. ``power.integration.PowerSim``) that condition a stream
    of same-shaped chunks: the returned function is cached per config, so
    repeated construction never retraces, and the carried ``PDUState`` is
    donated between chunks.
    """

    def build():
        def step(st, tr):
            return pdu.condition(cfg, st, tr, qp_iters=qp_iters)

        return jax.jit(step, donate_argnums=(0,) if donate else ())

    return _cached_engine(_engine_key(cfg, "condition_step", qp_iters, donate), build)


def _host_stream_step(cfg, qp_iters, chunk, n_int, mesh, rack_axis, bank,
                      use_faults=False, fault_edge=1):
    """Cached jitted host-loop chunk step: condition + accumulate on-device.

    Campus aggregates are written into the preallocated ``_CampusAccum``
    buffers with ``dynamic_update_slice`` (the chunk index rides in as a
    traced scalar, so one compilation serves every full chunk; a ragged
    tail adds one more) and the worst QP residual is folded as a running
    max — no host-side list appends, ``jnp.concatenate``, or growing lazy
    ``jnp.maximum`` chains.  Write offsets use the *full* chunk geometry
    (``chunk`` samples / ``n_int`` intervals), not the possibly-shorter
    incoming block, so the ragged tail lands at the right position.

    With ``use_faults`` the degraded step carries the fault schedule itself
    (a small episode-table pytree) instead of streamed per-chunk mask/weight
    blocks; the chunk's absolute start sample is ``c_idx * chunk`` in-jit,
    so one compilation still serves every full chunk.
    """

    def build():
        def step_impl(st, acc, tr, c_idx, on, wt, fl):
            if mesh is not None:
                tr = shard_racks_in_jit(tr, mesh, rack_axis)
            st2, ch = pdu.condition_campus(
                cfg, st, tr, qp_iters=qp_iters, ess_online=on, ess_weight=wt,
                faults=fl, chunk_start=c_idx * chunk, fault_edge=fault_edge,
            )
            acc2 = _CampusAccum(
                campus_rack=jax.lax.dynamic_update_slice(
                    acc.campus_rack, ch.campus_rack, (c_idx * chunk,)
                ),
                campus_grid=jax.lax.dynamic_update_slice(
                    acc.campus_grid, ch.campus_grid, (c_idx * chunk,)
                ),
                soc_mean=jax.lax.dynamic_update_slice(
                    acc.soc_mean, ch.soc_mean, (c_idx * n_int,)
                ),
                worst=jnp.maximum(acc.worst, ch.max_qp_residual),
                health_trace=jax.lax.dynamic_update_slice(
                    acc.health_trace, ch.health[None], (c_idx, 0)
                ),
                ess_frac=jax.lax.dynamic_update_slice(
                    acc.ess_frac, ch.ess_online_frac, (c_idx * n_int,)
                ),
                sm_trace=jax.lax.dynamic_update_slice(
                    acc.sm_trace, ch.safemode[None], (c_idx, 0)
                ),
                obs=_observers_update(acc.obs, bank, ch, cfg.sample_dt),
            )
            return st2, acc2

        if cfg.degraded_mode and use_faults:
            # Fault-schedule variant: the schedule rides in as a traced
            # pytree and availability renders inside the conditioning scan.
            @functools.partial(jax.jit, donate_argnums=(0, 1))
            def step(st, acc, tr, c_idx, fl):
                return step_impl(st, acc, tr, c_idx, None, None, fl)
        elif cfg.degraded_mode:
            # Degraded variant carries the chunk's availability-mask rows
            # and (optionally) the per-sample hardware weight block.
            @functools.partial(jax.jit, donate_argnums=(0, 1))
            def step(st, acc, tr, c_idx, on, wt):
                return step_impl(st, acc, tr, c_idx, on, wt, None)
        else:
            @functools.partial(jax.jit, donate_argnums=(0, 1))
            def step(st, acc, tr, c_idx):
                return step_impl(st, acc, tr, c_idx, None, None, None)

        return step

    return _cached_engine(
        _engine_key(cfg, "host_stream", qp_iters, chunk, n_int, mesh, rack_axis,
                    bank, use_faults, fault_edge),
        build,
    )


def _finish_streaming(
    cfg, grid_spec, state, campus_rack, campus_grid, soc_mean, worst,
    bank, obs, health_trace, ess_frac=None, sm_trace=None,
):
    """Assemble the result from streaming state: the compliance reports
    come from the cross-chunk observers (exact ramp, Goertzel spec lines),
    not from re-analyzing the materialized campus arrays — the arrays are
    returned for plotting/diagnostics but no longer gate compliance.  The
    observers (and their bank/spec) ride along so ``.report()`` can
    re-derive reports later."""
    return ConditioningResult(
        campus_rack=campus_rack,
        campus_grid=campus_grid,
        soc_mean=soc_mean,
        report_rack=compliance.report_from_observers(
            grid_spec, obs.ramp_rack, bank, obs.spec_rack
        ),
        report_grid=compliance.report_from_observers(
            grid_spec, obs.ramp_grid, bank, obs.spec_grid
        ),
        state=state,
        max_qp_residual=worst,
        health_trace=health_trace,
        health=hlt.report(
            _health_params(cfg), cfg.ess_params, state.health, cfg.sample_dt
        ),
        ess_online_frac=ess_frac,
        safemode_trace=sm_trace,
        grid_spec=grid_spec,
        bank=bank,
        observers=obs,
    )


def _condition_fleet_streaming_impl(
    cfg: pdu.PDUConfig,
    traces: jax.Array | Callable[[int, int], jax.Array],
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 30,
    chunk_intervals: int = 16,
    total_samples: int | None = None,
    mesh: jax.sharding.Mesh | None = None,
    rack_axis: str = "data",
    state: pdu.PDUState | None = None,
    ess_online: jax.Array | None = None,
    ess_weight: jax.Array | None = None,
    faults=None,
    fault_edge: int = 1,
) -> ConditioningResult:
    """Campus-scale conditioning in time chunks with bounded working set.

    ``condition_fleet`` materializes the rack traces *and* the conditioned
    grid waveform as full (T, R) arrays — 2x the campus trace in HBM, which
    is what caps fleet size for hour-long traces.  This engine walks the
    trace in chunks of ``chunk_intervals`` controller intervals, donates
    the per-rack ``PDUState`` and the campus output buffers between chunks,
    reduces each chunk to campus aggregates inside the jitted step (the
    per-rack grid waveform never leaves the chunk), and carries the
    controller's warm-started ADMM state across chunks via
    ``PDUState.qp_warm`` — so at equal ``qp_iters`` the result is identical
    to the one-shot ``condition_fleet`` call while live memory stays
    O(chunk * R).  The default ``qp_iters=30`` assumes the warm-started
    plan path, where 30 iterations match the seed cold-start path's
    residual at 120 (EXPERIMENTS.md §Perf-4).

    ``traces`` is either a (T, R) array or a chunk provider
    ``f(start, length) -> (length, R)`` (with ``total_samples`` given) for
    *external* sources — host-loaded or synthesized arrays the engine
    cannot see inside its jit.  Declarative scenarios should prefer
    ``condition_scenario_scanned``, which renders chunks inside one scanned
    jit and dispatches once for the whole trace.  With ``mesh`` set, each
    chunk is rack-sharded inside the jitted step
    (``shard_racks_in_jit``); host-resident (non-jax) chunks are placed
    with ``shard_racks`` first.  Passing ``state`` resumes a previous
    stream (``soc0`` is then ignored); the stream must resume at a
    controller-interval boundary, which every full chunk is.  A
    caller-supplied ``state`` is copied before the (donated) step consumes
    it, so the same checkpoint can seed several continuations.

    ``ess_online`` (requires ``cfg.degraded_mode``) is the ESS availability
    mask for the *whole* stream — ``(n_ctrl_total, R)`` per-interval rows
    (sliced per chunk) or one ``(R,)`` mask applied throughout; semantics
    as in ``pdu.condition``.  ``ess_weight`` is the optional per-sample
    ``(T, R)`` hardware availability weight for the whole stream (sliced
    per chunk by sample).  ``faults`` (mutually exclusive with both, and
    preferred) is a ``power.faults.FaultSchedule`` for the whole stream:
    availability renders inside the conditioning scan from the episode
    boundary tables instead of streaming ``(T, R)`` weight blocks through
    every chunk — bitwise-identical output at a fraction of the cost
    (``fault_edge`` is the schedule's static edge ramp width in samples).
    The scenario engines derive the right form from an attached fault
    schedule automatically.
    """
    k = max(int(round(float(cfg.controller.dt) / cfg.sample_dt)), 1)
    n_int = max(int(chunk_intervals), 1)
    chunk = n_int * k
    if callable(traces):
        if total_samples is None:
            raise ValueError("total_samples is required with a chunk provider")
        provider, t_total = traces, int(total_samples)
    else:
        provider, t_total = (lambda t0, n: traces[t0 : t0 + n]), traces.shape[0]
    n_chunks = -(-t_total // chunk)
    n_ctrl = -(-t_total // k)
    if ess_online is not None or ess_weight is not None:
        if not cfg.degraded_mode:
            raise ValueError(
                "ess_online/ess_weight require a degraded-mode config "
                "(make_pdu(..., degraded_mode=True))"
            )
        if faults is not None:
            raise ValueError(
                "faults is mutually exclusive with ess_online/ess_weight "
                "(the schedule renders both internally)"
            )
        if ess_online is not None:
            ess_online = jnp.asarray(ess_online, jnp.float32)
        if ess_weight is not None:
            ess_weight = jnp.asarray(ess_weight, jnp.float32)
    if faults is not None and not cfg.degraded_mode:
        raise ValueError(
            "faults requires a degraded-mode config "
            "(make_pdu(..., degraded_mode=True))"
        )

    if state is None:
        state = pdu.init_state(cfg, provider(0, 1)[0], soc0=soc0)
    else:
        # The step donates its state argument; copy so the caller's
        # checkpoint survives (and can seed several continuations).
        state = jax.tree_util.tree_map(jnp.copy, state)

    bank = _make_bank(grid_spec, cfg, t_total)
    step = _host_stream_step(cfg, qp_iters, chunk, n_int, mesh, rack_axis, bank,
                             use_faults=faults is not None,
                             fault_edge=int(fault_edge))
    acc = _CampusAccum(
        campus_rack=jnp.zeros((n_chunks * chunk,), jnp.float32),
        campus_grid=jnp.zeros((n_chunks * chunk,), jnp.float32),
        soc_mean=jnp.zeros((n_chunks * n_int,), jnp.float32),
        worst=jnp.zeros((), jnp.float32),
        health_trace=jnp.zeros((n_chunks, 3), jnp.float32),
        ess_frac=jnp.ones((n_chunks * n_int,), jnp.float32),
        sm_trace=jnp.zeros((n_chunks, 6), jnp.float32),
        obs=_observers_init(bank),
    )
    for c_idx, t0 in enumerate(range(0, t_total, chunk)):
        # The trailing partial chunk runs at its natural length (one extra
        # `step` compilation): `pdu.condition` ZOH-pads its trailing
        # partial controller interval internally, exactly as a one-shot
        # whole-trace call would, so the carried state / soc_mean /
        # max_qp_residual never see whole pad intervals and stay
        # chunk-size invariant (and scanned-engine identical).
        n = min(chunk, t_total - t0)
        with _prof.span("render") as sync:
            tr = sync(provider(t0, n))
        if mesh is not None and not isinstance(tr, jax.Array):
            tr = shard_racks(tr, mesh, rack_axis)  # host-resident input
        with _prof.span("solve") as sync:
            if cfg.degraded_mode and faults is not None:
                state, acc = step(
                    state, acc, tr, jnp.asarray(c_idx, jnp.int32), faults
                )
            elif cfg.degraded_mode:
                if ess_online is None or ess_online.ndim < 2:
                    on = ess_online  # one mask (or None) for the whole stream
                else:
                    on = ess_online[c_idx * n_int : c_idx * n_int + -(-n // k)]
                # The hardware weight is per *sample*: it slices by samples.
                wt = None if ess_weight is None else ess_weight[t0 : t0 + n]
                state, acc = step(
                    state, acc, tr, jnp.asarray(c_idx, jnp.int32), on, wt
                )
            else:
                state, acc = step(state, acc, tr, jnp.asarray(c_idx, jnp.int32))
            sync(acc.worst)

    with _prof.span("host-sync") as sync:
        res = _finish_streaming(
            cfg, grid_spec, state,
            acc.campus_rack[:t_total], acc.campus_grid[:t_total],
            acc.soc_mean[:n_ctrl], acc.worst,
            bank, acc.obs, acc.health_trace, acc.ess_frac[:n_ctrl],
            acc.sm_trace,
        )
        sync((res.campus_grid, res.report_grid))
    return res


def _condition_chunk(cfg, scen, st, t0, n, *, k, qp_iters, prep=None):
    """Render + condition one ``n``-sample chunk at absolute sample ``t0``.

    The per-chunk building block shared by the scanned engine and the
    grid-region engines (``core.grid``) — keeping it single-sourced is what
    keeps the sharded region run bitwise against the sequential loop.  With
    a fault schedule attached to the scenario (and a degraded-mode config)
    the schedule itself is handed to ``pdu.condition`` together with the
    chunk's absolute start sample: availability is rendered *inside* the
    conditioning scan from the episode boundary tables (the degraded fast
    path; safe-mode configs fall back to the streamed derivation
    internally).  Every rendered quantity is pure in the absolute sample
    index (like the trace renderer), so the result is chunk- and
    resume-invariant by construction.  ``prep`` post-processes the rendered
    ``(n, R)`` block (e.g. an in-jit rack sharding constraint).
    """
    from repro.power import scenario as SC

    # Trace-time structural check: the caller's jit retraces automatically
    # when the scenario gains/loses a fault schedule (treedef change).
    faulty = cfg.degraded_mode and scen.faults is not None
    tr = SC.render(scen, t0, n)
    if tr.ndim == 1:  # unbatched scenario: lift to a 1-rack fleet
        tr = tr[:, None]
    if prep is not None:
        tr = prep(tr)
    return pdu.condition_campus(
        cfg, st, tr, qp_iters=qp_iters,
        faults=scen.faults if faulty else None,
        chunk_start=t0,
        fault_edge=scen.edge_width if faulty else 1,
    )


def _scanned_engine(cfg, qp_iters, chunk, k, n_full, rem, mesh, rack_axis, bank):
    """Cached jitted scanned engine: the whole trace in ONE dispatch.

    ``jax.lax.scan`` walks the chunk index over the ``n_full`` full chunks;
    each iteration renders its (chunk, R) block on-device
    (``scenario.render`` with the traced chunk counter), optionally
    constrains the rack sharding in-jit, runs ``pdu.condition_campus``,
    and writes the campus aggregates into the scan's preallocated stacked
    outputs.  A ``rem``-sample ragged tail is conditioned by an epilogue
    step in the same jit at its *natural* length (static start index and
    shape; ``pdu.condition`` ZOH-pads the trailing partial controller
    interval internally, exactly as a one-shot whole-trace call would) —
    so the returned state, ``soc_mean``, and ``max_qp_residual`` never see
    pad intervals and are chunk-size invariant.  The scenario and the
    start sample ride in as traced arguments, so one compilation serves
    every scenario with the same structure and rack count — and every
    resume point with the same remaining chunk geometry (e.g. fixed-size
    windows of a long stream).

    With a fault schedule attached to the scenario (and a degraded-mode
    config), the per-interval ESS availability mask is derived *inside* the
    jit from the schedule's episode table (``faults.interval_online`` is
    pure in the absolute sample index, like the renderer), so the mask is
    chunk- and resume-invariant by construction.  ``scen.faults is None``
    vs a schedule changes the scenario treedef, which retraces the cached
    jit automatically — no extra cache key needed.
    """
    def prep(tr):
        if mesh is not None:
            tr = shard_racks_in_jit(tr, mesh, rack_axis)
        return tr

    def build():
        @functools.partial(jax.jit, donate_argnums=(1,))
        def run(scen, st, start):
            obs = _observers_init(bank)

            def body(carry, c_idx):
                st, obs = carry
                st2, ch = _condition_chunk(
                    cfg, scen, st, start + c_idx * chunk, chunk,
                    k=k, qp_iters=qp_iters, prep=prep,
                )
                obs2 = _observers_update(obs, bank, ch, cfg.sample_dt)
                return (st2, obs2), ch

            parts = []
            worst = []
            htrace = []
            strace = []
            if n_full:
                (st, obs), ch = jax.lax.scan(
                    body, (st, obs), jnp.arange(n_full, dtype=jnp.int32)
                )
                parts.append(pdu.CampusChunk(
                    ch.campus_rack.reshape(-1), ch.campus_grid.reshape(-1),
                    ch.soc_mean.reshape(-1), None, None,
                    ch.ess_online_frac.reshape(-1),
                ))
                worst.append(jnp.max(ch.max_qp_residual))
                htrace.append(ch.health)  # (n_full, 3)
                strace.append(ch.safemode)  # (n_full, 6)
            if rem:
                st, ch = _condition_chunk(
                    cfg, scen, st, start + n_full * chunk, rem,
                    k=k, qp_iters=qp_iters, prep=prep,
                )
                obs = _observers_update(obs, bank, ch, cfg.sample_dt)
                parts.append(ch)
                worst.append(ch.max_qp_residual)
                htrace.append(ch.health[None])  # (1, 3)
                strace.append(ch.safemode[None])  # (1, 6)
            cat = lambda xs: xs[0] if len(xs) == 1 else jnp.concatenate(xs)
            return st, pdu.CampusChunk(
                campus_rack=cat([p.campus_rack for p in parts]),
                campus_grid=cat([p.campus_grid for p in parts]),
                soc_mean=cat([p.soc_mean for p in parts]),
                max_qp_residual=functools.reduce(jnp.maximum, worst),
                health=cat(htrace),
                ess_online_frac=cat([p.ess_online_frac for p in parts]),
                safemode=cat(strace),
            ), obs

        return run

    return _cached_engine(
        _engine_key(cfg, "scanned", qp_iters, chunk, k, n_full, rem,
                    mesh, rack_axis, bank),
        build,
    )


def _condition_scenario_scanned_impl(
    cfg: pdu.PDUConfig,
    scenario,
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 30,
    chunk_intervals: int = 16,
    mesh: jax.sharding.Mesh | None = None,
    rack_axis: str = "data",
    state: pdu.PDUState | None = None,
    start_sample: int = 0,
    stop_sample: int | None = None,
) -> ConditioningResult:
    """Device-resident streaming: render + condition in one scanned jit.

    The host-loop engine pays per-chunk Python dispatch, a separately
    jitted scenario render, and host-side accumulation.  Because
    ``scenario.render(s, t0, n)`` is pure in the absolute sample index, the
    render can move *inside* the step: a single ``jax.lax.scan`` over chunk
    indices synthesizes each (chunk, R) block on-device, conditions it, and
    stacks the campus aggregates into preallocated scan outputs — one
    dispatch for the whole trace, zero host<->device ping-pong, donated
    ``PDUState``, and rack sharding expressed as a
    ``with_sharding_constraint`` inside the jit.  ``qp_iters`` / warm-start
    semantics are bit-identical to the host-loop engine and to one-shot
    ``condition_fleet`` at equal ``qp_iters``.

    ``state`` + ``start_sample`` / ``stop_sample`` window the stream: pass
    a previous call's returned state and the absolute sample index to
    resume at (a multiple of the controller interval — any multiple of the
    chunk size qualifies); aggregates cover ``[start_sample, stop_sample)``
    of the *unmodified* scenario, so a split-and-resume run reproduces the
    one-call run (truncating ``total_samples`` instead would change the
    edge-smoothing windows near the cut).  A caller-supplied ``state`` is
    copied before the (donated) engine consumes it, so the same checkpoint
    can seed several continuations.
    """
    from repro.power import scenario as SC

    _check_scenario_rate(scenario, cfg)
    _check_scenario_faults(scenario, cfg)
    k = max(int(round(float(cfg.controller.dt) / cfg.sample_dt)), 1)
    chunk = max(int(chunk_intervals), 1) * k
    start = int(start_sample)
    stop = scenario.total_samples if stop_sample is None else int(stop_sample)
    if not 0 <= stop <= scenario.total_samples:
        raise ValueError(
            f"stop_sample {stop} outside the scenario "
            f"({scenario.total_samples} samples)"
        )
    if start < 0 or start % k:
        raise ValueError(
            f"start_sample {start} must be a non-negative multiple of the "
            f"controller interval ({k} samples) so the resumed state stays "
            "interval-aligned"
        )
    t_total = stop - start
    if t_total <= 0:
        raise ValueError(
            f"start_sample {start} is past the scenario end "
            f"(stop at {stop} samples)"
        )
    n_full, rem = divmod(t_total, chunk)
    n_ctrl = -(-t_total // k)

    if state is None:
        r0 = SC.render(scenario, start, 1)[0]
        if r0.ndim == 0:
            r0 = r0[None]  # unbatched scenario: the engine lifts to 1 rack
        state = pdu.init_state(cfg, r0, soc0=soc0)
    else:
        # The engine donates its state argument; copy so the caller's
        # checkpoint survives (and can seed several continuations).
        state = jax.tree_util.tree_map(jnp.copy, state)

    bank = _make_bank(grid_spec, cfg, t_total)
    run = _scanned_engine(
        cfg, qp_iters, chunk, k, n_full, rem, mesh, rack_axis, bank
    )
    state_f, ch, obs = run(scenario, state, jnp.asarray(start, jnp.int32))
    return _finish_streaming(
        cfg, grid_spec, state_f,
        ch.campus_rack[:t_total], ch.campus_grid[:t_total],
        ch.soc_mean[:n_ctrl], ch.max_qp_residual,
        bank, obs, ch.health, ch.ess_online_frac[:n_ctrl],
        ch.safemode,
    )


def _check_scenario_rate(scenario, cfg: pdu.PDUConfig) -> None:
    if abs(1.0 / scenario.sample_hz - cfg.sample_dt) > 1e-9:
        raise ValueError(
            f"scenario sample rate {scenario.sample_hz} Hz != PDU sample_dt "
            f"{cfg.sample_dt} s; build the PDU with sample_dt=1/sample_hz"
        )


def _check_scenario_faults(scenario, cfg: pdu.PDUConfig) -> None:
    if getattr(scenario, "faults", None) is not None and not cfg.degraded_mode:
        raise ValueError(
            "the scenario has a fault schedule attached; conditioning it "
            "requires a degraded-mode config (make_pdu(..., "
            "degraded_mode=True)) so ESS trips are masked and sensor-dropout "
            "NaN samples are bridged instead of poisoning the state"
        )


def _scenario_fault_data(cfg: pdu.PDUConfig, scenario) -> dict:
    """Precomputed availability mask/weight for engines that take them as
    data (the one-shot engine, and the host loop when the caller overrides
    one of the two inputs) — the same pure functions the fast path renders
    from the episode tables, so every engine stays bitwise identical under
    any fault schedule."""
    if not (cfg.degraded_mode and getattr(scenario, "faults", None) is not None):
        return {}
    from repro.power import faults as FLT

    k = max(int(round(float(cfg.controller.dt) / cfg.sample_dt)), 1)
    n_ctrl = -(-scenario.total_samples // k)
    return {
        "ess_online": FLT.interval_online(scenario.faults, 0, n_ctrl, k),
        "ess_weight": FLT.ess_weight(
            scenario.faults, 0, scenario.total_samples, scenario.edge_width
        ),
    }


def _condition_scenario_host_impl(
    cfg: pdu.PDUConfig,
    scenario,
    grid_spec: compliance.GridSpec,
    *,
    mesh: jax.sharding.Mesh | None = None,
    rack_axis: str = "data",
    chunk_intervals: int = 16,
    state: pdu.PDUState | None = None,
    soc0: float = 0.5,
    qp_iters: int = 30,
    ess_online: jax.Array | None = None,
    ess_weight: jax.Array | None = None,
) -> ConditioningResult:
    """Scenario via the per-chunk host loop — the slow oracle the scanned
    engine is equivalence-tested against."""
    from repro.power import scenario as SC

    _check_scenario_rate(scenario, cfg)
    _check_scenario_faults(scenario, cfg)
    faulty = cfg.degraded_mode and getattr(scenario, "faults", None) is not None
    if faulty and (ess_online is not None or ess_weight is not None):
        # Caller-supplied overrides win; fill the missing half the legacy
        # streamed way so overriding one input does not change the other.
        fault_data = _scenario_fault_data(cfg, scenario)
        if ess_online is None:
            ess_online = fault_data.get("ess_online")
        if ess_weight is None:
            ess_weight = fault_data.get("ess_weight")
        faulty = False
    return _condition_fleet_streaming_impl(
        cfg,
        SC.chunk_provider(scenario),
        grid_spec,
        total_samples=scenario.total_samples,
        soc0=soc0,
        qp_iters=qp_iters,
        chunk_intervals=chunk_intervals,
        mesh=mesh,
        rack_axis=rack_axis,
        state=state,
        ess_online=ess_online,
        ess_weight=ess_weight,
        faults=scenario.faults if faulty else None,
        fault_edge=scenario.edge_width if faulty else 1,
    )


# ------------------------------------------------------------------- facade


@dataclasses.dataclass(frozen=True)
class StreamOptions:
    """Streaming window options for the ``condition`` facade.

    ``chunk_intervals`` sizes the streaming chunk (controller intervals per
    chunk); ``state`` resumes a previous stream (a prior result's
    ``.state`` — a tuple of per-campus states for a grid region);
    ``start_sample`` / ``stop_sample`` window the scanned engines over
    ``[start, stop)`` of the unmodified scenario; ``total_samples`` is
    required (and only meaningful) for raw chunk providers.
    """

    chunk_intervals: int = 16
    state: object = None
    start_sample: int = 0
    stop_sample: int | None = None
    total_samples: int | None = None


def _as_stream_options(stream) -> StreamOptions:
    if stream is None:
        return StreamOptions()
    if isinstance(stream, StreamOptions):
        return stream
    if isinstance(stream, dict):
        return StreamOptions(**stream)
    raise TypeError(
        f"stream must be a StreamOptions, dict or None, got {type(stream)!r}")


def _reject_stream_options(so: StreamOptions, engine: str, *fields: str) -> None:
    defaults = StreamOptions()
    for f in fields:
        if getattr(so, f) != getattr(defaults, f):
            raise ValueError(
                f"stream option {f!r} is not supported by the {engine!r} engine")


def condition(
    target,
    cfg: pdu.PDUConfig,
    grid_spec: compliance.GridSpec | None = None,
    *,
    engine: str = "scanned",
    mesh: jax.sharding.Mesh | None = None,
    rack_axis: str = "data",
    stream: StreamOptions | dict | None = None,
    **kwargs,
) -> ConditioningResult:
    """THE conditioning entry point: one facade over every engine.

    ``target`` selects the workload form:

    * a ``power.scenario.Scenario`` — a (possibly heterogeneous, faulted)
      campus, rendered on-device;
    * a ``core.grid.GridRegion`` — N campuses aggregated at a point of
      interconnection (POI observers + mode-band verdicts ride along; with
      a ``mesh`` carrying a ``"campus"`` axis the campuses run in parallel
      under ``shard_map``, bitwise against the sequential loop);
    * a materialized ``(T, R)`` rack-trace array, or a chunk provider
      ``f(start, length) -> (length, R)`` (with ``stream.total_samples``).

    ``engine`` picks the execution strategy: ``"scanned"`` (default —
    render + condition in one scanned jit; scenarios/regions only),
    ``"host"`` (per-chunk host loop, the slow oracle), or ``"oneshot"``
    (whole-trace ``(T, R)`` materialization; supports ``use_plan=False``).
    ``mesh`` is taken once here — rack sharding (``"data"`` axis) and
    campus sharding (``"campus"`` axis) both derive from it.  ``stream``
    bundles the windowing/resume options (see ``StreamOptions``).
    Remaining keywords (``soc0``, ``qp_iters``, ``use_plan``,
    ``ess_online``, ``ess_weight``) pass through to the engine.

    Returns a ``ConditioningResult`` whatever the path; fields the engine
    does not track are ``None``.  The pre-facade entry points
    (``condition_fleet``, ``condition_fleet_streaming``,
    ``condition_scenario_scanned``, ``condition_scenario_streaming``)
    remain as thin deprecated wrappers over this function.
    """
    spec = compliance.GridSpec.create() if grid_spec is None else grid_spec
    so = _as_stream_options(stream)

    if hasattr(target, "campuses"):  # GridRegion (duck-typed; grid imports us)
        from repro.core import grid as _grid

        if engine != "scanned":
            raise ValueError(
                f"grid regions run the scanned engine only (got {engine!r})")
        _reject_stream_options(so, "grid-region", "total_samples")
        return _grid.condition_region(
            cfg, target, spec, mesh=mesh,
            chunk_intervals=so.chunk_intervals, states=so.state,
            start_sample=so.start_sample, stop_sample=so.stop_sample,
            **kwargs,
        )

    is_scenario = hasattr(target, "total_samples") and not callable(target)
    if is_scenario:
        if engine == "scanned":
            _reject_stream_options(so, "scanned", "total_samples")
            return _condition_scenario_scanned_impl(
                cfg, target, spec, mesh=mesh, rack_axis=rack_axis,
                chunk_intervals=so.chunk_intervals, state=so.state,
                start_sample=so.start_sample, stop_sample=so.stop_sample,
                **kwargs,
            )
        if engine == "host":
            _reject_stream_options(
                so, "host", "start_sample", "stop_sample", "total_samples")
            return _condition_scenario_host_impl(
                cfg, target, spec, mesh=mesh, rack_axis=rack_axis,
                chunk_intervals=so.chunk_intervals, state=so.state,
                **kwargs,
            )
        if engine == "oneshot":
            from repro.power import scenario as SC

            _reject_stream_options(
                so, "oneshot", "state", "start_sample", "stop_sample",
                "total_samples")
            _check_scenario_rate(target, cfg)
            _check_scenario_faults(target, cfg)
            for key, val in _scenario_fault_data(cfg, target).items():
                kwargs.setdefault(key, val)
            tr = SC.render(target, 0, target.total_samples)
            if tr.ndim == 1:
                tr = tr[:, None]
            return _condition_fleet_impl(cfg, tr, spec, **kwargs)
        raise ValueError(
            f"unknown engine {engine!r} "
            "(expected 'scanned', 'host' or 'oneshot')")

    # Raw (T, R) array or chunk provider.
    if engine == "oneshot":
        if callable(target):
            raise ValueError(
                "engine='oneshot' needs a materialized (T, R) array "
                "(chunk providers stream via engine='host')")
        _reject_stream_options(
            so, "oneshot", "state", "start_sample", "stop_sample",
            "total_samples")
        return _condition_fleet_impl(cfg, target, spec, **kwargs)
    if engine == "host":
        _reject_stream_options(so, "host", "start_sample", "stop_sample")
        return _condition_fleet_streaming_impl(
            cfg, target, spec, mesh=mesh, rack_axis=rack_axis,
            chunk_intervals=so.chunk_intervals, state=so.state,
            total_samples=so.total_samples, **kwargs,
        )
    if engine == "scanned":
        raise ValueError(
            "engine='scanned' renders a declarative Scenario/GridRegion "
            "in-jit; raw trace arrays and chunk providers stream via "
            "engine='host' (or engine='oneshot' for materialized arrays)")
    raise ValueError(
        f"unknown engine {engine!r} (expected 'scanned', 'host' or 'oneshot')")


# -------------------------------------------------- deprecated entry points
# Thin wrappers over ``condition`` (golden-tested bitwise against it); kept
# so seven PRs of call sites keep working.  Prefer the facade in new code.


def condition_fleet(
    cfg: pdu.PDUConfig,
    traces: jax.Array,
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 60,
    use_plan: bool = True,
    ess_online: jax.Array | None = None,
    ess_weight: jax.Array | None = None,
) -> ConditioningResult:
    """One-shot whole-trace conditioning of a (T, R) rack-trace array.

    .. deprecated:: prefer ``condition(traces, cfg, spec, engine="oneshot")``.
    """
    return condition(
        traces, cfg, grid_spec, engine="oneshot", soc0=soc0,
        qp_iters=qp_iters, use_plan=use_plan,
        ess_online=ess_online, ess_weight=ess_weight,
    )


def condition_fleet_streaming(
    cfg: pdu.PDUConfig,
    traces: jax.Array | Callable[[int, int], jax.Array],
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 30,
    chunk_intervals: int = 16,
    total_samples: int | None = None,
    mesh: jax.sharding.Mesh | None = None,
    rack_axis: str = "data",
    state: pdu.PDUState | None = None,
    ess_online: jax.Array | None = None,
    ess_weight: jax.Array | None = None,
) -> ConditioningResult:
    """Host-loop streaming over a (T, R) array or chunk provider.

    .. deprecated:: prefer ``condition(traces, cfg, spec, engine="host",
       stream=StreamOptions(...))``.
    """
    return condition(
        traces, cfg, grid_spec, engine="host", mesh=mesh, rack_axis=rack_axis,
        stream=StreamOptions(chunk_intervals=chunk_intervals, state=state,
                             total_samples=total_samples),
        soc0=soc0, qp_iters=qp_iters,
        ess_online=ess_online, ess_weight=ess_weight,
    )


def condition_scenario_scanned(
    cfg: pdu.PDUConfig,
    scenario,
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 30,
    chunk_intervals: int = 16,
    mesh: jax.sharding.Mesh | None = None,
    rack_axis: str = "data",
    state: pdu.PDUState | None = None,
    start_sample: int = 0,
    stop_sample: int | None = None,
) -> ConditioningResult:
    """Scenario via the scanned engine (render + condition in one jit).

    .. deprecated:: prefer ``condition(scenario, cfg, spec)`` — the facade
       defaults to this engine.
    """
    return condition(
        scenario, cfg, grid_spec, engine="scanned", mesh=mesh,
        rack_axis=rack_axis,
        stream=StreamOptions(chunk_intervals=chunk_intervals, state=state,
                             start_sample=start_sample,
                             stop_sample=stop_sample),
        soc0=soc0, qp_iters=qp_iters,
    )


def condition_scenario_streaming(
    cfg: pdu.PDUConfig,
    scenario,
    grid_spec: compliance.GridSpec,
    *,
    engine: str = "scanned",
    **kwargs,
) -> ConditioningResult:
    """Condition a declarative ``repro.power.scenario.Scenario`` fleet.

    .. deprecated:: prefer ``condition(scenario, cfg, spec,
       engine="scanned"|"host")``.
    """
    if engine not in ("scanned", "host"):
        raise ValueError(
            f"unknown engine {engine!r} (expected 'scanned' or 'host')")
    stream = StreamOptions(
        chunk_intervals=kwargs.pop("chunk_intervals", 16),
        state=kwargs.pop("state", None),
        start_sample=kwargs.pop("start_sample", 0),
        stop_sample=kwargs.pop("stop_sample", None),
    )
    return condition(
        scenario, cfg, grid_spec, engine=engine,
        mesh=kwargs.pop("mesh", None),
        rack_axis=kwargs.pop("rack_axis", "data"),
        stream=stream, **kwargs,
    )
