"""Fleet-scale aggregation (paper Appendix D, Fig. 13).

The campus load is the sum of per-rack loads; the DFT is linear, so for N
racks in synchrony  P_IT(t) = N * P_i(t)  and  S_IT(f) = N * S_i(f).
Per-rack compliance therefore composes: a hall of EasyRider racks meets the
same (beta, alpha, f_c) budget in aggregate.

This module simulates heterogeneous fleets — per-rack phase offsets
(staggered schedulers), per-rack power scales, rack failures mid-trace —
with the rack dimension vectorized (racks ride in the trailing axis of
every core function, which the Pallas kernels map onto the 128-wide lane
dimension).  For very large fleets the rack axis can be sharded over the
same device mesh the trainer uses (`shard_racks`).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import compliance, pdu


def synchronous_aggregate(rack_power: jax.Array, n_racks: int) -> jax.Array:
    """Eq. 19: P_IT = N * P_i for lockstep racks (per-unit of campus rating)."""
    return rack_power  # per-unit traces are scale-invariant (Eq. 20)


def staggered_fleet(
    rack_trace: jax.Array,  # (T,)
    n_racks: int,
    key: jax.Array,
    *,
    max_offset_samples: int = 0,
    scale_jitter: float = 0.0,
) -> jax.Array:
    """(T, n_racks) traces: rolled copies with optional per-rack scaling."""
    k1, k2 = jax.random.split(key)
    if max_offset_samples > 0:
        offsets = jax.random.randint(k1, (n_racks,), 0, max_offset_samples)
    else:
        offsets = jnp.zeros((n_racks,), jnp.int32)
    scales = 1.0 + scale_jitter * jax.random.uniform(k2, (n_racks,), minval=-1.0, maxval=1.0)

    def one(off, sc):
        return jnp.roll(rack_trace, off) * sc

    return jax.vmap(one, out_axes=1)(offsets, scales)


def apply_failures(
    traces: jax.Array,  # (T, R)
    fail_times: jax.Array,  # (R,) sample index at which the rack drops to idle
    p_idle: float = 0.1,
) -> jax.Array:
    """Racks drop to idle power at their failure time (-1 = never)."""
    t_idx = jnp.arange(traces.shape[0])[:, None]
    failed = (fail_times[None, :] >= 0) & (t_idx >= fail_times[None, :])
    return jnp.where(failed, p_idle, traces)


class FleetResult(NamedTuple):
    grid_traces: jax.Array  # (T, R) conditioned per-rack
    campus_rack: jax.Array  # (T,) mean per-unit unconditioned campus load
    campus_grid: jax.Array  # (T,) mean per-unit conditioned campus load
    report_rack: compliance.ComplianceReport
    report_grid: compliance.ComplianceReport


def condition_fleet(
    cfg: pdu.PDUConfig,
    traces: jax.Array,  # (T, R) per-unit rack traces
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 60,
    use_plan: bool = True,
) -> FleetResult:
    """Condition every rack with its own PDU; check campus compliance.

    The per-rack state is fully vectorized (rack axis rides through the
    scans), so this is one fused XLA computation whatever R is.
    ``use_plan=False`` selects the per-rack build+factor controller path
    (the seed cold-start baseline used by benchmarks).
    """
    r0 = traces[0]
    state = pdu.init_state(cfg, r0, soc0=soc0)
    grid, _, _ = pdu.condition(cfg, state, traces, qp_iters=qp_iters, use_plan=use_plan)
    campus_rack = jnp.mean(traces, axis=1)
    campus_grid = jnp.mean(grid, axis=1)
    return FleetResult(
        grid_traces=grid,
        campus_rack=campus_rack,
        campus_grid=campus_grid,
        report_rack=compliance.check(campus_rack, cfg.sample_dt, grid_spec),
        report_grid=compliance.check(campus_grid, cfg.sample_dt, grid_spec),
    )


# ----------------------------------------------------------------- streaming


class StreamingFleetResult(NamedTuple):
    campus_rack: jax.Array  # (T,) mean per-unit unconditioned campus load
    campus_grid: jax.Array  # (T,) mean per-unit conditioned campus load
    soc_mean: jax.Array  # (n_ctrl,) fleet-mean SoC per control interval
    report_rack: compliance.ComplianceReport
    report_grid: compliance.ComplianceReport
    state: pdu.PDUState  # final per-rack PDU state (the stream can resume)
    max_qp_residual: jax.Array  # worst per-interval QP primal residual seen


def condition_fleet_streaming(
    cfg: pdu.PDUConfig,
    traces: jax.Array | Callable[[int, int], jax.Array],
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 30,
    chunk_intervals: int = 16,
    total_samples: int | None = None,
    mesh: jax.sharding.Mesh | None = None,
    rack_axis: str = "data",
) -> StreamingFleetResult:
    """Campus-scale conditioning in time chunks with bounded working set.

    ``condition_fleet`` materializes the rack traces *and* the conditioned
    grid waveform as full (T, R) arrays — 2x the campus trace in HBM, which
    is what caps fleet size for hour-long traces.  This engine walks the
    trace in chunks of ``chunk_intervals`` controller intervals, donates
    the per-rack ``PDUState`` buffers between chunks, reduces each chunk to
    campus aggregates inside the jitted step (the per-rack grid waveform
    never leaves the chunk), and carries the controller's warm-started ADMM
    state across chunks via ``PDUState.qp_warm`` — so at equal ``qp_iters``
    the result is identical to the one-shot ``condition_fleet`` call while
    live memory stays O(chunk * R).  The default ``qp_iters=30`` assumes
    the warm-started plan path, where 30 iterations match the seed
    cold-start path's residual at 120 (EXPERIMENTS.md §Perf-4).

    ``traces`` is either a (T, R) array or a chunk provider
    ``f(start, length) -> (length, R)`` (with ``total_samples`` given), so
    hour-long campus traces can be synthesized or loaded on the fly without
    ever materializing (T, R) on the host either.  With ``mesh`` set, each
    chunk is placed rack-sharded (``shard_racks``) before the step, so the
    fleet conditions data-parallel across devices.
    """
    k = max(int(round(float(cfg.controller.dt) / cfg.sample_dt)), 1)
    chunk = max(int(chunk_intervals), 1) * k
    if callable(traces):
        if total_samples is None:
            raise ValueError("total_samples is required with a chunk provider")
        provider, t_total = traces, int(total_samples)
    else:
        provider, t_total = (lambda t0, n: traces[t0 : t0 + n]), traces.shape[0]

    state = pdu.init_state(cfg, provider(0, 1)[0], soc0=soc0)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def step(st, tr):
        grid, st2, telem = pdu.condition(cfg, st, tr, qp_iters=qp_iters)
        return (
            st2,
            jnp.mean(tr, axis=1),
            jnp.mean(grid, axis=1),
            jnp.mean(telem.soc, axis=1),
            jnp.max(telem.qp_residual),
        )

    campus_rack, campus_grid, soc_mean = [], [], []
    worst = jnp.asarray(0.0, jnp.float32)
    for t0 in range(0, t_total, chunk):
        n_real = min(chunk, t_total - t0)
        tr = provider(t0, n_real)
        if n_real < chunk:
            # ZOH-pad the trailing partial chunk to the full chunk shape so
            # `step` compiles exactly once; the pad is sliced off the campus
            # aggregates below.  (pdu.condition already ZOH-pads ragged
            # trailing controller intervals internally, so the carried state
            # sees the same hold — just for the remaining pad intervals too.)
            tr = jnp.concatenate(
                [tr, jnp.repeat(tr[-1:], chunk - n_real, axis=0)], axis=0
            )
        if mesh is not None:
            tr = shard_racks(tr, mesh, rack_axis)
        state, cr, cg, sm, resid = step(state, tr)
        campus_rack.append(cr[:n_real])
        campus_grid.append(cg[:n_real])
        soc_mean.append(sm[: -(-n_real // k)])
        worst = jnp.maximum(worst, resid)

    campus_rack = jnp.concatenate(campus_rack)
    campus_grid = jnp.concatenate(campus_grid)
    return StreamingFleetResult(
        campus_rack=campus_rack,
        campus_grid=campus_grid,
        soc_mean=jnp.concatenate(soc_mean),
        report_rack=compliance.check(campus_rack, cfg.sample_dt, grid_spec),
        report_grid=compliance.check(campus_grid, cfg.sample_dt, grid_spec),
        state=state,
        max_qp_residual=worst,
    )


def condition_scenario_streaming(
    cfg: pdu.PDUConfig,
    scenario,
    grid_spec: compliance.GridSpec,
    **kwargs,
) -> StreamingFleetResult:
    """Condition a declarative ``repro.power.scenario.Scenario`` fleet.

    The scenario's ``render(s, t0, n)`` is the chunk provider: each (n, R)
    chunk is synthesized on-device and conditioned in place, so campus-scale
    heterogeneous fleets (per-rack model workloads, staggered starts, fault
    cascades, diurnal inference blocks) stream end-to-end without a (T, R)
    host materialization.  This is the scenario-native successor to
    ``staggered_fleet`` + ``apply_failures``, which express offsets/failures
    by materializing and mutating whole trace arrays.
    """
    from repro.power import scenario as SC

    if abs(1.0 / scenario.sample_hz - cfg.sample_dt) > 1e-9:
        raise ValueError(
            f"scenario sample rate {scenario.sample_hz} Hz != PDU sample_dt "
            f"{cfg.sample_dt} s; build the PDU with sample_dt=1/sample_hz"
        )
    return condition_fleet_streaming(
        cfg,
        SC.chunk_provider(scenario),
        grid_spec,
        total_samples=scenario.total_samples,
        **kwargs,
    )


def shard_racks(traces: jax.Array, mesh: jax.sharding.Mesh, axis: str = "data") -> jax.Array:
    """Place the rack axis of a (T, R) trace array across a mesh axis so
    fleet conditioning runs data-parallel across devices."""
    spec = jax.sharding.PartitionSpec(None, axis)
    return jax.device_put(traces, jax.sharding.NamedSharding(mesh, spec))
