"""Fleet-scale aggregation (paper Appendix D, Fig. 13).

The campus load is the sum of per-rack loads; the DFT is linear, so for N
racks in synchrony  P_IT(t) = N * P_i(t)  and  S_IT(f) = N * S_i(f).
Per-rack compliance therefore composes: a hall of EasyRider racks meets the
same (beta, alpha, f_c) budget in aggregate.

This module simulates heterogeneous fleets — per-rack phase offsets
(staggered schedulers), per-rack power scales, rack failures mid-trace —
with the rack dimension vectorized (racks ride in the trailing axis of
every core function, which the Pallas kernels map onto the 128-wide lane
dimension).  For very large fleets the rack axis can be sharded over the
same device mesh the trainer uses (`shard_racks`).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import compliance, pdu


def synchronous_aggregate(rack_power: jax.Array, n_racks: int) -> jax.Array:
    """Eq. 19: P_IT = N * P_i for lockstep racks (per-unit of campus rating)."""
    return rack_power  # per-unit traces are scale-invariant (Eq. 20)


def staggered_fleet(
    rack_trace: jax.Array,  # (T,)
    n_racks: int,
    key: jax.Array,
    *,
    max_offset_samples: int = 0,
    scale_jitter: float = 0.0,
) -> jax.Array:
    """(T, n_racks) traces: rolled copies with optional per-rack scaling."""
    k1, k2 = jax.random.split(key)
    if max_offset_samples > 0:
        offsets = jax.random.randint(k1, (n_racks,), 0, max_offset_samples)
    else:
        offsets = jnp.zeros((n_racks,), jnp.int32)
    scales = 1.0 + scale_jitter * jax.random.uniform(k2, (n_racks,), minval=-1.0, maxval=1.0)

    def one(off, sc):
        return jnp.roll(rack_trace, off) * sc

    return jax.vmap(one, out_axes=1)(offsets, scales)


def apply_failures(
    traces: jax.Array,  # (T, R)
    fail_times: jax.Array,  # (R,) sample index at which the rack drops to idle
    p_idle: float = 0.1,
) -> jax.Array:
    """Racks drop to idle power at their failure time (-1 = never)."""
    t_idx = jnp.arange(traces.shape[0])[:, None]
    failed = (fail_times[None, :] >= 0) & (t_idx >= fail_times[None, :])
    return jnp.where(failed, p_idle, traces)


class FleetResult(NamedTuple):
    grid_traces: jax.Array  # (T, R) conditioned per-rack
    campus_rack: jax.Array  # (T,) mean per-unit unconditioned campus load
    campus_grid: jax.Array  # (T,) mean per-unit conditioned campus load
    report_rack: compliance.ComplianceReport
    report_grid: compliance.ComplianceReport


def condition_fleet(
    cfg: pdu.PDUConfig,
    traces: jax.Array,  # (T, R) per-unit rack traces
    grid_spec: compliance.GridSpec,
    *,
    soc0: float = 0.5,
    qp_iters: int = 60,
) -> FleetResult:
    """Condition every rack with its own PDU; check campus compliance.

    The per-rack state is fully vectorized (rack axis rides through the
    scans), so this is one fused XLA computation whatever R is.
    """
    r0 = traces[0]
    state = pdu.init_state(cfg, r0, soc0=soc0)
    grid, _, _ = pdu.condition(cfg, state, traces, qp_iters=qp_iters)
    campus_rack = jnp.mean(traces, axis=1)
    campus_grid = jnp.mean(grid, axis=1)
    return FleetResult(
        grid_traces=grid,
        campus_rack=campus_rack,
        campus_grid=campus_grid,
        report_rack=compliance.check(campus_rack, cfg.sample_dt, grid_spec),
        report_grid=compliance.check(campus_grid, cfg.sample_dt, grid_spec),
    )


def shard_racks(traces: jax.Array, mesh: jax.sharding.Mesh, axis: str = "data") -> jax.Array:
    """Place the rack axis of a (T, R) trace array across a mesh axis so
    fleet conditioning runs data-parallel across devices."""
    spec = jax.sharding.PartitionSpec(None, axis)
    return jax.device_put(traces, jax.sharding.NamedSharding(mesh, spec))
