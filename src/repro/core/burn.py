"""Software GPU-burn baseline (paper §7.3, Appendix C).

The paper's most directly comparable software-only mitigation: inject GEMM
"burn" kernels so GPU power never falls (or rises) faster than the grid
allows.  We reproduce both algorithms:

  * **Algorithm 1 (calibration)** — learn a linear duty-cycle -> power map
    P(d) = a*d + b by sweeping duty cycles against a device power model
    (our analytic stand-in for NVML measurement) and fitting least squares,
    then invert to d(P).

  * **Algorithm 2 (burn-augmented schedule)** — warmup ramp from idle to
    training power, checkpoint compensation (other ranks burn while rank 0
    saves), cooldown ramp at job end.  At trace level this is exactly the
    *minimal ramp-compliant upper envelope* of the rack trace: burn can only
    ADD power, so the conditioned trace is the smallest e(t) >= rack(t) with
    |de/dt| <= beta.  We compute it with a forward pass (bounds downward
    ramps) and a backward pass (pre-ramps before fast rises — the paper's
    scheduled warmup, which requires knowing job structure in advance; we
    grant the baseline this omniscience, which *favors* the baseline).

The headline comparison (paper Fig. 11): burn consumes ~19% more energy
than rack + EasyRider, because burn must hold power *at the peak* while
EasyRider's battery lets grid power sag toward the average.

The GEMM burn compute itself is `repro.kernels.gemm_burn` (MXU-aligned
Pallas kernel with a FLOP knob); this module is the scheduling layer.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DutyCalibration(NamedTuple):
    a: jax.Array  # slope  [power fraction per unit duty]
    b: jax.Array  # intercept (idle power fraction)
    residual: jax.Array


def true_duty_power(duty: jax.Array, p_idle: float, p_peak: float) -> jax.Array:
    """Ground-truth device behavior for the calibration harness."""
    return p_idle + duty * (p_peak - p_idle)


def calibrate(
    key: jax.Array,
    p_idle: float,
    p_peak: float,
    *,
    n_duties: int = 16,
    samples_per_duty: int = 32,
    noise_std: float = 0.01,
) -> DutyCalibration:
    """Algorithm 1: sweep duty cycles, sample noisy power, fit linear map."""
    duties = jnp.linspace(0.0, 1.0, n_duties)
    clean = true_duty_power(duties, p_idle, p_peak)
    noise = noise_std * jax.random.normal(key, (n_duties, samples_per_duty))
    measured = jnp.mean(clean[:, None] + noise, axis=1)
    # Least-squares fit P(d) = a d + b.
    x = jnp.stack([duties, jnp.ones_like(duties)], axis=1)
    coef, res, _, _ = jnp.linalg.lstsq(x, measured)
    a, b = coef[0], coef[1]
    resid = jnp.sqrt(jnp.mean((x @ coef - measured) ** 2))
    return DutyCalibration(a=a, b=b, residual=resid)


def duty_for_power(cal: DutyCalibration, p_target: jax.Array) -> jax.Array:
    """Inverse mapping d(P) = clip((P - b)/a, 0, 1) (Algorithm 1, line 12)."""
    return jnp.clip((p_target - cal.b) / cal.a, 0.0, 1.0)


def ramp_compliant_envelope(rack_power: jax.Array, dt: float, beta: float) -> jax.Array:
    """Minimal e(t) >= rack(t) with |de/dt| <= beta (per-unit).

    Forward pass bounds downward ramps (burn fills dips as they happen);
    backward pass bounds upward ramps (scheduled pre-warmup before rises).
    """
    step = beta * dt

    def fwd(prev, r):
        e = jnp.maximum(r, prev - step)
        return e, e

    _, e_fwd = jax.lax.scan(fwd, rack_power[0], rack_power)

    def bwd(nxt, e):
        e2 = jnp.maximum(e, nxt - step)
        return e2, e2

    _, e_rev = jax.lax.scan(bwd, e_fwd[-1], e_fwd[::-1])
    return e_rev[::-1]


class BurnSchedule(NamedTuple):
    conditioned: jax.Array  # grid-visible power (rack + burn)
    burn_power: jax.Array  # extra power burned at each sample
    duty: jax.Array  # duty cycle commanded to the burn kernel
    energy_overhead_frac: jax.Array  # extra energy / rack energy


def burn_schedule(
    rack_power: jax.Array,
    dt: float,
    beta: float,
    cal: DutyCalibration,
    *,
    warmup_s: float = 30.0,
    p_warm: float = 0.1,
) -> BurnSchedule:
    """Algorithm 2 at trace level: warmup ramp + compensation + cooldown.

    ``warmup_s`` of lerp from ``p_warm`` to the first training power level is
    prepended (paper delays the trace ~41 s for this); the cooldown is the
    backward pass of the envelope.
    """
    n_warm = int(round(warmup_s / dt))
    warm_rack = jnp.full((n_warm,) + rack_power.shape[1:], p_warm, rack_power.dtype)
    full_rack = jnp.concatenate([warm_rack, rack_power], axis=0)
    # The backward pass of the envelope produces the scheduled pre-warmup
    # ramp through the prepended idle segment automatically.
    env = ramp_compliant_envelope(full_rack, dt, beta)
    burn = env - full_rack
    duty = duty_for_power(cal, env)
    rack_energy = jnp.sum(full_rack, axis=0) * dt
    overhead = jnp.sum(burn, axis=0) * dt / rack_energy
    return BurnSchedule(
        conditioned=env, burn_power=burn, duty=duty, energy_overhead_frac=overhead
    )


def compare_energy(
    rack_power: jax.Array,
    grid_power_easyrider: jax.Array,
    burn_conditioned: jax.Array,
    dt: float,
    *,
    soc_delta: jax.Array | float = 0.0,
    q_max_seconds: jax.Array | float = 0.0,
) -> dict:
    """Paper Fig. 11 headline numbers.

    EasyRider grid energy = integral of the conditioned grid trace (battery
    round-trip losses included); energy still parked in the battery at the
    window edge (soc_delta * q_max) is credited back so finite windows don't
    misstate the overhead.  Burn energy = integral of the burn-filled trace.
    Returns ratios relative to the raw rack energy.
    """
    e_rack = jnp.sum(rack_power) * dt
    e_ez = jnp.sum(grid_power_easyrider) * dt - jnp.asarray(soc_delta) * jnp.asarray(
        q_max_seconds
    )
    e_burn = jnp.sum(burn_conditioned) * dt
    return {
        "rack_energy": e_rack,
        "easyrider_energy": e_ez,
        "burn_energy": e_burn,
        "easyrider_overhead_frac": (e_ez - e_rack) / e_rack,
        "burn_overhead_frac": (e_burn - e_rack) / e_rack,
        "burn_vs_easyrider_frac": (e_burn - e_ez) / e_ez,
    }
