"""Passive input filter (paper §5.1) as an exact discrete state-space system.

The circuit (paper Fig. 5) is a second-order LC low-pass between the DC
busbar and the rack node, with an R-L damping leg in *parallel with the
filter inductor* (the standard Erickson R-L parallel damping — chosen
because the paper states the damping circuit "is inactive when the rack
power is steady": at DC the leg sits across a shorted inductor, carries the
inductor's DC split but dissipates ~nothing, and only absorbs energy during
transients near the LC resonance):

    busbar --+--[L_F]--------+----+---> node (DC-DC input)
             |               |    |
             +--[R_Da+L_Da]--+  [C_F]
                                  |
                                 gnd

States  x = [i_L, v_C, i_D]  (filter-inductor current, capacitor voltage,
damping-leg current).  Inputs u = [v_in, i_load] where ``i_load`` is the
current drawn at the node by the DC-DC stage (rack + battery branch).
The grid-side observable is the busbar current ``i_L + i_D``.

Continuous dynamics (KCL/KVL):

    L_F  di_L/dt = v_in - v_C
    L_Da di_D/dt = v_in - v_C - R_Da i_D
    C_F  dv_C/dt = i_L + i_D - i_load

This is linear, so we discretize **exactly** under a zero-order hold using
the augmented matrix exponential, preserving the paper's "filters behave
exactly as designed" property at any sample rate.  The transfer function
from rack current to grid current,

    H(s) = (i_L + i_D)(s) / i_load(s)   (v_in held fixed),

is second-order with cutoff f_f ~= 1/(2*pi*sqrt(L_F C_F)) and rolls off at
-40 dB/decade (factor 100 per 10x in frequency), matching paper §5.4.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field


@pytree_dataclass
class LCFilterParams:
    """Component values for the input filter (SI units)."""

    l_f: jax.Array  # filter inductance [H]
    c_f: jax.Array  # filter capacitance [F]
    r_da: jax.Array  # damping resistance [Ohm]
    l_da: jax.Array  # damping inductance [H]

    @staticmethod
    def create(l_f: float, c_f: float, r_da: float, l_da: float) -> "LCFilterParams":
        return LCFilterParams(
            l_f=jnp.asarray(l_f, jnp.float32),
            c_f=jnp.asarray(c_f, jnp.float32),
            r_da=jnp.asarray(r_da, jnp.float32),
            l_da=jnp.asarray(l_da, jnp.float32),
        )

    def cutoff_hz(self) -> jax.Array:
        return 1.0 / (2.0 * jnp.pi * jnp.sqrt(self.l_f * self.c_f))


def continuous_abc(p: LCFilterParams):
    """(A, B, C) continuous state-space matrices as numpy (for exactness)."""
    l_f = float(p.l_f)
    c_f = float(p.c_f)
    r_da = float(p.r_da)
    l_da = float(p.l_da)
    a = np.array(
        [
            [0.0, -1.0 / l_f, 0.0],
            [1.0 / c_f, 0.0, 1.0 / c_f],
            [0.0, -1.0 / l_da, -r_da / l_da],
        ]
    )
    b = np.array(
        [
            [1.0 / l_f, 0.0],
            [0.0, -1.0 / c_f],
            [1.0 / l_da, 0.0],
        ]
    )
    c = np.array([[1.0, 0.0, 1.0]])  # observe grid-side current i_L + i_D
    return a, b, c


def discretize_zoh(a: np.ndarray, b: np.ndarray, dt: float):
    """Exact zero-order-hold discretization via the augmented exponential.

    expm([[A, B], [0, 0]] * dt) = [[Ad, Bd], [0, I]].
    """
    n, m = b.shape
    aug = np.zeros((n + m, n + m))
    aug[:n, :n] = a
    aug[:n, n:] = b
    # scipy-free matrix exponential (Pade via jax, evaluated in fp64 numpy).
    import scipy.linalg  # available in this environment

    e = scipy.linalg.expm(aug * dt)
    ad = e[:n, :n]
    bd = e[:n, n:]
    return ad, bd


@pytree_dataclass
class DiscreteFilter:
    """x[t+1] = Ad x[t] + Bd u[t];  y[t] = C x[t] (+ D u[t])."""

    ad: jax.Array  # (n, n)
    bd: jax.Array  # (n, m)
    c: jax.Array  # (p, n)
    dt: float = static_field()


def make_discrete_filter(p: LCFilterParams, dt: float) -> DiscreteFilter:
    a, b, c = continuous_abc(p)
    ad, bd = discretize_zoh(a, b, dt)
    return DiscreteFilter(
        ad=jnp.asarray(ad, jnp.float32),
        bd=jnp.asarray(bd, jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        dt=float(dt),
    )


def steady_state(filt: DiscreteFilter, u: jax.Array) -> jax.Array:
    """State for a constant input u (solves (I - Ad) x = Bd u)."""
    n = filt.ad.shape[0]
    return jnp.linalg.solve(jnp.eye(n) - filt.ad, filt.bd @ u)


def simulate(
    filt: DiscreteFilter,
    x0: jax.Array,
    u: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Run the filter over inputs ``u``.

    Args:
      filt: discretized filter.
      x0:   initial state, shape (..., n) — leading dims broadcast over racks.
      u:    inputs, shape (T, ..., m).

    Returns:
      (y, x_final): outputs (T, ..., p) and final state (..., n).
    """

    def step(x, u_t):
        x_next = x @ filt.ad.T + u_t @ filt.bd.T
        y_t = x @ filt.c.T
        return x_next, y_t

    x_final, y = jax.lax.scan(step, x0, u)
    return y, x_final


def transfer_function_rack_to_grid(p: LCFilterParams, f_hz: jax.Array) -> jax.Array:
    """|H(j*2*pi*f)| from rack (node) current to grid current.

    Derived from the continuous system with v_in fixed (small-signal):
        H(s) = Z_C(s) / (Z_C(s) + Z_series(s))
    where Z_C = 1/(sC_F) and Z_series = sL_F || (R_Da + sL_Da).
    """
    s = 2j * jnp.pi * f_hz
    z_c = 1.0 / (s * p.c_f)
    z_lf = s * p.l_f
    z_d = p.r_da + s * p.l_da
    z_series = z_lf * z_d / (z_lf + z_d)
    h = z_c / (z_c + z_series)
    return jnp.abs(h)


def resonance_peak_db(p: LCFilterParams, n_points: int = 2048) -> jax.Array:
    """Worst-case magnification (dB) of the damped filter near resonance."""
    f0 = p.cutoff_hz()
    f = jnp.logspace(jnp.log10(f0 / 30.0), jnp.log10(f0 * 30.0), n_points)
    mag = transfer_function_rack_to_grid(p, f)
    return 20.0 * jnp.log10(jnp.max(mag))
