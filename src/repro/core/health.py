"""Battery health telemetry: online cycle counting + aging (paper §2, §6).

The paper's lifetime claim — "a software system continually monitors the
energy storage system to maximize its lifetime in the presence of frequent
charge/discharge cycles" — needs an *online* wear model: the per-iteration
workload cycling that EasyRider absorbs (sub-second, shallow) and the
storage-mode excursions the outer loop commands (minutes, deep) stress the
battery in completely different ways, and a post-hoc rainflow pass over an
unbounded campus stream is exactly the kind of whole-trace analysis the
streaming engines exist to avoid.

This module keeps all wear telemetry in a constant-size ``HealthState``
that rides the conditioning scan (one per rack, batched):

  * **Half-cycle counter** — a scan-carried turning-point state machine
    (last extremum, current direction): every SoC direction reversal closes
    a half-cycle of depth ``|extremum - previous extremum|``.  On
    monotone-segment traces (sawtooth / iteration waves) this is exactly
    the rainflow half-cycle count; nested-hysteresis traces split large
    cycles at interior reversals (conservative: small cycles are never
    merged away, and with ``kappa > 1`` splitting under-counts damage of
    the enclosing deep cycle, so pair it with the throughput EFC below).
  * **Throughput accumulators** — charge/discharge SoC movement summed per
    branch: equivalent full cycles and (via the efficiency split of
    ``ess.battery_power_from_soc_delta``) the terminal-side energy a BMS
    coulomb counter would report.
  * **SoC-stress + calendar accumulators** — running sums of SoC and SoC^2
    (mean / variance of the operating point) feeding a linear SoC-weighted
    calendar-aging model.

Damage model (equivalent-full-cycle Wöhler form): a half-cycle of depth
``d`` at mid-SoC ``m`` consumes ``0.5 * w(m) * d**kappa / n_cycles_ref`` of
cycle life, with ``w(m) = max(1 + soc_stress_gain*(m - soc_ref), 0)`` —
cycling high in the SoC window wears faster.  Calendar life drains at rate
``(1 + cal_soc_gain*(soc - soc_ref)) / calendar_life_s``.  Capacity fade is
``eol_fade`` at combined damage 1; projected lifetime extrapolates the
observed damage rate.

Chunk-invariance contract: the cycle counter folds sample-by-sample inside
a ``lax.scan`` whose carry is the state (bit-identical under ANY split of
the SoC stream); the throughput/stress integrals fold one block reduction
per ``update`` call — and every conditioning path calls ``update`` exactly
once per controller interval, so scanned / host-loop / one-shot engines
(and resumed streams) produce bitwise-equal ``HealthState``s by
construction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ess
from repro.utils import pytree_dataclass, static_field


@pytree_dataclass
class HealthParams:
    """Aging-model constants (per-unit SoC domain; times in seconds)."""

    n_cycles_ref: jax.Array  # cycle life at 100% DoD, w = 1 (full cycles)
    soc_stress_gain: jax.Array  # cycle-wear slope vs mid-SoC
    cal_soc_gain: jax.Array  # calendar-wear slope vs SoC
    soc_ref: jax.Array  # reference SoC for both stress weights
    calendar_life_s: jax.Array  # calendar life at soc_ref [s]
    eol_fade: jax.Array  # capacity-fade fraction at end of life
    rest_eps: jax.Array  # SoC hysteresis below which movement is "rest"
    kappa: float = static_field(default=2.0)  # Wöhler DoD exponent

    @staticmethod
    def create(
        n_cycles_ref: float = 4000.0,
        soc_stress_gain: float = 0.6,
        cal_soc_gain: float = 0.8,
        soc_ref: float = 0.5,
        calendar_life_years: float = 12.0,
        eol_fade: float = 0.2,
        rest_eps: float = 0.0,
        kappa: float = 2.0,
    ) -> "HealthParams":
        f = lambda v: jnp.asarray(v, jnp.float32)
        return HealthParams(
            n_cycles_ref=f(n_cycles_ref),
            soc_stress_gain=f(soc_stress_gain),
            cal_soc_gain=f(cal_soc_gain),
            soc_ref=f(soc_ref),
            calendar_life_s=f(calendar_life_years * 365.25 * 86400.0),
            eol_fade=f(eol_fade),
            rest_eps=f(rest_eps),
            kappa=float(kappa),
        )


class HealthState(NamedTuple):
    """Constant-size wear telemetry carried across samples/chunks/resumes.

    All leaves broadcast over leading rack dimensions.  ``samples`` is an
    exact integer count.
    """

    prev_soc: jax.Array  # last SoC sample seen
    last_ext: jax.Array  # SoC at the last direction reversal
    direction: jax.Array  # +1 rising / -1 falling / 0 not yet moved
    half_cycles: jax.Array  # closed half-cycle count
    cycle_damage: jax.Array  # sum of 0.5 * w(mid) * depth**kappa
    max_dod: jax.Array  # deepest closed half-cycle
    charge_soc: jax.Array  # sum of positive SoC steps (capacity fractions)
    discharge_soc: jax.Array  # sum of negative SoC steps (magnitudes)
    soc_sum: jax.Array  # running sum of SoC samples
    soc_sq_sum: jax.Array  # running sum of SoC^2 samples
    samples: jax.Array  # int32 samples observed


def init_state(
    soc0: jax.Array | float = 0.5, batch_shape: tuple[int, ...] | None = None
) -> HealthState:
    s0 = jnp.asarray(soc0, jnp.float32)
    if batch_shape is not None:
        s0 = jnp.broadcast_to(s0, batch_shape)
    # One allocation per leaf: the engines donate the whole state, and
    # donating the same buffer twice (aliased leaves) is an XLA error.
    z = lambda: jnp.zeros(jnp.shape(s0), jnp.float32)
    return HealthState(
        prev_soc=s0,
        last_ext=jnp.array(s0, copy=True),
        direction=z(),
        half_cycles=z(),
        cycle_damage=z(),
        max_dod=z(),
        charge_soc=z(),
        discharge_soc=z(),
        soc_sum=z(),
        soc_sq_sum=z(),
        samples=jnp.zeros(jnp.shape(s0), jnp.int32),
    )


def reinit_where(
    state: HealthState, mask: jax.Array, soc0: jax.Array | float
) -> HealthState:
    """Reset the masked racks' wear telemetry to a fresh history at ``soc0``.

    The safe-mode sanitizer uses this when quarantining a corrupted rack:
    its accumulators are unrecoverable (any of them may be the non-finite
    leaf), so the honest telemetry is "history restarted here" — the
    quarantine counter records that the restart happened.  An all-false
    mask is bitwise identity.
    """
    mask = mask.astype(bool)
    s0 = jnp.broadcast_to(jnp.asarray(soc0, jnp.float32), state.prev_soc.shape)
    pick = lambda new, old: jnp.where(mask, new, old)
    zf = jnp.zeros_like(state.direction)
    return HealthState(
        prev_soc=pick(s0, state.prev_soc),
        last_ext=pick(s0, state.last_ext),
        direction=pick(zf, state.direction),
        half_cycles=pick(zf, state.half_cycles),
        cycle_damage=pick(zf, state.cycle_damage),
        max_dod=pick(zf, state.max_dod),
        charge_soc=pick(zf, state.charge_soc),
        discharge_soc=pick(zf, state.discharge_soc),
        soc_sum=pick(zf, state.soc_sum),
        soc_sq_sum=pick(zf, state.soc_sq_sum),
        samples=pick(jnp.zeros_like(state.samples), state.samples),
    )


def _pow_depth(depth: jax.Array, kappa: float) -> jax.Array:
    """depth**kappa with a cheap repeated-multiply path for integer kappa
    (the scan body evaluates this every sample; ``jnp.power`` is the single
    most expensive op it could contain)."""
    if float(kappa) == 1.0:
        return depth
    if float(kappa).is_integer() and 2 <= int(kappa) <= 4:
        out = depth
        for _ in range(int(kappa) - 1):
            out = out * depth
        return out
    return jnp.power(depth, kappa)


def step_consts(p: HealthParams) -> tuple:
    """(c0, c1, rest_eps, kappa) for ``update_consts``, with the mid-SoC
    stress weight constants folded:
    ``0.5 * max(1 + g*(0.5*(prev+ext) - ref), 0) == max(c0 + c1*(prev+ext), 0)``.

    Computed as host floats (params must be concrete — the same convention
    ``pdu.condition`` applies to ``ESSParams``), so the conditioning path
    bakes them into its compiled step.
    """
    g = float(p.soc_stress_gain)
    ref = float(p.soc_ref)
    return 0.5 * (1.0 - g * ref), 0.25 * g, float(p.rest_eps), p.kappa


def update_consts(
    consts: tuple, state: HealthState, soc: jax.Array
) -> HealthState:
    """Fold one (T, ...) block of SoC samples with prebaked ``step_consts``.

    Hybrid fold, the profiled optimum at fleet width: only the genuinely
    sequential turning-point machine rides a ``lax.scan`` (5 small
    carries — a fatter scan spills the CPU loop's L1 working set), while
    the throughput/stress integrals are vectorized block reductions.
    Consequence for reproducibility: the scan-carried leaves (extremum,
    direction, half-cycle count, cycle damage, max DoD) are bit-identical
    under ANY split of the stream; the reduction leaves (charge/discharge/
    SoC sums) are bit-identical under any split into the SAME blocks — and
    every conditioning path folds exactly one controller interval per
    block, so scanned / host-loop / one-shot engines agree bitwise on the
    whole state.
    """
    c0, c1, eps, kappa = consts
    prev_t = jnp.concatenate([state.prev_soc[None], soc[:-1]], axis=0)
    delta = soc - prev_t
    step_dir = jnp.where(
        delta > eps, 1.0, jnp.where(delta < -eps, -1.0, 0.0)
    )

    def body(carry, inp):
        last_ext, direction, half_cycles, damage, max_dod = carry
        prev, sd = inp
        # A reversal: the new movement opposes the established direction.
        rev = (sd * direction) < 0.0
        revf = jnp.where(rev, 1.0, 0.0)
        depth = jnp.abs(prev - last_ext)
        half_w = jnp.maximum(c0 + c1 * (prev + last_ext), 0.0)
        dmg = half_w * _pow_depth(depth, kappa)
        return (
            jnp.where(rev, prev, last_ext),
            jnp.where(sd != 0.0, sd, direction),
            half_cycles + revf,
            damage + revf * dmg,
            jnp.maximum(max_dod, revf * depth),
        ), None

    (last_ext, direction, half_cycles, damage, max_dod), _ = jax.lax.scan(
        body,
        (state.last_ext, state.direction, state.half_cycles,
         state.cycle_damage, state.max_dod),
        (prev_t, step_dir),
    )
    return HealthState(
        prev_soc=soc[-1],
        last_ext=last_ext,
        direction=direction,
        half_cycles=half_cycles,
        cycle_damage=damage,
        max_dod=max_dod,
        charge_soc=state.charge_soc + jnp.sum(jnp.maximum(delta, 0.0), axis=0),
        discharge_soc=state.discharge_soc
        + jnp.sum(jnp.maximum(-delta, 0.0), axis=0),
        soc_sum=state.soc_sum + jnp.sum(soc, axis=0),
        soc_sq_sum=state.soc_sq_sum + jnp.sum(soc * soc, axis=0),
        samples=state.samples + jnp.int32(soc.shape[0]),
    )


def update(
    p: HealthParams,
    state: HealthState,
    soc: jax.Array,  # (T, ...) SoC trace block
    dt: float,
) -> HealthState:
    """Fold one block of SoC samples into the health state.

    ``dt`` is only used for the integer sample count; time integrals are
    scaled in the derived reports, so a block can be folded before its
    dt-dependent interpretation is fixed.
    """
    del dt  # time-scaling lives in the derived reports (samples * dt)
    return update_consts(step_consts(p), state, soc)


# ------------------------------------------------------------------ derived


def elapsed_seconds(state: HealthState, dt: float) -> jax.Array:
    return state.samples.astype(jnp.float32) * dt


def equivalent_full_cycles(state: HealthState) -> jax.Array:
    """Throughput EFC: total |dSoC| / 2 (one EFC = one full charge+discharge)."""
    return 0.5 * (state.charge_soc + state.discharge_soc)


def terminal_throughput_s(ep: ess.ESSParams, state: HealthState) -> jax.Array:
    """Terminal-side energy throughput [s * P_RATED]: what a BMS coulomb
    counter sees, via the branch split of ``ess.battery_power_from_soc_delta``
    (charging draws 1/eta_c per unit stored; discharging delivers eta_d)."""
    return ep.q_max * (state.charge_soc / ep.eta_c + state.discharge_soc * ep.eta_d)


def cycle_life_fraction(p: HealthParams, state: HealthState) -> jax.Array:
    """Fraction of cycle life consumed (the controller's wear signal)."""
    return state.cycle_damage / p.n_cycles_ref


def calendar_life_fraction(
    p: HealthParams, state: HealthState, dt: float
) -> jax.Array:
    """Fraction of calendar life consumed, SoC-weighted.

    The linear stress factor ``1 + g*(soc - soc_ref)`` integrates to a
    closed form of the additive accumulators — no per-sample exp needed:
    ``integral = elapsed + g * (soc_sum*dt - soc_ref * elapsed)``.
    """
    t = elapsed_seconds(state, dt)
    stress_t = t + p.cal_soc_gain * (state.soc_sum * dt - p.soc_ref * t)
    return jnp.maximum(stress_t, 0.0) / p.calendar_life_s


def capacity_fade(p: HealthParams, state: HealthState, dt: float) -> jax.Array:
    """Capacity-fade fraction: ``eol_fade`` at combined damage 1."""
    frac = cycle_life_fraction(p, state) + calendar_life_fraction(p, state, dt)
    return p.eol_fade * frac


def projected_lifetime_s(
    p: HealthParams, state: HealthState, dt: float
) -> jax.Array:
    """Extrapolated time to end of life at the observed damage rate."""
    t = elapsed_seconds(state, dt)
    frac = cycle_life_fraction(p, state) + calendar_life_fraction(p, state, dt)
    return jnp.where(frac > 0.0, t / jnp.maximum(frac, 1e-30), jnp.inf)


class HealthReport(NamedTuple):
    """Derived per-rack wear report (leaves broadcast over rack dims)."""

    efc: jax.Array  # equivalent full cycles (throughput)
    half_cycles: jax.Array
    max_dod: jax.Array
    throughput_s: jax.Array  # terminal energy throughput [s * P_RATED]
    cycle_life_frac: jax.Array
    calendar_life_frac: jax.Array
    capacity_fade: jax.Array
    projected_life_s: jax.Array
    mean_soc: jax.Array
    soc_std: jax.Array
    elapsed_s: jax.Array


def report(
    p: HealthParams, ep: ess.ESSParams, state: HealthState, dt: float
) -> HealthReport:
    n = jnp.maximum(state.samples.astype(jnp.float32), 1.0)
    mean = state.soc_sum / n
    var = jnp.maximum(state.soc_sq_sum / n - mean * mean, 0.0)
    return HealthReport(
        efc=equivalent_full_cycles(state),
        half_cycles=state.half_cycles,
        max_dod=state.max_dod,
        throughput_s=terminal_throughput_s(ep, state),
        cycle_life_frac=cycle_life_fraction(p, state),
        calendar_life_frac=calendar_life_fraction(p, state, dt),
        capacity_fade=capacity_fade(p, state, dt),
        projected_life_s=projected_lifetime_s(p, state, dt),
        mean_soc=mean,
        soc_std=jnp.sqrt(var),
        elapsed_s=elapsed_seconds(state, dt),
    )


def fleet_summary(rep: HealthReport, *, json_safe: bool = False) -> dict:
    """Campus-level headline numbers from a per-rack report (host floats).

    An empty wear history projects an INFINITE lifetime, and ``float('inf')``
    is not valid JSON — ``json.dumps`` emits the non-standard ``Infinity``
    literal that strict parsers (and most log pipelines) reject.  With
    ``json_safe=True`` every non-finite value is clamped to ``None`` (JSON
    null), so the summary always survives
    ``json.dumps(..., allow_nan=False)`` — the operator service's audit log
    writes it this way.
    """
    import math

    import numpy as np

    a = lambda x: np.asarray(x)
    out = {
        "efc_mean": float(a(rep.efc).mean()),
        "efc_max": float(a(rep.efc).max()),
        "half_cycles_mean": float(a(rep.half_cycles).mean()),
        "worst_dod": float(a(rep.max_dod).max()),
        "fade_mean": float(a(rep.capacity_fade).mean()),
        "fade_max": float(a(rep.capacity_fade).max()),
        "projected_life_years_min": float(
            a(rep.projected_life_s).min() / (365.25 * 86400.0)
        ),
        "mean_soc": float(a(rep.mean_soc).mean()),
    }
    if json_safe:
        out = {k: (v if math.isfinite(v) else None) for k, v in out.items()}
    return out


def chunk_aggregates(p: HealthParams, state: HealthState, dt: float) -> jax.Array:
    """(3,) fleet snapshot for streaming telemetry: [mean EFC, max fade,
    max closed-half-cycle DoD].  Cheap enough to evaluate at every chunk."""
    fade = capacity_fade(p, state, dt)
    return jnp.stack(
        [
            jnp.mean(equivalent_full_cycles(state)),
            jnp.max(fade),
            jnp.max(state.max_dod),
        ]
    )
